// Known-bad fixture for the lint_allow rule: an escape comment without
// a reason is itself a violation and suppresses nothing.

fn decode(buf: &[u8]) -> u8 {
    // lint:allow(panic_safety)
    buf[0]
}

fn other(buf: &[u8]) -> u8 {
    // lint:allow(made_up_rule) a reason that cannot save an unknown rule
    buf[1]
}
