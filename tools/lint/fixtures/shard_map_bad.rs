// Known-bad fixture for the determinism rule in the sharded ingest
// design space: a shard map keyed by HashMap, written the way it must
// NOT be. Iteration order over a HashMap depends on the hasher's
// per-process random seed, so draining shards through one would make
// merge order — and therefore the f64 accumulator bit pattern — vary
// run to run. The real aggregator uses fixed spans indexed by shard id.

use std::collections::{HashMap, HashSet};

fn merge_shards(shards: HashMap<usize, Vec<f64>>, acc: &mut Vec<f64>) {
    let started = std::time::Instant::now(); // wall clock in scoped code
    let mut seen: HashSet<usize> = HashSet::new();
    for (id, seg) in shards {
        // nondeterministic visit order: acc depends on the hasher seed
        if seen.insert(id) {
            acc.extend_from_slice(&seg);
        }
    }
    let _ = started.elapsed();
}
