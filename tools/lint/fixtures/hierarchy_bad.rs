// Known-bad fixture for the hierarchical aggregation plane (PR 10).
// orchestrator/hierarchy.rs sits in BOTH rule scopes: a site
// aggregator folds wire-delivered member updates (panic_safety — a
// hostile member must produce an Err, never a panic) and its fold
// order underwrites the two-tier ≡ flat bit-identity claim
// (determinism — no hash-order iteration, no wall-clock in the fold).
// Every construct below is a shape the real module must never contain.

use std::collections::HashMap;
use std::time::Instant;

fn fold_site_round(updates: &[Vec<f32>], weights: &HashMap<u64, f64>) -> Vec<f32> {
    let started = Instant::now(); // wall-clock inside the fold
    let first = updates[0].clone(); // indexing a wire-provided slice
    let w = weights.get(&0).unwrap(); // unwrap on peer-controlled data
    assert!(*w > 0.0); // assert! on a wire value
    let _ = started;
    let mut out = first;
    let head = out.first_mut().expect("empty update"); // expect
    *head *= *w as f32;
    out
}
