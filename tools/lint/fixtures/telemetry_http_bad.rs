// Known-bad fixture for the panic_safety rule in the telemetry
// subsystem: a hand-rolled HTTP request parser the way it must NOT be
// written. telemetry/ is wire-reachable (any scraper or operator can
// send arbitrary bytes), so every construct below must be flagged.

fn parse_request(head: &str) -> (String, String) {
    // a malformed request line has no second token: unwrap panics
    let mut parts = head.split_whitespace();
    let method = parts.next().unwrap().to_string(); // unwrap
    let target = parts.next().expect("no target").to_string(); // expect
    let first = head.as_bytes()[0]; // indexing
    assert!(first != b' '); // assert!
    (method, target)
}
