// Known-bad fixture for the panic_safety rule in the ingest worker
// pool: the shapes that caused the PR 8 bugfix, written the way they
// must NOT be. util/parallel.rs joined PANIC_SCOPE because a panicking
// worker thread must surface via `resume_unwind`, never via a second
// panic on the server thread — `join().unwrap()` swallows the payload
// and double-faults the hot path.

fn drain_pool(handles: Vec<std::thread::JoinHandle<()>>, queues: &[Vec<u64>]) -> u64 {
    let first = queues[0].len() as u64; // indexing
    for h in handles {
        h.join().unwrap(); // unwrap on a join result
    }
    let cap = std::thread::available_parallelism().expect("no cpus"); // expect
    assert_eq!(first, 0); // assert_eq!
    cap.get() as u64
}
