// Known-good fixture for the panic_safety rule: fallible decode done
// right, plus every legitimate escape the rule recognises. Zero
// unallowed findings expected.

fn decode(buf: &[u8], opt: Option<u32>) -> Result<u32, String> {
    let tag = *buf.first().ok_or("empty buffer")?; // get, not index
    let all = &buf[..]; // full-range slice is infallible
    let v = opt.ok_or("missing")?;
    debug_assert!(tag < 7); // compiled out in release: not flagged
    // the token inside a string is data, not code:
    let s = "never .unwrap() here";
    // lint:allow(panic_safety) tag already validated against the frame header above
    let first = buf[0];
    let _ = (all, s, first);
    Ok(v)
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_panic_freely() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
        let b = [1u8, 2];
        assert!(b[1] == 2);
    }
}
