// Known-good fixture for the determinism rule: ordered collections and
// seeded randomness only. Zero findings expected.

use std::collections::BTreeMap;
use std::collections::BTreeSet;

fn plan(ids: &[u32], seed: u64) -> Vec<u32> {
    let mut chosen: BTreeSet<u32> = BTreeSet::new();
    let scores: BTreeMap<u32, f64> = BTreeMap::new();
    let _ = (scores, seed); // a seeded Rng would be constructed here
    for &id in ids {
        chosen.insert(id);
    }
    let mut out: Vec<u32> = chosen.into_iter().collect();
    out.sort_unstable();
    out
}
