// Known-bad fixture for the determinism rule: hash-ordered collections
// and ambient time/entropy in a cohort-order-critical module.

use std::collections::HashMap;
use std::collections::HashSet;
use std::time::Instant;

fn plan(ids: &[u32]) -> Vec<u32> {
    let mut chosen: HashSet<u32> = HashSet::new();
    let scores: HashMap<u32, f64> = HashMap::new();
    let t = Instant::now();
    let wall = std::time::SystemTime::now();
    let _ = (t, wall, scores);
    for &id in ids {
        chosen.insert(id);
    }
    chosen.into_iter().collect()
}
