// Known-bad fixture for the panic_safety rule: every construct below
// must be flagged when scanned as a wire-reachable module.

fn decode(buf: &[u8], opt: Option<u32>) -> u32 {
    let tag = buf[0]; // indexing
    let head = &buf[..4]; // range slice
    let v = opt.unwrap(); // unwrap
    let w = opt.expect("missing"); // expect
    assert!(tag < 7); // assert!
    assert_eq!(v, w); // assert_eq!
    if tag == 5 {
        panic!("bad tag"); // panic!
    }
    match tag {
        0 => v,
        _ => unreachable!(), // unreachable!
    }
}
