//! `fedhpc-lint`: source-level static analysis for the fedhpc tree.
//!
//! Three rule families, enforced over `rust/src`:
//!
//! * **panic_safety** — no `.unwrap()` / `.expect(` / `panic!` /
//!   `unreachable!` / `todo!` / `unimplemented!` / `assert!` family /
//!   panicking slice indexing in wire-reachable modules
//!   ([`PANIC_SCOPE`]) outside `#[cfg(test)]` blocks. A hostile or
//!   corrupt peer must produce an `Err`, never a panic.
//! * **determinism** — no `HashMap`/`HashSet` and no `Instant::now` /
//!   `SystemTime::now` / ambient RNG in the modules that decide cohort
//!   order, fold order, or virtual time ([`DET_SCOPE`]). Seeded RNG and
//!   `BTreeMap`/sorted-`Vec` only — the paper's reproducible-convergence
//!   claim ("same seed ⇒ same final model hash") rests on this.
//! * **registry** — every spec name the config grammar parses is listed
//!   in a `KINDS` array, printed by `fedhpc list` (main.rs), and named
//!   in README.md, cross-checked mechanically.
//!
//! Escape hatch: a `// lint:allow(<rule>) <reason>` comment suppresses
//! matching-rule findings on its own line and the next line. An allow
//! without a reason, or naming an unknown rule, is itself a violation
//! (`lint_allow`).
//!
//! # Detector spec
//!
//! The scanner is a line/char hybrid, not a full parser:
//!
//! 1. [`strip_source`] removes comments and (by default) string/char
//!    literals with a char state machine that understands nested block
//!    comments, raw strings (`r"…"`, `r#"…"#`, `br#"…"#`), byte
//!    strings, char literals vs. lifetimes. Strings collapse to `""`;
//!    comment text is captured per line for `lint:allow` parsing.
//! 2. [`cfg_test_mask`] exempts every line inside a `#[cfg(test)]`-gated
//!    brace block (the attribute arms; the next `{` opens the exempt
//!    region, a `;` first disarms — `#[cfg(test)] use …;` items).
//! 3. Token rules run on the stripped lines: panic tokens are plain
//!    substrings, macros require a non-identifier left boundary
//!    (excludes `debug_assert!`), collection types require word
//!    boundaries on both sides. Indexing flags `[` immediately preceded
//!    by an identifier char, `)` or `]`, except the infallible
//!    full-range slice `[..]`.
//!
//! `tools/lint/mirror.py` is a line-for-line Python mirror of this spec
//! so the tree can be checked locally without cargo; this Rust
//! implementation is authoritative.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Wire-reachable modules (paths relative to `rust/src`, `/`-separated;
/// a trailing `/` means the whole subtree).
pub const PANIC_SCOPE: &[&str] = &[
    "network/",
    "compress/",
    "orchestrator/server.rs",
    "orchestrator/hierarchy.rs",
    "client/worker.rs",
    "util/logging.rs",
    "util/parallel.rs",
    "telemetry/",
];

/// Determinism-critical modules: cohort order, fold order, virtual time.
pub const DET_SCOPE: &[&str] = &[
    "orchestrator/planner.rs",
    "orchestrator/aggregate.rs",
    "orchestrator/hierarchy.rs",
    "orchestrator/strategy/",
    "sim/",
    "experiments/simrunner.rs",
];

/// Plain-substring panic tokens (method calls).
pub const PANIC_TOKENS: &[&str] = &[".unwrap()", ".expect("];

/// Panicking macros; matched with a non-identifier left boundary so
/// `debug_assert!` (compiled out in release) is not flagged.
pub const PANIC_MACROS: &[&str] = &[
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
    "assert!(",
    "assert_eq!",
    "assert_ne!",
];

/// Wall-clock / ambient-entropy tokens banned in [`DET_SCOPE`].
pub const DET_TOKENS: &[&str] = &[
    "Instant::now",
    "SystemTime::now",
    "thread_rng",
    "from_entropy",
    "rand::random",
];

/// Hash-order collections banned in [`DET_SCOPE`] (word-bounded).
pub const DET_TYPES: &[&str] = &["HashMap", "HashSet"];

/// `(impl name, diagnostic label)` for each spec registry in
/// `rust/src/config/mod.rs` that must carry a `KINDS` array.
pub const REGISTRY_GROUPS: &[(&str, &str)] = &[
    ("Aggregation", "aggregation"),
    ("ServerOptKind", "server_opt"),
    ("PlannerKind", "planner"),
    ("RoundMode", "round_mode"),
    ("StalenessFn", "staleness"),
    ("WeightScheme", "weight_scheme"),
    ("GroupingPolicy", "hierarchy"),
];

/// Parse-only aliases: accepted by the grammar, intentionally unlisted.
pub const REGISTRY_ALIASES: &[&str] = &["none"];

/// Tokens `fedhpc list` (main.rs) must reference so every registry is
/// user-discoverable.
pub const MAIN_TOKENS: &[&str] = &[
    "strategy_names()",
    "server_opt_names()",
    "planner_names()",
    "RoundMode::KINDS",
    "StalenessFn::KINDS",
    "WeightScheme::KINDS",
    "GroupingPolicy::KINDS",
];

/// One diagnostic. `line` is 1-based; registry findings use line 0.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
    pub allowed: bool,
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

fn starts_with_at(chars: &[char], i: usize, tok: &str) -> bool {
    let mut j = i;
    for tc in tok.chars() {
        if chars.get(j) != Some(&tc) {
            return false;
        }
        j += 1;
    }
    true
}

/// If `chars[i]` begins `r"…"`, `r#"…"#` or `br#"…"#`, return
/// `(index of the opening quote, hash count)`.
fn raw_start(chars: &[char], i: usize) -> Option<(usize, usize)> {
    let n = chars.len();
    let mut j = i + 1;
    if chars[i] == 'b' {
        if j < n && chars[j] == 'r' {
            j += 1;
        } else {
            return None;
        }
    }
    let mut h = 0;
    while j < n && chars[j] == '#' {
        h += 1;
        j += 1;
    }
    if j < n && chars[j] == '"' {
        Some((j, h))
    } else {
        None
    }
}

/// Remove comments (and string/char literals unless `keep_strings`).
///
/// Returns `(code_lines, comments)` where each comment is
/// `(1-based line, text)`; block comments are flushed per line.
/// Strings collapse to `""` unless kept; char literals and byte
/// strings are handled; lifetimes survive.
pub fn strip_source(src: &str, keep_strings: bool) -> (Vec<String>, Vec<(usize, String)>) {
    #[derive(Clone, Copy, PartialEq)]
    enum Mode {
        Normal,
        Line,
        Block,
        Str,
        RawStr,
    }
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut code_lines: Vec<String> = Vec::new();
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut cur = String::new();
    let mut comment_buf = String::new();
    let mut line_no = 1usize;
    let mut mode = Mode::Normal;
    let mut block_depth = 0i32;
    let mut raw_hashes = 0usize;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            match mode {
                Mode::Line => {
                    comments.push((line_no, std::mem::take(&mut comment_buf)));
                    mode = Mode::Normal;
                }
                Mode::Block => {
                    comments.push((line_no, std::mem::take(&mut comment_buf)));
                }
                _ => {}
            }
            code_lines.push(std::mem::take(&mut cur));
            line_no += 1;
            i += 1;
            continue;
        }
        match mode {
            Mode::Line => {
                comment_buf.push(c);
                i += 1;
            }
            Mode::Block => {
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    block_depth += 1;
                    i += 2;
                } else if c == '*' && chars.get(i + 1) == Some(&'/') {
                    block_depth -= 1;
                    i += 2;
                    if block_depth == 0 {
                        comments.push((line_no, std::mem::take(&mut comment_buf)));
                        mode = Mode::Normal;
                    }
                } else {
                    comment_buf.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    if keep_strings {
                        cur.push(c);
                        if let Some(&nc) = chars.get(i + 1) {
                            if nc != '\n' {
                                cur.push(nc);
                            }
                        }
                    }
                    i += 2;
                } else if c == '"' {
                    if keep_strings {
                        cur.push(c);
                    }
                    mode = Mode::Normal;
                    i += 1;
                } else {
                    if keep_strings {
                        cur.push(c);
                    }
                    i += 1;
                }
            }
            Mode::RawStr => {
                let closes = c == '"'
                    && i + raw_hashes < n
                    && (1..=raw_hashes).all(|k| chars[i + k] == '#');
                if closes {
                    if keep_strings {
                        cur.push('"');
                    }
                    mode = Mode::Normal;
                    i += 1 + raw_hashes;
                } else {
                    if keep_strings {
                        cur.push(c);
                    }
                    i += 1;
                }
            }
            Mode::Normal => {
                let prev_ident = i > 0 && is_ident(chars[i - 1]);
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    mode = Mode::Line;
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    mode = Mode::Block;
                    block_depth = 1;
                    i += 2;
                } else if c == '"' {
                    cur.push('"');
                    if !keep_strings {
                        cur.push('"');
                    }
                    mode = Mode::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_ident && raw_start(&chars, i).is_some() {
                    let (j, h) = match raw_start(&chars, i) {
                        Some(v) => v,
                        None => unreachable!(),
                    };
                    cur.push('"');
                    if !keep_strings {
                        cur.push('"');
                    }
                    mode = Mode::RawStr;
                    raw_hashes = h;
                    i = j + 1;
                } else if c == 'b' && !prev_ident && chars.get(i + 1) == Some(&'"') {
                    cur.push('"');
                    if !keep_strings {
                        cur.push('"');
                    }
                    mode = Mode::Str;
                    i += 2;
                } else if c == 'b' && !prev_ident && chars.get(i + 1) == Some(&'\'') {
                    // byte char literal: defer to the ' handler below
                    i += 1;
                    cur.push(' ');
                } else if c == '\'' {
                    if chars.get(i + 1) == Some(&'\\') {
                        let mut j = i + 2;
                        while j < n && chars[j] != '\'' && chars[j] != '\n' {
                            j += 1;
                        }
                        i = j + 1;
                    } else if i + 2 < n && chars[i + 2] == '\'' && chars[i + 1] != '\'' {
                        // 'x' char literal (vs 'a lifetime)
                        i += 3;
                    } else {
                        cur.push(c); // lifetime
                        i += 1;
                    }
                } else {
                    cur.push(c);
                    i += 1;
                }
            }
        }
    }
    if mode == Mode::Line && !comment_buf.is_empty() {
        comments.push((line_no, comment_buf));
    }
    if !cur.is_empty() {
        code_lines.push(cur);
    }
    (code_lines, comments)
}

/// True for every line inside a `#[cfg(test)]`-gated brace block.
pub fn cfg_test_mask(code_lines: &[String]) -> Vec<bool> {
    let mut mask = vec![false; code_lines.len()];
    let mut armed = false;
    let mut in_exempt = false;
    let mut exempt_depth = 0i64;
    let mut depth = 0i64;
    for (ln, line) in code_lines.iter().enumerate() {
        let chars: Vec<char> = line.chars().collect();
        let mut line_exempt = in_exempt;
        for (idx, &ch) in chars.iter().enumerate() {
            if !in_exempt && starts_with_at(&chars, idx, "#[cfg(test)]") {
                armed = true;
            }
            match ch {
                '{' => {
                    if armed && !in_exempt {
                        in_exempt = true;
                        exempt_depth = depth;
                        armed = false;
                        line_exempt = true;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if in_exempt && depth == exempt_depth {
                        in_exempt = false;
                        line_exempt = true;
                    }
                }
                ';' => {
                    if armed && !in_exempt {
                        armed = false;
                    }
                }
                _ => {}
            }
            if in_exempt {
                line_exempt = true;
            }
        }
        mask[ln] = line_exempt;
    }
    mask
}

/// `tok` at `i` with a non-identifier char (or line start) to its left.
fn token_at(chars: &[char], i: usize, tok: &str) -> bool {
    if !starts_with_at(chars, i, tok) {
        return false;
    }
    if i > 0 && is_ident(chars[i - 1]) {
        return false;
    }
    true
}

/// [`token_at`] plus a non-identifier right boundary.
fn word_at(chars: &[char], i: usize, tok: &str) -> bool {
    if !token_at(chars, i, tok) {
        return false;
    }
    let end = i + tok.chars().count();
    if end < chars.len() && is_ident(chars[end]) {
        return false;
    }
    true
}

/// Positions of panicking `expr[...]` index/slice expressions: `[`
/// immediately preceded by an identifier char, `)` or `]` — excluding
/// the infallible full-range slice `[..]`.
fn indexing_sites(chars: &[char]) -> Vec<usize> {
    let mut out = Vec::new();
    for (i, &ch) in chars.iter().enumerate() {
        if ch != '[' || i == 0 {
            continue;
        }
        let p = chars[i - 1];
        if !is_ident(p) && p != ')' && p != ']' {
            continue;
        }
        let mut d = 1i64;
        let mut j = i + 1;
        while j < chars.len() && d > 0 {
            match chars[j] {
                '[' => d += 1,
                ']' => d -= 1,
                _ => {}
            }
            j += 1;
        }
        if d == 0 {
            let inner: String = chars[i + 1..j - 1].iter().collect();
            if inner.trim() == ".." {
                continue; // full-range slice: infallible
            }
        }
        out.push(i);
    }
    out
}

/// Parse `lint:allow(<rule>) <reason>` escapes out of the captured
/// comments. Returns `(allows per line, violations for malformed ones)`.
fn parse_allows(
    comments: &[(usize, String)],
) -> (Vec<(usize, &'static str)>, Vec<(usize, String)>) {
    let mut allows = Vec::new();
    let mut bad = Vec::new();
    for (ln, text) in comments {
        let Some(k) = text.find("lint:allow(") else {
            continue;
        };
        let rest = &text[k + "lint:allow(".len()..];
        let Some(close) = rest.find(')') else {
            bad.push((*ln, "malformed lint:allow (no closing paren)".to_string()));
            continue;
        };
        let rule = rest[..close].trim();
        let reason = rest[close + 1..].trim();
        let rule = match rule {
            "panic_safety" => "panic_safety",
            "determinism" => "determinism",
            other => {
                bad.push((*ln, format!("lint:allow of unknown rule '{other}'")));
                continue;
            }
        };
        if reason.is_empty() {
            bad.push((*ln, format!("lint:allow({rule}) requires a reason")));
            continue;
        }
        allows.push((*ln, rule));
    }
    (allows, bad)
}

/// Scan one source snippet under the given rule scopes. `file` is left
/// empty; [`scan_tree`] fills it.
pub fn scan_snippet(src: &str, panic_scope: bool, det_scope: bool) -> Vec<Violation> {
    let (code, comments) = strip_source(src, false);
    let mask = cfg_test_mask(&code);
    let (allows, bad) = parse_allows(&comments);
    let mut out: Vec<Violation> = bad
        .into_iter()
        .map(|(ln, msg)| Violation {
            file: String::new(),
            line: ln,
            rule: "lint_allow",
            msg,
            allowed: false,
        })
        .collect();
    let allowed = |ln: usize, rule: &str| {
        allows
            .iter()
            .any(|&(al, ar)| ar == rule && (al == ln || al + 1 == ln))
    };
    let push = |out: &mut Vec<Violation>, ln: usize, rule: &'static str, msg: String| {
        let allowed = allowed(ln, rule);
        out.push(Violation {
            file: String::new(),
            line: ln,
            rule,
            msg,
            allowed,
        });
    };
    for (idx, line) in code.iter().enumerate() {
        let ln = idx + 1;
        if mask[idx] {
            continue;
        }
        let chars: Vec<char> = line.chars().collect();
        if panic_scope {
            for tok in PANIC_TOKENS {
                for i in 0..chars.len() {
                    if starts_with_at(&chars, i, tok) {
                        push(
                            &mut out,
                            ln,
                            "panic_safety",
                            format!("`{tok}` on a wire-reachable path"),
                        );
                    }
                }
            }
            for tok in PANIC_MACROS {
                for i in 0..chars.len() {
                    if token_at(&chars, i, tok) {
                        let name = tok.trim_end_matches('(');
                        push(
                            &mut out,
                            ln,
                            "panic_safety",
                            format!("`{name}` on a wire-reachable path"),
                        );
                    }
                }
            }
            for _ in indexing_sites(&chars) {
                push(
                    &mut out,
                    ln,
                    "panic_safety",
                    "slice/array indexing can panic (use get()/iterators)".to_string(),
                );
            }
        }
        if det_scope {
            for tok in DET_TYPES {
                for i in 0..chars.len() {
                    if word_at(&chars, i, tok) {
                        push(
                            &mut out,
                            ln,
                            "determinism",
                            format!(
                                "`{tok}` in a determinism-critical module \
                                 (use BTreeMap/BTreeSet/sorted Vec)"
                            ),
                        );
                    }
                }
            }
            for tok in DET_TOKENS {
                for i in 0..chars.len() {
                    if token_at(&chars, i, tok) {
                        push(
                            &mut out,
                            ln,
                            "determinism",
                            format!(
                                "`{tok}` in a determinism-critical module \
                                 (virtual time / seeded RNG only)"
                            ),
                        );
                    }
                }
            }
        }
    }
    out
}

/// Is `rel` (a `/`-separated path relative to `rust/src`) in `scope`?
pub fn in_scope(rel: &str, scope: &[&str]) -> bool {
    scope
        .iter()
        .any(|s| rel == *s || (s.ends_with('/') && rel.starts_with(s)))
}

/// Extract the contents of every `"…"` literal in `text` (escapes
/// dropped, matching the mirror).
fn extract_strings(text: &str) -> Vec<String> {
    let chars: Vec<char> = text.chars().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < chars.len() {
        if chars[i] == '"' {
            let mut j = i + 1;
            let mut buf = String::new();
            while j < chars.len() && chars[j] != '"' {
                if chars[j] == '\\' {
                    j += 1;
                } else {
                    buf.push(chars[j]);
                }
                j += 1;
            }
            out.push(buf);
            i = j + 1;
        } else {
            i += 1;
        }
    }
    out
}

/// The `KINDS` string array of `impl <impl_name>` in the config source,
/// or `None` if the impl or the array is missing.
pub fn extract_kinds(config_src: &str, impl_name: &str) -> Option<Vec<String>> {
    let start = config_src.find(&format!("impl {impl_name}"))?;
    let k = start + config_src[start..].find("const KINDS")?;
    let eq = k + config_src[k..].find('=')?;
    let open_b = eq + config_src[eq..].find('[')?;
    let close_b = open_b + config_src[open_b..].find(']')?;
    Some(extract_strings(&config_src[open_b..close_b]))
}

/// Every string literal used as a pure `"a" | "b" => …` match-arm
/// pattern in the config source — the names the grammar accepts.
pub fn arm_literals(config_src: &str) -> Vec<String> {
    let (code, _) = strip_source(config_src, true);
    let mut lits = Vec::new();
    for line in &code {
        let t = line.trim();
        if !t.starts_with('"') || !t.contains("=>") {
            continue;
        }
        let head = match t.split_once("=>") {
            Some((h, _)) => h,
            None => continue,
        };
        // only pure `"a" | "b"` patterns: remove each literal once and
        // require nothing but `|` and whitespace to remain
        let mut residue = head.to_string();
        for s in extract_strings(head) {
            let quoted = format!("\"{s}\"");
            if let Some(p) = residue.find(&quoted) {
                residue.replace_range(p..p + quoted.len(), "");
            }
        }
        if !residue.trim().replace('|', "").trim().is_empty() {
            continue;
        }
        lits.extend(extract_strings(head));
    }
    lits
}

/// Cross-check the config grammar against the KINDS registries, the
/// `fedhpc list` command and the README.
pub fn check_registry(config_src: &str, main_src: &str, readme_src: &str) -> Vec<Violation> {
    let mut out = Vec::new();
    let mut push = |msg: String| {
        out.push(Violation {
            file: String::new(),
            line: 0,
            rule: "registry",
            msg,
            allowed: false,
        });
    };
    let mut union: Vec<String> = REGISTRY_ALIASES.iter().map(|s| s.to_string()).collect();
    let arms = arm_literals(config_src);
    for (impl_name, label) in REGISTRY_GROUPS {
        let Some(kinds) = extract_kinds(config_src, impl_name) else {
            push(format!(
                "{label}: no `impl {impl_name}` KINDS array found in config"
            ));
            continue;
        };
        for kind in kinds {
            if !arms.contains(&kind) {
                push(format!("{label}: '{kind}' is in KINDS but has no parse arm"));
            }
            if !readme_src.contains(&kind) {
                push(format!("{label}: '{kind}' is not documented in README.md"));
            }
            union.push(kind);
        }
    }
    for arm in &arms {
        if !union.contains(arm) {
            push(format!(
                "config parses '{arm}' but no KINDS registry lists it"
            ));
        }
    }
    for tok in MAIN_TOKENS {
        if !main_src.contains(tok) {
            push(format!("`fedhpc list` (main.rs) does not print {tok}"));
        }
    }
    out
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)?
        .map(|e| e.map(|e| e.path()))
        .collect::<io::Result<_>>()?;
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().and_then(|s| s.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Scan the whole tree under `root` (the repo root). Returns all
/// findings (allowed and not) plus the number of files scanned.
pub fn scan_tree(root: &Path) -> io::Result<(Vec<Violation>, usize)> {
    let src_root = root.join("rust").join("src");
    let mut paths = Vec::new();
    collect_rs(&src_root, &mut paths)?;
    let mut violations = Vec::new();
    for path in &paths {
        let rel: String = path
            .strip_prefix(&src_root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = fs::read_to_string(path)?;
        let ps = in_scope(&rel, PANIC_SCOPE);
        let ds = in_scope(&rel, DET_SCOPE);
        for mut v in scan_snippet(&src, ps, ds) {
            v.file = format!("rust/src/{rel}");
            violations.push(v);
        }
    }
    let config_src = fs::read_to_string(src_root.join("config").join("mod.rs"))?;
    let main_src = fs::read_to_string(src_root.join("main.rs"))?;
    let readme_src = fs::read_to_string(root.join("README.md"))?;
    for mut v in check_registry(&config_src, &main_src, &readme_src) {
        v.file = "rust/src/config/mod.rs".to_string();
        violations.push(v);
    }
    Ok((violations, paths.len()))
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render the machine-readable report (benchkit-style JSON).
pub fn render_report(violations: &[Violation], files_scanned: usize, tool: &str) -> String {
    let unallowed: Vec<&Violation> = violations.iter().filter(|v| !v.allowed).collect();
    let allowed: Vec<&Violation> = violations.iter().filter(|v| v.allowed).collect();
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!(" \"tool\": \"{}\",\n", json_escape(tool)));
    s.push_str(" \"version\": 1,\n");
    s.push_str(&format!(" \"files_scanned\": {files_scanned},\n"));
    s.push_str(" \"rules\": {\n");
    let rule_names = ["panic_safety", "determinism", "registry", "lint_allow"];
    for (i, name) in rule_names.iter().enumerate() {
        let nv = unallowed.iter().filter(|v| v.rule == *name).count();
        let na = allowed.iter().filter(|v| v.rule == *name).count();
        s.push_str(&format!(
            "  \"{name}\": {{\"violations\": {nv}, \"allowed\": {na}}}{}\n",
            if i + 1 < rule_names.len() { "," } else { "" }
        ));
    }
    s.push_str(" },\n");
    for (key, list) in [("violations", &unallowed), ("allowed", &allowed)] {
        s.push_str(&format!(" \"{key}\": [\n"));
        for (i, v) in list.iter().enumerate() {
            s.push_str(&format!(
                "  {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"msg\": \"{}\"}}{}\n",
                json_escape(&v.file),
                v.line,
                v.rule,
                json_escape(&v.msg),
                if i + 1 < list.len() { "," } else { "" }
            ));
        }
        s.push_str(" ],\n");
    }
    s.push_str(&format!(
        " \"ok\": {}\n}}\n",
        if unallowed.is_empty() { "true" } else { "false" }
    ));
    s
}

/// Full run: scan, print human diagnostics to stdout, write the JSON
/// report at `root/<report>`. Returns `Ok(true)` iff the tree is clean.
pub fn run(root: &Path, report: &str) -> io::Result<bool> {
    let (violations, files) = scan_tree(root)?;
    let unallowed: Vec<&Violation> = violations.iter().filter(|v| !v.allowed).collect();
    let n_allowed = violations.len() - unallowed.len();
    for v in &unallowed {
        println!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.msg);
    }
    fs::write(
        root.join(report),
        render_report(&violations, files, "fedhpc-lint"),
    )?;
    println!(
        "fedhpc-lint: {files} files, {} violations, {n_allowed} allowed",
        unallowed.len()
    );
    Ok(unallowed.is_empty())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(vs: &[Violation]) -> Vec<(&'static str, usize, bool)> {
        vs.iter().map(|v| (v.rule, v.line, v.allowed)).collect()
    }

    #[test]
    fn strip_removes_comments_and_strings() {
        let src = "let a = \"x.unwrap()\"; // c1 .unwrap()\nlet b = 1; /* block\n.unwrap() */ let c = 2;\n";
        let (code, comments) = strip_source(src, false);
        assert_eq!(code[0], "let a = \"\"; ");
        assert!(!code.concat().contains(".unwrap()"));
        assert_eq!(comments.len(), 3); // line comment + 2 block-flushed lines
        assert!(comments[0].1.contains(".unwrap()"));
    }

    #[test]
    fn strip_handles_raw_strings_char_literals_lifetimes() {
        let src = "let r = r#\"raw \" [i] \"#; let c = '['; let b = b'\\n';\nfn f<'a>(x: &'a [u8]) {}\n";
        let (code, _) = strip_source(src, false);
        assert!(!code[0].contains("raw"));
        assert!(!code[0].contains('['), "char literal '[' must be stripped: {}", code[0]);
        assert!(code[1].contains("<'a>"), "lifetime survives: {}", code[1]);
    }

    #[test]
    fn cfg_test_blocks_are_exempt() {
        let src = "fn a() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn b() { y.unwrap(); }\n}\nfn c() { z.unwrap(); }\n";
        let vs = scan_snippet(src, true, false);
        let lines: Vec<usize> = vs.iter().map(|v| v.line).collect();
        assert_eq!(lines, vec![1, 6], "only non-test unwraps flagged: {vs:?}");
    }

    #[test]
    fn cfg_test_on_item_statement_does_not_arm() {
        // a `;` before any `{` disarms: `#[cfg(test)] use …;`
        let src = "#[cfg(test)]\nuse foo::bar;\nfn a() { x.unwrap(); }\n";
        let vs = scan_snippet(src, true, false);
        assert_eq!(rules_of(&vs), vec![("panic_safety", 3, false)]);
    }

    #[test]
    fn full_range_slice_is_not_flagged() {
        let src = "fn a(v: &[u8]) { let x = &v[..]; let y = &v[1..]; }\n";
        let vs = scan_snippet(src, true, false);
        assert_eq!(vs.len(), 1, "only v[1..] flagged: {vs:?}");
    }

    #[test]
    fn debug_assert_is_not_flagged() {
        let src = "fn a() { debug_assert!(x > 0); debug_assert_eq!(a, b); }\n";
        assert!(scan_snippet(src, true, false).is_empty());
    }

    #[test]
    fn allow_with_reason_suppresses_same_and_next_line() {
        let src = "fn a(v: &[u8]) {\n    // lint:allow(panic_safety) index < len by construction\n    let x = v[0];\n}\n";
        let vs = scan_snippet(src, true, false);
        assert_eq!(rules_of(&vs), vec![("panic_safety", 3, true)]);
    }

    #[test]
    fn allow_without_reason_is_a_violation() {
        let src = "fn a(v: &[u8]) {\n    // lint:allow(panic_safety)\n    let x = v[0];\n}\n";
        let vs = scan_snippet(src, true, false);
        assert!(
            vs.iter()
                .any(|v| v.rule == "lint_allow" && v.msg.contains("requires a reason")),
            "{vs:?}"
        );
        assert!(
            vs.iter().any(|v| v.rule == "panic_safety" && !v.allowed),
            "reasonless allow must not suppress: {vs:?}"
        );
    }

    #[test]
    fn allow_of_unknown_rule_is_a_violation() {
        let src = "// lint:allow(bogus) because\nfn a() {}\n";
        let vs = scan_snippet(src, true, false);
        assert_eq!(vs.len(), 1);
        assert_eq!(vs[0].rule, "lint_allow");
        assert!(vs[0].msg.contains("unknown rule"));
    }

    #[test]
    fn allow_does_not_cross_rules() {
        let src = "// lint:allow(determinism) reason here\nlet x = y.unwrap();\n";
        let vs = scan_snippet(src, true, false);
        assert_eq!(rules_of(&vs), vec![("panic_safety", 2, false)]);
    }

    #[test]
    fn determinism_tokens_word_bounded() {
        let src = "use std::collections::HashMap;\nstruct MyHashMapLike;\nlet t = Instant::now();\n";
        let vs = scan_snippet(src, false, true);
        assert_eq!(
            rules_of(&vs),
            vec![("determinism", 1, false), ("determinism", 3, false)],
            "HashMapLike must not match: {vs:?}"
        );
    }

    #[test]
    fn scope_matching() {
        assert!(in_scope("network/tcp.rs", PANIC_SCOPE));
        // the readiness-driven transport rebuild (ISSUE 9) added two
        // wire-facing modules; the network/ subtree rule must cover
        // them — a hostile peer reaches both the frame decoder and the
        // reactor's read path directly
        assert!(in_scope("network/framing.rs", PANIC_SCOPE));
        assert!(in_scope("network/reactor.rs", PANIC_SCOPE));
        assert!(in_scope("compress/mod.rs", PANIC_SCOPE));
        assert!(in_scope("orchestrator/server.rs", PANIC_SCOPE));
        assert!(!in_scope("orchestrator/planner.rs", PANIC_SCOPE));
        assert!(in_scope("telemetry/http.rs", PANIC_SCOPE));
        assert!(in_scope("telemetry/registry.rs", PANIC_SCOPE));
        // the ingest pool joins the panic scope (ISSUE 8) but stays out
        // of the determinism scope: its Instant::now() timing counters
        // are legal, and fold ordering is pinned by the shard queues
        assert!(in_scope("util/parallel.rs", PANIC_SCOPE));
        assert!(!in_scope("util/parallel.rs", DET_SCOPE));
        // the hierarchical aggregation plane (ISSUE 10) is in BOTH
        // scopes: a site aggregator folds wire-delivered updates (a
        // hostile member reaches it directly) and its fold order pins
        // the two-tier bit-identity claim
        assert!(in_scope("orchestrator/hierarchy.rs", PANIC_SCOPE));
        assert!(in_scope("orchestrator/hierarchy.rs", DET_SCOPE));
        assert!(!in_scope("util/scratch.rs", PANIC_SCOPE));
        assert!(!in_scope("telemetry/http.rs", DET_SCOPE));
        assert!(in_scope("orchestrator/planner.rs", DET_SCOPE));
        assert!(in_scope("sim/mod.rs", DET_SCOPE));
        assert!(!in_scope("network/tcp.rs", DET_SCOPE));
        assert!(!in_scope("simulator.rs", DET_SCOPE), "prefix needs the slash");
    }

    const GOOD_CFG: &str = r#"
impl Aggregation { pub const KINDS: &'static [&'static str] = &["fedavg"]; }
impl ServerOptKind { pub const KINDS: &'static [&'static str] = &["sgd"]; }
impl PlannerKind { pub const KINDS: &'static [&'static str] = &["random"]; }
impl RoundMode { pub const KINDS: &'static [&'static str] = &["sync"]; }
impl StalenessFn { pub const KINDS: &'static [&'static str] = &["poly"]; }
impl WeightScheme { pub const KINDS: &'static [&'static str] = &["data_size"]; }
impl GroupingPolicy { pub const KINDS: &'static [&'static str] = &["flat"]; }
fn parse(s: &str) -> u8 {
    match s {
        "fedavg" => 1,
        "sgd" | "none" => 2,
        "random" => 3,
        "sync" => 4,
        "poly" => 5,
        "data_size" => 6,
        "flat" => 7,
        _ => 0,
    }
}
"#;
    const GOOD_MAIN: &str = "strategy_names() server_opt_names() planner_names() \
                             RoundMode::KINDS StalenessFn::KINDS WeightScheme::KINDS \
                             GroupingPolicy::KINDS";
    const GOOD_README: &str = "fedavg sgd random sync poly data_size flat";

    #[test]
    fn registry_clean_config_passes() {
        assert!(check_registry(GOOD_CFG, GOOD_MAIN, GOOD_README).is_empty());
    }

    #[test]
    fn registry_flags_arm_missing_from_kinds() {
        let cfg = GOOD_CFG.replace("\"sync\" => 4,", "\"sync\" | \"extra_mode\" => 4,");
        let vs = check_registry(&cfg, GOOD_MAIN, GOOD_README);
        assert!(
            vs.iter().any(|v| v.msg.contains("'extra_mode'")),
            "{vs:?}"
        );
    }

    #[test]
    fn registry_flags_kind_without_parse_arm() {
        let cfg = GOOD_CFG.replace("&[\"sync\"]", "&[\"sync\", \"ghost\"]");
        let vs = check_registry(&cfg, GOOD_MAIN, GOOD_README);
        assert!(
            vs.iter().any(|v| v.msg.contains("no parse arm")),
            "{vs:?}"
        );
    }

    #[test]
    fn registry_flags_undocumented_kind_and_missing_list_token() {
        let vs = check_registry(GOOD_CFG, GOOD_MAIN, "everything but the weight scheme");
        assert!(
            vs.iter()
                .any(|v| v.msg.contains("not documented in README")),
            "{vs:?}"
        );
        let vs = check_registry(GOOD_CFG, "strategy_names()", GOOD_README);
        assert!(
            vs.iter()
                .any(|v| v.msg.contains("does not print WeightScheme::KINDS")),
            "{vs:?}"
        );
    }

    #[test]
    fn registry_flags_missing_kinds_array() {
        let cfg = GOOD_CFG.replace("impl WeightScheme", "impl Unrelated");
        let vs = check_registry(&cfg, GOOD_MAIN, GOOD_README);
        assert!(
            vs.iter()
                .any(|v| v.msg.contains("no `impl WeightScheme` KINDS array")),
            "{vs:?}"
        );
    }

    #[test]
    fn report_counts_and_ok_flag() {
        let vs = vec![
            Violation {
                file: "a.rs".into(),
                line: 3,
                rule: "panic_safety",
                msg: "`.unwrap()` on a wire-reachable path".into(),
                allowed: false,
            },
            Violation {
                file: "b.rs".into(),
                line: 7,
                rule: "panic_safety",
                msg: "ok".into(),
                allowed: true,
            },
        ];
        let r = render_report(&vs, 2, "fedhpc-lint");
        assert!(r.contains("\"panic_safety\": {\"violations\": 1, \"allowed\": 1}"));
        assert!(r.contains("\"ok\": false"));
        let r = render_report(&vs[1..], 2, "fedhpc-lint");
        assert!(r.contains("\"ok\": true"));
    }
}
