//! CLI for the fedhpc repo-invariant linter.
//!
//! ```text
//! fedhpc-lint [--deny] [--root <repo-root>] [--report <path>]
//! ```
//!
//! Prints one human diagnostic per unallowed violation, writes the
//! machine-readable report (default `LINT_report.json`, relative to the
//! root), and — under `--deny` — exits 1 if the tree is not clean.
//! Exit 2 is an operational error (bad flag, unreadable tree).

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut deny = false;
    let mut report = String::from("LINT_report.json");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--deny" => deny = true,
            "--root" => match args.next() {
                Some(v) => root = PathBuf::from(v),
                None => return usage_err("--root needs a value"),
            },
            "--report" => match args.next() {
                Some(v) => report = v,
                None => return usage_err("--report needs a value"),
            },
            "--help" | "-h" => {
                println!("usage: fedhpc-lint [--deny] [--root <repo-root>] [--report <path>]");
                return ExitCode::SUCCESS;
            }
            other => return usage_err(&format!("unknown arg '{other}'")),
        }
    }
    match fedhpc_lint::run(&root, &report) {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => {
            if deny {
                ExitCode::FAILURE
            } else {
                ExitCode::SUCCESS
            }
        }
        Err(e) => {
            eprintln!("fedhpc-lint: {e}");
            ExitCode::from(2)
        }
    }
}

fn usage_err(msg: &str) -> ExitCode {
    eprintln!("fedhpc-lint: {msg}");
    eprintln!("usage: fedhpc-lint [--deny] [--root <repo-root>] [--report <path>]");
    ExitCode::from(2)
}
