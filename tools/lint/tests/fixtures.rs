//! Fixture-driven proof that each rule family actually fires: every
//! known-bad fixture must produce findings, every known-good fixture
//! must scan clean, and a reasonless `lint:allow` is itself an error.

use fedhpc_lint::{scan_snippet, Violation};

const PANIC_BAD: &str = include_str!("../fixtures/panic_bad.rs");
const PANIC_GOOD: &str = include_str!("../fixtures/panic_good.rs");
const DET_BAD: &str = include_str!("../fixtures/det_bad.rs");
const DET_GOOD: &str = include_str!("../fixtures/det_good.rs");
const ALLOW_NO_REASON: &str = include_str!("../fixtures/allow_no_reason.rs");
const TELEMETRY_HTTP_BAD: &str = include_str!("../fixtures/telemetry_http_bad.rs");
const PARALLEL_BAD: &str = include_str!("../fixtures/parallel_bad.rs");
const SHARD_MAP_BAD: &str = include_str!("../fixtures/shard_map_bad.rs");
const HIERARCHY_BAD: &str = include_str!("../fixtures/hierarchy_bad.rs");

fn unallowed(vs: &[Violation]) -> Vec<&Violation> {
    vs.iter().filter(|v| !v.allowed).collect()
}

#[test]
fn panic_bad_fixture_trips_every_construct() {
    let vs = scan_snippet(PANIC_BAD, true, false);
    let msgs: Vec<&str> = vs.iter().map(|v| v.msg.as_str()).collect();
    for needle in [
        "`.unwrap()`",
        "`.expect(`",
        "`panic!`",
        "`unreachable!`",
        "`assert!`",
        "`assert_eq!`",
        "slice/array indexing",
    ] {
        assert!(
            msgs.iter().any(|m| m.contains(needle)),
            "expected a {needle} finding, got {msgs:?}"
        );
    }
    assert!(vs.iter().all(|v| !v.allowed), "nothing is allowlisted here");
    // `&buf[..4]` and `buf[0]` are two distinct indexing findings
    assert!(
        vs.iter()
            .filter(|v| v.msg.contains("slice/array indexing"))
            .count()
            >= 2
    );
}

#[test]
fn panic_good_fixture_scans_clean() {
    let vs = scan_snippet(PANIC_GOOD, true, false);
    let bad = unallowed(&vs);
    assert!(bad.is_empty(), "known-good fixture flagged: {bad:?}");
    // the reasoned allow is recorded as allowed, not silently dropped
    assert_eq!(vs.iter().filter(|v| v.allowed).count(), 1);
}

#[test]
fn det_bad_fixture_trips_collections_and_clocks() {
    let vs = scan_snippet(DET_BAD, false, true);
    let msgs: Vec<&str> = vs.iter().map(|v| v.msg.as_str()).collect();
    for needle in [
        "`HashMap`",
        "`HashSet`",
        "`Instant::now`",
        "`SystemTime::now`",
    ] {
        assert!(
            msgs.iter().any(|m| m.contains(needle)),
            "expected a {needle} finding, got {msgs:?}"
        );
    }
    assert!(vs.iter().all(|v| v.rule == "determinism"));
}

#[test]
fn det_good_fixture_scans_clean() {
    let vs = scan_snippet(DET_GOOD, false, true);
    assert!(vs.is_empty(), "known-good fixture flagged: {vs:?}");
}

#[test]
fn reasonless_or_unknown_allow_is_an_error_and_suppresses_nothing() {
    let vs = scan_snippet(ALLOW_NO_REASON, true, false);
    assert!(
        vs.iter()
            .any(|v| v.rule == "lint_allow" && v.msg.contains("requires a reason")),
        "{vs:?}"
    );
    assert!(
        vs.iter()
            .any(|v| v.rule == "lint_allow" && v.msg.contains("unknown rule")),
        "{vs:?}"
    );
    // both indexing sites must still be live violations
    assert_eq!(
        unallowed(&vs)
            .iter()
            .filter(|v| v.rule == "panic_safety")
            .count(),
        2
    );
}

#[test]
fn telemetry_http_bad_fixture_fires_under_panic_scope() {
    // telemetry/ joined PANIC_SCOPE in PR 7; this fixture proves an
    // `.unwrap()` in a telemetry request parser is actually caught
    assert!(fedhpc_lint::in_scope(
        "telemetry/http.rs",
        fedhpc_lint::PANIC_SCOPE
    ));
    let vs = scan_snippet(TELEMETRY_HTTP_BAD, true, false);
    let bad = unallowed(&vs);
    for needle in ["`.unwrap()`", "`.expect(`", "slice/array indexing", "`assert!`"] {
        assert!(
            bad.iter().any(|v| v.msg.contains(needle)),
            "expected a {needle} finding, got {bad:?}"
        );
    }
    // the unwrap is on the request line: pin it to its source line
    let unwrap_line = vs
        .iter()
        .find(|v| v.msg.contains("`.unwrap()`"))
        .map(|v| v.line);
    assert_eq!(unwrap_line, Some(9), "unwrap site moved in the fixture?");
}

#[test]
fn parallel_bad_fixture_fires_under_panic_scope() {
    // util/parallel.rs joined PANIC_SCOPE in PR 8; this fixture proves
    // the exact shape the satellite bugfix removed — `join().unwrap()`
    // on a worker handle — is actually caught, alongside its friends
    assert!(fedhpc_lint::in_scope(
        "util/parallel.rs",
        fedhpc_lint::PANIC_SCOPE
    ));
    let vs = scan_snippet(PARALLEL_BAD, true, false);
    let bad = unallowed(&vs);
    for needle in [
        "`.unwrap()`",
        "`.expect(`",
        "slice/array indexing",
        "`assert_eq!`",
    ] {
        assert!(
            bad.iter().any(|v| v.msg.contains(needle)),
            "expected a {needle} finding, got {bad:?}"
        );
    }
    // the unwrap is on the join call: pin it to its source line
    let unwrap_line = vs
        .iter()
        .find(|v| v.msg.contains("`.unwrap()`"))
        .map(|v| v.line);
    assert_eq!(unwrap_line, Some(11), "join().unwrap() site moved in the fixture?");
}

#[test]
fn shard_map_bad_fixture_fires_under_det_scope() {
    // design-space guard for the sharded aggregator: a HashMap-keyed
    // shard map (nondeterministic merge order) must fire under the
    // determinism scope that covers orchestrator/aggregate.rs
    assert!(fedhpc_lint::in_scope(
        "orchestrator/aggregate.rs",
        fedhpc_lint::DET_SCOPE
    ));
    let vs = scan_snippet(SHARD_MAP_BAD, false, true);
    let msgs: Vec<&str> = vs.iter().map(|v| v.msg.as_str()).collect();
    for needle in ["`HashMap`", "`HashSet`", "`Instant::now`"] {
        assert!(
            msgs.iter().any(|m| m.contains(needle)),
            "expected a {needle} finding, got {msgs:?}"
        );
    }
    assert!(vs.iter().all(|v| v.rule == "determinism"));
    // the map type appears in the use *and* the signature: both fire
    assert!(vs.iter().filter(|v| v.msg.contains("`HashMap`")).count() >= 2);
}

#[test]
fn hierarchy_bad_fixture_fires_under_both_scopes() {
    // orchestrator/hierarchy.rs joined BOTH scopes in PR 10: the site
    // aggregator's fold path is wire-reachable (panic_safety) and its
    // fold order pins two-tier ≡ flat bit-identity (determinism)
    assert!(fedhpc_lint::in_scope(
        "orchestrator/hierarchy.rs",
        fedhpc_lint::PANIC_SCOPE
    ));
    assert!(fedhpc_lint::in_scope(
        "orchestrator/hierarchy.rs",
        fedhpc_lint::DET_SCOPE
    ));
    let vs = scan_snippet(HIERARCHY_BAD, true, true);
    let bad = unallowed(&vs);
    for needle in [
        "`.unwrap()`",
        "`.expect(`",
        "slice/array indexing",
        "`assert!`",
        "`HashMap`",
        "`Instant::now`",
    ] {
        assert!(
            bad.iter().any(|v| v.msg.contains(needle)),
            "expected a {needle} finding, got {bad:?}"
        );
    }
    // both rule families fire on the same fixture
    assert!(bad.iter().any(|v| v.rule == "panic_safety"));
    assert!(bad.iter().any(|v| v.rule == "determinism"));
}

#[test]
fn fixtures_are_rule_scoped() {
    // panic fixtures scanned under the determinism rule only: the bad
    // panic fixture is determinism-clean, and vice versa
    assert!(scan_snippet(PANIC_BAD, false, true).is_empty());
    assert!(unallowed(&scan_snippet(DET_BAD, true, false)).is_empty());
}
