#!/usr/bin/env python3
"""Python mirror of fedhpc-lint (tools/lint/src/lib.rs).

The dev container for this repo has no Rust toolchain; CI builds and
runs the Rust binary, but locally this mirror lets you check a change
without cargo:

    python3 tools/lint/mirror.py [--deny] [--root .] [--report LINT_report.json]

The Rust implementation is authoritative. The two implementations share
one detector spec (documented in tools/lint/src/lib.rs); if they ever
disagree, fix the mirror to match the Rust tool.
"""

import json
import os
import sys

IDENT = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_")

PANIC_SCOPE = [
    "network/",
    "compress/",
    "orchestrator/server.rs",
    "orchestrator/hierarchy.rs",
    "client/worker.rs",
    "util/logging.rs",
    "util/parallel.rs",
    "telemetry/",
]
DET_SCOPE = [
    "orchestrator/planner.rs",
    "orchestrator/aggregate.rs",
    "orchestrator/hierarchy.rs",
    "orchestrator/strategy/",
    "sim/",
    "experiments/simrunner.rs",
]
PANIC_TOKENS = [".unwrap()", ".expect("]
PANIC_MACROS = ["panic!", "unreachable!", "todo!", "unimplemented!",
                "assert!(", "assert_eq!", "assert_ne!"]
DET_TOKENS = ["Instant::now", "SystemTime::now", "thread_rng",
              "from_entropy", "rand::random"]
DET_TYPES = ["HashMap", "HashSet"]
REGISTRY_GROUPS = [
    ("Aggregation", "aggregation"),
    ("ServerOptKind", "server_opt"),
    ("PlannerKind", "planner"),
    ("RoundMode", "round_mode"),
    ("StalenessFn", "staleness"),
    ("WeightScheme", "weight_scheme"),
    ("GroupingPolicy", "hierarchy"),
]
# Parse-only aliases: accepted by the grammar, intentionally not listed.
REGISTRY_ALIASES = ["none"]
MAIN_TOKENS = ["strategy_names()", "server_opt_names()", "planner_names()",
               "RoundMode::KINDS", "StalenessFn::KINDS", "WeightScheme::KINDS",
               "GroupingPolicy::KINDS"]


def strip_source(src, keep_strings=False):
    """Remove comments (and string/char literals unless keep_strings).

    Returns (code_lines, comments) where comments is a list of
    (1-based line, text) — block comments are flushed per line.
    """
    chars = list(src)
    n = len(chars)
    code_lines, comments = [], []
    cur, comment_buf = [], []
    line_no = 1
    mode = "normal"  # normal | line | block | str | rawstr
    block_depth = 0
    raw_hashes = 0
    i = 0
    while i < n:
        c = chars[i]
        if c == "\n":
            if mode == "line":
                comments.append((line_no, "".join(comment_buf)))
                comment_buf = []
                mode = "normal"
            elif mode == "block":
                comments.append((line_no, "".join(comment_buf)))
                comment_buf = []
            code_lines.append("".join(cur))
            cur = []
            line_no += 1
            i += 1
            continue
        if mode == "line":
            comment_buf.append(c)
            i += 1
        elif mode == "block":
            if c == "/" and i + 1 < n and chars[i + 1] == "*":
                block_depth += 1
                i += 2
            elif c == "*" and i + 1 < n and chars[i + 1] == "/":
                block_depth -= 1
                i += 2
                if block_depth == 0:
                    comments.append((line_no, "".join(comment_buf)))
                    comment_buf = []
                    mode = "normal"
            else:
                comment_buf.append(c)
                i += 1
        elif mode == "str":
            if c == "\\":
                if keep_strings:
                    cur.append(c)
                    if i + 1 < n and chars[i + 1] != "\n":
                        cur.append(chars[i + 1])
                i += 2
            elif c == '"':
                if keep_strings:
                    cur.append(c)
                mode = "normal"
                i += 1
            else:
                if keep_strings:
                    cur.append(c)
                i += 1
        elif mode == "rawstr":
            if c == '"' and all(
                j < n and chars[j] == "#"
                for j in range(i + 1, i + 1 + raw_hashes)
            ) and i + raw_hashes < n:
                if keep_strings:
                    cur.append('"')
                mode = "normal"
                i += 1 + raw_hashes
            else:
                if keep_strings:
                    cur.append(c)
                i += 1
        else:  # normal
            prev_ident = i > 0 and chars[i - 1] in IDENT
            if c == "/" and i + 1 < n and chars[i + 1] == "/":
                mode = "line"
                i += 2
            elif c == "/" and i + 1 < n and chars[i + 1] == "*":
                mode = "block"
                block_depth = 1
                i += 2
            elif c == '"':
                cur.append('"')
                if not keep_strings:
                    cur.append('"')
                mode = "str"
                i += 1
            elif c in "rb" and not prev_ident and _raw_start(chars, i):
                j, h = _raw_start(chars, i)
                cur.append('"')
                if not keep_strings:
                    cur.append('"')
                mode = "rawstr"
                raw_hashes = h
                i = j + 1
            elif c == "b" and not prev_ident and i + 1 < n and chars[i + 1] == '"':
                cur.append('"')
                if not keep_strings:
                    cur.append('"')
                mode = "str"
                i += 2
            elif c == "b" and not prev_ident and i + 1 < n and chars[i + 1] == "'":
                i += 1  # byte char literal: defer to the ' handler below
                cur.append(" ")
            elif c == "'":
                if i + 1 < n and chars[i + 1] == "\\":
                    j = i + 2
                    while j < n and chars[j] != "'" and chars[j] != "\n":
                        j += 1
                    i = j + 1
                elif i + 2 < n and chars[i + 2] == "'" and chars[i + 1] != "'":
                    i += 3
                else:
                    cur.append(c)  # lifetime
                    i += 1
            else:
                cur.append(c)
                i += 1
    if mode == "line" and comment_buf:
        comments.append((line_no, "".join(comment_buf)))
    if cur:
        code_lines.append("".join(cur))
    return code_lines, comments


def _raw_start(chars, i):
    """If chars[i] begins r"…", r#"…", br#"…", return (index of opening
    quote, hash count); else None."""
    n = len(chars)
    j = i + 1
    if chars[i] == "b":
        if j < n and chars[j] == "r":
            j += 1
        else:
            return None
    h = 0
    while j < n and chars[j] == "#":
        h += 1
        j += 1
    if j < n and chars[j] == '"':
        return (j, h)
    return None


def cfg_test_mask(code_lines):
    """True for every line inside a #[cfg(test)]-gated brace block."""
    mask = [False] * len(code_lines)
    armed = False
    in_exempt = False
    exempt_depth = 0
    depth = 0
    for ln, line in enumerate(code_lines):
        line_exempt = in_exempt
        for idx, ch in enumerate(line):
            if not in_exempt and line.startswith("#[cfg(test)]", idx):
                armed = True
            if ch == "{":
                if armed and not in_exempt:
                    in_exempt = True
                    exempt_depth = depth
                    armed = False
                    line_exempt = True
                depth += 1
            elif ch == "}":
                depth -= 1
                if in_exempt and depth == exempt_depth:
                    in_exempt = False
                    line_exempt = True
            elif ch == ";":
                if armed and not in_exempt:
                    armed = False
            if in_exempt:
                line_exempt = True
        mask[ln] = line_exempt
    return mask


def token_at(line, i, tok):
    if not line.startswith(tok, i):
        return False
    if i > 0 and line[i - 1] in IDENT:
        return False
    return True


def word_at(line, i, tok):
    if not token_at(line, i, tok):
        return False
    end = i + len(tok)
    if end < len(line) and line[end] in IDENT:
        return False
    return True


def indexing_sites(line):
    """Positions of panicking `expr[...]` index/slice expressions."""
    out = []
    for i, ch in enumerate(line):
        if ch != "[" or i == 0:
            continue
        p = line[i - 1]
        if p not in IDENT and p not in ")]":
            continue
        d = 1
        j = i + 1
        while j < len(line) and d > 0:
            if line[j] == "[":
                d += 1
            elif line[j] == "]":
                d -= 1
            j += 1
        inner = line[i + 1:j - 1] if d == 0 else line[i + 1:]
        if d == 0 and inner.strip() == "..":
            continue  # full-range slice: infallible
        out.append(i)
    return out


def parse_allows(comments):
    """-> (allows {line: set(rule)}, violations for malformed allows)."""
    allows = {}
    bad = []
    for ln, text in comments:
        k = text.find("lint:allow(")
        if k < 0:
            continue
        rest = text[k + len("lint:allow("):]
        close = rest.find(")")
        if close < 0:
            bad.append((ln, "lint_allow", "malformed lint:allow (no closing paren)"))
            continue
        rule = rest[:close].strip()
        reason = rest[close + 1:].strip()
        if rule not in ("panic_safety", "determinism"):
            bad.append((ln, "lint_allow", f"lint:allow of unknown rule '{rule}'"))
            continue
        if not reason:
            bad.append((ln, "lint_allow",
                        f"lint:allow({rule}) requires a reason"))
            continue
        allows.setdefault(ln, set()).add(rule)
    return allows, bad


def scan_snippet(src, panic_scope, det_scope):
    """-> list of dicts {line, rule, msg, allowed}."""
    code, comments = strip_source(src)
    mask = cfg_test_mask(code)
    allows, bad = parse_allows(comments)
    out = [
        {"line": ln, "rule": rule, "msg": msg, "allowed": False}
        for (ln, rule, msg) in bad
    ]

    def allowed(ln, rule):
        return rule in allows.get(ln, ()) or rule in allows.get(ln - 1, ())

    def push(ln, rule, msg):
        out.append({"line": ln, "rule": rule, "msg": msg,
                    "allowed": allowed(ln, rule)})

    for idx, line in enumerate(code):
        ln = idx + 1
        if mask[idx]:
            continue
        if panic_scope:
            for tok in PANIC_TOKENS:
                for i in range(len(line)):
                    if line.startswith(tok, i):
                        push(ln, "panic_safety", f"`{tok}` on a wire-reachable path")
            for tok in PANIC_MACROS:
                for i in range(len(line)):
                    if token_at(line, i, tok):
                        push(ln, "panic_safety", f"`{tok.rstrip('(')}` on a wire-reachable path")
            for _ in indexing_sites(line):
                push(ln, "panic_safety",
                     "slice/array indexing can panic (use get()/iterators)")
        if det_scope:
            for tok in DET_TYPES:
                for i in range(len(line)):
                    if word_at(line, i, tok):
                        push(ln, "determinism",
                             f"`{tok}` in a determinism-critical module (use BTreeMap/BTreeSet/sorted Vec)")
            for tok in DET_TOKENS:
                for i in range(len(line)):
                    if token_at(line, i, tok):
                        push(ln, "determinism",
                             f"`{tok}` in a determinism-critical module (virtual time / seeded RNG only)")
    return out


def in_scope(rel, scope):
    return any(rel == s or (s.endswith("/") and rel.startswith(s)) for s in scope)


# The readiness-driven transport rebuild added two wire-facing modules
# (frame codec + reactor); the network/ subtree rule must keep covering
# them — mirrors the scope_matching test in tools/lint/src/lib.rs.
assert in_scope("network/framing.rs", PANIC_SCOPE)
assert in_scope("network/reactor.rs", PANIC_SCOPE)
# The hierarchical aggregation plane joins BOTH scopes: a site
# aggregator folds wire-delivered member updates, and its fold order
# pins the two-tier bit-identity claim.
assert in_scope("orchestrator/hierarchy.rs", PANIC_SCOPE)
assert in_scope("orchestrator/hierarchy.rs", DET_SCOPE)


def extract_strings(text):
    out = []
    i = 0
    while i < len(text):
        if text[i] == '"':
            j = i + 1
            buf = []
            while j < len(text) and text[j] != '"':
                if text[j] == "\\":
                    j += 1
                else:
                    buf.append(text[j])
                j += 1
            out.append("".join(buf))
            i = j + 1
        else:
            i += 1
    return out


def extract_kinds(config_src, impl_name):
    start = config_src.find(f"impl {impl_name}")
    if start < 0:
        return None
    k = config_src.find("const KINDS", start)
    if k < 0:
        return None
    eq = config_src.find("=", k)
    open_b = config_src.find("[", eq)
    close_b = config_src.find("]", open_b)
    if min(eq, open_b, close_b) < 0:
        return None
    return extract_strings(config_src[open_b:close_b])


def arm_literals(config_src):
    code, _ = strip_source(config_src, keep_strings=True)
    lits = []
    for line in code:
        t = line.strip()
        if not t.startswith('"') or "=>" not in t:
            continue
        head = t.split("=>", 1)[0]
        # only pure `"a" | "b"` patterns
        residue = head
        for s in extract_strings(head):
            residue = residue.replace(f'"{s}"', "", 1)
        if residue.strip().replace("|", "").strip():
            continue
        lits.extend(extract_strings(head))
    return lits


def check_registry(config_src, main_src, readme_src):
    out = []

    def push(msg):
        out.append({"line": 0, "rule": "registry", "msg": msg, "allowed": False})

    union = set(REGISTRY_ALIASES)
    arms = arm_literals(config_src)
    for impl_name, label in REGISTRY_GROUPS:
        kinds = extract_kinds(config_src, impl_name)
        if kinds is None:
            push(f"{label}: no `impl {impl_name}` KINDS array found in config")
            continue
        union.update(kinds)
        for kind in kinds:
            if kind not in arms:
                push(f"{label}: '{kind}' is in KINDS but has no parse arm")
            if kind not in readme_src:
                push(f"{label}: '{kind}' is not documented in README.md")
    for arm in arms:
        if arm not in union:
            push(f"config parses '{arm}' but no KINDS registry lists it")
    for tok in MAIN_TOKENS:
        if tok not in main_src:
            push(f"`fedhpc list` (main.rs) does not print {tok}")
    return out


def scan_tree(root):
    src_root = os.path.join(root, "rust", "src")
    violations = []
    files = 0
    for dirpath, _dirnames, filenames in sorted(os.walk(src_root)):
        for fname in sorted(filenames):
            if not fname.endswith(".rs"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, src_root).replace(os.sep, "/")
            with open(path, encoding="utf-8") as f:
                src = f.read()
            files += 1
            ps = in_scope(rel, PANIC_SCOPE)
            ds = in_scope(rel, DET_SCOPE)
            for v in scan_snippet(src, ps, ds):
                v["file"] = f"rust/src/{rel}"
                violations.append(v)
    with open(os.path.join(root, "rust", "src", "config", "mod.rs"),
              encoding="utf-8") as f:
        config_src = f.read()
    with open(os.path.join(root, "rust", "src", "main.rs"), encoding="utf-8") as f:
        main_src = f.read()
    with open(os.path.join(root, "README.md"), encoding="utf-8") as f:
        readme_src = f.read()
    for v in check_registry(config_src, main_src, readme_src):
        v["file"] = "rust/src/config/mod.rs"
        violations.append(v)
    return violations, files


def main(argv):
    root = "."
    deny = False
    report = "LINT_report.json"
    i = 0
    while i < len(argv):
        a = argv[i]
        if a == "--deny":
            deny = True
        elif a == "--root":
            i += 1
            root = argv[i]
        elif a == "--report":
            i += 1
            report = argv[i]
        else:
            print(f"unknown arg {a}", file=sys.stderr)
            return 2
        i += 1
    violations, files = scan_tree(root)
    unallowed = [v for v in violations if not v["allowed"]]
    allowed = [v for v in violations if v["allowed"]]
    for v in unallowed:
        print(f"{v['file']}:{v['line']}: [{v['rule']}] {v['msg']}")
    rules = {}
    for name in ("panic_safety", "determinism", "registry", "lint_allow"):
        rules[name] = {
            "violations": sum(1 for v in unallowed if v["rule"] == name),
            "allowed": sum(1 for v in allowed if v["rule"] == name),
        }
    ok = not unallowed
    with open(os.path.join(root, report), "w", encoding="utf-8") as f:
        json.dump({
            "tool": "fedhpc-lint-mirror",
            "version": 1,
            "files_scanned": files,
            "rules": rules,
            "violations": [
                {k: v[k] for k in ("file", "line", "rule", "msg")}
                for v in unallowed
            ],
            "allowed": [
                {k: v[k] for k in ("file", "line", "rule", "msg")}
                for v in allowed
            ],
            "ok": ok,
        }, f, indent=1)
        f.write("\n")
    print(f"fedhpc-lint (mirror): {files} files, "
          f"{len(unallowed)} violations, {len(allowed)} allowed")
    return 1 if (deny and not ok) else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
