//! The paper's hybrid testbed (§5.1), end to end: 60 nodes (30 cloud
//! VMs incl. spot + 30 SLURM-style HPC nodes), 20 clients per round,
//! FedProx under non-IID CIFAR-style data, deadline + partial-k
//! straggler mitigation and the paper's compression pipeline.
//!
//! Full scale takes a while on CPU; `--small` runs a 12-node version,
//! `--mock` swaps in the pure-Rust runtime. The scheduler-adapter path
//! (SLURM/K8s simulators) is exercised first to obtain placements, as
//! the paper's deployment flow does.

use fedhpc::config::presets::paper_testbed;
use fedhpc::experiments::run_real;
use fedhpc::scheduler::{HybridScheduler, Job, K8sSim, Pool, SchedulerAdapter, SlurmSim};

fn main() -> anyhow::Result<()> {
    fedhpc::util::logging::init();
    let small = std::env::args().any(|a| a == "--small");
    let mock = std::env::args().any(|a| a == "--mock");

    let mut cfg = paper_testbed();
    cfg.mock_runtime = mock;
    cfg.data.dataset = if mock { "medmnist_mlp" } else { "cifar_cnn" }.to_string();
    if small {
        cfg.cluster.nodes = vec![
            ("p3.2xlarge".into(), 3),
            ("p3.2xlarge-spot".into(), 1),
            ("t3.large".into(), 2),
            ("hpc-rtx6000".into(), 4),
            ("hpc-cpu".into(), 2),
        ];
        cfg.selection.clients_per_round = 6;
        cfg.straggler.partial_k = Some(5);
        cfg.train.rounds = 8;
        cfg.data.samples_per_client = 128;
        cfg.data.eval_samples = 256;
    } else {
        cfg.train.rounds = 20;
        cfg.data.samples_per_client = 128;
        cfg.data.eval_samples = 512;
    }

    // --- scheduler adapter phase (paper §3.2): place workers ---------
    let n = cfg.cluster.total_nodes();
    let hpc_nodes: Vec<u32> = (0..n as u32 / 2).collect();
    let cloud_nodes: Vec<u32> = (n as u32 / 2..n as u32).collect();
    let mut sched = HybridScheduler::new(
        SlurmSim::new(vec![("gpu", hpc_nodes)]),
        K8sSim::new(vec![Pool {
            name: "gpu".into(),
            initial: cloud_nodes,
            scale_reserve: vec![],
            scale_up_delay_s: 30.0,
        }]),
    );
    for c in 0..n as u32 {
        let partition = if (c as usize) < n / 2 { "hpc:gpu" } else { "cloud:gpu" };
        sched.submit(Job {
            client: c,
            partition: partition.into(),
            priority: 1,
            walltime_s: 3600.0,
            preemptible: false,
        })?;
    }
    // advance the schedulers until all placements run (pod start ≈ 3 s)
    for t in [0.0, 3.0, 6.0] {
        sched.tick(t);
    }
    println!(
        "scheduler: {} — {} workers placed",
        sched.queue_summary(),
        sched.allocated_nodes().len()
    );

    // --- federated training ------------------------------------------
    println!(
        "hybrid testbed: {} nodes, {} clients/round, {} ({}), {} rounds",
        n,
        cfg.selection.clients_per_round,
        cfg.aggregation.name(),
        cfg.data.dataset,
        cfg.train.rounds,
    );
    let report = run_real(&cfg)?;
    for r in &report.rounds {
        println!(
            "round {:>3}: loss {:.4}  acc {}  {}/{} reported  {:.1}s  up {}",
            r.round,
            r.train_loss,
            r.eval_accuracy
                .map_or("-".to_string(), |a| format!("{:.3}", a)),
            r.reported,
            r.selected,
            r.duration_s,
            fedhpc::util::human_bytes(r.bytes_up),
        );
    }
    println!(
        "\nbest accuracy {:.1}% | compression saved {:.0}% upload vs dense",
        report.best_accuracy().unwrap_or(0.0) * 100.0,
        {
            let dense = report.rounds.len() as f64
                * cfg.selection.clients_per_round as f64
                * 4.0
                * 235_146.0; // P for medmnist; indicative only
            let (_, up) = report.total_bytes();
            (1.0 - up as f64 / dense).max(0.0) * 100.0
        }
    );
    report.save("results")?;
    Ok(())
}
