//! Quickstart: a complete federated run, wired by hand around the
//! composable `OrchestratorBuilder`.
//!
//! Trains the MedMNIST MLP across 8 simulated heterogeneous nodes
//! (2× p3.2xlarge, 2× t3.large, 2× RTX 6000, 2× HPC CPU) with non-IID
//! label-shard data and a round deadline. The aggregation strategy and
//! server optimizer are picked *by registry name*, so the same binary
//! demonstrates FedAvg, robust trimmed-mean, server momentum, …:
//!
//!   cargo run --release --example quickstart -- --mock
//!   cargo run --release --example quickstart -- --mock --aggregation trimmed_mean:0.2
//!   cargo run --release --example quickstart -- --mock --server-opt fedavgm:0.5
//!
//! Run with real AOT compute:   make artifacts && cargo run --release --example quickstart

use fedhpc::client::{Worker, WorkerOptions};
use fedhpc::cluster::Cluster;
use fedhpc::config::presets::quickstart;
use fedhpc::data::{FederatedDataset, Shard};
use fedhpc::faults::FaultInjector;
use fedhpc::network::inproc::InprocHub;
use fedhpc::network::{LinkShaper, TrafficLog};
use fedhpc::orchestrator::strategy::registry::{server_opt_by_name, strategy_by_name};
use fedhpc::orchestrator::{EvalHarness, NoHooks, Orchestrator};
use fedhpc::runtime::{MockRuntime, ModelRuntime, PjrtRuntime};
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    fedhpc::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mock = args.iter().any(|a| a == "--mock");
    let opt_of = |name: &str| {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .cloned()
    };
    // strategy + server optimizer by registry name
    let agg_name = opt_of("--aggregation").unwrap_or_else(|| "fedavg".into());
    let opt_name = opt_of("--server-opt").unwrap_or_else(|| "sgd".into());
    let strategy = strategy_by_name(&agg_name)?;
    let server_opt = server_opt_by_name(&opt_name)?;

    let mut cfg = quickstart();
    cfg.mock_runtime = mock;
    cfg.train.rounds = 10;

    println!(
        "quickstart: {} | {} nodes | {} clients/round | {} rounds | {} + {} | runtime: {}",
        cfg.data.dataset,
        cfg.cluster.total_nodes(),
        cfg.selection.clients_per_round,
        cfg.train.rounds,
        agg_name,
        opt_name,
        if mock { "mock" } else { "PJRT (AOT artifacts)" },
    );

    // the federation, wired by hand: cluster model, partitioned data,
    // in-process transport, one worker thread per node
    let cluster = Cluster::build(&cfg.cluster, cfg.seed)?;
    let n_clients = cluster.len();
    let dataset = FederatedDataset::build(&cfg.data, n_clients, cfg.seed)?;
    let traffic = Arc::new(TrafficLog::new());
    let hub = InprocHub::new(traffic.clone());

    let shared_pjrt = if mock {
        None
    } else {
        Some(PjrtRuntime::load(&cfg.artifacts_dir, &cfg.data.dataset)?)
    };
    let runtime_for = |shard: &Shard| -> Box<dyn ModelRuntime> {
        match &shared_pjrt {
            Some(rt) => Box::new(rt.clone()),
            None => Box::new(MockRuntime::new(shard.x_len, dataset.n_classes)),
        }
    };

    let mut handles = Vec::with_capacity(n_clients);
    for (node, shard) in cluster.nodes.iter().zip(&dataset.clients) {
        let worker = Worker::new(
            hub.add_client(node.id, LinkShaper::from_class(node.link())),
            runtime_for(shard),
            node.clone(),
            shard.clone(),
            FaultInjector::new(cfg.faults, cfg.seed),
            WorkerOptions {
                emulate_speed: true,
                seed: cfg.seed ^ node.id as u64,
                ..Default::default()
            },
        );
        handles.push(std::thread::spawn(move || worker.run()));
    }

    // the composable orchestrator: transport + strategy + server
    // optimizer + evaluation cadence, one typed builder
    let eval_runtime = runtime_for(&dataset.eval);
    let initial = eval_runtime.init(cfg.seed as u32)?;
    let mut orch = Orchestrator::builder(cfg.clone())
        .transport(hub.server())
        .traffic(traffic)
        .initial_params(initial)
        .strategy(strategy)
        .server_opt(server_opt)
        .eval(EvalHarness {
            runtime: eval_runtime,
            shard: dataset.eval.clone(),
        })
        .eval_every(1)
        .build()?;
    let report = orch.run(Some((n_clients, Duration::from_secs(60))), &mut NoHooks)?;
    for h in handles {
        let _ = h.join();
    }

    println!("\nround  train_loss  eval_acc  duration");
    for r in &report.rounds {
        println!(
            "{:>5}  {:>10.4}  {:>8}  {:>7.2}s",
            r.round,
            r.train_loss,
            r.eval_accuracy
                .map_or("-".to_string(), |a| format!("{:.3}", a)),
            r.duration_s
        );
    }
    let (down, up) = report.total_bytes();
    println!(
        "\nfinal accuracy: {:.1}%   traffic: {} down / {} up",
        report.final_accuracy().unwrap_or(0.0) * 100.0,
        fedhpc::util::human_bytes(down),
        fedhpc::util::human_bytes(up),
    );
    report.save("results")?;
    println!("report saved to results/{}.{{json,csv}}", cfg.name);
    Ok(())
}
