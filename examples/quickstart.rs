//! Quickstart: a complete federated run in ~40 lines.
//!
//! Trains the MedMNIST MLP across 8 simulated heterogeneous nodes
//! (2× p3.2xlarge, 2× t3.large, 2× RTX 6000, 2× HPC CPU) with non-IID
//! label-shard data, FedAvg aggregation and a round deadline.
//!
//! Run with real AOT compute:   make artifacts && cargo run --release --example quickstart
//! Run without artifacts:       cargo run --release --example quickstart -- --mock

use fedhpc::config::presets::quickstart;
use fedhpc::experiments::run_real;

fn main() -> anyhow::Result<()> {
    fedhpc::util::logging::init();
    let mock = std::env::args().any(|a| a == "--mock");

    let mut cfg = quickstart();
    cfg.mock_runtime = mock;
    cfg.train.rounds = 10;

    println!(
        "quickstart: {} | {} nodes | {} clients/round | {} rounds | runtime: {}",
        cfg.data.dataset,
        cfg.cluster.total_nodes(),
        cfg.selection.clients_per_round,
        cfg.train.rounds,
        if mock { "mock" } else { "PJRT (AOT artifacts)" },
    );

    let report = run_real(&cfg)?;

    println!("\nround  train_loss  eval_acc  duration");
    for r in &report.rounds {
        println!(
            "{:>5}  {:>10.4}  {:>8}  {:>7.2}s",
            r.round,
            r.train_loss,
            r.eval_accuracy
                .map_or("-".to_string(), |a| format!("{:.3}", a)),
            r.duration_s
        );
    }
    let (down, up) = report.total_bytes();
    println!(
        "\nfinal accuracy: {:.1}%   traffic: {} down / {} up",
        report.final_accuracy().unwrap_or(0.0) * 100.0,
        fedhpc::util::human_bytes(down),
        fedhpc::util::human_bytes(up),
    );
    report.save("results")?;
    println!("report saved to results/{}.{{json,csv}}", cfg.name);
    Ok(())
}
