//! Communication-efficiency demo (paper §4.3 / Table 4): sweep the
//! compression pipeline — none, q16, q8, top-k, federated dropout, and
//! the paper's combined configuration — reporting per-round upload
//! volume and accuracy cost on the same federated workload.

use fedhpc::config::presets::quickstart;
use fedhpc::config::CompressionConfig;
use fedhpc::experiments::run_real;
use fedhpc::util::human_bytes;

fn main() -> anyhow::Result<()> {
    fedhpc::util::logging::init();

    let variants: [(&str, CompressionConfig); 6] = [
        ("none (dense f32)", CompressionConfig::NONE),
        (
            "quantize int16",
            CompressionConfig {
                quant_bits: 16,
                topk_frac: 1.0,
                dropout_keep: 1.0,
            },
        ),
        (
            "quantize int8",
            CompressionConfig {
                quant_bits: 8,
                topk_frac: 1.0,
                dropout_keep: 1.0,
            },
        ),
        (
            "top-10% sparsify",
            CompressionConfig {
                quant_bits: 32,
                topk_frac: 0.1,
                dropout_keep: 1.0,
            },
        ),
        (
            "fed-dropout 50%",
            CompressionConfig {
                quant_bits: 32,
                topk_frac: 1.0,
                dropout_keep: 0.5,
            },
        ),
        ("paper (top-25% + q8)", CompressionConfig::PAPER),
    ];

    println!("compression sweep: 6 variants × 6 rounds, mock runtime\n");
    println!(
        "{:<22} {:>14} {:>10} {:>10}",
        "codec", "upload/round", "vs dense", "accuracy"
    );
    let mut dense_baseline = None;
    for (label, comp) in variants {
        let mut cfg = quickstart();
        cfg.name = format!(
            "compression_demo_{}",
            label
                .chars()
                .map(|c| if c.is_alphanumeric() { c } else { '_' })
                .collect::<String>()
        );
        cfg.mock_runtime = true;
        cfg.train.rounds = 6;
        cfg.train.local_epochs = 1;
        cfg.train.lr = 0.2;
        cfg.data.samples_per_client = 96;
        cfg.data.eval_samples = 256;
        cfg.compression = comp;
        let report = run_real(&cfg)?;
        let up = report.mean_upload_per_round();
        let base = *dense_baseline.get_or_insert(up);
        println!(
            "{:<22} {:>14} {:>9.0}% {:>9.1}%",
            label,
            human_bytes(up as u64),
            up / base * 100.0,
            report.final_accuracy().unwrap_or(0.0) * 100.0,
        );
        report.save("results")?;
    }
    println!("\n(paper Table 4: ~45 MB/round dense → ~15 MB compressed, ≈65% reduction)");
    Ok(())
}
