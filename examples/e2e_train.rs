//! End-to-end validation driver (DESIGN.md E8): federated training of
//! the ~3.3M-parameter char-transformer (`e2e_charlm`) through the full
//! stack — Pallas/JAX AOT artifacts, PJRT runtime, Rust orchestrator,
//! compression, heterogeneous cluster — for a few hundred aggregate
//! optimization rounds, logging the loss curve.
//!
//! Requires `make artifacts`. Runtime on CPU is dominated by the
//! transformer fwd/bwd (~0.7 s/step); the default configuration
//! (6 clients × 2 sel/round × 4 steps × 60 rounds ≈ 480 client steps)
//! finishes in tens of minutes. `--rounds N` / `--tiny` adjust.

use fedhpc::config::presets::quickstart;
use fedhpc::config::{Aggregation, CompressionConfig, Partition};
use fedhpc::experiments::run_real;

fn main() -> anyhow::Result<()> {
    fedhpc::util::logging::init();
    let args: Vec<String> = std::env::args().collect();
    let tiny = args.iter().any(|a| a == "--tiny");
    let rounds = args
        .iter()
        .position(|a| a == "--rounds")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(if tiny { 3 } else { 60 });

    let mut cfg = quickstart();
    cfg.name = "e2e_charlm".into();
    cfg.data.dataset = "e2e_charlm".into();
    cfg.mock_runtime = false; // the whole point: the real AOT stack
    cfg.data.partition = Partition::LabelShard {
        classes_per_client: 3, // 3 of the 10 corpus roles per client
    };
    cfg.aggregation = Aggregation::FedProx { mu: 0.001 };
    cfg.compression = CompressionConfig {
        quant_bits: 16,
        topk_frac: 0.5,
        dropout_keep: 1.0,
    };
    cfg.train.rounds = rounds;
    cfg.train.local_epochs = 1;
    cfg.train.lr = 0.05;
    cfg.cluster.nodes = vec![
        ("hpc-rtx6000".into(), 3),
        ("p3.2xlarge".into(), 2),
        ("t3.large".into(), 1),
    ];
    cfg.selection.clients_per_round = 2;
    cfg.straggler.deadline_ms = Some(3_600_000);
    if tiny {
        cfg.data.samples_per_client = 16; // 2 steps/epoch at batch 8
        cfg.data.eval_samples = 32;
    } else {
        cfg.data.samples_per_client = 32; // 4 steps/epoch at batch 8
        cfg.data.eval_samples = 64;
    }

    println!(
        "e2e: char-transformer (~3.3M params) | {} rounds | {} clients/round | fedprox+q16/top50%",
        cfg.train.rounds, cfg.selection.clients_per_round
    );
    let t0 = std::time::Instant::now();
    let report = run_real(&cfg)?;
    println!("\nround  train_loss  eval_loss  eval_acc  bytes_up");
    for r in &report.rounds {
        println!(
            "{:>5}  {:>10.4}  {:>9}  {:>8}  {:>9}",
            r.round,
            r.train_loss,
            r.eval_loss.map_or("-".into(), |l| format!("{l:.4}")),
            r.eval_accuracy
                .map_or("-".to_string(), |a| format!("{:.3}", a)),
            fedhpc::util::human_bytes(r.bytes_up),
        );
    }
    let first = report.rounds.first().map(|r| r.train_loss).unwrap_or(0.0);
    let last = report.rounds.last().map(|r| r.train_loss).unwrap_or(0.0);
    println!(
        "\nloss {first:.3} → {last:.3} over {} rounds in {:.1} min (char-level acc {:.1}%)",
        report.rounds.len(),
        t0.elapsed().as_secs_f64() / 60.0,
        report.final_accuracy().unwrap_or(0.0) * 100.0,
    );
    report.save("results")?;
    println!("loss curve saved to results/e2e_charlm.csv");
    Ok(())
}
