//! Straggler mitigation demo (paper §4.2): the same workload run
//! three ways against injected stragglers —
//!   1. no mitigation (wait for everyone),
//!   2. deadline-based cutoff,
//!   3. deadline + partial-k aggregation,
//! comparing wall-clock per round and accuracy. Uses the mock runtime
//! so it runs anywhere in seconds.

use fedhpc::config::presets::quickstart;
use fedhpc::config::StragglerConfig;
use fedhpc::experiments::run_real;

fn main() -> anyhow::Result<()> {
    fedhpc::util::logging::init();

    let variants: [(&str, StragglerConfig); 3] = [
        (
            "no mitigation",
            StragglerConfig {
                deadline_ms: None,
                partial_k: None,
            },
        ),
        (
            "deadline cutoff",
            StragglerConfig {
                deadline_ms: Some(400),
                partial_k: None,
            },
        ),
        (
            "deadline + partial-k",
            StragglerConfig {
                deadline_ms: Some(400),
                partial_k: Some(3),
            },
        ),
    ];

    println!("straggler demo: 25% of clients run 20x slower each round\n");
    println!(
        "{:<22} {:>12} {:>12} {:>10}",
        "mitigation", "s/round", "total", "accuracy"
    );
    for (label, straggler) in variants {
        let mut cfg = quickstart();
        cfg.name = format!("straggler_demo_{}", label.replace(' ', "_"));
        cfg.mock_runtime = true;
        cfg.train.rounds = 6;
        cfg.train.local_epochs = 3;
        cfg.train.lr = 0.2;
        cfg.data.samples_per_client = 384;
        cfg.data.eval_samples = 256;
        cfg.selection.clients_per_round = 4;
        cfg.faults.straggler_prob = 0.25;
        cfg.faults.straggler_factor = 20.0;
        cfg.straggler = straggler;
        let report = run_real(&cfg)?;
        println!(
            "{:<22} {:>11.2}s {:>11.1}s {:>9.1}%",
            label,
            report.total_duration_s() / report.rounds.len() as f64,
            report.total_duration_s(),
            report.final_accuracy().unwrap_or(0.0) * 100.0,
        );
        report.save("results")?;
    }
    println!("\n(paper §5.5: without straggler mitigation, 15–20% longer to 80% accuracy)");
    Ok(())
}
