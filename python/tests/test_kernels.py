"""L1 kernel correctness: Pallas vs pure-jnp oracle (ref.py).

hypothesis sweeps shapes and value ranges; every property is the core
correctness signal for what the Rust runtime will eventually execute.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import compress as C
from compile.kernels import matmul as M
from compile.kernels import ref as R

SETTINGS = dict(max_examples=25, deadline=None)


def rng_array(seed, shape, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


# ---------------------------------------------------------------- matmul


@settings(**SETTINGS)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_small(m, k, n, seed):
    x = rng_array(seed, (m, k))
    w = rng_array(seed + 1, (k, n))
    np.testing.assert_allclose(
        M.matmul(x, w), R.matmul_ref(x, w), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize(
    "m,k,n",
    [(128, 128, 128), (256, 384, 128), (32, 2048, 128), (200, 130, 250), (1, 1, 1)],
)
def test_matmul_matches_ref_tiled(m, k, n):
    x = rng_array(0, (m, k))
    w = rng_array(1, (k, n))
    np.testing.assert_allclose(
        M.matmul(x, w), R.matmul_ref(x, w), rtol=1e-4, atol=1e-4
    )


def test_matmul_zero_and_identity():
    x = rng_array(2, (16, 16))
    eye = jnp.eye(16)
    np.testing.assert_allclose(M.matmul(x, eye), x, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        M.matmul(x, jnp.zeros((16, 16))), jnp.zeros((16, 16)), atol=0
    )


def test_matmul_custom_vjp_matches_jnp_grad():
    x = rng_array(3, (24, 40))
    w = rng_array(4, (40, 12))

    def f_pallas(x, w):
        return jnp.sum(jnp.sin(M.matmul_ad(x, w)))

    def f_ref(x, w):
        return jnp.sum(jnp.sin(R.matmul_ref(x, w)))

    gx_p, gw_p = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx_p, gx_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw_p, gw_r, rtol=1e-4, atol=1e-4)


def test_dense_broadcasts_leading_axes():
    x = rng_array(5, (4, 7, 32))
    w = rng_array(6, (32, 9))
    b = rng_array(7, (9,))
    out = M.dense(x, w, b)
    assert out.shape == (4, 7, 9)
    ref = R.matmul_ref(x.reshape(-1, 32), w).reshape(4, 7, 9) + b
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ quantization


@settings(**SETTINGS)
@given(
    n=st.integers(1, 5000),
    scale=st.floats(1e-3, 1e3),
    bits=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_matches_ref(n, scale, bits, seed):
    g = rng_array(seed, (n,), scale)
    q, s = C.quantize(g, bits)
    qr, sr = R.quantize_ref(g, bits)
    np.testing.assert_allclose(s, sr, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))


@settings(**SETTINGS)
@given(n=st.integers(1, 5000), bits=st.sampled_from([8, 16]), seed=st.integers(0, 2**31 - 1))
def test_quantize_roundtrip_error_bound(n, bits, seed):
    """|dequant(quant(g)) - g| <= scale/2 + f32 rounding slack."""
    g = rng_array(seed, (n,))
    q, s = C.quantize(g, bits)
    back = C.dequantize(q, s)
    maxabs = float(jnp.max(jnp.abs(g)))
    tol = float(s) / 2 + maxabs * 1e-5 + 1e-7
    assert float(jnp.max(jnp.abs(back - g))) <= tol


def test_quantize_all_zero_vector():
    g = jnp.zeros(100)
    q, s = C.quantize(g, 8)
    assert float(s) == 1.0
    np.testing.assert_array_equal(np.asarray(q), np.zeros(100, np.int8))
    np.testing.assert_array_equal(np.asarray(C.dequantize(q, s)), np.zeros(100))


def test_quantize_extremes_hit_qmax():
    g = jnp.asarray([1.0, -1.0, 0.5], jnp.float32)
    q, s = C.quantize(g, 8)
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) == 127


# ----------------------------------------------------------- sparsification


@settings(**SETTINGS)
@given(n=st.integers(1, 4000), frac=st.floats(0.01, 1.0), seed=st.integers(0, 2**31 - 1))
def test_sparsify_matches_ref(n, frac, seed):
    g = rng_array(seed, (n,))
    k = max(1, int(n * frac))
    np.testing.assert_allclose(C.sparsify(g, k), R.sparsify_ref(g, k), atol=0)


@settings(**SETTINGS)
@given(n=st.integers(10, 4000), seed=st.integers(0, 2**31 - 1))
def test_sparsify_keeps_exactly_k_distinct_magnitudes(n, seed):
    g = rng_array(seed, (n,))  # continuous → ties have prob 0
    k = n // 3 + 1
    out = np.asarray(C.sparsify(g, k))
    assert int((out != 0).sum()) == k
    # survivors are exactly the k largest magnitudes
    idx = np.argsort(-np.abs(np.asarray(g)))[:k]
    mask = np.zeros(n, bool)
    mask[idx] = True
    np.testing.assert_allclose(out[mask], np.asarray(g)[mask], atol=0)
    assert (out[~mask] == 0).all()


def test_sparsify_k_ge_n_is_identity():
    g = rng_array(11, (37,))
    np.testing.assert_allclose(C.sparsify(g, 37), g, atol=0)
    np.testing.assert_allclose(C.sparsify(g, 100), g, atol=0)


# --------------------------------------------------------------- fedprox


@settings(**SETTINGS)
@given(
    n=st.integers(1, 4000),
    lr=st.floats(1e-4, 1.0),
    mu=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_fedprox_step_matches_ref(n, lr, mu, seed):
    w = rng_array(seed, (n,))
    g = rng_array(seed + 1, (n,))
    wg = rng_array(seed + 2, (n,))
    out = C.fedprox_step(w, g, wg, jnp.float32(lr), jnp.float32(mu))
    ref = R.fedprox_step_ref(w, g, wg, jnp.float32(lr), jnp.float32(mu))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)


def test_fedprox_mu_zero_is_plain_sgd():
    w = rng_array(20, (512,))
    g = rng_array(21, (512,))
    out = C.fedprox_step(w, g, jnp.zeros(512), jnp.float32(0.1), jnp.float32(0.0))
    np.testing.assert_allclose(out, w - 0.1 * g, rtol=1e-6, atol=1e-7)


def test_fedprox_pulls_toward_global():
    """With zero gradient, the prox term moves w toward w_global."""
    w = jnp.ones(64)
    wg = jnp.zeros(64)
    out = C.fedprox_step(w, jnp.zeros(64), wg, jnp.float32(0.5), jnp.float32(1.0))
    assert float(jnp.max(out)) < 1.0
    assert float(jnp.min(out)) >= 0.0
