"""L2 model correctness: shapes, flat-param round-trips, training signal,
FedProx semantics and pallas/jnp impl parity.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as steps
from compile.models import REGISTRY
from compile.models.common import init_flat

PAPER_MODELS = ["cifar_cnn", "charlm", "medmnist_mlp"]
ALL_MODELS = PAPER_MODELS + ["e2e_charlm"]


def make_batch(mdef, kind="train", seed=0, classes=None):
    rng = np.random.default_rng(seed)
    b = mdef.train_batch if kind == "train" else mdef.eval_batch
    if mdef.x_dtype == "f32":
        x = rng.standard_normal((b, *mdef.x_shape), dtype=np.float32)
    else:
        x = rng.integers(0, 50, (b, *mdef.x_shape)).astype(np.int32)
    hi = classes or 10
    y = rng.integers(0, hi, (b, *mdef.y_shape)).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


# ------------------------------------------------------------- param spec


@pytest.mark.parametrize("name", ALL_MODELS)
def test_spec_layout_is_contiguous(name):
    spec = REGISTRY[name].spec
    assert len(spec.names) == len(set(spec.names)), "duplicate param names"
    acc = 0
    for off, sz in zip(spec.offsets, spec.sizes):
        assert off == acc
        acc += sz
    assert acc == spec.total == REGISTRY[name].n_params


@pytest.mark.parametrize("name", PAPER_MODELS)
def test_flatten_unflatten_roundtrip(name):
    spec = REGISTRY[name].spec
    flat = jnp.arange(spec.total, dtype=jnp.float32)
    tree = spec.unflatten(flat)
    assert set(tree) == set(spec.names)
    for n, s in zip(spec.names, spec.shapes):
        assert tree[n].shape == tuple(s)
    np.testing.assert_array_equal(np.asarray(spec.flatten(tree)), np.asarray(flat))


@pytest.mark.parametrize("name", PAPER_MODELS)
def test_init_is_deterministic_and_seed_sensitive(name):
    spec = REGISTRY[name].spec
    a = init_flat(spec, jnp.uint32(7))
    b = init_flat(spec, jnp.uint32(7))
    c = init_flat(spec, jnp.uint32(8))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    assert np.isfinite(np.asarray(a)).all()


def test_init_respects_naming_convention():
    spec = REGISTRY["charlm"].spec
    tree = spec.unflatten(init_flat(spec, jnp.uint32(0)))
    np.testing.assert_array_equal(np.asarray(tree["b0_ln1_scale"]), np.ones(64))
    np.testing.assert_array_equal(np.asarray(tree["b0_qkv_b"]), np.zeros(192))
    assert float(jnp.std(tree["tok_emb"])) < 0.05  # 0.02-ish embeddings


# ----------------------------------------------------------------- steps


@pytest.mark.parametrize("name", PAPER_MODELS)
def test_train_step_shapes_and_finiteness(name):
    mdef = REGISTRY[name]
    p = init_flat(mdef.spec, jnp.uint32(0))
    x, y = make_batch(mdef)
    ts = jax.jit(steps.make_train_step(mdef, mdef.default_impl))
    p2, loss, correct = ts(p, p, x, y, jnp.float32(0.01), jnp.float32(0.0))
    assert p2.shape == p.shape
    assert np.isfinite(np.asarray(p2)).all()
    assert float(loss) > 0
    n_labels = y.size
    assert 0 <= float(correct) <= n_labels


@pytest.mark.parametrize("name", PAPER_MODELS)
def test_training_reduces_loss(name):
    """A few steps on a fixed batch must reduce loss (learning signal)."""
    mdef = REGISTRY[name]
    p = init_flat(mdef.spec, jnp.uint32(1))
    x, y = make_batch(mdef, seed=3)
    ts = jax.jit(steps.make_train_step(mdef, mdef.default_impl))
    first = None
    lr = jnp.float32(0.02 if name == "cifar_cnn" else 0.05)
    for i in range(8):
        p, loss, _ = ts(p, p, x, y, lr, jnp.float32(0.0))
        if first is None:
            first = float(loss)
    assert float(loss) < first, (first, float(loss))


@pytest.mark.parametrize("name", PAPER_MODELS)
def test_eval_step_counts(name):
    mdef = REGISTRY[name]
    p = init_flat(mdef.spec, jnp.uint32(0))
    x, y = make_batch(mdef, kind="eval")
    ev = jax.jit(steps.make_eval_step(mdef, mdef.default_impl))
    loss_sum, correct = ev(p, x, y)
    n_labels = y.size
    assert 0 <= float(correct) <= n_labels
    assert float(loss_sum) / n_labels > 0


def test_fedprox_mu_limits():
    """mu=0 equals plain SGD path; large mu keeps params near global."""
    mdef = REGISTRY["medmnist_mlp"]
    p = init_flat(mdef.spec, jnp.uint32(0))
    x, y = make_batch(mdef)
    ts = jax.jit(steps.make_train_step(mdef, mdef.default_impl))
    lr = jnp.float32(0.05)
    p_sgd, _, _ = ts(p, p, x, y, lr, jnp.float32(0.0))
    # identical global == params → prox gradient is 0 at the first step
    p_prox0, _, _ = ts(p, p, x, y, lr, jnp.float32(10.0))
    np.testing.assert_allclose(np.asarray(p_sgd), np.asarray(p_prox0), atol=1e-6)
    # after drifting, large mu pulls back toward global
    drift, _, _ = ts(p_sgd, p, x, y, lr, jnp.float32(0.0))
    pulled, _, _ = ts(p_sgd, p, x, y, lr, jnp.float32(50.0))
    d_drift = float(jnp.linalg.norm(drift - p))
    d_pull = float(jnp.linalg.norm(pulled - p))
    assert d_pull < d_drift


def test_pallas_and_jnp_impls_agree():
    """The two kernel impls must produce the same lowered math."""
    mdef = REGISTRY["medmnist_mlp"]
    p = init_flat(mdef.spec, jnp.uint32(2))
    x, y = make_batch(mdef, seed=5)
    ts_p = jax.jit(steps.make_train_step(mdef, "pallas"))
    ts_j = jax.jit(steps.make_train_step(mdef, "jnp"))
    args = (p, p, x, y, jnp.float32(0.05), jnp.float32(0.1))
    out_p = ts_p(*args)
    out_j = ts_j(*args)
    np.testing.assert_allclose(np.asarray(out_p[1]), np.asarray(out_j[1]), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(out_p[0]), np.asarray(out_j[0]), rtol=1e-4, atol=1e-5
    )


def test_charlm_causality():
    """Future tokens must not influence logits at earlier positions."""
    mdef = REGISTRY["charlm"]
    p = mdef.spec.unflatten(init_flat(mdef.spec, jnp.uint32(0)))
    rng = np.random.default_rng(0)
    x1 = rng.integers(0, 60, (1, 32)).astype(np.int32)
    x2 = x1.copy()
    x2[0, -1] = (x2[0, -1] + 1) % 60  # perturb only the last token
    l1 = mdef.apply(p, jnp.asarray(x1), "jnp")
    l2 = mdef.apply(p, jnp.asarray(x2), "jnp")
    np.testing.assert_allclose(
        np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]), atol=1e-5
    )
    assert not np.allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]))


def test_example_args_match_manifest_shapes():
    for name in ALL_MODELS:
        mdef = REGISTRY[name]
        args = steps.example_args(mdef, "train")
        assert args[0].shape == (mdef.n_params,)
        assert args[2].shape == (mdef.train_batch, *mdef.x_shape)
        args_e = steps.example_args(mdef, "eval")
        assert args_e[1].shape == (mdef.eval_batch, *mdef.x_shape)
