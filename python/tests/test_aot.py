"""AOT pipeline tests: HLO text is parseable-looking, manifest is
consistent with the registry, and exported entry computations carry the
expected parameter/result shapes.
"""

import json
import os

import pytest

from compile import aot
from compile.models import REGISTRY

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_small_model_produces_hlo_text():
    text = aot.lower_step(REGISTRY["medmnist_mlp"], "eval", "jnp")
    assert "HloModule" in text
    assert "ENTRY" in text
    # flat param vector appears as an f32[P] operand
    assert f"f32[{REGISTRY['medmnist_mlp'].n_params}]" in text


def test_lower_train_step_has_tuple_result():
    text = aot.lower_step(REGISTRY["medmnist_mlp"], "train", "jnp")
    # return_tuple=True → root is a tuple of (params', loss, correct)
    p = REGISTRY["medmnist_mlp"].n_params
    assert f"(f32[{p}]" in text


def test_model_manifest_fields():
    m = aot.model_manifest(REGISTRY["charlm"], "pallas")
    assert m["n_params"] == REGISTRY["charlm"].n_params
    assert m["x_dtype"] == "i32"
    assert m["samples_per_example"] == 32
    assert len(m["param_names"]) == len(m["param_shapes"])


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_built_artifacts_match_registry():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["version"] == aot.MANIFEST_VERSION
    for name, entry in manifest["models"].items():
        assert name in REGISTRY
        assert entry["n_params"] == REGISTRY[name].n_params
        for kind in ("init", "train", "eval"):
            path = os.path.join(ART, f"{name}_{kind}.hlo.txt")
            assert os.path.exists(path), path
            with open(path) as fh:
                head = fh.read(4096)
            assert "HloModule" in head
