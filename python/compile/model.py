"""L2 step builders: turn a :class:`ModelDef` into the three jittable
functions the Rust runtime executes (init / train_step / eval_step).

Signatures (all params flat ``f32[P]``; see models/common.py):

* ``init(seed u32[]) -> (params f32[P],)``
* ``train_step(params, global_params, x, y, lr f32[], mu f32[])
    -> (new_params f32[P], loss f32[], correct f32[])``
  One SGD minibatch step. The FedProx proximal term μ/2·‖w−w₀‖² is
  folded into the fused L1 update kernel (its gradient is μ(w−w₀));
  μ=0 recovers plain FedAvg local SGD, so one artifact serves both
  aggregation strategies (paper §4.4).
* ``eval_step(params, x, y) -> (loss_sum f32[], correct f32[])``
  Sum-reducible so the Rust side can accumulate over shards.

The Rust client drives ``train_step`` once per local minibatch for the
configured number of local epochs (paper §5.1: 5 local epochs), keeping
the epoch loop — a *policy* decision — in L3 while all math stays in
the AOT-compiled HLO.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .kernels.compress import fedprox_step
from .kernels.ref import fedprox_step_ref
from .models.common import ModelDef, softmax_xent


def make_init(mdef: ModelDef) -> Callable:
    from .models.common import init_flat

    def init(seed: jax.Array):
        return (init_flat(mdef.spec, seed),)

    return init


def make_train_step(mdef: ModelDef, impl: str) -> Callable:
    """Build the fused local-SGD/FedProx minibatch step."""
    use_pallas_update = impl == "pallas"

    def train_step(params, global_params, x, y, lr, mu):
        def loss_fn(flat):
            logits = mdef.apply(mdef.spec.unflatten(flat), x, impl)
            loss, correct = softmax_xent(logits, y)
            return loss, correct

        (loss, correct), grad = jax.value_and_grad(loss_fn, has_aux=True)(params)
        step = fedprox_step if use_pallas_update else fedprox_step_ref
        new_params = step(params, grad, global_params, lr, mu)
        return new_params, loss, correct

    return train_step


def make_eval_step(mdef: ModelDef, impl: str) -> Callable:
    def eval_step(params, x, y):
        logits = mdef.apply(mdef.spec.unflatten(params), x, impl)
        loss, correct = softmax_xent(logits, y)
        n = jnp.float32(logits.reshape((-1, logits.shape[-1])).shape[0])
        return loss * n, correct  # loss_sum over label positions

    return eval_step


def example_args(mdef: ModelDef, kind: str):
    """ShapeDtypeStructs to lower each step with (static shapes)."""
    p = jax.ShapeDtypeStruct((mdef.n_params,), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    if kind == "init":
        return (jax.ShapeDtypeStruct((), jnp.uint32),)
    batch = mdef.train_batch if kind == "train" else mdef.eval_batch
    x = jax.ShapeDtypeStruct((batch,) + mdef.x_shape, mdef.x_jnp_dtype())
    y = jax.ShapeDtypeStruct((batch,) + mdef.y_shape, jnp.int32)
    if kind == "train":
        return (p, p, x, y, scalar, scalar)
    assert kind == "eval"
    return (p, x, y)
