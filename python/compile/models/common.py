"""Flat-parameter model machinery shared by all L2 models.

The FL coordinator (L3, Rust) never sees pytrees: every exported HLO
takes and returns parameters as one contiguous ``f32[P]`` vector. Each
model declares an ordered :class:`ParamSpec` (name → shape); the L2
code unflattens inside the traced function (pure reshape/slice ops that
XLA folds away) so the flat API costs nothing at runtime.

``dense_fn(impl)`` selects the matmul implementation for dense layers:
``"pallas"`` routes through the L1 tiled MXU kernel (the default for
the paper's three workloads), ``"jnp"`` uses the jnp oracle (used for
the large e2e model where interpret-mode emulation overhead in the
*lowered* HLO would dominate CPU wall-clock; on a real TPU both lower
to the same Mosaic kernel — see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..kernels import matmul as pallas_mm
from ..kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Ordered layout of a model's parameters inside the flat vector."""

    names: Tuple[str, ...]
    shapes: Tuple[Tuple[int, ...], ...]

    @staticmethod
    def from_pairs(pairs: Sequence[Tuple[str, Tuple[int, ...]]]) -> "ParamSpec":
        names, shapes = zip(*pairs)
        return ParamSpec(tuple(names), tuple(tuple(s) for s in shapes))

    @property
    def sizes(self) -> Tuple[int, ...]:
        return tuple(int(math.prod(s)) for s in self.shapes)

    @property
    def offsets(self) -> Tuple[int, ...]:
        offs, acc = [], 0
        for sz in self.sizes:
            offs.append(acc)
            acc += sz
        return tuple(offs)

    @property
    def total(self) -> int:
        return sum(self.sizes)

    def unflatten(self, flat: jax.Array) -> Dict[str, jax.Array]:
        """Slice the flat vector into named, shaped parameters."""
        out = {}
        for name, shape, off, sz in zip(
            self.names, self.shapes, self.offsets, self.sizes
        ):
            out[name] = jax.lax.dynamic_slice(flat, (off,), (sz,)).reshape(shape)
        return out

    def flatten(self, tree: Dict[str, jax.Array]) -> jax.Array:
        """Concatenate named parameters back into the flat vector."""
        return jnp.concatenate(
            [tree[n].reshape(-1).astype(jnp.float32) for n in self.names]
        )


def init_param(key: jax.Array, name: str, shape: Tuple[int, ...]) -> jax.Array:
    """Initializer dispatch by naming convention.

    ``*_w`` dense/conv weights get fan-in-scaled normals (He), ``*_emb``
    embeddings get N(0, 0.02), ``*_scale`` LayerNorm scales get ones and
    everything else (biases, LN offsets) zeros.
    """
    if name.endswith("_scale"):
        return jnp.ones(shape, jnp.float32)
    if name.endswith("_emb"):
        return 0.02 * jax.random.normal(key, shape, jnp.float32)
    if name.endswith("_w"):
        fan_in = int(math.prod(shape[:-1])) if len(shape) > 1 else shape[0]
        std = math.sqrt(2.0 / max(1, fan_in))
        return std * jax.random.normal(key, shape, jnp.float32)
    return jnp.zeros(shape, jnp.float32)


def init_flat(spec: ParamSpec, seed: jax.Array) -> jax.Array:
    """Initialize the flat parameter vector from a scalar uint32 seed."""
    key = jax.random.PRNGKey(seed)
    parts = []
    for i, (name, shape) in enumerate(zip(spec.names, spec.shapes)):
        parts.append(init_param(jax.random.fold_in(key, i), name, shape).reshape(-1))
    return jnp.concatenate(parts)


def dense_fn(impl: str) -> Callable:
    """Return ``dense(x, w, b)`` for the chosen matmul implementation."""
    if impl == "pallas":
        return pallas_mm.dense

    def jnp_dense(x, w, b=None):
        y = kref.matmul_ref(x.reshape((-1, x.shape[-1])), w)
        if b is not None:
            y = y + b
        return y.reshape(x.shape[:-1] + (w.shape[1],))

    return jnp_dense


def softmax_xent(logits: jax.Array, y: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Mean cross-entropy + correct-prediction count over flattened labels.

    ``logits``: f32[..., C]; ``y``: i32[...]. Returns (mean_loss f32[],
    correct f32[]).
    """
    c = logits.shape[-1]
    logits2 = logits.reshape((-1, c))
    y2 = y.reshape((-1,))
    logz = jax.nn.logsumexp(logits2, axis=-1)
    ll = jnp.take_along_axis(logits2, y2[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - ll)
    correct = jnp.sum((jnp.argmax(logits2, axis=-1) == y2).astype(jnp.float32))
    return loss, correct


@dataclasses.dataclass(frozen=True)
class ModelDef:
    """Everything the AOT exporter needs to emit one model's artifacts."""

    name: str
    spec: ParamSpec
    x_shape: Tuple[int, ...]  # per-example input shape (no batch dim)
    x_dtype: str  # "f32" | "i32"
    y_shape: Tuple[int, ...]  # per-example label shape
    train_batch: int
    eval_batch: int
    default_impl: str
    # apply(params_dict, x, impl) -> logits
    apply: Callable[[Dict[str, jax.Array], jax.Array, str], jax.Array]
    # samples counted per batch element (e.g. seq_len for LMs)
    samples_per_example: int = 1

    @property
    def n_params(self) -> int:
        return self.spec.total

    def x_jnp_dtype(self):
        return jnp.float32 if self.x_dtype == "f32" else jnp.int32


REGISTRY: Dict[str, ModelDef] = {}


def register(mdef: ModelDef) -> ModelDef:
    REGISTRY[mdef.name] = mdef
    return mdef


def get_model(name: str) -> ModelDef:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown model {name!r}; have {sorted(REGISTRY)}") from None
