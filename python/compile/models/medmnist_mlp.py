"""MedMNIST workload (paper §5.2): 28×28 grayscale medical-image
classification, simulating the privacy-sensitive healthcare setting.

A 784→256→128→10 MLP; every layer is a Pallas-matmul dense layer, so
this model exercises the L1 kernel end-to-end including the backward
pass (custom VJP → two more Pallas matmuls per layer).
"""

from __future__ import annotations

from typing import Dict

import jax

from .common import ModelDef, ParamSpec, dense_fn, register

IN_DIM = 28 * 28
N_CLASSES = 10

SPEC = ParamSpec.from_pairs(
    [
        ("fc1_w", (IN_DIM, 256)),
        ("fc1_b", (256,)),
        ("fc2_w", (256, 128)),
        ("fc2_b", (128,)),
        ("fc3_w", (128, N_CLASSES)),
        ("fc3_b", (N_CLASSES,)),
    ]
)


def apply(params: Dict[str, jax.Array], x: jax.Array, impl: str) -> jax.Array:
    """Forward pass: x f32[B,784] → logits f32[B,10]."""
    dense = dense_fn(impl)
    h = jax.nn.relu(dense(x, params["fc1_w"], params["fc1_b"]))
    h = jax.nn.relu(dense(h, params["fc2_w"], params["fc2_b"]))
    return dense(h, params["fc3_w"], params["fc3_b"])


MODEL = register(
    ModelDef(
        name="medmnist_mlp",
        spec=SPEC,
        x_shape=(IN_DIM,),
        x_dtype="f32",
        y_shape=(),
        train_batch=32,
        eval_batch=64,
        default_impl="pallas",
        apply=apply,
    )
)
