"""L2 model zoo: the paper's three evaluation workloads plus the larger
end-to-end char-transformer, all exposed through a flat-parameter-vector
API so the Rust coordinator treats model state as a single ``f32[P]``
buffer (what gets aggregated, compressed and shipped).
"""

from . import cifar_cnn, charlm, medmnist_mlp
from .common import ModelDef, ParamSpec, REGISTRY, get_model

__all__ = [
    "ModelDef",
    "ParamSpec",
    "REGISTRY",
    "get_model",
    "cifar_cnn",
    "charlm",
    "medmnist_mlp",
]
