"""Shakespeare workload (paper §5.2): character-level language modeling.

Two sizes of the same pre-norm transformer:

* ``charlm`` — the federated evaluation model (vocab 64, seq 32, d=64,
  1 block): small enough that 60 simulated clients can train it in real
  time on CPU PJRT. Dense projections use the Pallas matmul kernel.
* ``e2e_charlm`` — the end-to-end driver model (vocab 96, seq 128,
  d=256, 4 blocks, ~3.4M params) used by ``examples/e2e_train.rs``.
  Exported with ``impl="jnp"`` by default: under CPU interpret mode the
  *emulated* Pallas loop nest in the lowered HLO would dominate
  wall-clock; on a real TPU both impls lower to the same MXU kernel
  (DESIGN.md §Hardware-Adaptation).

Attention mixing uses jnp einsums (batched per-head matmuls; the L1
kernel is 2-D) — the parameter-bearing projections and MLP, i.e. the
dominant FLOPs, go through the kernel.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .common import ModelDef, ParamSpec, dense_fn, register


def _spec(vocab: int, seq: int, d: int, blocks: int, mlp_mult: int) -> ParamSpec:
    pairs: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_emb", (vocab, d)),
        ("pos_emb", (seq, d)),
    ]
    for i in range(blocks):
        p = f"b{i}_"
        pairs += [
            (p + "ln1_scale", (d,)),
            (p + "ln1_bias", (d,)),
            (p + "qkv_w", (d, 3 * d)),
            (p + "qkv_b", (3 * d,)),
            (p + "proj_w", (d, d)),
            (p + "proj_b", (d,)),
            (p + "ln2_scale", (d,)),
            (p + "ln2_bias", (d,)),
            (p + "mlp1_w", (d, mlp_mult * d)),
            (p + "mlp1_b", (mlp_mult * d,)),
            (p + "mlp2_w", (mlp_mult * d, d)),
            (p + "mlp2_b", (d,)),
        ]
    pairs += [
        ("lnf_scale", (d,)),
        ("lnf_bias", (d,)),
        ("head_w", (d, vocab)),
        ("head_b", (vocab,)),
    ]
    return ParamSpec.from_pairs(pairs)


def _layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * scale + bias


def _attention(h: jax.Array, qkv, heads: int) -> jax.Array:
    """Causal multi-head self-attention. h: f32[B,T,D]."""
    b, t, d = h.shape
    hd = d // heads
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def split_heads(a):
        return a.reshape(b, t, heads, hd).transpose(0, 2, 1, 3)  # B,H,T,hd

    q, k, v = split_heads(q), split_heads(k), split_heads(v)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    return out.transpose(0, 2, 1, 3).reshape(b, t, d)


def _make_apply(seq: int, d: int, blocks: int, heads: int):
    def apply(params: Dict[str, jax.Array], x: jax.Array, impl: str) -> jax.Array:
        """Forward pass: x i32[B,T] → logits f32[B,T,V]."""
        dense = dense_fn(impl)
        h = params["tok_emb"][x] + params["pos_emb"][None, :, :]
        for i in range(blocks):
            p = f"b{i}_"
            a = _layernorm(h, params[p + "ln1_scale"], params[p + "ln1_bias"])
            qkv = dense(a, params[p + "qkv_w"], params[p + "qkv_b"])
            h = h + dense(
                _attention(a, qkv, heads), params[p + "proj_w"], params[p + "proj_b"]
            )
            m = _layernorm(h, params[p + "ln2_scale"], params[p + "ln2_bias"])
            m = jax.nn.gelu(dense(m, params[p + "mlp1_w"], params[p + "mlp1_b"]))
            h = h + dense(m, params[p + "mlp2_w"], params[p + "mlp2_b"])
        h = _layernorm(h, params["lnf_scale"], params["lnf_bias"])
        return dense(h, params["head_w"], params["head_b"])

    return apply


VOCAB, SEQ, D, BLOCKS, HEADS = 64, 32, 64, 1, 4
MODEL = register(
    ModelDef(
        name="charlm",
        spec=_spec(VOCAB, SEQ, D, BLOCKS, 4),
        x_shape=(SEQ,),
        x_dtype="i32",
        y_shape=(SEQ,),
        train_batch=16,
        eval_batch=32,
        default_impl="pallas",
        apply=_make_apply(SEQ, D, BLOCKS, HEADS),
        samples_per_example=SEQ,
    )
)

E2E_VOCAB, E2E_SEQ, E2E_D, E2E_BLOCKS, E2E_HEADS = 96, 128, 256, 4, 8
E2E_MODEL = register(
    ModelDef(
        name="e2e_charlm",
        spec=_spec(E2E_VOCAB, E2E_SEQ, E2E_D, E2E_BLOCKS, 4),
        x_shape=(E2E_SEQ,),
        x_dtype="i32",
        y_shape=(E2E_SEQ,),
        train_batch=8,
        eval_batch=16,
        default_impl="jnp",
        apply=_make_apply(E2E_SEQ, E2E_D, E2E_BLOCKS, E2E_HEADS),
        samples_per_example=E2E_SEQ,
    )
)
