"""CIFAR-10 workload (paper §5.2): a small conv-net over 32×32×3 images.

Two conv+pool stages feed a Pallas-matmul dense head; this mirrors the
class of model the paper trains on CIFAR-10 under non-IID label-shard
partitioning (2–3 classes per client). Convs use ``lax.conv`` (XLA's
native conv is already the right primitive on every backend); the dense
layers — where most parameters live — go through the L1 kernel.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .common import ModelDef, ParamSpec, dense_fn, register

IMG = 32
CHANNELS = 3
N_CLASSES = 10

SPEC = ParamSpec.from_pairs(
    [
        ("conv1_w", (3, 3, CHANNELS, 16)),
        ("conv1_b", (16,)),
        ("conv2_w", (3, 3, 16, 32)),
        ("conv2_b", (32,)),
        ("fc1_w", (8 * 8 * 32, 128)),
        ("fc1_b", (128,)),
        ("fc2_w", (128, N_CLASSES)),
        ("fc2_b", (N_CLASSES,)),
    ]
)


def _conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """3×3 SAME conv, NHWC."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def _maxpool2(x: jax.Array) -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def apply(params: Dict[str, jax.Array], x: jax.Array, impl: str) -> jax.Array:
    """Forward pass: x f32[B,32,32,3] → logits f32[B,10]."""
    dense = dense_fn(impl)
    h = jax.nn.relu(_conv(x, params["conv1_w"], params["conv1_b"]))
    h = _maxpool2(h)
    h = jax.nn.relu(_conv(h, params["conv2_w"], params["conv2_b"]))
    h = _maxpool2(h)
    h = h.reshape((h.shape[0], -1))
    h = jax.nn.relu(dense(h, params["fc1_w"], params["fc1_b"]))
    return dense(h, params["fc2_w"], params["fc2_b"])


MODEL = register(
    ModelDef(
        name="cifar_cnn",
        spec=SPEC,
        x_shape=(IMG, IMG, CHANNELS),
        x_dtype="f32",
        y_shape=(),
        train_batch=32,
        eval_batch=64,
        default_impl="pallas",
        apply=apply,
    )
)
