"""L1 Pallas kernels: communication-efficient update transforms (paper §4.3).

Three kernels, each an elementwise/VPU-shaped pass over the flat update
vector, tiled so a block fits comfortably in VMEM:

* ``quantize`` / ``dequantize`` — symmetric per-tensor int8/int16
  quantization. The global ``max|g|`` reduction happens in L2 (a single
  jnp reduce XLA fuses well); the kernel does the round/clip/scale pass.
* ``sparsify`` — top-k magnitude sparsification as a *threshold mask*
  pass. On the paper's GPUs top-k is a radix select; on TPU a
  threshold-apply maps to the VPU, with the threshold computed once by
  ``jax.lax.top_k`` in L2 (DESIGN.md §Hardware-Adaptation).
* ``fedprox_step`` — fused FedProx SGD update
  ``w - lr * (g + mu * (w - w_global))``: one pass instead of three,
  which matters because it runs P-sized work every minibatch.

All run under ``interpret=True`` on CPU PJRT; oracles in ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Flat vectors are processed as (rows, 128) tiles: 128 is the VPU lane
# width; BLOCK_ROWS * 128 * 4B = 256 KiB per operand block in VMEM.
LANES = 128
BLOCK_ROWS = 512


def _pad_2d(v: jax.Array, rows: int):
    """Reshape a flat f32 vector to (R, LANES) padded to BLOCK_ROWS tiles."""
    n = v.shape[0]
    cols = LANES
    total = ((n + cols - 1) // cols) * cols
    r = total // cols
    rp = ((r + rows - 1) // rows) * rows
    v2 = jnp.pad(v, (0, rp * cols - n)).reshape(rp, cols)
    return v2, rp


def _unpad(v2: jax.Array, n: int) -> jax.Array:
    return v2.reshape(-1)[:n]


def _quant_kernel(g_ref, scale_ref, q_ref, *, qmax: float):
    # True division (not mul-by-reciprocal): must round identically to the
    # oracle and to the Rust codec at ULP boundaries.
    q = jnp.clip(jnp.round(g_ref[...] / scale_ref[0]), -qmax, qmax)
    q_ref[...] = q.astype(q_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits",))
def quantize(g: jax.Array, bits: int = 8):
    """Quantize a flat f32 vector to (q, scale). See ref.quantize_ref."""
    assert bits in (8, 16)
    qmax = float(2 ** (bits - 1) - 1)
    dtype = jnp.int8 if bits == 8 else jnp.int16
    absmax = jnp.max(jnp.abs(g))  # L2-side reduction
    scale = jnp.where(absmax > 0, absmax / qmax, jnp.float32(1.0))
    n = g.shape[0]
    g2, rp = _pad_2d(g, BLOCK_ROWS)
    rows = min(BLOCK_ROWS, rp)
    q2 = pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax),
        grid=(rp // rows,),
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, LANES), dtype),
        interpret=True,
    )(g2, scale.reshape(1))
    return _unpad(q2, n), scale


def _dequant_kernel(q_ref, scale_ref, g_ref):
    g_ref[...] = q_ref[...].astype(jnp.float32) * scale_ref[0]


@jax.jit
def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize` for a flat int vector."""
    n = q.shape[0]
    q2, rp = _pad_2d(q.astype(jnp.float32), BLOCK_ROWS)
    q2 = q2.astype(q.dtype)
    rows = min(BLOCK_ROWS, rp)
    g2 = pl.pallas_call(
        _dequant_kernel,
        grid=(rp // rows,),
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, LANES), jnp.float32),
        interpret=True,
    )(q2, scale.reshape(1))
    return _unpad(g2, n)


def _mask_kernel(g_ref, t_ref, o_ref):
    g = g_ref[...]
    o_ref[...] = jnp.where(jnp.abs(g) >= t_ref[0], g, 0.0)


@functools.partial(jax.jit, static_argnames=("k",))
def sparsify(g: jax.Array, k: int) -> jax.Array:
    """Top-k magnitude sparsification of a flat f32 vector.

    Threshold from ``lax.top_k`` (L2), mask applied by the Pallas pass.
    Ties at the threshold are kept (pessimistic), matching ref + Rust.
    """
    n = g.shape[0]
    k = max(1, min(int(k), n))
    t = jax.lax.top_k(jnp.abs(g), k)[0][-1]
    g2, rp = _pad_2d(g, BLOCK_ROWS)
    rows = min(BLOCK_ROWS, rp)
    o2 = pl.pallas_call(
        _mask_kernel,
        grid=(rp // rows,),
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, LANES), jnp.float32),
        interpret=True,
    )(g2, t.reshape(1))
    return _unpad(o2, n)


def _fedprox_kernel(w_ref, g_ref, wg_ref, lr_ref, mu_ref, o_ref):
    w = w_ref[...]
    o_ref[...] = w - lr_ref[0] * (g_ref[...] + mu_ref[0] * (w - wg_ref[...]))


@jax.jit
def fedprox_step(
    w: jax.Array,
    g: jax.Array,
    w_global: jax.Array,
    lr: jax.Array,
    mu: jax.Array,
) -> jax.Array:
    """Fused FedProx SGD step over flat f32 params. See ref.fedprox_step_ref."""
    n = w.shape[0]
    w2, rp = _pad_2d(w, BLOCK_ROWS)
    g2, _ = _pad_2d(g, BLOCK_ROWS)
    wg2, _ = _pad_2d(w_global, BLOCK_ROWS)
    rows = min(BLOCK_ROWS, rp)
    vec = pl.BlockSpec((rows, LANES), lambda i: (i, 0))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    o2 = pl.pallas_call(
        _fedprox_kernel,
        grid=(rp // rows,),
        in_specs=[vec, vec, vec, scalar, scalar],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((rp, LANES), jnp.float32),
        interpret=True,
    )(w2, g2, wg2, jnp.reshape(lr, (1,)), jnp.reshape(mu, (1,)))
    return _unpad(o2, n)
