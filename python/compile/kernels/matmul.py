"""L1 Pallas kernel: MXU-tiled matmul.

The paper's local-training hot-spot is the dense compute of each client
model. On the paper's GPUs that is cuBLAS; the TPU rethink (DESIGN.md
§Hardware-Adaptation) expresses it as a Pallas kernel tiled for the MXU
systolic array: ``BlockSpec`` tiles staged HBM→VMEM, f32 accumulation in
a VMEM scratch accumulator, K-innermost grid so each (i, j) output tile
is revisited across the K dimension (double-buffered by the Mosaic
pipeline on real hardware).

On this CPU image the kernel runs under ``interpret=True`` (the Mosaic
custom-call is TPU-only); correctness is pinned to ``ref.matmul_ref`` by
python/tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128 matches both the MXU systolic array edge and
# the VPU lane count; VMEM footprint per step is
# (bm*bk + bk*bn + bm*bn) * 4B = 192 KiB at 128³ — far below ~16 MiB VMEM,
# leaving room for Mosaic's double buffering (2× input tiles in flight).
TILE_M = 128
TILE_N = 128
TILE_K = 128


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    """One grid step: accumulate x_tile @ w_tile into the VMEM scratch.

    Grid is (M/bm, N/bn, K/bk) with K innermost; the accumulator is
    zeroed on the first K step and flushed to the output tile on the
    last, so ``o_ref`` is written exactly once per (i, j).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = TILE_M,
    bn: int = TILE_N,
    bk: int = TILE_K,
) -> jax.Array:
    """Tiled Pallas matmul ``x @ w`` for 2-D f32 operands.

    Shapes need not be tile-aligned: inputs are zero-padded up to the
    tile lattice and the result is sliced back. Tile sizes are clamped
    to the (padded) problem so small matrices become a single-tile call.
    """
    assert x.ndim == 2 and w.ndim == 2, (x.shape, w.shape)
    assert x.shape[1] == w.shape[0], (x.shape, w.shape)
    m, k = x.shape
    _, n = w.shape

    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    bk = min(bk, _ceil_to(k, 8))

    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))

    n_k = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pl_scratch(bm, bn)],
        interpret=True,  # CPU PJRT; Mosaic lowering is TPU-only
    )(xp, wp)
    return out[:m, :n]


def pl_scratch(bm: int, bn: int):
    """VMEM f32 accumulator scratch shape for the kernel."""
    from jax.experimental.pallas import tpu as pltpu  # local: TPU-only names

    try:
        return pltpu.VMEM((bm, bn), jnp.float32)
    except Exception:  # pragma: no cover - fallback for older pallas
        return pl.VMEM((bm, bn), jnp.float32)


@jax.custom_vjp
def matmul_ad(x: jax.Array, w: jax.Array) -> jax.Array:
    """Differentiable wrapper: pallas_call has no autodiff rule, so the
    VJP is spelled explicitly — and the backward pass is itself two
    Pallas matmuls (``dx = dy @ wᵀ``, ``dw = xᵀ @ dy``), keeping the
    whole fwd+bwd on the MXU path."""
    return matmul(x, w)


def _matmul_fwd(x, w):
    return matmul(x, w), (x, w)


def _matmul_bwd(res, dy):
    x, w = res
    return matmul(dy, w.T), matmul(x.T, dy)


matmul_ad.defvjp(_matmul_fwd, _matmul_bwd)


def dense(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    """Dense layer over the last axis using the Pallas matmul.

    Collapses leading axes to a single M dimension (the kernel is 2-D),
    applies ``x @ w (+ b)`` and restores the leading shape.
    """
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    y = matmul_ad(x2, w)
    if b is not None:
        y = y + b
    return y.reshape(lead + (w.shape[1],))
