"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every kernel in this package has a reference implementation here; pytest
(python/tests/) asserts allclose between kernel and oracle across a
hypothesis-driven sweep of shapes and dtypes. These oracles are also used
directly by the L2 model code when ``kernel_impl="jnp"`` is selected at
AOT time (see aot.py), which keeps the lowered HLO small for the large
end-to-end model.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain f32 matmul oracle: ``x @ w`` with f32 accumulation."""
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))


def quantize_ref(g: jax.Array, bits: int = 8):
    """Symmetric per-tensor affine quantization oracle.

    Returns ``(q, scale)`` where ``q`` is int8/int16 and
    ``g ≈ q * scale``. ``scale = max|g| / qmax`` (all-zero tensors map to
    scale 1 to avoid div-by-zero, matching the Rust codec).
    """
    assert bits in (8, 16)
    qmax = float(2 ** (bits - 1) - 1)
    absmax = jnp.max(jnp.abs(g))
    scale = jnp.where(absmax > 0, absmax / qmax, jnp.float32(1.0))
    q = jnp.clip(jnp.round(g / scale), -qmax, qmax)
    dtype = jnp.int8 if bits == 8 else jnp.int16
    return q.astype(dtype), scale.astype(jnp.float32)


def dequantize_ref(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_ref`."""
    return q.astype(jnp.float32) * scale


def topk_threshold_ref(g: jax.Array, k: int) -> jax.Array:
    """Magnitude threshold such that the top-k survive ``|g| >= t``.

    Ties are kept pessimistically (may keep more than k when magnitudes
    are equal), matching the two-pass kernel and the Rust codec.
    """
    flat = jnp.abs(g.reshape(-1))
    k = max(1, min(int(k), flat.shape[0]))
    top = jax.lax.top_k(flat, k)[0]
    return top[-1]


def sparsify_ref(g: jax.Array, k: int) -> jax.Array:
    """Top-k magnitude sparsification oracle: zero all but top-k entries."""
    t = topk_threshold_ref(g, k)
    return jnp.where(jnp.abs(g) >= t, g, jnp.zeros_like(g))


def fedprox_step_ref(
    w: jax.Array,
    g: jax.Array,
    w_global: jax.Array,
    lr: jax.Array,
    mu: jax.Array,
) -> jax.Array:
    """Fused FedProx SGD step oracle.

    ``w' = w - lr * (g + mu * (w - w_global))`` — the proximal term of
    Li et al. (FedProx) folded into the parameter update so that the
    whole step is one elementwise pass (paper §4.4). ``mu = 0`` recovers
    plain FedAvg local SGD.
    """
    return w - lr * (g + mu * (w - w_global))


def dropout_mask_ref(key: jax.Array, shape, rate: float) -> jax.Array:
    """Federated-dropout mask oracle: 1 keeps a unit, 0 drops it."""
    return (jax.random.uniform(key, shape) >= rate).astype(jnp.float32)
