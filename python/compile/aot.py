"""AOT exporter: lower every model's init/train/eval step to HLO *text*
and write a manifest the Rust runtime reads to know shapes and layouts.

HLO text — NOT ``lowered.compile()`` / ``.serialize()`` — is the
interchange format: jax ≥ 0.5 emits HloModuleProtos with 64-bit
instruction ids that the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):  ``python -m compile.aot --out ../artifacts``

Per model ``m`` this writes::

    {m}_init.hlo.txt    (seed u32[]) -> (params f32[P],)
    {m}_train.hlo.txt   (params, global, x, y, lr, mu) -> (params', loss, correct)
    {m}_eval.hlo.txt    (params, x, y) -> (loss_sum, correct)

plus ``manifest.json`` with parameter counts, batch sizes, input
shapes/dtypes and the kernel impl each artifact was lowered with.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
from jax._src.lib import xla_client as xc

from . import model as steps
from .models import REGISTRY
from .models.common import ModelDef

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    Rust side always unwraps a tuple — see load_hlo.rs pattern)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_step(mdef: ModelDef, kind: str, impl: str) -> str:
    if kind == "init":
        fn = steps.make_init(mdef)
    elif kind == "train":
        fn = steps.make_train_step(mdef, impl)
    else:
        fn = steps.make_eval_step(mdef, impl)
    return to_hlo_text(jax.jit(fn).lower(*steps.example_args(mdef, kind)))


def model_manifest(mdef: ModelDef, impl: str) -> dict:
    return {
        "n_params": mdef.n_params,
        "kernel_impl": impl,
        "train_batch": mdef.train_batch,
        "eval_batch": mdef.eval_batch,
        "x_shape": list(mdef.x_shape),
        "x_dtype": mdef.x_dtype,
        "y_shape": list(mdef.y_shape),
        "samples_per_example": mdef.samples_per_example,
        "param_names": list(mdef.spec.names),
        "param_shapes": [list(s) for s in mdef.spec.shapes],
    }


def export_all(out_dir: str, models: list[str], impl_override: str | None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"version": MANIFEST_VERSION, "models": {}}
    for name in models:
        mdef = REGISTRY[name]
        impl = impl_override or mdef.default_impl
        for kind in ("init", "train", "eval"):
            t0 = time.time()
            text = lower_step(mdef, kind, impl)
            path = os.path.join(out_dir, f"{name}_{kind}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            print(
                f"  {name}_{kind}: {len(text) / 1e6:.2f} MB HLO "
                f"({time.time() - t0:.1f}s, impl={impl})"
            )
        manifest["models"][name] = model_manifest(mdef, impl)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--models",
        default=",".join(sorted(REGISTRY)),
        help="comma-separated subset of: " + ",".join(sorted(REGISTRY)),
    )
    ap.add_argument(
        "--impl",
        choices=["pallas", "jnp"],
        default=None,
        help="override each model's default kernel impl",
    )
    args = ap.parse_args()
    names = [n for n in args.models.split(",") if n]
    print(f"exporting {names} -> {args.out}")
    export_all(args.out, names, args.impl)
    print("done")


if __name__ == "__main__":
    main()
