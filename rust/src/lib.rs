//! # FedHPC — federated learning for heterogeneous HPC + cloud
//!
//! A from-scratch reproduction of *"Federated Learning Framework for
//! Scalable AI in Heterogeneous HPC and Cloud Environments"* (CS.DC
//! 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: central
//!   orchestrator, adaptive client selection, straggler mitigation,
//!   communication-efficient updates, scheduler adapters and
//!   fault-tolerant aggregation, plus every substrate they need
//!   (cluster simulation, transports, codecs, datasets, metrics).
//! * **L2/L1 (python/, build-time only)** — JAX models and Pallas
//!   kernels AOT-lowered to HLO text in `artifacts/`, executed here
//!   through the PJRT CPU client ([`runtime`]). Python is never on the
//!   training path.
//!
//! Start at [`orchestrator::Orchestrator`] (server side),
//! [`client::Worker`] (client side) and [`experiments`] (paper
//! table/figure reproductions). `examples/quickstart.rs` is the
//! five-minute tour.

pub mod client;
pub mod cluster;
pub mod compress;
pub mod config;
pub mod data;
pub mod faults;
pub mod metrics;
pub mod network;
pub mod orchestrator;
pub mod runtime;
pub mod scheduler;
pub mod secure;
pub mod sim;
pub mod telemetry;
pub mod util;

pub mod benchkit;
pub mod experiments;
pub mod testkit;

/// Crate version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
