//! Dependency-free utility substrates.
//!
//! The build image vendors only the `xla` crate closure, so the usual
//! ecosystem crates (rand, serde, clap, env_logger) are implemented
//! in-tree, each scoped to exactly what the framework needs.

pub mod argparse;
pub mod bytes;
pub mod json;
pub mod logging;
pub mod parallel;
pub mod rng;
pub mod scratch;

/// Lock a mutex, recovering the guard even if a previous holder
/// panicked. Every protected structure in this codebase is valid after
/// any partial mutation (queues, counters, logs), so continuing with
/// the poisoned state is strictly better than cascading the panic into
/// wire-facing threads.
pub fn lock_unpoisoned<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// FNV-1a over the exact bit patterns of an f32 slice — the model
/// fingerprint the deterministic-replay tests pin ("same seed ⇒ same
/// final model hash"). Bit-level: distinguishes `-0.0` from `0.0` and
/// every NaN payload, so any divergence in the aggregation path shows.
pub fn hash_f32_bits(xs: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for x in xs {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Format a byte count as a human-readable string (e.g. "1.25 MB").
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a duration in milliseconds with adaptive units.
pub fn human_ms(ms: f64) -> String {
    if ms < 1.0 {
        format!("{:.0} µs", ms * 1000.0)
    } else if ms < 1000.0 {
        format!("{ms:.1} ms")
    } else if ms < 60_000.0 {
        format!("{:.2} s", ms / 1000.0)
    } else {
        format!("{:.1} min", ms / 60_000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_is_bit_sensitive_and_stable() {
        let a = [1.0f32, -2.5, 0.0];
        assert_eq!(hash_f32_bits(&a), hash_f32_bits(&a));
        assert_ne!(hash_f32_bits(&a), hash_f32_bits(&[1.0, -2.5, -0.0]));
        assert_ne!(hash_f32_bits(&a), hash_f32_bits(&[1.0, -2.5]));
        assert_ne!(hash_f32_bits(&[]), hash_f32_bits(&[0.0]));
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KB");
        assert_eq!(human_bytes(5 * 1024 * 1024), "5.00 MB");
    }

    #[test]
    fn human_ms_units() {
        assert_eq!(human_ms(0.5), "500 µs");
        assert_eq!(human_ms(12.34), "12.3 ms");
        assert_eq!(human_ms(2500.0), "2.50 s");
        assert_eq!(human_ms(120_000.0), "2.0 min");
    }
}
