//! Scoped data-parallel helpers (no rayon on this image).
//!
//! `par_chunks_mut` splits a mutable slice across `available_parallelism`
//! threads with `std::thread::scope`; small inputs run inline so the
//! helpers are safe to use unconditionally on hot paths.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Threads to use for `n` elements with a minimum per-thread chunk.
fn n_threads(n: usize, min_chunk: usize) -> usize {
    let hw = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    (n / min_chunk.max(1)).clamp(1, hw)
}

/// Apply `f(offset, chunk)` over disjoint chunks of `data` in parallel.
/// `f` must be pure per-element (no cross-chunk dependencies).
pub fn par_chunks_mut<T, F>(data: &mut [T], min_chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    let threads = n_threads(n, min_chunk);
    if threads <= 1 {
        f(0, data);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (i, part) in data.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || f(i * chunk, part));
        }
    });
}

/// Parallel fold: apply `map(offset, chunk) -> A` over disjoint chunks
/// of a shared slice, then `reduce` the per-chunk results (order of
/// reduction is by chunk index, so deterministic).
pub fn par_fold<T, A, M, R>(data: &[T], min_chunk: usize, map: M, reduce: R) -> Option<A>
where
    T: Sync,
    A: Send,
    M: Fn(usize, &[T]) -> A + Sync,
    R: Fn(A, A) -> A,
{
    let n = data.len();
    if n == 0 {
        return None;
    }
    let threads = n_threads(n, min_chunk);
    if threads <= 1 {
        return Some(map(0, data));
    }
    let chunk = n.div_ceil(threads);
    let results: Vec<(usize, A)> = std::thread::scope(|s| {
        let handles: Vec<_> = data
            .chunks(chunk)
            .enumerate()
            .map(|(i, part)| {
                let map = &map;
                s.spawn(move || (i, map(i * chunk, part)))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut sorted = results;
    sorted.sort_by_key(|(i, _)| *i);
    sorted.into_iter().map(|(_, a)| a).reduce(reduce)
}

/// Global counter used by tests to verify multi-threading engaged.
#[doc(hidden)]
pub static PAR_INVOCATIONS: AtomicUsize = AtomicUsize::new(0);

#[doc(hidden)]
pub fn note_invocation() {
    PAR_INVOCATIONS.fetch_add(1, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_chunks_mut_covers_all_elements_once() {
        let mut v = vec![0u32; 100_000];
        par_chunks_mut(&mut v, 1024, |offset, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = (offset + j) as u32;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u32);
        }
    }

    #[test]
    fn small_input_runs_inline() {
        let mut v = vec![1u8; 10];
        par_chunks_mut(&mut v, 1024, |_, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 2));
    }

    #[test]
    fn par_fold_sum_matches_serial() {
        let v: Vec<f64> = (0..250_000).map(|i| i as f64).collect();
        let got = par_fold(&v, 4096, |_, c| c.iter().sum::<f64>(), |a, b| a + b).unwrap();
        let want: f64 = v.iter().sum();
        assert!((got - want).abs() < 1e-6 * want);
    }

    #[test]
    fn par_fold_max_deterministic() {
        let v: Vec<f32> = (0..100_000).map(|i| ((i * 37) % 1000) as f32).collect();
        let a = par_fold(&v, 1000, |_, c| c.iter().cloned().fold(0f32, f32::max), f32::max);
        let b = par_fold(&v, 1000, |_, c| c.iter().cloned().fold(0f32, f32::max), f32::max);
        assert_eq!(a, b);
        assert_eq!(a, Some(999.0));
    }

    #[test]
    fn par_fold_empty() {
        let v: Vec<f32> = vec![];
        assert!(par_fold(&v, 10, |_, c| c.len(), |a, b| a + b).is_none());
    }
}
