//! Scoped data-parallel helpers and the persistent ingest worker pool
//! (no rayon on this image).
//!
//! `par_chunks_mut` splits a mutable slice across `available_parallelism`
//! threads with `std::thread::scope`; small inputs run inline so the
//! helpers are safe to use unconditionally on hot paths.
//!
//! `ShardPool` is the opposite trade: N long-lived threads with
//! per-shard bounded FIFO queues, parked when idle, so the server's
//! sharded ingest pays zero thread spawns per fold. A shard is owned
//! by exactly one worker (`shard % n_workers`), so jobs for a shard
//! run serially in submission order — the property the sharded
//! aggregator's bit-identity argument rests on.

use crate::util::lock_unpoisoned;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Chunk size (elements) for parallel folds over the dense accumulator.
/// Shared by `compress::DecodedView::fold_scaled_into` and the dense
/// fold/normalize loops in `orchestrator::aggregate` — the paths must
/// chunk identically so their floating-point addition order matches.
pub const FOLD_CHUNK: usize = 256 * 1024;

/// Threads to use for `n` elements with a minimum per-thread chunk.
fn n_threads(n: usize, min_chunk: usize) -> usize {
    let hw = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    (n / min_chunk.max(1)).clamp(1, hw)
}

/// Apply `f(offset, chunk)` over disjoint chunks of `data` in parallel.
/// `f` must be pure per-element (no cross-chunk dependencies).
pub fn par_chunks_mut<T, F>(data: &mut [T], min_chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    let threads = n_threads(n, min_chunk);
    if threads <= 1 {
        f(0, data);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (i, part) in data.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || f(i * chunk, part));
        }
    });
}

/// Parallel fold: apply `map(offset, chunk) -> A` over disjoint chunks
/// of a shared slice, then `reduce` the per-chunk results (order of
/// reduction is by chunk index, so deterministic).
pub fn par_fold<T, A, M, R>(data: &[T], min_chunk: usize, map: M, reduce: R) -> Option<A>
where
    T: Sync,
    A: Send,
    M: Fn(usize, &[T]) -> A + Sync,
    R: Fn(A, A) -> A,
{
    let n = data.len();
    if n == 0 {
        return None;
    }
    let threads = n_threads(n, min_chunk);
    if threads <= 1 {
        return Some(map(0, data));
    }
    let chunk = n.div_ceil(threads);
    let results: Vec<(usize, A)> = std::thread::scope(|s| {
        let handles: Vec<_> = data
            .chunks(chunk)
            .enumerate()
            .map(|(i, part)| {
                let map = &map;
                s.spawn(move || (i, map(i * chunk, part)))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(v) => v,
                // a panicking map closure must surface on the caller,
                // not abort the scope with a generic join error
                Err(payload) => std::panic::resume_unwind(payload),
            })
            .collect()
    });
    let mut sorted = results;
    sorted.sort_by_key(|(i, _)| *i);
    sorted.into_iter().map(|(_, a)| a).reduce(reduce)
}

/// Global counter used by tests to verify multi-threading engaged.
#[doc(hidden)]
pub static PAR_INVOCATIONS: AtomicUsize = AtomicUsize::new(0);

#[doc(hidden)]
pub fn note_invocation() {
    PAR_INVOCATIONS.fetch_add(1, Ordering::Relaxed);
}

/// Resolve the `ingest_threads` knob: 0 = auto (`available_parallelism`),
/// anything else is taken literally.
pub fn resolve_ingest_threads(requested: u32) -> usize {
    if requested == 0 {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
    } else {
        requested as usize
    }
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Bound on each shard queue: a producer submitting to a full shard
/// blocks (counted as an ingest stall) until the owning worker drains.
const QUEUE_CAP: usize = 64;

struct ShardQueue {
    q: Mutex<VecDeque<Job>>,
    not_full: Condvar,
}

struct PoolInner {
    queues: Vec<ShardQueue>,
    n_workers: usize,
    /// Outstanding (submitted, not yet finished) jobs + idle condvar.
    pending: Mutex<usize>,
    idle: Condvar,
    /// First panic payload from a worker job; re-thrown at `wait_idle`.
    panicked: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    shutdown: AtomicBool,
    spawned: AtomicUsize,
    jobs: AtomicUsize,
    stalls: AtomicUsize,
    fold_ns: AtomicU64,
}

impl PoolInner {
    fn finish_job(&self, outcome: std::thread::Result<()>, started: Instant) {
        self.fold_ns
            .fetch_add(started.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.jobs.fetch_add(1, Ordering::Relaxed);
        if let Err(payload) = outcome {
            let mut slot = lock_unpoisoned(&self.panicked);
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        let mut p = lock_unpoisoned(&self.pending);
        *p = p.saturating_sub(1);
        if *p == 0 {
            self.idle.notify_all();
        }
    }
}

fn worker_loop(inner: &Arc<PoolInner>, worker: usize) {
    inner.spawned.fetch_add(1, Ordering::Relaxed);
    let stride = inner.n_workers.max(1);
    loop {
        let mut ran = false;
        let mut s = worker;
        // sweep owned shards in index order; one job per shard per pass
        // so no shard starves its siblings
        while let Some(slot) = inner.queues.get(s) {
            let job = {
                let mut q = lock_unpoisoned(&slot.q);
                let job = q.pop_front();
                if job.is_some() {
                    slot.not_full.notify_one();
                }
                job
            };
            if let Some(job) = job {
                ran = true;
                let t0 = Instant::now();
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                inner.finish_job(outcome, t0);
            }
            s += stride;
        }
        if !ran {
            if inner.shutdown.load(Ordering::Acquire) {
                return;
            }
            // the producer pushes before unparking, so a token left by a
            // racing submit makes this park return immediately
            std::thread::park();
        }
    }
}

/// Persistent shard-worker pool for the server ingest hot path.
///
/// `n_shards` FIFO queues are statically owned by `n_workers` threads
/// (shard `s` → worker `s % n_workers`). Threads spawn once at
/// construction and park when idle; `submit` never spawns. Per shard,
/// jobs run serially in submission order — concurrency exists only
/// *across* shards, which is what keeps the sharded fold bit-identical
/// to the serial reference for a fixed arrival order.
pub struct ShardPool {
    inner: Arc<PoolInner>,
    /// Worker thread handles for unparking on submit/shutdown.
    workers: Vec<std::thread::Thread>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Spawn failed: run jobs inline on the caller instead of hanging.
    inline: bool,
}

impl ShardPool {
    /// Spawn `n_workers` threads serving `n_shards` queues. Worker count
    /// is clamped to the shard count (extra workers would own nothing).
    pub fn new(n_workers: usize, n_shards: usize) -> Self {
        let n_shards = n_shards.max(1);
        let n_workers = n_workers.clamp(1, n_shards);
        let inner = Arc::new(PoolInner {
            queues: (0..n_shards)
                .map(|_| ShardQueue {
                    q: Mutex::new(VecDeque::new()),
                    not_full: Condvar::new(),
                })
                .collect(),
            n_workers,
            pending: Mutex::new(0),
            idle: Condvar::new(),
            panicked: Mutex::new(None),
            shutdown: AtomicBool::new(false),
            spawned: AtomicUsize::new(0),
            jobs: AtomicUsize::new(0),
            stalls: AtomicUsize::new(0),
            fold_ns: AtomicU64::new(0),
        });
        let mut handles = Vec::with_capacity(n_workers);
        let mut workers = Vec::with_capacity(n_workers);
        let mut ok = true;
        for w in 0..n_workers {
            let inner_w = inner.clone();
            match std::thread::Builder::new()
                .name(format!("fedhpc-ingest-{w}"))
                .spawn(move || worker_loop(&inner_w, w))
            {
                Ok(h) => {
                    workers.push(h.thread().clone());
                    handles.push(h);
                }
                Err(_) => {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            // partial pools would strand shards owned by unspawned
            // workers; fall back to inline execution entirely
            inner.shutdown.store(true, Ordering::Release);
            for t in &workers {
                t.unpark();
            }
            for h in handles.drain(..) {
                let _ = h.join();
            }
            workers.clear();
        }
        Self {
            inner,
            workers,
            handles,
            inline: !ok,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.inner.queues.len()
    }

    pub fn n_workers(&self) -> usize {
        if self.inline {
            0
        } else {
            self.inner.n_workers
        }
    }

    /// Enqueue `f` on `shard`'s FIFO queue, blocking if it is full.
    /// Jobs submitted to the same shard run serially in this order.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, shard: usize, f: F) {
        let n = self.inner.queues.len();
        let Some(slot) = self.inner.queues.get(shard % n.max(1)) else {
            f();
            return;
        };
        if self.inline {
            let t0 = Instant::now();
            {
                let mut p = lock_unpoisoned(&self.inner.pending);
                *p += 1;
            }
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
            self.inner.finish_job(outcome, t0);
            return;
        }
        {
            let mut p = lock_unpoisoned(&self.inner.pending);
            *p += 1;
        }
        let mut q = lock_unpoisoned(&slot.q);
        while q.len() >= QUEUE_CAP {
            self.inner.stalls.fetch_add(1, Ordering::Relaxed);
            q = match slot.not_full.wait(q) {
                Ok(g) => g,
                Err(e) => e.into_inner(),
            };
        }
        q.push_back(Box::new(f));
        drop(q);
        if let Some(t) = self.workers.get((shard % n.max(1)) % self.inner.n_workers.max(1)) {
            t.unpark();
        }
    }

    /// Block until every submitted job has finished. Re-throws the first
    /// worker-job panic on the caller, mirroring `par_fold` semantics.
    pub fn wait_idle(&self) {
        {
            let mut p = lock_unpoisoned(&self.inner.pending);
            while *p > 0 {
                p = match self.inner.idle.wait(p) {
                    Ok(g) => g,
                    Err(e) => e.into_inner(),
                };
            }
        }
        if let Some(payload) = lock_unpoisoned(&self.inner.panicked).take() {
            std::panic::resume_unwind(payload);
        }
    }

    /// Threads spawned over the pool's lifetime — constant after `new`,
    /// which is exactly what the zero-spawn-per-fold test pins.
    pub fn threads_spawned(&self) -> usize {
        self.inner.spawned.load(Ordering::Relaxed)
    }

    /// Jobs executed to completion (including panicked ones).
    pub fn jobs_executed(&self) -> usize {
        self.inner.jobs.load(Ordering::Relaxed)
    }

    /// Times a producer blocked on a full shard queue.
    pub fn stall_count(&self) -> usize {
        self.inner.stalls.load(Ordering::Relaxed)
    }

    /// Cumulative nanoseconds workers spent inside fold jobs.
    pub fn fold_ns_total(&self) -> u64 {
        self.inner.fold_ns.load(Ordering::Relaxed)
    }

    /// Jobs currently queued across all shards (point-in-time).
    pub fn queue_depth(&self) -> usize {
        self.inner
            .queues
            .iter()
            .map(|s| lock_unpoisoned(&s.q).len())
            .sum()
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::Release);
        for t in &self.workers {
            t.unpark();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_chunks_mut_covers_all_elements_once() {
        let mut v = vec![0u32; 100_000];
        par_chunks_mut(&mut v, 1024, |offset, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = (offset + j) as u32;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u32);
        }
    }

    #[test]
    fn small_input_runs_inline() {
        let mut v = vec![1u8; 10];
        par_chunks_mut(&mut v, 1024, |_, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 2));
    }

    #[test]
    fn par_fold_sum_matches_serial() {
        let v: Vec<f64> = (0..250_000).map(|i| i as f64).collect();
        let got = par_fold(&v, 4096, |_, c| c.iter().sum::<f64>(), |a, b| a + b).unwrap();
        let want: f64 = v.iter().sum();
        assert!((got - want).abs() < 1e-6 * want);
    }

    #[test]
    fn par_fold_max_deterministic() {
        let v: Vec<f32> = (0..100_000).map(|i| ((i * 37) % 1000) as f32).collect();
        let a = par_fold(&v, 1000, |_, c| c.iter().cloned().fold(0f32, f32::max), f32::max);
        let b = par_fold(&v, 1000, |_, c| c.iter().cloned().fold(0f32, f32::max), f32::max);
        assert_eq!(a, b);
        assert_eq!(a, Some(999.0));
    }

    #[test]
    fn par_fold_empty() {
        let v: Vec<f32> = vec![];
        assert!(par_fold(&v, 10, |_, c| c.len(), |a, b| a + b).is_none());
    }

    #[test]
    fn par_fold_propagates_worker_panic() {
        let v: Vec<u32> = (0..200_000).collect();
        let caught = std::panic::catch_unwind(|| {
            par_fold(
                &v,
                1024,
                |off, _| {
                    if off > 0 {
                        panic!("boom at {off}");
                    }
                    0usize
                },
                |a, b| a + b,
            )
        });
        let payload = caught.expect_err("panic must propagate to the caller");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("boom at"), "original payload lost: {msg:?}");
    }

    #[test]
    fn shard_pool_runs_every_job_exactly_once() {
        let pool = ShardPool::new(4, 8);
        let hits = Arc::new(Mutex::new(vec![0u32; 1000]));
        for i in 0..1000usize {
            let hits = hits.clone();
            pool.submit(i % 8, move || {
                let mut h = lock_unpoisoned(&hits);
                h[i] += 1;
            });
        }
        pool.wait_idle();
        assert!(lock_unpoisoned(&hits).iter().all(|&c| c == 1));
        assert_eq!(pool.jobs_executed(), 1000);
        assert_eq!(pool.queue_depth(), 0);
    }

    #[test]
    fn shard_pool_preserves_per_shard_fifo_order() {
        let pool = ShardPool::new(3, 7);
        let seen: Arc<Vec<Mutex<Vec<usize>>>> =
            Arc::new((0..7).map(|_| Mutex::new(Vec::new())).collect());
        for seq in 0..700usize {
            let shard = seq % 7;
            let seen = seen.clone();
            pool.submit(shard, move || {
                lock_unpoisoned(&seen[shard]).push(seq);
            });
        }
        pool.wait_idle();
        for shard in 0..7 {
            let order = lock_unpoisoned(&seen[shard]);
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(*order, sorted, "shard {shard} ran out of submission order");
            assert_eq!(order.len(), 100);
        }
    }

    #[test]
    fn shard_pool_spawns_threads_once_across_many_folds() {
        // the acceptance criterion: zero per-fold spawns — the pool's
        // thread count is fixed at construction and reused forever
        let pool = ShardPool::new(2, 4);
        let spawned_at_birth = pool.threads_spawned();
        assert_eq!(spawned_at_birth, 2);
        for _round in 0..50 {
            for shard in 0..4 {
                pool.submit(shard, || {
                    std::hint::black_box(1 + 1);
                });
            }
            pool.wait_idle();
        }
        assert_eq!(pool.threads_spawned(), spawned_at_birth);
        assert_eq!(pool.jobs_executed(), 200);
    }

    #[test]
    fn shard_pool_rethrows_job_panic_at_wait_idle() {
        let pool = ShardPool::new(2, 2);
        pool.submit(0, || panic!("shard job exploded"));
        pool.submit(1, || {});
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.wait_idle()));
        let payload = caught.expect_err("wait_idle must re-throw the job panic");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or_default();
        assert!(msg.contains("exploded"), "payload lost: {msg:?}");
        // the pool stays usable after a panic
        pool.submit(0, || {});
        pool.wait_idle();
    }

    #[test]
    fn shard_pool_backpressure_counts_stalls() {
        use std::sync::mpsc;
        let pool = ShardPool::new(1, 1);
        let (tx, rx) = mpsc::channel::<()>();
        let rx = Arc::new(Mutex::new(rx));
        // first job blocks the single worker until released
        let rx0 = rx.clone();
        pool.submit(0, move || {
            let _ = lock_unpoisoned(&rx0).recv();
        });
        // overfill the queue from another thread, then release
        let n_extra = QUEUE_CAP + 8;
        std::thread::scope(|s| {
            s.spawn(|| {
                for _ in 0..n_extra {
                    pool.submit(0, || {});
                }
            });
            // give the producer time to hit the bound, then unblock
            std::thread::sleep(std::time::Duration::from_millis(50));
            tx.send(()).unwrap();
        });
        pool.wait_idle();
        assert_eq!(pool.jobs_executed(), n_extra + 1);
        assert!(pool.stall_count() > 0, "full queue never stalled producer");
    }
}
