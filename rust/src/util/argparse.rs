//! Tiny CLI argument parser (no clap on this image).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed getters, defaults and a generated usage block.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct Spec {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
    default: Option<String>,
}

/// Declarative arg set: declare flags/options, then `parse`.
#[derive(Debug, Default)]
pub struct Args {
    specs: Vec<Spec>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    pub fn new() -> Self {
        Self::default()
    }

    /// Declare `--name <value>` with an optional default.
    pub fn opt(mut self, name: &'static str, default: Option<&str>, help: &'static str) -> Self {
        self.specs.push(Spec {
            name,
            help,
            takes_value: true,
            default: default.map(|s| s.to_string()),
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(Spec {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    pub fn usage(&self, cmd: &str) -> String {
        let mut s = format!("usage: {cmd} [options]\n\noptions:\n");
        for spec in &self.specs {
            let left = if spec.takes_value {
                format!("--{} <v>", spec.name)
            } else {
                format!("--{}", spec.name)
            };
            let def = spec
                .default
                .as_deref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {left:<26} {}{def}\n", spec.help));
        }
        s
    }

    /// Parse a raw token list (no program name).
    pub fn parse(mut self, tokens: &[String]) -> Result<Parsed> {
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(rest) = t.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| anyhow!("unknown option --{name}"))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            tokens
                                .get(i)
                                .cloned()
                                .ok_or_else(|| anyhow!("--{name} needs a value"))?
                        }
                    };
                    self.values.insert(name, v);
                } else {
                    if inline.is_some() {
                        bail!("flag --{name} does not take a value");
                    }
                    self.flags.push(name);
                }
            } else {
                self.positional.push(t.clone());
            }
            i += 1;
        }
        // fill defaults
        for spec in &self.specs {
            if spec.takes_value && !self.values.contains_key(spec.name) {
                if let Some(d) = &spec.default {
                    self.values.insert(spec.name.to_string(), d.clone());
                }
            }
        }
        Ok(Parsed {
            values: self.values,
            flags: self.flags,
            positional: self.positional,
        })
    }
}

/// Result of parsing; typed getters.
#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn req(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn usize(&self, name: &str) -> Result<usize> {
        self.req(name)?
            .parse()
            .map_err(|e| anyhow!("--{name}: {e}"))
    }

    pub fn u64(&self, name: &str) -> Result<u64> {
        self.req(name)?
            .parse()
            .map_err(|e| anyhow!("--{name}: {e}"))
    }

    pub fn f64(&self, name: &str) -> Result<f64> {
        self.req(name)?
            .parse()
            .map_err(|e| anyhow!("--{name}: {e}"))
    }

    pub fn f32(&self, name: &str) -> Result<f32> {
        self.req(name)?
            .parse()
            .map_err(|e| anyhow!("--{name}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    fn spec() -> Args {
        Args::new()
            .opt("rounds", Some("100"), "number of rounds")
            .opt("model", None, "model name")
            .flag("verbose", "chatty output")
    }

    #[test]
    fn parses_values_flags_positionals() {
        let p = spec()
            .parse(&toks("train --rounds 5 --model=charlm --verbose"))
            .unwrap();
        assert_eq!(p.usize("rounds").unwrap(), 5);
        assert_eq!(p.get("model"), Some("charlm"));
        assert!(p.has("verbose"));
        assert_eq!(p.positional(), &["train".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let p = spec().parse(&toks("")).unwrap();
        assert_eq!(p.usize("rounds").unwrap(), 100);
        assert_eq!(p.get("model"), None);
        assert!(!p.has("verbose"));
    }

    #[test]
    fn unknown_and_missing_value_error() {
        assert!(spec().parse(&toks("--bogus")).is_err());
        assert!(spec().parse(&toks("--model")).is_err());
        assert!(spec().parse(&toks("--verbose=yes")).is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = spec().usage("fedhpc train");
        assert!(u.contains("--rounds"));
        assert!(u.contains("default: 100"));
    }
}
