//! Minimal `log` facade backend (no env_logger on this image).
//!
//! Timestamped, leveled, thread-named output to stderr. Level comes
//! from `FEDHPC_LOG` (error|warn|info|debug|trace), default `info`.

use log::{Level, LevelFilter, Log, Metadata, Record};
use std::sync::Once;
use std::time::{SystemTime, UNIX_EPOCH};

struct StderrLogger;

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let now = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .unwrap_or_default();
        let secs = now.as_secs();
        let millis = now.subsec_millis();
        let tag = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let thread = std::thread::current();
        let name = thread.name().unwrap_or("?");
        eprintln!(
            "[{secs}.{millis:03} {tag} {name} {}] {}",
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

static INIT: Once = Once::new();
static LOGGER: StderrLogger = StderrLogger;

/// Install the logger (idempotent). Returns the active level.
pub fn init() -> LevelFilter {
    let level = match std::env::var("FEDHPC_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        Ok("off") => LevelFilter::Off,
        _ => LevelFilter::Info,
    };
    INIT.call_once(|| {
        let _ = log::set_logger(&LOGGER);
        log::set_max_level(level);
    });
    level
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        let a = super::init();
        let b = super::init();
        assert_eq!(a, b);
        log::info!("logging smoke test line");
    }
}
