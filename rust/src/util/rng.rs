//! Deterministic pseudo-random number generation (no `rand` crate).
//!
//! `Rng` is xoshiro256** seeded via SplitMix64 — the standard pairing:
//! fast, well-distributed, and fully reproducible from a `u64` seed.
//! Every stochastic component in the framework (partitioners, client
//! selection exploration, fault injection, synthetic data) takes an
//! explicit seed so whole experiments replay bit-identically.

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Derive an independent child stream (for per-client/per-round rngs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Uses Lemire's method (no modulo bias).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + (self.below((hi - lo + 1) as usize) as u64)
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// N(mean, std).
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with the given rate (mean = 1/rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (used for Dirichlet draws).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0);
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            return g * self.f64().powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha, ..., alpha) over `n` categories.
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..n).map(|_| self.gamma(alpha)).collect();
        let s: f64 = v.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / n as f64; n];
        }
        for x in &mut v {
            *x /= s;
        }
        v
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Weighted index sample (linear scan; weights need not normalize).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() needs a positive-total weight vector");
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.below(7);
            assert!(x < 7);
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            let expected = n / 10;
            assert!(
                (c as i64 - expected as i64).abs() < (expected as i64) / 5,
                "bucket {c} far from {expected}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(3);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let v = r.dirichlet(alpha, 8);
            assert_eq!(v.len(), 8);
            let s: f64 = v.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_low_alpha_is_skewed() {
        let mut r = Rng::new(4);
        // alpha=0.05 should concentrate mass on few categories
        let v = r.dirichlet(0.05, 10);
        let max = v.iter().cloned().fold(0.0, f64::max);
        assert!(max > 0.5, "expected skew, got max {max}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 30);
        assert!(t.iter().all(|&i| i < 100));
    }

    #[test]
    fn sample_indices_k_ge_n() {
        let mut r = Rng::new(6);
        let s = r.sample_indices(5, 10);
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn weighted_prefers_heavy_buckets() {
        let mut r = Rng::new(8);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(9);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
