//! Little-endian wire encoding helpers for the message codec.
//!
//! A tiny, allocation-conscious reader/writer pair. The framework's
//! protocol (network::message) encodes everything through these, so the
//! wire format is defined in exactly one place.
//!
//! Slice codecs are bulk operations: on little-endian targets (every
//! deployment target we have) the in-memory representation of
//! `f32`/`u32`/`i16` arrays *is* the wire representation, so writers
//! and readers chunk-copy whole payloads (compiling to `memcpy`)
//! instead of looping element-wise. The element-wise `to_le_bytes`/
//! `from_le_bytes` path is kept as the big-endian fallback, selected at
//! compile time, so the wire format stays identical on every target.
//! The `*_raw` reader methods additionally expose the borrowed payload
//! bytes without any allocation — the zero-materialization ingest path
//! (`compress::DecodedView`) decodes values straight out of them.

use anyhow::{bail, Result};

/// View a numeric slice as its raw in-memory bytes — which on an LE
/// target are exactly the wire encoding, so slice writes become one
/// `memcpy`. Only instantiated with the padding-free primitive types
/// the codec carries (`f32`, `u32`, `i16`).
#[cfg(target_endian = "little")]
fn pod_bytes<T: Copy>(v: &[T]) -> &[u8] {
    // SAFETY: T is a padding-free primitive (see above), so every byte
    // of the slice is initialized; the length is the exact byte size.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

/// Bulk-decode a packed little-endian payload (`raw.len() == n *
/// size_of::<T>()`) into a typed vector — the reader-side `memcpy`.
#[cfg(target_endian = "little")]
fn pod_vec_from_bytes<T: Copy + Default>(raw: &[u8], n: usize) -> Vec<T> {
    let mut out = vec![T::default(); n];
    // hard assert: the unsafe copy below is only sound for an exact
    // byte-count match, and a mismatched future caller must fail loudly
    // in release too (one compare vs a memcpy-sized operation)
    assert_eq!(raw.len(), std::mem::size_of_val(out.as_slice()));
    // SAFETY: `out` owns exactly `raw.len()` writable bytes (asserted
    // above), T is a padding-free primitive whose LE in-memory layout
    // is the wire layout, and the two allocations cannot overlap.
    unsafe {
        std::ptr::copy_nonoverlapping(raw.as_ptr(), out.as_mut_ptr() as *mut u8, raw.len());
    }
    out
}

/// Append-only byte writer.
#[derive(Default, Debug)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(n: usize) -> Self {
        Writer {
            buf: Vec::with_capacity(n),
        }
    }

    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn bytes(&mut self, v: &[u8]) {
        self.u64(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    /// Append pre-serialized bytes verbatim (no length prefix). Used to
    /// splice an already-encoded payload into a larger message.
    pub fn raw(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    pub fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    /// Length-prefixed f32 slice, bulk-copied as raw LE bytes.
    pub fn f32_slice(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        #[cfg(target_endian = "little")]
        self.buf.extend_from_slice(pod_bytes(v));
        #[cfg(not(target_endian = "little"))]
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Length-prefixed u32 slice.
    pub fn u32_slice(&mut self, v: &[u32]) {
        self.u64(v.len() as u64);
        #[cfg(target_endian = "little")]
        self.buf.extend_from_slice(pod_bytes(v));
        #[cfg(not(target_endian = "little"))]
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Length-prefixed i8 slice.
    pub fn i8_slice(&mut self, v: &[i8]) {
        self.u64(v.len() as u64);
        // i8 -> u8 reinterpret is byte-identical on every endianness
        self.buf
            .extend_from_slice(unsafe { &*(v as *const [i8] as *const [u8]) });
    }

    /// Length-prefixed i16 slice.
    pub fn i16_slice(&mut self, v: &[i16]) {
        self.u64(v.len() as u64);
        #[cfg(target_endian = "little")]
        self.buf.extend_from_slice(pod_bytes(v));
        #[cfg(not(target_endian = "little"))]
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Cursor-based byte reader with bounds-checked typed accessors.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_done(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "wire decode: wanted {n} bytes, have {} (pos {})",
                self.remaining(),
                self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn len_prefix(&mut self) -> Result<usize> {
        let n = self.u64()?;
        // sanity bound: one message never exceeds 16 GiB
        if n > (16u64 << 30) {
            bail!("wire decode: implausible length {n}");
        }
        Ok(n as usize)
    }

    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.len_prefix()?;
        self.take(n)
    }

    pub fn str(&mut self) -> Result<String> {
        let b = self.bytes()?;
        Ok(std::str::from_utf8(b)
            .map_err(|e| anyhow::anyhow!("wire decode: bad utf-8: {e}"))?
            .to_string())
    }

    pub fn f32_vec(&mut self) -> Result<Vec<f32>> {
        let n = self.len_prefix()?;
        let raw = self.take(n * 4)?;
        #[cfg(target_endian = "little")]
        {
            Ok(pod_vec_from_bytes(raw, n))
        }
        #[cfg(not(target_endian = "little"))]
        {
            Ok(raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect())
        }
    }

    pub fn u32_vec(&mut self) -> Result<Vec<u32>> {
        let n = self.len_prefix()?;
        let raw = self.take(n * 4)?;
        #[cfg(target_endian = "little")]
        {
            Ok(pod_vec_from_bytes(raw, n))
        }
        #[cfg(not(target_endian = "little"))]
        {
            Ok(raw
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                .collect())
        }
    }

    pub fn i8_vec(&mut self) -> Result<Vec<i8>> {
        let n = self.len_prefix()?;
        let raw = self.take(n)?;
        // i8 and u8 are layout-identical: one bulk copy, no per-byte map
        Ok(unsafe { &*(raw as *const [u8] as *const [i8]) }.to_vec())
    }

    pub fn i16_vec(&mut self) -> Result<Vec<i16>> {
        let n = self.len_prefix()?;
        let raw = self.take(n * 2)?;
        #[cfg(target_endian = "little")]
        {
            Ok(pod_vec_from_bytes(raw, n))
        }
        #[cfg(not(target_endian = "little"))]
        {
            Ok(raw
                .chunks_exact(2)
                .map(|c| i16::from_le_bytes(c.try_into().unwrap()))
                .collect())
        }
    }

    /// Borrowed payload of a length-prefixed f32 slice (`4·n` raw LE
    /// bytes) — no decode, no allocation. The zero-materialization
    /// ingest path reads values out of this lazily.
    pub fn f32_raw(&mut self) -> Result<&'a [u8]> {
        let n = self.len_prefix()?;
        self.take(n * 4)
    }

    /// Borrowed payload of a length-prefixed u32 slice (`4·n` bytes).
    pub fn u32_raw(&mut self) -> Result<&'a [u8]> {
        let n = self.len_prefix()?;
        self.take(n * 4)
    }

    /// Borrowed payload of a length-prefixed i8 slice, reinterpreted.
    pub fn i8_raw(&mut self) -> Result<&'a [i8]> {
        let n = self.len_prefix()?;
        let raw = self.take(n)?;
        // i8 and u8 are layout-identical
        Ok(unsafe { &*(raw as *const [u8] as *const [i8]) })
    }

    /// Borrowed payload of a length-prefixed i16 slice (`2·n` bytes).
    pub fn i16_raw(&mut self) -> Result<&'a [u8]> {
        let n = self.len_prefix()?;
        self.take(n * 2)
    }
}

/// Read the `i`-th little-endian f32 from a packed payload (as returned
/// by [`Reader::f32_raw`]).
#[inline]
pub fn f32_le_at(raw: &[u8], i: usize) -> f32 {
    f32::from_le_bytes(raw[4 * i..4 * i + 4].try_into().unwrap())
}

/// Read the `i`-th little-endian u32 from a packed payload.
#[inline]
pub fn u32_le_at(raw: &[u8], i: usize) -> u32 {
    u32::from_le_bytes(raw[4 * i..4 * i + 4].try_into().unwrap())
}

/// Read the `i`-th little-endian i16 from a packed payload.
#[inline]
pub fn i16_le_at(raw: &[u8], i: usize) -> i16 {
    i16::from_le_bytes(raw[2 * i..2 * i + 2].try_into().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        let mut w = Writer::new();
        w.u8(7);
        w.u16(300);
        w.u32(70_000);
        w.u64(1 << 40);
        w.f32(1.5);
        w.f64(-2.25);
        w.str("héllo");
        let v = w.into_vec();
        let mut r = Reader::new(&v);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 300);
        assert_eq!(r.u32().unwrap(), 70_000);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.f64().unwrap(), -2.25);
        assert_eq!(r.str().unwrap(), "héllo");
        assert!(r.is_done());
    }

    #[test]
    fn roundtrip_slices() {
        let f = vec![1.0f32, -2.0, 3.5];
        let u = vec![1u32, 2, 3, 4];
        let i8s = vec![-128i8, 0, 127];
        let i16s = vec![-32768i16, 0, 32767];
        let mut w = Writer::new();
        w.f32_slice(&f);
        w.u32_slice(&u);
        w.i8_slice(&i8s);
        w.i16_slice(&i16s);
        let v = w.into_vec();
        let mut r = Reader::new(&v);
        assert_eq!(r.f32_vec().unwrap(), f);
        assert_eq!(r.u32_vec().unwrap(), u);
        assert_eq!(r.i8_vec().unwrap(), i8s);
        assert_eq!(r.i16_vec().unwrap(), i16s);
    }

    #[test]
    fn truncated_input_errors() {
        let mut w = Writer::new();
        w.f32_slice(&[1.0, 2.0, 3.0]);
        let v = w.into_vec();
        let mut r = Reader::new(&v[..v.len() - 1]);
        assert!(r.f32_vec().is_err());
        let mut r2 = Reader::new(&v[..4]);
        assert!(r2.f32_vec().is_err());
    }

    #[test]
    fn raw_readers_borrow_exact_payloads() {
        let f = vec![1.0f32, -2.5, 3.5];
        let u = vec![7u32, 0, u32::MAX];
        let i8s = vec![-128i8, 0, 127];
        let i16s = vec![-32768i16, -1, 32767];
        let mut w = Writer::new();
        w.f32_slice(&f);
        w.u32_slice(&u);
        w.i8_slice(&i8s);
        w.i16_slice(&i16s);
        let v = w.into_vec();
        let mut r = Reader::new(&v);
        let fr = r.f32_raw().unwrap();
        assert_eq!(fr.len(), 12);
        for (i, &x) in f.iter().enumerate() {
            assert_eq!(f32_le_at(fr, i).to_bits(), x.to_bits());
        }
        let ur = r.u32_raw().unwrap();
        for (i, &x) in u.iter().enumerate() {
            assert_eq!(u32_le_at(ur, i), x);
        }
        assert_eq!(r.i8_raw().unwrap(), &i8s[..]);
        let ir = r.i16_raw().unwrap();
        for (i, &x) in i16s.iter().enumerate() {
            assert_eq!(i16_le_at(ir, i), x);
        }
        assert!(r.is_done());
    }

    #[test]
    fn bulk_slice_codecs_cover_extreme_bit_patterns() {
        // the memcpy fast path must agree with the element-wise wire
        // format for every byte pattern, including NaN/inf/-0.0
        let f = vec![
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            -0.0,
            f32::MIN_POSITIVE,
            f32::from_bits(0xDEAD_BEEF),
        ];
        let mut w = Writer::new();
        w.f32_slice(&f);
        let v = w.into_vec();
        // wire layout: u64 length + per-element to_le_bytes
        assert_eq!(v.len(), 8 + 4 * f.len());
        for (i, x) in f.iter().enumerate() {
            assert_eq!(&v[8 + 4 * i..8 + 4 * i + 4], &x.to_le_bytes());
        }
        let back = Reader::new(&v).f32_vec().unwrap();
        for (a, b) in f.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn implausible_length_rejected() {
        let mut w = Writer::new();
        w.u64(u64::MAX);
        let v = w.into_vec();
        let mut r = Reader::new(&v);
        assert!(r.bytes().is_err());
    }
}
