//! Little-endian wire encoding helpers for the message codec.
//!
//! A tiny, allocation-conscious reader/writer pair. The framework's
//! protocol (network::message) encodes everything through these, so the
//! wire format is defined in exactly one place.

use anyhow::{bail, Result};

/// Append-only byte writer.
#[derive(Default, Debug)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(n: usize) -> Self {
        Writer {
            buf: Vec::with_capacity(n),
        }
    }

    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn bytes(&mut self, v: &[u8]) {
        self.u64(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    /// Append pre-serialized bytes verbatim (no length prefix). Used to
    /// splice an already-encoded payload into a larger message.
    pub fn raw(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    pub fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    /// Length-prefixed f32 slice, bulk-copied as raw LE bytes.
    pub fn f32_slice(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        self.buf.reserve(v.len() * 4);
        // f32 -> LE bytes; on LE targets this is a straight memcpy
        for chunk in v {
            self.buf.extend_from_slice(&chunk.to_le_bytes());
        }
    }

    /// Length-prefixed u32 slice.
    pub fn u32_slice(&mut self, v: &[u32]) {
        self.u64(v.len() as u64);
        self.buf.reserve(v.len() * 4);
        for chunk in v {
            self.buf.extend_from_slice(&chunk.to_le_bytes());
        }
    }

    /// Length-prefixed i8 slice.
    pub fn i8_slice(&mut self, v: &[i8]) {
        self.u64(v.len() as u64);
        // i8 -> u8 reinterpret is byte-identical
        self.buf
            .extend_from_slice(unsafe { &*(v as *const [i8] as *const [u8]) });
    }

    /// Length-prefixed i16 slice.
    pub fn i16_slice(&mut self, v: &[i16]) {
        self.u64(v.len() as u64);
        self.buf.reserve(v.len() * 2);
        for chunk in v {
            self.buf.extend_from_slice(&chunk.to_le_bytes());
        }
    }
}

/// Cursor-based byte reader with bounds-checked typed accessors.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_done(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "wire decode: wanted {n} bytes, have {} (pos {})",
                self.remaining(),
                self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn len_prefix(&mut self) -> Result<usize> {
        let n = self.u64()?;
        // sanity bound: one message never exceeds 16 GiB
        if n > (16u64 << 30) {
            bail!("wire decode: implausible length {n}");
        }
        Ok(n as usize)
    }

    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.len_prefix()?;
        self.take(n)
    }

    pub fn str(&mut self) -> Result<String> {
        let b = self.bytes()?;
        Ok(std::str::from_utf8(b)
            .map_err(|e| anyhow::anyhow!("wire decode: bad utf-8: {e}"))?
            .to_string())
    }

    pub fn f32_vec(&mut self) -> Result<Vec<f32>> {
        let n = self.len_prefix()?;
        let raw = self.take(n * 4)?;
        let mut out = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            out.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(out)
    }

    pub fn u32_vec(&mut self) -> Result<Vec<u32>> {
        let n = self.len_prefix()?;
        let raw = self.take(n * 4)?;
        let mut out = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            out.push(u32::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(out)
    }

    pub fn i8_vec(&mut self) -> Result<Vec<i8>> {
        let n = self.len_prefix()?;
        let raw = self.take(n)?;
        Ok(raw.iter().map(|&b| b as i8).collect())
    }

    pub fn i16_vec(&mut self) -> Result<Vec<i16>> {
        let n = self.len_prefix()?;
        let raw = self.take(n * 2)?;
        let mut out = Vec::with_capacity(n);
        for c in raw.chunks_exact(2) {
            out.push(i16::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        let mut w = Writer::new();
        w.u8(7);
        w.u16(300);
        w.u32(70_000);
        w.u64(1 << 40);
        w.f32(1.5);
        w.f64(-2.25);
        w.str("héllo");
        let v = w.into_vec();
        let mut r = Reader::new(&v);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 300);
        assert_eq!(r.u32().unwrap(), 70_000);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.f64().unwrap(), -2.25);
        assert_eq!(r.str().unwrap(), "héllo");
        assert!(r.is_done());
    }

    #[test]
    fn roundtrip_slices() {
        let f = vec![1.0f32, -2.0, 3.5];
        let u = vec![1u32, 2, 3, 4];
        let i8s = vec![-128i8, 0, 127];
        let i16s = vec![-32768i16, 0, 32767];
        let mut w = Writer::new();
        w.f32_slice(&f);
        w.u32_slice(&u);
        w.i8_slice(&i8s);
        w.i16_slice(&i16s);
        let v = w.into_vec();
        let mut r = Reader::new(&v);
        assert_eq!(r.f32_vec().unwrap(), f);
        assert_eq!(r.u32_vec().unwrap(), u);
        assert_eq!(r.i8_vec().unwrap(), i8s);
        assert_eq!(r.i16_vec().unwrap(), i16s);
    }

    #[test]
    fn truncated_input_errors() {
        let mut w = Writer::new();
        w.f32_slice(&[1.0, 2.0, 3.0]);
        let v = w.into_vec();
        let mut r = Reader::new(&v[..v.len() - 1]);
        assert!(r.f32_vec().is_err());
        let mut r2 = Reader::new(&v[..4]);
        assert!(r2.f32_vec().is_err());
    }

    #[test]
    fn implausible_length_rejected() {
        let mut w = Writer::new();
        w.u64(u64::MAX);
        let v = w.into_vec();
        let mut r = Reader::new(&v);
        assert!(r.bytes().is_err());
    }
}
