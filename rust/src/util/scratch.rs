//! Pooled dense scratch buffers for the decode paths that still need
//! one (see `compress::DecodedView` for the paths that don't).
//!
//! The ingest pipeline folds compressed updates straight from their
//! encoded form, but three paths still materialize a dense `Vec<f32>`:
//! buffered (order-statistic) aggregation strategies, custom strategies
//! that rely on the default densifying `AggStrategy::fold_view`, and
//! the client-side global-model decode in `client::worker`. Before this
//! pool each of those allocated (and zeroed) a fresh P-length vector
//! per update per round; with it, one allocation is recycled across
//! updates *and* rounds.
//!
//! The pool is a plain free-list behind a `Mutex`: `take` pops (or
//! allocates) a buffer and resizes it to the requested length, `put`
//! returns it. Contents of a taken buffer are unspecified — every
//! consumer fully initializes it (`DecodedView::write_dense` zero-fills
//! before scattering), which is exactly why `take` does not pay for a
//! zeroing pass. Retention is bounded by a fixed buffer-count cap, not
//! by capacity: a buffer sized for an old model is kept and simply
//! re-grown (one realloc) the next time `take` asks for more — pools
//! are per-federation objects, so request sizes are stable in
//! practice. Callers must only `put` buffers on paths that also
//! `take` from the pool, or the cap fills with dead buffers.

use std::sync::{Arc, Mutex, OnceLock};

/// How many idle buffers a pool retains. Streaming ingest needs one;
/// buffered strategies need one per in-flight update of a round.
const MAX_POOLED: usize = 64;

/// Global hit/miss counters shared by every pool instance (resolved
/// once — `take` pays one extra relaxed atomic increment, see the
/// accuracy contract in [`crate::telemetry`]).
fn pool_counters() -> &'static (
    Arc<crate::telemetry::Counter>,
    Arc<crate::telemetry::Counter>,
) {
    static COUNTERS: OnceLock<(
        Arc<crate::telemetry::Counter>,
        Arc<crate::telemetry::Counter>,
    )> = OnceLock::new();
    COUNTERS.get_or_init(|| {
        use crate::telemetry::names;
        let g = crate::telemetry::global();
        (
            g.counter(
                names::SCRATCH_HITS_TOTAL,
                "ScratchPool takes served from the free-list.",
            ),
            g.counter(
                names::SCRATCH_MISSES_TOTAL,
                "ScratchPool takes that had to allocate.",
            ),
        )
    })
}

/// Thread-safe free-list of dense `f32` scratch buffers.
#[derive(Debug, Default)]
pub struct ScratchPool {
    bufs: Mutex<Vec<Vec<f32>>>,
}

impl ScratchPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pop a pooled buffer (or allocate one) and size it to `n`
    /// elements. Contents are **unspecified** — the caller must fully
    /// initialize the buffer before reading it.
    pub fn take(&self, n: usize) -> Vec<f32> {
        let pooled = self.bufs.lock().expect("scratch pool poisoned").pop();
        let (hits, misses) = pool_counters();
        let mut buf = match pooled {
            Some(b) => {
                hits.inc();
                b
            }
            None => {
                misses.inc();
                Vec::new()
            }
        };
        buf.resize(n, 0.0);
        buf
    }

    /// Return a buffer for reuse. Buffers beyond the retention cap are
    /// dropped (freed) instead of pooled.
    pub fn put(&self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        let mut bufs = self.bufs.lock().expect("scratch pool poisoned");
        if bufs.len() < MAX_POOLED {
            bufs.push(buf);
        }
    }

    /// Idle buffers currently pooled (for tests/metrics).
    pub fn idle(&self) -> usize {
        self.bufs.lock().expect("scratch pool poisoned").len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_reuses_returned_allocation() {
        let pool = ScratchPool::new();
        let mut a = pool.take(1000);
        a[0] = 7.0;
        let ptr = a.as_ptr();
        let cap = a.capacity();
        pool.put(a);
        assert_eq!(pool.idle(), 1);
        let b = pool.take(1000);
        assert_eq!(b.as_ptr(), ptr, "allocation must be recycled");
        assert_eq!(b.capacity(), cap);
        assert_eq!(b.len(), 1000);
        assert_eq!(pool.idle(), 0);
    }

    #[test]
    fn take_resizes_to_request() {
        let pool = ScratchPool::new();
        pool.put(vec![1.0; 10]);
        let b = pool.take(25);
        assert_eq!(b.len(), 25);
        let c = pool.take(5);
        assert_eq!(c.len(), 5);
    }

    #[test]
    fn retention_is_capped() {
        let pool = ScratchPool::new();
        for _ in 0..(MAX_POOLED + 10) {
            pool.put(vec![0.0; 4]);
        }
        assert_eq!(pool.idle(), MAX_POOLED);
        // zero-capacity buffers are not worth pooling
        pool.put(Vec::new());
        assert_eq!(pool.idle(), MAX_POOLED);
    }
}
