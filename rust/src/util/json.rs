//! Minimal JSON parser + writer (no serde on this image).
//!
//! Scope: everything the framework serializes — the AOT
//! `artifacts/manifest.json`, experiment configs, and metric exports.
//! Full JSON grammar (strings with escapes, numbers, bool/null,
//! arrays, objects); numbers are f64 (adequate: the manifest's largest
//! integers are parameter counts < 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    pub fn parse(s: &str) -> Result<Value, ParseError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Fetch a required field, with a path-ish error message.
    pub fn req<'a>(&'a self, key: &str) -> anyhow::Result<&'a Value> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field '{key}'"))
    }
}

// ---------------------------------------------------------------- parser

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pairs
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            cp
                        };
                        s.push(
                            char::from_u32(c)
                                .ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences verbatim
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

// ---------------------------------------------------------------- writer

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Value::Str(s) => write_escaped(f, s),
            Value::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience builders used by metric exporters.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

pub fn arr<I: IntoIterator<Item = Value>>(items: I) -> Value {
    Value::Arr(items.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(Value::parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": false}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_usize().unwrap(), 1);
        assert_eq!(a[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parse_unicode_escapes() {
        let v = Value::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Value::parse("\"héllo — wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — wörld");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("12 34").is_err());
        assert!(Value::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"m": {"n_params": 235146, "x": [1.5, -2, true, null], "s": "a\"b"}}"#;
        let v = Value::parse(src).unwrap();
        let printed = v.to_string();
        assert_eq!(Value::parse(&printed).unwrap(), v);
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{"version":1,"models":{"mlp":{"n_params":10,"x_shape":[784],"x_dtype":"f32"}}}"#;
        let v = Value::parse(src).unwrap();
        let m = v.get("models").unwrap().get("mlp").unwrap();
        assert_eq!(m.get("n_params").unwrap().as_usize(), Some(10));
        assert_eq!(m.get("x_dtype").unwrap().as_str(), Some("f32"));
    }
}
