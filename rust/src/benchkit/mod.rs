//! Minimal benchmarking harness (criterion is not vendored on this
//! image; see .cargo/config.toml). Provides warmup + timed iterations,
//! robust statistics and aligned table output. Used by every target in
//! `rust/benches/` (all declared `harness = false`).

use std::time::{Duration, Instant};

/// Result statistics for one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// Throughput helper: elements processed per second at the mean.
    pub fn throughput(&self, elems_per_iter: f64) -> f64 {
        elems_per_iter / (self.mean_ns / 1e9)
    }
}

/// Run `f` repeatedly: warmup for ~10% of the budget, then sample until
/// `budget` elapses or `max_iters` reached. Returns robust stats.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchStats {
    // warmup
    let warm_until = Instant::now() + budget.mul_f64(0.1);
    while Instant::now() < warm_until {
        f();
    }
    let mut samples_ns: Vec<f64> = Vec::new();
    let end = Instant::now() + budget;
    let max_iters = 100_000;
    while Instant::now() < end && samples_ns.len() < max_iters {
        let t0 = Instant::now();
        f();
        samples_ns.push(t0.elapsed().as_nanos() as f64);
    }
    if samples_ns.is_empty() {
        // budget too small for even one run: take one sample anyway
        let t0 = Instant::now();
        f();
        samples_ns.push(t0.elapsed().as_nanos() as f64);
    }
    samples_ns.sort_by(f64::total_cmp);
    let n = samples_ns.len();
    let mean = samples_ns.iter().sum::<f64>() / n as f64;
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        median_ns: samples_ns[n / 2],
        p95_ns: samples_ns[(n as f64 * 0.95) as usize % n.max(1)],
        min_ns: samples_ns[0],
    }
}

/// Per-case time budget for a bench binary: the default, unless
/// `FEDHPC_BENCH_BUDGET_MS` overrides it (CI smoke runs set a few tens
/// of milliseconds so the binaries double as cheap regression probes).
pub fn budget_from_env(default_ms: u64) -> Duration {
    let ms = std::env::var("FEDHPC_BENCH_BUDGET_MS")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(default_ms);
    Duration::from_millis(ms.max(1))
}

/// Build a JSON object from numeric key/value pairs (helper for the
/// `extra` metrics of [`write_json_report`]).
pub fn json_num_obj(pairs: &[(&str, f64)]) -> crate::util::json::Value {
    use crate::util::json::Value;
    let mut m = std::collections::BTreeMap::new();
    for (k, v) in pairs {
        m.insert((*k).to_string(), Value::Num(*v));
    }
    Value::Obj(m)
}

/// Write a bench run as machine-readable JSON (the repo convention is
/// `BENCH_<name>.json` in the working directory) so the perf
/// trajectory is trackable across PRs. Timing stats are keyed by
/// benchmark name; `extra` carries bench-specific derived metrics
/// (updates/sec, speedups, bytes/update, …).
pub fn write_json_report(
    path: &str,
    bench: &str,
    stats: &[BenchStats],
    extra: &[(&str, crate::util::json::Value)],
) -> std::io::Result<()> {
    use crate::util::json::Value;
    use std::collections::BTreeMap;
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Value::Str(bench.to_string()));
    let mut results = BTreeMap::new();
    for s in stats {
        let mut m = BTreeMap::new();
        m.insert("iters".to_string(), Value::Num(s.iters as f64));
        m.insert("mean_ns".to_string(), Value::Num(s.mean_ns));
        m.insert("median_ns".to_string(), Value::Num(s.median_ns));
        m.insert("p95_ns".to_string(), Value::Num(s.p95_ns));
        m.insert("min_ns".to_string(), Value::Num(s.min_ns));
        results.insert(s.name.clone(), Value::Obj(m));
    }
    root.insert("results".to_string(), Value::Obj(results));
    for (k, v) in extra {
        root.insert((*k).to_string(), v.clone());
    }
    let mut body = Value::Obj(root).to_string();
    body.push('\n');
    std::fs::write(path, body)?;
    println!("\nmachine-readable report: {path}");
    Ok(())
}

/// Print a group of results as an aligned table.
pub fn print_table(title: &str, stats: &[BenchStats]) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>10} {:>12} {:>12} {:>12}",
        "benchmark", "iters", "mean", "median", "p95"
    );
    for s in stats {
        println!(
            "{:<44} {:>10} {:>12} {:>12} {:>12}",
            s.name,
            s.iters,
            fmt_ns(s.mean_ns),
            fmt_ns(s.median_ns),
            fmt_ns(s.p95_ns)
        );
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut counter = 0u64;
        let s = bench("noop", Duration::from_millis(30), || {
            counter = counter.wrapping_add(1);
        });
        assert!(s.iters > 10, "iters {}", s.iters);
        assert!(s.mean_ns >= 0.0);
        assert!(s.min_ns <= s.median_ns);
        assert!(s.median_ns <= s.p95_ns * 1.001);
    }

    #[test]
    fn stats_helpers() {
        let s = BenchStats {
            name: "x".into(),
            iters: 10,
            mean_ns: 2e6,
            median_ns: 2e6,
            p95_ns: 3e6,
            min_ns: 1e6,
        };
        assert_eq!(s.mean_ms(), 2.0);
        // 1000 elements in 2 ms → 500k/s
        assert!((s.throughput(1000.0) - 500_000.0).abs() < 1.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(1.2e9), "1.20 s");
    }
}
