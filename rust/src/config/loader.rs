//! JSON (de)serialization of [`ExperimentConfig`] via the in-tree
//! `util::json` — hand-rolled field mapping (no serde on this image),
//! with round-trip tests pinning the schema.

use super::*;
use crate::util::json::{self, Value};
use anyhow::{anyhow, bail, Context, Result};

pub fn from_json_file(path: &str) -> Result<ExperimentConfig> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading config file {path}"))?;
    from_json_str(&text).with_context(|| format!("parsing config file {path}"))
}

pub fn from_json_str(text: &str) -> Result<ExperimentConfig> {
    let v = Value::parse(text).map_err(|e| anyhow!("{e}"))?;
    let cfg = decode(&v)?;
    validate(&cfg)?;
    Ok(cfg)
}

fn f64_of(v: &Value, key: &str) -> Result<f64> {
    v.req(key)?
        .as_f64()
        .ok_or_else(|| anyhow!("field '{key}' must be a number"))
}

fn usize_of(v: &Value, key: &str) -> Result<usize> {
    v.req(key)?
        .as_usize()
        .ok_or_else(|| anyhow!("field '{key}' must be a non-negative integer"))
}

fn str_of(v: &Value, key: &str) -> Result<String> {
    Ok(v.req(key)?
        .as_str()
        .ok_or_else(|| anyhow!("field '{key}' must be a string"))?
        .to_string())
}

fn decode(v: &Value) -> Result<ExperimentConfig> {
    let data = v.req("data")?;
    let partition = {
        let p = data.req("partition")?;
        match str_of(p, "kind")?.as_str() {
            "iid" => Partition::Iid,
            "label_shard" => Partition::LabelShard {
                classes_per_client: usize_of(p, "classes_per_client")?,
            },
            "dirichlet" => Partition::Dirichlet {
                alpha: f64_of(p, "alpha")?,
            },
            k => bail!("unknown partition kind '{k}'"),
        }
    };
    let cluster = {
        let c = v.req("cluster")?;
        let nodes = c
            .req("nodes")?
            .as_arr()
            .ok_or_else(|| anyhow!("cluster.nodes must be an array"))?
            .iter()
            .map(|n| Ok((str_of(n, "sku")?, usize_of(n, "count")?)))
            .collect::<Result<Vec<_>>>()?;
        ClusterConfig {
            nodes,
            cloud_backend: str_of(c, "cloud_backend").unwrap_or_else(|_| "inproc".into()),
            hpc_backend: str_of(c, "hpc_backend").unwrap_or_else(|_| "inproc".into()),
        }
    };
    let aggregation = {
        let a = v.req("aggregation")?;
        // kind strings are the registry names (Aggregation::KINDS);
        // an unknown name is a load-time error, never a panic
        match str_of(a, "kind")?.as_str() {
            "fedavg" => Aggregation::FedAvg,
            "fedprox" => Aggregation::FedProx {
                mu: f64_of(a, "mu")? as f32,
            },
            "weighted" => Aggregation::Weighted(WeightScheme::parse(
                str_of(a, "scheme")?.as_str(),
            )?),
            "trimmed_mean" => Aggregation::TrimmedMean {
                trim_frac: a
                    .get("trim_frac")
                    .and_then(Value::as_f64)
                    .unwrap_or(defaults::TRIM_FRAC as f64) as f32,
            },
            "coordinate_median" => Aggregation::CoordinateMedian,
            k => bail!(
                "unknown aggregation kind '{k}' (known: {})",
                Aggregation::KINDS.join(", ")
            ),
        }
    };
    // optional sibling of `kind` inside the aggregation object; absent
    // means 0 = auto. Strict on junk values like max_staleness above.
    let ingest_threads = match v.req("aggregation")?.get("ingest_threads") {
        None => 0,
        Some(t) => u32::try_from(t.as_usize().ok_or_else(|| {
            anyhow!("aggregation.ingest_threads must be a non-negative integer")
        })?)
        .map_err(|_| anyhow!("aggregation.ingest_threads exceeds u32"))?,
    };
    let server_opt = match v.get("server_opt") {
        None => ServerOptKind::Sgd,
        Some(o) => match str_of(o, "kind")?.as_str() {
            "sgd" => ServerOptKind::Sgd,
            "fedavgm" => ServerOptKind::FedAvgM {
                beta: o
                    .get("beta")
                    .and_then(Value::as_f64)
                    .unwrap_or(defaults::FEDAVGM_BETA as f64) as f32,
            },
            "fedadam" => ServerOptKind::FedAdam {
                lr: o
                    .get("lr")
                    .and_then(Value::as_f64)
                    .unwrap_or(defaults::FEDADAM_LR as f64) as f32,
                beta1: o
                    .get("beta1")
                    .and_then(Value::as_f64)
                    .unwrap_or(defaults::FEDADAM_BETA1 as f64) as f32,
                beta2: o
                    .get("beta2")
                    .and_then(Value::as_f64)
                    .unwrap_or(defaults::FEDADAM_BETA2 as f64) as f32,
                eps: o
                    .get("eps")
                    .and_then(Value::as_f64)
                    .unwrap_or(defaults::FEDADAM_EPS as f64) as f32,
            },
            k => bail!(
                "unknown server_opt kind '{k}' (known: {})",
                ServerOptKind::KINDS.join(", ")
            ),
        },
    };
    let round_mode = match v.get("round_mode") {
        None => RoundMode::Sync,
        Some(m) => match str_of(m, "kind")?.as_str() {
            "sync" => RoundMode::Sync,
            "async_fedbuff" => RoundMode::BufferedAsync {
                buffer_k: m
                    .get("buffer_k")
                    .and_then(Value::as_usize)
                    .unwrap_or(defaults::ASYNC_BUFFER_K),
                // strict like the CLI path: a present-but-negative /
                // fractional / oversized value is an error, never a
                // silent saturation
                max_staleness: match m.get("max_staleness") {
                    None => defaults::ASYNC_MAX_STALENESS,
                    Some(v) => u32::try_from(v.as_usize().ok_or_else(|| {
                        anyhow!("round_mode.max_staleness must be a non-negative integer")
                    })?)
                    .map_err(|_| anyhow!("round_mode.max_staleness exceeds u32"))?,
                },
                staleness: match m.get("staleness").and_then(|s| s.as_str()) {
                    None => StalenessFn::Polynomial {
                        alpha: defaults::ASYNC_ALPHA,
                    },
                    Some(spec) => StalenessFn::parse(spec)?,
                },
            },
            k => bail!(
                "unknown round_mode kind '{k}' (known: {})",
                RoundMode::KINDS.join(", ")
            ),
        },
    };
    let selection = {
        let s = v.req("selection")?;
        let policy = match str_of(s, "policy")?.as_str() {
            "random" => SelectionPolicy::Random,
            "adaptive" => SelectionPolicy::Adaptive {
                explore_frac: s
                    .get("explore_frac")
                    .and_then(Value::as_f64)
                    .unwrap_or(0.2),
                exclude_factor: s
                    .get("exclude_factor")
                    .and_then(Value::as_f64)
                    .unwrap_or(2.5),
            },
            p => bail!("unknown selection policy '{p}'"),
        };
        // optional planner spec string ("tiered:4", "deadline:2000",
        // …); pre-planner configs without the field still load and
        // derive their planner from `policy`
        let planner = match s.get("planner") {
            None => None,
            Some(p) => Some(PlannerKind::parse(
                p.as_str().ok_or_else(|| anyhow!("selection.planner must be a spec string"))?,
            )?),
        };
        SelectionConfig {
            policy,
            planner,
            clients_per_round: usize_of(s, "clients_per_round")?,
        }
    };
    let straggler = match v.get("straggler") {
        None => StragglerConfig::default(),
        Some(s) => StragglerConfig {
            deadline_ms: s
                .get("deadline_ms")
                .and_then(Value::as_f64)
                .map(|d| d as u64),
            partial_k: s.get("partial_k").and_then(Value::as_usize),
        },
    };
    let compression = match v.get("compression") {
        None => CompressionConfig::NONE,
        Some(c) => CompressionConfig {
            quant_bits: c
                .get("quant_bits")
                .and_then(Value::as_usize)
                .unwrap_or(32) as u8,
            topk_frac: c
                .get("topk_frac")
                .and_then(Value::as_f64)
                .unwrap_or(1.0) as f32,
            dropout_keep: c
                .get("dropout_keep")
                .and_then(Value::as_f64)
                .unwrap_or(1.0) as f32,
        },
    };
    let faults = match v.get("faults") {
        None => FaultConfig::default(),
        Some(f) => FaultConfig {
            dropout_prob: f.get("dropout_prob").and_then(Value::as_f64).unwrap_or(0.0),
            preemption_prob: f
                .get("preemption_prob")
                .and_then(Value::as_f64)
                .unwrap_or(0.0),
            straggler_prob: f
                .get("straggler_prob")
                .and_then(Value::as_f64)
                .unwrap_or(0.0),
            straggler_factor: f
                .get("straggler_factor")
                .and_then(Value::as_f64)
                .unwrap_or(4.0),
        },
    };
    let t = v.req("train")?;
    let train = TrainConfig {
        local_epochs: usize_of(t, "local_epochs")?,
        lr: f64_of(t, "lr")? as f32,
        rounds: usize_of(t, "rounds")?,
        converge_eps: t
            .get("converge_eps")
            .and_then(Value::as_f64)
            .unwrap_or(1e-5) as f32,
        converge_patience: t
            .get("converge_patience")
            .and_then(Value::as_usize)
            .unwrap_or(3),
        target_accuracy: t.get("target_accuracy").and_then(Value::as_f64),
    };
    Ok(ExperimentConfig {
        name: str_of(v, "name")?,
        seed: f64_of(v, "seed").unwrap_or(42.0) as u64,
        data: DataConfig {
            dataset: str_of(data, "dataset")?,
            partition,
            samples_per_client: usize_of(data, "samples_per_client")?,
            eval_samples: usize_of(data, "eval_samples")?,
        },
        cluster,
        train,
        aggregation,
        ingest_threads,
        server_opt,
        round_mode,
        selection,
        straggler,
        compression,
        faults,
        artifacts_dir: str_of(v, "artifacts_dir").unwrap_or_else(|_| "artifacts".into()),
        mock_runtime: v
            .get("mock_runtime")
            .and_then(Value::as_bool)
            .unwrap_or(false),
        // optional section: absent (old configs) means disabled
        telemetry: TelemetryConfig {
            addr: v
                .get("telemetry")
                .and_then(|t| t.get("addr"))
                .and_then(Value::as_str)
                .map(str::to_string),
        },
        // optional section: absent (old configs) means defaults
        transport: match v.get("transport") {
            None => TransportConfig::default(),
            Some(t) => {
                let d = TransportConfig::default();
                TransportConfig {
                    max_connections: t
                        .get("max_connections")
                        .and_then(Value::as_usize)
                        .unwrap_or(d.max_connections),
                    compression: t
                        .get("compression")
                        .and_then(Value::as_bool)
                        .unwrap_or(d.compression),
                    reactor_threads: t
                        .get("reactor_threads")
                        .and_then(Value::as_usize)
                        .map(|n| n as u32)
                        .unwrap_or(d.reactor_threads),
                    idle_timeout_ms: t
                        .get("idle_timeout_ms")
                        .and_then(Value::as_f64)
                        .map(|n| n as u64)
                        .unwrap_or(d.idle_timeout_ms),
                    outbox_frames: t
                        .get("outbox_frames")
                        .and_then(Value::as_usize)
                        .unwrap_or(d.outbox_frames),
                }
            }
        },
        // optional section: absent (old configs) means flat (no
        // aggregator tier); grouping specs are registry names
        // ("flat", "site:<n>", "zone") — unknown names are load-time
        // errors, never panics
        hierarchy: match v.get("hierarchy") {
            None => HierarchyConfig::default(),
            Some(h) => HierarchyConfig {
                grouping: match h.get("grouping") {
                    None => GroupingPolicy::default(),
                    Some(g) => GroupingPolicy::parse(g.as_str().ok_or_else(
                        || anyhow!("hierarchy.grouping must be a spec string"),
                    )?)?,
                },
            },
        },
    })
}

pub fn to_json(cfg: &ExperimentConfig) -> String {
    use json::{arr, num, obj, s, Value as V};
    let partition = match cfg.data.partition {
        Partition::Iid => obj(vec![("kind", s("iid"))]),
        Partition::LabelShard { classes_per_client } => obj(vec![
            ("kind", s("label_shard")),
            ("classes_per_client", num(classes_per_client as f64)),
        ]),
        Partition::Dirichlet { alpha } => {
            obj(vec![("kind", s("dirichlet")), ("alpha", num(alpha))])
        }
    };
    let aggregation = {
        let mut fields = match cfg.aggregation {
            Aggregation::FedAvg => vec![("kind", s("fedavg"))],
            Aggregation::FedProx { mu } => {
                vec![("kind", s("fedprox")), ("mu", num(mu as f64))]
            }
            Aggregation::Weighted(scheme) => vec![
                ("kind", s("weighted")),
                ("scheme", s(scheme.name())),
            ],
            Aggregation::TrimmedMean { trim_frac } => vec![
                ("kind", s("trimmed_mean")),
                ("trim_frac", num(trim_frac as f64)),
            ],
            Aggregation::CoordinateMedian => vec![("kind", s("coordinate_median"))],
        };
        fields.push(("ingest_threads", num(f64::from(cfg.ingest_threads))));
        obj(fields)
    };
    let server_opt = match cfg.server_opt {
        ServerOptKind::Sgd => obj(vec![("kind", s("sgd"))]),
        ServerOptKind::FedAvgM { beta } => obj(vec![
            ("kind", s("fedavgm")),
            ("beta", num(beta as f64)),
        ]),
        ServerOptKind::FedAdam {
            lr,
            beta1,
            beta2,
            eps,
        } => obj(vec![
            ("kind", s("fedadam")),
            ("lr", num(lr as f64)),
            ("beta1", num(beta1 as f64)),
            ("beta2", num(beta2 as f64)),
            ("eps", num(eps as f64)),
        ]),
    };
    let round_mode = match cfg.round_mode {
        RoundMode::Sync => obj(vec![("kind", s("sync"))]),
        RoundMode::BufferedAsync {
            buffer_k,
            max_staleness,
            staleness,
        } => obj(vec![
            ("kind", s("async_fedbuff")),
            ("buffer_k", num(buffer_k as f64)),
            ("max_staleness", num(max_staleness as f64)),
            ("staleness", s(&staleness.spec())),
        ]),
    };
    let selection = {
        let mut fields = match cfg.selection.policy {
            SelectionPolicy::Random => vec![("policy", s("random"))],
            SelectionPolicy::Adaptive {
                explore_frac,
                exclude_factor,
            } => vec![
                ("policy", s("adaptive")),
                ("explore_frac", num(explore_frac)),
                ("exclude_factor", num(exclude_factor)),
            ],
        };
        let planner_spec = cfg.selection.planner.as_ref().map(|p| p.spec());
        if let Some(spec) = &planner_spec {
            fields.push(("planner", s(spec)));
        }
        fields.push((
            "clients_per_round",
            num(cfg.selection.clients_per_round as f64),
        ));
        obj(fields)
    };
    let mut straggler_fields = vec![];
    if let Some(d) = cfg.straggler.deadline_ms {
        straggler_fields.push(("deadline_ms", num(d as f64)));
    }
    if let Some(k) = cfg.straggler.partial_k {
        straggler_fields.push(("partial_k", num(k as f64)));
    }
    let mut train_fields = vec![
        ("local_epochs", num(cfg.train.local_epochs as f64)),
        ("lr", num(cfg.train.lr as f64)),
        ("rounds", num(cfg.train.rounds as f64)),
        ("converge_eps", num(cfg.train.converge_eps as f64)),
        ("converge_patience", num(cfg.train.converge_patience as f64)),
    ];
    if let Some(t) = cfg.train.target_accuracy {
        train_fields.push(("target_accuracy", num(t)));
    }
    let mut telemetry_fields = vec![];
    if let Some(addr) = &cfg.telemetry.addr {
        telemetry_fields.push(("addr", s(addr)));
    }
    obj(vec![
        ("name", s(&cfg.name)),
        ("seed", num(cfg.seed as f64)),
        (
            "data",
            obj(vec![
                ("dataset", s(&cfg.data.dataset)),
                ("partition", partition),
                (
                    "samples_per_client",
                    num(cfg.data.samples_per_client as f64),
                ),
                ("eval_samples", num(cfg.data.eval_samples as f64)),
            ]),
        ),
        (
            "cluster",
            obj(vec![
                (
                    "nodes",
                    arr(cfg.cluster.nodes.iter().map(|(sku, count)| {
                        obj(vec![("sku", s(sku)), ("count", num(*count as f64))])
                    })),
                ),
                ("cloud_backend", s(&cfg.cluster.cloud_backend)),
                ("hpc_backend", s(&cfg.cluster.hpc_backend)),
            ]),
        ),
        ("train", obj(train_fields)),
        ("aggregation", aggregation),
        ("server_opt", server_opt),
        ("round_mode", round_mode),
        ("selection", selection),
        ("straggler", obj(straggler_fields)),
        (
            "compression",
            obj(vec![
                ("quant_bits", num(cfg.compression.quant_bits as f64)),
                ("topk_frac", num(cfg.compression.topk_frac as f64)),
                ("dropout_keep", num(cfg.compression.dropout_keep as f64)),
            ]),
        ),
        (
            "faults",
            obj(vec![
                ("dropout_prob", num(cfg.faults.dropout_prob)),
                ("preemption_prob", num(cfg.faults.preemption_prob)),
                ("straggler_prob", num(cfg.faults.straggler_prob)),
                ("straggler_factor", num(cfg.faults.straggler_factor)),
            ]),
        ),
        ("artifacts_dir", s(&cfg.artifacts_dir)),
        (
            "mock_runtime",
            V::Bool(cfg.mock_runtime),
        ),
        ("telemetry", obj(telemetry_fields)),
        (
            "transport",
            obj(vec![
                (
                    "max_connections",
                    num(cfg.transport.max_connections as f64),
                ),
                ("compression", V::Bool(cfg.transport.compression)),
                (
                    "reactor_threads",
                    num(f64::from(cfg.transport.reactor_threads)),
                ),
                (
                    "idle_timeout_ms",
                    num(cfg.transport.idle_timeout_ms as f64),
                ),
                ("outbox_frames", num(cfg.transport.outbox_frames as f64)),
            ]),
        ),
        (
            "hierarchy",
            obj(vec![("grouping", s(&cfg.hierarchy.grouping.spec()))]),
        ),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::super::presets::{paper_testbed, quickstart};
    use super::*;

    #[test]
    fn roundtrip_quickstart() {
        let cfg = quickstart();
        let text = to_json(&cfg);
        let back = from_json_str(&text).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn roundtrip_paper_testbed() {
        let cfg = paper_testbed();
        let back = from_json_str(&to_json(&cfg)).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn roundtrip_all_aggregations_and_partitions() {
        for agg in [
            Aggregation::FedAvg,
            Aggregation::FedProx { mu: 0.5 },
            Aggregation::Weighted(WeightScheme::DataSize),
            Aggregation::Weighted(WeightScheme::InverseLoss),
            Aggregation::Weighted(WeightScheme::InverseVariance),
            Aggregation::TrimmedMean { trim_frac: 0.25 },
            Aggregation::CoordinateMedian,
        ] {
            for part in [
                Partition::Iid,
                Partition::LabelShard {
                    classes_per_client: 2,
                },
                Partition::Dirichlet { alpha: 0.3 },
            ] {
                let mut cfg = quickstart();
                cfg.aggregation = agg;
                cfg.data.partition = part;
                let back = from_json_str(&to_json(&cfg)).unwrap();
                assert_eq!(cfg, back);
            }
        }
    }

    #[test]
    fn roundtrip_all_server_opts() {
        for opt in [
            ServerOptKind::Sgd,
            ServerOptKind::FedAvgM { beta: 0.9 },
            ServerOptKind::FedAdam {
                lr: 0.05,
                beta1: 0.9,
                beta2: 0.99,
                eps: 1e-3,
            },
        ] {
            let mut cfg = quickstart();
            cfg.server_opt = opt;
            let back = from_json_str(&to_json(&cfg)).unwrap();
            assert_eq!(cfg, back);
        }
    }

    #[test]
    fn roundtrip_round_modes() {
        for mode in [
            RoundMode::Sync,
            RoundMode::BufferedAsync {
                buffer_k: 10,
                max_staleness: 20,
                staleness: StalenessFn::Polynomial { alpha: 0.5 },
            },
            RoundMode::BufferedAsync {
                buffer_k: 3,
                max_staleness: 7,
                staleness: StalenessFn::Uniform,
            },
        ] {
            let mut cfg = quickstart();
            cfg.round_mode = mode;
            let back = from_json_str(&to_json(&cfg)).unwrap();
            assert_eq!(cfg, back);
        }
    }

    #[test]
    fn roundtrip_planners() {
        for planner in [
            None,
            Some(PlannerKind::Random),
            Some(PlannerKind::Adaptive {
                explore_frac: 0.3,
                exclude_factor: 4.0,
            }),
            Some(PlannerKind::Tiered { tiers: 3 }),
            Some(PlannerKind::Deadline { target_ms: None }),
            Some(PlannerKind::Deadline {
                target_ms: Some(2500),
            }),
        ] {
            let mut cfg = quickstart();
            cfg.selection.planner = planner;
            let back = from_json_str(&to_json(&cfg)).unwrap();
            assert_eq!(cfg, back);
        }
    }

    #[test]
    fn missing_planner_field_derives_from_policy() {
        // pre-planner configs (no selection.planner key) still load
        let mut cfg = quickstart();
        cfg.selection.planner = None;
        let text = to_json(&cfg);
        assert!(!text.contains("planner"), "None must not serialize");
        let back = from_json_str(&text).unwrap();
        assert_eq!(back.selection.planner, None);
        assert_eq!(
            back.selection.planner_kind(),
            PlannerKind::from_policy(cfg.selection.policy)
        );
    }

    #[test]
    fn unknown_planner_spec_errors() {
        let mut cfg = quickstart();
        cfg.selection.planner = Some(PlannerKind::Tiered { tiers: 4 });
        let text = to_json(&cfg).replace("\"tiered:4\"", "\"oracle:9\"");
        let err = from_json_str(&text).unwrap_err();
        assert!(
            format!("{err:#}").contains("unknown planner 'oracle'"),
            "got: {err:#}"
        );
    }

    #[test]
    fn missing_round_mode_section_defaults_to_sync() {
        // configs written before the round_mode axis existed still load
        let text = to_json(&quickstart());
        let stripped = {
            let v = Value::parse(&text).unwrap();
            let keep: Vec<(&str, Value)> = [
                "name",
                "seed",
                "data",
                "cluster",
                "train",
                "aggregation",
                "selection",
            ]
            .iter()
            .map(|k| (*k, v.req(k).unwrap().clone()))
            .collect();
            json::obj(keep).to_string()
        };
        let cfg = from_json_str(&stripped).unwrap();
        assert_eq!(cfg.round_mode, RoundMode::Sync);
    }

    #[test]
    fn unknown_round_mode_kind_errors() {
        let mut text = to_json(&quickstart());
        text = text.replace("\"sync\"", "\"semi_sync\"");
        let err = from_json_str(&text).unwrap_err();
        assert!(
            format!("{err:#}").contains("unknown round_mode kind 'semi_sync'"),
            "got: {err:#}"
        );
    }

    #[test]
    fn negative_max_staleness_errors_instead_of_saturating() {
        // strict like the CLI path: -5 must not silently become 0
        let mut cfg = quickstart();
        cfg.round_mode = RoundMode::BufferedAsync {
            buffer_k: 2,
            max_staleness: 7,
            staleness: StalenessFn::Uniform,
        };
        let text = to_json(&cfg).replace("\"max_staleness\":7", "\"max_staleness\":-5");
        assert!(text.contains("-5"), "replacement failed: {text}");
        let err = from_json_str(&text).unwrap_err();
        assert!(
            format!("{err:#}").contains("max_staleness"),
            "got: {err:#}"
        );
    }

    #[test]
    fn missing_ingest_threads_defaults_to_auto() {
        // configs written before the parallel-ingest axis existed
        // still load, resolving to auto (0)
        let text = to_json(&quickstart());
        assert!(text.contains("\"ingest_threads\":1"), "got: {text}");
        let stripped = text.replace(",\"ingest_threads\":1", "");
        assert!(!stripped.contains("ingest_threads"), "strip failed");
        let cfg = from_json_str(&stripped).unwrap();
        assert_eq!(cfg.ingest_threads, 0);
    }

    #[test]
    fn negative_ingest_threads_errors_instead_of_saturating() {
        let text = to_json(&quickstart())
            .replace("\"ingest_threads\":1", "\"ingest_threads\":-2");
        assert!(text.contains("-2"), "replacement failed: {text}");
        let err = from_json_str(&text).unwrap_err();
        assert!(
            format!("{err:#}").contains("ingest_threads"),
            "got: {err:#}"
        );
    }

    #[test]
    fn missing_server_opt_section_defaults_to_sgd() {
        // configs written before the server_opt axis existed still load
        let text = to_json(&quickstart());
        let stripped = {
            let v = Value::parse(&text).unwrap();
            let keep: Vec<(&str, Value)> = [
                "name",
                "seed",
                "data",
                "cluster",
                "train",
                "aggregation",
                "selection",
            ]
            .iter()
            .map(|k| (*k, v.req(k).unwrap().clone()))
            .collect();
            json::obj(keep).to_string()
        };
        let cfg = from_json_str(&stripped).unwrap();
        assert_eq!(cfg.server_opt, ServerOptKind::Sgd);
    }

    #[test]
    fn unknown_strategy_names_error_instead_of_panicking() {
        let mut text = to_json(&quickstart());
        text = text.replace("\"fedavg\"", "\"krum\"");
        let err = from_json_str(&text).unwrap_err();
        assert!(
            format!("{err:#}").contains("unknown aggregation kind 'krum'"),
            "got: {err:#}"
        );

        let mut text = to_json(&quickstart());
        text = text.replace("\"sgd\"", "\"lamb\"");
        let err = from_json_str(&text).unwrap_err();
        assert!(
            format!("{err:#}").contains("unknown server_opt kind 'lamb'"),
            "got: {err:#}"
        );
    }

    #[test]
    fn roundtrip_telemetry_addr() {
        let mut cfg = quickstart();
        cfg.telemetry.addr = Some("127.0.0.1:9469".into());
        let back = from_json_str(&to_json(&cfg)).unwrap();
        assert_eq!(back.telemetry.addr.as_deref(), Some("127.0.0.1:9469"));
        assert_eq!(cfg, back);
    }

    #[test]
    fn missing_telemetry_section_defaults_to_disabled() {
        // configs written before the telemetry axis existed still load
        let text = to_json(&quickstart());
        let stripped = {
            let v = Value::parse(&text).unwrap();
            let keep: Vec<(&str, Value)> = [
                "name",
                "seed",
                "data",
                "cluster",
                "train",
                "aggregation",
                "selection",
            ]
            .iter()
            .map(|k| (*k, v.req(k).unwrap().clone()))
            .collect();
            json::obj(keep).to_string()
        };
        let cfg = from_json_str(&stripped).unwrap();
        assert_eq!(cfg.telemetry, TelemetryConfig::default());
        assert_eq!(cfg.telemetry.addr, None);
    }

    #[test]
    fn roundtrip_transport_section() {
        let mut cfg = quickstart();
        cfg.transport = TransportConfig {
            max_connections: 4_096,
            compression: false,
            reactor_threads: 3,
            idle_timeout_ms: 12_500,
            outbox_frames: 16,
        };
        let back = from_json_str(&to_json(&cfg)).unwrap();
        assert_eq!(back.transport, cfg.transport);
        assert_eq!(cfg, back);
    }

    #[test]
    fn missing_transport_section_defaults() {
        // configs written before the transport axis existed still load
        let text = to_json(&quickstart());
        let stripped = {
            let v = Value::parse(&text).unwrap();
            let keep: Vec<(&str, Value)> = [
                "name",
                "seed",
                "data",
                "cluster",
                "train",
                "aggregation",
                "selection",
            ]
            .iter()
            .map(|k| (*k, v.req(k).unwrap().clone()))
            .collect();
            json::obj(keep).to_string()
        };
        let cfg = from_json_str(&stripped).unwrap();
        assert_eq!(cfg.transport, TransportConfig::default());
        assert!(cfg.transport.compression);
        assert_eq!(cfg.transport.max_connections, 10_240);
    }

    #[test]
    fn partial_transport_section_fills_defaults() {
        // an operator overriding one knob keeps the rest at defaults:
        // parse the full config, swap in a one-field transport section
        let v = Value::parse(&to_json(&quickstart())).unwrap();
        let mut fields: Vec<(&str, Value)> = Vec::new();
        for (k, val) in v.as_obj().unwrap() {
            if k == "transport" {
                fields.push((
                    "transport",
                    json::obj(vec![("compression", Value::Bool(false))]),
                ));
            } else {
                fields.push((k.as_str(), val.clone()));
            }
        }
        let cfg = from_json_str(&json::obj(fields).to_string()).unwrap();
        assert!(!cfg.transport.compression);
        assert_eq!(
            cfg.transport.max_connections,
            TransportConfig::default().max_connections
        );
        assert_eq!(
            cfg.transport.outbox_frames,
            TransportConfig::default().outbox_frames
        );
    }

    #[test]
    fn roundtrip_hierarchy_section() {
        for grouping in [
            GroupingPolicy::Flat,
            GroupingPolicy::Site { sites: 2 },
            GroupingPolicy::Zone,
        ] {
            let mut cfg = quickstart();
            cfg.hierarchy.grouping = grouping;
            let back = from_json_str(&to_json(&cfg)).unwrap();
            assert_eq!(back.hierarchy.grouping, grouping);
            assert_eq!(cfg, back);
        }
    }

    #[test]
    fn missing_hierarchy_section_defaults_to_flat() {
        // configs written before the hierarchy axis existed still load
        let text = to_json(&quickstart());
        let stripped = {
            let v = Value::parse(&text).unwrap();
            let keep: Vec<(&str, Value)> = [
                "name",
                "seed",
                "data",
                "cluster",
                "train",
                "aggregation",
                "selection",
            ]
            .iter()
            .map(|k| (*k, v.req(k).unwrap().clone()))
            .collect();
            json::obj(keep).to_string()
        };
        let cfg = from_json_str(&stripped).unwrap();
        assert_eq!(cfg.hierarchy, HierarchyConfig::default());
        assert!(!cfg.hierarchy.enabled());
    }

    #[test]
    fn unknown_grouping_policy_errors() {
        let mut cfg = quickstart();
        cfg.hierarchy.grouping = GroupingPolicy::Zone;
        let text = to_json(&cfg).replace("\"zone\"", "\"region:3\"");
        let err = from_json_str(&text).unwrap_err();
        assert!(
            format!("{err:#}").contains("unknown grouping policy 'region'"),
            "got: {err:#}"
        );
    }

    #[test]
    fn missing_required_field_errors() {
        assert!(from_json_str(r#"{"seed": 1}"#).is_err());
    }

    #[test]
    fn invalid_config_rejected_on_load() {
        let mut cfg = quickstart();
        cfg.selection.clients_per_round = 0;
        // to_json happily writes it; from_json_str must refuse it
        assert!(from_json_str(&to_json(&cfg)).is_err());
    }
}
