//! Canonical experiment presets.

use super::*;

/// Named presets exposed on the CLI (`fedhpc train --preset ...`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preset {
    /// Small, fast sanity run (8 clients, 10 rounds, MLP).
    Quickstart,
    /// The paper's hybrid testbed (§5.1): 30 cloud VMs + 30 HPC nodes,
    /// 20 clients/round, 100 rounds, 5 local epochs.
    PaperTestbed,
}

impl Preset {
    pub fn parse(s: &str) -> Option<Preset> {
        match s {
            "quickstart" => Some(Preset::Quickstart),
            "paper" | "paper_testbed" => Some(Preset::PaperTestbed),
            _ => None,
        }
    }

    pub fn build(self) -> ExperimentConfig {
        match self {
            Preset::Quickstart => quickstart(),
            Preset::PaperTestbed => paper_testbed(),
        }
    }
}

/// Small, fast sanity configuration used by `examples/quickstart.rs`
/// and most integration tests.
pub fn quickstart() -> ExperimentConfig {
    ExperimentConfig {
        name: "quickstart".into(),
        seed: 42,
        data: DataConfig {
            dataset: "medmnist_mlp".into(),
            partition: Partition::LabelShard {
                classes_per_client: 3,
            },
            samples_per_client: 256,
            eval_samples: 512,
        },
        cluster: ClusterConfig {
            // a small heterogeneous mix: 4 cloud (1 spot) + 4 HPC
            nodes: vec![
                ("p3.2xlarge".into(), 2),
                ("t3.large".into(), 2),
                ("hpc-rtx6000".into(), 2),
                ("hpc-cpu".into(), 2),
            ],
            cloud_backend: "inproc".into(),
            hpc_backend: "inproc".into(),
        },
        train: TrainConfig {
            local_epochs: 2,
            lr: 0.05,
            rounds: 10,
            ..TrainConfig::default()
        },
        aggregation: Aggregation::FedAvg,
        // tests and examples want the serial reference path unless a
        // run opts in; 1 builds no pool at all
        ingest_threads: 1,
        server_opt: ServerOptKind::Sgd,
        round_mode: RoundMode::Sync,
        selection: SelectionConfig {
            policy: SelectionPolicy::default(),
            planner: None,
            clients_per_round: 4,
        },
        straggler: StragglerConfig::default(),
        compression: CompressionConfig::NONE,
        faults: FaultConfig::default(),
        artifacts_dir: "artifacts".into(),
        mock_runtime: false,
        telemetry: TelemetryConfig::default(),
        transport: TransportConfig::default(),
        hierarchy: HierarchyConfig::default(),
    }
}

/// The paper's experimental setup (§5.1): a hybrid cluster of 30 AWS
/// EC2 VMs (GPU p3.2xlarge + CPU t3.large) and 30 SLURM-managed HPC
/// nodes (Quadro RTX 6000 + CPU-only), 20 clients selected per round,
/// 100 rounds, 5 local epochs.
pub fn paper_testbed() -> ExperimentConfig {
    ExperimentConfig {
        name: "paper_testbed".into(),
        seed: 7,
        data: DataConfig {
            dataset: "cifar_cnn".into(),
            partition: Partition::LabelShard {
                classes_per_client: 2,
            },
            samples_per_client: 512,
            eval_samples: 1024,
        },
        cluster: ClusterConfig {
            nodes: vec![
                // 30 cloud VMs: mixed GPU/CPU, some spot
                ("p3.2xlarge".into(), 10),
                ("p3.2xlarge-spot".into(), 5),
                ("t3.large".into(), 15),
                // 30 HPC nodes: SLURM partition
                ("hpc-rtx6000".into(), 20),
                ("hpc-cpu".into(), 10),
            ],
            cloud_backend: "grpc".into(),
            hpc_backend: "mpi".into(),
        },
        train: TrainConfig {
            local_epochs: 5,
            lr: 0.02,
            rounds: 100,
            ..TrainConfig::default()
        },
        aggregation: Aggregation::FedProx { mu: 0.01 },
        // the paper testbed ingests 20 clients/round of full-size
        // models — let the pool size itself to the host
        ingest_threads: 0,
        server_opt: ServerOptKind::Sgd,
        round_mode: RoundMode::Sync,
        selection: SelectionConfig {
            policy: SelectionPolicy::default(),
            planner: None,
            clients_per_round: 20,
        },
        straggler: StragglerConfig {
            deadline_ms: Some(120_000),
            partial_k: Some(16),
        },
        compression: CompressionConfig::PAPER,
        faults: FaultConfig::default(),
        artifacts_dir: "artifacts".into(),
        mock_runtime: false,
        telemetry: TelemetryConfig::default(),
        transport: TransportConfig::default(),
        hierarchy: HierarchyConfig::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        super::super::validate(&quickstart()).unwrap();
        super::super::validate(&paper_testbed()).unwrap();
    }

    #[test]
    fn paper_testbed_matches_section_5_1() {
        let c = paper_testbed();
        assert_eq!(c.cluster.total_nodes(), 60);
        assert_eq!(c.selection.clients_per_round, 20);
        assert_eq!(c.train.rounds, 100);
        assert_eq!(c.train.local_epochs, 5);
    }

    #[test]
    fn preset_parse() {
        assert_eq!(Preset::parse("quickstart"), Some(Preset::Quickstart));
        assert_eq!(Preset::parse("paper"), Some(Preset::PaperTestbed));
        assert_eq!(Preset::parse("nope"), None);
    }
}
