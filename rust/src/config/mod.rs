//! Configuration system.
//!
//! All tunables of the framework live in one tree of plain-data structs
//! ([`ExperimentConfig`] at the root) so that every experiment is fully
//! described by one value: CLI flags, JSON config files and the presets
//! below all construct the same thing. Modules consume their slice of
//! the tree (e.g. `orchestrator` reads [`SelectionConfig`]).

pub mod loader;
pub mod presets;
pub mod validate;

pub use loader::{from_json_file, from_json_str, to_json};
pub use presets::{paper_testbed, quickstart, Preset};
pub use validate::validate;

use anyhow::{bail, Result};

/// Default strategy / server-optimizer parameters — the single source
/// both the name parser ([`Aggregation::parse`] /
/// [`ServerOptKind::parse`]) and the JSON loader draw from, so the CLI
/// path and the config-file path can never drift apart.
pub mod defaults {
    pub const FEDPROX_MU: f32 = 0.01;
    pub const TRIM_FRAC: f32 = 0.1;
    pub const FEDAVGM_BETA: f32 = 0.9;
    pub const FEDADAM_LR: f32 = 0.1;
    pub const FEDADAM_BETA1: f32 = 0.9;
    pub const FEDADAM_BETA2: f32 = 0.99;
    pub const FEDADAM_EPS: f32 = 1e-3;
    pub const ASYNC_BUFFER_K: usize = 10;
    pub const ASYNC_ALPHA: f32 = 0.5;
    pub const ASYNC_MAX_STALENESS: u32 = 20;
}

/// Staleness discount applied to an update that trained on a model
/// `s` commits behind the current one (buffered-async mode, FedBuff /
/// Xie et al.). Selected by registry name: `"poly"` / `"poly:0.5"`
/// (α), `"uniform"`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StalenessFn {
    /// `1 / (1 + s)^α` — the FedBuff polynomial discount.
    Polynomial { alpha: f32 },
    /// No discount (every update weighs as if fresh).
    Uniform,
}

impl StalenessFn {
    pub const KINDS: &'static [&'static str] = &["poly", "uniform"];

    /// Largest accepted polynomial α. Keeps `(1+s)^α` finite for every
    /// `s: u32` (`(2^32)^30 < f64::MAX`), so the discount can never
    /// collapse to exactly 0 and zero out a whole commit's weight.
    pub const MAX_ALPHA: f32 = 30.0;

    /// The multiplicative weight discount for staleness `s` (s = 0 for
    /// a fresh update). Always finite and in (0, 1] — the positive
    /// floor is belt-and-braces; [`StalenessFn::check_params`] bounds α
    /// so the power cannot overflow in the first place.
    pub fn discount(&self, s: u32) -> f64 {
        match *self {
            StalenessFn::Polynomial { alpha } => {
                (1.0 / (1.0 + s as f64).powf(alpha as f64)).max(f64::MIN_POSITIVE)
            }
            StalenessFn::Uniform => 1.0,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            StalenessFn::Polynomial { .. } => "poly",
            StalenessFn::Uniform => "uniform",
        }
    }

    /// The `"name[:param]"` spec that parses back to this value.
    pub fn spec(&self) -> String {
        match *self {
            StalenessFn::Polynomial { alpha } => format!("poly:{alpha}"),
            StalenessFn::Uniform => "uniform".into(),
        }
    }

    /// Parse by registry name: `"poly"` / `"poly:0.5"` (α),
    /// `"uniform"`.
    pub fn parse(spec: &str) -> Result<StalenessFn> {
        let (kind, arg) = match spec.split_once(':') {
            Some((k, a)) => (k, Some(a)),
            None => (spec, None),
        };
        let f = match kind {
            "poly" => {
                let alpha = match arg {
                    None => defaults::ASYNC_ALPHA,
                    Some(a) => a
                        .parse::<f32>()
                        .map_err(|_| anyhow::anyhow!("staleness 'poly': bad parameter '{a}'"))?,
                };
                StalenessFn::Polynomial { alpha }
            }
            "uniform" => {
                if let Some(a) = arg {
                    bail!("staleness 'uniform' takes no parameter (got '{a}')");
                }
                StalenessFn::Uniform
            }
            k => bail!(
                "unknown staleness fn '{k}' (known: {})",
                StalenessFn::KINDS.join(", ")
            ),
        };
        f.check_params()?;
        Ok(f)
    }

    pub fn check_params(&self) -> Result<()> {
        if let StalenessFn::Polynomial { alpha } = *self {
            if alpha.is_nan() || !(0.0..=Self::MAX_ALPHA).contains(&alpha) {
                bail!(
                    "config: staleness poly alpha must be in [0, {}], got {alpha}",
                    Self::MAX_ALPHA
                );
            }
        }
        Ok(())
    }
}

/// Round execution semantics: how the orchestrator turns client
/// updates into model commits.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum RoundMode {
    /// Synchronous rounds (Algorithm 1): broadcast, collect under the
    /// deadline / partial-k rule, aggregate, commit. The default.
    #[default]
    Sync,
    /// Buffered asynchronous aggregation (FedBuff, Nguyen et al.): the
    /// server folds updates as they arrive regardless of round tag,
    /// discounts each by its staleness (`staleness.discount(s)` where
    /// `s = current model version − the update's base version`), and
    /// commits a model version every `buffer_k` folds. Updates staler
    /// than `max_staleness` are discarded. Stragglers are absorbed as
    /// stale-but-useful contributions instead of being dropped at a
    /// deadline.
    BufferedAsync {
        /// Folds per commit (FedBuff's K).
        buffer_k: usize,
        /// Discard updates with staleness beyond this.
        max_staleness: u32,
        /// Staleness discount function.
        staleness: StalenessFn,
    },
}

impl RoundMode {
    /// Registry names accepted by [`RoundMode::parse`] (and by config
    /// files as `round_mode.kind`).
    pub const KINDS: &'static [&'static str] = &["sync", "async_fedbuff"];

    pub fn name(&self) -> &'static str {
        match self {
            RoundMode::Sync => "sync",
            RoundMode::BufferedAsync { .. } => "async_fedbuff",
        }
    }

    pub fn is_async(&self) -> bool {
        matches!(self, RoundMode::BufferedAsync { .. })
    }

    /// Parse a round mode by registry name with optional `:`-suffixed
    /// parameters: `"sync"`,
    /// `"async_fedbuff[:buffer_k[:alpha[:max_staleness]]]"` — e.g.
    /// `"async_fedbuff:10:0.5"` commits every 10 folds with the
    /// `1/(1+s)^0.5` polynomial discount. Unknown names and
    /// out-of-range parameters are errors, never a panic.
    pub fn parse(spec: &str) -> Result<RoundMode> {
        let mut parts = spec.split(':');
        let kind = parts.next().unwrap_or("");
        let mode = match kind {
            "sync" => {
                if let Some(a) = parts.next() {
                    bail!("round mode 'sync' takes no parameter (got '{a}')");
                }
                RoundMode::Sync
            }
            "async_fedbuff" => {
                let buffer_k = match parts.next() {
                    None | Some("") => defaults::ASYNC_BUFFER_K,
                    Some(a) => a.parse::<usize>().map_err(|_| {
                        anyhow::anyhow!("round mode 'async_fedbuff': bad buffer_k '{a}'")
                    })?,
                };
                let alpha = match parts.next() {
                    None => defaults::ASYNC_ALPHA,
                    Some(a) => a.parse::<f32>().map_err(|_| {
                        anyhow::anyhow!("round mode 'async_fedbuff': bad alpha '{a}'")
                    })?,
                };
                let max_staleness = match parts.next() {
                    None => defaults::ASYNC_MAX_STALENESS,
                    Some(a) => a.parse::<u32>().map_err(|_| {
                        anyhow::anyhow!("round mode 'async_fedbuff': bad max_staleness '{a}'")
                    })?,
                };
                if let Some(extra) = parts.next() {
                    bail!("round mode 'async_fedbuff': stray parameter '{extra}'");
                }
                RoundMode::BufferedAsync {
                    buffer_k,
                    max_staleness,
                    staleness: StalenessFn::Polynomial { alpha },
                }
            }
            k => bail!(
                "unknown round mode '{k}' (known: {})",
                RoundMode::KINDS.join(", ")
            ),
        };
        mode.check_params()?;
        Ok(mode)
    }

    /// Range checks — shared by [`RoundMode::parse`] and [`validate`].
    pub fn check_params(&self) -> Result<()> {
        if let RoundMode::BufferedAsync {
            buffer_k,
            staleness,
            ..
        } = self
        {
            if *buffer_k == 0 {
                bail!("config: async buffer_k must be >= 1");
            }
            staleness.check_params()?;
        }
        Ok(())
    }
}

/// Aggregation strategy (paper §4.4, Table 1). Each variant maps 1:1 to
/// an [`crate::orchestrator::strategy::AggStrategy`] implementation via
/// the strategy registry; [`Aggregation::parse`] is the name-keyed axis
/// the CLI, examples and config files share.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Aggregation {
    /// FedAvg: data-size-weighted mean of client models (McMahan et al.).
    FedAvg,
    /// FedProx: FedAvg server-side + proximal term μ in the client
    /// objective (Li et al.). μ is shipped to clients each round.
    FedProx { mu: f32 },
    /// Weighted aggregation with a dynamic weighting scheme.
    Weighted(WeightScheme),
    /// Coordinate-wise trimmed mean (Yin et al.): per parameter, drop
    /// the `trim_frac` fraction of largest and smallest client values
    /// and average the rest. Robust to poisoned/faulty clients; runs in
    /// the orchestrator's buffered mode (order statistic).
    TrimmedMean { trim_frac: f32 },
    /// Coordinate-wise median: maximally robust order statistic,
    /// ignores sample-count weighting entirely. Buffered mode.
    CoordinateMedian,
}

impl Aggregation {
    /// Registry names accepted by [`Aggregation::parse`] (and by config
    /// files as `aggregation.kind`).
    pub const KINDS: &'static [&'static str] = &[
        "fedavg",
        "fedprox",
        "weighted",
        "trimmed_mean",
        "coordinate_median",
    ];

    /// The proximal coefficient clients should train with.
    pub fn mu(&self) -> f32 {
        match self {
            Aggregation::FedProx { mu } => *mu,
            _ => 0.0,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Aggregation::FedAvg => "fedavg",
            Aggregation::FedProx { .. } => "fedprox",
            Aggregation::Weighted(_) => "weighted",
            Aggregation::TrimmedMean { .. } => "trimmed_mean",
            Aggregation::CoordinateMedian => "coordinate_median",
        }
    }

    /// Parse a strategy by registry name, with an optional `:`-suffixed
    /// parameter: `"fedavg"`, `"fedprox"` / `"fedprox:0.1"` (μ),
    /// `"weighted:inverse_loss"` (scheme, default `data_size`),
    /// `"trimmed_mean"` / `"trimmed_mean:0.2"` (trim fraction),
    /// `"coordinate_median"`. Unknown names, out-of-range parameters
    /// and stray parameters on parameterless kinds are errors, never a
    /// panic — config loading and the CLI funnel through here.
    pub fn parse(spec: &str) -> Result<Aggregation> {
        let (kind, arg) = match spec.split_once(':') {
            Some((k, a)) => (k, Some(a)),
            None => (spec, None),
        };
        let num = |default: f32| -> Result<f32> {
            match arg {
                None => Ok(default),
                Some(a) => match a.parse::<f32>() {
                    Ok(v) => Ok(v),
                    Err(_) => bail!("aggregation '{kind}': bad parameter '{a}'"),
                },
            }
        };
        let no_arg = || -> Result<()> {
            match arg {
                None => Ok(()),
                Some(a) => bail!("aggregation '{kind}' takes no parameter (got '{a}')"),
            }
        };
        let agg = match kind {
            "fedavg" => {
                no_arg()?;
                Aggregation::FedAvg
            }
            "fedprox" => Aggregation::FedProx {
                mu: num(defaults::FEDPROX_MU)?,
            },
            "weighted" => Aggregation::Weighted(match arg {
                None => WeightScheme::DataSize,
                Some(s) => WeightScheme::parse(s)?,
            }),
            "trimmed_mean" => Aggregation::TrimmedMean {
                trim_frac: num(defaults::TRIM_FRAC)?,
            },
            "coordinate_median" => {
                no_arg()?;
                Aggregation::CoordinateMedian
            }
            k => bail!(
                "unknown aggregation kind '{k}' (known: {})",
                Aggregation::KINDS.join(", ")
            ),
        };
        agg.check_params()?;
        Ok(agg)
    }

    /// Range checks for variant parameters — shared by
    /// [`Aggregation::parse`] (so the by-name/CLI path rejects what a
    /// config file would) and by [`validate`].
    pub fn check_params(&self) -> Result<()> {
        match *self {
            Aggregation::FedProx { mu } => {
                if mu.is_nan() || mu < 0.0 {
                    bail!("config: fedprox mu must be >= 0, got {mu}");
                }
            }
            Aggregation::TrimmedMean { trim_frac } => {
                if trim_frac.is_nan() || trim_frac <= 0.0 || trim_frac >= 0.5 {
                    bail!("config: trimmed_mean trim_frac must be in (0, 0.5), got {trim_frac}");
                }
            }
            _ => {}
        }
        Ok(())
    }
}

/// Dynamic client-update weighting (paper §4.4: "local data size,
/// training loss, or gradient variance").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightScheme {
    /// ∝ n_c (identical to FedAvg weighting).
    DataSize,
    /// ∝ n_c / (1 + loss_c): down-weights clients that fit poorly.
    InverseLoss,
    /// ∝ n_c / (1 + Var(Δ_c)): down-weights noisy updates.
    InverseVariance,
}

impl WeightScheme {
    /// Parseable scheme names, in `parse` order (registry-completeness
    /// contract: every arm here, in `fedhpc list`, and in README).
    pub const KINDS: &'static [&'static str] = &["data_size", "inverse_loss", "inverse_variance"];

    pub fn name(&self) -> &'static str {
        match self {
            WeightScheme::DataSize => "data_size",
            WeightScheme::InverseLoss => "inverse_loss",
            WeightScheme::InverseVariance => "inverse_variance",
        }
    }

    pub fn parse(name: &str) -> Result<WeightScheme> {
        Ok(match name {
            "data_size" => WeightScheme::DataSize,
            "inverse_loss" => WeightScheme::InverseLoss,
            "inverse_variance" => WeightScheme::InverseVariance,
            s => bail!("unknown weight scheme '{s}'"),
        })
    }
}

/// Server-side optimizer applied when a round finalizes (FedOpt family,
/// Reddi et al.): `M_{r+1} = opt(M_r, Δ_agg)`. Optimizer state
/// (momentum, second moments) lives on the orchestrator and carries
/// across rounds.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum ServerOptKind {
    /// Plain server step: `M_{r+1} = M_r + Δ_agg` (the classic FedAvg
    /// server, and the default).
    #[default]
    Sgd,
    /// Server momentum (FedAvgM, Hsu et al.):
    /// `v ← β·v + Δ_agg; M ← M + v`.
    FedAvgM { beta: f32 },
    /// Server Adam (FedAdam, Reddi et al.) with bias correction.
    FedAdam {
        lr: f32,
        beta1: f32,
        beta2: f32,
        eps: f32,
    },
}

impl ServerOptKind {
    /// Registry names accepted by [`ServerOptKind::parse`] (and by
    /// config files as `server_opt.kind`).
    pub const KINDS: &'static [&'static str] = &["sgd", "fedavgm", "fedadam"];

    pub fn name(&self) -> &'static str {
        match self {
            ServerOptKind::Sgd => "sgd",
            ServerOptKind::FedAvgM { .. } => "fedavgm",
            ServerOptKind::FedAdam { .. } => "fedadam",
        }
    }

    /// Parse a server optimizer by registry name with an optional
    /// `:`-suffixed parameter: `"sgd"`, `"fedavgm"` / `"fedavgm:0.9"`
    /// (β), `"fedadam"` / `"fedadam:0.05"` (server lr). Unknown names,
    /// out-of-range parameters and stray parameters on parameterless
    /// kinds are errors.
    pub fn parse(spec: &str) -> Result<ServerOptKind> {
        let (kind, arg) = match spec.split_once(':') {
            Some((k, a)) => (k, Some(a)),
            None => (spec, None),
        };
        let num = |default: f32| -> Result<f32> {
            match arg {
                None => Ok(default),
                Some(a) => match a.parse::<f32>() {
                    Ok(v) => Ok(v),
                    Err(_) => bail!("server_opt '{kind}': bad parameter '{a}'"),
                },
            }
        };
        let opt = match kind {
            "sgd" | "none" => {
                if let Some(a) = arg {
                    bail!("server_opt '{kind}' takes no parameter (got '{a}')");
                }
                ServerOptKind::Sgd
            }
            "fedavgm" => ServerOptKind::FedAvgM {
                beta: num(defaults::FEDAVGM_BETA)?,
            },
            "fedadam" => ServerOptKind::FedAdam {
                lr: num(defaults::FEDADAM_LR)?,
                beta1: defaults::FEDADAM_BETA1,
                beta2: defaults::FEDADAM_BETA2,
                eps: defaults::FEDADAM_EPS,
            },
            k => bail!(
                "unknown server_opt kind '{k}' (known: {})",
                ServerOptKind::KINDS.join(", ")
            ),
        };
        opt.check_params()?;
        Ok(opt)
    }

    /// Range checks for variant parameters — shared by
    /// [`ServerOptKind::parse`] and [`validate`].
    pub fn check_params(&self) -> Result<()> {
        match *self {
            ServerOptKind::Sgd => {}
            ServerOptKind::FedAvgM { beta } => {
                if beta.is_nan() || !(0.0..1.0).contains(&beta) {
                    bail!("config: fedavgm beta must be in [0, 1), got {beta}");
                }
            }
            ServerOptKind::FedAdam {
                lr,
                beta1,
                beta2,
                eps,
            } => {
                if lr.is_nan() || lr <= 0.0 {
                    bail!("config: fedadam lr must be positive, got {lr}");
                }
                for (name, b) in [("beta1", beta1), ("beta2", beta2)] {
                    if b.is_nan() || !(0.0..1.0).contains(&b) {
                        bail!("config: fedadam {name} must be in [0, 1), got {b}");
                    }
                }
                if eps.is_nan() || eps <= 0.0 {
                    bail!("config: fedadam eps must be positive, got {eps}");
                }
            }
        }
        Ok(())
    }
}

/// Cohort planner (paper §4.1 resource-aware scheduling). Each variant
/// maps 1:1 to a [`crate::orchestrator::planner::CohortPlanner`]
/// implementation via the planner registry; [`PlannerKind::parse`] is
/// the name-keyed axis the CLI (`--planner`), config files
/// (`selection.planner`) and benches share. `random` / `adaptive`
/// reproduce the historical [`SelectionPolicy`] cohorts bit-identically
/// for the same seed; `tiered` / `deadline` additionally vary the
/// per-client [`crate::orchestrator::planner::DispatchPlan`]
/// (deadline, local epochs, compression) by observed heterogeneity.
#[derive(Debug, Clone, PartialEq)]
pub enum PlannerKind {
    /// Uniform random cohort, identical dispatch for everyone.
    Random,
    /// Score-based exploitation + exploration floor + straggler
    /// benching (the historical adaptive policy).
    Adaptive {
        explore_frac: f64,
        exclude_factor: f64,
    },
    /// Bucket the cohort into `tiers` tiers by EWMA round time; slower
    /// tiers get proportionally fewer local epochs and a sparser
    /// top-k uplink hint so they make the round deadline.
    Tiered { tiers: usize },
    /// Fit each client's local-epoch budget to a target round deadline
    /// from its profiled round-time estimate and link bandwidth.
    /// `None` targets the config's `straggler.deadline_ms`.
    Deadline { target_ms: Option<u64> },
}

impl PlannerKind {
    /// Registry names accepted by [`PlannerKind::parse`] (and by config
    /// files as `selection.planner`).
    pub const KINDS: &'static [&'static str] = &["random", "adaptive", "tiered", "deadline"];

    /// Most tiers a tiered planner may use (more would leave sub-client
    /// buckets at any realistic cohort size).
    pub const MAX_TIERS: usize = 64;

    pub fn name(&self) -> &'static str {
        match self {
            PlannerKind::Random => "random",
            PlannerKind::Adaptive { .. } => "adaptive",
            PlannerKind::Tiered { .. } => "tiered",
            PlannerKind::Deadline { .. } => "deadline",
        }
    }

    /// The `"name[:params]"` spec that parses back to this value.
    pub fn spec(&self) -> String {
        match *self {
            PlannerKind::Random => "random".into(),
            PlannerKind::Adaptive {
                explore_frac,
                exclude_factor,
            } => format!("adaptive:{explore_frac}:{exclude_factor}"),
            PlannerKind::Tiered { tiers } => format!("tiered:{tiers}"),
            PlannerKind::Deadline { target_ms: None } => "deadline".into(),
            PlannerKind::Deadline {
                target_ms: Some(ms),
            } => format!("deadline:{ms}"),
        }
    }

    /// The planner a legacy [`SelectionPolicy`] maps to — the
    /// back-compat bridge for configs that only set `policy`.
    pub fn from_policy(policy: SelectionPolicy) -> PlannerKind {
        match policy {
            SelectionPolicy::Random => PlannerKind::Random,
            SelectionPolicy::Adaptive {
                explore_frac,
                exclude_factor,
            } => PlannerKind::Adaptive {
                explore_frac,
                exclude_factor,
            },
        }
    }

    /// Parse a planner by registry name with optional `:`-suffixed
    /// parameters: `"random"`, `"adaptive[:explore[:exclude]]"`,
    /// `"tiered[:n]"`, `"deadline[:ms]"`. Unknown names, out-of-range
    /// parameters and stray parameters are errors, never a panic.
    pub fn parse(spec: &str) -> Result<PlannerKind> {
        let mut parts = spec.split(':');
        let kind = parts.next().unwrap_or("");
        let planner = match kind {
            "random" => {
                if let Some(a) = parts.next() {
                    bail!("planner 'random' takes no parameter (got '{a}')");
                }
                PlannerKind::Random
            }
            "adaptive" => {
                let explore_frac = match parts.next() {
                    None | Some("") => 0.2,
                    Some(a) => a.parse::<f64>().map_err(|_| {
                        anyhow::anyhow!("planner 'adaptive': bad explore_frac '{a}'")
                    })?,
                };
                let exclude_factor = match parts.next() {
                    None => 2.5,
                    Some(a) => a.parse::<f64>().map_err(|_| {
                        anyhow::anyhow!("planner 'adaptive': bad exclude_factor '{a}'")
                    })?,
                };
                if let Some(extra) = parts.next() {
                    bail!("planner 'adaptive': stray parameter '{extra}'");
                }
                PlannerKind::Adaptive {
                    explore_frac,
                    exclude_factor,
                }
            }
            "tiered" => {
                let tiers = match parts.next() {
                    None | Some("") => 4,
                    Some(a) => a
                        .parse::<usize>()
                        .map_err(|_| anyhow::anyhow!("planner 'tiered': bad tier count '{a}'"))?,
                };
                if let Some(extra) = parts.next() {
                    bail!("planner 'tiered': stray parameter '{extra}'");
                }
                PlannerKind::Tiered { tiers }
            }
            "deadline" => {
                let target_ms = match parts.next() {
                    None | Some("") => None,
                    Some(a) => Some(a.parse::<u64>().map_err(|_| {
                        anyhow::anyhow!("planner 'deadline': bad target_ms '{a}'")
                    })?),
                };
                if let Some(extra) = parts.next() {
                    bail!("planner 'deadline': stray parameter '{extra}'");
                }
                PlannerKind::Deadline { target_ms }
            }
            k => bail!(
                "unknown planner '{k}' (known: {})",
                PlannerKind::KINDS.join(", ")
            ),
        };
        planner.check_params()?;
        Ok(planner)
    }

    /// Range checks — shared by [`PlannerKind::parse`] and [`validate`].
    pub fn check_params(&self) -> Result<()> {
        match *self {
            PlannerKind::Random => {}
            PlannerKind::Adaptive {
                explore_frac,
                exclude_factor,
            } => {
                if explore_frac.is_nan() || !(0.0..=1.0).contains(&explore_frac) {
                    bail!("config: planner explore_frac must be in [0,1], got {explore_frac}");
                }
                if exclude_factor.is_nan() || exclude_factor <= 1.0 {
                    bail!("config: planner exclude_factor must be > 1, got {exclude_factor}");
                }
            }
            PlannerKind::Tiered { tiers } => {
                if !(2..=Self::MAX_TIERS).contains(&tiers) {
                    bail!(
                        "config: planner tiered tiers must be in [2, {}], got {tiers}",
                        Self::MAX_TIERS
                    );
                }
            }
            PlannerKind::Deadline { target_ms } => {
                if target_ms == Some(0) {
                    bail!("config: planner deadline target_ms must be positive");
                }
            }
        }
        Ok(())
    }
}

/// Client-selection policy (paper §4.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SelectionPolicy {
    /// Uniform random among available clients (the paper's baseline and
    /// the ablation arm of E5).
    Random,
    /// Adaptive: score = capability × reliability × bandwidth with an
    /// exploration floor; slow/unreliable nodes are temporarily excluded.
    Adaptive {
        /// Fraction of each round's slots reserved for uniform
        /// exploration so profiles stay fresh (0.0–1.0).
        explore_frac: f64,
        /// Clients whose EWMA round time exceeds `exclude_factor` ×
        /// median are benched for a cool-down period.
        exclude_factor: f64,
    },
}

impl Default for SelectionPolicy {
    fn default() -> Self {
        SelectionPolicy::Adaptive {
            explore_frac: 0.2,
            exclude_factor: 2.5,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct SelectionConfig {
    pub policy: SelectionPolicy,
    /// Cohort planner override by registry kind. `None` (the default,
    /// and what pre-planner configs load as) derives the planner from
    /// `policy`, so existing configs and tests keep their exact
    /// behavior; `Some(..)` selects a heterogeneity-aware planner
    /// (`tiered`, `deadline`, …) regardless of `policy`.
    pub planner: Option<PlannerKind>,
    /// Clients sampled per round (paper §5.1: 20).
    pub clients_per_round: usize,
}

impl SelectionConfig {
    /// The planner this config resolves to: the explicit `planner`
    /// field when set, else the [`PlannerKind`] equivalent of `policy`.
    pub fn planner_kind(&self) -> PlannerKind {
        self.planner
            .clone()
            .unwrap_or_else(|| PlannerKind::from_policy(self.policy))
    }
}

/// Straggler mitigation (paper §4.2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StragglerConfig {
    /// Round deadline; late clients are skipped (deadline-based cutoff).
    /// `None` disables the cutoff (ablation E7).
    pub deadline_ms: Option<u64>,
    /// Aggregate after the fastest k updates (partial aggregation).
    /// `None` waits for all selected clients (minus deadline misses).
    pub partial_k: Option<usize>,
}

impl Default for StragglerConfig {
    fn default() -> Self {
        StragglerConfig {
            deadline_ms: Some(60_000),
            partial_k: None,
        }
    }
}

/// Update compression pipeline (paper §4.3, Table 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompressionConfig {
    /// Quantization bit-width for values (32 = off, 16, 8).
    pub quant_bits: u8,
    /// Keep only the top-k fraction of update entries by magnitude
    /// (1.0 = off). Applied before quantization.
    pub topk_frac: f32,
    /// Federated dropout: fraction of parameters each client trains and
    /// transmits (1.0 = off). Mask is derived from (round, client) seed.
    pub dropout_keep: f32,
}

impl CompressionConfig {
    pub const NONE: CompressionConfig = CompressionConfig {
        quant_bits: 32,
        topk_frac: 1.0,
        dropout_keep: 1.0,
    };

    /// The paper's headline configuration: 8-bit quantization + top-25%
    /// sparsification (≈65% volume reduction in Table 4).
    pub const PAPER: CompressionConfig = CompressionConfig {
        quant_bits: 8,
        topk_frac: 0.25,
        dropout_keep: 1.0,
    };

    pub fn is_none(&self) -> bool {
        self.quant_bits == 32 && self.topk_frac >= 1.0 && self.dropout_keep >= 1.0
    }
}

/// Dataset + partitioning (paper §5.2).
#[derive(Debug, Clone, PartialEq)]
pub struct DataConfig {
    /// One of: "cifar_cnn", "charlm", "medmnist_mlp", "e2e_charlm" —
    /// dataset and model are paired 1:1 as in the paper.
    pub dataset: String,
    pub partition: Partition,
    /// Training samples per client (mean; actual counts vary ±).
    pub samples_per_client: usize,
    /// Centralized held-out evaluation set size (paper §5.3).
    pub eval_samples: usize,
}

/// Non-IID partitioning strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Partition {
    Iid,
    /// Each client sees only `classes_per_client` classes (paper: 2–3).
    LabelShard { classes_per_client: usize },
    /// Dirichlet(α) class mixture per client (α→0 = extreme skew).
    Dirichlet { alpha: f64 },
}

/// Hybrid testbed composition (paper §5.1).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// (SKU name, count) pairs; SKUs come from `cluster::catalog`.
    pub nodes: Vec<(String, usize)>,
    /// Communication backend for cloud nodes ("grpc") / HPC nodes
    /// ("mpi"). In-process simulation uses "inproc" for both.
    pub cloud_backend: String,
    pub hpc_backend: String,
}

impl ClusterConfig {
    pub fn total_nodes(&self) -> usize {
        self.nodes.iter().map(|(_, c)| c).sum()
    }
}

/// Fault injection (paper §5.4 "Straggler Resilience", §3.1 fault
/// tolerance objective).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Per-round probability that a selected client drops (crash or
    /// network loss) before reporting.
    pub dropout_prob: f64,
    /// Per-round probability a *spot* node is preempted mid-training.
    pub preemption_prob: f64,
    /// Probability a client is slowed by `straggler_factor` this round.
    pub straggler_prob: f64,
    pub straggler_factor: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            dropout_prob: 0.0,
            preemption_prob: 0.0,
            straggler_prob: 0.0,
            straggler_factor: 4.0,
        }
    }
}

/// Local training hyper-parameters (paper §5.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainConfig {
    pub local_epochs: usize,
    pub lr: f32,
    /// Target rounds (paper: 100).
    pub rounds: usize,
    /// Convergence: stop when relative model delta < eps for
    /// `patience` consecutive rounds (Algorithm 1 line 13).
    pub converge_eps: f32,
    pub converge_patience: usize,
    /// Optional accuracy target for time-to-accuracy experiments.
    pub target_accuracy: Option<f64>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            local_epochs: 5,
            lr: 0.05,
            rounds: 100,
            converge_eps: 1e-5,
            converge_patience: 3,
            target_accuracy: None,
        }
    }
}

/// Operations endpoint: live /metrics exposition + control plane
/// (`telemetry::http`). `addr` is a bind address like
/// "127.0.0.1:9469"; `None` (the default) disables the listener
/// entirely — zero threads, zero sockets. The CLI flag
/// `--telemetry-addr` overrides whatever the config file says.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct TelemetryConfig {
    pub addr: Option<String>,
}

/// TCP transport tuning (`network::reactor` + `network::framing`).
/// Only consulted on the real-socket path; inproc ignores it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TransportConfig {
    /// Live-socket ceiling; connections beyond it are refused at
    /// accept. Sized for 10k-client fleets by default.
    pub max_connections: usize,
    /// Transparent whole-frame compression (negotiated per peer: only
    /// protocol-v3+ peers ever receive compressed frames; frames under
    /// 256 B are never compressed).
    pub compression: bool,
    /// Reactor sweep threads; 0 = auto (hardware parallelism, capped).
    pub reactor_threads: u32,
    /// Reap connections that never register, stall mid-frame
    /// (slowloris), or stop draining their outbox for this long.
    /// Registered-but-quiet peers are never reaped.
    pub idle_timeout_ms: u64,
    /// Bounded per-peer outbox, in frames: enqueueing onto a full
    /// outbox errors immediately (backpressure) instead of buffering
    /// without limit behind a stalled client.
    pub outbox_frames: usize,
}

impl Default for TransportConfig {
    fn default() -> Self {
        TransportConfig {
            max_connections: 10_240,
            compression: true,
            reactor_threads: 0,
            idle_timeout_ms: 30_000,
            outbox_frames: 64,
        }
    }
}

/// How nodes are grouped into aggregation sites for the hierarchical
/// plane (`orchestrator::hierarchy`). Selected by registry name:
/// `"flat"` (no aggregator tier — every client reports straight to the
/// root), `"site"` / `"site:<n>"` (n contiguous blocks of node ids),
/// `"zone"` (one site per `(sku, count)` entry of the cluster config —
/// the natural "facility" boundary of the testbed model).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GroupingPolicy {
    /// Single-server topology (the default): no aggregator tier.
    #[default]
    Flat,
    /// Partition node ids into `sites` contiguous, balanced blocks.
    Site { sites: usize },
    /// One site per cluster-config `(sku, count)` entry.
    Zone,
}

impl GroupingPolicy {
    pub const KINDS: &'static [&'static str] = &["flat", "site", "zone"];

    /// Default site count for a bare `"site"` spec.
    pub const DEFAULT_SITES: usize = 4;

    pub fn name(&self) -> &'static str {
        match self {
            GroupingPolicy::Flat => "flat",
            GroupingPolicy::Site { .. } => "site",
            GroupingPolicy::Zone => "zone",
        }
    }

    /// The `"name[:param]"` spec that parses back to this value.
    pub fn spec(&self) -> String {
        match *self {
            GroupingPolicy::Flat => "flat".into(),
            GroupingPolicy::Site { sites } => format!("site:{sites}"),
            GroupingPolicy::Zone => "zone".into(),
        }
    }

    /// Parse by registry name: `"flat"`, `"site"` / `"site:<n>"`,
    /// `"zone"`.
    pub fn parse(spec: &str) -> Result<GroupingPolicy> {
        let (kind, arg) = match spec.split_once(':') {
            Some((k, a)) => (k, Some(a)),
            None => (spec, None),
        };
        let g = match kind {
            "flat" => {
                if let Some(a) = arg {
                    bail!("grouping 'flat' takes no parameter (got '{a}')");
                }
                GroupingPolicy::Flat
            }
            "site" => {
                let sites = match arg {
                    None => Self::DEFAULT_SITES,
                    Some(a) => a
                        .parse::<usize>()
                        .map_err(|_| anyhow::anyhow!("grouping 'site': bad parameter '{a}'"))?,
                };
                GroupingPolicy::Site { sites }
            }
            "zone" => {
                if let Some(a) = arg {
                    bail!("grouping 'zone' takes no parameter (got '{a}')");
                }
                GroupingPolicy::Zone
            }
            k => bail!(
                "unknown grouping policy '{k}' (known: {})",
                GroupingPolicy::KINDS.join(", ")
            ),
        };
        g.check_params()?;
        Ok(g)
    }

    pub fn check_params(&self) -> Result<()> {
        if let GroupingPolicy::Site { sites } = *self {
            if sites == 0 {
                bail!("config: hierarchy grouping 'site' needs at least 1 site");
            }
        }
        Ok(())
    }
}

/// Hierarchical aggregation plane (`orchestrator::hierarchy`): a tier
/// of per-site aggregators that fold their clients' updates locally
/// and report one pre-aggregated update upstream, cutting
/// cross-facility traffic from O(clients) to O(sites) per round.
/// `grouping: flat` (the default) disables the tier entirely.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct HierarchyConfig {
    pub grouping: GroupingPolicy,
}

impl HierarchyConfig {
    /// Whether the aggregator tier is on at all.
    pub fn enabled(&self) -> bool {
        self.grouping != GroupingPolicy::Flat
    }
}

/// Root experiment description.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    pub seed: u64,
    pub data: DataConfig,
    pub cluster: ClusterConfig,
    pub train: TrainConfig,
    pub aggregation: Aggregation,
    /// Shard-worker threads for parallel server ingest: 0 = auto
    /// (hardware parallelism), 1 = serial reference path, N > 1 = a
    /// persistent pool of N workers folding update spans concurrently.
    /// The aggregate is bit-identical for a fixed arrival order at any
    /// setting (see `orchestrator::aggregate::ShardedAggregator`).
    pub ingest_threads: u32,
    pub server_opt: ServerOptKind,
    /// Round execution semantics (sync rounds vs buffered async).
    pub round_mode: RoundMode,
    pub selection: SelectionConfig,
    pub straggler: StragglerConfig,
    pub compression: CompressionConfig,
    pub faults: FaultConfig,
    /// Directory with AOT artifacts (HLO text + manifest.json).
    pub artifacts_dir: String,
    /// Use the pure-Rust mock runtime instead of PJRT (tests / timing
    /// sims that don't need real learning).
    pub mock_runtime: bool,
    /// Optional live-operations endpoint (off by default).
    pub telemetry: TelemetryConfig,
    /// TCP transport tuning (reactor pool, frame compression,
    /// backpressure); defaults hold a 10k-client fleet.
    pub transport: TransportConfig,
    /// Hierarchical aggregation plane: site grouping for the
    /// tree-of-aggregators topology (`flat` = single server).
    pub hierarchy: HierarchyConfig,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregation_mu() {
        assert_eq!(Aggregation::FedAvg.mu(), 0.0);
        assert_eq!(Aggregation::FedProx { mu: 0.1 }.mu(), 0.1);
        assert_eq!(Aggregation::Weighted(WeightScheme::InverseLoss).mu(), 0.0);
        assert_eq!(Aggregation::TrimmedMean { trim_frac: 0.1 }.mu(), 0.0);
    }

    #[test]
    fn aggregation_parse_known_names_and_params() {
        assert_eq!(Aggregation::parse("fedavg").unwrap(), Aggregation::FedAvg);
        assert_eq!(
            Aggregation::parse("fedprox:0.5").unwrap(),
            Aggregation::FedProx { mu: 0.5 }
        );
        assert_eq!(
            Aggregation::parse("weighted:inverse_variance").unwrap(),
            Aggregation::Weighted(WeightScheme::InverseVariance)
        );
        assert_eq!(
            Aggregation::parse("weighted").unwrap(),
            Aggregation::Weighted(WeightScheme::DataSize)
        );
        assert_eq!(
            Aggregation::parse("trimmed_mean:0.25").unwrap(),
            Aggregation::TrimmedMean { trim_frac: 0.25 }
        );
        assert_eq!(
            Aggregation::parse("coordinate_median").unwrap(),
            Aggregation::CoordinateMedian
        );
        // every registered kind parses with defaults
        for kind in Aggregation::KINDS {
            let agg = Aggregation::parse(kind).unwrap();
            assert_eq!(&agg.name(), kind);
        }
        assert!(Aggregation::parse("krum").is_err());
        assert!(Aggregation::parse("fedprox:not_a_number").is_err());
        assert!(Aggregation::parse("weighted:no_such_scheme").is_err());
        // out-of-range parameters are rejected on the by-name path too
        assert!(Aggregation::parse("trimmed_mean:0.9").is_err());
        assert!(Aggregation::parse("fedprox:-0.5").is_err());
        // parameterless kinds reject a stray parameter instead of
        // silently discarding it
        assert!(Aggregation::parse("fedavg:1").is_err());
        assert!(Aggregation::parse("coordinate_median:0.3").is_err());
    }

    #[test]
    fn grouping_parse_known_names_and_params() {
        assert_eq!(GroupingPolicy::parse("flat").unwrap(), GroupingPolicy::Flat);
        assert_eq!(
            GroupingPolicy::parse("site").unwrap(),
            GroupingPolicy::Site {
                sites: GroupingPolicy::DEFAULT_SITES
            }
        );
        assert_eq!(
            GroupingPolicy::parse("site:10").unwrap(),
            GroupingPolicy::Site { sites: 10 }
        );
        assert_eq!(GroupingPolicy::parse("zone").unwrap(), GroupingPolicy::Zone);
        // every registered kind parses with defaults and round-trips
        // through its spec
        for kind in GroupingPolicy::KINDS {
            let g = GroupingPolicy::parse(kind).unwrap();
            assert_eq!(&g.name(), kind);
            assert_eq!(GroupingPolicy::parse(&g.spec()).unwrap(), g);
        }
        assert!(GroupingPolicy::parse("region").is_err());
        assert!(GroupingPolicy::parse("site:zero").is_err());
        assert!(GroupingPolicy::parse("site:0").is_err());
        // parameterless kinds reject a stray parameter
        assert!(GroupingPolicy::parse("flat:1").is_err());
        assert!(GroupingPolicy::parse("zone:2").is_err());
    }

    #[test]
    fn hierarchy_default_is_flat_and_disabled() {
        let h = HierarchyConfig::default();
        assert_eq!(h.grouping, GroupingPolicy::Flat);
        assert!(!h.enabled());
        assert!(HierarchyConfig {
            grouping: GroupingPolicy::Zone
        }
        .enabled());
    }

    #[test]
    fn server_opt_parse_known_names_and_params() {
        assert_eq!(ServerOptKind::parse("sgd").unwrap(), ServerOptKind::Sgd);
        assert_eq!(ServerOptKind::parse("none").unwrap(), ServerOptKind::Sgd);
        assert_eq!(
            ServerOptKind::parse("fedavgm:0.5").unwrap(),
            ServerOptKind::FedAvgM { beta: 0.5 }
        );
        assert!(matches!(
            ServerOptKind::parse("fedadam:0.05").unwrap(),
            ServerOptKind::FedAdam { lr, .. } if lr == 0.05
        ));
        for kind in ServerOptKind::KINDS {
            let opt = ServerOptKind::parse(kind).unwrap();
            assert_eq!(&opt.name(), kind);
        }
        assert!(ServerOptKind::parse("lamb").is_err());
        assert!(ServerOptKind::parse("fedavgm:x").is_err());
        // out-of-range / stray parameters are rejected
        assert!(ServerOptKind::parse("fedavgm:1.5").is_err());
        assert!(ServerOptKind::parse("fedadam:0").is_err());
        assert!(ServerOptKind::parse("sgd:0.1").is_err());
    }

    #[test]
    fn round_mode_parse_known_names_and_params() {
        assert_eq!(RoundMode::parse("sync").unwrap(), RoundMode::Sync);
        assert_eq!(
            RoundMode::parse("async_fedbuff").unwrap(),
            RoundMode::BufferedAsync {
                buffer_k: defaults::ASYNC_BUFFER_K,
                max_staleness: defaults::ASYNC_MAX_STALENESS,
                staleness: StalenessFn::Polynomial {
                    alpha: defaults::ASYNC_ALPHA,
                },
            }
        );
        // the ISSUE's canonical spelling: buffer_k 10, alpha 0.5
        assert_eq!(
            RoundMode::parse("async_fedbuff:10:0.5").unwrap(),
            RoundMode::BufferedAsync {
                buffer_k: 10,
                max_staleness: defaults::ASYNC_MAX_STALENESS,
                staleness: StalenessFn::Polynomial { alpha: 0.5 },
            }
        );
        assert_eq!(
            RoundMode::parse("async_fedbuff:4:1:7").unwrap(),
            RoundMode::BufferedAsync {
                buffer_k: 4,
                max_staleness: 7,
                staleness: StalenessFn::Polynomial { alpha: 1.0 },
            }
        );
        for kind in RoundMode::KINDS {
            let m = RoundMode::parse(kind).unwrap();
            assert_eq!(&m.name(), kind);
        }
        assert!(RoundMode::parse("semi_sync").is_err());
        assert!(RoundMode::parse("sync:1").is_err());
        assert!(RoundMode::parse("async_fedbuff:0").is_err()); // k = 0
        assert!(RoundMode::parse("async_fedbuff:x").is_err());
        assert!(RoundMode::parse("async_fedbuff:4:-1").is_err()); // alpha < 0
        assert!(RoundMode::parse("async_fedbuff:4:400").is_err()); // alpha > max
        assert!(RoundMode::parse("async_fedbuff:4:1:2:9").is_err()); // stray
    }

    #[test]
    fn staleness_fn_parse_and_discount() {
        assert_eq!(
            StalenessFn::parse("poly:0.5").unwrap(),
            StalenessFn::Polynomial { alpha: 0.5 }
        );
        assert_eq!(StalenessFn::parse("uniform").unwrap(), StalenessFn::Uniform);
        assert!(StalenessFn::parse("uniform:1").is_err());
        assert!(StalenessFn::parse("linear").is_err());
        assert!(StalenessFn::parse("poly:nan_ish").is_err());
        // α is bounded so the discount can never collapse to 0 and
        // zero out a whole commit's aggregate weight
        assert!(StalenessFn::parse("poly:400").is_err());
        assert!(StalenessFn::parse("poly:inf").is_err());
        let max = StalenessFn::Polynomial {
            alpha: StalenessFn::MAX_ALPHA,
        };
        assert!(max.discount(u32::MAX) > 0.0);
        // every registered kind parses with defaults and round-trips
        // through its spec string
        for kind in StalenessFn::KINDS {
            let f = StalenessFn::parse(kind).unwrap();
            assert_eq!(&f.name(), kind);
            assert_eq!(StalenessFn::parse(&f.spec()).unwrap(), f);
        }
        // discount semantics: fresh = 1, decays polynomially, (0, 1]
        let p = StalenessFn::Polynomial { alpha: 1.0 };
        assert_eq!(p.discount(0), 1.0);
        assert_eq!(p.discount(1), 0.5);
        assert_eq!(p.discount(3), 0.25);
        let sqrt = StalenessFn::Polynomial { alpha: 0.5 };
        assert!((sqrt.discount(3) - 0.5).abs() < 1e-12);
        assert_eq!(StalenessFn::Uniform.discount(1000), 1.0);
        for s in [0u32, 1, 10, 1000] {
            let d = sqrt.discount(s);
            assert!(d > 0.0 && d <= 1.0 && d.is_finite());
        }
    }

    #[test]
    fn planner_parse_known_names_and_params() {
        assert_eq!(PlannerKind::parse("random").unwrap(), PlannerKind::Random);
        assert_eq!(
            PlannerKind::parse("adaptive:0.3:4.0").unwrap(),
            PlannerKind::Adaptive {
                explore_frac: 0.3,
                exclude_factor: 4.0,
            }
        );
        assert_eq!(
            PlannerKind::parse("adaptive").unwrap(),
            PlannerKind::Adaptive {
                explore_frac: 0.2,
                exclude_factor: 2.5,
            }
        );
        assert_eq!(
            PlannerKind::parse("tiered:3").unwrap(),
            PlannerKind::Tiered { tiers: 3 }
        );
        assert_eq!(
            PlannerKind::parse("deadline:2000").unwrap(),
            PlannerKind::Deadline {
                target_ms: Some(2000),
            }
        );
        assert_eq!(
            PlannerKind::parse("deadline").unwrap(),
            PlannerKind::Deadline { target_ms: None }
        );
        // every registered kind parses with defaults and round-trips
        // through its spec string
        for kind in PlannerKind::KINDS {
            let p = PlannerKind::parse(kind).unwrap();
            assert_eq!(&p.name(), kind);
            assert_eq!(PlannerKind::parse(&p.spec()).unwrap(), p);
        }
        assert!(PlannerKind::parse("oracle").is_err());
        assert!(PlannerKind::parse("random:1").is_err());
        assert!(PlannerKind::parse("adaptive:x").is_err());
        assert!(PlannerKind::parse("adaptive:0.2:2.5:9").is_err()); // stray
        assert!(PlannerKind::parse("adaptive:1.5").is_err()); // explore > 1
        assert!(PlannerKind::parse("adaptive:0.2:0.5").is_err()); // exclude <= 1
        assert!(PlannerKind::parse("tiered:1").is_err()); // < 2 tiers
        assert!(PlannerKind::parse("tiered:1000").is_err()); // > max
        assert!(PlannerKind::parse("deadline:0").is_err());
        assert!(PlannerKind::parse("deadline:soon").is_err());
    }

    #[test]
    fn selection_config_derives_planner_from_policy() {
        let mut sel = SelectionConfig {
            policy: SelectionPolicy::Random,
            planner: None,
            clients_per_round: 4,
        };
        assert_eq!(sel.planner_kind(), PlannerKind::Random);
        sel.policy = SelectionPolicy::Adaptive {
            explore_frac: 0.3,
            exclude_factor: 3.0,
        };
        assert_eq!(
            sel.planner_kind(),
            PlannerKind::Adaptive {
                explore_frac: 0.3,
                exclude_factor: 3.0,
            }
        );
        // explicit planner wins over the legacy policy
        sel.planner = Some(PlannerKind::Tiered { tiers: 2 });
        assert_eq!(sel.planner_kind(), PlannerKind::Tiered { tiers: 2 });
    }

    #[test]
    fn compression_none_detection() {
        assert!(CompressionConfig::NONE.is_none());
        assert!(!CompressionConfig::PAPER.is_none());
        let half = CompressionConfig {
            quant_bits: 32,
            topk_frac: 0.5,
            dropout_keep: 1.0,
        };
        assert!(!half.is_none());
    }

    #[test]
    fn cluster_total() {
        let c = ClusterConfig {
            nodes: vec![("a".into(), 3), ("b".into(), 7)],
            cloud_backend: "inproc".into(),
            hpc_backend: "inproc".into(),
        };
        assert_eq!(c.total_nodes(), 10);
    }
}
