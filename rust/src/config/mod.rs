//! Configuration system.
//!
//! All tunables of the framework live in one tree of plain-data structs
//! ([`ExperimentConfig`] at the root) so that every experiment is fully
//! described by one value: CLI flags, JSON config files and the presets
//! below all construct the same thing. Modules consume their slice of
//! the tree (e.g. `orchestrator` reads [`SelectionConfig`]).

pub mod loader;
pub mod presets;
pub mod validate;

pub use loader::{from_json_file, from_json_str, to_json};
pub use presets::{paper_testbed, quickstart, Preset};
pub use validate::validate;

/// Aggregation strategy (paper §4.4, Table 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Aggregation {
    /// FedAvg: data-size-weighted mean of client models (McMahan et al.).
    FedAvg,
    /// FedProx: FedAvg server-side + proximal term μ in the client
    /// objective (Li et al.). μ is shipped to clients each round.
    FedProx { mu: f32 },
    /// Weighted aggregation with a dynamic weighting scheme.
    Weighted(WeightScheme),
}

impl Aggregation {
    /// The proximal coefficient clients should train with.
    pub fn mu(&self) -> f32 {
        match self {
            Aggregation::FedProx { mu } => *mu,
            _ => 0.0,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Aggregation::FedAvg => "fedavg",
            Aggregation::FedProx { .. } => "fedprox",
            Aggregation::Weighted(_) => "weighted",
        }
    }
}

/// Dynamic client-update weighting (paper §4.4: "local data size,
/// training loss, or gradient variance").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightScheme {
    /// ∝ n_c (identical to FedAvg weighting).
    DataSize,
    /// ∝ n_c / (1 + loss_c): down-weights clients that fit poorly.
    InverseLoss,
    /// ∝ n_c / (1 + Var(Δ_c)): down-weights noisy updates.
    InverseVariance,
}

/// Client-selection policy (paper §4.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SelectionPolicy {
    /// Uniform random among available clients (the paper's baseline and
    /// the ablation arm of E5).
    Random,
    /// Adaptive: score = capability × reliability × bandwidth with an
    /// exploration floor; slow/unreliable nodes are temporarily excluded.
    Adaptive {
        /// Fraction of each round's slots reserved for uniform
        /// exploration so profiles stay fresh (0.0–1.0).
        explore_frac: f64,
        /// Clients whose EWMA round time exceeds `exclude_factor` ×
        /// median are benched for a cool-down period.
        exclude_factor: f64,
    },
}

impl Default for SelectionPolicy {
    fn default() -> Self {
        SelectionPolicy::Adaptive {
            explore_frac: 0.2,
            exclude_factor: 2.5,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SelectionConfig {
    pub policy: SelectionPolicy,
    /// Clients sampled per round (paper §5.1: 20).
    pub clients_per_round: usize,
}

/// Straggler mitigation (paper §4.2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StragglerConfig {
    /// Round deadline; late clients are skipped (deadline-based cutoff).
    /// `None` disables the cutoff (ablation E7).
    pub deadline_ms: Option<u64>,
    /// Aggregate after the fastest k updates (partial aggregation).
    /// `None` waits for all selected clients (minus deadline misses).
    pub partial_k: Option<usize>,
}

impl Default for StragglerConfig {
    fn default() -> Self {
        StragglerConfig {
            deadline_ms: Some(60_000),
            partial_k: None,
        }
    }
}

/// Update compression pipeline (paper §4.3, Table 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompressionConfig {
    /// Quantization bit-width for values (32 = off, 16, 8).
    pub quant_bits: u8,
    /// Keep only the top-k fraction of update entries by magnitude
    /// (1.0 = off). Applied before quantization.
    pub topk_frac: f32,
    /// Federated dropout: fraction of parameters each client trains and
    /// transmits (1.0 = off). Mask is derived from (round, client) seed.
    pub dropout_keep: f32,
}

impl CompressionConfig {
    pub const NONE: CompressionConfig = CompressionConfig {
        quant_bits: 32,
        topk_frac: 1.0,
        dropout_keep: 1.0,
    };

    /// The paper's headline configuration: 8-bit quantization + top-25%
    /// sparsification (≈65% volume reduction in Table 4).
    pub const PAPER: CompressionConfig = CompressionConfig {
        quant_bits: 8,
        topk_frac: 0.25,
        dropout_keep: 1.0,
    };

    pub fn is_none(&self) -> bool {
        self.quant_bits == 32 && self.topk_frac >= 1.0 && self.dropout_keep >= 1.0
    }
}

/// Dataset + partitioning (paper §5.2).
#[derive(Debug, Clone, PartialEq)]
pub struct DataConfig {
    /// One of: "cifar_cnn", "charlm", "medmnist_mlp", "e2e_charlm" —
    /// dataset and model are paired 1:1 as in the paper.
    pub dataset: String,
    pub partition: Partition,
    /// Training samples per client (mean; actual counts vary ±).
    pub samples_per_client: usize,
    /// Centralized held-out evaluation set size (paper §5.3).
    pub eval_samples: usize,
}

/// Non-IID partitioning strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Partition {
    Iid,
    /// Each client sees only `classes_per_client` classes (paper: 2–3).
    LabelShard { classes_per_client: usize },
    /// Dirichlet(α) class mixture per client (α→0 = extreme skew).
    Dirichlet { alpha: f64 },
}

/// Hybrid testbed composition (paper §5.1).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// (SKU name, count) pairs; SKUs come from `cluster::catalog`.
    pub nodes: Vec<(String, usize)>,
    /// Communication backend for cloud nodes ("grpc") / HPC nodes
    /// ("mpi"). In-process simulation uses "inproc" for both.
    pub cloud_backend: String,
    pub hpc_backend: String,
}

impl ClusterConfig {
    pub fn total_nodes(&self) -> usize {
        self.nodes.iter().map(|(_, c)| c).sum()
    }
}

/// Fault injection (paper §5.4 "Straggler Resilience", §3.1 fault
/// tolerance objective).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Per-round probability that a selected client drops (crash or
    /// network loss) before reporting.
    pub dropout_prob: f64,
    /// Per-round probability a *spot* node is preempted mid-training.
    pub preemption_prob: f64,
    /// Probability a client is slowed by `straggler_factor` this round.
    pub straggler_prob: f64,
    pub straggler_factor: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            dropout_prob: 0.0,
            preemption_prob: 0.0,
            straggler_prob: 0.0,
            straggler_factor: 4.0,
        }
    }
}

/// Local training hyper-parameters (paper §5.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainConfig {
    pub local_epochs: usize,
    pub lr: f32,
    /// Target rounds (paper: 100).
    pub rounds: usize,
    /// Convergence: stop when relative model delta < eps for
    /// `patience` consecutive rounds (Algorithm 1 line 13).
    pub converge_eps: f32,
    pub converge_patience: usize,
    /// Optional accuracy target for time-to-accuracy experiments.
    pub target_accuracy: Option<f64>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            local_epochs: 5,
            lr: 0.05,
            rounds: 100,
            converge_eps: 1e-5,
            converge_patience: 3,
            target_accuracy: None,
        }
    }
}

/// Root experiment description.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    pub seed: u64,
    pub data: DataConfig,
    pub cluster: ClusterConfig,
    pub train: TrainConfig,
    pub aggregation: Aggregation,
    pub selection: SelectionConfig,
    pub straggler: StragglerConfig,
    pub compression: CompressionConfig,
    pub faults: FaultConfig,
    /// Directory with AOT artifacts (HLO text + manifest.json).
    pub artifacts_dir: String,
    /// Use the pure-Rust mock runtime instead of PJRT (tests / timing
    /// sims that don't need real learning).
    pub mock_runtime: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregation_mu() {
        assert_eq!(Aggregation::FedAvg.mu(), 0.0);
        assert_eq!(Aggregation::FedProx { mu: 0.1 }.mu(), 0.1);
        assert_eq!(Aggregation::Weighted(WeightScheme::InverseLoss).mu(), 0.0);
    }

    #[test]
    fn compression_none_detection() {
        assert!(CompressionConfig::NONE.is_none());
        assert!(!CompressionConfig::PAPER.is_none());
        let half = CompressionConfig {
            quant_bits: 32,
            topk_frac: 0.5,
            dropout_keep: 1.0,
        };
        assert!(!half.is_none());
    }

    #[test]
    fn cluster_total() {
        let c = ClusterConfig {
            nodes: vec![("a".into(), 3), ("b".into(), 7)],
            cloud_backend: "inproc".into(),
            hpc_backend: "inproc".into(),
        };
        assert_eq!(c.total_nodes(), 10);
    }
}
