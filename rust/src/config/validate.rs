//! Config validation: every experiment is checked once, up front, so
//! failures surface as one readable error instead of a mid-run panic.

use super::*;
use anyhow::{bail, Result};

pub fn validate(cfg: &ExperimentConfig) -> Result<()> {
    if cfg.name.is_empty() {
        bail!("config: name must not be empty");
    }
    let n = cfg.cluster.total_nodes();
    if n == 0 {
        bail!("config: cluster has no nodes");
    }
    if cfg.selection.clients_per_round == 0 {
        bail!("config: clients_per_round must be >= 1");
    }
    if cfg.selection.clients_per_round > n {
        bail!(
            "config: clients_per_round ({}) exceeds cluster size ({n})",
            cfg.selection.clients_per_round
        );
    }
    if let SelectionPolicy::Adaptive {
        explore_frac,
        exclude_factor,
    } = cfg.selection.policy
    {
        if !(0.0..=1.0).contains(&explore_frac) {
            bail!("config: explore_frac must be in [0,1], got {explore_frac}");
        }
        if exclude_factor <= 1.0 {
            bail!("config: exclude_factor must be > 1, got {exclude_factor}");
        }
    }
    // the effective planner's own parameter ranges (covers the explicit
    // `planner` override; the legacy policy fields were checked above)
    cfg.selection.planner_kind().check_params()?;
    if let Some(k) = cfg.straggler.partial_k {
        if k == 0 {
            bail!("config: partial_k must be >= 1");
        }
        if k > cfg.selection.clients_per_round {
            bail!(
                "config: partial_k ({k}) exceeds clients_per_round ({})",
                cfg.selection.clients_per_round
            );
        }
    }
    if cfg.straggler.deadline_ms == Some(0) {
        bail!("config: deadline_ms must be positive");
    }
    match cfg.compression.quant_bits {
        8 | 16 | 32 => {}
        b => bail!("config: quant_bits must be 8, 16 or 32, got {b}"),
    }
    if !(0.0..=1.0).contains(&cfg.compression.topk_frac) || cfg.compression.topk_frac == 0.0 {
        bail!(
            "config: topk_frac must be in (0,1], got {}",
            cfg.compression.topk_frac
        );
    }
    if !(0.0..=1.0).contains(&cfg.compression.dropout_keep)
        || cfg.compression.dropout_keep == 0.0
    {
        bail!(
            "config: dropout_keep must be in (0,1], got {}",
            cfg.compression.dropout_keep
        );
    }
    if cfg.train.local_epochs == 0 {
        bail!("config: local_epochs must be >= 1");
    }
    if cfg.train.rounds == 0 {
        bail!("config: rounds must be >= 1");
    }
    if cfg.train.lr.is_nan() || cfg.train.lr <= 0.0 {
        bail!("config: lr must be positive, got {}", cfg.train.lr);
    }
    // 0 = auto and 1 = serial are always fine; an absurd explicit
    // thread count is almost certainly a typo'd units mistake
    if cfg.ingest_threads > 1024 {
        bail!(
            "config: ingest_threads must be <= 1024 (0 = auto), got {}",
            cfg.ingest_threads
        );
    }
    // strategy / server-opt parameter ranges: shared with the name
    // parser so the CLI and config-file paths reject the same inputs
    cfg.aggregation.check_params()?;
    cfg.server_opt.check_params()?;
    cfg.round_mode.check_params()?;
    if let RoundMode::BufferedAsync { buffer_k, .. } = cfg.round_mode {
        if buffer_k > cfg.selection.clients_per_round {
            bail!(
                "config: async buffer_k ({buffer_k}) exceeds clients_per_round ({}) — \
                 a commit could never fill",
                cfg.selection.clients_per_round
            );
        }
        // order-statistic strategies buffer whole rounds; the async
        // engine folds continuously with per-update staleness
        // discounts, which only streaming strategies support
        if matches!(
            cfg.aggregation,
            Aggregation::TrimmedMean { .. } | Aggregation::CoordinateMedian
        ) {
            bail!(
                "config: round mode 'async_fedbuff' requires a streaming aggregation \
                 strategy (got buffered '{}')",
                cfg.aggregation.name()
            );
        }
    }
    match cfg.data.partition {
        Partition::LabelShard { classes_per_client } if classes_per_client == 0 => {
            bail!("config: classes_per_client must be >= 1")
        }
        Partition::Dirichlet { alpha } if alpha.is_nan() || alpha <= 0.0 => {
            bail!("config: dirichlet alpha must be > 0, got {alpha}")
        }
        _ => {}
    }
    if cfg.data.samples_per_client == 0 {
        bail!("config: samples_per_client must be >= 1");
    }
    for p in [cfg.faults.dropout_prob, cfg.faults.preemption_prob, cfg.faults.straggler_prob] {
        if !(0.0..=1.0).contains(&p) {
            bail!("config: fault probabilities must be in [0,1], got {p}");
        }
    }
    if cfg.faults.straggler_factor < 1.0 {
        bail!(
            "config: straggler_factor must be >= 1, got {}",
            cfg.faults.straggler_factor
        );
    }
    if cfg.transport.max_connections == 0 {
        bail!("config: transport.max_connections must be >= 1");
    }
    if cfg.transport.max_connections > 1_048_576 {
        bail!(
            "config: transport.max_connections must be <= 1048576, got {}",
            cfg.transport.max_connections
        );
    }
    // 0 = auto-size to the host; an explicit count beyond 256 sweep
    // threads is certainly a typo (the pool busy-polls when loaded)
    if cfg.transport.reactor_threads > 256 {
        bail!(
            "config: transport.reactor_threads must be <= 256 (0 = auto), got {}",
            cfg.transport.reactor_threads
        );
    }
    if cfg.transport.idle_timeout_ms < 10 {
        bail!(
            "config: transport.idle_timeout_ms must be >= 10, got {} — \
             sub-10ms reaping races legitimate handshakes",
            cfg.transport.idle_timeout_ms
        );
    }
    if cfg.transport.outbox_frames == 0 {
        bail!("config: transport.outbox_frames must be >= 1");
    }
    cfg.hierarchy.grouping.check_params()?;
    if cfg.hierarchy.enabled() {
        // the grouping must actually partition this cluster (e.g.
        // "site:10" over 6 nodes has empty sites)
        crate::cluster::SiteMap::build(&cfg.cluster, cfg.hierarchy.grouping)?;
        // order-statistic strategies buffer whole cohorts; a site
        // aggregator can only report one pre-folded mean upstream, so
        // trimming / medians do not compose across the tree
        if matches!(
            cfg.aggregation,
            Aggregation::TrimmedMean { .. } | Aggregation::CoordinateMedian
        ) {
            bail!(
                "config: hierarchical aggregation requires a streaming strategy \
                 (got buffered '{}') — order statistics do not compose across sites",
                cfg.aggregation.name()
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::presets::quickstart;
    use super::*;

    #[test]
    fn rejects_bad_clients_per_round() {
        let mut c = quickstart();
        c.selection.clients_per_round = 0;
        assert!(validate(&c).is_err());
        c.selection.clients_per_round = 10_000;
        assert!(validate(&c).is_err());
    }

    #[test]
    fn rejects_bad_planner_params() {
        let mut c = quickstart();
        c.selection.planner = Some(PlannerKind::Tiered { tiers: 1 });
        assert!(validate(&c).is_err());
        c.selection.planner = Some(PlannerKind::Deadline { target_ms: Some(0) });
        assert!(validate(&c).is_err());
        c.selection.planner = Some(PlannerKind::Adaptive {
            explore_frac: 2.0,
            exclude_factor: 2.5,
        });
        assert!(validate(&c).is_err());
        c.selection.planner = Some(PlannerKind::Tiered { tiers: 4 });
        assert!(validate(&c).is_ok());
        c.selection.planner = Some(PlannerKind::Deadline { target_ms: None });
        assert!(validate(&c).is_ok());
    }

    #[test]
    fn rejects_bad_partial_k() {
        let mut c = quickstart();
        c.straggler.partial_k = Some(0);
        assert!(validate(&c).is_err());
        c.straggler.partial_k = Some(c.selection.clients_per_round + 1);
        assert!(validate(&c).is_err());
    }

    #[test]
    fn rejects_bad_quant_bits() {
        let mut c = quickstart();
        c.compression.quant_bits = 7;
        assert!(validate(&c).is_err());
    }

    #[test]
    fn rejects_bad_probs_and_rates() {
        let mut c = quickstart();
        c.faults.dropout_prob = 1.5;
        assert!(validate(&c).is_err());
        let mut c = quickstart();
        c.train.lr = f32::NAN;
        assert!(validate(&c).is_err());
        let mut c = quickstart();
        c.compression.topk_frac = 0.0;
        assert!(validate(&c).is_err());
    }

    #[test]
    fn rejects_bad_dirichlet() {
        let mut c = quickstart();
        c.data.partition = Partition::Dirichlet { alpha: 0.0 };
        assert!(validate(&c).is_err());
    }

    #[test]
    fn rejects_bad_trim_frac() {
        for bad in [0.0f32, 0.5, 0.9, -0.1, f32::NAN] {
            let mut c = quickstart();
            c.aggregation = Aggregation::TrimmedMean { trim_frac: bad };
            assert!(validate(&c).is_err(), "trim_frac {bad} should be rejected");
        }
        let mut c = quickstart();
        c.aggregation = Aggregation::TrimmedMean { trim_frac: 0.25 };
        assert!(validate(&c).is_ok());
        c.aggregation = Aggregation::CoordinateMedian;
        assert!(validate(&c).is_ok());
    }

    #[test]
    fn rejects_bad_round_mode_combinations() {
        let async_mode = |buffer_k| RoundMode::BufferedAsync {
            buffer_k,
            max_staleness: 20,
            staleness: StalenessFn::Polynomial { alpha: 0.5 },
        };
        let mut c = quickstart();
        c.round_mode = async_mode(0);
        assert!(validate(&c).is_err(), "buffer_k 0");
        let mut c = quickstart();
        c.round_mode = async_mode(c.selection.clients_per_round + 1);
        assert!(validate(&c).is_err(), "buffer_k > cohort");
        let mut c = quickstart();
        c.round_mode = async_mode(2);
        c.aggregation = Aggregation::CoordinateMedian;
        assert!(validate(&c).is_err(), "buffered strategy in async mode");
        let mut c = quickstart();
        c.round_mode = async_mode(2);
        assert!(validate(&c).is_ok());
        c.round_mode = RoundMode::BufferedAsync {
            buffer_k: 2,
            max_staleness: 20,
            staleness: StalenessFn::Polynomial { alpha: f32::NAN },
        };
        assert!(validate(&c).is_err(), "NaN alpha");
    }

    #[test]
    fn rejects_absurd_ingest_threads() {
        let mut c = quickstart();
        c.ingest_threads = 1025;
        assert!(validate(&c).is_err());
        for ok in [0, 1, 8, 1024] {
            c.ingest_threads = ok;
            assert!(validate(&c).is_ok(), "ingest_threads {ok} should pass");
        }
    }

    #[test]
    fn rejects_bad_transport_params() {
        let mut c = quickstart();
        c.transport.max_connections = 0;
        assert!(validate(&c).is_err(), "max_connections 0");
        c.transport.max_connections = 2_000_000;
        assert!(validate(&c).is_err(), "max_connections 2M");
        let mut c = quickstart();
        c.transport.reactor_threads = 257;
        assert!(validate(&c).is_err(), "reactor_threads 257");
        let mut c = quickstart();
        c.transport.idle_timeout_ms = 5;
        assert!(validate(&c).is_err(), "idle_timeout_ms 5");
        let mut c = quickstart();
        c.transport.outbox_frames = 0;
        assert!(validate(&c).is_err(), "outbox_frames 0");
        let mut c = quickstart();
        c.transport = TransportConfig {
            max_connections: 10_240,
            compression: false,
            reactor_threads: 0,
            idle_timeout_ms: 30_000,
            outbox_frames: 64,
        };
        assert!(validate(&c).is_ok());
    }

    #[test]
    fn rejects_bad_hierarchy() {
        // more sites than nodes: the site map cannot be built
        let mut c = quickstart();
        c.hierarchy.grouping = GroupingPolicy::Site { sites: 100 };
        assert!(validate(&c).is_err(), "site:100 over 8 nodes");
        // zero sites is a parameter error even without building the map
        let mut c = quickstart();
        c.hierarchy.grouping = GroupingPolicy::Site { sites: 0 };
        assert!(validate(&c).is_err(), "site:0");
        // buffered strategies do not compose across sites
        let mut c = quickstart();
        c.hierarchy.grouping = GroupingPolicy::Site { sites: 2 };
        c.aggregation = Aggregation::TrimmedMean { trim_frac: 0.25 };
        assert!(validate(&c).is_err(), "trimmed_mean under hierarchy");
        c.aggregation = Aggregation::CoordinateMedian;
        assert!(validate(&c).is_err(), "coordinate_median under hierarchy");
        // streaming strategies over a feasible grouping are fine
        c.aggregation = Aggregation::FedAvg;
        assert!(validate(&c).is_ok());
        c.hierarchy.grouping = GroupingPolicy::Zone;
        assert!(validate(&c).is_ok());
    }

    #[test]
    fn rejects_bad_server_opt_params() {
        let mut c = quickstart();
        c.server_opt = ServerOptKind::FedAvgM { beta: 1.0 };
        assert!(validate(&c).is_err());
        c.server_opt = ServerOptKind::FedAvgM { beta: -0.1 };
        assert!(validate(&c).is_err());
        c.server_opt = ServerOptKind::FedAvgM { beta: 0.9 };
        assert!(validate(&c).is_ok());
        c.server_opt = ServerOptKind::FedAdam {
            lr: 0.0,
            beta1: 0.9,
            beta2: 0.99,
            eps: 1e-3,
        };
        assert!(validate(&c).is_err());
        c.server_opt = ServerOptKind::FedAdam {
            lr: 0.1,
            beta1: 0.9,
            beta2: 1.5,
            eps: 1e-3,
        };
        assert!(validate(&c).is_err());
        c.server_opt = ServerOptKind::FedAdam {
            lr: 0.1,
            beta1: 0.9,
            beta2: 0.99,
            eps: 1e-3,
        };
        assert!(validate(&c).is_ok());
    }
}
