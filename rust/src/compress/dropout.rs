//! Federated dropout (paper §4.3): each client trains/transmits only a
//! seeded random subset of coordinates. Only the *seed* crosses the
//! wire — both sides regenerate the identical mask, so the payload
//! saves the full masked fraction.

use crate::util::rng::Rng;

/// Deterministic kept-coordinate set for (len, keep_frac, seed).
/// Sorted ascending.
pub fn dropout_mask_indices(len: usize, keep_frac: f32, seed: u64) -> Vec<u32> {
    // callers validate the wire-carried fraction; clamp (NaN → 1.0)
    // instead of asserting so a bad value can never panic this path
    let keep_frac = if keep_frac.is_nan() {
        1.0
    } else {
        keep_frac.clamp(0.0, 1.0)
    };
    if keep_frac >= 1.0 || len == 0 {
        // len == 0: nothing to keep — the old `.clamp(1, 0)` panicked
        return (0..len as u32).collect();
    }
    let k = ((len as f64 * keep_frac as f64).round() as usize).clamp(1, len);
    let mut rng = Rng::new(seed ^ 0xD20_0FF);
    let mut idx = rng.sample_indices(len, k);
    idx.sort_unstable();
    idx.into_iter().map(|i| i as u32).collect()
}

/// A reusable mask handle (kept indices + complement application).
#[derive(Debug, Clone, PartialEq)]
pub struct DropoutMask {
    pub kept: Vec<u32>,
    pub dense_len: usize,
}

impl DropoutMask {
    pub fn generate(dense_len: usize, keep_frac: f32, seed: u64) -> Self {
        DropoutMask {
            kept: dropout_mask_indices(dense_len, keep_frac, seed),
            dense_len,
        }
    }

    /// Gather the kept coordinates of `dense`.
    ///
    /// Callers pass a `dense` of length `dense_len`; `generate` only
    /// emits indices `< dense_len`, so the indexing is infallible.
    pub fn gather(&self, dense: &[f32]) -> Vec<f32> {
        // lint:allow(panic_safety) kept indices are < dense_len by construction (generate samples in 0..dense_len)
        self.kept.iter().map(|&i| dense[i as usize]).collect()
    }

    /// Scatter `vals` back into a zero vector of the dense length.
    /// `vals` must be a `gather` result for this mask.
    pub fn scatter(&self, vals: &[f32]) -> Vec<f32> {
        // lint:allow(panic_safety) local-only helper (compress-side + tests); arity is the gather contract, not wire input
        assert_eq!(vals.len(), self.kept.len());
        let mut out = vec![0f32; self.dense_len];
        for (&i, &v) in self.kept.iter().zip(vals) {
            // lint:allow(panic_safety) kept indices are < dense_len by construction
            out[i as usize] = v;
        }
        out
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests may panic freely
mod tests {
    use super::*;

    #[test]
    fn mask_is_deterministic_and_sorted() {
        let a = dropout_mask_indices(1000, 0.3, 42);
        let b = dropout_mask_indices(1000, 0.3, 42);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] < w[1]));
        let c = dropout_mask_indices(1000, 0.3, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn mask_size_matches_fraction() {
        let m = dropout_mask_indices(10_000, 0.25, 0);
        assert_eq!(m.len(), 2500);
        let all = dropout_mask_indices(100, 1.0, 0);
        assert_eq!(all.len(), 100);
        let one = dropout_mask_indices(100, 0.001, 0);
        assert_eq!(one.len(), 1); // clamped to >= 1
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let dense: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let m = DropoutMask::generate(100, 0.4, 7);
        let vals = m.gather(&dense);
        let back = m.scatter(&vals);
        for (i, &v) in back.iter().enumerate() {
            if m.kept.contains(&(i as u32)) {
                assert_eq!(v, dense[i]);
            } else {
                assert_eq!(v, 0.0);
            }
        }
    }

    #[test]
    fn masks_differ_across_rounds() {
        // (round, client) seeds must give different coordinate subsets so
        // coverage rotates (otherwise some params never train)
        let r1 = dropout_mask_indices(500, 0.5, (100 << 32) | 1);
        let r2 = dropout_mask_indices(500, 0.5, (101 << 32) | 1);
        assert_ne!(r1, r2);
    }
}
