//! Communication-efficient update encoding (paper §4.3, Table 1).
//!
//! The codec pipeline transforms a dense f32 update vector into a
//! compact wire payload and back:
//!
//! ```text
//! dense Δ ──(federated dropout mask)──(top-k sparsify)──(quantize)──> payload
//! ```
//!
//! Semantics are bit-matched to the L1 Pallas kernels (same scale rule,
//! same round-half-even, same pessimistic tie handling) — pinned by
//! tests against values exported from the Python oracle.
//!
//! # Ingest without materialization
//!
//! [`decompress`] reconstructs the dense vector — O(P) allocation and
//! writes no matter how sparse the encoding was. The server-side ingest
//! hot path never needs that vector: aggregation folds `w·Δ` into an
//! accumulator, and a sparse update only touches its stored
//! coordinates. [`DecodedView`] is the zero-materialization decode: a
//! borrowed, *validated* view over an [`Encoded`] (including the raw
//! bytes of a [`PreEncoded`] payload, read in place) that yields
//! `(index, value)` pairs via [`DecodedView::for_each_nonzero`] or
//! fused-folds them via [`DecodedView::fold_scaled_into`] — O(k) for
//! Sparse/QSparse, O(kept) for Masked, chunk-parallel for Dense/QDense.
//! Skipping the unstored coordinates is bit-identical to folding the
//! densified vector: every unstored coordinate decodes to `+0.0`, and
//! `acc + w·(+0.0)` cannot change `acc` because a fold accumulator is
//! never `-0.0` (it starts at `+0.0`, and IEEE-754 addition only
//! yields `-0.0` from `(-0.0) + (-0.0)`, which `+0.0 + t` never
//! produces). Stored zeros (including `-0.0`) are still yielded, so
//! their contributions match the dense path exactly; the invariant is
//! pinned by property tests across all five encodings.
//!
//! Validation is strict: a view rejects out-of-bounds, non-increasing
//! or duplicated indices up front (the densify path's last-write-wins
//! on duplicates cannot be reproduced by a fold, so such updates are
//! refused rather than silently aggregated differently).

// Wire-reachable tree: corrupt payloads must produce an `Err`, never a
// panic. `fedhpc-lint` enforces the wider panic-safety rule (indexing,
// assert!, unreachable!); these attributes make the unwrap/expect
// subclass unwriteable even under plain clippy.
#![deny(clippy::unwrap_used, clippy::expect_used)]

mod dropout;
mod quantize;
mod sparsify;

pub use dropout::{dropout_mask_indices, DropoutMask};
pub use quantize::{dequantize, quantize, QData, QuantBits, Quantized};
pub use sparsify::{sparsify_topk, Sparse};

use crate::config::CompressionConfig;
use crate::util::bytes::{f32_le_at, i16_le_at, u32_le_at};
use anyhow::{bail, Result};
use std::sync::Arc;

/// A wire-ready encoded update.
#[derive(Debug, Clone, PartialEq)]
pub enum Encoded {
    /// Raw f32 (compression off).
    Dense(Vec<f32>),
    /// Quantized dense values.
    QDense(Quantized),
    /// Sparse f32 (indices + values).
    Sparse(Sparse),
    /// Sparse + quantized values.
    QSparse { idx: Vec<u32>, q: Quantized },
    /// Federated-dropout masked values: the kept-coordinate set is
    /// derived from `(seed, keep, dense_len)` on BOTH sides, so only
    /// the seed + payload cross the wire (no indices — that is the
    /// entire bandwidth win of federated dropout). `inner` is the
    /// Dense or QDense encoding of the kept values, in mask order.
    Masked {
        seed: u64,
        keep: f32,
        dense_len: usize,
        inner: Box<Encoded>,
    },
    /// An encoding already serialized to its codec bytes, shared behind
    /// an `Arc`. The orchestrator pre-encodes the round's model payload
    /// once and every broadcast send clones only the pointer; on the
    /// wire the bytes are indistinguishable from the underlying
    /// encoding (the decoder never produces this variant).
    PreEncoded(PreEncoded),
}

/// Shared, pre-serialized payload: the exact bytes the wire codec
/// (`network::message`) writes for the underlying encoding, plus the
/// metadata needed for accounting without re-decoding.
#[derive(Debug, Clone, PartialEq)]
pub struct PreEncoded {
    /// Serialized encoding (codec tag + body).
    pub bytes: std::sync::Arc<[u8]>,
    /// Logical decoded length of the underlying encoding.
    pub dense_len: usize,
    /// `wire_bytes()` of the underlying encoding.
    pub wire: u64,
}

impl Encoded {
    /// Bytes this encoding occupies on the wire (payload only; framing
    /// overhead is accounted by the transport).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Encoded::Dense(v) => 4 * v.len() as u64,
            Encoded::QDense(q) => q.wire_bytes(),
            Encoded::Sparse(s) => 8 * s.idx.len() as u64, // 4B idx + 4B val
            Encoded::QSparse { idx, q } => 4 * idx.len() as u64 + q.wire_bytes(),
            Encoded::Masked { inner, .. } => 16 + inner.wire_bytes(),
            Encoded::PreEncoded(p) => p.wire,
        }
    }

    /// Logical (decoded) length.
    pub fn dense_len(&self) -> usize {
        match self {
            Encoded::Dense(v) => v.len(),
            Encoded::QDense(q) => q.n,
            Encoded::Sparse(s) => s.dense_len,
            Encoded::QSparse { q, .. } => q.n,
            Encoded::Masked { dense_len, .. } => *dense_len,
            Encoded::PreEncoded(p) => p.dense_len,
        }
    }
}

/// Borrowed value storage of a view: decoded f32 values are produced on
/// the fly from whatever representation the encoding carries — owned
/// typed slices for a decoded [`Encoded`], raw little-endian wire bytes
/// for a [`PreEncoded`] payload (no intermediate `Vec` either way).
#[derive(Debug, Clone, Copy)]
pub(crate) enum ValSlice<'a> {
    F32(&'a [f32]),
    /// Packed LE f32 wire bytes (`4·len`).
    F32Le(&'a [u8]),
    Q8 { v: &'a [i8], scale: f32 },
    Q16 { v: &'a [i16], scale: f32 },
    /// Packed LE i16 wire bytes (`2·len`).
    Q16Le { v: &'a [u8], scale: f32 },
}

impl<'a> ValSlice<'a> {
    fn len(&self) -> usize {
        match self {
            ValSlice::F32(v) => v.len(),
            ValSlice::F32Le(v) => v.len() / 4,
            ValSlice::Q8 { v, .. } => v.len(),
            ValSlice::Q16 { v, .. } => v.len(),
            ValSlice::Q16Le { v, .. } => v.len() / 2,
        }
    }

    /// `f(i, value)` for `i` in `lo..hi`, with the representation match
    /// hoisted out of the loop. Decode math is identical to
    /// [`dequantize`] (`int as f32 * scale`), so views are bit-equal
    /// to densifying.
    fn for_each_range(&self, lo: usize, hi: usize, mut f: impl FnMut(usize, f32)) {
        match self {
            ValSlice::F32(v) => {
                // lint:allow(panic_safety) callers pass lo <= hi <= self.len(), validated by the from_parts_* constructors
                for (i, &x) in v[lo..hi].iter().enumerate() {
                    f(lo + i, x);
                }
            }
            ValSlice::F32Le(v) => {
                for i in lo..hi {
                    f(i, f32_le_at(v, i));
                }
            }
            ValSlice::Q8 { v, scale } => {
                // lint:allow(panic_safety) callers pass lo <= hi <= self.len(), validated by the from_parts_* constructors
                for (i, &x) in v[lo..hi].iter().enumerate() {
                    f(lo + i, x as f32 * scale);
                }
            }
            ValSlice::Q16 { v, scale } => {
                // lint:allow(panic_safety) callers pass lo <= hi <= self.len(), validated by the from_parts_* constructors
                for (i, &x) in v[lo..hi].iter().enumerate() {
                    f(lo + i, x as f32 * scale);
                }
            }
            ValSlice::Q16Le { v, scale } => {
                for i in lo..hi {
                    f(i, i16_le_at(v, i) as f32 * scale);
                }
            }
        }
    }
}

/// Borrowed index storage of a sparse view (owned or raw LE bytes).
#[derive(Debug, Clone, Copy)]
pub(crate) enum IdxSlice<'a> {
    U32(&'a [u32]),
    /// Packed LE u32 wire bytes (`4·len`).
    U32Le(&'a [u8]),
}

impl<'a> IdxSlice<'a> {
    fn len(&self) -> usize {
        match self {
            IdxSlice::U32(v) => v.len(),
            IdxSlice::U32Le(v) => v.len() / 4,
        }
    }

    #[inline]
    fn get(&self, i: usize) -> u32 {
        match self {
            // lint:allow(panic_safety) every caller iterates j in 0..self.len(); arity is constructor-validated
            IdxSlice::U32(v) => v[i],
            IdxSlice::U32Le(v) => u32_le_at(v, i),
        }
    }

    /// First position whose index is ≥ `bound` (indices are validated
    /// strictly increasing, so binary search is valid).
    fn lower_bound(&self, bound: u32) -> usize {
        let (mut lo, mut hi) = (0, self.len());
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if self.get(mid) < bound {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo
    }
}

enum ViewKind<'a> {
    /// Every coordinate is stored, in order.
    Dense(ValSlice<'a>),
    /// Explicit (strictly increasing) indices + values.
    Indexed { idx: IdxSlice<'a>, vals: ValSlice<'a> },
    /// Seeded federated-dropout mask: kept indices are regenerated
    /// (owned, O(kept)) or borrowed from a [`SharedDecoded`] that
    /// regenerated them once; values borrow from the inner encoding.
    Kept {
        kept: std::borrow::Cow<'a, [u32]>,
        vals: ValSlice<'a>,
    },
}

/// A validated, zero-materialization decode of an [`Encoded`] update:
/// the nonzero structure is exposed for visiting / fused folding
/// without ever building the dense vector. See the module docs for the
/// bit-identity argument and the strictness contract.
pub struct DecodedView<'a> {
    n: usize,
    kind: ViewKind<'a>,
}

/// Minimum stored entries before a fold parallelizes (below this the
/// scoped-thread spawn costs more than the scatter).
const PAR_MIN_NNZ: usize = 64 * 1024;
/// Accumulator chunk for parallel folds — the single shared constant in
/// `util::parallel` keeps this path and the dense fold/normalize in
/// `orchestrator::aggregate` chunking identically, so thread-count
/// determinism arguments carry over unchanged.
const FOLD_CHUNK: usize = crate::util::parallel::FOLD_CHUNK;

impl<'a> DecodedView<'a> {
    /// Build a view over `enc` for a model of `n` parameters,
    /// performing every check [`decompress`] would (lengths, bounds)
    /// plus strict index monotonicity.
    pub fn of(enc: &'a Encoded, n: usize) -> Result<DecodedView<'a>> {
        match enc {
            Encoded::Dense(v) => Self::from_parts_dense(ValSlice::F32(v), n, "dense"),
            Encoded::QDense(q) => {
                if q.n != n {
                    bail!("qdense length {} != {}", q.n, n);
                }
                Self::from_parts_dense(quantized_vals(q), n, "qdense")
            }
            Encoded::Sparse(s) => {
                if s.dense_len != n {
                    bail!("sparse dense length {} != {}", s.dense_len, n);
                }
                Self::from_parts_indexed(IdxSlice::U32(&s.idx), ValSlice::F32(&s.val), n, "sparse")
            }
            Encoded::QSparse { idx, q } => {
                if q.n != n {
                    bail!("qsparse length {} != {}", q.n, n);
                }
                Self::from_parts_indexed(IdxSlice::U32(idx), quantized_vals(q), n, "qsparse")
            }
            Encoded::Masked {
                seed,
                keep,
                dense_len,
                inner,
            } => {
                let vals = match inner.as_ref() {
                    Encoded::Dense(v) => ValSlice::F32(v),
                    Encoded::QDense(q) => quantized_vals(q),
                    other => bail!("masked inner must be dense-like, got {other:?}"),
                };
                Self::from_parts_masked(*seed, *keep, *dense_len, vals, n)
            }
            Encoded::PreEncoded(p) => crate::network::message::view_payload(&p.bytes, n),
        }
    }

    /// Dense-like view: exactly `n` stored values.
    pub(crate) fn from_parts_dense(
        vals: ValSlice<'a>,
        n: usize,
        what: &str,
    ) -> Result<DecodedView<'a>> {
        if vals.len() != n {
            bail!("{what} length {} != {}", vals.len(), n);
        }
        Ok(DecodedView {
            n,
            kind: ViewKind::Dense(vals),
        })
    }

    /// Explicitly-indexed sparse view; validates arity, bounds and
    /// strict monotonicity (duplicates would make the fold diverge from
    /// the densify path's last-write-wins — refuse them instead).
    pub(crate) fn from_parts_indexed(
        idx: IdxSlice<'a>,
        vals: ValSlice<'a>,
        n: usize,
        what: &str,
    ) -> Result<DecodedView<'a>> {
        let len = idx.len();
        if len != vals.len() {
            bail!("{what} arity mismatch: {} vs {}", vals.len(), len);
        }
        // hot path: one tight monotonicity sweep per representation;
        // once indices strictly increase, only the last needs a bounds
        // check
        let increasing = match idx {
            // lint:allow(panic_safety) windows(2) yields exactly-2-element slices
            IdxSlice::U32(v) => v.windows(2).all(|w| w[0] < w[1]),
            IdxSlice::U32Le(raw) => (1..len).all(|j| u32_le_at(raw, j - 1) < u32_le_at(raw, j)),
        };
        if !increasing {
            bail!("{what} indices not strictly increasing");
        }
        if len > 0 {
            let last = idx.get(len - 1);
            if last as usize >= n {
                bail!("{what} index {last} out of bounds {n}");
            }
        }
        Ok(DecodedView {
            n,
            kind: ViewKind::Indexed { idx, vals },
        })
    }

    /// Seeded-mask view: regenerates the kept-coordinate set and
    /// validates it against the stored values.
    pub(crate) fn from_parts_masked(
        seed: u64,
        keep: f32,
        dense_len: usize,
        vals: ValSlice<'a>,
        n: usize,
    ) -> Result<DecodedView<'a>> {
        if dense_len != n {
            bail!("masked dense length {dense_len} != {n}");
        }
        if !(0.0..=1.0).contains(&keep) {
            bail!("masked keep fraction {keep} outside [0, 1]");
        }
        let kept = dropout_mask_indices(n, keep, seed);
        if vals.len() != kept.len() {
            bail!(
                "masked arity mismatch: {} values for {} kept coords",
                vals.len(),
                kept.len()
            );
        }
        Ok(DecodedView {
            n,
            kind: ViewKind::Kept {
                kept: std::borrow::Cow::Owned(kept),
                vals,
            },
        })
    }

    /// Logical (dense) length of the decoded update.
    pub fn dense_len(&self) -> usize {
        self.n
    }

    /// Stored entries the view will yield (== `dense_len` for
    /// dense-like encodings).
    pub fn nnz(&self) -> usize {
        match &self.kind {
            ViewKind::Dense(v) => v.len(),
            ViewKind::Indexed { idx, .. } => idx.len(),
            ViewKind::Kept { kept, .. } => kept.len(),
        }
    }

    /// Whether every coordinate is stored (Dense/QDense payloads).
    pub fn is_dense(&self) -> bool {
        matches!(self.kind, ViewKind::Dense(_))
    }

    /// Visit every *stored* `(index, value)` pair in increasing index
    /// order. Unstored coordinates are exactly `0.0` and are not
    /// yielded; stored zeros (including `-0.0`) are.
    pub fn for_each_nonzero(&self, mut f: impl FnMut(usize, f32)) {
        match &self.kind {
            ViewKind::Dense(vals) => vals.for_each_range(0, vals.len(), f),
            ViewKind::Indexed { idx, vals } => {
                vals.for_each_range(0, vals.len(), |j, v| f(idx.get(j) as usize, v))
            }
            ViewKind::Kept { kept, vals } => {
                // lint:allow(panic_safety) from_parts_masked validated vals.len() == kept.len()
                vals.for_each_range(0, vals.len(), |j, v| f(kept[j] as usize, v))
            }
        }
    }

    /// Materialize into `out` (fully overwritten) — bit-identical to
    /// [`decompress`]. This is the escape hatch for consumers that
    /// genuinely need the dense vector (buffered strategies, the
    /// client-side global-model decode); pair it with a
    /// [`crate::util::scratch::ScratchPool`] buffer to avoid the
    /// per-update allocation.
    pub fn write_dense(&self, out: &mut [f32]) {
        // lint:allow(panic_safety) caller-contract arity (scratch buffers sized to dense_len), not wire input
        assert_eq!(out.len(), self.n, "write_dense length mismatch");
        match &self.kind {
            ViewKind::Dense(ValSlice::F32(v)) => out.copy_from_slice(v),
            // lint:allow(panic_safety) stored index < n validated by the from_parts_* constructors; out.len() == n asserted above
            ViewKind::Dense(vals) => vals.for_each_range(0, vals.len(), |i, v| out[i] = v),
            _ => {
                out.fill(0.0);
                // lint:allow(panic_safety) stored index < n validated by the from_parts_* constructors; out.len() == n asserted above
                self.for_each_nonzero(|i, v| out[i] = v);
            }
        }
    }

    /// Fused decode→fold: `acc[i] += w * value as f64` for every stored
    /// entry. Cost is O(nnz); dense payloads and large sparse payloads
    /// partition the accumulator across threads (each element still
    /// receives exactly one addition, so the result is independent of
    /// thread count — the same argument as the dense fold in
    /// `orchestrator::aggregate`).
    pub fn fold_scaled_into(&self, acc: &mut [f64], w: f64) {
        // lint:allow(panic_safety) caller-contract arity (accumulators sized to dense_len), not wire input
        assert_eq!(acc.len(), self.n, "fold_scaled_into length mismatch");
        let parallel = match &self.kind {
            ViewKind::Dense(_) => true,
            ViewKind::Indexed { idx, .. } => idx.len() >= PAR_MIN_NNZ,
            ViewKind::Kept { kept, .. } => kept.len() >= PAR_MIN_NNZ,
        };
        if parallel {
            crate::util::parallel::par_chunks_mut(acc, FOLD_CHUNK, |offset, chunk| {
                self.fold_range(chunk, offset, w);
            });
        } else {
            self.fold_range(acc, 0, w);
        }
    }

    /// Fold only the stored entries with coordinates in `[lo, hi)` into
    /// `seg` (the accumulator sub-slice covering that coordinate range:
    /// `seg[i - lo] += w * value`). This is the sharded-ingest entry
    /// point: each shard worker folds its disjoint range, so across
    /// shards every element still receives exactly one addition and the
    /// result is independent of shard count for a fixed arrival order.
    pub fn fold_scaled_into_range(&self, seg: &mut [f64], lo: usize, hi: usize, w: f64) {
        // lint:allow(panic_safety) caller-contract arity (shard spans are computed from dense_len), not wire input
        assert!(
            lo <= hi && hi <= self.n && seg.len() == hi - lo,
            "fold_scaled_into_range span mismatch"
        );
        self.fold_range(seg, lo, w);
    }

    /// Shared scatter kernel: fold stored entries with coordinates in
    /// `[lo, lo + seg.len())` into `seg`. Sparse kinds bracket the
    /// stored-entry positions by binary search (indices are strictly
    /// increasing by construction).
    fn fold_range(&self, seg: &mut [f64], lo: usize, w: f64) {
        let hi = lo + seg.len();
        match &self.kind {
            ViewKind::Dense(vals) => {
                vals.for_each_range(lo, hi, |i, v| {
                    // lint:allow(panic_safety) for_each_range yields i in lo..lo+seg.len()
                    seg[i - lo] += w * v as f64;
                });
            }
            ViewKind::Indexed { idx, vals } => {
                let a = idx.lower_bound(lo as u32);
                let b = idx.lower_bound(hi.min(u32::MAX as usize) as u32);
                vals.for_each_range(a, b, |j, v| {
                    // lint:allow(panic_safety) lower_bound brackets the span's index subrange; indices validated < n
                    seg[idx.get(j) as usize - lo] += w * v as f64;
                });
            }
            ViewKind::Kept { kept, vals } => {
                let a = kept.partition_point(|&i| (i as usize) < lo);
                let b = kept.partition_point(|&i| (i as usize) < hi);
                vals.for_each_range(a, b, |j, v| {
                    // lint:allow(panic_safety) partition_point brackets the span's index subrange; kept indices < n
                    seg[kept[j] as usize - lo] += w * v as f64;
                });
            }
        }
    }
}

/// An owned, validated, shard-shareable decode of an [`Encoded`]
/// update. `new` performs every [`DecodedView::of`] check exactly once
/// on the ingest thread (pre-encoded wire bytes are decoded to the
/// owned inner encoding, bit-identically — pinned by property test;
/// seeded dropout masks regenerate their kept-index set once); shard
/// workers then re-view the payload without re-validating and fold
/// disjoint coordinate ranges via [`DecodedView::fold_scaled_into_range`].
pub struct SharedDecoded {
    enc: Arc<Encoded>,
    /// Kept-coordinate set for `Encoded::Masked`, regenerated once.
    kept: Option<Arc<Vec<u32>>>,
    n: usize,
}

impl SharedDecoded {
    /// Validate `enc` for a model of `n` parameters and make it
    /// shareable across shard workers.
    pub fn new(enc: Arc<Encoded>, n: usize) -> Result<SharedDecoded> {
        let enc = match enc.as_ref() {
            // decode wire bytes once to the owned inner encoding so the
            // payload is self-contained ('static) for shard queues
            Encoded::PreEncoded(p) => Arc::new(crate::network::message::decode_payload(&p.bytes)?),
            _ => enc,
        };
        let view = DecodedView::of(&enc, n)?;
        let kept = match view.kind {
            ViewKind::Kept { kept, .. } => Some(Arc::new(kept.into_owned())),
            _ => None,
        };
        Ok(SharedDecoded { enc, kept, n })
    }

    /// Logical (dense) length of the decoded update.
    pub fn dense_len(&self) -> usize {
        self.n
    }

    /// Stored entries the payload will fold.
    pub fn nnz(&self) -> usize {
        self.trusted_view().map(|v| v.nnz()).unwrap_or(0)
    }

    /// Fold this payload's entries with coordinates in `[lo, hi)` into
    /// the shard segment `seg` (see
    /// [`DecodedView::fold_scaled_into_range`]).
    pub fn fold_range_into(&self, seg: &mut [f64], lo: usize, hi: usize, w: f64) {
        if let Some(view) = self.trusted_view() {
            view.fold_scaled_into_range(seg, lo, hi, w);
        }
    }

    /// Re-build a view over the already-validated payload without
    /// re-running the constructor checks. Returns `None` only for
    /// variants `new` makes unrepresentable (kept without mask, wire
    /// bytes), so callers treat it as a structural no-op, not an error.
    fn trusted_view(&self) -> Option<DecodedView<'_>> {
        let kind = match self.enc.as_ref() {
            Encoded::Dense(v) => ViewKind::Dense(ValSlice::F32(v)),
            Encoded::QDense(q) => ViewKind::Dense(quantized_vals(q)),
            Encoded::Sparse(s) => ViewKind::Indexed {
                idx: IdxSlice::U32(&s.idx),
                vals: ValSlice::F32(&s.val),
            },
            Encoded::QSparse { idx, q } => ViewKind::Indexed {
                idx: IdxSlice::U32(idx),
                vals: quantized_vals(q),
            },
            Encoded::Masked { inner, .. } => {
                let vals = match inner.as_ref() {
                    Encoded::Dense(v) => ValSlice::F32(v),
                    Encoded::QDense(q) => quantized_vals(q),
                    _ => return None,
                };
                let kept = self.kept.as_ref()?;
                ViewKind::Kept {
                    kept: std::borrow::Cow::Borrowed(kept.as_slice()),
                    vals,
                }
            }
            Encoded::PreEncoded(_) => return None,
        };
        Some(DecodedView { n: self.n, kind })
    }
}

/// Map a quantized payload to its value slice (arity against the
/// surrounding structure is checked by the `from_parts_*` constructor).
fn quantized_vals(q: &Quantized) -> ValSlice<'_> {
    match &q.data {
        QData::I8(v) => ValSlice::Q8 { v, scale: q.scale },
        QData::I16(v) => ValSlice::Q16 { v, scale: q.scale },
    }
}

/// Compress a dense update under the given config.
///
/// `mask_seed` derives the federated-dropout mask; the orchestrator
/// uses the same (round, client) seed to know which coordinates were
/// trained, so only the seed crosses the wire.
pub fn compress(update: &[f32], cfg: &CompressionConfig, mask_seed: u64) -> Encoded {
    // 1. federated dropout: keep a seeded coordinate subset
    let dropped: Option<(Vec<u32>, Vec<f32>)> = if cfg.dropout_keep < 1.0 {
        let keep = dropout_mask_indices(update.len(), cfg.dropout_keep, mask_seed);
        // lint:allow(panic_safety) mask indices are < update.len() by construction
        let vals = keep.iter().map(|&i| update[i as usize]).collect();
        Some((keep, vals))
    } else {
        None
    };

    // 2. top-k sparsification (within the kept coordinates)
    let sparsified: Option<(Vec<u32>, Vec<f32>)> = if cfg.topk_frac < 1.0 {
        match &dropped {
            Some((idx, vals)) => {
                let k = k_of(vals.len(), cfg.topk_frac);
                let s = sparsify_topk(vals, k);
                // lint:allow(panic_safety) top-k positions index the kept-vals vector they were selected from
                let gidx: Vec<u32> = s.idx.iter().map(|&i| idx[i as usize]).collect();
                Some((gidx, s.val))
            }
            None => {
                let k = k_of(update.len(), cfg.topk_frac);
                let s = sparsify_topk(update, k);
                Some((s.idx, s.val))
            }
        }
    } else {
        dropped
    };

    // 3. quantization + encoding selection.
    // dropout WITHOUT top-k → seeded Masked encoding (no indices on the
    // wire — both sides regenerate the mask from the seed). Top-k
    // survivors are data-dependent, so those need explicit indices.
    let bits = QuantBits::from_u8(cfg.quant_bits);
    if cfg.topk_frac >= 1.0 {
        if let Some((_, vals)) = sparsified {
            let inner = match bits {
                None => Encoded::Dense(vals),
                Some(b) => Encoded::QDense(quantize(&vals, b)),
            };
            return Encoded::Masked {
                seed: mask_seed,
                keep: cfg.dropout_keep,
                dense_len: update.len(),
                inner: Box::new(inner),
            };
        }
    }
    match (sparsified, bits) {
        (None, None) => Encoded::Dense(update.to_vec()),
        (None, Some(b)) => Encoded::QDense(quantize(update, b)),
        (Some((idx, vals)), None) => Encoded::Sparse(Sparse {
            idx,
            val: vals,
            dense_len: update.len(),
        }),
        (Some((idx, vals)), Some(b)) => {
            let mut q = quantize(&vals, b);
            q.n = update.len(); // decoded length is the full vector
            Encoded::QSparse { idx, q }
        }
    }
}

/// Decompress back to a dense vector of length `n`.
pub fn decompress(enc: &Encoded, n: usize) -> Result<Vec<f32>> {
    match enc {
        Encoded::Dense(v) => {
            if v.len() != n {
                bail!("dense length {} != {}", v.len(), n);
            }
            Ok(v.clone())
        }
        Encoded::QDense(q) => {
            if q.n != n {
                bail!("qdense length {} != {}", q.n, n);
            }
            if q.data.len() != n {
                // a corrupt payload must error, not hand back a
                // wrong-length "dense vector of length n"
                bail!("qdense arity mismatch: {} vs {}", q.data.len(), n);
            }
            Ok(dequantize(q))
        }
        Encoded::Sparse(s) => {
            let mut out = vec![0f32; n];
            for (&i, &v) in s.idx.iter().zip(&s.val) {
                let i = i as usize;
                if i >= n {
                    bail!("sparse index {i} out of bounds {n}");
                }
                // lint:allow(panic_safety) bounds-checked against n just above
                out[i] = v;
            }
            Ok(out)
        }
        Encoded::QSparse { idx, q } => {
            let vals = dequantize_values(q);
            if vals.len() != idx.len() {
                bail!("qsparse arity mismatch: {} vs {}", vals.len(), idx.len());
            }
            let mut out = vec![0f32; n];
            for (&i, v) in idx.iter().zip(vals) {
                let i = i as usize;
                if i >= n {
                    bail!("qsparse index {i} out of bounds {n}");
                }
                // lint:allow(panic_safety) bounds-checked against n just above
                out[i] = v;
            }
            Ok(out)
        }
        Encoded::Masked {
            seed,
            keep,
            dense_len,
            inner,
        } => {
            if *dense_len != n {
                bail!("masked dense length {dense_len} != {n}");
            }
            if !(0.0..=1.0).contains(keep) {
                // a hostile wire value must error, not trip the
                // mask generator's assert
                bail!("masked keep fraction {keep} outside [0, 1]");
            }
            let kept = dropout_mask_indices(n, *keep, *seed);
            let vals = match inner.as_ref() {
                Encoded::Dense(v) => v.clone(),
                Encoded::QDense(q) => dequantize_values(q),
                other => bail!("masked inner must be dense-like, got {other:?}"),
            };
            if vals.len() != kept.len() {
                bail!(
                    "masked arity mismatch: {} values for {} kept coords",
                    vals.len(),
                    kept.len()
                );
            }
            let mut out = vec![0f32; n];
            for (&i, v) in kept.iter().zip(vals) {
                // lint:allow(panic_safety) mask indices are < n by construction (regenerated locally, not wire data)
                out[i as usize] = v;
            }
            Ok(out)
        }
        Encoded::PreEncoded(p) => {
            // deserialize the shared bytes back into the underlying
            // encoding (never PreEncoded itself), then decode that;
            // the dense case moves the freshly decoded vector out
            // rather than re-cloning it through the Dense arm
            match crate::network::message::decode_payload(&p.bytes)? {
                Encoded::Dense(v) => {
                    if v.len() != n {
                        bail!("dense length {} != {}", v.len(), n);
                    }
                    Ok(v)
                }
                inner => decompress(&inner, n),
            }
        }
    }
}

/// [`decompress`] for callers that own the encoding: the `Dense` (and
/// pre-encoded dense) payload is moved out instead of cloned — the
/// client-side global-model decode receives a fresh dense vector every
/// round and was paying a full O(P) copy for nothing.
pub fn decompress_owned(enc: Encoded, n: usize) -> Result<Vec<f32>> {
    match enc {
        Encoded::Dense(v) => {
            if v.len() != n {
                bail!("dense length {} != {}", v.len(), n);
            }
            Ok(v)
        }
        // decode_payload materializes the inner encoding owned, so the
        // dense case moves through the arm above
        Encoded::PreEncoded(p) => {
            decompress_owned(crate::network::message::decode_payload(&p.bytes)?, n)
        }
        other => decompress(&other, n),
    }
}

fn dequantize_values(q: &Quantized) -> Vec<f32> {
    // dequantize exactly the stored values (q.n may be the dense len
    // for QSparse)
    match &q.data {
        quantize::QData::I8(v) => v.iter().map(|&x| x as f32 * q.scale).collect(),
        quantize::QData::I16(v) => v.iter().map(|&x| x as f32 * q.scale).collect(),
    }
}

fn k_of(n: usize, frac: f32) -> usize {
    if n == 0 {
        // an empty update keeps an empty encoding — the old
        // `.clamp(1, 0)` panicked here
        return 0;
    }
    ((n as f64 * frac as f64).round() as usize).clamp(1, n)
}

/// Expected wire bytes for an update of `n` dense f32 entries under
/// `cfg` — the analytic counterpart of [`compress`] + [`Encoded::wire_bytes`],
/// used by the virtual-time simulator where no real update exists.
pub fn expected_wire_bytes(n: usize, cfg: &crate::config::CompressionConfig) -> u64 {
    let kept = (n as f64 * cfg.dropout_keep.min(1.0) as f64).round().max(1.0);
    let after_topk = if cfg.topk_frac < 1.0 {
        (kept * cfg.topk_frac as f64).round().max(1.0)
    } else {
        kept
    };
    // top-k survivors need explicit indices; dropout-only uses the
    // seeded Masked encoding (no indices, 16-byte header)
    let idx_bytes = if cfg.topk_frac < 1.0 { 4.0 } else { 0.0 };
    let header = if cfg.topk_frac >= 1.0 && cfg.dropout_keep < 1.0 {
        16.0
    } else {
        0.0
    };
    let value_bytes = match cfg.quant_bits {
        8 => 1.0,
        16 => 2.0,
        _ => 4.0,
    };
    let scale_bytes = if cfg.quant_bits < 32 { 4.0 } else { 0.0 };
    (after_topk * (value_bytes + idx_bytes) + scale_bytes + header) as u64
}

/// Compression accounting for the metrics module / Table 4.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompressionStats {
    pub dense_bytes: u64,
    pub wire_bytes: u64,
}

impl CompressionStats {
    pub fn of(enc: &Encoded) -> Self {
        CompressionStats {
            dense_bytes: 4 * enc.dense_len() as u64,
            wire_bytes: enc.wire_bytes(),
        }
    }

    pub fn ratio(&self) -> f64 {
        self.wire_bytes as f64 / self.dense_bytes.max(1) as f64
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests may panic freely
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn vec_of(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn no_compression_is_identity() {
        let v = vec_of(1000, 0);
        let enc = compress(&v, &CompressionConfig::NONE, 1);
        assert_eq!(enc, Encoded::Dense(v.clone()));
        assert_eq!(decompress(&enc, 1000).unwrap(), v);
        assert_eq!(CompressionStats::of(&enc).ratio(), 1.0);
    }

    #[test]
    fn paper_config_hits_target_reduction() {
        // Table 4: ~45 MB → ~15 MB, i.e. ratio ≈ 0.33; our PAPER config
        // (top-25% + int8) gives 0.25 * (4+1)/4 = ~0.31
        let v = vec_of(100_000, 1);
        let enc = compress(&v, &CompressionConfig::PAPER, 2);
        let r = CompressionStats::of(&enc).ratio();
        assert!((0.25..=0.40).contains(&r), "ratio {r}");
    }

    #[test]
    fn quant_only_roundtrip_error_bounded() {
        let v = vec_of(5000, 2);
        let cfg = CompressionConfig {
            quant_bits: 8,
            topk_frac: 1.0,
            dropout_keep: 1.0,
        };
        let enc = compress(&v, &cfg, 0);
        let back = decompress(&enc, v.len()).unwrap();
        let maxabs = v.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let scale = maxabs / 127.0;
        for (a, b) in v.iter().zip(&back) {
            assert!((a - b).abs() <= scale / 2.0 + 1e-7);
        }
    }

    #[test]
    fn topk_only_keeps_largest() {
        let v = vec![0.1f32, -5.0, 0.2, 3.0, -0.05];
        let cfg = CompressionConfig {
            quant_bits: 32,
            topk_frac: 0.4,
            dropout_keep: 1.0,
        };
        let enc = compress(&v, &cfg, 0);
        let back = decompress(&enc, 5).unwrap();
        assert_eq!(back, vec![0.0, -5.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn dropout_then_decompress_zeroes_masked() {
        let v = vec_of(1000, 3);
        let cfg = CompressionConfig {
            quant_bits: 32,
            topk_frac: 1.0,
            dropout_keep: 0.5,
        };
        let enc = compress(&v, &cfg, 77);
        let back = decompress(&enc, 1000).unwrap();
        let kept = dropout_mask_indices(1000, 0.5, 77);
        let kept_set: std::collections::HashSet<u32> = kept.into_iter().collect();
        for (i, (&a, &b)) in v.iter().zip(&back).enumerate() {
            if kept_set.contains(&(i as u32)) {
                assert_eq!(a, b);
            } else {
                assert_eq!(b, 0.0);
            }
        }
    }

    #[test]
    fn full_pipeline_roundtrip_preserves_survivors() {
        let v = vec_of(10_000, 4);
        let cfg = CompressionConfig {
            quant_bits: 16,
            topk_frac: 0.1,
            dropout_keep: 0.8,
        };
        let enc = compress(&v, &cfg, 5);
        let back = decompress(&enc, v.len()).unwrap();
        // survivors approximate originals; everything else is zero
        let nonzero = back.iter().filter(|&&x| x != 0.0).count();
        let expect = (10_000f64 * 0.8 * 0.1).round() as usize;
        assert!(
            (nonzero as i64 - expect as i64).abs() <= 2,
            "nonzero {nonzero} vs {expect}"
        );
        for (a, b) in v.iter().zip(&back) {
            if *b != 0.0 {
                assert!((a - b).abs() < 0.05 * a.abs().max(0.1));
            }
        }
    }

    #[test]
    fn decompress_rejects_bad_lengths_and_indices() {
        let enc = Encoded::Dense(vec![1.0; 4]);
        assert!(decompress(&enc, 5).is_err());
        let bad = Encoded::Sparse(Sparse {
            idx: vec![10],
            val: vec![1.0],
            dense_len: 5,
        });
        assert!(decompress(&bad, 5).is_err());
    }

    #[test]
    fn pre_encoded_decompresses_like_inner() {
        let v = vec_of(500, 9);
        let pre = Encoded::PreEncoded(crate::network::message::pre_encode(&Encoded::Dense(
            v.clone(),
        )));
        assert_eq!(pre.dense_len(), 500);
        assert_eq!(pre.wire_bytes(), 4 * 500);
        assert_eq!(decompress(&pre, 500).unwrap(), v);
    }

    /// ISSUE satellite regression: an empty update with `topk_frac <
    /// 1.0` (or `dropout_keep < 1.0`) used to panic in `k_of` /
    /// `dropout_mask_indices` via `.clamp(1, 0)`.
    #[test]
    fn empty_update_compresses_to_empty_encoding() {
        for cfg in [
            CompressionConfig::NONE,
            CompressionConfig::PAPER,
            CompressionConfig {
                quant_bits: 8,
                topk_frac: 1.0,
                dropout_keep: 0.5,
            },
            CompressionConfig {
                quant_bits: 32,
                topk_frac: 0.5,
                dropout_keep: 0.5,
            },
        ] {
            let enc = compress(&[], &cfg, 3);
            assert_eq!(enc.dense_len(), 0, "{cfg:?}");
            assert_eq!(decompress(&enc, 0).unwrap(), Vec::<f32>::new());
            let view = DecodedView::of(&enc, 0).unwrap();
            assert_eq!(view.nnz(), 0);
        }
    }

    fn all_encoding_configs() -> Vec<CompressionConfig> {
        vec![
            CompressionConfig::NONE, // Dense
            CompressionConfig {
                quant_bits: 8,
                topk_frac: 1.0,
                dropout_keep: 1.0,
            }, // QDense i8
            CompressionConfig {
                quant_bits: 16,
                topk_frac: 1.0,
                dropout_keep: 1.0,
            }, // QDense i16
            CompressionConfig {
                quant_bits: 32,
                topk_frac: 0.25,
                dropout_keep: 1.0,
            }, // Sparse
            CompressionConfig::PAPER, // QSparse
            CompressionConfig {
                quant_bits: 32,
                topk_frac: 1.0,
                dropout_keep: 0.5,
            }, // Masked + Dense
            CompressionConfig {
                quant_bits: 8,
                topk_frac: 1.0,
                dropout_keep: 0.5,
            }, // Masked + QDense
        ]
    }

    #[test]
    fn decoded_view_matches_decompress_for_every_encoding() {
        let v = vec_of(2000, 11);
        for cfg in all_encoding_configs() {
            let enc = compress(&v, &cfg, 9);
            let dense = decompress(&enc, v.len()).unwrap();
            let pre = Encoded::PreEncoded(crate::network::message::pre_encode(&enc));
            for enc in [enc, pre] {
                let view = DecodedView::of(&enc, v.len()).unwrap();
                assert_eq!(view.dense_len(), v.len());
                // stored pairs come in strictly increasing index order
                // and carry exactly the densified values
                let mut last: Option<usize> = None;
                let mut count = 0usize;
                let mut seen = vec![false; v.len()];
                view.for_each_nonzero(|i, x| {
                    if let Some(p) = last {
                        assert!(p < i, "indices must increase ({p} then {i})");
                    }
                    last = Some(i);
                    assert_eq!(x.to_bits(), dense[i].to_bits(), "{cfg:?} at {i}");
                    seen[i] = true;
                    count += 1;
                });
                assert_eq!(count, view.nnz());
                for (i, s) in seen.iter().enumerate() {
                    if !s {
                        assert_eq!(dense[i], 0.0, "unstored coord {i} must be zero");
                    }
                }
                // write_dense is bit-identical to decompress
                let mut buf = vec![9f32; v.len()];
                view.write_dense(&mut buf);
                for (j, (a, b)) in buf.iter().zip(&dense).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "{cfg:?} write_dense at {j}");
                }
            }
        }
    }

    #[test]
    fn decoded_view_rejects_malformed_encodings() {
        // wrong dense length
        assert!(DecodedView::of(&Encoded::Dense(vec![1.0; 4]), 5).is_err());
        // out-of-bounds index
        let bad = |idx: Vec<u32>, val: Vec<f32>| {
            Encoded::Sparse(Sparse {
                idx,
                val,
                dense_len: 5,
            })
        };
        assert!(DecodedView::of(&bad(vec![10], vec![1.0]), 5).is_err());
        // duplicate / non-increasing indices: densify's last-write-wins
        // cannot be reproduced by a fold, so the view refuses them
        assert!(DecodedView::of(&bad(vec![1, 1], vec![1.0, 2.0]), 5).is_err());
        assert!(DecodedView::of(&bad(vec![3, 1], vec![1.0, 2.0]), 5).is_err());
        // arity mismatch
        assert!(DecodedView::of(&bad(vec![1], vec![1.0, 2.0]), 5).is_err());
        // qdense declared length must match the model size even when
        // the stored value count happens to (decompress parity)
        let bad_qn = Encoded::QDense(Quantized {
            data: QData::I8(vec![0; 5]),
            scale: 1.0,
            n: 4,
        });
        assert!(DecodedView::of(&bad_qn, 5).is_err());
        // declared length right but payload short: both decode paths
        // must error rather than hand back a wrong-length vector
        let bad_arity = Encoded::QDense(Quantized {
            data: QData::I8(vec![0; 3]),
            scale: 1.0,
            n: 5,
        });
        assert!(decompress(&bad_arity, 5).is_err());
        assert!(DecodedView::of(&bad_arity, 5).is_err());
        // hostile keep fraction errors instead of tripping the mask
        // generator's assert — on both decode paths
        let bad_keep = Encoded::Masked {
            seed: 0,
            keep: 2.0,
            dense_len: 4,
            inner: Box::new(Encoded::Dense(vec![0.0; 4])),
        };
        assert!(DecodedView::of(&bad_keep, 4).is_err());
        assert!(decompress(&bad_keep, 4).is_err());
    }

    #[test]
    fn decompress_owned_moves_dense_out() {
        let v = vec![1.0f32, 2.0, 3.0];
        let ptr = v.as_ptr();
        let out = decompress_owned(Encoded::Dense(v), 3).unwrap();
        assert_eq!(out.as_ptr(), ptr, "owned dense decode must not copy");
        assert!(decompress_owned(Encoded::Dense(vec![1.0]), 3).is_err());
        // pre-encoded dense moves the freshly decoded vector out too
        let pre = Encoded::PreEncoded(crate::network::message::pre_encode_dense(&[1.5, -2.5]));
        assert_eq!(decompress_owned(pre, 2).unwrap(), vec![1.5, -2.5]);
        // non-dense encodings fall through to the borrowed path
        let sp = compress(&vec_of(100, 2), &CompressionConfig::PAPER, 1);
        let a = decompress(&sp, 100).unwrap();
        let b = decompress_owned(sp, 100).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn wire_bytes_accounting() {
        let v = vec_of(1000, 6);
        let enc8 = compress(
            &v,
            &CompressionConfig {
                quant_bits: 8,
                topk_frac: 1.0,
                dropout_keep: 1.0,
            },
            0,
        );
        assert_eq!(enc8.wire_bytes(), 1000 + 4); // i8 payload + f32 scale
        let enc16 = compress(
            &v,
            &CompressionConfig {
                quant_bits: 16,
                topk_frac: 1.0,
                dropout_keep: 1.0,
            },
            0,
        );
        assert_eq!(enc16.wire_bytes(), 2000 + 4);
    }
}
