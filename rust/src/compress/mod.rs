//! Communication-efficient update encoding (paper §4.3, Table 1).
//!
//! The codec pipeline transforms a dense f32 update vector into a
//! compact wire payload and back:
//!
//! ```text
//! dense Δ ──(federated dropout mask)──(top-k sparsify)──(quantize)──> payload
//! ```
//!
//! Semantics are bit-matched to the L1 Pallas kernels (same scale rule,
//! same round-half-even, same pessimistic tie handling) — pinned by
//! tests against values exported from the Python oracle.

mod dropout;
mod quantize;
mod sparsify;

pub use dropout::{dropout_mask_indices, DropoutMask};
pub use quantize::{dequantize, quantize, QData, QuantBits, Quantized};
pub use sparsify::{sparsify_topk, Sparse};

use crate::config::CompressionConfig;
use anyhow::{bail, Result};

/// A wire-ready encoded update.
#[derive(Debug, Clone, PartialEq)]
pub enum Encoded {
    /// Raw f32 (compression off).
    Dense(Vec<f32>),
    /// Quantized dense values.
    QDense(Quantized),
    /// Sparse f32 (indices + values).
    Sparse(Sparse),
    /// Sparse + quantized values.
    QSparse { idx: Vec<u32>, q: Quantized },
    /// Federated-dropout masked values: the kept-coordinate set is
    /// derived from `(seed, keep, dense_len)` on BOTH sides, so only
    /// the seed + payload cross the wire (no indices — that is the
    /// entire bandwidth win of federated dropout). `inner` is the
    /// Dense or QDense encoding of the kept values, in mask order.
    Masked {
        seed: u64,
        keep: f32,
        dense_len: usize,
        inner: Box<Encoded>,
    },
    /// An encoding already serialized to its codec bytes, shared behind
    /// an `Arc`. The orchestrator pre-encodes the round's model payload
    /// once and every broadcast send clones only the pointer; on the
    /// wire the bytes are indistinguishable from the underlying
    /// encoding (the decoder never produces this variant).
    PreEncoded(PreEncoded),
}

/// Shared, pre-serialized payload: the exact bytes the wire codec
/// (`network::message`) writes for the underlying encoding, plus the
/// metadata needed for accounting without re-decoding.
#[derive(Debug, Clone, PartialEq)]
pub struct PreEncoded {
    /// Serialized encoding (codec tag + body).
    pub bytes: std::sync::Arc<[u8]>,
    /// Logical decoded length of the underlying encoding.
    pub dense_len: usize,
    /// `wire_bytes()` of the underlying encoding.
    pub wire: u64,
}

impl Encoded {
    /// Bytes this encoding occupies on the wire (payload only; framing
    /// overhead is accounted by the transport).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Encoded::Dense(v) => 4 * v.len() as u64,
            Encoded::QDense(q) => q.wire_bytes(),
            Encoded::Sparse(s) => 8 * s.idx.len() as u64, // 4B idx + 4B val
            Encoded::QSparse { idx, q } => 4 * idx.len() as u64 + q.wire_bytes(),
            Encoded::Masked { inner, .. } => 16 + inner.wire_bytes(),
            Encoded::PreEncoded(p) => p.wire,
        }
    }

    /// Logical (decoded) length.
    pub fn dense_len(&self) -> usize {
        match self {
            Encoded::Dense(v) => v.len(),
            Encoded::QDense(q) => q.n,
            Encoded::Sparse(s) => s.dense_len,
            Encoded::QSparse { q, .. } => q.n,
            Encoded::Masked { dense_len, .. } => *dense_len,
            Encoded::PreEncoded(p) => p.dense_len,
        }
    }
}

/// Compress a dense update under the given config.
///
/// `mask_seed` derives the federated-dropout mask; the orchestrator
/// uses the same (round, client) seed to know which coordinates were
/// trained, so only the seed crosses the wire.
pub fn compress(update: &[f32], cfg: &CompressionConfig, mask_seed: u64) -> Encoded {
    // 1. federated dropout: keep a seeded coordinate subset
    let dropped: Option<(Vec<u32>, Vec<f32>)> = if cfg.dropout_keep < 1.0 {
        let keep = dropout_mask_indices(update.len(), cfg.dropout_keep, mask_seed);
        let vals = keep.iter().map(|&i| update[i as usize]).collect();
        Some((keep, vals))
    } else {
        None
    };

    // 2. top-k sparsification (within the kept coordinates)
    let sparsified: Option<(Vec<u32>, Vec<f32>)> = if cfg.topk_frac < 1.0 {
        match &dropped {
            Some((idx, vals)) => {
                let k = k_of(vals.len(), cfg.topk_frac);
                let s = sparsify_topk(vals, k);
                let gidx: Vec<u32> = s.idx.iter().map(|&i| idx[i as usize]).collect();
                Some((gidx, s.val))
            }
            None => {
                let k = k_of(update.len(), cfg.topk_frac);
                let s = sparsify_topk(update, k);
                Some((s.idx, s.val))
            }
        }
    } else {
        dropped
    };

    // 3. quantization + encoding selection.
    // dropout WITHOUT top-k → seeded Masked encoding (no indices on the
    // wire — both sides regenerate the mask from the seed). Top-k
    // survivors are data-dependent, so those need explicit indices.
    let bits = QuantBits::from_u8(cfg.quant_bits);
    if cfg.topk_frac >= 1.0 {
        if let Some((_, vals)) = sparsified {
            let inner = match bits {
                None => Encoded::Dense(vals),
                Some(b) => Encoded::QDense(quantize(&vals, b)),
            };
            return Encoded::Masked {
                seed: mask_seed,
                keep: cfg.dropout_keep,
                dense_len: update.len(),
                inner: Box::new(inner),
            };
        }
    }
    match (sparsified, bits) {
        (None, None) => Encoded::Dense(update.to_vec()),
        (None, Some(b)) => Encoded::QDense(quantize(update, b)),
        (Some((idx, vals)), None) => Encoded::Sparse(Sparse {
            idx,
            val: vals,
            dense_len: update.len(),
        }),
        (Some((idx, vals)), Some(b)) => {
            let mut q = quantize(&vals, b);
            q.n = update.len(); // decoded length is the full vector
            Encoded::QSparse { idx, q }
        }
    }
}

/// Decompress back to a dense vector of length `n`.
pub fn decompress(enc: &Encoded, n: usize) -> Result<Vec<f32>> {
    match enc {
        Encoded::Dense(v) => {
            if v.len() != n {
                bail!("dense length {} != {}", v.len(), n);
            }
            Ok(v.clone())
        }
        Encoded::QDense(q) => {
            if q.n != n {
                bail!("qdense length {} != {}", q.n, n);
            }
            Ok(dequantize(q))
        }
        Encoded::Sparse(s) => {
            let mut out = vec![0f32; n];
            for (&i, &v) in s.idx.iter().zip(&s.val) {
                let i = i as usize;
                if i >= n {
                    bail!("sparse index {i} out of bounds {n}");
                }
                out[i] = v;
            }
            Ok(out)
        }
        Encoded::QSparse { idx, q } => {
            let vals = dequantize_values(q);
            if vals.len() != idx.len() {
                bail!("qsparse arity mismatch: {} vs {}", vals.len(), idx.len());
            }
            let mut out = vec![0f32; n];
            for (&i, v) in idx.iter().zip(vals) {
                let i = i as usize;
                if i >= n {
                    bail!("qsparse index {i} out of bounds {n}");
                }
                out[i] = v;
            }
            Ok(out)
        }
        Encoded::Masked {
            seed,
            keep,
            dense_len,
            inner,
        } => {
            if *dense_len != n {
                bail!("masked dense length {dense_len} != {n}");
            }
            let kept = dropout_mask_indices(n, *keep, *seed);
            let vals = match inner.as_ref() {
                Encoded::Dense(v) => v.clone(),
                Encoded::QDense(q) => dequantize_values(q),
                other => bail!("masked inner must be dense-like, got {other:?}"),
            };
            if vals.len() != kept.len() {
                bail!(
                    "masked arity mismatch: {} values for {} kept coords",
                    vals.len(),
                    kept.len()
                );
            }
            let mut out = vec![0f32; n];
            for (&i, v) in kept.iter().zip(vals) {
                out[i as usize] = v;
            }
            Ok(out)
        }
        Encoded::PreEncoded(p) => {
            // deserialize the shared bytes back into the underlying
            // encoding (never PreEncoded itself), then decode that;
            // the dense case moves the freshly decoded vector out
            // rather than re-cloning it through the Dense arm
            match crate::network::message::decode_payload(&p.bytes)? {
                Encoded::Dense(v) => {
                    if v.len() != n {
                        bail!("dense length {} != {}", v.len(), n);
                    }
                    Ok(v)
                }
                inner => decompress(&inner, n),
            }
        }
    }
}

fn dequantize_values(q: &Quantized) -> Vec<f32> {
    // dequantize exactly the stored values (q.n may be the dense len
    // for QSparse)
    match &q.data {
        quantize::QData::I8(v) => v.iter().map(|&x| x as f32 * q.scale).collect(),
        quantize::QData::I16(v) => v.iter().map(|&x| x as f32 * q.scale).collect(),
    }
}

fn k_of(n: usize, frac: f32) -> usize {
    ((n as f64 * frac as f64).round() as usize).clamp(1, n)
}

/// Expected wire bytes for an update of `n` dense f32 entries under
/// `cfg` — the analytic counterpart of [`compress`] + [`Encoded::wire_bytes`],
/// used by the virtual-time simulator where no real update exists.
pub fn expected_wire_bytes(n: usize, cfg: &crate::config::CompressionConfig) -> u64 {
    let kept = (n as f64 * cfg.dropout_keep.min(1.0) as f64).round().max(1.0);
    let after_topk = if cfg.topk_frac < 1.0 {
        (kept * cfg.topk_frac as f64).round().max(1.0)
    } else {
        kept
    };
    // top-k survivors need explicit indices; dropout-only uses the
    // seeded Masked encoding (no indices, 16-byte header)
    let idx_bytes = if cfg.topk_frac < 1.0 { 4.0 } else { 0.0 };
    let header = if cfg.topk_frac >= 1.0 && cfg.dropout_keep < 1.0 {
        16.0
    } else {
        0.0
    };
    let value_bytes = match cfg.quant_bits {
        8 => 1.0,
        16 => 2.0,
        _ => 4.0,
    };
    let scale_bytes = if cfg.quant_bits < 32 { 4.0 } else { 0.0 };
    (after_topk * (value_bytes + idx_bytes) + scale_bytes + header) as u64
}

/// Compression accounting for the metrics module / Table 4.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompressionStats {
    pub dense_bytes: u64,
    pub wire_bytes: u64,
}

impl CompressionStats {
    pub fn of(enc: &Encoded) -> Self {
        CompressionStats {
            dense_bytes: 4 * enc.dense_len() as u64,
            wire_bytes: enc.wire_bytes(),
        }
    }

    pub fn ratio(&self) -> f64 {
        self.wire_bytes as f64 / self.dense_bytes.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn vec_of(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn no_compression_is_identity() {
        let v = vec_of(1000, 0);
        let enc = compress(&v, &CompressionConfig::NONE, 1);
        assert_eq!(enc, Encoded::Dense(v.clone()));
        assert_eq!(decompress(&enc, 1000).unwrap(), v);
        assert_eq!(CompressionStats::of(&enc).ratio(), 1.0);
    }

    #[test]
    fn paper_config_hits_target_reduction() {
        // Table 4: ~45 MB → ~15 MB, i.e. ratio ≈ 0.33; our PAPER config
        // (top-25% + int8) gives 0.25 * (4+1)/4 = ~0.31
        let v = vec_of(100_000, 1);
        let enc = compress(&v, &CompressionConfig::PAPER, 2);
        let r = CompressionStats::of(&enc).ratio();
        assert!((0.25..=0.40).contains(&r), "ratio {r}");
    }

    #[test]
    fn quant_only_roundtrip_error_bounded() {
        let v = vec_of(5000, 2);
        let cfg = CompressionConfig {
            quant_bits: 8,
            topk_frac: 1.0,
            dropout_keep: 1.0,
        };
        let enc = compress(&v, &cfg, 0);
        let back = decompress(&enc, v.len()).unwrap();
        let maxabs = v.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let scale = maxabs / 127.0;
        for (a, b) in v.iter().zip(&back) {
            assert!((a - b).abs() <= scale / 2.0 + 1e-7);
        }
    }

    #[test]
    fn topk_only_keeps_largest() {
        let v = vec![0.1f32, -5.0, 0.2, 3.0, -0.05];
        let cfg = CompressionConfig {
            quant_bits: 32,
            topk_frac: 0.4,
            dropout_keep: 1.0,
        };
        let enc = compress(&v, &cfg, 0);
        let back = decompress(&enc, 5).unwrap();
        assert_eq!(back, vec![0.0, -5.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn dropout_then_decompress_zeroes_masked() {
        let v = vec_of(1000, 3);
        let cfg = CompressionConfig {
            quant_bits: 32,
            topk_frac: 1.0,
            dropout_keep: 0.5,
        };
        let enc = compress(&v, &cfg, 77);
        let back = decompress(&enc, 1000).unwrap();
        let kept = dropout_mask_indices(1000, 0.5, 77);
        let kept_set: std::collections::HashSet<u32> = kept.into_iter().collect();
        for (i, (&a, &b)) in v.iter().zip(&back).enumerate() {
            if kept_set.contains(&(i as u32)) {
                assert_eq!(a, b);
            } else {
                assert_eq!(b, 0.0);
            }
        }
    }

    #[test]
    fn full_pipeline_roundtrip_preserves_survivors() {
        let v = vec_of(10_000, 4);
        let cfg = CompressionConfig {
            quant_bits: 16,
            topk_frac: 0.1,
            dropout_keep: 0.8,
        };
        let enc = compress(&v, &cfg, 5);
        let back = decompress(&enc, v.len()).unwrap();
        // survivors approximate originals; everything else is zero
        let nonzero = back.iter().filter(|&&x| x != 0.0).count();
        let expect = (10_000f64 * 0.8 * 0.1).round() as usize;
        assert!(
            (nonzero as i64 - expect as i64).abs() <= 2,
            "nonzero {nonzero} vs {expect}"
        );
        for (a, b) in v.iter().zip(&back) {
            if *b != 0.0 {
                assert!((a - b).abs() < 0.05 * a.abs().max(0.1));
            }
        }
    }

    #[test]
    fn decompress_rejects_bad_lengths_and_indices() {
        let enc = Encoded::Dense(vec![1.0; 4]);
        assert!(decompress(&enc, 5).is_err());
        let bad = Encoded::Sparse(Sparse {
            idx: vec![10],
            val: vec![1.0],
            dense_len: 5,
        });
        assert!(decompress(&bad, 5).is_err());
    }

    #[test]
    fn pre_encoded_decompresses_like_inner() {
        let v = vec_of(500, 9);
        let pre = Encoded::PreEncoded(crate::network::message::pre_encode(&Encoded::Dense(
            v.clone(),
        )));
        assert_eq!(pre.dense_len(), 500);
        assert_eq!(pre.wire_bytes(), 4 * 500);
        assert_eq!(decompress(&pre, 500).unwrap(), v);
    }

    #[test]
    fn wire_bytes_accounting() {
        let v = vec_of(1000, 6);
        let enc8 = compress(
            &v,
            &CompressionConfig {
                quant_bits: 8,
                topk_frac: 1.0,
                dropout_keep: 1.0,
            },
            0,
        );
        assert_eq!(enc8.wire_bytes(), 1000 + 4); // i8 payload + f32 scale
        let enc16 = compress(
            &v,
            &CompressionConfig {
                quant_bits: 16,
                topk_frac: 1.0,
                dropout_keep: 1.0,
            },
            0,
        );
        assert_eq!(enc16.wire_bytes(), 2000 + 4);
    }
}
