//! Top-k magnitude sparsification, matching the L1 kernel's threshold
//! semantics: compute the k-th largest |g| and keep every entry with
//! `|g| >= t` (ties kept pessimistically, like the kernel's mask pass).
//!
//! Selection is O(n) via `select_nth_unstable` on magnitudes — the
//! radix-select replacement for CPU (DESIGN.md §Hardware-Adaptation).

/// Sparse update: parallel (index, value) arrays plus the dense length.
#[derive(Debug, Clone, PartialEq)]
pub struct Sparse {
    pub idx: Vec<u32>,
    pub val: Vec<f32>,
    pub dense_len: usize,
}

/// Keep the top-k entries of `g` by |magnitude|.
pub fn sparsify_topk(g: &[f32], k: usize) -> Sparse {
    let n = g.len();
    let k = k.clamp(1, n.max(1));
    if n == 0 {
        return Sparse {
            idx: vec![],
            val: vec![],
            dense_len: 0,
        };
    }
    if k >= n {
        return Sparse {
            idx: (0..n as u32).collect(),
            val: g.to_vec(),
            dense_len: n,
        };
    }
    // threshold = k-th largest magnitude (kernel parity: |g| >= t kept)
    let mut mags: Vec<f32> = g.iter().map(|x| x.abs()).collect();
    let (_, t, _) = mags.select_nth_unstable_by(k - 1, |a, b| b.total_cmp(a));
    let t = *t;
    let mut idx = Vec::with_capacity(k + 8);
    let mut val = Vec::with_capacity(k + 8);
    for (i, &x) in g.iter().enumerate() {
        if x.abs() >= t {
            idx.push(i as u32);
            val.push(x);
        }
    }
    Sparse {
        idx,
        val,
        dense_len: n,
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests may panic freely
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn keeps_exactly_k_distinct_magnitudes() {
        let mut rng = Rng::new(0);
        let g: Vec<f32> = (0..5000).map(|_| rng.normal() as f32).collect();
        let s = sparsify_topk(&g, 500);
        assert_eq!(s.idx.len(), 500); // continuous values → no ties
        assert_eq!(s.dense_len, 5000);
        // survivors are the actual top 500
        let mut mags: Vec<f32> = g.iter().map(|x| x.abs()).collect();
        mags.sort_by(|a, b| b.total_cmp(a));
        let t = mags[499];
        for &i in &s.idx {
            assert!(g[i as usize].abs() >= t);
        }
    }

    #[test]
    fn ties_kept_pessimistically() {
        let g = vec![1.0f32, -1.0, 1.0, 0.5];
        let s = sparsify_topk(&g, 2);
        // threshold is 1.0; all three 1.0-magnitude entries survive
        assert_eq!(s.idx, vec![0, 1, 2]);
    }

    #[test]
    fn k_bounds() {
        let g = vec![3.0f32, 1.0, 2.0];
        let all = sparsify_topk(&g, 10);
        assert_eq!(all.idx.len(), 3);
        let one = sparsify_topk(&g, 0); // clamps to 1
        assert_eq!(one.idx, vec![0]);
        let empty = sparsify_topk(&[], 5);
        assert_eq!(empty.dense_len, 0);
        assert!(empty.idx.is_empty());
    }

    #[test]
    fn indices_sorted_and_in_bounds() {
        let mut rng = Rng::new(1);
        let g: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        let s = sparsify_topk(&g, 100);
        assert!(s.idx.windows(2).all(|w| w[0] < w[1]));
        assert!(s.idx.iter().all(|&i| (i as usize) < 1000));
        for (&i, &v) in s.idx.iter().zip(&s.val) {
            assert_eq!(g[i as usize], v);
        }
    }

    #[test]
    fn preserves_signs() {
        let g = vec![-10.0f32, 0.1, 9.0, -0.2];
        let s = sparsify_topk(&g, 2);
        assert_eq!(s.idx, vec![0, 2]);
        assert_eq!(s.val, vec![-10.0, 9.0]);
    }
}
