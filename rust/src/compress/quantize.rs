//! Symmetric per-tensor quantization, bit-matched to the L1 kernel
//! (`python/compile/kernels/compress.py::quantize`):
//!
//! * `scale = max|g| / qmax`, or 1.0 for all-zero vectors,
//! * `q = clip(round_half_even(g / scale), -qmax, qmax)` — jnp.round
//!   rounds half-to-even, so we must too.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantBits {
    B8,
    B16,
}

impl QuantBits {
    pub fn from_u8(bits: u8) -> Option<QuantBits> {
        match bits {
            8 => Some(QuantBits::B8),
            16 => Some(QuantBits::B16),
            _ => None, // 32 = off
        }
    }

    pub fn qmax(self) -> f32 {
        match self {
            QuantBits::B8 => 127.0,
            QuantBits::B16 => 32767.0,
        }
    }
}

/// Quantized payload storage.
#[derive(Debug, Clone, PartialEq)]
pub enum QData {
    I8(Vec<i8>),
    I16(Vec<i16>),
}

impl QData {
    pub fn len(&self) -> usize {
        match self {
            QData::I8(v) => v.len(),
            QData::I16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A quantized vector: integer payload + scale. `n` is the *decoded*
/// length (== payload length for dense use; the full dense length when
/// used inside a sparse encoding).
#[derive(Debug, Clone, PartialEq)]
pub struct Quantized {
    pub data: QData,
    pub scale: f32,
    pub n: usize,
}

impl Quantized {
    pub fn wire_bytes(&self) -> u64 {
        let payload = match &self.data {
            QData::I8(v) => v.len() as u64,
            QData::I16(v) => 2 * v.len() as u64,
        };
        payload + 4 // + f32 scale
    }
}

/// Quantize `g` with the kernel's exact semantics.
///
/// Hot path (every update, every round): both the |g| max-reduce and
/// the round/clip pass are data-parallel over chunks; per-element math
/// is unchanged (true division + round-half-even), so the output is
/// bit-identical to the serial implementation and the L1 kernel.
pub fn quantize(g: &[f32], bits: QuantBits) -> Quantized {
    const MIN_CHUNK: usize = 64 * 1024;
    let qmax = bits.qmax();
    let absmax = crate::util::parallel::par_fold(
        g,
        MIN_CHUNK,
        |_, c| c.iter().fold(0f32, |m, &x| m.max(x.abs())),
        f32::max,
    )
    .unwrap_or(0.0);
    let scale = if absmax > 0.0 { absmax / qmax } else { 1.0 };
    let data = match bits {
        QuantBits::B8 => {
            let mut out = vec![0i8; g.len()];
            crate::util::parallel::par_chunks_mut(&mut out, MIN_CHUNK, |offset, chunk| {
                // lint:allow(panic_safety) out.len() == g.len(), so every chunk subrange is in bounds
                let src = &g[offset..offset + chunk.len()];
                for (o, &x) in chunk.iter_mut().zip(src) {
                    *o = (x / scale).round_ties_even().clamp(-qmax, qmax) as i8;
                }
            });
            QData::I8(out)
        }
        QuantBits::B16 => {
            let mut out = vec![0i16; g.len()];
            crate::util::parallel::par_chunks_mut(&mut out, MIN_CHUNK, |offset, chunk| {
                // lint:allow(panic_safety) out.len() == g.len(), so every chunk subrange is in bounds
                let src = &g[offset..offset + chunk.len()];
                for (o, &x) in chunk.iter_mut().zip(src) {
                    *o = (x / scale).round_ties_even().clamp(-qmax, qmax) as i16;
                }
            });
            QData::I16(out)
        }
    };
    Quantized {
        data,
        scale,
        n: g.len(),
    }
}

/// Dequantize a dense quantized vector.
pub fn dequantize(q: &Quantized) -> Vec<f32> {
    match &q.data {
        QData::I8(v) => v.iter().map(|&x| x as f32 * q.scale).collect(),
        QData::I16(v) => v.iter().map(|&x| x as f32 * q.scale).collect(),
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests may panic freely
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn scale_rule_matches_kernel() {
        let q = quantize(&[1.0, -2.0, 0.5], QuantBits::B8);
        assert_eq!(q.scale, 2.0 / 127.0);
        let z = quantize(&[0.0; 10], QuantBits::B8);
        assert_eq!(z.scale, 1.0);
        assert_eq!(dequantize(&z), vec![0.0; 10]);
    }

    #[test]
    fn extremes_hit_qmax() {
        let q = quantize(&[3.0, -3.0, 1.5], QuantBits::B8);
        match &q.data {
            QData::I8(v) => assert_eq!(&v[..2], &[127, -127]),
            _ => unreachable!(),
        }
    }

    #[test]
    fn round_half_even() {
        // values exactly at .5 quantization boundaries must round to even
        // scale = 2.0 (absmax 254); 1.0/2.0 = 0.5 -> rounds to 0 (even),
        // 3.0/2.0 = 1.5 -> rounds to 2
        let q = quantize(&[254.0, 1.0, 3.0], QuantBits::B8);
        match &q.data {
            QData::I8(v) => {
                assert_eq!(v[0], 127);
                assert_eq!(v[1], 0, "0.5 must round to even (0)");
                assert_eq!(v[2], 2, "1.5 must round to even (2)");
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn roundtrip_error_bound() {
        let mut rng = Rng::new(0);
        for bits in [QuantBits::B8, QuantBits::B16] {
            let g: Vec<f32> = (0..4096).map(|_| rng.normal() as f32 * 3.0).collect();
            let q = quantize(&g, bits);
            let back = dequantize(&q);
            for (a, b) in g.iter().zip(&back) {
                assert!(
                    (a - b).abs() <= q.scale / 2.0 + 1e-6,
                    "err {} > {}",
                    (a - b).abs(),
                    q.scale / 2.0
                );
            }
        }
    }

    #[test]
    fn b16_more_precise_than_b8() {
        let mut rng = Rng::new(1);
        let g: Vec<f32> = (0..2048).map(|_| rng.normal() as f32).collect();
        let err = |bits| {
            let q = quantize(&g, bits);
            let back = dequantize(&q);
            g.iter()
                .zip(&back)
                .map(|(a, b)| (a - b).abs() as f64)
                .sum::<f64>()
        };
        assert!(err(QuantBits::B16) < err(QuantBits::B8) / 50.0);
    }

    #[test]
    fn wire_bytes() {
        let g = vec![1.0f32; 100];
        assert_eq!(quantize(&g, QuantBits::B8).wire_bytes(), 104);
        assert_eq!(quantize(&g, QuantBits::B16).wire_bytes(), 204);
    }
}
