//! Cluster instantiation: config → a concrete set of [`Node`]s with
//! per-node speed samples, availability models and link classes.

use super::{catalog::lookup_sku, AvailabilityModel, Domain, LinkClass, NodeSku};
use crate::config::{ClusterConfig, GroupingPolicy};
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// Stable node identifier (also the FL client id).
pub type NodeId = u32;

/// A concrete node instance in the testbed.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub sku: &'static NodeSku,
    /// This instance's speed (SKU speed × per-instance lottery): two
    /// "identical" VMs never perform identically in practice.
    pub speed_factor: f64,
    pub availability: AvailabilityModel,
}

impl Node {
    pub fn domain(&self) -> Domain {
        self.sku.domain
    }

    pub fn link(&self) -> LinkClass {
        self.sku.link
    }

    /// Sample this node's wall-clock duration for `work_s` seconds of
    /// reference-node compute, including tenancy jitter.
    pub fn compute_time_s(&self, work_s: f64, rng: &mut Rng) -> f64 {
        let base = work_s / self.speed_factor.max(1e-9);
        let jitter = 1.0 + self.sku.jitter * rng.normal();
        base * jitter.max(0.2)
    }

    /// Transfer time for `bytes` over this node's link (one direction).
    pub fn transfer_time_s(&self, bytes: u64) -> f64 {
        let (bw, lat_ms) = self.link().profile();
        lat_ms / 1e3 + bytes as f64 / bw
    }
}

/// The instantiated testbed.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub nodes: Vec<Node>,
}

impl Cluster {
    /// Build from config; deterministic in `seed`.
    pub fn build(cfg: &ClusterConfig, seed: u64) -> Result<Cluster> {
        let mut rng = Rng::new(seed ^ 0xC1F5_7E12);
        let mut nodes = Vec::new();
        let mut id: NodeId = 0;
        for (sku_name, count) in &cfg.nodes {
            let Some(sku) = lookup_sku(sku_name) else {
                bail!(
                    "unknown SKU '{sku_name}'; available: {:?}",
                    super::catalog().iter().map(|s| s.name).collect::<Vec<_>>()
                );
            };
            for _ in 0..*count {
                // per-instance silicon/tenancy lottery: ±10%
                let lottery = 1.0 + 0.1 * rng.normal();
                nodes.push(Node {
                    id,
                    sku,
                    speed_factor: (sku.speed_factor * lottery.clamp(0.5, 1.5)).max(1e-6),
                    availability: AvailabilityModel::new(sku.preempt_per_hour),
                });
                id += 1;
            }
        }
        Ok(Cluster { nodes })
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: NodeId) -> Option<&Node> {
        self.nodes.get(id as usize)
    }

    pub fn by_domain(&self, d: Domain) -> impl Iterator<Item = &Node> {
        self.nodes.iter().filter(move |n| n.domain() == d)
    }

    /// Summary line for logs: counts per SKU.
    pub fn describe(&self) -> String {
        let mut counts: Vec<(&str, usize)> = Vec::new();
        for n in &self.nodes {
            match counts.iter_mut().find(|(name, _)| *name == n.sku.name) {
                Some((_, c)) => *c += 1,
                None => counts.push((n.sku.name, 1)),
            }
        }
        counts
            .iter()
            .map(|(name, c)| format!("{c}×{name}"))
            .collect::<Vec<_>>()
            .join(", ")
    }
}

/// Deterministic partition of a cluster's node ids into aggregation
/// sites — the tree shape of the hierarchical aggregation plane
/// (`orchestrator::hierarchy`). Derivable from the cluster config
/// alone (no RNG, no built [`Cluster`] needed), so the root, every
/// aggregator, every worker and both sim engines reconstruct the
/// identical tree from the shared experiment config.
///
/// Site ids are dense `0..n_sites()`; members are ascending node ids.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SiteMap {
    /// Site index per node, indexed by [`NodeId`].
    assignment: Vec<usize>,
    /// Member node ids per site, each ascending.
    members: Vec<Vec<NodeId>>,
}

impl SiteMap {
    /// Build the site partition for `cfg` under `policy`:
    ///
    /// * `flat` — one site holding every node (the degenerate
    ///   single-server tree).
    /// * `site:<n>` — `n` contiguous, balanced blocks of node ids
    ///   (node `i` lands in site `i·n / total`).
    /// * `zone` — one site per non-empty `(sku, count)` entry, in
    ///   entry order ([`Cluster::build`] assigns ids sequentially per
    ///   entry, so a zone is exactly one entry's id range).
    pub fn build(cfg: &ClusterConfig, policy: GroupingPolicy) -> Result<SiteMap> {
        let total = cfg.total_nodes();
        if total == 0 {
            bail!("site map: cluster has no nodes");
        }
        let assignment: Vec<usize> = match policy {
            GroupingPolicy::Flat => vec![0; total],
            GroupingPolicy::Site { sites } => {
                if sites == 0 || sites > total {
                    bail!("site map: {sites} sites over {total} nodes");
                }
                (0..total).map(|i| i * sites / total).collect()
            }
            GroupingPolicy::Zone => {
                let mut a = Vec::with_capacity(total);
                let mut zone = 0usize;
                for (_, count) in &cfg.nodes {
                    if *count == 0 {
                        continue; // empty entries produce no site
                    }
                    let len = a.len();
                    a.resize(len + *count, zone);
                    zone += 1;
                }
                a
            }
        };
        let n_sites = assignment.iter().copied().max().map_or(0, |m| m + 1);
        let mut members: Vec<Vec<NodeId>> = vec![Vec::new(); n_sites];
        for (id, &site) in assignment.iter().enumerate() {
            if let Some(m) = members.get_mut(site) {
                m.push(id as NodeId);
            }
        }
        Ok(SiteMap {
            assignment,
            members,
        })
    }

    /// Number of sites (every site has at least one member).
    pub fn n_sites(&self) -> usize {
        self.members.len()
    }

    /// Which site a node belongs to; `None` for ids outside the
    /// cluster.
    pub fn site_of(&self, id: NodeId) -> Option<usize> {
        self.assignment.get(id as usize).copied()
    }

    /// A site's member node ids, ascending. Empty for unknown sites.
    pub fn members(&self, site: usize) -> &[NodeId] {
        self.members.get(site).map_or(&[], Vec::as_slice)
    }

    /// The site's stable representative (lowest member id) — the
    /// client id its aggregator reports upstream under.
    pub fn representative(&self, site: usize) -> Option<NodeId> {
        self.members.get(site).and_then(|m| m.first().copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ClusterConfig {
        ClusterConfig {
            nodes: vec![
                ("hpc-rtx6000".into(), 3),
                ("t3.large".into(), 2),
                ("p3.2xlarge-spot".into(), 1),
            ],
            cloud_backend: "inproc".into(),
            hpc_backend: "inproc".into(),
        }
    }

    #[test]
    fn build_assigns_sequential_ids() {
        let c = Cluster::build(&cfg(), 1).unwrap();
        assert_eq!(c.len(), 6);
        for (i, n) in c.nodes.iter().enumerate() {
            assert_eq!(n.id as usize, i);
        }
    }

    #[test]
    fn build_is_deterministic() {
        let a = Cluster::build(&cfg(), 5).unwrap();
        let b = Cluster::build(&cfg(), 5).unwrap();
        for (x, y) in a.nodes.iter().zip(&b.nodes) {
            assert_eq!(x.speed_factor, y.speed_factor);
        }
        let c = Cluster::build(&cfg(), 6).unwrap();
        assert!(a
            .nodes
            .iter()
            .zip(&c.nodes)
            .any(|(x, y)| x.speed_factor != y.speed_factor));
    }

    #[test]
    fn unknown_sku_rejected() {
        let mut bad = cfg();
        bad.nodes.push(("quantum-node".into(), 1));
        assert!(Cluster::build(&bad, 0).is_err());
    }

    #[test]
    fn per_instance_speeds_vary_but_track_sku() {
        let c = Cluster::build(&cfg(), 2).unwrap();
        let rtx: Vec<f64> = c
            .by_domain(Domain::Hpc)
            .map(|n| n.speed_factor)
            .collect();
        assert_eq!(rtx.len(), 3);
        assert!(rtx.iter().any(|&s| s != rtx[0]), "lottery should vary");
        for s in rtx {
            assert!((0.5..=1.5).contains(&s));
        }
    }

    #[test]
    fn compute_time_faster_on_faster_nodes() {
        let c = Cluster::build(&cfg(), 3).unwrap();
        let mut rng = Rng::new(0);
        let gpu = &c.nodes[0]; // hpc-rtx6000
        let cpu = &c.nodes[3]; // t3.large
        let tg: f64 = (0..20).map(|_| gpu.compute_time_s(10.0, &mut rng)).sum();
        let tc: f64 = (0..20).map(|_| cpu.compute_time_s(10.0, &mut rng)).sum();
        assert!(tc > tg * 5.0, "cpu {tc} vs gpu {tg}");
    }

    #[test]
    fn transfer_time_reflects_link_class() {
        let c = Cluster::build(&cfg(), 4).unwrap();
        let hpc = &c.nodes[0];
        let wan = &c.nodes[3];
        let payload = 45 * 1024 * 1024; // paper Table 4: ~45 MB model
        assert!(wan.transfer_time_s(payload) > 10.0 * hpc.transfer_time_s(payload));
    }

    #[test]
    fn site_map_flat_is_one_site() {
        let m = SiteMap::build(&cfg(), GroupingPolicy::Flat).unwrap();
        assert_eq!(m.n_sites(), 1);
        assert_eq!(m.members(0).len(), 6);
        assert_eq!(m.site_of(5), Some(0));
        assert_eq!(m.site_of(6), None);
    }

    #[test]
    fn site_map_contiguous_blocks_are_balanced() {
        let m = SiteMap::build(&cfg(), GroupingPolicy::Site { sites: 3 }).unwrap();
        assert_eq!(m.n_sites(), 3);
        assert_eq!(m.members(0), &[0, 1]);
        assert_eq!(m.members(1), &[2, 3]);
        assert_eq!(m.members(2), &[4, 5]);
        assert_eq!(m.representative(2), Some(4));
        // uneven split: every site non-empty, sizes differ by ≤ 1
        let m = SiteMap::build(&cfg(), GroupingPolicy::Site { sites: 4 }).unwrap();
        let sizes: Vec<usize> = (0..4).map(|s| m.members(s).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 6);
        assert!(sizes.iter().all(|&s| (1..=2).contains(&s)));
    }

    #[test]
    fn site_map_zone_follows_sku_entries() {
        let m = SiteMap::build(&cfg(), GroupingPolicy::Zone).unwrap();
        assert_eq!(m.n_sites(), 3);
        assert_eq!(m.members(0), &[0, 1, 2]); // 3× hpc-rtx6000
        assert_eq!(m.members(1), &[3, 4]); // 2× t3.large
        assert_eq!(m.members(2), &[5]); // 1× p3.2xlarge-spot
        // zone ids match Cluster::build's sequential id assignment
        let c = Cluster::build(&cfg(), 1).unwrap();
        assert_eq!(c.len(), m.members(0).len() + m.members(1).len() + m.members(2).len());
    }

    #[test]
    fn site_map_zone_skips_empty_entries() {
        let mut c = cfg();
        c.nodes.insert(1, ("t3.large".into(), 0));
        let m = SiteMap::build(&c, GroupingPolicy::Zone).unwrap();
        assert_eq!(m.n_sites(), 3);
        assert!((0..m.n_sites()).all(|s| !m.members(s).is_empty()));
    }

    #[test]
    fn site_map_rejects_degenerate_shapes() {
        assert!(SiteMap::build(&cfg(), GroupingPolicy::Site { sites: 0 }).is_err());
        assert!(SiteMap::build(&cfg(), GroupingPolicy::Site { sites: 7 }).is_err());
        let empty = ClusterConfig {
            nodes: vec![],
            cloud_backend: "inproc".into(),
            hpc_backend: "inproc".into(),
        };
        assert!(SiteMap::build(&empty, GroupingPolicy::Flat).is_err());
    }

    #[test]
    fn describe_lists_all_skus() {
        let c = Cluster::build(&cfg(), 0).unwrap();
        let d = c.describe();
        assert!(d.contains("3×hpc-rtx6000"));
        assert!(d.contains("2×t3.large"));
    }
}
