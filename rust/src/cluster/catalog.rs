//! Hardware SKU catalog.
//!
//! Speed factors are *relative local-training throughput* (1.0 = the
//! paper's fastest node class, the HPC Quadro RTX 6000). They are
//! derived from public spec ratios (FP32 TFLOPs, memory bandwidth),
//! which is what matters to the coordinator: who finishes a round
//! faster, by roughly what factor. Absolute step time comes from
//! measuring the real PJRT step on this machine and scaling by these
//! factors (sim) or from actual wall-clock (real runs).

use super::{Accel, Domain, LinkClass};

/// A node SKU: the unit of heterogeneity in the testbed.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSku {
    pub name: &'static str,
    pub domain: Domain,
    pub accel: Accel,
    /// Relative training throughput; higher is faster (RTX 6000 = 1.0).
    pub speed_factor: f64,
    /// Round-time jitter stddev as a fraction of mean (shared tenancy).
    pub jitter: f64,
    pub link: LinkClass,
    /// Probability of preemption per hour (spot instances / shared
    /// SLURM queues); 0 for on-demand.
    pub preempt_per_hour: f64,
    pub mem_gb: f64,
}

/// Paper §5.1 testbed SKUs (+ a spot variant used by fault experiments).
pub fn catalog() -> &'static [NodeSku] {
    // NVIDIA V100 16GB (p3.2xlarge): 15.7 TF fp32 vs Quadro RTX 6000:
    // 16.3 TF fp32 — near parity; cloud virtualization overhead puts it
    // slightly under. t3.large (2 vCPU) and hpc-cpu (dual-socket Xeon)
    // are 1–2 orders slower for dense training.
    const CATALOG: &[NodeSku] = &[
        NodeSku {
            name: "hpc-rtx6000",
            domain: Domain::Hpc,
            accel: Accel::Gpu,
            speed_factor: 1.0,
            jitter: 0.03,
            link: LinkClass::Infiniband,
            preempt_per_hour: 0.0,
            mem_gb: 24.0,
        },
        NodeSku {
            name: "hpc-cpu",
            domain: Domain::Hpc,
            accel: Accel::CpuOnly,
            speed_factor: 0.08,
            jitter: 0.05,
            link: LinkClass::Infiniband,
            preempt_per_hour: 0.0,
            mem_gb: 192.0,
        },
        NodeSku {
            name: "p3.2xlarge",
            domain: Domain::Cloud,
            accel: Accel::Gpu,
            speed_factor: 0.9,
            jitter: 0.08,
            link: LinkClass::CloudLan,
            preempt_per_hour: 0.0,
            mem_gb: 16.0,
        },
        NodeSku {
            name: "p3.2xlarge-spot",
            domain: Domain::Cloud,
            accel: Accel::Gpu,
            speed_factor: 0.9,
            jitter: 0.08,
            link: LinkClass::CloudLan,
            preempt_per_hour: 0.15,
            mem_gb: 16.0,
        },
        NodeSku {
            name: "t3.large",
            domain: Domain::Cloud,
            accel: Accel::CpuOnly,
            speed_factor: 0.02,
            jitter: 0.15,
            link: LinkClass::CloudWan,
            preempt_per_hour: 0.0,
            mem_gb: 8.0,
        },
        // extra SKUs for scaling / elasticity experiments
        NodeSku {
            name: "a100-cloud",
            domain: Domain::Cloud,
            accel: Accel::Gpu,
            speed_factor: 3.2,
            jitter: 0.06,
            link: LinkClass::CloudLan,
            preempt_per_hour: 0.0,
            mem_gb: 40.0,
        },
        NodeSku {
            name: "edge-cpu",
            domain: Domain::Cloud,
            accel: Accel::CpuOnly,
            speed_factor: 0.005,
            jitter: 0.3,
            link: LinkClass::CloudWan,
            preempt_per_hour: 0.02,
            mem_gb: 4.0,
        },
    ];
    CATALOG
}

/// Find a SKU by name.
pub fn lookup_sku(name: &str) -> Option<&'static NodeSku> {
    catalog().iter().find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_known_and_unknown() {
        assert!(lookup_sku("hpc-rtx6000").is_some());
        assert!(lookup_sku("p3.2xlarge-spot").is_some());
        assert!(lookup_sku("dgx-station").is_none());
    }

    #[test]
    fn paper_sku_relationships() {
        let rtx = lookup_sku("hpc-rtx6000").unwrap();
        let v100 = lookup_sku("p3.2xlarge").unwrap();
        let t3 = lookup_sku("t3.large").unwrap();
        // RTX 6000 ≳ V100 ≫ t3.large (paper's hardware mix)
        assert!(rtx.speed_factor >= v100.speed_factor);
        assert!(v100.speed_factor > 10.0 * t3.speed_factor);
        // spot SKU preempts, on-demand doesn't
        assert!(lookup_sku("p3.2xlarge-spot").unwrap().preempt_per_hour > 0.0);
        assert_eq!(v100.preempt_per_hour, 0.0);
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<_> = catalog().iter().map(|s| s.name).collect();
        names.sort_unstable();
        let n = names.len();
        names.dedup();
        assert_eq!(n, names.len());
    }
}
