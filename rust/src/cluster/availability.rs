//! Node availability traces: a two-state (up/down) Markov model.
//!
//! Spot preemption and shared-queue evictions arrive as a Poisson
//! process with the SKU's `preempt_per_hour` rate; recovery (a new
//! instance or the queue freeing up) takes an exponential time with a
//! few-minute mean. Deterministic per (seed, node), so experiments
//! replay identically.

use crate::util::rng::Rng;

/// Samples up/down intervals for one node over a virtual-time horizon.
#[derive(Debug, Clone)]
pub struct AvailabilityModel {
    preempt_per_hour: f64,
    /// Mean recovery time in seconds.
    mean_recovery_s: f64,
}

impl AvailabilityModel {
    pub fn new(preempt_per_hour: f64) -> Self {
        AvailabilityModel {
            preempt_per_hour,
            mean_recovery_s: 180.0,
        }
    }

    /// Is the node up at virtual time `t_s` (seconds)? Consumes a
    /// deterministic trace derived from `seed`.
    pub fn is_up_at(&self, seed: u64, t_s: f64) -> bool {
        if self.preempt_per_hour <= 0.0 {
            return true;
        }
        let mut rng = Rng::new(seed ^ 0x5EED_A1A1_1AB1_E000u64.wrapping_add(1));
        let rate_per_s = self.preempt_per_hour / 3600.0;
        let mut now = 0.0;
        let mut up = true;
        // walk the alternating renewal process until we pass t_s
        while now <= t_s {
            if up {
                now += rng.exponential(rate_per_s);
                if now > t_s {
                    return true;
                }
                up = false;
            } else {
                now += rng.exponential(1.0 / self.mean_recovery_s);
                if now > t_s {
                    return false;
                }
                up = true;
            }
        }
        up
    }

    /// Does a preemption strike within `[t_s, t_s + dur_s)`? Used to
    /// decide mid-round spot interruptions.
    pub fn preempted_during(&self, seed: u64, t_s: f64, dur_s: f64) -> bool {
        if self.preempt_per_hour <= 0.0 {
            return false;
        }
        // thinning: P(at least one arrival in dur) = 1 - exp(-rate*dur)
        let rate_per_s = self.preempt_per_hour / 3600.0;
        let p = 1.0 - (-rate_per_s * dur_s).exp();
        let mut rng = Rng::new(seed ^ (t_s.to_bits().rotate_left(17)));
        rng.chance(p)
    }

    /// Long-run fraction of time the node is up.
    pub fn steady_state_uptime(&self) -> f64 {
        if self.preempt_per_hour <= 0.0 {
            return 1.0;
        }
        let mean_up = 3600.0 / self.preempt_per_hour;
        mean_up / (mean_up + self.mean_recovery_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn on_demand_always_up() {
        let m = AvailabilityModel::new(0.0);
        for t in [0.0, 1e3, 1e6] {
            assert!(m.is_up_at(1, t));
        }
        assert!(!m.preempted_during(1, 0.0, 1e6));
        assert_eq!(m.steady_state_uptime(), 1.0);
    }

    #[test]
    fn trace_is_deterministic() {
        let m = AvailabilityModel::new(2.0);
        for t in [10.0, 500.0, 3600.0, 7200.0] {
            assert_eq!(m.is_up_at(9, t), m.is_up_at(9, t));
        }
    }

    #[test]
    fn spot_nodes_sometimes_down() {
        let m = AvailabilityModel::new(6.0); // aggressive: 6 preemptions/hour
        let downs = (0..200)
            .filter(|i| !m.is_up_at(*i as u64, 1800.0))
            .count();
        assert!(downs > 0, "expected some nodes down at t=30min");
        assert!(downs < 200, "expected some nodes up");
    }

    #[test]
    fn empirical_uptime_tracks_steady_state() {
        let m = AvailabilityModel::new(4.0);
        let expect = m.steady_state_uptime();
        let n = 2000;
        let ups = (0..n).filter(|i| m.is_up_at(*i as u64, 5000.0)).count();
        let frac = ups as f64 / n as f64;
        assert!(
            (frac - expect).abs() < 0.1,
            "empirical {frac} vs steady-state {expect}"
        );
    }

    #[test]
    fn preemption_probability_scales_with_duration() {
        let m = AvailabilityModel::new(1.0);
        let n = 3000;
        let short = (0..n)
            .filter(|i| m.preempted_during(*i as u64, 0.0, 60.0))
            .count();
        let long = (0..n)
            .filter(|i| m.preempted_during(*i as u64, 1.0, 3600.0))
            .count();
        assert!(long > short * 5, "long {long} vs short {short}");
    }
}
