//! Heterogeneous cluster substrate (substitution for the paper's
//! physical testbed — see DESIGN.md §1).
//!
//! The paper ran on 30 AWS EC2 VMs + 30 SLURM nodes. What the
//! coordinator actually *observes* from that hardware is: relative
//! compute speed, link bandwidth/latency, and (un)availability. This
//! module models those signals from public SKU specs so the selection,
//! straggler and scheduling logic runs against realistic heterogeneity.

mod availability;
mod catalog;
mod topology;

pub use availability::AvailabilityModel;
pub use catalog::{catalog, lookup_sku, NodeSku};
pub use topology::{Cluster, Node, NodeId, SiteMap};

/// Where a node lives — decides transport backend, scheduler adapter
/// and link class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Domain {
    /// Cloud VM (gRPC transport, Kubernetes scheduling, WAN-ish links).
    Cloud,
    /// HPC compute node (MPI transport, SLURM scheduling, Infiniband).
    Hpc,
}

/// Accelerator class of a node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Accel {
    Gpu,
    CpuOnly,
}

/// Network link class, used by the bandwidth shaper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkClass {
    /// HPC interconnect: ~100 Gbit/s, microsecond latency.
    Infiniband,
    /// Intra-region cloud: ~10 Gbit/s, sub-ms latency.
    CloudLan,
    /// Cross-region / egress-constrained: ~1 Gbit/s, tens of ms.
    CloudWan,
}

impl LinkClass {
    /// (bandwidth bytes/sec, one-way latency ms)
    pub fn profile(self) -> (f64, f64) {
        match self {
            LinkClass::Infiniband => (12.5e9, 0.005),
            LinkClass::CloudLan => (1.25e9, 0.4),
            LinkClass::CloudWan => (0.125e9, 25.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_profiles_ordered() {
        let (ib_bw, ib_lat) = LinkClass::Infiniband.profile();
        let (lan_bw, lan_lat) = LinkClass::CloudLan.profile();
        let (wan_bw, wan_lat) = LinkClass::CloudWan.profile();
        assert!(ib_bw > lan_bw && lan_bw > wan_bw);
        assert!(ib_lat < lan_lat && lan_lat < wan_lat);
    }
}
