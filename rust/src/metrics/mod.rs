//! Experiment metrics (paper §5.3): accuracy, convergence speed,
//! round/total training time, communication volume, fault counts.
//!
//! [`RoundMetrics`] is appended once per round by the orchestrator;
//! [`TrainingReport`] summarizes a run and exports CSV/JSON for the
//! table/figure harnesses in `experiments/`.

use crate::util::json::{arr, num, obj, s, Value};
use std::io::Write;

/// Everything measured in one round.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundMetrics {
    pub round: u32,
    /// Clients selected / reported / dropped / missed-deadline.
    pub selected: u32,
    pub reported: u32,
    pub dropped: u32,
    pub deadline_misses: u32,
    /// Mean client training loss (weighted by samples).
    pub train_loss: f64,
    /// Centralized eval after aggregation (None if eval skipped).
    pub eval_accuracy: Option<f64>,
    pub eval_loss: Option<f64>,
    /// Wall-clock (real runs) or virtual (sim runs) duration, seconds.
    pub duration_s: f64,
    /// Bytes down (broadcast) / up (updates) this round.
    pub bytes_down: u64,
    pub bytes_up: u64,
    /// Relative model movement ‖ΔM‖/‖M‖ (convergence tracking).
    pub model_delta: f64,
    /// Staleness of the updates folded this round/commit, in model
    /// versions behind at fold time. Always 0 in sync rounds (every
    /// update trains on the version it is folded into); meaningful in
    /// async_fedbuff commits. Min/max are 0 when nothing folded.
    pub staleness_min: u32,
    pub staleness_mean: f64,
    pub staleness_max: u32,
}

/// Summarize the staleness values of one commit's folded updates into
/// the `(min, mean, max)` triple `RoundMetrics` records. Empty input
/// (an empty commit) yields `(0, 0.0, 0)`.
pub fn staleness_summary(staleness: &[u32]) -> (u32, f64, u32) {
    if staleness.is_empty() {
        return (0, 0.0, 0);
    }
    let mut min = u32::MAX;
    let mut max = 0u32;
    let mut sum = 0u64;
    for &s in staleness {
        min = min.min(s);
        max = max.max(s);
        sum += u64::from(s);
    }
    (min, sum as f64 / staleness.len() as f64, max)
}

impl RoundMetrics {
    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("round", num(self.round as f64)),
            ("selected", num(self.selected as f64)),
            ("reported", num(self.reported as f64)),
            ("dropped", num(self.dropped as f64)),
            ("deadline_misses", num(self.deadline_misses as f64)),
            ("train_loss", num(self.train_loss)),
            ("duration_s", num(self.duration_s)),
            ("bytes_down", num(self.bytes_down as f64)),
            ("bytes_up", num(self.bytes_up as f64)),
            ("model_delta", num(self.model_delta)),
            ("staleness_min", num(self.staleness_min as f64)),
            ("staleness_mean", num(self.staleness_mean)),
            ("staleness_max", num(self.staleness_max as f64)),
        ];
        if let Some(a) = self.eval_accuracy {
            fields.push(("eval_accuracy", num(a)));
        }
        if let Some(l) = self.eval_loss {
            fields.push(("eval_loss", num(l)));
        }
        obj(fields)
    }

    // Staleness columns are appended at the end so the first 12
    // columns stay byte-identical to pre-staleness reports (pinned by
    // `sync_csv_prefix_is_stable` below).
    pub const CSV_HEADER: &'static str = "round,selected,reported,dropped,deadline_misses,train_loss,eval_accuracy,eval_loss,duration_s,bytes_down,bytes_up,model_delta,staleness_min,staleness_mean,staleness_max";

    pub fn to_csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{:.6},{},{},{:.3},{},{},{:.3e},{},{:.3},{}",
            self.round,
            self.selected,
            self.reported,
            self.dropped,
            self.deadline_misses,
            self.train_loss,
            self.eval_accuracy.map_or(String::new(), |a| format!("{a:.4}")),
            self.eval_loss.map_or(String::new(), |l| format!("{l:.4}")),
            self.duration_s,
            self.bytes_down,
            self.bytes_up,
            self.model_delta,
            self.staleness_min,
            self.staleness_mean,
            self.staleness_max,
        )
    }
}

/// Whole-run record.
#[derive(Debug, Clone, Default)]
pub struct TrainingReport {
    pub name: String,
    pub rounds: Vec<RoundMetrics>,
    pub converged_at: Option<u32>,
    pub target_accuracy_at: Option<u32>,
}

impl TrainingReport {
    pub fn new(name: &str) -> Self {
        TrainingReport {
            name: name.to_string(),
            ..Default::default()
        }
    }

    pub fn push(&mut self, m: RoundMetrics) {
        self.rounds.push(m);
    }

    pub fn final_accuracy(&self) -> Option<f64> {
        self.rounds.iter().rev().find_map(|r| r.eval_accuracy)
    }

    pub fn best_accuracy(&self) -> Option<f64> {
        self.rounds
            .iter()
            .filter_map(|r| r.eval_accuracy)
            .fold(None, |best, a| Some(best.map_or(a, |b: f64| b.max(a))))
    }

    pub fn total_duration_s(&self) -> f64 {
        self.rounds.iter().map(|r| r.duration_s).sum()
    }

    pub fn total_bytes(&self) -> (u64, u64) {
        self.rounds
            .iter()
            .fold((0, 0), |(d, u), r| (d + r.bytes_down, u + r.bytes_up))
    }

    /// Mean per-client upload per round (Table 4's metric), bytes.
    pub fn mean_upload_per_round(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|r| r.bytes_up as f64).sum::<f64>() / self.rounds.len() as f64
    }

    /// First round whose eval accuracy reached `target`.
    pub fn rounds_to_accuracy(&self, target: f64) -> Option<u32> {
        self.rounds
            .iter()
            .find(|r| r.eval_accuracy.is_some_and(|a| a >= target))
            .map(|r| r.round)
    }

    /// Virtual/wall time until accuracy reached `target`, seconds.
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        let mut t = 0.0;
        for r in &self.rounds {
            t += r.duration_s;
            if r.eval_accuracy.is_some_and(|a| a >= target) {
                return Some(t);
            }
        }
        None
    }

    pub fn to_json(&self) -> Value {
        obj(vec![
            ("name", s(&self.name)),
            (
                "converged_at",
                self.converged_at.map_or(Value::Null, |r| num(r as f64)),
            ),
            (
                "final_accuracy",
                self.final_accuracy().map_or(Value::Null, num),
            ),
            ("total_duration_s", num(self.total_duration_s())),
            ("rounds", arr(self.rounds.iter().map(|r| r.to_json()))),
        ])
    }

    pub fn write_csv<W: Write>(&self, mut w: W) -> std::io::Result<()> {
        writeln!(w, "{}", RoundMetrics::CSV_HEADER)?;
        for r in &self.rounds {
            writeln!(w, "{}", r.to_csv_row())?;
        }
        Ok(())
    }

    pub fn save(&self, dir: &str) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        let base = format!("{dir}/{}", self.name);
        std::fs::write(format!("{base}.json"), self.to_json().to_string())?;
        let mut csv = Vec::new();
        self.write_csv(&mut csv)?;
        std::fs::write(format!("{base}.csv"), csv)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rm(round: u32, acc: Option<f64>, dur: f64) -> RoundMetrics {
        RoundMetrics {
            round,
            selected: 4,
            reported: 4,
            dropped: 0,
            deadline_misses: 0,
            train_loss: 1.0 / (round + 1) as f64,
            eval_accuracy: acc,
            eval_loss: acc.map(|a| 1.0 - a),
            duration_s: dur,
            bytes_down: 100,
            bytes_up: 50,
            model_delta: 0.01,
            staleness_min: 0,
            staleness_mean: 0.0,
            staleness_max: 0,
        }
    }

    #[test]
    fn report_aggregates() {
        let mut rep = TrainingReport::new("t");
        rep.push(rm(0, Some(0.3), 10.0));
        rep.push(rm(1, None, 10.0));
        rep.push(rm(2, Some(0.8), 10.0));
        rep.push(rm(3, Some(0.7), 10.0));
        assert_eq!(rep.final_accuracy(), Some(0.7));
        assert_eq!(rep.best_accuracy(), Some(0.8));
        assert_eq!(rep.total_duration_s(), 40.0);
        assert_eq!(rep.total_bytes(), (400, 200));
        assert_eq!(rep.mean_upload_per_round(), 50.0);
        assert_eq!(rep.rounds_to_accuracy(0.75), Some(2));
        assert_eq!(rep.rounds_to_accuracy(0.99), None);
        assert_eq!(rep.time_to_accuracy(0.75), Some(30.0));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut rep = TrainingReport::new("t");
        rep.push(rm(0, Some(0.5), 1.0));
        let mut buf = Vec::new();
        rep.write_csv(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let lines: Vec<&str> = text.trim().lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(
            lines[0].split(',').count(),
            lines[1].split(',').count(),
            "header/row column mismatch"
        );
    }

    /// Regression pin for the staleness-column addition: the first 12
    /// CSV columns (the whole pre-staleness schema) must stay
    /// byte-identical, and a sync round's staleness triple is 0,0.000,0.
    #[test]
    fn sync_csv_prefix_is_stable() {
        let row = rm(3, Some(0.5), 1.0).to_csv_row();
        let cols: Vec<&str> = row.split(',').collect();
        assert_eq!(
            cols.get(..12),
            Some(
                &[
                    "3", "4", "4", "0", "0", "0.250000", "0.5000", "0.5000", "1.000", "100",
                    "50", "1.000e-2"
                ][..]
            )
        );
        assert_eq!(cols.get(12..), Some(&["0", "0.000", "0"][..]));
        assert_eq!(
            RoundMetrics::CSV_HEADER
                .split(',')
                .take(12)
                .collect::<Vec<_>>()
                .join(","),
            "round,selected,reported,dropped,deadline_misses,train_loss,eval_accuracy,\
             eval_loss,duration_s,bytes_down,bytes_up,model_delta"
        );
    }

    #[test]
    fn staleness_summary_triple() {
        assert_eq!(staleness_summary(&[]), (0, 0.0, 0));
        assert_eq!(staleness_summary(&[2]), (2, 2.0, 2));
        assert_eq!(staleness_summary(&[0, 1, 5]), (0, 2.0, 5));
    }

    #[test]
    fn json_includes_staleness_fields() {
        let mut m = rm(0, None, 1.0);
        m.staleness_min = 1;
        m.staleness_mean = 2.5;
        m.staleness_max = 4;
        let v = crate::util::json::Value::parse(&m.to_json().to_string()).unwrap();
        assert_eq!(v.get("staleness_min").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("staleness_max").unwrap().as_usize(), Some(4));
    }

    #[test]
    fn json_export_parses_back() {
        let mut rep = TrainingReport::new("t");
        rep.push(rm(0, Some(0.5), 1.0));
        rep.converged_at = Some(9);
        let text = rep.to_json().to_string();
        let v = crate::util::json::Value::parse(&text).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("t"));
        assert_eq!(v.get("converged_at").unwrap().as_usize(), Some(9));
        assert_eq!(v.get("rounds").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn save_writes_files() {
        let dir = std::env::temp_dir().join("fedhpc_metrics_test");
        let dir = dir.to_str().unwrap();
        let mut rep = TrainingReport::new("unit");
        rep.push(rm(0, Some(0.5), 1.0));
        rep.save(dir).unwrap();
        assert!(std::path::Path::new(&format!("{dir}/unit.json")).exists());
        assert!(std::path::Path::new(&format!("{dir}/unit.csv")).exists());
        std::fs::remove_dir_all(dir).ok();
    }
}
