//! Fault injection (paper §3.1 fault-tolerance objective, §5.4
//! straggler-resilience experiment).
//!
//! Deterministic per (seed, round, client): experiments replay exactly,
//! and the orchestrator/client code paths cannot tell injected faults
//! from real ones — dropouts simply never report, preemptions abort
//! mid-training, stragglers run N× slower, network faults degrade the
//! link.

use crate::config::FaultConfig;
use crate::util::rng::Rng;

/// What happens to one client in one round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultAction {
    /// Train and report normally.
    None,
    /// Vanish for the round (crash / network partition): no update.
    Dropout,
    /// Start training, get killed partway (spot preemption): no update,
    /// wasted compute.
    Preempt {
        /// Fraction of local work completed before the kill.
        progress: f64,
    },
    /// Run `factor`× slower this round (noisy neighbor, thermal
    /// throttling, shared queue contention).
    Straggle { factor: f64 },
}

impl FaultAction {
    pub fn reports_update(&self) -> bool {
        matches!(self, FaultAction::None | FaultAction::Straggle { .. })
    }
}

/// Deterministic fault oracle.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    cfg: FaultConfig,
    seed: u64,
}

impl FaultInjector {
    pub fn new(cfg: FaultConfig, seed: u64) -> Self {
        FaultInjector { cfg, seed }
    }

    pub fn disabled() -> Self {
        FaultInjector {
            cfg: FaultConfig::default(),
            seed: 0,
        }
    }

    fn rng_for(&self, round: u32, client: u32) -> Rng {
        Rng::new(
            self.seed
                ^ (((round as u64) << 32) | client as u64).wrapping_mul(0xFA17_1B2D_9E37_79B9),
        )
    }

    /// Decide this client's fate for the round. Checks are ordered by
    /// severity: dropout > preemption > straggle.
    pub fn action(&self, round: u32, client: u32, is_spot: bool) -> FaultAction {
        let mut rng = self.rng_for(round, client);
        if rng.chance(self.cfg.dropout_prob) {
            return FaultAction::Dropout;
        }
        if is_spot && rng.chance(self.cfg.preemption_prob) {
            return FaultAction::Preempt {
                progress: rng.f64(),
            };
        }
        if rng.chance(self.cfg.straggler_prob) {
            return FaultAction::Straggle {
                factor: self.cfg.straggler_factor.max(1.0),
            };
        }
        FaultAction::None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(dropout: f64, preempt: f64, straggle: f64) -> FaultConfig {
        FaultConfig {
            dropout_prob: dropout,
            preemption_prob: preempt,
            straggler_prob: straggle,
            straggler_factor: 4.0,
        }
    }

    #[test]
    fn no_faults_when_disabled() {
        let inj = FaultInjector::disabled();
        for r in 0..50 {
            for c in 0..20 {
                assert_eq!(inj.action(r, c, true), FaultAction::None);
            }
        }
    }

    #[test]
    fn deterministic_per_round_client() {
        let inj = FaultInjector::new(cfg(0.3, 0.3, 0.3), 7);
        for r in 0..20 {
            for c in 0..10 {
                assert_eq!(inj.action(r, c, true), inj.action(r, c, true));
            }
        }
    }

    #[test]
    fn dropout_rate_is_calibrated() {
        // paper §5.4: "20% simulated client dropouts per round"
        let inj = FaultInjector::new(cfg(0.2, 0.0, 0.0), 1);
        let n = 10_000;
        let drops = (0..n)
            .filter(|i| inj.action((i / 100) as u32, (i % 100) as u32, false) == FaultAction::Dropout)
            .count();
        let rate = drops as f64 / n as f64;
        assert!((rate - 0.2).abs() < 0.02, "dropout rate {rate}");
    }

    #[test]
    fn preemption_only_hits_spot_nodes() {
        let inj = FaultInjector::new(cfg(0.0, 0.9, 0.0), 2);
        for r in 0..20 {
            assert_eq!(inj.action(r, 0, false), FaultAction::None);
        }
        let preempts = (0..100)
            .filter(|&r| matches!(inj.action(r, 0, true), FaultAction::Preempt { .. }))
            .count();
        assert!(preempts > 70, "spot preemptions {preempts}/100");
    }

    #[test]
    fn straggle_factor_and_report_semantics() {
        let inj = FaultInjector::new(cfg(0.0, 0.0, 1.0), 3);
        match inj.action(0, 0, false) {
            FaultAction::Straggle { factor } => assert_eq!(factor, 4.0),
            other => panic!("expected straggle, got {other:?}"),
        }
        assert!(FaultAction::None.reports_update());
        assert!(FaultAction::Straggle { factor: 2.0 }.reports_update());
        assert!(!FaultAction::Dropout.reports_update());
        assert!(!FaultAction::Preempt { progress: 0.5 }.reports_update());
    }
}
