//! Hierarchical aggregation plane: the tree-of-aggregators subsystem
//! (paper §3.2 scaled out; cf. OmniFed's edge-to-HPC topologies and
//! cross-facility FL on multiple supercomputers).
//!
//! Every update in a flat deployment funnels into one orchestrator —
//! O(clients) cross-facility traffic per round. This module adds a
//! middle tier: a site [`Aggregator`] runs the same fold machinery as
//! the root against its own site's clients over the ordinary reactor
//! transport, then re-encodes its *pre-folded* delta and reports it
//! upstream **as if it were a client**. The wire protocol is unchanged
//! ([`Msg::Update`] carries the site report); the root needs zero
//! special-casing because the summed site weight rides in
//! `stats.n_samples` and `AggStrategy::scalar_weight` already folds
//! weight-correctly. Cross-facility traffic drops to O(sites).
//!
//! # [`FoldCore`]
//!
//! The role-agnostic heart of both the root engines and the site
//! aggregator: "begin a round's aggregator, fold encoded updates into
//! it, finalize". It is exactly the select→broadcast→collect→finalize
//! fold path factored out of `Orchestrator::run_round` / `run_async` —
//! the fused O(nnz) ingest ([`crate::compress::DecodedView`]), the
//! sharded ingest pool handoff ([`crate::compress::SharedDecoded`])
//! and the [`RoundAggregator`] mode selection are reused as-is, so a
//! site round is bit-compatible with a root round by construction.
//!
//! # Determinism contract
//!
//! Fold-then-normalize is associative across sites when weights are
//! carried exactly: the root folds `W_site · Δ_site` where
//! `Δ_site = (Σ_c raw_c·Δ_c)/W_site`, which recovers the flat sum
//! `Σ_c raw_c·Δ_c` whenever the division and the f32 narrowing of the
//! site mean are exact (dyadic update values and power-of-two integral
//! weights — pinned by property test in `rust/tests/hierarchy.rs`);
//! for arbitrary inputs the two-tier result differs from flat by ≤1 ulp
//! per coordinate. Buffered (order-statistic) strategies do not
//! compose across sites at all and are refused by config validation.
//! The summed weight is shipped through `stats.n_samples` (a `u64`),
//! which is exact for the sample-count weight schemes; fractional
//! schemes round to the nearest integer at the site boundary.

use super::aggregate::{default_ingest_shards, SharedInput, ViewInput};
use super::registry::ClientRegistry;
use super::server::mask_seed;
use super::strategy::{registry as strategy_registry, AggStrategy, RoundAggregator};
use crate::cluster::NodeId;
use crate::compress::{compress, decompress_owned, expected_wire_bytes, DecodedView, Encoded,
                      SharedDecoded};
use crate::config::{CompressionConfig, ExperimentConfig};
use crate::network::{pre_encode_dense, ClientProfile, ClientTransport, Msg, ServerTransport,
                     UpdateStats};
use crate::telemetry::{self, Counter};
use crate::util::parallel::{resolve_ingest_threads, ShardPool};
use crate::util::scratch::ScratchPool;
use anyhow::{bail, Result};
use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Wall-clock reads, funneled through one site: a live aggregator's
/// deadlines and fold timings are inherently wall-clock (the sim
/// engines never construct an [`Aggregator`], so virtual time is not
/// at stake here).
fn now() -> Instant {
    // lint:allow(determinism) live site deadlines / fold timing are wall-clock by nature
    Instant::now()
}

/// The role-agnostic fold/commit core shared by the root orchestrator
/// engines and the site [`Aggregator`]: everything a round needs to
/// turn encoded updates into a finalized aggregate, minus any role
/// policy (selection, deadlines, model stepping).
///
/// Cheap to construct (three `Arc` clones); the orchestrator builds
/// one per use so a live `set-strategy` control swap is always
/// reflected.
pub struct FoldCore {
    strategy: Arc<dyn AggStrategy>,
    scratch: Arc<ScratchPool>,
    ingest: Option<Arc<ShardPool>>,
    n_params: usize,
}

impl FoldCore {
    pub fn new(
        strategy: Arc<dyn AggStrategy>,
        n_params: usize,
        scratch: Arc<ScratchPool>,
        ingest: Option<Arc<ShardPool>>,
    ) -> Self {
        FoldCore {
            strategy,
            scratch,
            ingest,
            n_params,
        }
    }

    /// Assemble a core from a config alone (the site-aggregator path:
    /// strategy from the registry name, fresh scratch pool, ingest
    /// pool per `cfg.ingest_threads` exactly like the root builder).
    pub fn from_config(cfg: &ExperimentConfig, n_params: usize) -> Self {
        let threads = resolve_ingest_threads(cfg.ingest_threads);
        let ingest = if threads > 1 {
            Some(Arc::new(ShardPool::new(
                threads,
                default_ingest_shards(n_params),
            )))
        } else {
            None
        };
        FoldCore::new(
            strategy_registry::strategy_from_config(&cfg.aggregation),
            n_params,
            Arc::new(ScratchPool::new()),
            ingest,
        )
    }

    /// Model size this core folds.
    pub fn n_params(&self) -> usize {
        self.n_params
    }

    /// The strategy updates fold under.
    pub fn strategy(&self) -> &dyn AggStrategy {
        self.strategy.as_ref()
    }

    /// Begin one round/commit window: a fresh [`RoundAggregator`] in
    /// whichever mode the strategy + ingest pool select (sharded /
    /// streaming / buffered) — the exact constructor call the engines
    /// used inline before this refactor.
    pub fn begin(&self) -> RoundAggregator {
        RoundAggregator::with_ingest(
            self.strategy.clone(),
            self.n_params,
            self.scratch.clone(),
            self.ingest.clone(),
        )
    }

    /// Fold one arriving encoded update into `agg` — the single fused
    /// ingest dispatch both engines and the site aggregator share.
    /// `scale` is the update's staleness discount (1.0 in sync
    /// rounds). A sharded round takes ownership of the decode so shard
    /// workers fold disjoint spans concurrently while the caller
    /// returns to the socket; otherwise the update folds straight from
    /// its [`DecodedView`] (O(nnz), no dense materialization). A bad
    /// update (undecodable, or refused by the strategy) returns `Err`
    /// and must skip the client, never abort the round.
    pub fn fold_encoded(
        &self,
        agg: &mut RoundAggregator,
        client: NodeId,
        delta: Encoded,
        stats: &UpdateStats,
        scale: f64,
    ) -> Result<()> {
        if agg.ingest_sharded() {
            SharedDecoded::new(Arc::new(delta), self.n_params).and_then(|payload| {
                agg.fold_shared_scaled(
                    &SharedInput {
                        client,
                        payload: Arc::new(payload),
                        n_samples: stats.n_samples,
                        train_loss: stats.train_loss,
                        update_var: stats.update_var,
                    },
                    scale,
                )
            })
        } else {
            DecodedView::of(&delta, self.n_params).and_then(|view| {
                agg.fold_view_scaled(
                    &ViewInput {
                        client,
                        view: &view,
                        n_samples: stats.n_samples,
                        train_loss: stats.train_loss,
                        update_var: stats.update_var,
                    },
                    scale,
                )
            })
        }
    }
}

/// Per-site telemetry, resolved once (commit-boundary sampling — the
/// per-update path never touches the registry mutex).
struct SiteMetrics {
    updates: Arc<Counter>,
    fold_ns: Arc<Counter>,
    upstream_bytes: Arc<Counter>,
}

impl SiteMetrics {
    fn new(site: usize) -> Self {
        use crate::telemetry::names;
        let g = telemetry::global();
        let s = site.to_string();
        SiteMetrics {
            updates: g.counter_with(
                names::SITE_UPDATES_TOTAL,
                "Member updates folded by a site aggregator, by site.",
                "site",
                &s,
            ),
            fold_ns: g.counter_with(
                names::SITE_FOLD_NS_TOTAL,
                "Nanoseconds a site aggregator spent folding, by site.",
                "site",
                &s,
            ),
            upstream_bytes: g.counter_with(
                names::UPSTREAM_REPORT_BYTES_TOTAL,
                "Encoded bytes of pre-folded deltas reported upstream, by site.",
                "site",
                &s,
            ),
        }
    }
}

/// One upstream `RoundStart`, destructured (keeps the per-round entry
/// point a single argument).
struct SiteRound {
    round: u32,
    model_version: u32,
    deadline_ms: u64,
    lr: f32,
    mu: f32,
    local_epochs: u32,
    params: Encoded,
    mask_seed: u64,
    compression: CompressionConfig,
}

/// A mid-tier site aggregator: a server toward its site's clients, a
/// client toward the root. Its event loop mirrors
/// [`crate::client::Worker::run`] — register upstream, then answer
/// each `RoundStart` — but "local training" is a whole site round run
/// through the same [`FoldCore`] the root uses.
///
/// Crash behaviour is the graceful-degradation contract: if the
/// aggregator dies (or its site produces zero updates), the root
/// simply counts one missing reporter — the round still commits from
/// the other sites, exactly like any slow flat client.
pub struct Aggregator<D: ServerTransport, U: ClientTransport> {
    downstream: D,
    upstream: U,
    core: FoldCore,
    registry: ClientRegistry,
    cfg: ExperimentConfig,
    metrics: SiteMetrics,
}

impl<D: ServerTransport, U: ClientTransport> Aggregator<D, U> {
    /// Build a site aggregator for site index `site` over a model of
    /// `n_params` entries. `downstream` serves the site's clients;
    /// `upstream` connects to the root (or a higher-tier aggregator —
    /// the protocol is tier-agnostic).
    pub fn new(
        cfg: ExperimentConfig,
        site: usize,
        n_params: usize,
        downstream: D,
        upstream: U,
    ) -> Self {
        let core = FoldCore::from_config(&cfg, n_params);
        Aggregator {
            downstream,
            upstream,
            core,
            registry: ClientRegistry::new(),
            cfg,
            metrics: SiteMetrics::new(site),
        }
    }

    /// Members registered so far.
    pub fn n_members(&self) -> usize {
        self.registry.len()
    }

    /// Absorb member registrations until `expected` joined or
    /// `timeout` passed (the site-side mirror of
    /// `Orchestrator::wait_for_clients`).
    pub fn wait_for_members(&mut self, expected: usize, timeout: Duration) -> Result<usize> {
        let deadline = now() + timeout;
        while self.registry.len() < expected {
            let t = now();
            if t >= deadline {
                break;
            }
            let step = (deadline - t).min(Duration::from_millis(100));
            if let Some((from, msg)) = self.downstream.recv_timeout(step)? {
                self.handle_member_control(from, msg)?;
            }
        }
        log::info!(
            "aggregator: {} / {expected} members registered",
            self.registry.len()
        );
        Ok(self.registry.len())
    }

    fn handle_member_control(&mut self, from: NodeId, msg: Msg) -> Result<()> {
        match msg {
            Msg::Register { client, profile } => {
                if client != from {
                    log::warn!("register id mismatch: envelope {from}, body {client}");
                }
                self.registry.register(client, profile);
                self.downstream
                    .send_to(client, &Msg::RegisterAck { client })?;
            }
            Msg::Heartbeat { .. } => {}
            other => {
                log::debug!("aggregator: ignoring {} outside round", other.name());
            }
        }
        Ok(())
    }

    /// Register with the root as one client whose profile summarizes
    /// the site: total samples (the weight mass it will report),
    /// slowest member speed (the site finishes with its straggler) and
    /// the narrowest member link.
    fn register_upstream(&self) -> Result<()> {
        let mut profile = ClientProfile {
            speed_factor: f64::INFINITY,
            mem_gb: f64::INFINITY,
            link_bw: f64::INFINITY,
            n_samples: 0,
            bench_step_ms: 0.0,
        };
        for r in self.registry.records() {
            profile.speed_factor = profile.speed_factor.min(r.profile.speed_factor);
            profile.mem_gb = profile.mem_gb.min(r.profile.mem_gb);
            profile.link_bw = profile.link_bw.min(r.profile.link_bw);
            profile.n_samples += r.profile.n_samples;
            profile.bench_step_ms = profile.bench_step_ms.max(r.profile.bench_step_ms);
        }
        if !profile.speed_factor.is_finite() {
            bail!("aggregator: cannot register upstream with zero members");
        }
        self.upstream.send(&Msg::Register {
            client: self.upstream.id(),
            profile,
        })
    }

    /// Drain pending member traffic (late registrations, heartbeats)
    /// while idle between upstream rounds.
    fn pump_downstream(&mut self) -> Result<()> {
        while let Some((from, msg)) = self.downstream.recv_timeout(Duration::from_millis(1))? {
            self.handle_member_control(from, msg)?;
        }
        Ok(())
    }

    /// Forward a root notification to every registered member (send
    /// failures degrade to that member missing the notification).
    fn forward_to_members(&self, msg: &Msg) {
        for id in self.registry.ids() {
            if let Err(e) = self.downstream.send_to(id, msg) {
                log::debug!("aggregator: forward {} to {id} failed ({e})", msg.name());
            }
        }
    }

    /// Main loop: wait for `expected` members, register upstream, then
    /// answer `RoundStart`s until `Shutdown`. Returns the number of
    /// site rounds run.
    pub fn run(&mut self, expected: usize, join_timeout: Duration) -> Result<u64> {
        let got = self.wait_for_members(expected, join_timeout)?;
        if got == 0 {
            bail!("aggregator: no members registered");
        }
        self.register_upstream()?;
        let mut rounds = 0u64;
        loop {
            let Some(msg) = self.upstream.recv_timeout(Duration::from_millis(250))? else {
                self.pump_downstream()?;
                continue;
            };
            match msg {
                Msg::RoundStart {
                    round,
                    model_version,
                    deadline_ms,
                    lr,
                    mu,
                    local_epochs,
                    params,
                    mask_seed,
                    compression,
                } => {
                    let site_round = SiteRound {
                        round,
                        model_version,
                        deadline_ms,
                        lr,
                        mu,
                        local_epochs,
                        params,
                        mask_seed,
                        compression,
                    };
                    if let Some(report) = self.run_site_round(site_round)? {
                        self.upstream.send(&report)?;
                    }
                    rounds += 1;
                }
                m @ (Msg::RoundEnd { .. } | Msg::Abort { .. }) => self.forward_to_members(&m),
                Msg::Shutdown => {
                    self.forward_to_members(&Msg::Shutdown);
                    return Ok(rounds);
                }
                Msg::RegisterAck { .. } => {}
                other => log::debug!("aggregator: unexpected {}", other.name()),
            }
        }
    }

    /// Run one site round: rebroadcast the model to every member,
    /// collect their updates through the shared [`FoldCore`], and
    /// package the pre-folded site delta as one upstream
    /// [`Msg::Update`]. Returns `None` when no member reported — the
    /// root then counts this site as one missing reporter and the
    /// global round still commits (graceful degradation).
    fn run_site_round(&mut self, sr: SiteRound) -> Result<Option<Msg>> {
        let t_round = now();
        // decode the broadcast exactly once, then share the re-encoded
        // dense bytes across every member RoundStart (same single
        // serialization discipline as the root's broadcast phase)
        let dense = decompress_owned(sr.params, self.core.n_params())?;
        let shared = Encoded::PreEncoded(pre_encode_dense(&dense));
        drop(dense);
        // the site must fold and report before the root's deadline:
        // members get 3/4 of the handed-down budget. Clamped to
        // [50ms, 24h] — a disabled root deadline arrives as u64::MAX,
        // which must not overflow `Instant + Duration`
        let site_deadline_ms = (sr.deadline_ms / 4).saturating_mul(3).clamp(50, 86_400_000);
        let members = self.registry.ids();
        let mut reached: Vec<NodeId> = Vec::with_capacity(members.len());
        for &m in &members {
            let msg = Msg::RoundStart {
                round: sr.round,
                model_version: sr.model_version,
                deadline_ms: site_deadline_ms,
                lr: sr.lr,
                mu: sr.mu,
                local_epochs: sr.local_epochs,
                params: shared.clone(),
                // the same (experiment, round, client) mask-seed
                // formula the flat root uses, so a member behaves
                // identically under either topology
                mask_seed: mask_seed(self.cfg.seed, sr.round, m),
                compression: sr.compression,
            };
            match self.downstream.send_to(m, &msg) {
                Ok(()) => reached.push(m),
                Err(e) => log::warn!(
                    "site round {}: broadcast to {m} failed ({e}) — excluded",
                    sr.round
                ),
            }
        }
        let mut agg = self.core.begin();
        let mut fold_ns = 0u64;
        let deadline = t_round + Duration::from_millis(site_deadline_ms);
        let reached_set: BTreeSet<NodeId> = reached.iter().copied().collect();
        let mut reported: BTreeSet<NodeId> = BTreeSet::new();
        while reported.len() < reached.len() {
            let t = now();
            if t >= deadline {
                break;
            }
            let step = (deadline - t).min(Duration::from_millis(50));
            let Some((from, msg)) = self.downstream.recv_timeout(step)? else {
                continue;
            };
            match msg {
                Msg::Update {
                    round: r,
                    client,
                    base_version: _,
                    delta,
                    stats,
                } => {
                    if r != sr.round
                        || !reached_set.contains(&client)
                        || reported.contains(&client)
                    {
                        continue;
                    }
                    let t_fold = now();
                    match self.core.fold_encoded(&mut agg, client, delta, &stats, 1.0) {
                        Ok(()) => {
                            reported.insert(client);
                            self.registry.report_success(
                                client,
                                sr.round,
                                t_round.elapsed().as_secs_f64() * 1e3,
                            );
                        }
                        Err(e) => {
                            log::warn!("site round {}: bad update from {client}: {e}", sr.round);
                            self.registry.report_failure(client, sr.round);
                            reported.insert(client);
                        }
                    }
                    fold_ns += t_fold.elapsed().as_nanos() as u64;
                }
                other => self.handle_member_control(from, other)?,
            }
        }
        for &m in &members {
            if !reported.contains(&m) {
                self.registry.report_failure(m, sr.round);
            }
        }
        let n_updates = agg.n_updates();
        // commit-boundary telemetry sample (never per-update)
        self.metrics.updates.add(n_updates as u64);
        self.metrics.fold_ns.add(fold_ns);
        if n_updates == 0 {
            log::warn!(
                "site round {}: zero member updates — reporting nothing upstream",
                sr.round
            );
            return Ok(None);
        }
        let (site_delta, total_weight) = agg.finalize_delta()?;
        let mean_f32: Vec<f32> = site_delta.delta.iter().map(|&d| d as f32).collect();
        let delta = compress(&mean_f32, &sr.compression, sr.mask_seed);
        self.metrics
            .upstream_bytes
            .add(expected_wire_bytes(mean_f32.len(), &sr.compression));
        let stats = UpdateStats {
            // the site's exact weight mass for sample-count schemes;
            // fractional schemes round at this tier boundary (see the
            // module docs' determinism contract)
            n_samples: (total_weight.round() as u64).max(1),
            train_loss: site_delta.mean_train_loss as f32,
            steps: n_updates as u32,
            compute_ms: t_round.elapsed().as_secs_f64() * 1e3,
            update_var: 0.0,
        };
        Ok(Some(Msg::Update {
            round: sr.round,
            client: self.upstream.id(),
            // protocol-v2 carriage: in async mode the root derives this
            // site report's staleness from the base version of the
            // model the site folded against
            base_version: sr.model_version,
            delta,
            stats,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::super::registry::test_profile;
    use super::super::strategy::SgdServer;
    use super::*;
    use crate::config::presets::quickstart;
    use crate::network::inproc::InprocHub;
    use crate::network::TrafficLog;
    use crate::network::LinkShaper;

    fn stats_for(n: u64) -> UpdateStats {
        UpdateStats {
            n_samples: n,
            train_loss: 1.0,
            steps: 1,
            compute_ms: 1.0,
            update_var: 0.0,
        }
    }

    #[test]
    fn fold_core_matches_inline_round_aggregator() {
        let cfg = quickstart();
        let core = FoldCore::from_config(&cfg, 4);
        assert_eq!(core.n_params(), 4);
        assert_eq!(core.strategy().name(), "fedavg");
        let mut agg = core.begin();
        core.fold_encoded(
            &mut agg,
            0,
            Encoded::Dense(vec![1.0, 2.0, 3.0, 4.0]),
            &stats_for(8),
            1.0,
        )
        .unwrap();
        core.fold_encoded(
            &mut agg,
            1,
            Encoded::Dense(vec![0.0; 4]),
            &stats_for(8),
            1.0,
        )
        .unwrap();
        assert_eq!(agg.n_updates(), 2);
        let out = agg.finalize(&[0.0; 4], &mut SgdServer).unwrap();
        assert_eq!(out.new_params, vec![0.5, 1.0, 1.5, 2.0]);
    }

    #[test]
    fn fold_core_rejects_bad_updates_without_poisoning_round() {
        let cfg = quickstart();
        let core = FoldCore::from_config(&cfg, 4);
        let mut agg = core.begin();
        // wrong length: refused, aggregator untouched
        assert!(core
            .fold_encoded(&mut agg, 0, Encoded::Dense(vec![1.0]), &stats_for(1), 1.0)
            .is_err());
        assert_eq!(agg.n_updates(), 0);
        core.fold_encoded(
            &mut agg,
            1,
            Encoded::Dense(vec![1.0; 4]),
            &stats_for(4),
            1.0,
        )
        .unwrap();
        assert_eq!(agg.n_updates(), 1);
    }

    #[test]
    fn finalize_delta_carries_summed_weight() {
        let cfg = quickstart();
        let core = FoldCore::from_config(&cfg, 2);
        let mut agg = core.begin();
        core.fold_encoded(
            &mut agg,
            0,
            Encoded::Dense(vec![1.0, 0.0]),
            &stats_for(3),
            1.0,
        )
        .unwrap();
        core.fold_encoded(
            &mut agg,
            1,
            Encoded::Dense(vec![0.0, 1.0]),
            &stats_for(5),
            1.0,
        )
        .unwrap();
        let (delta, total) = agg.finalize_delta().unwrap();
        assert_eq!(total, 8.0);
        assert!((delta.delta[0] - 3.0 / 8.0).abs() < 1e-12);
        assert!((delta.delta[1] - 5.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn finalize_delta_refuses_buffered_strategies() {
        let core = FoldCore::new(
            strategy_registry::strategy_by_name("coordinate_median").unwrap(),
            2,
            Arc::new(ScratchPool::new()),
            None,
        );
        let mut agg = core.begin();
        core.fold_encoded(
            &mut agg,
            0,
            Encoded::Dense(vec![1.0, 1.0]),
            &stats_for(1),
            1.0,
        )
        .unwrap();
        let err = agg.finalize_delta().unwrap_err();
        assert!(format!("{err:#}").contains("cannot report"), "got {err:#}");
    }

    /// One full site round over inproc hubs: two hand-driven members
    /// report fixed dyadic updates, and the aggregator's upstream
    /// report must carry the exact site mean and summed weight.
    #[test]
    fn aggregator_reports_site_mean_and_weight_upstream() {
        let root_traffic = Arc::new(TrafficLog::new());
        let root_hub = InprocHub::new(root_traffic);
        // the aggregator joins the root as client 0 (its site's
        // representative id)
        let up = root_hub.add_client(0, LinkShaper::unshaped());
        let root = root_hub.server();

        let site_traffic = Arc::new(TrafficLog::new());
        let site_hub = InprocHub::new(site_traffic);
        let m0 = site_hub.add_client(0, LinkShaper::unshaped());
        let m1 = site_hub.add_client(1, LinkShaper::unshaped());
        let down = site_hub.server();

        let mut cfg = quickstart();
        cfg.seed = 9;
        let seed = cfg.seed;
        let mut agg = Aggregator::new(cfg, 0, 2, down, up);
        for c in [&m0, &m1] {
            c.send(&Msg::Register {
                client: c.id(),
                profile: test_profile(1.0, 1e9),
            })
            .unwrap();
        }
        let handle = std::thread::spawn(move || agg.run(2, Duration::from_secs(5)).unwrap());

        // members drain their acks
        for c in [&m0, &m1] {
            let ack = c.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
            assert!(matches!(ack, Msg::RegisterAck { .. }));
        }
        // the aggregator registers upstream with the summed site profile
        let (from, reg) = root.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(from, 0);
        match reg {
            Msg::Register { client, profile } => {
                assert_eq!(client, 0);
                assert_eq!(profile.n_samples, 2 * test_profile(1.0, 1e9).n_samples);
            }
            other => panic!("expected Register, got {}", other.name()),
        }
        // root opens a round
        root.send_to(
            0,
            &Msg::RoundStart {
                round: 3,
                model_version: 7,
                deadline_ms: 4_000,
                lr: 0.1,
                mu: 0.0,
                local_epochs: 1,
                params: Encoded::Dense(vec![0.0, 0.0]),
                mask_seed: mask_seed(seed, 3, 0),
                compression: CompressionConfig::NONE,
            },
        )
        .unwrap();
        // members see the rebroadcast with per-member mask seeds and a
        // shrunken deadline, then answer with dyadic updates
        for (c, delta, n) in [(&m0, vec![1.0f32, 0.0], 1u64), (&m1, vec![0.0, 1.0], 3)] {
            let rs = c.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
            match rs {
                Msg::RoundStart {
                    round,
                    model_version,
                    deadline_ms,
                    mask_seed: ms,
                    ..
                } => {
                    assert_eq!(round, 3);
                    assert_eq!(model_version, 7);
                    assert_eq!(deadline_ms, 3_000);
                    assert_eq!(ms, mask_seed(seed, 3, c.id()));
                }
                other => panic!("expected RoundStart, got {}", other.name()),
            }
            c.send(&Msg::Update {
                round: 3,
                client: c.id(),
                base_version: 7,
                delta: Encoded::Dense(delta),
                stats: stats_for(n),
            })
            .unwrap();
        }
        // the upstream report: site mean (1/4, 3/4), weight 4, base
        // version echoed for async staleness
        let (_, up_msg) = root.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        match up_msg {
            Msg::Update {
                round,
                client,
                base_version,
                delta,
                stats,
            } => {
                assert_eq!(round, 3);
                assert_eq!(client, 0);
                assert_eq!(base_version, 7);
                assert_eq!(stats.n_samples, 4);
                assert_eq!(stats.steps, 2);
                let d = crate::compress::decompress(&delta, 2).unwrap();
                assert_eq!(d, vec![0.25, 0.75]);
            }
            other => panic!("expected Update, got {}", other.name()),
        }
        root.send_to(0, &Msg::Shutdown).unwrap();
        assert_eq!(handle.join().unwrap(), 1);
        // members got the forwarded shutdown
        for c in [&m0, &m1] {
            let msg = c.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
            assert!(matches!(msg, Msg::Shutdown));
        }
    }

    /// Zero member reports: the site round closes with no upstream
    /// report (the root degrades it to a missing reporter).
    #[test]
    fn aggregator_reports_nothing_on_empty_site_round() {
        let root_hub = InprocHub::new(Arc::new(TrafficLog::new()));
        let up = root_hub.add_client(5, LinkShaper::unshaped());
        let root = root_hub.server();
        let site_hub = InprocHub::new(Arc::new(TrafficLog::new()));
        let member = site_hub.add_client(6, LinkShaper::unshaped());
        let down = site_hub.server();
        let mut agg = Aggregator::new(quickstart(), 1, 2, down, up);
        member
            .send(&Msg::Register {
                client: 6,
                profile: test_profile(1.0, 1e9),
            })
            .unwrap();
        let handle = std::thread::spawn(move || agg.run(1, Duration::from_secs(5)).unwrap());
        root.recv_timeout(Duration::from_secs(5)).unwrap(); // Register
        root.send_to(
            5,
            &Msg::RoundStart {
                round: 0,
                model_version: 0,
                deadline_ms: 400,
                lr: 0.1,
                mu: 0.0,
                local_epochs: 1,
                params: Encoded::Dense(vec![0.0, 0.0]),
                mask_seed: 1,
                compression: CompressionConfig::NONE,
            },
        )
        .unwrap();
        // the member stays silent; no upstream Update may arrive
        let got = root.recv_timeout(Duration::from_millis(900)).unwrap();
        assert!(got.is_none(), "empty site sent {got:?}");
        root.send_to(5, &Msg::Shutdown).unwrap();
        assert_eq!(handle.join().unwrap(), 1);
    }
}
