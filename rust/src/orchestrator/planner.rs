//! Pluggable cohort planning (paper §4.1 resource-aware scheduling).
//!
//! A [`CohortPlanner`] owns the per-round question the orchestrator
//! used to hard-code: *who* trains this round and *on what terms*.
//! [`CohortPlanner::plan`] returns a [`RoundPlan`] — the cohort plus a
//! per-client [`DispatchPlan`] (round deadline, local-epoch budget,
//! uplink compression) — so heterogeneity-aware planners can give a
//! slow client fewer epochs or a sparser uplink instead of watching it
//! miss the deadline. The per-client fields ride in the existing
//! `Msg::RoundStart` fields, so the wire protocol is untouched.
//!
//! Planners are a configuration axis like aggregation strategies
//! (PR 2): [`crate::config::PlannerKind::parse`] owns the
//! `"name[:params]"` grammar shared by the CLI (`--planner`), config
//! files (`selection.planner`) and benches; [`planner_by_name`] /
//! [`planner_from_config`] own instantiation. Registered planners:
//!
//! * `random` — uniform cohort, identical dispatch for everyone (the
//!   ablation baseline). Bit-identical cohorts to the historical
//!   `SelectionPolicy::Random` for the same seed (pinned by test).
//! * `adaptive[:explore[:exclude]]` — score = capability × reliability
//!   × bandwidth with an exploration floor; chronic stragglers (EWMA
//!   round time > `exclude` × median) are benched for
//!   [`AdaptivePlanner::bench_rounds`] rounds. Bit-identical cohorts to
//!   the historical `SelectionPolicy::Adaptive` (pinned by test).
//! * `tiered[:n]` — cohort sampled uniformly (so ablations against
//!   `random` differ only in dispatch), then bucketed into `n` tiers
//!   by EWMA round time normalized per observed epoch budget (see
//!   `EpochLedger::est_epoch_ms` for why the normalization matters).
//!   Each tier's epoch budget and top-k fraction shrink by the tier's
//!   slowdown ratio versus the fastest tier, so slow clients finish
//!   inside the same deadline fast ones do.
//! * `deadline[:ms]` — fits each client's epoch budget to a target
//!   round deadline from its profiled round-time estimate (seeded by
//!   `bench_step_ms`) and link bandwidth; low-bandwidth links keep
//!   extra transfer headroom. Without `:ms` the config's
//!   `straggler.deadline_ms` is the target.
//!
//! Registry feedback ([`CohortPlanner::report_success`] /
//! [`CohortPlanner::report_failure`]) also flows through the trait, so
//! a planner owns its own learning signal the way a `ServerOpt` owns
//! its optimizer state — the default implementations forward to the
//! shared [`ClientRegistry`].
//!
//! # Determinism
//!
//! `plan` draws only from the caller's [`Rng`]: the same seed produces
//! the same cohorts *and* the same per-client plans, in the real
//! engines and the virtual-time sim alike.

use super::registry::ClientRegistry;
use crate::cluster::NodeId;
use crate::config::{CompressionConfig, PlannerKind, SelectionConfig};
use crate::util::rng::Rng;
use anyhow::Result;
use std::collections::{BTreeMap, BTreeSet};

/// Per-client dispatch terms for one round. These are exactly the
/// `Msg::RoundStart` fields a planner may vary per client; everything
/// else in the broadcast (learning rate, μ, model payload) is global.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DispatchPlan {
    /// Round deadline handed to this client (advisory on the wire; the
    /// collect phase waits out the cohort's maximum).
    pub deadline_ms: u64,
    /// Local-epoch budget for this client.
    pub local_epochs: u32,
    /// Uplink compression this client must apply to its update.
    pub compression: CompressionConfig,
}

/// Everything the orchestrator hands the planner besides the registry:
/// the round number, the cohort size target and the config-derived
/// default dispatch terms.
#[derive(Debug, Clone, Copy)]
pub struct PlanContext {
    pub round: u32,
    /// Cohort size target (`selection.clients_per_round`).
    pub k: usize,
    /// Dispatch terms for a client the planner doesn't tune.
    pub defaults: DispatchPlan,
}

/// A planned round: the cohort in dispatch order, one
/// [`DispatchPlan`] per member.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundPlan {
    cohort: Vec<NodeId>,
    /// Parallel to `cohort`.
    plans: Vec<DispatchPlan>,
}

impl RoundPlan {
    pub fn empty() -> RoundPlan {
        RoundPlan {
            cohort: Vec::new(),
            plans: Vec::new(),
        }
    }

    /// Every cohort member gets the same dispatch terms.
    pub fn uniform(cohort: Vec<NodeId>, plan: DispatchPlan) -> RoundPlan {
        let plans = vec![plan; cohort.len()];
        RoundPlan { cohort, plans }
    }

    pub fn from_entries(entries: Vec<(NodeId, DispatchPlan)>) -> RoundPlan {
        let (cohort, plans) = entries.into_iter().unzip();
        RoundPlan { cohort, plans }
    }

    /// The cohort in dispatch order.
    pub fn cohort(&self) -> &[NodeId] {
        &self.cohort
    }

    pub fn len(&self) -> usize {
        self.cohort.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cohort.is_empty()
    }

    /// `(client, plan)` pairs in dispatch order.
    pub fn iter(&self) -> impl Iterator<Item = (NodeId, &DispatchPlan)> {
        self.cohort.iter().copied().zip(self.plans.iter())
    }

    /// This round's dispatch terms for `id` (a cohort member). Linear
    /// scan — fine for one-off lookups; callers doing per-client
    /// lookups in a loop should build [`RoundPlan::to_map`] once.
    pub fn get(&self, id: NodeId) -> Option<&DispatchPlan> {
        self.cohort
            .iter()
            .position(|&c| c == id)
            .map(|i| &self.plans[i])
    }

    /// The plan as a by-client lookup table (what the async engines
    /// keep for per-report re-dispatch). `BTreeMap` so iterating the
    /// table is NodeId-ordered — re-dispatch sweeps stay deterministic.
    pub fn to_map(&self) -> BTreeMap<NodeId, DispatchPlan> {
        self.iter().map(|(c, p)| (c, *p)).collect()
    }

    /// The latest deadline any cohort member was given — the round's
    /// collect-phase wait bound.
    pub fn max_deadline_ms(&self) -> u64 {
        self.plans.iter().map(|p| p.deadline_ms).max().unwrap_or(0)
    }
}

/// The cohort-planning strategy interface. One instance lives on the
/// orchestrator for the whole run, so implementations may carry state
/// across rounds (bench counters, learned tiers, …) the way a
/// `ServerOpt` carries optimizer state.
pub trait CohortPlanner: Send {
    /// Registry name (matches [`crate::config::PlannerKind::name`]).
    fn name(&self) -> &'static str;

    /// Pick this round's cohort from `available` and assign each
    /// member its dispatch terms. Deterministic in `rng`; returns at
    /// most `ctx.k` clients (fewer only if `available` is short).
    fn plan(
        &mut self,
        registry: &mut ClientRegistry,
        available: &[NodeId],
        ctx: &PlanContext,
        rng: &mut Rng,
    ) -> RoundPlan;

    /// Feedback: a planned client reported a usable update `round_ms`
    /// into the round. Default: update the shared registry's EWMA /
    /// reliability history.
    fn report_success(
        &mut self,
        registry: &mut ClientRegistry,
        id: NodeId,
        round: u32,
        round_ms: f64,
    ) {
        registry.report_success(id, round, round_ms);
    }

    /// Feedback: a planned client dropped out, missed its deadline or
    /// sent a rejected update. Default: registry failure count.
    fn report_failure(&mut self, registry: &mut ClientRegistry, id: NodeId, round: u32) {
        registry.report_failure(id, round);
    }
}

/// Uniform random cohort (the ablation baseline).
pub struct RandomPlanner;

impl CohortPlanner for RandomPlanner {
    fn name(&self) -> &'static str {
        "random"
    }

    fn plan(
        &mut self,
        _registry: &mut ClientRegistry,
        available: &[NodeId],
        ctx: &PlanContext,
        rng: &mut Rng,
    ) -> RoundPlan {
        let k = ctx.k.min(available.len());
        if k == 0 {
            return RoundPlan::empty();
        }
        let picks = rng.sample_indices(available.len(), k);
        RoundPlan::uniform(
            picks.into_iter().map(|i| available[i]).collect(),
            ctx.defaults,
        )
    }
}

/// Score-based selection with an exploration floor and straggler
/// benching — the historical adaptive policy behind the trait, with
/// the O(k²) `Vec::contains` scans replaced by a `BTreeSet` (the same
/// smell PR 1 fixed in round collection; pure lookup change, cohort
/// order is untouched).
pub struct AdaptivePlanner {
    pub explore_frac: f64,
    pub exclude_factor: f64,
    /// Rounds a detected straggler sits out (was a hard-coded 3 in the
    /// old free function; now planner-owned state).
    pub bench_rounds: u32,
}

impl AdaptivePlanner {
    pub fn new(explore_frac: f64, exclude_factor: f64) -> AdaptivePlanner {
        AdaptivePlanner {
            explore_frac,
            exclude_factor,
            bench_rounds: 3,
        }
    }
}

impl CohortPlanner for AdaptivePlanner {
    fn name(&self) -> &'static str {
        "adaptive"
    }

    fn plan(
        &mut self,
        registry: &mut ClientRegistry,
        available: &[NodeId],
        ctx: &PlanContext,
        rng: &mut Rng,
    ) -> RoundPlan {
        let k = ctx.k.min(available.len());
        if k == 0 {
            return RoundPlan::empty();
        }
        registry.tick_round();
        // bench chronic stragglers: EWMA round time far above the median
        let median = registry.median_round_ms();
        if median > 0.0 && ctx.round > 0 {
            let stragglers: Vec<NodeId> = available
                .iter()
                .copied()
                .filter(|&id| {
                    registry
                        .get(id)
                        .is_some_and(|r| r.ewma_round_ms > self.exclude_factor * median)
                })
                .collect();
            for id in stragglers {
                registry.bench(id, self.bench_rounds);
                log::debug!(
                    "planner: benching straggler {id} for {} rounds",
                    self.bench_rounds
                );
            }
        }
        // eligible = available and not benched
        let eligible: Vec<NodeId> = available
            .iter()
            .copied()
            .filter(|&id| registry.get(id).map_or(true, |r| r.benched_for == 0))
            .collect();
        // if benching ate too much of the pool, fall back to all available
        let pool: &[NodeId] = if eligible.len() >= k {
            &eligible
        } else {
            available
        };

        let n_explore = (((k as f64) * self.explore_frac).round() as usize).min(k);
        let n_exploit = k - n_explore;

        // exploit: top-scoring clients
        let mut scored: Vec<(f64, NodeId)> = pool
            .iter()
            .map(|&id| {
                let s = registry.get(id).map_or(0.0, |r| r.score());
                (s, id)
            })
            .collect();
        scored.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        let mut selected: Vec<NodeId> = scored.iter().take(n_exploit).map(|&(_, id)| id).collect();
        let mut chosen: BTreeSet<NodeId> = selected.iter().copied().collect();

        // explore: uniform among the rest
        let rest: Vec<NodeId> = pool
            .iter()
            .copied()
            .filter(|id| !chosen.contains(id))
            .collect();
        let picks = rng.sample_indices(rest.len(), n_explore.min(rest.len()));
        for i in picks {
            let id = rest[i];
            selected.push(id);
            chosen.insert(id);
        }

        // top up if exploration pool was short
        if selected.len() < k {
            for &(_, id) in scored.iter() {
                if selected.len() >= k {
                    break;
                }
                if chosen.insert(id) {
                    selected.push(id);
                }
            }
        }
        selected.truncate(k);
        RoundPlan::uniform(selected, ctx.defaults)
    }
}

/// Planner-owned record of which epoch budget each client's EWMA was
/// observed under. `dispatch` notes the budget handed out at plan
/// time; only a *success* promotes it to the observed budget — a
/// client that never reports under a new budget keeps its last honest
/// divisor (its EWMA never saw the new budget either).
#[derive(Debug, Default)]
struct EpochLedger {
    dispatched: BTreeMap<NodeId, u32>,
    observed: BTreeMap<NodeId, u32>,
}

impl EpochLedger {
    fn dispatch(&mut self, id: NodeId, epochs: u32) {
        self.dispatched.insert(id, epochs);
    }

    /// The client reported: its EWMA now reflects the last dispatched
    /// budget.
    fn observe(&mut self, id: NodeId) {
        if let Some(&b) = self.dispatched.get(&id) {
            self.observed.insert(id, b);
        }
    }

    /// Per-epoch round-time estimate for `id`: the registry's EWMA
    /// round time divided by the budget it was observed under.
    /// Normalizing by that budget is what keeps the feedback loop
    /// stable: without it, cutting a slow client's epochs shrinks its
    /// EWMA, which shrinks its apparent slowdown, which hands it a
    /// bigger budget again — and it flips back to missing deadlines.
    /// Falls back to `default_epochs` for never-observed clients
    /// (their EWMA is the registration prior, a full default-budget
    /// round estimate) and to a neutral prior when the client never
    /// registered at all (test rigs, races at startup).
    fn est_epoch_ms(&self, registry: &ClientRegistry, default_epochs: u32, id: NodeId) -> f64 {
        let est_round = registry.get(id).map_or(1.0, |r| r.ewma_round_ms.max(1e-3));
        let epochs = self.observed.get(&id).copied().unwrap_or(default_epochs).max(1);
        est_round / epochs as f64
    }
}

/// Tier-bucketed dispatch: cohort sampled uniformly (identical picks
/// to [`RandomPlanner`] for the same seed, so tiered-vs-random
/// ablations isolate the dispatch effect), then bucketed into
/// `tiers` contiguous tiers by ascending per-epoch round time
/// (`EpochLedger::est_epoch_ms`). Tier `t`'s members get their epoch
/// budget and top-k fraction divided by the tier's median slowdown
/// versus the fastest tier — a client ~4× slower trains ~¼ the epochs
/// and uploads a sparser update, so it lands inside the same deadline
/// the fast tier meets.
pub struct TieredPlanner {
    pub tiers: usize,
    /// Which budget each client's EWMA was observed under
    /// (planner-owned state; see [`EpochLedger`]).
    ledger: EpochLedger,
}

impl TieredPlanner {
    pub fn new(tiers: usize) -> TieredPlanner {
        TieredPlanner {
            tiers,
            ledger: EpochLedger::default(),
        }
    }
}

impl CohortPlanner for TieredPlanner {
    fn name(&self) -> &'static str {
        "tiered"
    }

    fn plan(
        &mut self,
        registry: &mut ClientRegistry,
        available: &[NodeId],
        ctx: &PlanContext,
        rng: &mut Rng,
    ) -> RoundPlan {
        let k = ctx.k.min(available.len());
        if k == 0 {
            return RoundPlan::empty();
        }
        let picks = rng.sample_indices(available.len(), k);
        let cohort: Vec<NodeId> = picks.into_iter().map(|i| available[i]).collect();
        let d = ctx.defaults;

        // rank the cohort fast → slow (deterministic tie-break on id)
        let mut ranked: Vec<(f64, NodeId)> = cohort
            .iter()
            .map(|&id| (self.ledger.est_epoch_ms(registry, d.local_epochs, id), id))
            .collect();
        ranked.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));

        // contiguous buckets; ratio = tier median / fastest-tier median
        let tiers = self.tiers.clamp(1, k);
        let bucket = k.div_ceil(tiers);
        let tier_of = |pos: usize| pos / bucket;
        let median_of = |t: usize| -> f64 {
            let lo = t * bucket;
            let hi = ((t + 1) * bucket).min(k);
            ranked[lo + (hi - lo) / 2].0
        };
        let fastest = median_of(0).max(1e-3);
        let mut entries: Vec<(NodeId, DispatchPlan)> = Vec::with_capacity(k);
        for (pos, &(_, id)) in ranked.iter().enumerate() {
            let ratio = (median_of(tier_of(pos)) / fastest).max(1.0);
            // max(1) guards a zero-epoch default from inverting the clamp
            let local_epochs =
                ((d.local_epochs as f64 / ratio).round() as u32).clamp(1, d.local_epochs.max(1));
            // sparser uplink hint for slow tiers; floored so hostile
            // ratios can never zero out the update
            let topk = (d.compression.topk_frac as f64 / ratio)
                .max(0.05f64.min(d.compression.topk_frac as f64))
                as f32;
            self.ledger.dispatch(id, local_epochs);
            entries.push((
                id,
                DispatchPlan {
                    deadline_ms: d.deadline_ms,
                    local_epochs,
                    compression: CompressionConfig {
                        topk_frac: topk,
                        ..d.compression
                    },
                },
            ));
        }
        RoundPlan::from_entries(entries)
    }

    fn report_success(
        &mut self,
        registry: &mut ClientRegistry,
        id: NodeId,
        round: u32,
        round_ms: f64,
    ) {
        // the EWMA about to absorb `round_ms` was produced under the
        // last dispatched budget — record that pairing
        self.ledger.observe(id);
        registry.report_success(id, round, round_ms);
    }
}

/// Deadline-fitted dispatch: cohort sampled uniformly, then each
/// member's epoch budget is fitted to a target round deadline from its
/// per-epoch round-time estimate (`EpochLedger::est_epoch_ms`, seeded
/// by the profiled `bench_step_ms` prior before any history exists)
/// and link bandwidth — clients on sub-GB/s links keep 20% of the
/// budget as transfer headroom, fast links 5%.
pub struct DeadlinePlanner {
    /// Target round deadline; `None` uses the config default
    /// (`ctx.defaults.deadline_ms`).
    pub target_ms: Option<u64>,
    /// Which budget each client's EWMA was observed under
    /// (planner-owned state; see [`EpochLedger`]).
    ledger: EpochLedger,
}

impl DeadlinePlanner {
    pub fn new(target_ms: Option<u64>) -> DeadlinePlanner {
        DeadlinePlanner {
            target_ms,
            ledger: EpochLedger::default(),
        }
    }
}

impl CohortPlanner for DeadlinePlanner {
    fn name(&self) -> &'static str {
        "deadline"
    }

    fn plan(
        &mut self,
        registry: &mut ClientRegistry,
        available: &[NodeId],
        ctx: &PlanContext,
        rng: &mut Rng,
    ) -> RoundPlan {
        let k = ctx.k.min(available.len());
        if k == 0 {
            return RoundPlan::empty();
        }
        let picks = rng.sample_indices(available.len(), k);
        let cohort: Vec<NodeId> = picks.into_iter().map(|i| available[i]).collect();
        let d = ctx.defaults;
        let target_ms = self.target_ms.unwrap_or(d.deadline_ms);
        let mut entries: Vec<(NodeId, DispatchPlan)> = Vec::with_capacity(k);
        for id in cohort {
            let per_epoch_ms = self.ledger.est_epoch_ms(registry, d.local_epochs, id).max(1e-3);
            let link_bw = registry.get(id).map_or(1e9, |r| r.profile.link_bw);
            let headroom = if link_bw < 1e9 { 0.8 } else { 0.95 };
            let budget = (target_ms as f64 * headroom / per_epoch_ms).floor();
            let local_epochs = (budget as u32).clamp(1, d.local_epochs.max(1));
            self.ledger.dispatch(id, local_epochs);
            entries.push((
                id,
                DispatchPlan {
                    deadline_ms: target_ms,
                    local_epochs,
                    compression: d.compression,
                },
            ));
        }
        RoundPlan::from_entries(entries)
    }

    fn report_success(
        &mut self,
        registry: &mut ClientRegistry,
        id: NodeId,
        round: u32,
        round_ms: f64,
    ) {
        self.ledger.observe(id);
        registry.report_success(id, round, round_ms);
    }
}

/// All registered planner names.
pub fn planner_names() -> &'static [&'static str] {
    PlannerKind::KINDS
}

/// Record one planned cohort into the global telemetry registry
/// (cohorts-planned counter + last-cohort-size gauge). Handles are
/// resolved once per process behind a `OnceLock`, so the per-round
/// cost is two relaxed atomic stores. Strictly write-only telemetry:
/// nothing here reads planner state, the client registry or the RNG,
/// so planning stays bit-deterministic with or without a scraper
/// attached (pinned by `rust/tests/telemetry_determinism.rs`).
pub fn record_plan_telemetry(plan: &RoundPlan) {
    use crate::telemetry::{self, names, Counter, Gauge};
    use std::sync::{Arc, OnceLock};
    static HANDLES: OnceLock<(Arc<Counter>, Arc<Gauge>)> = OnceLock::new();
    let (planned, size) = HANDLES.get_or_init(|| {
        let g = telemetry::global();
        (
            g.counter(
                names::COHORTS_PLANNED_TOTAL,
                "Cohorts planned since process start.",
            ),
            g.gauge(names::COHORT_SIZE, "Size of the most recently planned cohort."),
        )
    });
    planned.inc();
    size.set(plan.len() as u64);
}

/// Instantiate the planner a config value describes.
pub fn planner_from_config(kind: &PlannerKind) -> Box<dyn CohortPlanner> {
    match *kind {
        PlannerKind::Random => Box::new(RandomPlanner),
        PlannerKind::Adaptive {
            explore_frac,
            exclude_factor,
        } => Box::new(AdaptivePlanner::new(explore_frac, exclude_factor)),
        PlannerKind::Tiered { tiers } => Box::new(TieredPlanner::new(tiers)),
        PlannerKind::Deadline { target_ms } => Box::new(DeadlinePlanner::new(target_ms)),
    }
}

/// Instantiate a planner by registry name (`"random"`,
/// `"adaptive:0.2:2.5"`, `"tiered:4"`, `"deadline:2000"`, …). Unknown
/// names error.
pub fn planner_by_name(spec: &str) -> Result<Box<dyn CohortPlanner>> {
    Ok(planner_from_config(&PlannerKind::parse(spec)?))
}

/// The planner a [`SelectionConfig`] resolves to (explicit `planner`
/// spec, else the legacy `policy`). Fresh state every call — bench
/// counters and any learned planner state belong to one training run.
pub fn planner_from_selection(sel: &SelectionConfig) -> Box<dyn CohortPlanner> {
    planner_from_config(&sel.planner_kind())
}

#[cfg(test)]
mod tests {
    use super::super::registry::test_profile;
    use super::*;
    use crate::network::ClientProfile;

    /// Verbatim port of the pre-planner `selection::select_clients`
    /// free function (O(k²) `Vec::contains` and all) — the reference
    /// the acceptance criterion pins `random` / `adaptive` against.
    mod legacy {
        use crate::cluster::NodeId;
        use crate::orchestrator::ClientRegistry;
        use crate::util::rng::Rng;

        pub enum Policy {
            Random,
            Adaptive {
                explore_frac: f64,
                exclude_factor: f64,
            },
        }

        pub fn select_clients(
            registry: &mut ClientRegistry,
            available: &[NodeId],
            policy: &Policy,
            clients_per_round: usize,
            round: u32,
            rng: &mut Rng,
        ) -> Vec<NodeId> {
            let k = clients_per_round.min(available.len());
            if k == 0 {
                return vec![];
            }
            match *policy {
                Policy::Random => {
                    let picks = rng.sample_indices(available.len(), k);
                    picks.into_iter().map(|i| available[i]).collect()
                }
                Policy::Adaptive {
                    explore_frac,
                    exclude_factor,
                } => adaptive(registry, available, k, explore_frac, exclude_factor, round, rng),
            }
        }

        fn adaptive(
            registry: &mut ClientRegistry,
            available: &[NodeId],
            k: usize,
            explore_frac: f64,
            exclude_factor: f64,
            round: u32,
            rng: &mut Rng,
        ) -> Vec<NodeId> {
            registry.tick_round();
            let median = registry.median_round_ms();
            if median > 0.0 && round > 0 {
                let stragglers: Vec<NodeId> = available
                    .iter()
                    .copied()
                    .filter(|&id| {
                        registry
                            .get(id)
                            .is_some_and(|r| r.ewma_round_ms > exclude_factor * median)
                    })
                    .collect();
                for id in stragglers {
                    registry.bench(id, 3);
                }
            }
            let eligible: Vec<NodeId> = available
                .iter()
                .copied()
                .filter(|&id| registry.get(id).map_or(true, |r| r.benched_for == 0))
                .collect();
            let pool: &[NodeId] = if eligible.len() >= k {
                &eligible
            } else {
                available
            };
            let n_explore = ((k as f64) * explore_frac).round() as usize;
            let n_exploit = k - n_explore;
            let mut scored: Vec<(f64, NodeId)> = pool
                .iter()
                .map(|&id| {
                    let s = registry.get(id).map_or(0.0, |r| r.score());
                    (s, id)
                })
                .collect();
            scored.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
            let mut selected: Vec<NodeId> =
                scored.iter().take(n_exploit).map(|&(_, id)| id).collect();
            let rest: Vec<NodeId> = pool
                .iter()
                .copied()
                .filter(|id| !selected.contains(id))
                .collect();
            let picks = rng.sample_indices(rest.len(), n_explore.min(rest.len()));
            selected.extend(picks.into_iter().map(|i| rest[i]));
            if selected.len() < k {
                for &(_, id) in scored.iter() {
                    if selected.len() >= k {
                        break;
                    }
                    if !selected.contains(&id) {
                        selected.push(id);
                    }
                }
            }
            selected.truncate(k);
            selected
        }
    }

    fn defaults() -> DispatchPlan {
        DispatchPlan {
            deadline_ms: 60_000,
            local_epochs: 5,
            compression: CompressionConfig::NONE,
        }
    }

    fn ctx(round: u32, k: usize) -> PlanContext {
        PlanContext {
            round,
            k,
            defaults: defaults(),
        }
    }

    fn registry_with(n: u32) -> (ClientRegistry, Vec<NodeId>) {
        let mut reg = ClientRegistry::new();
        for i in 0..n {
            reg.register(i, test_profile(1.0, 1e9));
        }
        (reg, (0..n).collect())
    }

    /// A heterogeneous registry with mixed history, shared by the
    /// legacy-equivalence grid.
    fn heterogeneous_registry(n: u32, seed: u64) -> (ClientRegistry, Vec<NodeId>) {
        let mut reg = ClientRegistry::new();
        let mut rng = Rng::new(seed);
        for i in 0..n {
            reg.register(
                i,
                ClientProfile {
                    speed_factor: 0.05 + rng.f64() * 2.0,
                    mem_gb: 16.0,
                    link_bw: 1e8 + rng.f64() * 1e10,
                    n_samples: 100,
                    bench_step_ms: 1.0 + rng.f64() * 100.0,
                },
            );
            for r in 0..5 {
                if rng.chance(0.8) {
                    reg.report_success(i, r, 20.0 + rng.f64() * 5_000.0);
                } else {
                    reg.report_failure(i, r);
                }
            }
        }
        (reg, (0..n).collect())
    }

    /// The acceptance pin: `random` and `adaptive` planners reproduce
    /// the pre-planner cohorts bit-identically — same seed, same
    /// registry history, same cohort, across multi-round sequences
    /// (which exercise benching + tick + fallback paths).
    #[test]
    fn random_and_adaptive_reproduce_legacy_cohorts_bit_identically() {
        for seed in 0..12u64 {
            for &k in &[1usize, 7, 10, 29, 40] {
                for &explore in &[0.0f64, 0.2, 0.5, 1.0] {
                    let (mut legacy_reg, avail) = heterogeneous_registry(30, seed);
                    let (mut new_reg, _) = heterogeneous_registry(30, seed);
                    let mut legacy_rng = Rng::new(seed ^ 0xBEEF);
                    let mut new_rng = Rng::new(seed ^ 0xBEEF);
                    let mut planner = AdaptivePlanner::new(explore, 2.5);
                    for round in 0..4u32 {
                        let want = legacy::select_clients(
                            &mut legacy_reg,
                            &avail,
                            &legacy::Policy::Adaptive {
                                explore_frac: explore,
                                exclude_factor: 2.5,
                            },
                            k,
                            round,
                            &mut legacy_rng,
                        );
                        let got = planner.plan(&mut new_reg, &avail, &ctx(round, k), &mut new_rng);
                        assert_eq!(
                            got.cohort(),
                            &want[..],
                            "adaptive diverged: seed {seed} k {k} explore {explore} round {round}"
                        );
                        // identical feedback keeps the registries in lockstep
                        for &id in &want {
                            legacy_reg.report_success(id, round, 40.0 * (id as f64 + 1.0));
                            planner.report_success(
                                &mut new_reg,
                                id,
                                round,
                                40.0 * (id as f64 + 1.0),
                            );
                        }
                    }

                    let want = legacy::select_clients(
                        &mut legacy_reg,
                        &avail,
                        &legacy::Policy::Random,
                        k,
                        0,
                        &mut Rng::new(seed),
                    );
                    let got = RandomPlanner.plan(
                        &mut new_reg,
                        &avail,
                        &ctx(0, k),
                        &mut Rng::new(seed),
                    );
                    assert_eq!(got.cohort(), &want[..], "random diverged: seed {seed} k {k}");
                }
            }
        }
    }

    #[test]
    fn every_registered_name_instantiates_with_matching_name() {
        for name in planner_names() {
            let p = planner_by_name(name).unwrap();
            assert_eq!(&p.name(), name);
        }
        assert!(planner_by_name("no_such_planner").is_err());
    }

    #[test]
    fn params_flow_through_by_name_selection() {
        let mut p = planner_by_name("deadline:1234").unwrap();
        let (mut reg, avail) = registry_with(4);
        let plan = p.plan(&mut reg, &avail, &ctx(0, 2), &mut Rng::new(0));
        assert!(plan.iter().all(|(_, d)| d.deadline_ms == 1234));
    }

    #[test]
    fn planner_from_selection_honours_override_and_policy() {
        use crate::config::SelectionPolicy;
        let mut sel = SelectionConfig {
            policy: SelectionPolicy::Random,
            planner: None,
            clients_per_round: 4,
        };
        assert_eq!(planner_from_selection(&sel).name(), "random");
        sel.policy = SelectionPolicy::default();
        assert_eq!(planner_from_selection(&sel).name(), "adaptive");
        sel.planner = Some(PlannerKind::Tiered { tiers: 2 });
        assert_eq!(planner_from_selection(&sel).name(), "tiered");
    }

    #[test]
    fn random_selects_k_distinct_with_default_plans() {
        let (mut reg, avail) = registry_with(30);
        let plan = RandomPlanner.plan(&mut reg, &avail, &ctx(0, 10), &mut Rng::new(0));
        assert_eq!(plan.len(), 10);
        let mut s = plan.cohort().to_vec();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
        for (_, p) in plan.iter() {
            assert_eq!(*p, defaults());
        }
    }

    #[test]
    fn k_larger_than_pool_takes_all() {
        let (mut reg, avail) = registry_with(5);
        let mut rng = Rng::new(1);
        for spec in ["random", "adaptive", "tiered:2", "deadline"] {
            let mut p = planner_by_name(spec).unwrap();
            let plan = p.plan(&mut reg, &avail, &ctx(0, 20), &mut rng);
            assert_eq!(plan.len(), 5, "{spec}");
        }
    }

    #[test]
    fn adaptive_prefers_fast_reliable_clients() {
        let mut reg = ClientRegistry::new();
        // 0..5 fast, 5..10 slow
        for i in 0..10u32 {
            let speed = if i < 5 { 1.0 } else { 0.02 };
            reg.register(i, test_profile(speed, 1e9));
        }
        for r in 0..10 {
            for i in 0..10u32 {
                let t = if i < 5 { 100.0 } else { 5_000.0 };
                reg.report_success(i, r, t);
            }
        }
        let avail: Vec<NodeId> = (0..10).collect();
        // no exploration → pure exploitation for determinism
        let mut planner = AdaptivePlanner::new(0.0, 100.0);
        let plan = planner.plan(&mut reg, &avail, &ctx(5, 5), &mut Rng::new(2));
        assert_eq!(plan.len(), 5);
        assert!(
            plan.cohort().iter().all(|&id| id < 5),
            "picked slow clients: {:?}",
            plan.cohort()
        );
    }

    #[test]
    fn adaptive_benches_extreme_stragglers() {
        let mut reg = ClientRegistry::new();
        for i in 0..10u32 {
            reg.register(i, test_profile(1.0, 1e9));
        }
        for r in 0..5 {
            for i in 0..10u32 {
                let t = if i == 9 { 100_000.0 } else { 100.0 };
                reg.report_success(i, r, t);
            }
        }
        let avail: Vec<NodeId> = (0..10).collect();
        let mut planner = AdaptivePlanner::new(0.0, 2.5);
        let plan = planner.plan(&mut reg, &avail, &ctx(5, 9), &mut Rng::new(3));
        assert!(
            !plan.cohort().contains(&9),
            "straggler 9 selected: {:?}",
            plan.cohort()
        );
        assert!(reg.get(9).unwrap().benched_for > 0);
    }

    #[test]
    fn exploration_reaches_cold_clients() {
        let mut reg = ClientRegistry::new();
        for i in 0..20u32 {
            reg.register(i, test_profile(1.0, 1e9));
        }
        // clients 0..10 have glowing history; 10..20 are cold
        for r in 0..10 {
            for i in 0..10u32 {
                reg.report_success(i, r, 50.0);
            }
        }
        let avail: Vec<NodeId> = (0..20).collect();
        let mut hit_cold = false;
        for seed in 0..20 {
            let mut planner = AdaptivePlanner::new(0.4, 100.0);
            let plan = planner.plan(&mut reg, &avail, &ctx(1, 10), &mut Rng::new(seed));
            if plan.cohort().iter().any(|&id| id >= 10) {
                hit_cold = true;
                break;
            }
        }
        assert!(hit_cold, "exploration never sampled cold clients");
    }

    /// ISSUE satellite: `explore_frac == 1.0` means every slot is an
    /// exploration slot — still exactly `k` distinct clients.
    #[test]
    fn adaptive_all_explore_fills_the_cohort() {
        let (mut reg, avail) = registry_with(25);
        let mut planner = AdaptivePlanner::new(1.0, 2.5);
        let plan = planner.plan(&mut reg, &avail, &ctx(0, 10), &mut Rng::new(4));
        assert_eq!(plan.len(), 10);
        let mut s = plan.cohort().to_vec();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10, "duplicate ids in all-explore cohort");
    }

    /// ISSUE satellite: when benching shrinks the eligible pool below
    /// `k`, the planner falls back to the full available set.
    #[test]
    fn adaptive_falls_back_when_cohort_exceeds_unbenched_pool() {
        let (mut reg, avail) = registry_with(10);
        for i in 0..8u32 {
            reg.bench(i, 5);
        }
        // k = 6 > the 2 unbenched clients → fallback to all 10
        let mut planner = AdaptivePlanner::new(0.0, 100.0);
        let plan = planner.plan(&mut reg, &avail, &ctx(0, 6), &mut Rng::new(5));
        assert_eq!(plan.len(), 6);
        let mut s = plan.cohort().to_vec();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 6);
    }

    /// ISSUE satellite: a single-client cluster works under every
    /// planner (cohort = that client, with a plan).
    #[test]
    fn single_client_cluster_plans_under_every_planner() {
        for spec in ["random", "adaptive", "tiered:4", "deadline:500"] {
            let (mut reg, avail) = registry_with(1);
            let mut p = planner_by_name(spec).unwrap();
            let plan = p.plan(&mut reg, &avail, &ctx(0, 3), &mut Rng::new(6));
            assert_eq!(plan.cohort(), &[0], "{spec}");
            assert!(plan.get(0).is_some(), "{spec}: member without a plan");
            assert!(plan.get(0).unwrap().local_epochs >= 1, "{spec}");
        }
    }

    #[test]
    fn empty_pool_returns_empty_plan() {
        let (mut reg, _) = registry_with(5);
        for spec in ["random", "adaptive", "tiered:2", "deadline"] {
            let mut p = planner_by_name(spec).unwrap();
            let plan = p.plan(&mut reg, &[], &ctx(0, 3), &mut Rng::new(0));
            assert!(plan.is_empty(), "{spec}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        for spec in ["random", "adaptive", "tiered:3", "deadline:900"] {
            let (mut r1, avail) = heterogeneous_registry(30, 3);
            let (mut r2, _) = heterogeneous_registry(30, 3);
            let a = planner_by_name(spec).unwrap().plan(
                &mut r1,
                &avail,
                &ctx(0, 10),
                &mut Rng::new(9),
            );
            let b = planner_by_name(spec).unwrap().plan(
                &mut r2,
                &avail,
                &ctx(0, 10),
                &mut Rng::new(9),
            );
            assert_eq!(a, b, "{spec}: same seed must give same cohort and plans");
        }
    }

    /// Regression for the HashMap/HashSet → BTree conversion: two
    /// identically-seeded multi-round runs must emit identical cohorts
    /// in identical dispatch order (stateful planners included), and
    /// the re-dispatch lookup table must iterate NodeId-ordered —
    /// nothing left depends on hasher seeds.
    #[test]
    fn run_twice_cohorts_and_plan_maps_are_identical() {
        for spec in ["random", "adaptive", "tiered:3", "deadline:900"] {
            let run = || {
                let (mut reg, avail) = heterogeneous_registry(40, 11);
                let mut planner = planner_by_name(spec).unwrap();
                let mut rng = Rng::new(77);
                let mut cohorts: Vec<Vec<NodeId>> = Vec::new();
                for round in 0..5 {
                    let plan = planner.plan(&mut reg, &avail, &ctx(round, 12), &mut rng);
                    let map = plan.to_map();
                    let keys: Vec<NodeId> = map.keys().copied().collect();
                    let mut sorted = keys.clone();
                    sorted.sort_unstable();
                    assert_eq!(keys, sorted, "{spec}: to_map must iterate NodeId-ordered");
                    for (c, p) in plan.iter() {
                        assert_eq!(map.get(&c), Some(p), "{spec}: map/plan disagree for {c}");
                    }
                    cohorts.push(plan.cohort().to_vec());
                }
                cohorts
            };
            assert_eq!(run(), run(), "{spec}: run-twice cohort sequences diverged");
        }
    }

    #[test]
    fn tiered_gives_slow_clients_fewer_epochs_and_sparser_uplink() {
        let mut reg = ClientRegistry::new();
        // 0..4 fast (≈100 ms rounds), 4..8 slow (≈1600 ms rounds)
        for i in 0..8u32 {
            reg.register(i, test_profile(1.0, 1e9));
        }
        for r in 0..10 {
            for i in 0..8u32 {
                let t = if i < 4 { 100.0 } else { 1_600.0 };
                reg.report_success(i, r, t);
            }
        }
        let avail: Vec<NodeId> = (0..8).collect();
        let mut planner = TieredPlanner::new(2);
        let mut c = ctx(0, 8);
        c.defaults.local_epochs = 8;
        c.defaults.compression = CompressionConfig {
            quant_bits: 32,
            topk_frac: 1.0,
            dropout_keep: 1.0,
        };
        let plan = planner.plan(&mut reg, &avail, &c, &mut Rng::new(7));
        assert_eq!(plan.len(), 8);
        for (id, p) in plan.iter() {
            if id < 4 {
                // fastest tier keeps the full budget
                assert_eq!(p.local_epochs, 8, "fast client {id}");
                assert_eq!(p.compression.topk_frac, 1.0);
            } else {
                // ~16× slower tier: epochs cut to the floor, uplink sparser
                assert!(p.local_epochs <= 2, "slow client {id}: {}", p.local_epochs);
                assert!(p.local_epochs >= 1);
                assert!(
                    p.compression.topk_frac < 0.5,
                    "slow client {id}: topk {}",
                    p.compression.topk_frac
                );
                assert!(p.compression.topk_frac >= 0.05);
            }
            assert_eq!(p.deadline_ms, c.defaults.deadline_ms);
        }
    }

    /// Review fix: a client that never reports under a newly
    /// dispatched budget keeps its last *observed* estimate divisor —
    /// its EWMA never saw the new budget either. (Promoting at plan
    /// time inflated a non-reporting client's per-epoch estimate
    /// budget-fold, pinning it to the floor even after it recovered.)
    #[test]
    fn tiered_failed_dispatch_does_not_switch_the_epoch_divisor() {
        let mut reg = ClientRegistry::new();
        reg.register(0, test_profile(1.0, 1e9));
        reg.register(1, test_profile(1.0, 1e9));
        for r in 0..10 {
            reg.report_success(0, r, 100.0);
            reg.report_success(1, r, 400.0);
        }
        let mut planner = TieredPlanner::new(2);
        let mut c = ctx(0, 2);
        c.defaults.local_epochs = 8;
        // per-epoch estimates ≈ 100/8 vs 400/8 → ratio ≈ 4 → the slow
        // client's budget is halved twice: round(8/4) = 2
        let plan = planner.plan(&mut reg, &[0, 1], &c, &mut Rng::new(0));
        assert_eq!(plan.get(1).unwrap().local_epochs, 2);
        // the slow client misses the round entirely: its EWMA is
        // untouched, so the 2-epoch dispatch must NOT become its
        // estimate divisor — the next plan is unchanged, not floored
        planner.report_success(&mut reg, 0, 0, 100.0);
        planner.report_failure(&mut reg, 1, 0);
        let plan = planner.plan(&mut reg, &[0, 1], &c, &mut Rng::new(1));
        assert_eq!(
            plan.get(1).unwrap().local_epochs,
            2,
            "estimate divisor switched on a failed dispatch"
        );
    }

    #[test]
    fn tiered_homogeneous_fleet_keeps_default_dispatch() {
        let mut reg = ClientRegistry::new();
        for i in 0..6u32 {
            reg.register(i, test_profile(1.0, 1e9));
            for r in 0..5 {
                reg.report_success(i, r, 200.0);
            }
        }
        let avail: Vec<NodeId> = (0..6).collect();
        let mut planner = TieredPlanner::new(3);
        let plan = planner.plan(&mut reg, &avail, &ctx(0, 6), &mut Rng::new(8));
        for (_, p) in plan.iter() {
            assert_eq!(p.local_epochs, defaults().local_epochs);
            assert_eq!(p.compression, defaults().compression);
        }
    }

    #[test]
    fn tiered_cohort_matches_random_cohort_for_same_seed() {
        // tiered-vs-random ablations must isolate the dispatch effect:
        // the cohort itself is the same uniform sample
        let (mut r1, avail) = heterogeneous_registry(40, 11);
        let (mut r2, _) = heterogeneous_registry(40, 11);
        let a = RandomPlanner.plan(&mut r1, &avail, &ctx(0, 12), &mut Rng::new(13));
        let b = TieredPlanner::new(4).plan(&mut r2, &avail, &ctx(0, 12), &mut Rng::new(13));
        let mut sa = a.cohort().to_vec();
        let mut sb = b.cohort().to_vec();
        sa.sort_unstable();
        sb.sort_unstable();
        assert_eq!(sa, sb);
    }

    #[test]
    fn deadline_fits_epoch_budget_to_target() {
        let mut reg = ClientRegistry::new();
        // client 0: ~100 ms rounds at 5 epochs (20 ms/epoch);
        // client 1: ~2000 ms rounds (400 ms/epoch)
        for i in 0..2u32 {
            reg.register(i, test_profile(1.0, 1e10));
            for r in 0..10 {
                reg.report_success(i, r, if i == 0 { 100.0 } else { 2_000.0 });
            }
        }
        let avail = vec![0, 1];
        let mut planner = DeadlinePlanner::new(Some(800));
        let plan = planner.plan(&mut reg, &avail, &ctx(0, 2), &mut Rng::new(0));
        let fast = plan.get(0).unwrap();
        let slow = plan.get(1).unwrap();
        // fast client: 800·0.95 / 20 = 38 → clamped to the default 5
        assert_eq!(fast.local_epochs, 5);
        // slow client: 800·0.95 / 400 = 1.9 → 1 epoch
        assert_eq!(slow.local_epochs, 1);
        assert_eq!(fast.deadline_ms, 800);
        assert_eq!(slow.deadline_ms, 800);
    }

    #[test]
    fn deadline_low_bandwidth_links_keep_more_headroom() {
        let mut reg = ClientRegistry::new();
        // identical compute history, different link classes
        reg.register(0, test_profile(1.0, 1e10));
        reg.register(1, test_profile(1.0, 1e8));
        for r in 0..10 {
            reg.report_success(0, r, 500.0);
            reg.report_success(1, r, 500.0);
        }
        // 100 ms/epoch estimate: at a 350 ms target the fast link fits
        // floor(350·0.95/100) = 3 epochs, the slow link only
        // floor(350·0.8/100) = 2 — the 20% transfer headroom bites
        let mut planner = DeadlinePlanner::new(Some(350));
        let plan = planner.plan(&mut reg, &[0, 1], &ctx(0, 2), &mut Rng::new(0));
        assert_eq!(plan.get(0).unwrap().local_epochs, 3);
        assert_eq!(plan.get(1).unwrap().local_epochs, 2);
    }

    #[test]
    fn round_plan_lookup_and_deadline_bound() {
        let plan = RoundPlan::from_entries(vec![
            (
                7,
                DispatchPlan {
                    deadline_ms: 100,
                    local_epochs: 2,
                    compression: CompressionConfig::NONE,
                },
            ),
            (
                3,
                DispatchPlan {
                    deadline_ms: 900,
                    local_epochs: 1,
                    compression: CompressionConfig::NONE,
                },
            ),
        ]);
        assert_eq!(plan.cohort(), &[7, 3]);
        assert_eq!(plan.get(3).unwrap().deadline_ms, 900);
        assert!(plan.get(4).is_none());
        assert_eq!(plan.max_deadline_ms(), 900);
        assert_eq!(RoundPlan::empty().max_deadline_ms(), 0);
    }
}
