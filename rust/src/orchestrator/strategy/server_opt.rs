//! Server-side optimizers (FedOpt family, Reddi et al.): the second
//! half of a round's finalize, `M_{r+1} = opt(M_r, Δ_agg)`.
//!
//! The aggregation strategy reduces k client updates to one f64 Δ_agg;
//! the server optimizer decides how that update moves the global
//! model. [`SgdServer`] reproduces the classic FedAvg step
//! bit-identically; [`FedAvgM`] and [`FedAdam`] carry optimizer state
//! (momentum / second moments, in f64) across rounds on the
//! orchestrator — state the old enum-based aggregation path had no
//! place to keep.

use crate::util::parallel::par_chunks_mut;
use anyhow::{bail, Result};

/// One server optimizer step per non-empty round. Implementations may
/// carry state across calls (`&mut self`); a zero-update round skips
/// the step entirely, so state advances only when the model does.
pub trait ServerOpt: Send {
    /// Registry name (matches [`crate::config::ServerOptKind::name`]).
    fn name(&self) -> &'static str;

    /// `M_{r+1}` from `M_r` and the round's aggregated update Δ_agg.
    /// `delta` is f64 end to end; the result is cast to f32 once, at
    /// the very end, exactly like the pre-refactor finalize.
    fn apply(&mut self, global: &[f32], delta: &[f64]) -> Result<Vec<f32>>;
}

fn check_lengths(name: &str, global: &[f32], delta: &[f64]) -> Result<()> {
    if global.len() != delta.len() {
        bail!(
            "server-opt {name}: global length {} != delta length {}",
            global.len(),
            delta.len()
        );
    }
    Ok(())
}

/// Plain server step `M_{r+1} = M_r + Δ_agg` — the classic FedAvg
/// server and the default. Stateless; bit-identical to the
/// pre-refactor fold-then-normalize finalize.
#[derive(Debug, Clone, Copy, Default)]
pub struct SgdServer;

impl ServerOpt for SgdServer {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn apply(&mut self, global: &[f32], delta: &[f64]) -> Result<Vec<f32>> {
        check_lengths("sgd", global, delta)?;
        let mut new_params = vec![0f32; global.len()];
        par_chunks_mut(&mut new_params, 256 * 1024, |offset, chunk| {
            let d = &delta[offset..offset + chunk.len()];
            let g = &global[offset..offset + chunk.len()];
            for ((out, &dv), &gv) in chunk.iter_mut().zip(d).zip(g) {
                *out = (gv as f64 + dv) as f32;
            }
        });
        Ok(new_params)
    }
}

/// Server momentum (FedAvgM, Hsu et al.):
/// `v ← β·v + Δ_agg; M_{r+1} = M_r + v`. The velocity vector persists
/// across rounds (f64, O(P)).
#[derive(Debug)]
pub struct FedAvgM {
    beta: f64,
    velocity: Vec<f64>,
}

impl FedAvgM {
    pub fn new(beta: f32) -> Self {
        FedAvgM {
            beta: beta as f64,
            velocity: Vec::new(),
        }
    }

    /// Current velocity (empty before the first step) — for tests.
    pub fn velocity(&self) -> &[f64] {
        &self.velocity
    }
}

impl ServerOpt for FedAvgM {
    fn name(&self) -> &'static str {
        "fedavgm"
    }

    fn apply(&mut self, global: &[f32], delta: &[f64]) -> Result<Vec<f32>> {
        check_lengths("fedavgm", global, delta)?;
        if self.velocity.is_empty() {
            self.velocity = vec![0f64; delta.len()];
        }
        if self.velocity.len() != delta.len() {
            bail!(
                "server-opt fedavgm: model size changed ({} != {})",
                self.velocity.len(),
                delta.len()
            );
        }
        let beta = self.beta;
        par_chunks_mut(&mut self.velocity, 256 * 1024, |offset, chunk| {
            let d = &delta[offset..offset + chunk.len()];
            for (v, &dv) in chunk.iter_mut().zip(d) {
                *v = beta * *v + dv;
            }
        });
        let velocity = &self.velocity;
        let mut new_params = vec![0f32; global.len()];
        par_chunks_mut(&mut new_params, 256 * 1024, |offset, chunk| {
            let v = &velocity[offset..offset + chunk.len()];
            let g = &global[offset..offset + chunk.len()];
            for ((out, &vv), &gv) in chunk.iter_mut().zip(v).zip(g) {
                *out = (gv as f64 + vv) as f32;
            }
        });
        Ok(new_params)
    }
}

/// Server Adam (FedAdam, Reddi et al.) with bias correction:
/// `m ← β₁·m + (1−β₁)·Δ; v ← β₂·v + (1−β₂)·Δ²;`
/// `M_{r+1} = M_r + lr · m̂ / (√v̂ + ε)`. First/second moments persist
/// across rounds (f64, O(P) each).
#[derive(Debug)]
pub struct FedAdam {
    lr: f64,
    beta1: f64,
    beta2: f64,
    eps: f64,
    t: i32,
    m: Vec<f64>,
    v: Vec<f64>,
}

impl FedAdam {
    pub fn new(lr: f32, beta1: f32, beta2: f32, eps: f32) -> Self {
        FedAdam {
            lr: lr as f64,
            beta1: beta1 as f64,
            beta2: beta2 as f64,
            eps: eps as f64,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
        }
    }
}

impl ServerOpt for FedAdam {
    fn name(&self) -> &'static str {
        "fedadam"
    }

    fn apply(&mut self, global: &[f32], delta: &[f64]) -> Result<Vec<f32>> {
        check_lengths("fedadam", global, delta)?;
        if self.m.is_empty() {
            self.m = vec![0f64; delta.len()];
            self.v = vec![0f64; delta.len()];
        }
        if self.m.len() != delta.len() {
            bail!(
                "server-opt fedadam: model size changed ({} != {})",
                self.m.len(),
                delta.len()
            );
        }
        self.t += 1;
        let (b1, b2) = (self.beta1, self.beta2);
        par_chunks_mut(&mut self.m, 256 * 1024, |offset, chunk| {
            let d = &delta[offset..offset + chunk.len()];
            for (m, &dv) in chunk.iter_mut().zip(d) {
                *m = b1 * *m + (1.0 - b1) * dv;
            }
        });
        par_chunks_mut(&mut self.v, 256 * 1024, |offset, chunk| {
            let d = &delta[offset..offset + chunk.len()];
            for (v, &dv) in chunk.iter_mut().zip(d) {
                *v = b2 * *v + (1.0 - b2) * dv * dv;
            }
        });
        let bc1 = 1.0 - b1.powi(self.t);
        let bc2 = 1.0 - b2.powi(self.t);
        let (lr, eps) = (self.lr, self.eps);
        let (m, v) = (&self.m, &self.v);
        let mut new_params = vec![0f32; global.len()];
        par_chunks_mut(&mut new_params, 256 * 1024, |offset, chunk| {
            let mm = &m[offset..offset + chunk.len()];
            let vv = &v[offset..offset + chunk.len()];
            let g = &global[offset..offset + chunk.len()];
            for (i, out) in chunk.iter_mut().enumerate() {
                let mhat = mm[i] / bc1;
                let vhat = vv[i] / bc2;
                *out = (g[i] as f64 + lr * mhat / (vhat.sqrt() + eps)) as f32;
            }
        });
        Ok(new_params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_is_plain_add() {
        let out = SgdServer
            .apply(&[1.0, 2.0, 3.0], &[0.5, -0.5, 0.0])
            .unwrap();
        assert_eq!(out, vec![1.5, 1.5, 3.0]);
        assert!(SgdServer.apply(&[1.0], &[0.5, 0.5]).is_err());
    }

    /// The momentum satellite: state must carry across rounds.
    #[test]
    fn fedavgm_momentum_accumulates_across_rounds() {
        let mut opt = FedAvgM::new(0.5);
        assert!(opt.velocity().is_empty());
        // round 0: v = 1.0, M = 0 + 1.0
        let m1 = opt.apply(&[0.0; 4], &[1.0; 4]).unwrap();
        assert_eq!(m1, vec![1.0f32; 4]);
        assert_eq!(opt.velocity(), &[1.0f64; 4][..]);
        // round 1 (same delta): v = 0.5·1 + 1 = 1.5, M = 1 + 1.5 = 2.5
        let m2 = opt.apply(&m1, &[1.0; 4]).unwrap();
        assert_eq!(m2, vec![2.5f32; 4]);
        assert_eq!(opt.velocity(), &[1.5f64; 4][..]);
        // round 2: v = 0.75 + 1 = 1.75, M = 4.25
        let m3 = opt.apply(&m2, &[1.0; 4]).unwrap();
        assert_eq!(m3, vec![4.25f32; 4]);
    }

    #[test]
    fn fedavgm_beta_zero_matches_sgd() {
        let mut opt = FedAvgM::new(0.0);
        let g = [0.5f32, -1.0, 2.0];
        let d = [0.25f64, 0.25, -0.5];
        let a = opt.apply(&g, &d).unwrap();
        let b = SgdServer.apply(&g, &d).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn fedavgm_rejects_model_size_change() {
        let mut opt = FedAvgM::new(0.9);
        opt.apply(&[0.0; 3], &[1.0; 3]).unwrap();
        assert!(opt.apply(&[0.0; 4], &[1.0; 4]).is_err());
    }

    #[test]
    fn fedadam_steps_toward_delta_direction_bounded_by_lr() {
        let mut opt = FedAdam::new(0.1, 0.9, 0.99, 1e-8);
        let mut global = vec![0f32; 3];
        for _ in 0..5 {
            global = opt.apply(&global, &[1.0, -1.0, 1.0]).unwrap();
        }
        // with bias correction and constant delta, each step ≈ lr
        assert!(global[0] > 0.3 && global[0] < 0.6, "got {}", global[0]);
        assert!(global[1] < -0.3 && global[1] > -0.6);
        assert!((global[0] + global[1]).abs() < 1e-6, "symmetry");
    }

    #[test]
    fn fedadam_adapts_per_coordinate_scale() {
        // a coordinate with tiny gradients moves at the same ~lr pace
        // as a large one (that's the point of Adam)
        let mut opt = FedAdam::new(0.1, 0.9, 0.99, 1e-12);
        let mut global = vec![0f32; 2];
        for _ in 0..10 {
            global = opt.apply(&global, &[1e-4, 10.0]).unwrap();
        }
        let ratio = global[1] / global[0];
        assert!(
            (0.5..2.0).contains(&ratio),
            "per-coordinate normalization failed: {global:?}"
        );
    }
}
