//! Pluggable round strategies: aggregation policies and server-side
//! optimizers (paper §4.4; FedOpt, Reddi et al.; robust aggregation,
//! Yin et al.).
//!
//! The orchestrator's round loop is generic over two seams:
//!
//! * [`AggStrategy`] — *how client updates combine into one round
//!   update* `Δ_agg`. Configured per experiment
//!   ([`crate::config::Aggregation`]) or injected directly via
//!   [`crate::orchestrator::OrchestratorBuilder::strategy`]; the
//!   name-keyed [`registry`] maps config/CLI strings to instances.
//! * [`ServerOpt`] — *how `Δ_agg` moves the global model*:
//!   `M_{r+1} = opt(M_r, Δ_agg)`. Optimizer state (momentum, second
//!   moments) lives on the orchestrator and carries across rounds.
//!
//! # Streaming vs. buffered contract
//!
//! A strategy declares its collection mode via
//! [`AggStrategy::needs_buffering`]:
//!
//! * **Streaming** (default): each arriving update contributes only a
//!   scalar raw weight ([`AggStrategy::weight`]); the
//!   [`RoundAggregator`] folds `raw_c·Δ_c` into one O(P) f64
//!   accumulator and frees the decoded delta immediately
//!   (fold-then-normalize — see [`super::aggregate`] for the
//!   invariant and its cost model). Collection memory is O(P)
//!   regardless of how many clients report. On the ingest hot path the
//!   update is never decoded densely at all:
//!   [`AggStrategy::fold_view`] folds it straight from its
//!   [`crate::compress::DecodedView`] — O(nnz) per update for the
//!   sparse-aware built-ins, with a densifying (pooled-scratch)
//!   default so custom strategies keep working unchanged.
//! * **Buffered** (`needs_buffering() == true`): the round keeps every
//!   decoded delta alive (O(k·P)) and [`AggStrategy::buffered_delta`]
//!   sees them together at finalize. This is the escape hatch for
//!   order statistics — [`TrimmedMean`], [`CoordinateMedian`] — which
//!   cannot be expressed as a weighted sum. Views are densified into
//!   pooled scratch buffers, recycled when the round finalizes.
//!
//! # Determinism invariant
//!
//! For a fixed arrival order, both modes are bit-deterministic across
//! thread counts: the streaming fold partitions *elements* (never one
//! element's additions), and buffered strategies sort each
//! coordinate's values with a total order (`f64::total_cmp`). The
//! batch [`super::aggregate::aggregate`] wrapper replays the same code
//! paths in slice order, so batch/streaming bit-equivalence is pinned
//! by construction (and by test).

pub mod registry;
mod robust;
mod server_opt;

pub use robust::{CoordinateMedian, TrimmedMean};
pub use server_opt::{FedAdam, FedAvgM, ServerOpt, SgdServer};

use super::aggregate::{
    AggDelta, AggInput, AggOutcome, ShardedAggregator, SharedInput, StreamingAggregator, ViewInput,
};
use crate::config::WeightScheme;
use crate::util::parallel::ShardPool;
use crate::util::scratch::ScratchPool;
use anyhow::{bail, Result};
use std::sync::Arc;

/// A per-round aggregation policy. Implementations must be cheap to
/// share (`Send + Sync`); all per-round state lives in the
/// [`RoundAggregator`], so one instance serves every round.
pub trait AggStrategy: Send + Sync {
    /// Registry name (matches [`crate::config::Aggregation::name`]).
    fn name(&self) -> &'static str;

    /// Proximal coefficient shipped to clients each round (FedProx);
    /// 0 for strategies without a proximal term.
    fn mu(&self) -> f32 {
        0.0
    }

    /// `false` (default): stream via [`AggStrategy::weight`].
    /// `true`: buffer the round's deltas for
    /// [`AggStrategy::buffered_delta`] (order statistics).
    fn needs_buffering(&self) -> bool {
        false
    }

    /// Raw (unnormalized) weight of one arriving update on the
    /// streaming path. Must be finite and non-negative; the engine
    /// normalizes by the sum over arrived updates. Unused when
    /// `needs_buffering()`.
    fn weight(&self, input: &AggInput) -> f64;

    /// Raw weight from the update's scalar stats alone, when the
    /// strategy can compute it without inspecting delta values.
    /// `Some` opts the strategy into the sharded ingest backend (the
    /// weight must be known before the payload is enqueued to shard
    /// workers); the `None` default keeps delta-inspecting custom
    /// strategies on the serial reference path.
    ///
    /// Contract: whether this returns `Some` must not depend on the
    /// argument *values* (the engine probes once at round start), and a
    /// returned weight must equal what [`AggStrategy::weight`] /
    /// [`AggStrategy::fold_view`] would fold with for the same stats —
    /// otherwise sharded and serial rounds diverge.
    fn scalar_weight(&self, _n_samples: u64, _train_loss: f32, _update_var: f32) -> Option<f64> {
        None
    }

    /// Buffered-mode aggregation over the full round (only called when
    /// `needs_buffering()`): produce the round's aggregated update
    /// Δ_agg from all k buffered inputs.
    fn buffered_delta(&self, _n_params: usize, _inputs: &[AggInput]) -> Result<AggDelta> {
        bail!(
            "strategy '{}' is streaming-only (buffered_delta not implemented)",
            self.name()
        )
    }

    /// Streaming-mode ingest of one update as a zero-materialization
    /// [`ViewInput`] — the hot path the orchestrator drives. `scale`
    /// multiplies the strategy's raw weight; the engine passes `1.0`
    /// for synchronous rounds and a staleness discount
    /// ([`crate::config::StalenessFn::discount`]) in buffered-async
    /// mode, so strategies stay oblivious to round semantics.
    ///
    /// The default implementation densifies the view into a pooled
    /// scratch buffer and replays the legacy [`AggStrategy::weight`] +
    /// dense-fold path, so existing custom strategies (including any
    /// whose `weight` inspects the delta values) keep working
    /// unchanged, just with the per-update allocation pooled away.
    /// Sparse-aware strategies — every built-in streaming strategy —
    /// override this to fold the view directly: O(nnz) per update and
    /// no dense vector at any point. Overrides must produce results
    /// bit-identical to the default (fold the same `scale·w·Δ`); the
    /// engine's bookkeeping is shared either way.
    fn fold_view(
        &self,
        core: &mut StreamingAggregator,
        input: &ViewInput<'_>,
        pool: &ScratchPool,
        scale: f64,
    ) -> Result<()> {
        let mut delta = pool.take(input.view.dense_len());
        input.view.write_dense(&mut delta);
        let dense = AggInput {
            client: input.client,
            delta,
            n_samples: input.n_samples,
            train_loss: input.train_loss,
            update_var: input.update_var,
        };
        let w = scale * self.weight(&dense);
        let res = core.fold(&dense, w);
        pool.put(dense.delta);
        res
    }
}

/// Raw weight from the update's scalar stats alone — the shared
/// implementation behind every built-in streaming strategy's `weight`
/// and its sparse-aware `fold_view` override (one formula, two entry
/// points, so the two paths cannot drift apart).
fn stat_weight(
    scheme: Option<WeightScheme>,
    n_samples: u64,
    train_loss: f32,
    update_var: f32,
) -> f64 {
    let n = n_samples.max(1) as f64;
    match scheme {
        None | Some(WeightScheme::DataSize) => n,
        Some(WeightScheme::InverseLoss) => n / (1.0 + train_loss.max(0.0) as f64),
        Some(WeightScheme::InverseVariance) => n / (1.0 + update_var.max(0.0) as f64),
    }
}

/// FedAvg: `w_c ∝ n_c` (McMahan et al.).
#[derive(Debug, Clone, Copy, Default)]
pub struct FedAvg;

impl AggStrategy for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn weight(&self, input: &AggInput) -> f64 {
        stat_weight(None, input.n_samples, input.train_loss, input.update_var)
    }

    fn scalar_weight(&self, n_samples: u64, train_loss: f32, update_var: f32) -> Option<f64> {
        Some(stat_weight(None, n_samples, train_loss, update_var))
    }

    fn fold_view(
        &self,
        core: &mut StreamingAggregator,
        input: &ViewInput<'_>,
        _pool: &ScratchPool,
        scale: f64,
    ) -> Result<()> {
        let w = stat_weight(None, input.n_samples, input.train_loss, input.update_var);
        core.fold_view(input, scale * w)
    }
}

/// FedProx (Li et al.): server side identical to FedAvg; the proximal
/// term μ lives in the client objective and is shipped each round via
/// [`AggStrategy::mu`].
#[derive(Debug, Clone, Copy)]
pub struct FedProx {
    pub mu: f32,
}

impl AggStrategy for FedProx {
    fn name(&self) -> &'static str {
        "fedprox"
    }

    fn mu(&self) -> f32 {
        self.mu
    }

    fn weight(&self, input: &AggInput) -> f64 {
        stat_weight(None, input.n_samples, input.train_loss, input.update_var)
    }

    fn scalar_weight(&self, n_samples: u64, train_loss: f32, update_var: f32) -> Option<f64> {
        Some(stat_weight(None, n_samples, train_loss, update_var))
    }

    fn fold_view(
        &self,
        core: &mut StreamingAggregator,
        input: &ViewInput<'_>,
        _pool: &ScratchPool,
        scale: f64,
    ) -> Result<()> {
        let w = stat_weight(None, input.n_samples, input.train_loss, input.update_var);
        core.fold_view(input, scale * w)
    }
}

/// Dynamic weighting (paper §4.4): `w_c ∝ n_c`, `n_c / (1 + loss_c)`
/// or `n_c / (1 + Var(Δ_c))` depending on the scheme.
#[derive(Debug, Clone, Copy)]
pub struct WeightedAgg {
    pub scheme: WeightScheme,
}

impl AggStrategy for WeightedAgg {
    fn name(&self) -> &'static str {
        "weighted"
    }

    fn weight(&self, input: &AggInput) -> f64 {
        stat_weight(
            Some(self.scheme),
            input.n_samples,
            input.train_loss,
            input.update_var,
        )
    }

    fn scalar_weight(&self, n_samples: u64, train_loss: f32, update_var: f32) -> Option<f64> {
        Some(stat_weight(
            Some(self.scheme),
            n_samples,
            train_loss,
            update_var,
        ))
    }

    fn fold_view(
        &self,
        core: &mut StreamingAggregator,
        input: &ViewInput<'_>,
        _pool: &ScratchPool,
        scale: f64,
    ) -> Result<()> {
        let w = stat_weight(
            Some(self.scheme),
            input.n_samples,
            input.train_loss,
            input.update_var,
        );
        core.fold_view(input, scale * w)
    }
}

/// Per-round aggregation engine: drives one round's collection under a
/// strategy, in whichever mode the strategy declares.
///
/// Streaming strategies fold straight into a [`StreamingAggregator`]
/// (O(P) collection state); buffered strategies accumulate inputs
/// (O(k·P)) and defer to [`AggStrategy::buffered_delta`]. Either way
/// [`RoundAggregator::finalize`] hands Δ_agg to a [`ServerOpt`] for
/// the model step.
///
/// Dense scratch buffers (buffered mode, densifying `fold_view`
/// defaults) come from a [`ScratchPool`]; pass the orchestrator's
/// long-lived pool via [`RoundAggregator::with_pool`] to recycle them
/// across updates *and* rounds.
pub struct RoundAggregator {
    strategy: Arc<dyn AggStrategy>,
    pool: Arc<ScratchPool>,
    mode: Mode,
}

enum Mode {
    Streaming(StreamingAggregator),
    /// Accumulator sharded across a persistent worker pool — selected
    /// by [`RoundAggregator::with_ingest`] when the strategy can weigh
    /// updates from scalar stats alone ([`AggStrategy::scalar_weight`]).
    Sharded(ShardedAggregator),
    Buffered {
        n_params: usize,
        inputs: Vec<AggInput>,
    },
}

impl RoundAggregator {
    /// Begin a round for a model of `n_params` entries, with a private
    /// scratch pool (recycles within the round only).
    pub fn new(strategy: Arc<dyn AggStrategy>, n_params: usize) -> Self {
        Self::with_pool(strategy, n_params, Arc::new(ScratchPool::new()))
    }

    /// Begin a round backed by a shared, long-lived scratch pool (the
    /// orchestrator passes its own, so buffers survive across rounds).
    pub fn with_pool(
        strategy: Arc<dyn AggStrategy>,
        n_params: usize,
        pool: Arc<ScratchPool>,
    ) -> Self {
        Self::with_ingest(strategy, n_params, pool, None)
    }

    /// Begin a round with an optional persistent ingest pool. Sharded
    /// mode engages only when a pool is supplied *and* the strategy
    /// supports it (streaming, scalar-stat weights); everything else
    /// falls back to the serial reference path, so passing a pool is
    /// always safe.
    pub fn with_ingest(
        strategy: Arc<dyn AggStrategy>,
        n_params: usize,
        pool: Arc<ScratchPool>,
        ingest: Option<Arc<ShardPool>>,
    ) -> Self {
        // probe with arbitrary stats: Some-ness must not depend on the
        // values (documented scalar_weight contract)
        let sharded_ok =
            !strategy.needs_buffering() && strategy.scalar_weight(1, 0.0, 0.0).is_some();
        let mode = match ingest {
            Some(shards) if sharded_ok => Mode::Sharded(ShardedAggregator::new(n_params, shards)),
            _ if strategy.needs_buffering() => Mode::Buffered {
                n_params,
                inputs: Vec::new(),
            },
            _ => Mode::Streaming(StreamingAggregator::new(n_params)),
        };
        RoundAggregator {
            strategy,
            pool,
            mode,
        }
    }

    /// The strategy this round is running.
    pub fn strategy(&self) -> &dyn AggStrategy {
        self.strategy.as_ref()
    }

    /// Whether this round folds through the sharded ingest backend
    /// (callers pick the [`RoundAggregator::fold_shared`] entry point).
    pub fn ingest_sharded(&self) -> bool {
        matches!(self.mode, Mode::Sharded(_))
    }

    /// The shard pool backing a sharded round (telemetry sampling).
    pub fn ingest_pool(&self) -> Option<&Arc<ShardPool>> {
        match &self.mode {
            Mode::Sharded(core) => Some(core.pool()),
            _ => None,
        }
    }

    /// Updates accepted so far.
    pub fn n_updates(&self) -> usize {
        match &self.mode {
            Mode::Streaming(core) => core.n_updates(),
            Mode::Sharded(core) => core.n_updates(),
            Mode::Buffered { inputs, .. } => inputs.len(),
        }
    }

    /// Fold one arriving update. The streaming path only reads the
    /// input (the caller frees its decoded delta on return — O(P)
    /// collection state); the buffered path clones and retains it
    /// until finalize (O(k·P), inherent to order statistics).
    pub fn fold(&mut self, input: &AggInput) -> Result<()> {
        self.fold_scaled(input, 1.0)
    }

    /// [`RoundAggregator::fold`] with a weight multiplier — the dense
    /// entry point of the buffered-async engine, where `scale` is the
    /// update's staleness discount. Order-statistic (buffered)
    /// strategies have no per-update weight to discount, so a non-unit
    /// scale is an error there (the async engine refuses them up front
    /// — see [`crate::config::validate`]).
    pub fn fold_scaled(&mut self, input: &AggInput, scale: f64) -> Result<()> {
        match &mut self.mode {
            Mode::Streaming(core) => {
                let w = scale * self.strategy.weight(input);
                core.fold(input, w)
            }
            Mode::Sharded(_) => bail!(
                "strategy '{}': sharded round accepts only fold_shared (owned payloads)",
                self.strategy.name()
            ),
            Mode::Buffered { n_params, inputs } => {
                if scale != 1.0 {
                    bail!(
                        "strategy '{}' cannot apply a staleness discount (buffered mode)",
                        self.strategy.name()
                    );
                }
                if input.delta.len() != *n_params {
                    bail!(
                        "aggregate: client {} delta length {} != {}",
                        input.client,
                        input.delta.len(),
                        *n_params
                    );
                }
                inputs.push(input.clone());
                Ok(())
            }
        }
    }

    /// Fold one arriving update from its decode view — the
    /// zero-materialization ingest entry point the orchestrator's
    /// collect phase drives. Streaming strategies dispatch through
    /// [`AggStrategy::fold_view`] (sparse-aware built-ins never touch a
    /// dense vector); buffered strategies densify into a pooled scratch
    /// buffer they retain until finalize (inherent to order
    /// statistics), recycled at finalize.
    pub fn fold_view(&mut self, input: &ViewInput<'_>) -> Result<()> {
        self.fold_view_scaled(input, 1.0)
    }

    /// [`RoundAggregator::fold_view`] with a weight multiplier — the
    /// fused-ingest entry point of the buffered-async engine (`scale` =
    /// the update's staleness discount, `1.0` for sync rounds).
    /// Buffered strategies reject non-unit scales, as in
    /// [`RoundAggregator::fold_scaled`].
    pub fn fold_view_scaled(&mut self, input: &ViewInput<'_>, scale: f64) -> Result<()> {
        let RoundAggregator {
            strategy,
            pool,
            mode,
        } = self;
        match mode {
            Mode::Streaming(core) => strategy.fold_view(core, input, pool, scale),
            Mode::Sharded(_) => bail!(
                "strategy '{}': sharded round accepts only fold_shared (owned payloads)",
                strategy.name()
            ),
            Mode::Buffered { n_params, inputs } => {
                if scale != 1.0 {
                    bail!(
                        "strategy '{}' cannot apply a staleness discount (buffered mode)",
                        strategy.name()
                    );
                }
                if input.view.dense_len() != *n_params {
                    bail!(
                        "aggregate: client {} delta length {} != {}",
                        input.client,
                        input.view.dense_len(),
                        *n_params
                    );
                }
                let mut delta = pool.take(*n_params);
                input.view.write_dense(&mut delta);
                inputs.push(AggInput {
                    client: input.client,
                    delta,
                    n_samples: input.n_samples,
                    train_loss: input.train_loss,
                    update_var: input.update_var,
                });
                Ok(())
            }
        }
    }

    /// Fold one arriving update as an owned, shard-shareable payload —
    /// the sharded-ingest entry point. Only valid on rounds where
    /// [`RoundAggregator::ingest_sharded`] is true.
    pub fn fold_shared(&mut self, input: &SharedInput) -> Result<()> {
        self.fold_shared_scaled(input, 1.0)
    }

    /// [`RoundAggregator::fold_shared`] with a weight multiplier
    /// (`scale` = the update's staleness discount in buffered-async
    /// mode, `1.0` for sync rounds).
    pub fn fold_shared_scaled(&mut self, input: &SharedInput, scale: f64) -> Result<()> {
        let RoundAggregator { strategy, mode, .. } = self;
        match mode {
            Mode::Sharded(core) => {
                let Some(w) =
                    strategy.scalar_weight(input.n_samples, input.train_loss, input.update_var)
                else {
                    bail!(
                        "strategy '{}' cannot weigh updates from scalar stats (sharded ingest)",
                        strategy.name()
                    );
                };
                core.fold_shared(input, scale * w)
            }
            _ => bail!(
                "strategy '{}': fold_shared requires a sharded round (use fold_view)",
                strategy.name()
            ),
        }
    }

    /// Finalize the round: normalize (or run the order statistic),
    /// then apply the server optimizer `M_{r+1} = opt(M_r, Δ_agg)`.
    pub fn finalize(self, global: &[f32], opt: &mut dyn ServerOpt) -> Result<AggOutcome> {
        let agg = match self.mode {
            Mode::Streaming(core) => core.finalize()?,
            Mode::Sharded(core) => core.finalize()?,
            Mode::Buffered { n_params, inputs } => {
                if inputs.is_empty() {
                    bail!("aggregate: no updates to aggregate");
                }
                let agg = self.strategy.buffered_delta(n_params, &inputs)?;
                // hand the round's dense buffers back for the next round
                for input in inputs {
                    self.pool.put(input.delta);
                }
                agg
            }
        };
        let new_params = opt.apply(global, &agg.delta)?;
        Ok(AggOutcome {
            new_params,
            weights: agg.weights,
            mean_train_loss: agg.mean_train_loss,
        })
    }

    /// Finalize the round *without* a server optimizer step, returning
    /// the normalized aggregate Δ_agg together with the summed raw
    /// weight `Σ raw_c` — the mid-tier exit used by a site aggregator,
    /// which reports `(Δ_site, W_site)` upstream instead of stepping a
    /// model. Carrying W_site makes fold-then-normalize associative
    /// across the tree: the root folds `W_site · Δ_site`, which equals
    /// `Σ_c raw_c·Δ_c` over the site's members exactly.
    ///
    /// Buffered (order-statistic) strategies have no summed weight that
    /// composes this way, so they are rejected here — `validate`
    /// refuses them up front when the hierarchy is enabled.
    pub fn finalize_delta(self) -> Result<(AggDelta, f64)> {
        match self.mode {
            Mode::Streaming(core) => {
                let total = core.total_weight();
                Ok((core.finalize()?, total))
            }
            Mode::Sharded(core) => {
                let total = core.total_weight();
                Ok((core.finalize()?, total))
            }
            Mode::Buffered { .. } => bail!(
                "strategy '{}' buffers whole rounds and cannot report a \
                 pre-folded delta upstream",
                self.strategy.name()
            ),
        }
    }
}

/// Uniform per-client report weights for order-statistic strategies
/// (weights don't drive the math there, but logs and tests still see a
/// normalized distribution).
pub(crate) fn uniform_weights(inputs: &[AggInput]) -> Vec<(crate::cluster::NodeId, f64)> {
    let w = 1.0 / inputs.len() as f64;
    inputs.iter().map(|i| (i.client, w)).collect()
}

/// Sample-weighted mean train loss — identical to the streaming
/// engine's bookkeeping.
pub(crate) fn weighted_mean_loss(inputs: &[AggInput]) -> f64 {
    let mut n_total = 0.0f64;
    let mut loss_weighted = 0.0f64;
    for i in inputs {
        let n = i.n_samples.max(1) as f64;
        n_total += n;
        loss_weighted += i.train_loss as f64 * n;
    }
    loss_weighted / n_total
}

#[cfg(test)]
mod tests {
    use super::*;

    fn input(client: u32, delta: Vec<f32>, n: u64) -> AggInput {
        AggInput {
            client,
            delta,
            n_samples: n,
            train_loss: 1.0,
            update_var: 0.0,
        }
    }

    #[test]
    fn streaming_strategies_report_streaming_mode() {
        for s in [
            &FedAvg as &dyn AggStrategy,
            &FedProx { mu: 0.1 },
            &WeightedAgg {
                scheme: WeightScheme::InverseLoss,
            },
        ] {
            assert!(!s.needs_buffering(), "{} should stream", s.name());
        }
        assert!(TrimmedMean { trim_frac: 0.1 }.needs_buffering());
        assert!(CoordinateMedian.needs_buffering());
    }

    #[test]
    fn streaming_only_strategy_rejects_buffered_call() {
        assert!(FedAvg.buffered_delta(2, &[]).is_err());
    }

    #[test]
    fn mu_flows_from_strategy() {
        assert_eq!(FedProx { mu: 0.25 }.mu(), 0.25);
        assert_eq!(FedAvg.mu(), 0.0);
        assert_eq!(TrimmedMean { trim_frac: 0.1 }.mu(), 0.0);
    }

    #[test]
    fn buffered_mode_checks_lengths_and_counts() {
        let mut agg = RoundAggregator::new(Arc::new(CoordinateMedian), 2);
        assert_eq!(agg.n_updates(), 0);
        assert!(agg.fold(&input(0, vec![1.0], 10)).is_err());
        agg.fold(&input(0, vec![1.0, 2.0], 10)).unwrap();
        agg.fold(&input(1, vec![3.0, 4.0], 10)).unwrap();
        assert_eq!(agg.n_updates(), 2);
        let out = agg.finalize(&[0.0, 0.0], &mut SgdServer).unwrap();
        // even k: median is the mean of the two middle values
        assert_eq!(out.new_params, vec![2.0, 3.0]);
    }

    #[test]
    fn empty_buffered_round_errors() {
        let agg = RoundAggregator::new(Arc::new(CoordinateMedian), 2);
        assert!(agg.finalize(&[0.0, 0.0], &mut SgdServer).is_err());
    }

    fn view_input<'a>(
        client: u32,
        view: &'a crate::compress::DecodedView<'a>,
    ) -> ViewInput<'a> {
        ViewInput {
            client,
            view,
            n_samples: 10,
            train_loss: 1.0,
            update_var: 0.0,
        }
    }

    #[test]
    fn buffered_fold_view_densifies_and_recycles_via_pool() {
        use crate::compress::{DecodedView, Encoded};
        let pool = Arc::new(ScratchPool::new());
        let mut agg = RoundAggregator::with_pool(Arc::new(CoordinateMedian), 2, pool.clone());
        for (c, enc) in [
            Encoded::Dense(vec![1.0, 2.0]),
            Encoded::Dense(vec![3.0, 4.0]),
        ]
        .iter()
        .enumerate()
        {
            let view = DecodedView::of(enc, 2).unwrap();
            agg.fold_view(&view_input(c as u32, &view)).unwrap();
        }
        assert_eq!(agg.n_updates(), 2);
        let out = agg.finalize(&[0.0, 0.0], &mut SgdServer).unwrap();
        assert_eq!(out.new_params, vec![2.0, 3.0]);
        // the round's dense buffers were handed back at finalize
        assert_eq!(pool.idle(), 2);
    }

    #[test]
    fn buffered_fold_view_checks_lengths() {
        use crate::compress::{DecodedView, Encoded};
        let mut agg = RoundAggregator::new(Arc::new(CoordinateMedian), 2);
        let enc = Encoded::Dense(vec![1.0; 3]);
        let view = DecodedView::of(&enc, 3).unwrap();
        assert!(agg.fold_view(&view_input(0, &view)).is_err());
        assert_eq!(agg.n_updates(), 0);
    }

    /// The staleness hook: a scaled fold must weigh exactly like a
    /// fold whose raw weight was pre-multiplied — for both the dense
    /// and the view entry points, across every streaming built-in.
    #[test]
    fn scaled_folds_match_premultiplied_weights() {
        use crate::compress::{DecodedView, Encoded};
        for strategy in [
            Arc::new(FedAvg) as Arc<dyn AggStrategy>,
            Arc::new(FedProx { mu: 0.1 }),
            Arc::new(WeightedAgg {
                scheme: WeightScheme::InverseLoss,
            }),
        ] {
            // reference: raw weights 100 and 0.25·100 folded by hand
            let w0 = strategy.weight(&input(0, vec![2.0, 0.0], 10));
            let w1 = strategy.weight(&input(1, vec![0.0, 8.0], 10));
            let mut reference = StreamingAggregator::new(2);
            reference
                .fold(&input(0, vec![2.0, 0.0], 10), w0)
                .unwrap();
            reference
                .fold(&input(1, vec![0.0, 8.0], 10), 0.25 * w1)
                .unwrap();
            let want = reference.finalize().unwrap();

            // dense scaled path
            let mut agg = RoundAggregator::new(strategy.clone(), 2);
            agg.fold_scaled(&input(0, vec![2.0, 0.0], 10), 1.0).unwrap();
            agg.fold_scaled(&input(1, vec![0.0, 8.0], 10), 0.25).unwrap();
            let dense = agg.finalize(&[0.0, 0.0], &mut SgdServer).unwrap();

            // view scaled path
            let mut agg = RoundAggregator::new(strategy.clone(), 2);
            let e0 = Encoded::Dense(vec![2.0, 0.0]);
            let e1 = Encoded::Dense(vec![0.0, 8.0]);
            let v0 = DecodedView::of(&e0, 2).unwrap();
            let v1 = DecodedView::of(&e1, 2).unwrap();
            agg.fold_view_scaled(&view_input(0, &v0), 1.0).unwrap();
            agg.fold_view_scaled(&view_input(1, &v1), 0.25).unwrap();
            let viewed = agg.finalize(&[0.0, 0.0], &mut SgdServer).unwrap();

            for j in 0..2 {
                let w = (want.delta[j]) as f32;
                assert_eq!(
                    w.to_bits(),
                    dense.new_params[j].to_bits(),
                    "{} dense scaled fold diverged at {j}",
                    strategy.name()
                );
                assert_eq!(
                    dense.new_params[j].to_bits(),
                    viewed.new_params[j].to_bits(),
                    "{} view scaled fold diverged at {j}",
                    strategy.name()
                );
            }
            assert_eq!(dense.weights, want.weights);
        }
    }

    #[test]
    fn buffered_strategies_reject_staleness_scales() {
        let mut agg = RoundAggregator::new(Arc::new(CoordinateMedian), 2);
        assert!(agg.fold_scaled(&input(0, vec![1.0, 2.0], 10), 0.5).is_err());
        let enc = crate::compress::Encoded::Dense(vec![1.0, 2.0]);
        let view = crate::compress::DecodedView::of(&enc, 2).unwrap();
        assert!(agg.fold_view_scaled(&view_input(0, &view), 0.5).is_err());
        assert_eq!(agg.n_updates(), 0);
        // unit scale still works
        agg.fold_scaled(&input(0, vec![1.0, 2.0], 10), 1.0).unwrap();
        assert_eq!(agg.n_updates(), 1);
    }

    #[test]
    fn with_ingest_selects_sharded_only_for_scalar_weight_streamers() {
        let shards = Arc::new(ShardPool::new(2, 4));
        let scratch = Arc::new(ScratchPool::new());
        for strategy in [
            Arc::new(FedAvg) as Arc<dyn AggStrategy>,
            Arc::new(FedProx { mu: 0.1 }),
            Arc::new(WeightedAgg {
                scheme: WeightScheme::InverseVariance,
            }),
        ] {
            let agg = RoundAggregator::with_ingest(
                strategy.clone(),
                8,
                scratch.clone(),
                Some(shards.clone()),
            );
            assert!(agg.ingest_sharded(), "{} should shard", strategy.name());
            assert!(agg.ingest_pool().is_some());
        }
        // buffered strategies and no-pool rounds stay on the reference path
        let agg = RoundAggregator::with_ingest(
            Arc::new(CoordinateMedian),
            8,
            scratch.clone(),
            Some(shards.clone()),
        );
        assert!(!agg.ingest_sharded());
        let agg = RoundAggregator::with_ingest(Arc::new(FedAvg), 8, scratch.clone(), None);
        assert!(!agg.ingest_sharded());
        assert!(agg.ingest_pool().is_none());
        // a delta-inspecting custom strategy (scalar_weight = None) too
        struct DeltaPeek;
        impl AggStrategy for DeltaPeek {
            fn name(&self) -> &'static str {
                "peek"
            }
            fn weight(&self, input: &AggInput) -> f64 {
                input.delta.iter().map(|x| x.abs() as f64).sum()
            }
        }
        let agg = RoundAggregator::with_ingest(Arc::new(DeltaPeek), 8, scratch, Some(shards));
        assert!(!agg.ingest_sharded());
    }

    #[test]
    fn sharded_round_matches_view_round_bitwise_and_scales() {
        use crate::compress::{DecodedView, Encoded, SharedDecoded};
        let shards = Arc::new(ShardPool::new(3, 5));
        let scratch = Arc::new(ScratchPool::new());
        for strategy in [
            Arc::new(FedAvg) as Arc<dyn AggStrategy>,
            Arc::new(WeightedAgg {
                scheme: WeightScheme::InverseLoss,
            }),
        ] {
            let deltas = [vec![2.0f32, 0.0, -1.5, 4.0], vec![0.0, 8.0, 0.25, -0.5]];
            let mut serial = RoundAggregator::with_pool(strategy.clone(), 4, scratch.clone());
            let mut sharded = RoundAggregator::with_ingest(
                strategy.clone(),
                4,
                scratch.clone(),
                Some(shards.clone()),
            );
            for (c, d) in deltas.iter().enumerate() {
                let scale = if c == 0 { 1.0 } else { 0.25 };
                let enc = Encoded::Dense(d.clone());
                let view = DecodedView::of(&enc, 4).unwrap();
                serial
                    .fold_view_scaled(&view_input(c as u32, &view), scale)
                    .unwrap();
                let payload =
                    Arc::new(SharedDecoded::new(Arc::new(Encoded::Dense(d.clone())), 4).unwrap());
                sharded
                    .fold_shared_scaled(
                        &SharedInput {
                            client: c as u32,
                            payload,
                            n_samples: 10,
                            train_loss: 1.0,
                            update_var: 0.0,
                        },
                        scale,
                    )
                    .unwrap();
            }
            let a = serial.finalize(&[0.0; 4], &mut SgdServer).unwrap();
            let b = sharded.finalize(&[0.0; 4], &mut SgdServer).unwrap();
            for (x, y) in a.new_params.iter().zip(&b.new_params) {
                assert_eq!(x.to_bits(), y.to_bits(), "{} diverged", strategy.name());
            }
            assert_eq!(a.weights, b.weights);
        }
    }

    #[test]
    fn sharded_round_rejects_mismatched_entry_points() {
        use crate::compress::{DecodedView, Encoded, SharedDecoded};
        let shards = Arc::new(ShardPool::new(2, 2));
        let mut sharded = RoundAggregator::with_ingest(
            Arc::new(FedAvg),
            2,
            Arc::new(ScratchPool::new()),
            Some(shards),
        );
        assert!(sharded.fold(&input(0, vec![1.0, 2.0], 10)).is_err());
        let enc = Encoded::Dense(vec![1.0, 2.0]);
        let view = DecodedView::of(&enc, 2).unwrap();
        assert!(sharded.fold_view(&view_input(0, &view)).is_err());
        assert_eq!(sharded.n_updates(), 0);
        // and a serial round rejects fold_shared
        let payload = Arc::new(SharedDecoded::new(Arc::new(enc.clone()), 2).unwrap());
        let mut serial = RoundAggregator::new(Arc::new(FedAvg), 2);
        assert!(serial
            .fold_shared(&SharedInput {
                client: 0,
                payload,
                n_samples: 10,
                train_loss: 1.0,
                update_var: 0.0,
            })
            .is_err());
    }

    /// A custom strategy that only implements `weight` — including one
    /// that inspects the delta *values* — must keep working through
    /// the densifying `fold_view` default.
    #[test]
    fn default_fold_view_densifies_for_custom_strategies() {
        use crate::compress::{DecodedView, Encoded, Sparse};
        struct L1Weight;
        impl AggStrategy for L1Weight {
            fn name(&self) -> &'static str {
                "l1"
            }
            fn weight(&self, input: &AggInput) -> f64 {
                input.delta.iter().map(|x| x.abs() as f64).sum()
            }
        }
        let mut agg = RoundAggregator::new(Arc::new(L1Weight), 2);
        let enc = Encoded::Sparse(Sparse {
            idx: vec![1],
            val: vec![2.0],
            dense_len: 2,
        });
        let view = DecodedView::of(&enc, 2).unwrap();
        agg.fold_view(&view_input(0, &view)).unwrap();
        let out = agg.finalize(&[0.0, 0.0], &mut SgdServer).unwrap();
        // weight normalizes away for a single client; the default path
        // saw the densified [0.0, 2.0]
        assert_eq!(out.new_params, vec![0.0, 2.0]);
    }
}
