//! Name-keyed strategy registry: one place that maps configuration
//! values — and the `"name[:param]"` strings the CLI, config files,
//! examples and benches share — to strategy / server-optimizer
//! instances. [`crate::config::Aggregation::parse`] and
//! [`crate::config::ServerOptKind::parse`] own the string grammar;
//! this module owns the instantiation, so adding a strategy means one
//! config variant + one arm here, and every selection surface (JSON
//! loader, `--aggregation` flag, builder) picks it up.

use super::{
    AggStrategy, CoordinateMedian, FedAdam, FedAvg, FedAvgM, FedProx, ServerOpt, SgdServer,
    TrimmedMean, WeightedAgg,
};
use crate::config::{Aggregation, ServerOptKind};
use anyhow::Result;
use std::sync::Arc;

/// All registered aggregation strategy names.
pub fn strategy_names() -> &'static [&'static str] {
    Aggregation::KINDS
}

/// All registered server-optimizer names.
pub fn server_opt_names() -> &'static [&'static str] {
    ServerOptKind::KINDS
}

/// Instantiate the strategy a config value describes.
pub fn strategy_from_config(agg: &Aggregation) -> Arc<dyn AggStrategy> {
    match *agg {
        Aggregation::FedAvg => Arc::new(FedAvg),
        Aggregation::FedProx { mu } => Arc::new(FedProx { mu }),
        Aggregation::Weighted(scheme) => Arc::new(WeightedAgg { scheme }),
        Aggregation::TrimmedMean { trim_frac } => Arc::new(TrimmedMean { trim_frac }),
        Aggregation::CoordinateMedian => Arc::new(CoordinateMedian),
    }
}

/// Instantiate the server optimizer a config value describes. Fresh
/// state every call — optimizer state belongs to one training run.
pub fn server_opt_from_config(kind: &ServerOptKind) -> Box<dyn ServerOpt> {
    match *kind {
        ServerOptKind::Sgd => Box::new(SgdServer),
        ServerOptKind::FedAvgM { beta } => Box::new(FedAvgM::new(beta)),
        ServerOptKind::FedAdam {
            lr,
            beta1,
            beta2,
            eps,
        } => Box::new(FedAdam::new(lr, beta1, beta2, eps)),
    }
}

/// Instantiate a strategy by registry name (`"fedavg"`,
/// `"fedprox:0.1"`, `"trimmed_mean:0.2"`, …). Unknown names error.
pub fn strategy_by_name(spec: &str) -> Result<Arc<dyn AggStrategy>> {
    Ok(strategy_from_config(&Aggregation::parse(spec)?))
}

/// Instantiate a server optimizer by registry name (`"sgd"`,
/// `"fedavgm:0.9"`, `"fedadam:0.05"`, …). Unknown names error.
pub fn server_opt_by_name(spec: &str) -> Result<Box<dyn ServerOpt>> {
    Ok(server_opt_from_config(&ServerOptKind::parse(spec)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_registered_name_instantiates_with_matching_name() {
        for name in strategy_names() {
            let s = strategy_by_name(name).unwrap();
            assert_eq!(&s.name(), name);
        }
        for name in server_opt_names() {
            let o = server_opt_by_name(name).unwrap();
            assert_eq!(&o.name(), name);
        }
    }

    #[test]
    fn params_flow_through_by_name_selection() {
        let s = strategy_by_name("fedprox:0.125").unwrap();
        assert_eq!(s.mu(), 0.125);
        let s = strategy_by_name("trimmed_mean:0.3").unwrap();
        assert!(s.needs_buffering());
    }

    #[test]
    fn unknown_names_error() {
        assert!(strategy_by_name("no_such_strategy").is_err());
        assert!(server_opt_by_name("no_such_opt").is_err());
    }

    #[test]
    fn config_and_instance_names_agree() {
        for agg in [
            Aggregation::FedAvg,
            Aggregation::FedProx { mu: 0.1 },
            Aggregation::TrimmedMean { trim_frac: 0.1 },
            Aggregation::CoordinateMedian,
        ] {
            assert_eq!(strategy_from_config(&agg).name(), agg.name());
        }
        for opt in [
            ServerOptKind::Sgd,
            ServerOptKind::FedAvgM { beta: 0.9 },
        ] {
            assert_eq!(server_opt_from_config(&opt).name(), opt.name());
        }
    }
}
