//! Robust order-statistic aggregation (buffered mode): coordinate-wise
//! trimmed mean and median (Yin et al., "Byzantine-Robust Distributed
//! Learning"). These defend the fault-tolerance claims of the paper's
//! §3.1 against *adversarial* failures — a poisoned or faulty client
//! whose update is arbitrarily large moves a weighted mean arbitrarily
//! far, but cannot move an order statistic past the honest majority.
//!
//! Both strategies are deterministic for a fixed arrival order: each
//! coordinate's k values are sorted with the total order
//! `f64::total_cmp`, and the parallel sweep partitions coordinates
//! (never one coordinate's values), so results are bit-identical
//! across thread counts.
//!
//! Order statistics are the one ingest path that genuinely needs the
//! round's dense deltas: `needs_buffering()` makes the
//! [`super::RoundAggregator`] densify each arriving view into a pooled
//! scratch buffer (recycled at finalize) instead of streaming it —
//! O(k·P) held memory is inherent here, but the per-update allocation
//! is not.

use super::{uniform_weights, weighted_mean_loss, AggDelta, AggInput, AggStrategy};
use crate::util::parallel::par_chunks_mut;
use anyhow::{bail, Result};

fn check_lengths(n_params: usize, inputs: &[AggInput]) -> Result<()> {
    if inputs.is_empty() {
        bail!("aggregate: no updates to aggregate");
    }
    for input in inputs {
        if input.delta.len() != n_params {
            bail!(
                "aggregate: client {} delta length {} != {}",
                input.client,
                input.delta.len(),
                n_params
            );
        }
    }
    Ok(())
}

/// Coordinate-wise trimmed mean: per parameter, sort the k client
/// values, drop `⌊trim_frac·k⌋` from each end and average the rest
/// (clamped so at least one value always survives). Tolerates up to
/// `⌊trim_frac·k⌋` arbitrarily-poisoned clients per round.
#[derive(Debug, Clone, Copy)]
pub struct TrimmedMean {
    /// Fraction trimmed from *each* end, in (0, 0.5).
    pub trim_frac: f32,
}

impl AggStrategy for TrimmedMean {
    fn name(&self) -> &'static str {
        "trimmed_mean"
    }

    fn needs_buffering(&self) -> bool {
        true
    }

    /// Unused: order statistics don't weight updates (documented
    /// contract — only consulted on the streaming path).
    fn weight(&self, _input: &AggInput) -> f64 {
        1.0
    }

    fn buffered_delta(&self, n_params: usize, inputs: &[AggInput]) -> Result<AggDelta> {
        check_lengths(n_params, inputs)?;
        let k = inputs.len();
        let trim = ((self.trim_frac as f64) * k as f64).floor() as usize;
        // keep at least one value even at tiny k
        let trim = trim.min(k.saturating_sub(1) / 2);
        let keep = (k - 2 * trim) as f64;
        let mut delta = vec![0f64; n_params];
        par_chunks_mut(&mut delta, 64 * 1024, |offset, chunk| {
            let mut vals = vec![0f64; k];
            for (i, out) in chunk.iter_mut().enumerate() {
                let j = offset + i;
                for (slot, input) in vals.iter_mut().zip(inputs) {
                    *slot = input.delta[j] as f64;
                }
                vals.sort_unstable_by(f64::total_cmp);
                *out = vals[trim..k - trim].iter().sum::<f64>() / keep;
            }
        });
        Ok(AggDelta {
            delta,
            weights: uniform_weights(inputs),
            mean_train_loss: weighted_mean_loss(inputs),
        })
    }
}

/// Coordinate-wise median: the maximally robust order statistic
/// (breakdown point 1/2). Ignores sample-count weighting entirely.
#[derive(Debug, Clone, Copy, Default)]
pub struct CoordinateMedian;

impl AggStrategy for CoordinateMedian {
    fn name(&self) -> &'static str {
        "coordinate_median"
    }

    fn needs_buffering(&self) -> bool {
        true
    }

    /// Unused: see [`TrimmedMean::weight`].
    fn weight(&self, _input: &AggInput) -> f64 {
        1.0
    }

    fn buffered_delta(&self, n_params: usize, inputs: &[AggInput]) -> Result<AggDelta> {
        check_lengths(n_params, inputs)?;
        let k = inputs.len();
        let mut delta = vec![0f64; n_params];
        par_chunks_mut(&mut delta, 64 * 1024, |offset, chunk| {
            let mut vals = vec![0f64; k];
            for (i, out) in chunk.iter_mut().enumerate() {
                let j = offset + i;
                for (slot, input) in vals.iter_mut().zip(inputs) {
                    *slot = input.delta[j] as f64;
                }
                vals.sort_unstable_by(f64::total_cmp);
                *out = if k % 2 == 1 {
                    vals[k / 2]
                } else {
                    (vals[k / 2 - 1] + vals[k / 2]) / 2.0
                };
            }
        });
        Ok(AggDelta {
            delta,
            weights: uniform_weights(inputs),
            mean_train_loss: weighted_mean_loss(inputs),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::registry::strategy_from_config;
    use super::super::SgdServer;
    use super::*;
    use crate::config::Aggregation;
    use crate::orchestrator::aggregate::aggregate;

    fn input(client: u32, delta: Vec<f32>) -> AggInput {
        AggInput {
            client,
            delta,
            n_samples: 100,
            train_loss: 1.0,
            update_var: 0.0,
        }
    }

    #[test]
    fn trimmed_mean_ignores_outliers() {
        let global = vec![0f32; 2];
        let inputs: Vec<AggInput> = vec![
            input(0, vec![1.0, -1.0]),
            input(1, vec![1.0, -1.0]),
            input(2, vec![1.0, -1.0]),
            input(3, vec![1.0, -1.0]),
            input(4, vec![1000.0, -1000.0]), // poisoned
        ];
        let out = aggregate(
            &global,
            &inputs,
            Aggregation::TrimmedMean { trim_frac: 0.2 },
        )
        .unwrap();
        // trim = 1 from each end: the poisoned value never contributes
        assert_eq!(out.new_params, vec![1.0, -1.0]);
        // FedAvg, by contrast, is dragged far off
        let avg = aggregate(&global, &inputs, Aggregation::FedAvg).unwrap();
        assert!(avg.new_params[0] > 100.0);
    }

    #[test]
    fn trimmed_mean_small_k_degrades_to_mean() {
        // k=1 and k=2: trim clamps to 0, plain unweighted mean
        let global = vec![0f32; 1];
        let out = aggregate(
            &global,
            &[input(0, vec![2.0])],
            Aggregation::TrimmedMean { trim_frac: 0.4 },
        )
        .unwrap();
        assert_eq!(out.new_params, vec![2.0]);
        let out = aggregate(
            &global,
            &[input(0, vec![2.0]), input(1, vec![4.0])],
            Aggregation::TrimmedMean { trim_frac: 0.4 },
        )
        .unwrap();
        assert_eq!(out.new_params, vec![3.0]);
    }

    #[test]
    fn coordinate_median_picks_middle_per_coordinate() {
        let global = vec![10f32; 3];
        let out = aggregate(
            &global,
            &[
                input(0, vec![1.0, 5.0, -3.0]),
                input(1, vec![2.0, 4.0, 900.0]), // one poisoned coordinate
                input(2, vec![3.0, 6.0, -4.0]),
            ],
            Aggregation::CoordinateMedian,
        )
        .unwrap();
        assert_eq!(out.new_params, vec![12.0, 15.0, 7.0]);
    }

    #[test]
    fn median_ignores_sample_count_weighting() {
        let global = vec![0f32; 1];
        let mut heavy = input(0, vec![100.0]);
        heavy.n_samples = 1_000_000; // huge n must not matter
        let out = aggregate(
            &global,
            &[heavy, input(1, vec![1.0]), input(2, vec![2.0])],
            Aggregation::CoordinateMedian,
        )
        .unwrap();
        assert_eq!(out.new_params, vec![2.0]);
    }

    /// The headline robustness scenario (ISSUE satellite): one client
    /// sends a huge poisoned update every round. Under FedAvg the
    /// global model is dragged far from the optimum and stays there;
    /// under TrimmedMean the federation converges to the target as if
    /// the attacker were absent.
    #[test]
    fn trimmed_mean_converges_under_poisoning_where_fedavg_diverges() {
        let target = vec![3.0f32; 8];
        let run = |strategy: Aggregation| -> Vec<f32> {
            let mut global = vec![0f32; 8];
            for _round in 0..40 {
                let mut inputs: Vec<AggInput> = (0..5u32)
                    .map(|c| {
                        // honest clients: step 30% of the way to target
                        let delta: Vec<f32> = global
                            .iter()
                            .zip(&target)
                            .map(|(&g, &t)| 0.3 * (t - g))
                            .collect();
                        input(c, delta)
                    })
                    .collect();
                inputs.push(input(5, vec![100.0; 8])); // poisoned client
                let out = aggregate(&global, &inputs, strategy).unwrap();
                global = out.new_params;
            }
            global
        };
        let robust = run(Aggregation::TrimmedMean { trim_frac: 0.2 });
        let avg = run(Aggregation::FedAvg);
        for (r, &t) in robust.iter().zip(&target) {
            assert!(
                (r - t).abs() < 0.05,
                "trimmed mean should converge to {t}, got {r}"
            );
        }
        assert!(
            (avg[0] - target[0]).abs() > 10.0,
            "fedavg should be dragged off target by the poisoned client, got {}",
            avg[0]
        );
    }

    #[test]
    fn buffered_batch_and_incremental_fold_agree_bitwise() {
        use super::super::RoundAggregator;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        let p = 777;
        let global: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
        let inputs: Vec<AggInput> = (0..9u32)
            .map(|c| {
                input(
                    c,
                    (0..p).map(|_| rng.normal() as f32 * 0.1).collect(),
                )
            })
            .collect();
        for strat in [
            Aggregation::TrimmedMean { trim_frac: 0.25 },
            Aggregation::CoordinateMedian,
        ] {
            let batch = aggregate(&global, &inputs, strat).unwrap();
            let mut agg = RoundAggregator::new(strategy_from_config(&strat), p);
            for i in &inputs {
                agg.fold(i).unwrap();
            }
            let streamed = agg.finalize(&global, &mut SgdServer).unwrap();
            for (a, b) in batch.new_params.iter().zip(&streamed.new_params) {
                assert_eq!(a.to_bits(), b.to_bits(), "{strat:?} diverged");
            }
        }
    }
}
