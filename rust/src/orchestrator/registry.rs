//! Client registry: profiles from registration + running reliability
//! and timing history (paper §4.1 "performance history": successful
//! participation, update quality, completion time).

use crate::cluster::NodeId;
use crate::network::ClientProfile;
use std::collections::BTreeMap;

/// EWMA smoothing for round-time estimates.
const EWMA_ALPHA: f64 = 0.3;

/// Everything the orchestrator knows about one client.
#[derive(Debug, Clone)]
pub struct ClientRecord {
    pub id: NodeId,
    pub profile: ClientProfile,
    /// EWMA of observed round completion time (ms); starts from the
    /// profile's benchmark estimate.
    pub ewma_round_ms: f64,
    pub successes: u64,
    pub failures: u64,
    /// Rounds remaining on the bench after being excluded as a
    /// straggler (0 = eligible).
    pub benched_for: u32,
    /// Round in which this client last participated.
    pub last_selected_round: Option<u32>,
}

impl ClientRecord {
    /// Laplace-smoothed success rate in [0, 1].
    pub fn reliability(&self) -> f64 {
        (self.successes as f64 + 1.0) / ((self.successes + self.failures) as f64 + 2.0)
    }

    /// Selection score (paper §4.1): compute capability × reliability ×
    /// bandwidth, where capability is inverse expected round time.
    pub fn score(&self) -> f64 {
        let speed = 1.0 / self.ewma_round_ms.max(1.0);
        let bw = (self.profile.link_bw / 1e9).clamp(0.05, 10.0);
        speed * self.reliability() * bw.sqrt()
    }
}

/// The registry.
#[derive(Debug, Default)]
pub struct ClientRegistry {
    clients: BTreeMap<NodeId, ClientRecord>,
}

impl ClientRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&mut self, id: NodeId, profile: ClientProfile) {
        let est_round_ms = profile.bench_step_ms.max(0.1) * 10.0; // rough prior
        self.clients
            .entry(id)
            .and_modify(|r| r.profile = profile.clone())
            .or_insert(ClientRecord {
                id,
                profile,
                ewma_round_ms: est_round_ms,
                successes: 0,
                failures: 0,
                benched_for: 0,
                last_selected_round: None,
            });
    }

    pub fn len(&self) -> usize {
        self.clients.len()
    }

    pub fn is_empty(&self) -> bool {
        self.clients.is_empty()
    }

    pub fn get(&self, id: NodeId) -> Option<&ClientRecord> {
        self.clients.get(&id)
    }

    pub fn ids(&self) -> Vec<NodeId> {
        self.clients.keys().copied().collect()
    }

    pub fn records(&self) -> impl Iterator<Item = &ClientRecord> {
        self.clients.values()
    }

    /// Record a successful round: update EWMA time + success count.
    pub fn report_success(&mut self, id: NodeId, round: u32, round_ms: f64) {
        if let Some(r) = self.clients.get_mut(&id) {
            r.successes += 1;
            r.ewma_round_ms = EWMA_ALPHA * round_ms + (1.0 - EWMA_ALPHA) * r.ewma_round_ms;
            r.last_selected_round = Some(round);
        }
    }

    /// Record a failure (dropout, deadline miss, preemption).
    pub fn report_failure(&mut self, id: NodeId, round: u32) {
        if let Some(r) = self.clients.get_mut(&id) {
            r.failures += 1;
            r.last_selected_round = Some(round);
        }
    }

    /// Bench a straggler for `rounds` rounds (paper §4.1 load
    /// balancing: "temporarily excluded").
    pub fn bench(&mut self, id: NodeId, rounds: u32) {
        if let Some(r) = self.clients.get_mut(&id) {
            r.benched_for = r.benched_for.max(rounds);
        }
    }

    /// Start-of-round housekeeping: decrement bench counters.
    pub fn tick_round(&mut self) {
        for r in self.clients.values_mut() {
            r.benched_for = r.benched_for.saturating_sub(1);
        }
    }

    /// Median EWMA round time across clients (exclusion threshold).
    pub fn median_round_ms(&self) -> f64 {
        let mut times: Vec<f64> = self.clients.values().map(|r| r.ewma_round_ms).collect();
        if times.is_empty() {
            return 0.0;
        }
        times.sort_by(f64::total_cmp);
        times[times.len() / 2]
    }
}

#[cfg(test)]
pub(crate) fn test_profile(speed: f64, bw: f64) -> ClientProfile {
    ClientProfile {
        speed_factor: speed,
        mem_gb: 16.0,
        link_bw: bw,
        n_samples: 100,
        bench_step_ms: 10.0 / speed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_rereg() {
        let mut reg = ClientRegistry::new();
        reg.register(1, test_profile(1.0, 1e9));
        reg.register(1, test_profile(0.5, 1e9));
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.get(1).unwrap().profile.speed_factor, 0.5);
    }

    #[test]
    fn reliability_laplace_smoothed() {
        let mut reg = ClientRegistry::new();
        reg.register(1, test_profile(1.0, 1e9));
        assert_eq!(reg.get(1).unwrap().reliability(), 0.5); // no history
        for r in 0..8 {
            reg.report_success(1, r, 100.0);
        }
        assert!(reg.get(1).unwrap().reliability() > 0.8);
        reg.report_failure(1, 9);
        let rel = reg.get(1).unwrap().reliability();
        assert!(rel < 0.9 && rel > 0.5);
    }

    #[test]
    fn ewma_tracks_recent_times() {
        let mut reg = ClientRegistry::new();
        reg.register(1, test_profile(1.0, 1e9));
        let before = reg.get(1).unwrap().ewma_round_ms;
        for r in 0..20 {
            reg.report_success(1, r, 500.0);
        }
        let after = reg.get(1).unwrap().ewma_round_ms;
        assert!((after - 500.0).abs() < 50.0, "ewma {after} from {before}");
    }

    #[test]
    fn score_orders_by_capability() {
        let mut reg = ClientRegistry::new();
        reg.register(1, test_profile(1.0, 1e9)); // fast gpu
        reg.register(2, test_profile(0.02, 1e8)); // slow cpu
        for r in 0..5 {
            reg.report_success(1, r, 100.0);
            reg.report_success(2, r, 5000.0);
        }
        assert!(reg.get(1).unwrap().score() > 10.0 * reg.get(2).unwrap().score());
    }

    #[test]
    fn bench_and_tick() {
        let mut reg = ClientRegistry::new();
        reg.register(1, test_profile(1.0, 1e9));
        reg.bench(1, 2);
        assert_eq!(reg.get(1).unwrap().benched_for, 2);
        reg.tick_round();
        assert_eq!(reg.get(1).unwrap().benched_for, 1);
        reg.tick_round();
        reg.tick_round();
        assert_eq!(reg.get(1).unwrap().benched_for, 0);
    }

    #[test]
    fn median_round_time() {
        let mut reg = ClientRegistry::new();
        for (i, t) in [(1u32, 100.0), (2, 200.0), (3, 10_000.0)] {
            reg.register(i, test_profile(1.0, 1e9));
            for r in 0..10 {
                reg.report_success(i, r, t);
            }
        }
        let m = reg.median_round_ms();
        assert!((150.0..=300.0).contains(&m), "median {m}");
    }
}
