//! Central orchestrator (paper §3.2): the lightweight, stateless-ish
//! coordination unit that selects clients, distributes the global
//! model, collects updates under deadlines, aggregates and tracks
//! convergence (Algorithm 1).
//!
//! * [`registry`] — client profiles + reliability/timing history.
//! * [`selection`] — adaptive client selection (paper §4.1).
//! * [`aggregate`] — FedAvg / FedProx / weighted + partial-k (§4.2, §4.4).
//! * [`convergence`] — Algorithm 1 line 13.
//! * [`server`] — the round loop over a [`ServerTransport`].

mod aggregate;
mod convergence;
mod registry;
mod selection;
mod server;

pub use aggregate::{aggregate, AggInput, AggOutcome, StreamingAggregator};
pub use convergence::ConvergenceTracker;
pub use registry::{ClientRecord, ClientRegistry};
pub use selection::select_clients;
pub use server::{mask_seed, EvalHarness, NoHooks, Orchestrator, OrchestratorHooks, RoundOutcome};
