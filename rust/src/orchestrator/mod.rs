//! Central orchestrator (paper §3.2): the lightweight, stateless-ish
//! coordination unit that selects clients, distributes the global
//! model, collects updates under deadlines, aggregates and tracks
//! convergence (Algorithm 1).
//!
//! * [`registry`] — client profiles + reliability/timing history.
//! * [`planner`] — pluggable cohort planning (paper §4.1): who trains
//!   each round and on what per-client terms (deadline, epoch budget,
//!   uplink compression), selected by registry name like strategies.
//! * [`aggregate`] — the streaming fold-then-normalize core (§4.2, §4.4).
//! * [`strategy`] — pluggable aggregation strategies (FedAvg/FedProx/
//!   weighted/robust), server optimizers (FedAvgM/FedAdam) and the
//!   name-keyed registry that makes them a configuration axis.
//! * [`convergence`] — Algorithm 1 line 13.
//! * [`server`] — the round loop over a [`crate::network::ServerTransport`],
//!   assembled via [`OrchestratorBuilder`].
//! * [`hierarchy`] — the tree-of-aggregators plane: the role-agnostic
//!   [`FoldCore`] both engines fold through, and the mid-tier site
//!   [`Aggregator`] that reports pre-folded deltas upstream.

pub mod aggregate;
mod convergence;
pub mod hierarchy;
pub mod planner;
mod registry;
mod server;
pub mod strategy;

pub use aggregate::{
    aggregate, default_ingest_shards, shard_spans, AggDelta, AggInput, AggOutcome,
    ShardedAggregator, SharedInput, StreamingAggregator, ViewInput,
};
pub use convergence::ConvergenceTracker;
pub use hierarchy::{Aggregator, FoldCore};
pub use planner::{CohortPlanner, DispatchPlan, PlanContext, RoundPlan};
pub use registry::{ClientRecord, ClientRegistry};
pub use server::{
    mask_seed, EvalHarness, NoHooks, Orchestrator, OrchestratorBuilder, OrchestratorHooks,
    RoundOutcome,
};
pub use strategy::{AggStrategy, RoundAggregator, ServerOpt};
