//! Convergence detection (Algorithm 1 line 13): relative model
//! movement ‖M_{r+1} − M_r‖ / ‖M_r‖ below ε for `patience` consecutive
//! rounds, plus optional target-accuracy early stop.

/// Tracks convergence across rounds.
#[derive(Debug, Clone)]
pub struct ConvergenceTracker {
    eps: f32,
    patience: usize,
    below: usize,
    pub target_accuracy: Option<f64>,
    last_delta: f64,
}

impl ConvergenceTracker {
    pub fn new(eps: f32, patience: usize, target_accuracy: Option<f64>) -> Self {
        ConvergenceTracker {
            eps,
            patience: patience.max(1),
            below: 0,
            target_accuracy,
            last_delta: f64::INFINITY,
        }
    }

    /// Relative movement between old and new parameters.
    pub fn relative_delta(old: &[f32], new: &[f32]) -> f64 {
        debug_assert_eq!(old.len(), new.len());
        let mut num = 0f64;
        let mut den = 0f64;
        for (&o, &n) in old.iter().zip(new) {
            let d = (n - o) as f64;
            num += d * d;
            den += (o as f64) * (o as f64);
        }
        if den == 0.0 {
            return if num == 0.0 { 0.0 } else { f64::INFINITY };
        }
        (num / den).sqrt()
    }

    /// Feed one round; returns true if converged (Algorithm 1's
    /// `Converged(M_r, M_{r+1}, ε)` with patience).
    pub fn update(&mut self, old: &[f32], new: &[f32], eval_accuracy: Option<f64>) -> bool {
        self.last_delta = Self::relative_delta(old, new);
        if self.last_delta < self.eps as f64 {
            self.below += 1;
        } else {
            self.below = 0;
        }
        if self.below >= self.patience {
            return true;
        }
        if let (Some(target), Some(acc)) = (self.target_accuracy, eval_accuracy) {
            if acc >= target {
                return true;
            }
        }
        false
    }

    pub fn last_delta(&self) -> f64 {
        self.last_delta
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relative_delta_basics() {
        let a = vec![1.0f32, 0.0];
        assert_eq!(ConvergenceTracker::relative_delta(&a, &a), 0.0);
        let b = vec![1.1f32, 0.0];
        let d = ConvergenceTracker::relative_delta(&a, &b);
        assert!((d - 0.1).abs() < 1e-6);
        // zero old params, nonzero new -> infinity
        assert!(ConvergenceTracker::relative_delta(&[0.0], &[1.0]).is_infinite());
        assert_eq!(ConvergenceTracker::relative_delta(&[0.0], &[0.0]), 0.0);
    }

    #[test]
    fn patience_requires_consecutive_quiet_rounds() {
        let mut t = ConvergenceTracker::new(0.01, 3, None);
        let base = vec![1.0f32; 10];
        let quiet: Vec<f32> = base.iter().map(|v| v + 1e-5).collect();
        let loud: Vec<f32> = base.iter().map(|v| v + 0.5).collect();
        assert!(!t.update(&base, &quiet, None));
        assert!(!t.update(&base, &quiet, None));
        assert!(!t.update(&base, &loud, None)); // resets the streak
        assert!(!t.update(&base, &quiet, None));
        assert!(!t.update(&base, &quiet, None));
        assert!(t.update(&base, &quiet, None)); // 3rd consecutive
    }

    #[test]
    fn target_accuracy_stops_early() {
        let mut t = ConvergenceTracker::new(1e-9, 5, Some(0.8));
        let a = vec![1.0f32];
        let b = vec![2.0f32];
        assert!(!t.update(&a, &b, Some(0.5)));
        assert!(t.update(&a, &b, Some(0.85)));
    }

    #[test]
    fn no_accuracy_no_early_stop() {
        let mut t = ConvergenceTracker::new(1e-9, 5, Some(0.8));
        assert!(!t.update(&[1.0], &[2.0], None));
    }
}
