//! Aggregation engine (paper §4.4 + Algorithm 1 line 11).
//!
//! All strategies share one shape: `M_{r+1} = M_r + Σ_c w_c Δ_c` with
//! weights normalized over the updates that actually arrived (partial
//! aggregation is therefore "free": the weight mass renormalizes over
//! the fastest k — Liu et al.'s FedPA behaviour).
//!
//! * FedAvg / FedProx: `w_c ∝ n_c` (server side identical; the proximal
//!   term lives in the client objective).
//! * Weighted(InverseLoss): `w_c ∝ n_c / (1 + loss_c)`.
//! * Weighted(InverseVariance): `w_c ∝ n_c / (1 + Var(Δ_c))`.
//!
//! # Streaming invariant (fold-then-normalize)
//!
//! Because `M_{r+1} = M_r + (Σ_c raw_c·Δ_c) / Σ_c raw_c`, aggregation
//! is a single global scalar away from fully streamable: each arriving
//! update folds its *unnormalized* contribution `raw_c·Δ_c` into one
//! f64 accumulator of length P and its decoded delta can be freed on
//! the spot, so the collection phase holds O(P) state instead of
//! buffering all k deltas (O(k·P)). [`StreamingAggregator::finalize`]
//! then applies the one normalization scalar `1/Σ raw_c` and adds the
//! global model.
//!
//! Determinism: per element, additions happen in arrival order and the
//! parallel fold partitions elements (never splits one element's
//! additions across threads), so for a fixed arrival order the result
//! is bit-identical regardless of thread count — and the batch
//! [`aggregate`] is a thin wrapper that folds its slice in order
//! through the same code path, pinning batch/streaming equivalence.
//!
//! Cost of streaming: each fold streams the full 8·P-byte accumulator
//! once, so a k-client round moves ~k·16P bytes of accumulator traffic
//! where the old block-major batch kernel kept a 4 KiB block in L1 and
//! moved ~k·4P. That extra bandwidth is the price of O(P) collection
//! memory and of overlapping aggregation with network arrival (the
//! end-of-round stall disappears); `benches/hotpath_streaming.rs`
//! measures both sides against the old blocked kernel.

use crate::cluster::NodeId;
use crate::config::{Aggregation, WeightScheme};
use anyhow::{bail, Result};

/// One client's contribution.
#[derive(Debug, Clone)]
pub struct AggInput {
    pub client: NodeId,
    /// Dense decoded update Δ_c.
    pub delta: Vec<f32>,
    pub n_samples: u64,
    pub train_loss: f32,
    pub update_var: f32,
}

/// Aggregation result.
#[derive(Debug, Clone)]
pub struct AggOutcome {
    pub new_params: Vec<f32>,
    /// Normalized weight per contributing client (for logs/tests).
    pub weights: Vec<(NodeId, f64)>,
    /// Sample-weighted mean train loss across contributors.
    pub mean_train_loss: f64,
}

/// Streaming aggregation state: O(P) regardless of how many clients
/// report (the collection loop folds each decoded delta the moment it
/// arrives and frees it — see the module docs for the invariant).
#[derive(Debug)]
pub struct StreamingAggregator {
    strategy: Aggregation,
    /// Unnormalized running sum `Σ raw_c·Δ_c` in f64 — the only
    /// parameter-sized state held during collection.
    acc: Vec<f64>,
    /// `(client, raw_c)` per folded update, in arrival order.
    raw: Vec<(NodeId, f64)>,
    /// `Σ raw_c` — the single normalization scalar.
    total_weight: f64,
    /// `Σ n_c` and `Σ loss_c·n_c` for the sample-weighted mean loss.
    n_total: f64,
    loss_weighted: f64,
}

impl StreamingAggregator {
    /// Start a round's aggregation for a model of `n_params` entries.
    pub fn new(n_params: usize, strategy: Aggregation) -> Self {
        StreamingAggregator {
            strategy,
            acc: vec![0f64; n_params],
            raw: Vec::new(),
            total_weight: 0.0,
            n_total: 0.0,
            loss_weighted: 0.0,
        }
    }

    /// Updates folded so far.
    pub fn n_updates(&self) -> usize {
        self.raw.len()
    }

    /// Raw (unnormalized) weight of one update under `strategy`.
    fn raw_weight(strategy: Aggregation, input: &AggInput) -> f64 {
        let n = input.n_samples.max(1) as f64;
        match strategy {
            Aggregation::FedAvg | Aggregation::FedProx { .. } => n,
            Aggregation::Weighted(WeightScheme::DataSize) => n,
            Aggregation::Weighted(WeightScheme::InverseLoss) => {
                n / (1.0 + input.train_loss.max(0.0) as f64)
            }
            Aggregation::Weighted(WeightScheme::InverseVariance) => {
                n / (1.0 + input.update_var.max(0.0) as f64)
            }
        }
    }

    /// Fold one arriving update into the accumulator. The caller can
    /// (and the orchestrator does) drop the decoded delta immediately
    /// afterwards — nothing of it is retained.
    pub fn fold(&mut self, input: &AggInput) -> Result<()> {
        if input.delta.len() != self.acc.len() {
            bail!(
                "aggregate: client {} delta length {} != {}",
                input.client,
                input.delta.len(),
                self.acc.len()
            );
        }
        let w = Self::raw_weight(self.strategy, input);
        let delta = &input.delta;
        // parallel across disjoint element ranges; each element gets
        // exactly one addition per fold, so the value is independent of
        // the thread count (arrival order is the only order that
        // matters — see module docs)
        crate::util::parallel::par_chunks_mut(&mut self.acc, 256 * 1024, |offset, chunk| {
            let d = &delta[offset..offset + chunk.len()];
            for (a, &x) in chunk.iter_mut().zip(d) {
                *a += w * x as f64;
            }
        });
        self.raw.push((input.client, w));
        self.total_weight += w;
        let n = input.n_samples.max(1) as f64;
        self.n_total += n;
        self.loss_weighted += input.train_loss as f64 * n;
        Ok(())
    }

    /// Apply the single normalization scalar and produce the new global
    /// model: `M_{r+1} = M_r + acc / Σ raw_c`.
    pub fn finalize(self, global: &[f32]) -> Result<AggOutcome> {
        if self.raw.is_empty() {
            bail!("aggregate: no updates to aggregate");
        }
        if global.len() != self.acc.len() {
            bail!(
                "aggregate: global length {} != {}",
                global.len(),
                self.acc.len()
            );
        }
        let total = self.total_weight;
        if !(total > 0.0) {
            bail!("aggregate: degenerate weights (total {total})");
        }
        let acc = self.acc;
        let mut new_params = vec![0f32; acc.len()];
        crate::util::parallel::par_chunks_mut(&mut new_params, 256 * 1024, |offset, chunk| {
            let a = &acc[offset..offset + chunk.len()];
            let g = &global[offset..offset + chunk.len()];
            for ((out, &av), &gv) in chunk.iter_mut().zip(a).zip(g) {
                *out = (gv as f64 + av / total) as f32;
            }
        });
        Ok(AggOutcome {
            new_params,
            weights: self.raw.iter().map(|&(c, w)| (c, w / total)).collect(),
            mean_train_loss: self.loss_weighted / self.n_total,
        })
    }
}

/// Aggregate updates into new global parameters.
///
/// Thin wrapper over [`StreamingAggregator`]: the slice is folded in
/// order through the exact streaming code path, so batch and streaming
/// results are bit-identical by construction for the same arrival
/// order.
pub fn aggregate(
    global: &[f32],
    inputs: &[AggInput],
    strategy: Aggregation,
) -> Result<AggOutcome> {
    if inputs.is_empty() {
        bail!("aggregate: no updates to aggregate");
    }
    let mut agg = StreamingAggregator::new(global.len(), strategy);
    for input in inputs {
        agg.fold(input)?;
    }
    agg.finalize(global)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn input(client: NodeId, delta: Vec<f32>, n: u64, loss: f32, var: f32) -> AggInput {
        AggInput {
            client,
            delta,
            n_samples: n,
            train_loss: loss,
            update_var: var,
        }
    }

    #[test]
    fn fedavg_weights_by_samples() {
        let global = vec![0f32; 3];
        let out = aggregate(
            &global,
            &[
                input(0, vec![1.0, 1.0, 1.0], 300, 1.0, 0.0),
                input(1, vec![-1.0, -1.0, -1.0], 100, 1.0, 0.0),
            ],
            Aggregation::FedAvg,
        )
        .unwrap();
        // w = (0.75, 0.25) → M = 0.75*1 - 0.25*1 = 0.5
        for v in out.new_params {
            assert!((v - 0.5).abs() < 1e-6);
        }
        assert_eq!(out.weights[0], (0, 0.75));
        assert_eq!(out.weights[1], (1, 0.25));
    }

    #[test]
    fn weights_always_normalize() {
        let global = vec![0f32; 2];
        for strat in [
            Aggregation::FedAvg,
            Aggregation::FedProx { mu: 0.1 },
            Aggregation::Weighted(WeightScheme::DataSize),
            Aggregation::Weighted(WeightScheme::InverseLoss),
            Aggregation::Weighted(WeightScheme::InverseVariance),
        ] {
            let out = aggregate(
                &global,
                &[
                    input(0, vec![1.0, 0.0], 50, 2.0, 0.5),
                    input(1, vec![0.0, 1.0], 70, 0.5, 0.1),
                    input(2, vec![1.0, 1.0], 30, 1.0, 0.9),
                ],
                strat,
            )
            .unwrap();
            let sum: f64 = out.weights.iter().map(|(_, w)| w).sum();
            assert!((sum - 1.0).abs() < 1e-9, "{strat:?}: weights sum {sum}");
        }
    }

    #[test]
    fn inverse_loss_downweights_lossy_clients() {
        let global = vec![0f32; 1];
        let out = aggregate(
            &global,
            &[
                input(0, vec![1.0], 100, 0.1, 0.0), // fits well
                input(1, vec![-1.0], 100, 9.0, 0.0), // fits poorly
            ],
            Aggregation::Weighted(WeightScheme::InverseLoss),
        )
        .unwrap();
        assert!(out.new_params[0] > 0.5, "got {}", out.new_params[0]);
    }

    #[test]
    fn inverse_variance_downweights_noisy_updates() {
        let global = vec![0f32; 1];
        let out = aggregate(
            &global,
            &[
                input(0, vec![1.0], 100, 1.0, 0.01),
                input(1, vec![-1.0], 100, 1.0, 10.0),
            ],
            Aggregation::Weighted(WeightScheme::InverseVariance),
        )
        .unwrap();
        assert!(out.new_params[0] > 0.5);
    }

    #[test]
    fn partial_aggregation_renormalizes() {
        // aggregating 2-of-3 must behave as if only those 2 existed
        let global = vec![10f32; 2];
        let all = [
            input(0, vec![1.0, 0.0], 100, 1.0, 0.0),
            input(1, vec![0.0, 1.0], 100, 1.0, 0.0),
        ];
        let out = aggregate(&global, &all, Aggregation::FedAvg).unwrap();
        assert!((out.new_params[0] - 10.5).abs() < 1e-6);
        assert!((out.new_params[1] - 10.5).abs() < 1e-6);
    }

    #[test]
    fn mean_train_loss_weighted_by_samples() {
        let global = vec![0f32; 1];
        let out = aggregate(
            &global,
            &[
                input(0, vec![0.0], 300, 1.0, 0.0),
                input(1, vec![0.0], 100, 5.0, 0.0),
            ],
            Aggregation::FedAvg,
        )
        .unwrap();
        assert!((out.mean_train_loss - 2.0).abs() < 1e-9);
    }

    #[test]
    fn errors_on_empty_and_mismatched() {
        let global = vec![0f32; 3];
        assert!(aggregate(&global, &[], Aggregation::FedAvg).is_err());
        assert!(aggregate(
            &global,
            &[input(0, vec![1.0], 1, 0.0, 0.0)],
            Aggregation::FedAvg
        )
        .is_err());
    }

    #[test]
    fn streaming_fold_matches_batch_bitwise() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(11);
        let p = 1537; // deliberately not a multiple of any chunk size
        let global: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
        let inputs: Vec<AggInput> = (0..7u32)
            .map(|c| {
                input(
                    c,
                    (0..p).map(|_| rng.normal() as f32 * 0.01).collect(),
                    10 + c as u64 * 13,
                    0.5 + c as f32 * 0.1,
                    0.01 * c as f32,
                )
            })
            .collect();
        for strat in [
            Aggregation::FedAvg,
            Aggregation::Weighted(WeightScheme::InverseLoss),
            Aggregation::Weighted(WeightScheme::InverseVariance),
        ] {
            let batch = aggregate(&global, &inputs, strat).unwrap();
            let mut agg = StreamingAggregator::new(p, strat);
            for i in &inputs {
                agg.fold(i).unwrap();
                assert!(agg.n_updates() <= inputs.len());
            }
            let streamed = agg.finalize(&global).unwrap();
            for (a, b) in batch.new_params.iter().zip(&streamed.new_params) {
                assert_eq!(a.to_bits(), b.to_bits(), "{strat:?} diverged");
            }
            assert_eq!(batch.weights, streamed.weights);
            assert_eq!(
                batch.mean_train_loss.to_bits(),
                streamed.mean_train_loss.to_bits()
            );
        }
    }

    #[test]
    fn streaming_rejects_bad_lengths_and_empty() {
        let mut agg = StreamingAggregator::new(3, Aggregation::FedAvg);
        assert!(agg.fold(&input(0, vec![1.0], 1, 0.0, 0.0)).is_err());
        assert_eq!(agg.n_updates(), 0);
        assert!(StreamingAggregator::new(3, Aggregation::FedAvg)
            .finalize(&[0.0; 3])
            .is_err());
        let mut agg = StreamingAggregator::new(2, Aggregation::FedAvg);
        agg.fold(&input(0, vec![1.0, 2.0], 1, 0.0, 0.0)).unwrap();
        assert_eq!(agg.n_updates(), 1);
        assert!(agg.finalize(&[0.0; 3]).is_err());
    }

    #[test]
    fn f64_accumulation_is_stable() {
        // many tiny contributions must not vanish in f32 rounding
        let global = vec![0f32; 1];
        let inputs: Vec<AggInput> = (0..10_000)
            .map(|i| input(i, vec![1e-4], 1, 0.0, 0.0))
            .collect();
        let out = aggregate(&global, &inputs, Aggregation::FedAvg).unwrap();
        assert!((out.new_params[0] - 1e-4).abs() < 1e-9);
    }
}
