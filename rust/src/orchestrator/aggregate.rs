//! Aggregation engine (paper §4.4 + Algorithm 1 line 11).
//!
//! All strategies share one shape: `M_{r+1} = M_r + Σ_c w_c Δ_c` with
//! weights normalized over the updates that actually arrived (partial
//! aggregation is therefore "free": the weight mass renormalizes over
//! the fastest k — Liu et al.'s FedPA behaviour).
//!
//! * FedAvg / FedProx: `w_c ∝ n_c` (server side identical; the proximal
//!   term lives in the client objective).
//! * Weighted(InverseLoss): `w_c ∝ n_c / (1 + loss_c)`.
//! * Weighted(InverseVariance): `w_c ∝ n_c / (1 + Var(Δ_c))`.

use crate::cluster::NodeId;
use crate::config::{Aggregation, WeightScheme};
use anyhow::{bail, Result};

/// One client's contribution.
#[derive(Debug, Clone)]
pub struct AggInput {
    pub client: NodeId,
    /// Dense decoded update Δ_c.
    pub delta: Vec<f32>,
    pub n_samples: u64,
    pub train_loss: f32,
    pub update_var: f32,
}

/// Aggregation result.
#[derive(Debug, Clone)]
pub struct AggOutcome {
    pub new_params: Vec<f32>,
    /// Normalized weight per contributing client (for logs/tests).
    pub weights: Vec<(NodeId, f64)>,
    /// Sample-weighted mean train loss across contributors.
    pub mean_train_loss: f64,
}

/// Aggregate updates into new global parameters.
pub fn aggregate(
    global: &[f32],
    inputs: &[AggInput],
    strategy: Aggregation,
) -> Result<AggOutcome> {
    if inputs.is_empty() {
        bail!("aggregate: no updates to aggregate");
    }
    let p = global.len();
    for i in inputs {
        if i.delta.len() != p {
            bail!(
                "aggregate: client {} delta length {} != {}",
                i.client,
                i.delta.len(),
                p
            );
        }
    }
    let raw: Vec<f64> = inputs
        .iter()
        .map(|i| {
            let n = i.n_samples.max(1) as f64;
            match strategy {
                Aggregation::FedAvg | Aggregation::FedProx { .. } => n,
                Aggregation::Weighted(WeightScheme::DataSize) => n,
                Aggregation::Weighted(WeightScheme::InverseLoss) => {
                    n / (1.0 + i.train_loss.max(0.0) as f64)
                }
                Aggregation::Weighted(WeightScheme::InverseVariance) => {
                    n / (1.0 + i.update_var.max(0.0) as f64)
                }
            }
        })
        .collect();
    let total: f64 = raw.iter().sum();
    if !(total > 0.0) {
        bail!("aggregate: degenerate weights (total {total})");
    }
    // Accumulate in f64 for stability. Hot path (60 clients × 1M params
    // per round — EXPERIMENTS.md §Perf): the f64 accumulator is blocked
    // so it stays in L1 while we stream each client's delta through it
    // once (the naive input-major loop re-streams the 8·P-byte
    // accumulator per client). Parallel across chunks on multi-core;
    // per-element input order is fixed either way, so results are
    // bit-identical to the serial loop.
    const BLOCK: usize = 4096;
    let wn: Vec<f64> = raw.iter().map(|&w| w / total).collect();
    let mut new_params = vec![0f32; p];
    crate::util::parallel::par_chunks_mut(&mut new_params, 256 * 1024, |offset, chunk| {
        let mut acc = [0f64; BLOCK];
        let mut start = 0;
        while start < chunk.len() {
            let len = BLOCK.min(chunk.len() - start);
            let base = offset + start;
            acc[..len].fill(0.0);
            for (input, &w) in inputs.iter().zip(&wn) {
                let d = &input.delta[base..base + len];
                for (a, &x) in acc[..len].iter_mut().zip(d) {
                    *a += w * x as f64;
                }
            }
            let g = &global[base..base + len];
            for ((out, &a), &gv) in chunk[start..start + len]
                .iter_mut()
                .zip(&acc[..len])
                .zip(g)
            {
                *out = (gv as f64 + a) as f32;
            }
            start += len;
        }
    });
    let n_total: f64 = inputs.iter().map(|i| i.n_samples.max(1) as f64).sum();
    let mean_train_loss = inputs
        .iter()
        .map(|i| i.train_loss as f64 * i.n_samples.max(1) as f64)
        .sum::<f64>()
        / n_total;
    Ok(AggOutcome {
        new_params,
        weights: inputs
            .iter()
            .zip(&raw)
            .map(|(i, &w)| (i.client, w / total))
            .collect(),
        mean_train_loss,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn input(client: NodeId, delta: Vec<f32>, n: u64, loss: f32, var: f32) -> AggInput {
        AggInput {
            client,
            delta,
            n_samples: n,
            train_loss: loss,
            update_var: var,
        }
    }

    #[test]
    fn fedavg_weights_by_samples() {
        let global = vec![0f32; 3];
        let out = aggregate(
            &global,
            &[
                input(0, vec![1.0, 1.0, 1.0], 300, 1.0, 0.0),
                input(1, vec![-1.0, -1.0, -1.0], 100, 1.0, 0.0),
            ],
            Aggregation::FedAvg,
        )
        .unwrap();
        // w = (0.75, 0.25) → M = 0.75*1 - 0.25*1 = 0.5
        for v in out.new_params {
            assert!((v - 0.5).abs() < 1e-6);
        }
        assert_eq!(out.weights[0], (0, 0.75));
        assert_eq!(out.weights[1], (1, 0.25));
    }

    #[test]
    fn weights_always_normalize() {
        let global = vec![0f32; 2];
        for strat in [
            Aggregation::FedAvg,
            Aggregation::FedProx { mu: 0.1 },
            Aggregation::Weighted(WeightScheme::DataSize),
            Aggregation::Weighted(WeightScheme::InverseLoss),
            Aggregation::Weighted(WeightScheme::InverseVariance),
        ] {
            let out = aggregate(
                &global,
                &[
                    input(0, vec![1.0, 0.0], 50, 2.0, 0.5),
                    input(1, vec![0.0, 1.0], 70, 0.5, 0.1),
                    input(2, vec![1.0, 1.0], 30, 1.0, 0.9),
                ],
                strat,
            )
            .unwrap();
            let sum: f64 = out.weights.iter().map(|(_, w)| w).sum();
            assert!((sum - 1.0).abs() < 1e-9, "{strat:?}: weights sum {sum}");
        }
    }

    #[test]
    fn inverse_loss_downweights_lossy_clients() {
        let global = vec![0f32; 1];
        let out = aggregate(
            &global,
            &[
                input(0, vec![1.0], 100, 0.1, 0.0), // fits well
                input(1, vec![-1.0], 100, 9.0, 0.0), // fits poorly
            ],
            Aggregation::Weighted(WeightScheme::InverseLoss),
        )
        .unwrap();
        assert!(out.new_params[0] > 0.5, "got {}", out.new_params[0]);
    }

    #[test]
    fn inverse_variance_downweights_noisy_updates() {
        let global = vec![0f32; 1];
        let out = aggregate(
            &global,
            &[
                input(0, vec![1.0], 100, 1.0, 0.01),
                input(1, vec![-1.0], 100, 1.0, 10.0),
            ],
            Aggregation::Weighted(WeightScheme::InverseVariance),
        )
        .unwrap();
        assert!(out.new_params[0] > 0.5);
    }

    #[test]
    fn partial_aggregation_renormalizes() {
        // aggregating 2-of-3 must behave as if only those 2 existed
        let global = vec![10f32; 2];
        let all = [
            input(0, vec![1.0, 0.0], 100, 1.0, 0.0),
            input(1, vec![0.0, 1.0], 100, 1.0, 0.0),
        ];
        let out = aggregate(&global, &all, Aggregation::FedAvg).unwrap();
        assert!((out.new_params[0] - 10.5).abs() < 1e-6);
        assert!((out.new_params[1] - 10.5).abs() < 1e-6);
    }

    #[test]
    fn mean_train_loss_weighted_by_samples() {
        let global = vec![0f32; 1];
        let out = aggregate(
            &global,
            &[
                input(0, vec![0.0], 300, 1.0, 0.0),
                input(1, vec![0.0], 100, 5.0, 0.0),
            ],
            Aggregation::FedAvg,
        )
        .unwrap();
        assert!((out.mean_train_loss - 2.0).abs() < 1e-9);
    }

    #[test]
    fn errors_on_empty_and_mismatched() {
        let global = vec![0f32; 3];
        assert!(aggregate(&global, &[], Aggregation::FedAvg).is_err());
        assert!(aggregate(
            &global,
            &[input(0, vec![1.0], 1, 0.0, 0.0)],
            Aggregation::FedAvg
        )
        .is_err());
    }

    #[test]
    fn f64_accumulation_is_stable() {
        // many tiny contributions must not vanish in f32 rounding
        let global = vec![0f32; 1];
        let inputs: Vec<AggInput> = (0..10_000)
            .map(|i| input(i, vec![1e-4], 1, 0.0, 0.0))
            .collect();
        let out = aggregate(&global, &inputs, Aggregation::FedAvg).unwrap();
        assert!((out.new_params[0] - 1e-4).abs() < 1e-9);
    }
}
