//! Streaming aggregation core (paper §4.4 + Algorithm 1 line 11).
//!
//! This module owns the *mechanics* of fold-then-normalize; the
//! *policy* (how an update is weighted, whether a round must buffer)
//! lives in [`super::strategy`]. All streaming strategies share one
//! shape: `M_{r+1} = M_r + Σ_c w_c Δ_c` with weights normalized over
//! the updates that actually arrived (partial aggregation is therefore
//! "free": the weight mass renormalizes over the fastest k — Liu et
//! al.'s FedPA behaviour).
//!
//! # Streaming invariant (fold-then-normalize)
//!
//! Because `M_{r+1} = M_r + (Σ_c raw_c·Δ_c) / Σ_c raw_c`, aggregation
//! is a single global scalar away from fully streamable: each arriving
//! update folds its *unnormalized* contribution `raw_c·Δ_c` into one
//! f64 accumulator of length P and its decoded delta can be freed on
//! the spot, so the collection phase holds O(P) state instead of
//! buffering all k deltas (O(k·P)). [`StreamingAggregator::finalize`]
//! then applies the one normalization scalar `1/Σ raw_c`, yielding the
//! round's aggregated update Δ_agg ([`AggDelta`]); a
//! [`super::strategy::ServerOpt`] turns that into the new global model.
//!
//! Determinism: per element, additions happen in arrival order and the
//! parallel fold partitions elements (never splits one element's
//! additions across threads), so for a fixed arrival order the result
//! is bit-identical regardless of thread count — and the batch
//! [`aggregate`] folds its slice in order through the same code path,
//! pinning batch/streaming equivalence.
//!
//! Cost of streaming: each *dense* fold streams the full 8·P-byte
//! accumulator once, so a k-client round moves ~k·16P bytes of
//! accumulator traffic where the old block-major batch kernel kept a
//! 4 KiB block in L1 and moved ~k·4P. That extra bandwidth is the
//! price of O(P) collection memory and of overlapping aggregation with
//! network arrival (the end-of-round stall disappears);
//! `benches/hotpath_streaming.rs` measures both sides against the old
//! blocked kernel.
//!
//! # Fused decode→fold ingest
//!
//! The round loop does not decode updates densely at all:
//! [`StreamingAggregator::fold_view`] folds an arriving update straight
//! from its [`crate::compress::DecodedView`], touching only the
//! coordinates that actually crossed the wire — O(k) for a top-k
//! sparse update, not O(P). The dense fold above remains for callers
//! that already hold a dense delta (the batch [`aggregate`] wrapper,
//! tests, custom strategies); both entry points are bit-identical for
//! the same update and pinned so by property test.
//! `benches/hotpath_ingest.rs` measures fused vs densify-then-fold and
//! emits `BENCH_ingest.json`.

use super::strategy::{registry, RoundAggregator, SgdServer};
use crate::cluster::NodeId;
use crate::compress::{DecodedView, SharedDecoded};
use crate::config::Aggregation;
use crate::util::lock_unpoisoned;
use crate::util::parallel::{ShardPool, FOLD_CHUNK};
use anyhow::{bail, Result};
use std::sync::{Arc, Mutex};

/// One client's contribution.
#[derive(Debug, Clone)]
pub struct AggInput {
    pub client: NodeId,
    /// Dense decoded update Δ_c.
    pub delta: Vec<f32>,
    pub n_samples: u64,
    pub train_loss: f32,
    pub update_var: f32,
}

/// One client's contribution as an owned, shard-shareable decoded
/// payload — the sharded-ingest counterpart of [`ViewInput`]. The
/// payload was validated exactly once on the ingest thread
/// ([`SharedDecoded::new`]); shard workers fold disjoint coordinate
/// ranges of it concurrently.
pub struct SharedInput {
    pub client: NodeId,
    /// Validated, owned decode of the arriving update Δ_c.
    pub payload: Arc<SharedDecoded>,
    pub n_samples: u64,
    pub train_loss: f32,
    pub update_var: f32,
}

/// One client's contribution as a zero-materialization decode view —
/// the ingest-path counterpart of [`AggInput`]. The delta is borrowed
/// straight from the arriving [`crate::compress::Encoded`] (or its
/// pre-encoded wire bytes); strategies that can fold sparsely never
/// see a dense vector at all.
pub struct ViewInput<'a> {
    pub client: NodeId,
    /// Validated decode view over the arriving update Δ_c.
    pub view: &'a DecodedView<'a>,
    pub n_samples: u64,
    pub train_loss: f32,
    pub update_var: f32,
}

/// Aggregation result (after the server optimizer is applied).
#[derive(Debug, Clone)]
pub struct AggOutcome {
    pub new_params: Vec<f32>,
    /// Normalized weight per contributing client (for logs/tests).
    pub weights: Vec<(NodeId, f64)>,
    /// Sample-weighted mean train loss across contributors.
    pub mean_train_loss: f64,
}

/// A finalized round aggregate *before* the server optimizer runs: the
/// f64 aggregated update Δ_agg plus per-round bookkeeping. Every
/// strategy — streaming or buffered — produces one of these; a
/// [`super::strategy::ServerOpt`] maps `(M_r, Δ_agg) → M_{r+1}`.
#[derive(Debug, Clone)]
pub struct AggDelta {
    /// The aggregated update Δ_agg, in f64 (cast to f32 only after the
    /// server optimizer has combined it with the global model).
    pub delta: Vec<f64>,
    /// Normalized weight per contributing client, in arrival order.
    pub weights: Vec<(NodeId, f64)>,
    /// Sample-weighted mean train loss across contributors.
    pub mean_train_loss: f64,
}

/// Streaming aggregation state: O(P) regardless of how many clients
/// report (the collection loop folds each decoded delta the moment it
/// arrives and frees it — see the module docs for the invariant).
///
/// Weight-agnostic: the caller (normally a
/// [`super::strategy::RoundAggregator`]) supplies each update's raw
/// weight, so one kernel serves every streaming strategy.
#[derive(Debug)]
pub struct StreamingAggregator {
    /// Unnormalized running sum `Σ raw_c·Δ_c` in f64 — the only
    /// parameter-sized state held during collection.
    acc: Vec<f64>,
    /// `(client, raw_c)` per folded update, in arrival order.
    raw: Vec<(NodeId, f64)>,
    /// `Σ raw_c` — the single normalization scalar.
    total_weight: f64,
    /// `Σ n_c` and `Σ loss_c·n_c` for the sample-weighted mean loss.
    n_total: f64,
    loss_weighted: f64,
}

impl StreamingAggregator {
    /// Start a round's aggregation for a model of `n_params` entries.
    pub fn new(n_params: usize) -> Self {
        StreamingAggregator {
            acc: vec![0f64; n_params],
            raw: Vec::new(),
            total_weight: 0.0,
            n_total: 0.0,
            loss_weighted: 0.0,
        }
    }

    /// Updates folded so far.
    pub fn n_updates(&self) -> usize {
        self.raw.len()
    }

    /// Summed raw (unnormalized) weight `Σ raw_c` folded so far — the
    /// quantity a site aggregator must carry upstream so the root's
    /// fold weighs the site exactly as much as its members.
    pub fn total_weight(&self) -> f64 {
        self.total_weight
    }

    fn check_weight(&self, w: f64, client: NodeId) -> Result<()> {
        check_weight(w, client)
    }

    /// Per-update bookkeeping shared by both fold entry points.
    fn note(&mut self, client: NodeId, w: f64, n_samples: u64, train_loss: f32) {
        self.raw.push((client, w));
        self.total_weight += w;
        let n = n_samples.max(1) as f64;
        self.n_total += n;
        self.loss_weighted += train_loss as f64 * n;
    }

    /// Fold one arriving update with raw (unnormalized) weight `w` into
    /// the accumulator. The caller can (and the orchestrator does) drop
    /// the decoded delta immediately afterwards — nothing of it is
    /// retained.
    pub fn fold(&mut self, input: &AggInput, w: f64) -> Result<()> {
        if input.delta.len() != self.acc.len() {
            bail!(
                "aggregate: client {} delta length {} != {}",
                input.client,
                input.delta.len(),
                self.acc.len()
            );
        }
        self.check_weight(w, input.client)?;
        let delta = &input.delta;
        // parallel across disjoint element ranges; each element gets
        // exactly one addition per fold, so the value is independent of
        // the thread count (arrival order is the only order that
        // matters — see module docs)
        crate::util::parallel::par_chunks_mut(&mut self.acc, FOLD_CHUNK, |offset, chunk| {
            let d = &delta[offset..offset + chunk.len()];
            for (a, &x) in chunk.iter_mut().zip(d) {
                *a += w * x as f64;
            }
        });
        self.note(input.client, w, input.n_samples, input.train_loss);
        Ok(())
    }

    /// Fused decode→fold: like [`StreamingAggregator::fold`] but
    /// straight from an encoded update's [`DecodedView`] — O(nnz)
    /// instead of O(P), and no dense vector is ever materialized.
    /// Bit-identical to decoding and folding densely (stored entries
    /// perform the same `acc += w·x` additions in the same per-element
    /// order; unstored coordinates contribute exactly nothing, which
    /// matches adding `w·0.0` — see the `compress` module docs for the
    /// signed-zero argument, and `prop_invariants` for the pin).
    pub fn fold_view(&mut self, input: &ViewInput<'_>, w: f64) -> Result<()> {
        if input.view.dense_len() != self.acc.len() {
            bail!(
                "aggregate: client {} delta length {} != {}",
                input.client,
                input.view.dense_len(),
                self.acc.len()
            );
        }
        self.check_weight(w, input.client)?;
        input.view.fold_scaled_into(&mut self.acc, w);
        self.note(input.client, w, input.n_samples, input.train_loss);
        Ok(())
    }

    /// Apply the single normalization scalar, producing the round's
    /// aggregated update `Δ_agg = acc / Σ raw_c`.
    pub fn finalize(self) -> Result<AggDelta> {
        normalize_delta(
            self.acc,
            self.raw,
            self.total_weight,
            self.n_total,
            self.loss_weighted,
        )
    }
}

/// Raw-weight sanity shared by every fold entry point.
fn check_weight(w: f64, client: NodeId) -> Result<()> {
    if w.is_nan() || w.is_infinite() || w < 0.0 {
        bail!("aggregate: invalid weight {w} for client {client}");
    }
    Ok(())
}

/// Shared finalize tail: validate the weight mass, apply the single
/// normalization scalar `1/Σ raw_c`, and package the round's
/// [`AggDelta`]. Both the streaming and sharded backends end here, so
/// their outputs are bit-identical by construction once their merged
/// accumulators match.
fn normalize_delta(
    mut delta: Vec<f64>,
    raw: Vec<(NodeId, f64)>,
    total_weight: f64,
    n_total: f64,
    loss_weighted: f64,
) -> Result<AggDelta> {
    if raw.is_empty() {
        bail!("aggregate: no updates to aggregate");
    }
    let total = total_weight;
    if total.is_nan() || total <= 0.0 {
        bail!("aggregate: degenerate weights (total {total})");
    }
    crate::util::parallel::par_chunks_mut(&mut delta, FOLD_CHUNK, |_offset, chunk| {
        for a in chunk.iter_mut() {
            *a /= total;
        }
    });
    Ok(AggDelta {
        delta,
        weights: raw.iter().map(|&(c, w)| (c, w / total)).collect(),
        mean_train_loss: loss_weighted / n_total,
    })
}

/// Elements per ingest shard. At 1M params this yields 8 shards, so the
/// bench's 8-worker sweep point still has distinct shards to own.
pub const INGEST_SHARD_SPAN: usize = 128 * 1024;

/// Number of accumulator shards for a model of `n_params` elements — a
/// pure function of the model size, never of the thread count, so the
/// element→shard mapping (and hence the per-shard addition order) is
/// identical no matter how many workers serve the pool.
pub fn default_ingest_shards(n_params: usize) -> usize {
    n_params.div_ceil(INGEST_SHARD_SPAN).max(1)
}

/// Fixed shard boundaries: `n_shards` contiguous disjoint `[lo, hi)`
/// spans covering `[0, n_params)`. Computed once per round from the
/// model size and shard count alone (determinism: same inputs → same
/// boundaries, enforced by fedhpc-lint's determinism scope on this
/// module).
pub fn shard_spans(n_params: usize, n_shards: usize) -> Vec<(usize, usize)> {
    let n_shards = n_shards.max(1);
    let span = n_params.div_ceil(n_shards).max(1);
    (0..n_shards)
        .map(|s| ((s * span).min(n_params), ((s + 1) * span).min(n_params)))
        .collect()
}

/// Sharded streaming backend: the f64 accumulator is split at the fixed
/// [`shard_spans`] boundaries and each span lives behind its own lock;
/// folding an update enqueues one job per shard on the persistent
/// [`ShardPool`], so *different* updates fold concurrently on disjoint
/// element ranges.
///
/// # Bit-identity argument
///
/// Every element belongs to exactly one shard (fixed boundaries,
/// independent of worker count); each shard's queue is FIFO and served
/// by exactly one worker, so a shard's elements receive their additions
/// in submission (= arrival) order — the same per-element addition
/// order as the serial [`StreamingAggregator`]. Segments start at
/// `+0.0` like the serial accumulator, the merge at [`finalize`] is a
/// bitwise copy in shard-index order, and the normalization tail is the
/// shared [`normalize_delta`]. Hence for a fixed arrival order the
/// result is bit-identical to the serial path at every shard/worker
/// count — pinned by property test in `prop_invariants`.
///
/// [`finalize`]: ShardedAggregator::finalize
pub struct ShardedAggregator {
    pool: Arc<ShardPool>,
    /// Fixed `[lo, hi)` coordinate span per shard.
    spans: Vec<(usize, usize)>,
    /// Per-shard accumulator segment (starts at `+0.0`).
    segs: Vec<Arc<Mutex<Vec<f64>>>>,
    raw: Vec<(NodeId, f64)>,
    total_weight: f64,
    n_total: f64,
    loss_weighted: f64,
    n_params: usize,
}

impl ShardedAggregator {
    /// Start a round's sharded aggregation for a model of `n_params`
    /// entries, reusing the given persistent pool (no threads spawn
    /// here — that is the point).
    pub fn new(n_params: usize, pool: Arc<ShardPool>) -> Self {
        let spans = shard_spans(n_params, pool.n_shards());
        let segs = spans
            .iter()
            .map(|&(lo, hi)| Arc::new(Mutex::new(vec![0f64; hi - lo])))
            .collect();
        ShardedAggregator {
            pool,
            spans,
            segs,
            raw: Vec::new(),
            total_weight: 0.0,
            n_total: 0.0,
            loss_weighted: 0.0,
            n_params,
        }
    }

    /// Updates accepted (enqueued) so far.
    pub fn n_updates(&self) -> usize {
        self.raw.len()
    }

    /// Summed raw (unnormalized) weight `Σ raw_c` folded so far (see
    /// [`StreamingAggregator::total_weight`]).
    pub fn total_weight(&self) -> f64 {
        self.total_weight
    }

    /// Fold one arriving update with raw weight `w`: validation and
    /// bookkeeping happen here on the ingest thread (same error surface
    /// as [`StreamingAggregator::fold_view`]), then one job per shard
    /// is enqueued in arrival order and this call returns — the actual
    /// additions overlap with the next arrival.
    pub fn fold_shared(&mut self, input: &SharedInput, w: f64) -> Result<()> {
        if input.payload.dense_len() != self.n_params {
            bail!(
                "aggregate: client {} delta length {} != {}",
                input.client,
                input.payload.dense_len(),
                self.n_params
            );
        }
        check_weight(w, input.client)?;
        for (s, (&(lo, hi), seg)) in self.spans.iter().zip(&self.segs).enumerate() {
            let seg = seg.clone();
            let payload = input.payload.clone();
            self.pool.submit(s, move || {
                let mut seg = lock_unpoisoned(&seg);
                payload.fold_range_into(&mut seg, lo, hi, w);
            });
        }
        self.raw.push((input.client, w));
        self.total_weight += w;
        let n = input.n_samples.max(1) as f64;
        self.n_total += n;
        self.loss_weighted += input.train_loss as f64 * n;
        Ok(())
    }

    /// Deterministic barrier + merge: wait for every enqueued shard job
    /// (re-throwing any worker panic), copy the segments back into one
    /// accumulator in shard-index order, and normalize via the shared
    /// tail — producing an [`AggDelta`] indistinguishable from the
    /// serial backend's.
    pub fn finalize(self) -> Result<AggDelta> {
        self.pool.wait_idle();
        let mut delta = vec![0f64; self.n_params];
        for (&(lo, hi), seg) in self.spans.iter().zip(&self.segs) {
            let seg = lock_unpoisoned(seg);
            if let Some(dst) = delta.get_mut(lo..hi) {
                dst.copy_from_slice(&seg);
            }
        }
        normalize_delta(
            delta,
            self.raw,
            self.total_weight,
            self.n_total,
            self.loss_weighted,
        )
    }

    /// The pool backing this aggregator (for telemetry sampling).
    pub fn pool(&self) -> &Arc<ShardPool> {
        &self.pool
    }
}

/// Aggregate a batch of updates into new global parameters with a
/// plain SGD server step.
///
/// Thin wrapper over [`super::strategy::RoundAggregator`]: the slice is
/// folded in order through the exact streaming (or buffered) code
/// path, so batch and streaming results are bit-identical by
/// construction for the same arrival order.
pub fn aggregate(
    global: &[f32],
    inputs: &[AggInput],
    strategy: Aggregation,
) -> Result<AggOutcome> {
    if inputs.is_empty() {
        bail!("aggregate: no updates to aggregate");
    }
    let mut agg = RoundAggregator::new(registry::strategy_from_config(&strategy), global.len());
    for input in inputs {
        agg.fold(input)?;
    }
    agg.finalize(global, &mut SgdServer)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WeightScheme;

    fn input(client: NodeId, delta: Vec<f32>, n: u64, loss: f32, var: f32) -> AggInput {
        AggInput {
            client,
            delta,
            n_samples: n,
            train_loss: loss,
            update_var: var,
        }
    }

    #[test]
    fn fedavg_weights_by_samples() {
        let global = vec![0f32; 3];
        let out = aggregate(
            &global,
            &[
                input(0, vec![1.0, 1.0, 1.0], 300, 1.0, 0.0),
                input(1, vec![-1.0, -1.0, -1.0], 100, 1.0, 0.0),
            ],
            Aggregation::FedAvg,
        )
        .unwrap();
        // w = (0.75, 0.25) → M = 0.75*1 - 0.25*1 = 0.5
        for v in out.new_params {
            assert!((v - 0.5).abs() < 1e-6);
        }
        assert_eq!(out.weights[0], (0, 0.75));
        assert_eq!(out.weights[1], (1, 0.25));
    }

    #[test]
    fn weights_always_normalize() {
        let global = vec![0f32; 2];
        for strat in [
            Aggregation::FedAvg,
            Aggregation::FedProx { mu: 0.1 },
            Aggregation::Weighted(WeightScheme::DataSize),
            Aggregation::Weighted(WeightScheme::InverseLoss),
            Aggregation::Weighted(WeightScheme::InverseVariance),
            Aggregation::TrimmedMean { trim_frac: 0.25 },
            Aggregation::CoordinateMedian,
        ] {
            let out = aggregate(
                &global,
                &[
                    input(0, vec![1.0, 0.0], 50, 2.0, 0.5),
                    input(1, vec![0.0, 1.0], 70, 0.5, 0.1),
                    input(2, vec![1.0, 1.0], 30, 1.0, 0.9),
                ],
                strat,
            )
            .unwrap();
            let sum: f64 = out.weights.iter().map(|(_, w)| w).sum();
            assert!((sum - 1.0).abs() < 1e-9, "{strat:?}: weights sum {sum}");
        }
    }

    #[test]
    fn inverse_loss_downweights_lossy_clients() {
        let global = vec![0f32; 1];
        let out = aggregate(
            &global,
            &[
                input(0, vec![1.0], 100, 0.1, 0.0), // fits well
                input(1, vec![-1.0], 100, 9.0, 0.0), // fits poorly
            ],
            Aggregation::Weighted(WeightScheme::InverseLoss),
        )
        .unwrap();
        assert!(out.new_params[0] > 0.5, "got {}", out.new_params[0]);
    }

    #[test]
    fn inverse_variance_downweights_noisy_updates() {
        let global = vec![0f32; 1];
        let out = aggregate(
            &global,
            &[
                input(0, vec![1.0], 100, 1.0, 0.01),
                input(1, vec![-1.0], 100, 1.0, 10.0),
            ],
            Aggregation::Weighted(WeightScheme::InverseVariance),
        )
        .unwrap();
        assert!(out.new_params[0] > 0.5);
    }

    #[test]
    fn partial_aggregation_renormalizes() {
        // aggregating 2-of-3 must behave as if only those 2 existed
        let global = vec![10f32; 2];
        let all = [
            input(0, vec![1.0, 0.0], 100, 1.0, 0.0),
            input(1, vec![0.0, 1.0], 100, 1.0, 0.0),
        ];
        let out = aggregate(&global, &all, Aggregation::FedAvg).unwrap();
        assert!((out.new_params[0] - 10.5).abs() < 1e-6);
        assert!((out.new_params[1] - 10.5).abs() < 1e-6);
    }

    #[test]
    fn mean_train_loss_weighted_by_samples() {
        let global = vec![0f32; 1];
        let out = aggregate(
            &global,
            &[
                input(0, vec![0.0], 300, 1.0, 0.0),
                input(1, vec![0.0], 100, 5.0, 0.0),
            ],
            Aggregation::FedAvg,
        )
        .unwrap();
        assert!((out.mean_train_loss - 2.0).abs() < 1e-9);
    }

    #[test]
    fn errors_on_empty_and_mismatched() {
        let global = vec![0f32; 3];
        assert!(aggregate(&global, &[], Aggregation::FedAvg).is_err());
        assert!(aggregate(
            &global,
            &[input(0, vec![1.0], 1, 0.0, 0.0)],
            Aggregation::FedAvg
        )
        .is_err());
    }

    /// The pre-refactor pinned behaviour: for every streaming strategy,
    /// folding through a [`RoundAggregator`] one update at a time is
    /// bit-identical to the batch wrapper — and both match the
    /// closed-form `M + Σ raw·Δ / Σ raw` the old enum-matched
    /// aggregator computed.
    #[test]
    fn streaming_fold_matches_batch_bitwise() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(11);
        let p = 1537; // deliberately not a multiple of any chunk size
        let global: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
        let inputs: Vec<AggInput> = (0..7u32)
            .map(|c| {
                input(
                    c,
                    (0..p).map(|_| rng.normal() as f32 * 0.01).collect(),
                    10 + c as u64 * 13,
                    0.5 + c as f32 * 0.1,
                    0.01 * c as f32,
                )
            })
            .collect();
        for strat in [
            Aggregation::FedAvg,
            Aggregation::FedProx { mu: 0.1 },
            Aggregation::Weighted(WeightScheme::DataSize),
            Aggregation::Weighted(WeightScheme::InverseLoss),
            Aggregation::Weighted(WeightScheme::InverseVariance),
        ] {
            let batch = aggregate(&global, &inputs, strat).unwrap();
            let mut agg = RoundAggregator::new(registry::strategy_from_config(&strat), p);
            for i in &inputs {
                agg.fold(i).unwrap();
                assert!(agg.n_updates() <= inputs.len());
            }
            let streamed = agg.finalize(&global, &mut SgdServer).unwrap();
            for (a, b) in batch.new_params.iter().zip(&streamed.new_params) {
                assert_eq!(a.to_bits(), b.to_bits(), "{strat:?} diverged");
            }
            assert_eq!(batch.weights, streamed.weights);
            assert_eq!(
                batch.mean_train_loss.to_bits(),
                streamed.mean_train_loss.to_bits()
            );

            // pre-refactor reference: raw weights exactly as the old
            // enum-matched StreamingAggregator computed them
            let raw: Vec<f64> = inputs
                .iter()
                .map(|i| {
                    let n = i.n_samples.max(1) as f64;
                    match strat {
                        Aggregation::Weighted(WeightScheme::InverseLoss) => {
                            n / (1.0 + i.train_loss.max(0.0) as f64)
                        }
                        Aggregation::Weighted(WeightScheme::InverseVariance) => {
                            n / (1.0 + i.update_var.max(0.0) as f64)
                        }
                        _ => n,
                    }
                })
                .collect();
            let total: f64 = raw.iter().sum();
            for j in 0..p {
                let mut acc = 0f64;
                for (i, &w) in inputs.iter().zip(&raw) {
                    acc += w * i.delta[j] as f64;
                }
                let want = (global[j] as f64 + acc / total) as f32;
                assert_eq!(
                    want.to_bits(),
                    batch.new_params[j].to_bits(),
                    "{strat:?} diverged from pre-refactor formula at {j}"
                );
            }
        }
    }

    #[test]
    fn streaming_rejects_bad_lengths_weights_and_empty() {
        let mut agg = StreamingAggregator::new(3);
        assert!(agg.fold(&input(0, vec![1.0], 1, 0.0, 0.0), 1.0).is_err());
        assert_eq!(agg.n_updates(), 0);
        assert!(agg
            .fold(&input(0, vec![1.0, 2.0, 3.0], 1, 0.0, 0.0), f64::NAN)
            .is_err());
        assert!(agg
            .fold(&input(0, vec![1.0, 2.0, 3.0], 1, 0.0, 0.0), -1.0)
            .is_err());
        assert!(StreamingAggregator::new(3).finalize().is_err());
        // a server opt rejects a global/delta length mismatch
        let strategy = registry::strategy_from_config(&Aggregation::FedAvg);
        let mut agg = RoundAggregator::new(strategy, 2);
        agg.fold(&input(0, vec![1.0, 2.0], 1, 0.0, 0.0)).unwrap();
        assert_eq!(agg.n_updates(), 1);
        assert!(agg.finalize(&[0.0; 3], &mut SgdServer).is_err());
    }

    /// The fused decode→fold entry point is bit-identical to decoding
    /// densely and folding — including the signed-zero edge (stored
    /// `-0.0`/`0.0` values and unstored coordinates). The broad pin
    /// across encodings/permutations lives in `prop_invariants`.
    #[test]
    fn fold_view_is_bit_identical_to_densify_then_fold() {
        use crate::compress::{compress, decompress, DecodedView};
        use crate::config::CompressionConfig;
        use crate::util::rng::Rng;
        let p = 1000;
        let mut rng = Rng::new(3);
        let cfg = CompressionConfig::PAPER;
        let mut dense_agg = StreamingAggregator::new(p);
        let mut view_agg = StreamingAggregator::new(p);
        for c in 0..5u32 {
            let mut upd: Vec<f32> = (0..p).map(|_| rng.normal() as f32 * 0.01).collect();
            upd[10] = -0.0;
            upd[20] = 0.0;
            let enc = compress(&upd, &cfg, c as u64);
            let dense = decompress(&enc, p).unwrap();
            let w = 1.0 + c as f64;
            dense_agg.fold(&input(c, dense, 10, 1.0, 0.0), w).unwrap();
            let view = DecodedView::of(&enc, p).unwrap();
            view_agg
                .fold_view(
                    &ViewInput {
                        client: c,
                        view: &view,
                        n_samples: 10,
                        train_loss: 1.0,
                        update_var: 0.0,
                    },
                    w,
                )
                .unwrap();
        }
        let a = dense_agg.finalize().unwrap();
        let b = view_agg.finalize().unwrap();
        for (x, y) in a.delta.iter().zip(&b.delta) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.weights, b.weights);
        assert_eq!(a.mean_train_loss.to_bits(), b.mean_train_loss.to_bits());
    }

    #[test]
    fn fold_view_rejects_bad_lengths_and_weights() {
        use crate::compress::{DecodedView, Encoded};
        let enc = Encoded::Dense(vec![1.0; 3]);
        let view = DecodedView::of(&enc, 3).unwrap();
        let vi = |view| ViewInput {
            client: 0,
            view,
            n_samples: 1,
            train_loss: 0.0,
            update_var: 0.0,
        };
        let mut agg = StreamingAggregator::new(2);
        assert!(agg.fold_view(&vi(&view), 1.0).is_err());
        assert_eq!(agg.n_updates(), 0);
        let mut agg = StreamingAggregator::new(3);
        assert!(agg.fold_view(&vi(&view), f64::NAN).is_err());
        assert!(agg.fold_view(&vi(&view), -1.0).is_err());
        assert_eq!(agg.n_updates(), 0);
        agg.fold_view(&vi(&view), 2.0).unwrap();
        assert_eq!(agg.n_updates(), 1);
    }

    #[test]
    fn shard_spans_are_disjoint_cover_and_size_independent_of_workers() {
        for (n_params, n_shards) in [(1usize, 1usize), (10, 3), (1537, 7), (1 << 20, 8), (100, 200)]
        {
            let spans = shard_spans(n_params, n_shards);
            assert_eq!(spans.len(), n_shards.max(1));
            let mut cursor = 0;
            for &(lo, hi) in &spans {
                assert_eq!(lo, cursor.min(n_params));
                assert!(lo <= hi && hi <= n_params);
                cursor = hi.max(cursor);
            }
            assert_eq!(spans.last().map(|&(_, hi)| hi), Some(n_params));
        }
        // pure function of (n_params, n_shards): recomputing gives the
        // exact same boundaries
        assert_eq!(shard_spans(1 << 20, 8), shard_spans(1 << 20, 8));
        assert_eq!(default_ingest_shards(1 << 20), 8);
        assert_eq!(default_ingest_shards(1), 1);
    }

    #[test]
    fn sharded_fold_is_bit_identical_to_streaming_for_fixed_arrival_order() {
        use crate::compress::{compress, SharedDecoded};
        use crate::config::CompressionConfig;
        use crate::util::rng::Rng;
        let p = 12_345;
        let mut rng = Rng::new(17);
        let updates: Vec<(u32, Vec<f32>, f64)> = (0..6u32)
            .map(|c| {
                let upd: Vec<f32> = (0..p).map(|_| rng.normal() as f32 * 0.01).collect();
                (c, upd, 1.0 + c as f64 * 0.5)
            })
            .collect();
        let mut serial = StreamingAggregator::new(p);
        for (c, upd, w) in &updates {
            serial
                .fold(&input(*c, upd.clone(), 10, 1.0, 0.0), *w)
                .unwrap();
        }
        let want = serial.finalize().unwrap();
        for n_workers in [1usize, 2, 3] {
            let pool = Arc::new(ShardPool::new(n_workers, 5));
            let mut sharded = ShardedAggregator::new(p, pool);
            for (c, upd, w) in &updates {
                let payload = Arc::new(
                    SharedDecoded::new(
                        Arc::new(compress(upd, &CompressionConfig::NONE, *c as u64)),
                        p,
                    )
                    .unwrap(),
                );
                sharded
                    .fold_shared(
                        &SharedInput {
                            client: *c,
                            payload,
                            n_samples: 10,
                            train_loss: 1.0,
                            update_var: 0.0,
                        },
                        *w,
                    )
                    .unwrap();
            }
            let got = sharded.finalize().unwrap();
            for (a, b) in want.delta.iter().zip(&got.delta) {
                assert_eq!(a.to_bits(), b.to_bits(), "{n_workers} workers diverged");
            }
            assert_eq!(want.weights, got.weights);
            assert_eq!(
                want.mean_train_loss.to_bits(),
                got.mean_train_loss.to_bits()
            );
        }
    }

    #[test]
    fn sharded_rejects_bad_lengths_weights_and_empty() {
        use crate::compress::{Encoded, SharedDecoded};
        let pool = Arc::new(ShardPool::new(2, 3));
        let payload =
            Arc::new(SharedDecoded::new(Arc::new(Encoded::Dense(vec![1.0; 4])), 4).unwrap());
        let si = |payload: &Arc<SharedDecoded>| SharedInput {
            client: 0,
            payload: payload.clone(),
            n_samples: 1,
            train_loss: 0.0,
            update_var: 0.0,
        };
        let mut agg = ShardedAggregator::new(9, pool.clone());
        assert!(agg.fold_shared(&si(&payload), 1.0).is_err());
        assert_eq!(agg.n_updates(), 0);
        let mut agg = ShardedAggregator::new(4, pool.clone());
        assert!(agg.fold_shared(&si(&payload), f64::NAN).is_err());
        assert!(agg.fold_shared(&si(&payload), -1.0).is_err());
        assert_eq!(agg.n_updates(), 0);
        assert!(ShardedAggregator::new(4, pool.clone()).finalize().is_err());
        let mut agg = ShardedAggregator::new(4, pool);
        agg.fold_shared(&si(&payload), 2.0).unwrap();
        assert_eq!(agg.n_updates(), 1);
        assert!(agg.finalize().is_ok());
    }

    #[test]
    fn f64_accumulation_is_stable() {
        // many tiny contributions must not vanish in f32 rounding
        let global = vec![0f32; 1];
        let inputs: Vec<AggInput> = (0..10_000)
            .map(|i| input(i, vec![1e-4], 1, 0.0, 0.0))
            .collect();
        let out = aggregate(&global, &inputs, Aggregation::FedAvg).unwrap();
        assert!((out.new_params[0] - 1e-4).abs() < 1e-9);
    }
}
