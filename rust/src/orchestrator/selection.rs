//! Adaptive client selection (paper §4.1).
//!
//! `Random` samples uniformly (the ablation baseline). `Adaptive`
//! scores clients by capability × reliability × bandwidth, benches
//! chronic stragglers (EWMA round time > exclude_factor × median) and
//! reserves an exploration fraction of slots for uniform sampling so
//! cold/benched profiles keep getting refreshed.

use super::registry::ClientRegistry;
use crate::cluster::NodeId;
use crate::config::{SelectionConfig, SelectionPolicy};
use crate::util::rng::Rng;

/// Pick this round's cohort from `available` clients.
///
/// Deterministic in `rng`. Returns at most `cfg.clients_per_round` ids
/// (fewer if not enough clients are available).
pub fn select_clients(
    registry: &mut ClientRegistry,
    available: &[NodeId],
    cfg: &SelectionConfig,
    round: u32,
    rng: &mut Rng,
) -> Vec<NodeId> {
    let k = cfg.clients_per_round.min(available.len());
    if k == 0 {
        return vec![];
    }
    match cfg.policy {
        SelectionPolicy::Random => {
            let picks = rng.sample_indices(available.len(), k);
            picks.into_iter().map(|i| available[i]).collect()
        }
        SelectionPolicy::Adaptive {
            explore_frac,
            exclude_factor,
        } => adaptive(registry, available, k, explore_frac, exclude_factor, round, rng),
    }
}

fn adaptive(
    registry: &mut ClientRegistry,
    available: &[NodeId],
    k: usize,
    explore_frac: f64,
    exclude_factor: f64,
    round: u32,
    rng: &mut Rng,
) -> Vec<NodeId> {
    registry.tick_round();
    // bench chronic stragglers: EWMA round time far above the median
    let median = registry.median_round_ms();
    if median > 0.0 && round > 0 {
        let stragglers: Vec<NodeId> = available
            .iter()
            .copied()
            .filter(|&id| {
                registry
                    .get(id)
                    .is_some_and(|r| r.ewma_round_ms > exclude_factor * median)
            })
            .collect();
        for id in stragglers {
            registry.bench(id, 3);
            log::debug!("selection: benching straggler {id} for 3 rounds");
        }
    }
    // eligible = available and not benched
    let eligible: Vec<NodeId> = available
        .iter()
        .copied()
        .filter(|&id| registry.get(id).map_or(true, |r| r.benched_for == 0))
        .collect();
    // if benching ate too much of the pool, fall back to all available
    let pool: &[NodeId] = if eligible.len() >= k {
        &eligible
    } else {
        available
    };

    let n_explore = ((k as f64) * explore_frac).round() as usize;
    let n_exploit = k - n_explore;

    // exploit: top-scoring clients
    let mut scored: Vec<(f64, NodeId)> = pool
        .iter()
        .map(|&id| {
            let s = registry.get(id).map_or(0.0, |r| r.score());
            (s, id)
        })
        .collect();
    scored.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    let mut selected: Vec<NodeId> = scored.iter().take(n_exploit).map(|&(_, id)| id).collect();

    // explore: uniform among the rest
    let rest: Vec<NodeId> = pool
        .iter()
        .copied()
        .filter(|id| !selected.contains(id))
        .collect();
    let picks = rng.sample_indices(rest.len(), n_explore.min(rest.len()));
    selected.extend(picks.into_iter().map(|i| rest[i]));

    // top up if exploration pool was short
    if selected.len() < k {
        for &(_, id) in scored.iter() {
            if selected.len() >= k {
                break;
            }
            if !selected.contains(&id) {
                selected.push(id);
            }
        }
    }
    selected.truncate(k);
    selected
}

#[cfg(test)]
mod tests {
    use super::super::registry::test_profile;
    use super::*;

    fn registry_with(n: u32) -> (ClientRegistry, Vec<NodeId>) {
        let mut reg = ClientRegistry::new();
        for i in 0..n {
            reg.register(i, test_profile(1.0, 1e9));
        }
        (reg, (0..n).collect())
    }

    fn cfg(policy: SelectionPolicy, k: usize) -> SelectionConfig {
        SelectionConfig {
            policy,
            clients_per_round: k,
        }
    }

    #[test]
    fn random_selects_k_distinct() {
        let (mut reg, avail) = registry_with(30);
        let mut rng = Rng::new(0);
        let sel = select_clients(&mut reg, &avail, &cfg(SelectionPolicy::Random, 10), 0, &mut rng);
        assert_eq!(sel.len(), 10);
        let mut s = sel.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
    }

    #[test]
    fn k_larger_than_pool_takes_all() {
        let (mut reg, avail) = registry_with(5);
        let mut rng = Rng::new(1);
        for policy in [SelectionPolicy::Random, SelectionPolicy::default()] {
            let sel = select_clients(&mut reg, &avail, &cfg(policy, 20), 0, &mut rng);
            assert_eq!(sel.len(), 5);
        }
    }

    #[test]
    fn adaptive_prefers_fast_reliable_clients() {
        let mut reg = ClientRegistry::new();
        // 0..5 fast, 5..10 slow
        for i in 0..10u32 {
            let speed = if i < 5 { 1.0 } else { 0.02 };
            reg.register(i, test_profile(speed, 1e9));
        }
        for r in 0..10 {
            for i in 0..10u32 {
                let t = if i < 5 { 100.0 } else { 5_000.0 };
                reg.report_success(i, r, t);
            }
        }
        let avail: Vec<NodeId> = (0..10).collect();
        let mut rng = Rng::new(2);
        // no exploration → pure exploitation for determinism
        let sel = select_clients(
            &mut reg,
            &avail,
            &cfg(
                SelectionPolicy::Adaptive {
                    explore_frac: 0.0,
                    exclude_factor: 100.0,
                },
                5,
            ),
            5,
            &mut rng,
        );
        assert_eq!(sel.len(), 5);
        assert!(sel.iter().all(|&id| id < 5), "picked slow clients: {sel:?}");
    }

    #[test]
    fn adaptive_benches_extreme_stragglers() {
        let mut reg = ClientRegistry::new();
        for i in 0..10u32 {
            reg.register(i, test_profile(1.0, 1e9));
        }
        for r in 0..5 {
            for i in 0..10u32 {
                let t = if i == 9 { 100_000.0 } else { 100.0 };
                reg.report_success(i, r, t);
            }
        }
        let avail: Vec<NodeId> = (0..10).collect();
        let mut rng = Rng::new(3);
        let sel = select_clients(
            &mut reg,
            &avail,
            &cfg(
                SelectionPolicy::Adaptive {
                    explore_frac: 0.0,
                    exclude_factor: 2.5,
                },
                9,
            ),
            5,
            &mut rng,
        );
        assert!(!sel.contains(&9), "straggler 9 selected: {sel:?}");
        assert!(reg.get(9).unwrap().benched_for > 0);
    }

    #[test]
    fn exploration_reaches_cold_clients() {
        let mut reg = ClientRegistry::new();
        for i in 0..20u32 {
            reg.register(i, test_profile(1.0, 1e9));
        }
        // clients 0..10 have glowing history; 10..20 are cold
        for r in 0..10 {
            for i in 0..10u32 {
                reg.report_success(i, r, 50.0);
            }
        }
        let avail: Vec<NodeId> = (0..20).collect();
        let mut hit_cold = false;
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let sel = select_clients(
                &mut reg,
                &avail,
                &cfg(
                    SelectionPolicy::Adaptive {
                        explore_frac: 0.4,
                        exclude_factor: 100.0,
                    },
                    10,
                ),
                1,
                &mut rng,
            );
            if sel.iter().any(|&id| id >= 10) {
                hit_cold = true;
                break;
            }
        }
        assert!(hit_cold, "exploration never sampled cold clients");
    }

    #[test]
    fn deterministic_given_seed() {
        let (mut r1, avail) = registry_with(30);
        let (mut r2, _) = registry_with(30);
        let c = cfg(SelectionPolicy::default(), 10);
        let a = select_clients(&mut r1, &avail, &c, 0, &mut Rng::new(9));
        let b = select_clients(&mut r2, &avail, &c, 0, &mut Rng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn empty_pool_returns_empty() {
        let (mut reg, _) = registry_with(5);
        let mut rng = Rng::new(0);
        let sel = select_clients(&mut reg, &[], &cfg(SelectionPolicy::Random, 3), 0, &mut rng);
        assert!(sel.is_empty());
    }
}
