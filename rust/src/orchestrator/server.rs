//! The orchestrator round loop (Algorithm 1).
//!
//! Generic over [`ServerTransport`], so the same loop drives in-process
//! simulations, multi-thread runs and multi-process TCP deployments.
//! Per round: select → broadcast → collect-with-deadline/partial-k →
//! aggregate → evaluate → convergence check. Fault tolerance: clients
//! that miss the deadline or vanish are simply skipped (their registry
//! reliability drops, which feeds back into selection).
//!
//! Scaling shape of one round (the two limits OmniFed and the
//! cross-facility FL literature identify on FL servers):
//!
//! * **Broadcast fan-out** — the round's model payload is serialized
//!   exactly once ([`crate::network::pre_encode_dense`]) and every
//!   per-client `RoundStart` shares the same `Arc`'d bytes; only the
//!   small per-client header (mask seed etc.) differs.
//! * **Collection memory** — arriving updates are folded straight into
//!   a [`StreamingAggregator`] (fold-then-normalize, see the
//!   `orchestrator::aggregate` module docs) and each decoded delta is
//!   freed on the spot, so collection holds O(P) state, not O(k·P).

use super::aggregate::{AggInput, StreamingAggregator};
use super::convergence::ConvergenceTracker;
use super::registry::ClientRegistry;
use super::selection::select_clients;
use crate::cluster::NodeId;
use crate::compress::{decompress, Encoded};
use crate::config::ExperimentConfig;
use crate::data::{Batch, Shard};
use crate::metrics::{RoundMetrics, TrainingReport};
use crate::network::{pre_encode_dense, Msg, ServerTransport, TrafficLog};
use crate::runtime::{EvalOut, ModelRuntime};
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::collections::HashSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Centralized evaluation harness (paper §5.3: accuracy on a
/// centralized held-out set).
pub struct EvalHarness {
    pub runtime: Box<dyn ModelRuntime>,
    pub shard: Shard,
}

impl EvalHarness {
    pub fn evaluate(&self, params: &[f32]) -> Result<EvalOut> {
        let b = self.runtime.eval_batch();
        let n_batches = (self.shard.n / b).max(1);
        let mut total = EvalOut {
            loss_sum: 0.0,
            correct: 0.0,
            n: 0,
        };
        for i in 0..n_batches {
            let mut x = Vec::with_capacity(b * self.shard.x_len);
            let mut y = Vec::with_capacity(b * self.shard.y_len);
            for k in 0..b {
                let idx = (i * b + k) % self.shard.n;
                let (ex, ey) = self.shard.example(idx);
                x.extend_from_slice(ex);
                y.extend_from_slice(ey);
            }
            total.merge(self.runtime.eval_step(params, &Batch { x, y, n: b })?);
        }
        Ok(total)
    }
}

/// Hooks for experiment harnesses (ablation logging etc.).
pub trait OrchestratorHooks {
    /// Called after each round with its metrics.
    fn on_round(&mut self, _m: &RoundMetrics) {}
}

/// Default no-op hooks.
pub struct NoHooks;
impl OrchestratorHooks for NoHooks {}

/// Outcome of a completed round.
#[derive(Debug)]
pub struct RoundOutcome {
    pub metrics: RoundMetrics,
    pub converged: bool,
}

/// The central orchestrator.
pub struct Orchestrator<T: ServerTransport> {
    cfg: ExperimentConfig,
    transport: T,
    registry: ClientRegistry,
    traffic: Arc<TrafficLog>,
    eval: Option<EvalHarness>,
    rng: Rng,
    params: Vec<f32>,
    model_version: u32,
    /// Evaluate every N rounds (1 = every round).
    pub eval_every: u32,
}

impl<T: ServerTransport> Orchestrator<T> {
    pub fn new(
        cfg: ExperimentConfig,
        transport: T,
        traffic: Arc<TrafficLog>,
        initial_params: Vec<f32>,
        eval: Option<EvalHarness>,
    ) -> Self {
        let rng = Rng::new(cfg.seed ^ 0x0C5);
        Orchestrator {
            cfg,
            transport,
            registry: ClientRegistry::new(),
            traffic,
            eval,
            rng,
            params: initial_params,
            model_version: 0,
            eval_every: 1,
        }
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    pub fn registry(&self) -> &ClientRegistry {
        &self.registry
    }

    /// Phase 0: absorb registrations until `expected` clients joined or
    /// `timeout` passed. Returns the number registered.
    pub fn wait_for_clients(&mut self, expected: usize, timeout: Duration) -> Result<usize> {
        let deadline = Instant::now() + timeout;
        while self.registry.len() < expected {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let step = (deadline - now).min(Duration::from_millis(100));
            if let Some((from, msg)) = self.transport.recv_timeout(step)? {
                self.handle_control(from, msg)?;
            }
        }
        log::info!(
            "orchestrator: {} / {expected} clients registered",
            self.registry.len()
        );
        Ok(self.registry.len())
    }

    fn handle_control(&mut self, from: NodeId, msg: Msg) -> Result<()> {
        match msg {
            Msg::Register { client, profile } => {
                if client != from {
                    log::warn!("register id mismatch: envelope {from}, body {client}");
                }
                self.registry.register(client, profile);
                self.transport
                    .send_to(client, &Msg::RegisterAck { client })?;
            }
            Msg::Heartbeat { .. } => {}
            other => {
                log::debug!("orchestrator: ignoring {} outside round", other.name());
            }
        }
        Ok(())
    }

    /// Run one round `r`. Blocking; returns metrics + convergence info.
    pub fn run_round(
        &mut self,
        round: u32,
        tracker: &mut ConvergenceTracker,
    ) -> Result<RoundOutcome> {
        let t_round = Instant::now();
        let available = self.registry.ids();
        if available.is_empty() {
            bail!("round {round}: no clients registered");
        }
        let mut round_rng = self.rng.fork(round as u64);
        let selected = select_clients(
            &mut self.registry,
            &available,
            &self.cfg.selection,
            round,
            &mut round_rng,
        );
        if selected.is_empty() {
            bail!("round {round}: selection returned no clients");
        }
        log::debug!("round {round}: selected {selected:?}");

        let deadline_ms = self.cfg.straggler.deadline_ms.unwrap_or(3_600_000);
        // Algorithm 1 line 5: broadcast the global model. The payload
        // is serialized exactly once per round; each send only clones
        // the Arc (inproc) or re-writes the shared bytes (tcp).
        let shared_params = Encoded::PreEncoded(pre_encode_dense(&self.params));
        for &c in &selected {
            let msg = Msg::RoundStart {
                round,
                model_version: self.model_version,
                deadline_ms,
                lr: self.cfg.train.lr,
                mu: self.cfg.aggregation.mu(),
                local_epochs: self.cfg.train.local_epochs as u32,
                params: shared_params.clone(),
                mask_seed: mask_seed(self.cfg.seed, round, c),
                compression: self.cfg.compression,
            };
            if let Err(e) = self.transport.send_to(c, &msg) {
                log::warn!("round {round}: broadcast to {c} failed: {e}");
            }
        }
        drop(shared_params);

        // Algorithm 1 lines 6–10: collect updates, folding each one
        // into the streaming aggregator as it arrives — at most one
        // decoded delta is alive at any time (O(P), not O(k·P))
        let partial_k = self
            .cfg
            .straggler
            .partial_k
            .unwrap_or(usize::MAX)
            .min(selected.len());
        let deadline = t_round + Duration::from_millis(deadline_ms);
        let selected_set: HashSet<NodeId> = selected.iter().copied().collect();
        let mut reported: HashSet<NodeId> = HashSet::with_capacity(selected.len());
        let mut agg = StreamingAggregator::new(self.params.len(), self.cfg.aggregation);
        while reported.len() < selected.len() && agg.n_updates() < partial_k {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let step = (deadline - now).min(Duration::from_millis(50));
            let Some((from, msg)) = self.transport.recv_timeout(step)? else {
                continue;
            };
            match msg {
                Msg::Update {
                    round: r,
                    client,
                    delta,
                    stats,
                } => {
                    if r != round {
                        log::debug!("stale update from {client} for round {r}");
                        continue;
                    }
                    if !selected_set.contains(&client) || reported.contains(&client) {
                        continue;
                    }
                    match decompress(&delta, self.params.len()) {
                        Ok(dense) => {
                            agg.fold(&AggInput {
                                client,
                                delta: dense,
                                n_samples: stats.n_samples,
                                train_loss: stats.train_loss,
                                update_var: stats.update_var,
                            })?;
                            reported.insert(client);
                            self.registry.report_success(
                                client,
                                round,
                                t_round.elapsed().as_secs_f64() * 1e3,
                            );
                        }
                        Err(e) => {
                            log::warn!("round {round}: bad update from {client}: {e}");
                            self.registry.report_failure(client, round);
                            reported.insert(client);
                        }
                    }
                }
                other => self.handle_control(from, other)?,
            }
        }

        // fault accounting: selected clients that never reported
        let mut deadline_misses = 0u32;
        for &c in &selected {
            if !reported.contains(&c) {
                self.registry.report_failure(c, round);
                deadline_misses += 1;
            }
        }

        // Algorithm 1 lines 11–12: finalize the aggregate (one
        // normalization scalar) + update the global model. On a
        // zero-update round the old model is kept as-is — no clone.
        let n_updates = agg.n_updates();
        let (new_params, mean_loss) = if n_updates == 0 {
            log::warn!("round {round}: zero updates — keeping old model");
            (None, f64::NAN)
        } else {
            let out = agg.finalize(&self.params)?;
            (Some(out.new_params), out.mean_train_loss)
        };
        let current: &[f32] = new_params.as_deref().unwrap_or(&self.params);

        // evaluate (centralized, §5.3); eval_every == 0 means never
        let do_eval = self.eval_every != 0 && round % self.eval_every == 0;
        let (eval_accuracy, eval_loss) = if do_eval {
            match &self.eval {
                Some(h) => {
                    let e = h.evaluate(current)?;
                    (Some(e.accuracy()), Some(e.mean_loss()))
                }
                None => (None, None),
            }
        } else {
            (None, None)
        };

        let converged = tracker.update(&self.params, current, eval_accuracy);
        let model_delta = tracker.last_delta();
        if let Some(p) = new_params {
            self.params = p;
        }
        self.model_version = round + 1;

        // notify round end (selected only; broadcast would also be fine)
        for &c in &selected {
            let _ = self.transport.send_to(
                c,
                &Msg::RoundEnd {
                    round,
                    model_version: self.model_version,
                },
            );
        }

        let (bytes_down, bytes_up) = self.traffic.round(round);
        Ok(RoundOutcome {
            metrics: RoundMetrics {
                round,
                selected: selected.len() as u32,
                reported: n_updates as u32,
                dropped: (selected.len() - reported.len()) as u32,
                deadline_misses,
                train_loss: mean_loss,
                eval_accuracy,
                eval_loss,
                duration_s: t_round.elapsed().as_secs_f64(),
                bytes_down,
                bytes_up,
                model_delta,
            },
            converged,
        })
    }

    /// Full training run (Algorithm 1). Consumes registrations first if
    /// `wait_for` is given.
    pub fn run(
        &mut self,
        wait_for: Option<(usize, Duration)>,
        hooks: &mut dyn OrchestratorHooks,
    ) -> Result<TrainingReport> {
        if let Some((n, timeout)) = wait_for {
            let got = self.wait_for_clients(n, timeout)?;
            if got == 0 {
                bail!("no clients registered");
            }
        }
        let mut report = TrainingReport::new(&self.cfg.name);
        let mut tracker = ConvergenceTracker::new(
            self.cfg.train.converge_eps,
            self.cfg.train.converge_patience,
            self.cfg.train.target_accuracy,
        );
        for round in 0..self.cfg.train.rounds as u32 {
            let outcome = self.run_round(round, &mut tracker)?;
            log::info!(
                "round {round}: loss={:.4} acc={} reported={}/{} dur={:.2}s",
                outcome.metrics.train_loss,
                outcome
                    .metrics
                    .eval_accuracy
                    .map_or("-".into(), |a| format!("{:.3}", a)),
                outcome.metrics.reported,
                outcome.metrics.selected,
                outcome.metrics.duration_s,
            );
            hooks.on_round(&outcome.metrics);
            let converged = outcome.converged;
            report.push(outcome.metrics);
            if converged {
                report.converged_at = Some(round);
                log::info!("converged at round {round}");
                break;
            }
        }
        if let Some(t) = self.cfg.train.target_accuracy {
            report.target_accuracy_at = report.rounds_to_accuracy(t);
        }
        // Algorithm 1 done: release the fleet
        for c in self.transport.connected() {
            let _ = self.transport.send_to(c, &Msg::Shutdown);
        }
        Ok(report)
    }
}

/// Federated-dropout mask seed for (experiment, round, client) — the
/// client derives the identical mask from this.
pub fn mask_seed(exp_seed: u64, round: u32, client: NodeId) -> u64 {
    exp_seed ^ ((round as u64) << 32 | client as u64).wrapping_mul(0x2545F4914F6CDD1D)
}

#[cfg(test)]
mod tests {
    use super::super::registry::test_profile;
    use super::*;
    use crate::config::SelectionPolicy;
    use crate::network::inproc::{InprocClient, InprocHub, InprocServer};
    use crate::network::{ClientTransport, LinkShaper, UpdateStats};

    #[test]
    fn mask_seed_unique_per_round_and_client() {
        let mut seen = std::collections::HashSet::new();
        for r in 0..100 {
            for c in 0..60 {
                assert!(seen.insert(mask_seed(7, r, c)));
            }
        }
        assert_eq!(mask_seed(7, 3, 4), mask_seed(7, 3, 4));
        assert_ne!(mask_seed(7, 3, 4), mask_seed(8, 3, 4));
    }

    fn test_cfg(k: usize) -> ExperimentConfig {
        let mut cfg = crate::config::presets::quickstart();
        cfg.selection.clients_per_round = k;
        cfg.selection.policy = SelectionPolicy::Random;
        cfg.straggler.deadline_ms = Some(400);
        cfg.straggler.partial_k = None;
        cfg
    }

    /// n registered dummy clients + an orchestrator over inproc, with
    /// the RegisterAck handshake already drained from every client.
    fn federation(
        cfg: ExperimentConfig,
        n: u32,
        initial: Vec<f32>,
    ) -> (Orchestrator<InprocServer>, Vec<InprocClient>) {
        let traffic = Arc::new(TrafficLog::new());
        let hub = InprocHub::new(traffic.clone());
        let clients: Vec<InprocClient> = (0..n)
            .map(|i| hub.add_client(i, LinkShaper::unshaped()))
            .collect();
        let mut orch = Orchestrator::new(cfg, hub.server(), traffic, initial, None);
        for c in &clients {
            c.send(&Msg::Register {
                client: c.id(),
                profile: test_profile(1.0, 1e9),
            })
            .unwrap();
        }
        assert_eq!(
            orch.wait_for_clients(n as usize, Duration::from_secs(5)).unwrap(),
            n as usize
        );
        for c in &clients {
            let ack = c.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
            assert!(matches!(ack, Msg::RegisterAck { .. }));
        }
        (orch, clients)
    }

    fn update(client: NodeId, round: u32, delta: Vec<f32>) -> Msg {
        Msg::Update {
            round,
            client,
            delta: Encoded::Dense(delta),
            stats: UpdateStats {
                n_samples: 100,
                train_loss: 1.0,
                steps: 1,
                compute_ms: 1.0,
                update_var: 0.0,
            },
        }
    }

    fn tracker() -> ConvergenceTracker {
        ConvergenceTracker::new(1e-12, 1000, None)
    }

    #[test]
    fn eval_every_zero_means_never_evaluate() {
        // regression: `round % eval_every` used to divide by zero
        let (mut orch, clients) = federation(test_cfg(1), 1, vec![0f32; 4]);
        orch.eval_every = 0;
        clients[0].send(&update(0, 0, vec![1.0; 4])).unwrap();
        let out = orch.run_round(0, &mut tracker()).unwrap();
        assert_eq!(out.metrics.reported, 1);
        assert!(out.metrics.eval_accuracy.is_none());
    }

    #[test]
    fn stale_round_updates_are_ignored() {
        let (mut orch, clients) = federation(test_cfg(1), 1, vec![0f32; 3]);
        clients[0].send(&update(0, 7, vec![9.0; 3])).unwrap(); // stale
        clients[0].send(&update(0, 0, vec![2.0; 3])).unwrap();
        let out = orch.run_round(0, &mut tracker()).unwrap();
        assert_eq!(out.metrics.reported, 1);
        assert_eq!(orch.params(), &[2.0f32; 3][..]);
    }

    #[test]
    fn duplicate_updates_from_same_client_first_wins() {
        let (mut orch, clients) = federation(test_cfg(2), 2, vec![0f32; 3]);
        clients[0].send(&update(0, 0, vec![2.0; 3])).unwrap();
        clients[0].send(&update(0, 0, vec![100.0; 3])).unwrap(); // dup
        clients[1].send(&update(1, 0, vec![4.0; 3])).unwrap();
        let out = orch.run_round(0, &mut tracker()).unwrap();
        assert_eq!(out.metrics.reported, 2);
        // (100·2 + 100·4) / 200 = 3; the duplicate never contributes
        assert_eq!(orch.params(), &[3.0f32; 3][..]);
    }

    #[test]
    fn updates_from_unselected_clients_are_ignored() {
        let (mut orch, clients) = federation(test_cfg(1), 2, vec![0f32; 3]);
        clients[0].send(&update(0, 0, vec![1.0; 3])).unwrap();
        clients[1].send(&update(1, 0, vec![2.0; 3])).unwrap();
        let out = orch.run_round(0, &mut tracker()).unwrap();
        assert_eq!(out.metrics.selected, 1);
        assert_eq!(out.metrics.reported, 1);
        // only the selected client (the one that got a RoundStart)
        // contributed to the aggregate
        let mut sel = None;
        for c in &clients {
            if let Some(Msg::RoundStart { .. }) =
                c.recv_timeout(Duration::from_millis(100)).unwrap()
            {
                sel = Some(c.id());
            }
        }
        let want = if sel.unwrap() == 0 { 1.0f32 } else { 2.0f32 };
        assert_eq!(orch.params(), &[want; 3][..]);
    }

    #[test]
    fn partial_k_cuts_off_in_arrival_order() {
        let mut cfg = test_cfg(3);
        cfg.straggler.partial_k = Some(2);
        let (mut orch, clients) = federation(cfg, 3, vec![0f32; 3]);
        clients[0].send(&update(0, 0, vec![2.0; 3])).unwrap();
        clients[1].send(&update(1, 0, vec![4.0; 3])).unwrap();
        clients[2].send(&update(2, 0, vec![1000.0; 3])).unwrap(); // too late
        let out = orch.run_round(0, &mut tracker()).unwrap();
        assert_eq!(out.metrics.selected, 3);
        assert_eq!(out.metrics.reported, 2);
        assert_eq!(out.metrics.dropped, 1);
        assert_eq!(out.metrics.deadline_misses, 1);
        // first two arrivals only: (100·2 + 100·4) / 200 = 3
        assert_eq!(orch.params(), &[3.0f32; 3][..]);
    }

    #[test]
    fn broadcast_payload_is_encoded_once_and_shared() {
        let (mut orch, clients) = federation(test_cfg(3), 3, vec![0.5f32; 3]);
        for c in &clients {
            c.send(&update(c.id(), 0, vec![1.0; 3])).unwrap();
        }
        orch.run_round(0, &mut tracker()).unwrap();
        let mut arcs = Vec::new();
        for c in &clients {
            match c.recv_timeout(Duration::from_secs(1)).unwrap().unwrap() {
                Msg::RoundStart { params, .. } => match params {
                    Encoded::PreEncoded(p) => {
                        let dec = decompress(&Encoded::PreEncoded(p.clone()), 3).unwrap();
                        assert_eq!(dec, vec![0.5f32; 3]);
                        arcs.push(p.bytes);
                    }
                    other => panic!("expected shared payload, got {other:?}"),
                },
                other => panic!("expected RoundStart, got {}", other.name()),
            }
        }
        // one serialization per round: all k sends share the same bytes
        assert!(Arc::ptr_eq(&arcs[0], &arcs[1]));
        assert!(Arc::ptr_eq(&arcs[1], &arcs[2]));
    }

    #[test]
    fn zero_update_round_keeps_model_unchanged() {
        let (mut orch, _clients) = federation(test_cfg(1), 1, vec![1.5f32; 3]);
        let out = orch.run_round(0, &mut tracker()).unwrap();
        assert_eq!(out.metrics.reported, 0);
        assert_eq!(out.metrics.deadline_misses, 1);
        assert!(out.metrics.train_loss.is_nan());
        assert_eq!(orch.params(), &[1.5f32; 3][..]);
    }
}
