//! The orchestrator round loop (Algorithm 1).
//!
//! Generic over [`ServerTransport`], so the same loop drives in-process
//! simulations, multi-thread runs and multi-process TCP deployments.
//! Per round: select → broadcast → collect-with-deadline/partial-k →
//! aggregate → evaluate → convergence check. Fault tolerance: clients
//! that miss the deadline or vanish are simply skipped (their registry
//! reliability drops, which feeds back into selection).

use super::aggregate::{aggregate, AggInput};
use super::convergence::ConvergenceTracker;
use super::registry::ClientRegistry;
use super::selection::select_clients;
use crate::cluster::NodeId;
use crate::compress::{decompress, Encoded};
use crate::config::ExperimentConfig;
use crate::data::{Batch, Shard};
use crate::metrics::{RoundMetrics, TrainingReport};
use crate::network::{Msg, ServerTransport, TrafficLog};
use crate::runtime::{EvalOut, ModelRuntime};
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Centralized evaluation harness (paper §5.3: accuracy on a
/// centralized held-out set).
pub struct EvalHarness {
    pub runtime: Box<dyn ModelRuntime>,
    pub shard: Shard,
}

impl EvalHarness {
    pub fn evaluate(&self, params: &[f32]) -> Result<EvalOut> {
        let b = self.runtime.eval_batch();
        let n_batches = (self.shard.n / b).max(1);
        let mut total = EvalOut {
            loss_sum: 0.0,
            correct: 0.0,
            n: 0,
        };
        for i in 0..n_batches {
            let mut x = Vec::with_capacity(b * self.shard.x_len);
            let mut y = Vec::with_capacity(b * self.shard.y_len);
            for k in 0..b {
                let idx = (i * b + k) % self.shard.n;
                let (ex, ey) = self.shard.example(idx);
                x.extend_from_slice(ex);
                y.extend_from_slice(ey);
            }
            total.merge(self.runtime.eval_step(params, &Batch { x, y, n: b })?);
        }
        Ok(total)
    }
}

/// Hooks for experiment harnesses (ablation logging etc.).
pub trait OrchestratorHooks {
    /// Called after each round with its metrics.
    fn on_round(&mut self, _m: &RoundMetrics) {}
}

/// Default no-op hooks.
pub struct NoHooks;
impl OrchestratorHooks for NoHooks {}

/// Outcome of a completed round.
#[derive(Debug)]
pub struct RoundOutcome {
    pub metrics: RoundMetrics,
    pub converged: bool,
}

/// The central orchestrator.
pub struct Orchestrator<T: ServerTransport> {
    cfg: ExperimentConfig,
    transport: T,
    registry: ClientRegistry,
    traffic: Arc<TrafficLog>,
    eval: Option<EvalHarness>,
    rng: Rng,
    params: Vec<f32>,
    model_version: u32,
    /// Evaluate every N rounds (1 = every round).
    pub eval_every: u32,
}

impl<T: ServerTransport> Orchestrator<T> {
    pub fn new(
        cfg: ExperimentConfig,
        transport: T,
        traffic: Arc<TrafficLog>,
        initial_params: Vec<f32>,
        eval: Option<EvalHarness>,
    ) -> Self {
        let rng = Rng::new(cfg.seed ^ 0x0C5);
        Orchestrator {
            cfg,
            transport,
            registry: ClientRegistry::new(),
            traffic,
            eval,
            rng,
            params: initial_params,
            model_version: 0,
            eval_every: 1,
        }
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    pub fn registry(&self) -> &ClientRegistry {
        &self.registry
    }

    /// Phase 0: absorb registrations until `expected` clients joined or
    /// `timeout` passed. Returns the number registered.
    pub fn wait_for_clients(&mut self, expected: usize, timeout: Duration) -> Result<usize> {
        let deadline = Instant::now() + timeout;
        while self.registry.len() < expected {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let step = (deadline - now).min(Duration::from_millis(100));
            if let Some((from, msg)) = self.transport.recv_timeout(step)? {
                self.handle_control(from, msg)?;
            }
        }
        log::info!(
            "orchestrator: {} / {expected} clients registered",
            self.registry.len()
        );
        Ok(self.registry.len())
    }

    fn handle_control(&mut self, from: NodeId, msg: Msg) -> Result<()> {
        match msg {
            Msg::Register { client, profile } => {
                if client != from {
                    log::warn!("register id mismatch: envelope {from}, body {client}");
                }
                self.registry.register(client, profile);
                self.transport
                    .send_to(client, &Msg::RegisterAck { client })?;
            }
            Msg::Heartbeat { .. } => {}
            other => {
                log::debug!("orchestrator: ignoring {} outside round", other.name());
            }
        }
        Ok(())
    }

    /// Run one round `r`. Blocking; returns metrics + convergence info.
    pub fn run_round(&mut self, round: u32, tracker: &mut ConvergenceTracker) -> Result<RoundOutcome> {
        let t_round = Instant::now();
        let available = self.registry.ids();
        if available.is_empty() {
            bail!("round {round}: no clients registered");
        }
        let mut round_rng = self.rng.fork(round as u64);
        let selected = select_clients(
            &mut self.registry,
            &available,
            &self.cfg.selection,
            round,
            &mut round_rng,
        );
        if selected.is_empty() {
            bail!("round {round}: selection returned no clients");
        }
        log::debug!("round {round}: selected {selected:?}");

        let deadline_ms = self.cfg.straggler.deadline_ms.unwrap_or(3_600_000);
        // Algorithm 1 line 5: broadcast the global model
        for &c in &selected {
            let msg = Msg::RoundStart {
                round,
                model_version: self.model_version,
                deadline_ms,
                lr: self.cfg.train.lr,
                mu: self.cfg.aggregation.mu(),
                local_epochs: self.cfg.train.local_epochs as u32,
                params: Encoded::Dense(self.params.clone()),
                mask_seed: mask_seed(self.cfg.seed, round, c),
                compression: self.cfg.compression,
            };
            if let Err(e) = self.transport.send_to(c, &msg) {
                log::warn!("round {round}: broadcast to {c} failed: {e}");
            }
        }

        // Algorithm 1 lines 6–10: collect updates
        let partial_k = self
            .cfg
            .straggler
            .partial_k
            .unwrap_or(usize::MAX)
            .min(selected.len());
        let deadline = t_round + Duration::from_millis(deadline_ms);
        let mut inputs: Vec<AggInput> = Vec::with_capacity(selected.len());
        let mut reported: Vec<NodeId> = Vec::new();
        while reported.len() < selected.len() && inputs.len() < partial_k {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let step = (deadline - now).min(Duration::from_millis(50));
            let Some((from, msg)) = self.transport.recv_timeout(step)? else {
                continue;
            };
            match msg {
                Msg::Update {
                    round: r,
                    client,
                    delta,
                    stats,
                } => {
                    if r != round {
                        log::debug!("stale update from {client} for round {r}");
                        continue;
                    }
                    if !selected.contains(&client) || reported.contains(&client) {
                        continue;
                    }
                    match decompress(&delta, self.params.len()) {
                        Ok(dense) => {
                            inputs.push(AggInput {
                                client,
                                delta: dense,
                                n_samples: stats.n_samples,
                                train_loss: stats.train_loss,
                                update_var: stats.update_var,
                            });
                            reported.push(client);
                            self.registry.report_success(
                                client,
                                round,
                                t_round.elapsed().as_secs_f64() * 1e3,
                            );
                        }
                        Err(e) => {
                            log::warn!("round {round}: bad update from {client}: {e}");
                            self.registry.report_failure(client, round);
                            reported.push(client);
                        }
                    }
                }
                other => self.handle_control(from, other)?,
            }
        }

        // fault accounting: selected clients that never reported
        let mut deadline_misses = 0u32;
        for &c in &selected {
            if !reported.contains(&c) {
                self.registry.report_failure(c, round);
                deadline_misses += 1;
            }
        }

        // Algorithm 1 lines 11–12: aggregate + update global model
        let old_params = std::mem::take(&mut self.params);
        let (new_params, mean_loss) = if inputs.is_empty() {
            log::warn!("round {round}: zero updates — keeping old model");
            (old_params.clone(), f64::NAN)
        } else {
            let out = aggregate(&old_params, &inputs, self.cfg.aggregation)?;
            (out.new_params, out.mean_train_loss)
        };

        // evaluate (centralized, §5.3)
        let (eval_accuracy, eval_loss) = if round % self.eval_every == 0 {
            match &self.eval {
                Some(h) => {
                    let e = h.evaluate(&new_params)?;
                    (Some(e.accuracy()), Some(e.mean_loss()))
                }
                None => (None, None),
            }
        } else {
            (None, None)
        };

        let converged = tracker.update(&old_params, &new_params, eval_accuracy);
        let model_delta = tracker.last_delta();
        self.params = new_params;
        self.model_version = round + 1;

        // notify round end (selected only; broadcast would also be fine)
        for &c in &selected {
            let _ = self.transport.send_to(
                c,
                &Msg::RoundEnd {
                    round,
                    model_version: self.model_version,
                },
            );
        }

        let (bytes_down, bytes_up) = self.traffic.round(round);
        Ok(RoundOutcome {
            metrics: RoundMetrics {
                round,
                selected: selected.len() as u32,
                reported: inputs.len() as u32,
                dropped: (selected.len() - reported.len()) as u32,
                deadline_misses,
                train_loss: mean_loss,
                eval_accuracy,
                eval_loss,
                duration_s: t_round.elapsed().as_secs_f64(),
                bytes_down,
                bytes_up,
                model_delta,
            },
            converged,
        })
    }

    /// Full training run (Algorithm 1). Consumes registrations first if
    /// `wait_for` is given.
    pub fn run(
        &mut self,
        wait_for: Option<(usize, Duration)>,
        hooks: &mut dyn OrchestratorHooks,
    ) -> Result<TrainingReport> {
        if let Some((n, timeout)) = wait_for {
            let got = self.wait_for_clients(n, timeout)?;
            if got == 0 {
                bail!("no clients registered");
            }
        }
        let mut report = TrainingReport::new(&self.cfg.name);
        let mut tracker = ConvergenceTracker::new(
            self.cfg.train.converge_eps,
            self.cfg.train.converge_patience,
            self.cfg.train.target_accuracy,
        );
        for round in 0..self.cfg.train.rounds as u32 {
            let outcome = self.run_round(round, &mut tracker)?;
            log::info!(
                "round {round}: loss={:.4} acc={} reported={}/{} dur={:.2}s",
                outcome.metrics.train_loss,
                outcome
                    .metrics
                    .eval_accuracy
                    .map_or("-".into(), |a| format!("{:.3}", a)),
                outcome.metrics.reported,
                outcome.metrics.selected,
                outcome.metrics.duration_s,
            );
            hooks.on_round(&outcome.metrics);
            let converged = outcome.converged;
            report.push(outcome.metrics);
            if converged {
                report.converged_at = Some(round);
                log::info!("converged at round {round}");
                break;
            }
        }
        if let Some(t) = self.cfg.train.target_accuracy {
            report.target_accuracy_at = report.rounds_to_accuracy(t);
        }
        // Algorithm 1 done: release the fleet
        for c in self.transport.connected() {
            let _ = self.transport.send_to(c, &Msg::Shutdown);
        }
        Ok(report)
    }
}

/// Federated-dropout mask seed for (experiment, round, client) — the
/// client derives the identical mask from this.
pub fn mask_seed(exp_seed: u64, round: u32, client: NodeId) -> u64 {
    exp_seed ^ ((round as u64) << 32 | client as u64).wrapping_mul(0x2545F4914F6CDD1D)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_seed_unique_per_round_and_client() {
        let mut seen = std::collections::HashSet::new();
        for r in 0..100 {
            for c in 0..60 {
                assert!(seen.insert(mask_seed(7, r, c)));
            }
        }
        assert_eq!(mask_seed(7, 3, 4), mask_seed(7, 3, 4));
        assert_ne!(mask_seed(7, 3, 4), mask_seed(8, 3, 4));
    }
}
