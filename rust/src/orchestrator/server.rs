//! The orchestrator round engine: synchronous rounds (Algorithm 1) and
//! buffered-async aggregation (FedBuff), selected by
//! [`crate::config::RoundMode`].
//!
//! Generic over [`ServerTransport`], so the same loop drives in-process
//! simulations, multi-thread runs and multi-process TCP deployments.
//! Orchestrators are assembled with [`OrchestratorBuilder`]
//! (`Orchestrator::builder(cfg).transport(..).strategy(..)…build()`),
//! which defaults the aggregation strategy and server optimizer from
//! the config's registry names. [`Orchestrator::run`] dispatches on the
//! config's round mode; [`Orchestrator::run_round`] is the synchronous
//! engine's single-round entry point.
//!
//! # Buffered-async mode (`--round-mode async_fedbuff[:k[:α[:s_max]]]`)
//!
//! In [`crate::config::RoundMode::BufferedAsync`] the server never
//! waits for a cohort: it keeps every reachable client training, folds
//! each update the moment it arrives — *regardless of round tag* —
//! weighted by `w_c · discount(staleness)` where `staleness` is how
//! many commits the client's base model is behind
//! ([`crate::config::StalenessFn`]), and commits a new model version
//! every `buffer_k` folds. After each fold the reporting client is
//! immediately handed the current model, so stragglers are absorbed as
//! stale-but-useful contributions instead of being dropped at a
//! deadline. Updates staler than `max_staleness` are discarded.
//! `cfg.train.rounds` counts commits; `straggler.deadline_ms` bounds
//! how long one commit may wait before closing (possibly empty, model
//! unchanged). Requires a streaming aggregation strategy — order
//! statistics cannot discount individual updates
//! ([`crate::config::validate`] enforces this for config-selected
//! strategies, [`Orchestrator::run`] for injected ones). The fused
//! O(nnz) decode→fold ingest is the same
//! [`RoundAggregator::fold_view_scaled`] path the sync engine uses
//! with scale 1.
//!
//! Per round, [`Orchestrator::run_round`] runs three phases:
//!
//! 1. **broadcast** — select clients, serialize the model payload
//!    exactly once ([`crate::network::pre_encode_dense`]) and share
//!    the `Arc`'d bytes across every per-client `RoundStart`. A failed
//!    send excludes that client from the expected-reporter count (it
//!    never got the model, so waiting for it would just burn the
//!    deadline) — it is counted in `dropped`, not `deadline_misses`.
//! 2. **collect** — fold arriving updates into a
//!    [`RoundAggregator`] under the deadline / partial-k stopping
//!    rule. Ingest is fused: each update folds straight from its
//!    encoded form via [`crate::compress::DecodedView`] (O(nnz) per
//!    update, no dense materialization); streaming strategies hold
//!    O(P) state, while buffered (order-statistic) strategies densify
//!    into pooled scratch buffers they keep alive until finalize (see
//!    `orchestrator::strategy`).
//! 3. **finalize** — normalize into Δ_agg, apply the server optimizer
//!    `M_{r+1} = opt(M_r, Δ_agg)`, evaluate, track convergence.
//!
//! Fault tolerance: clients that miss the deadline or vanish are
//! simply skipped (their registry reliability drops, which feeds back
//! into selection).

use super::aggregate::default_ingest_shards;
use super::convergence::ConvergenceTracker;
use super::hierarchy::FoldCore;
use super::planner::{self, CohortPlanner, DispatchPlan, PlanContext, RoundPlan};
use super::registry::ClientRegistry;
use super::strategy::{registry as strategy_registry, AggStrategy, RoundAggregator, ServerOpt};
use crate::cluster::NodeId;
use crate::compress::Encoded;
use crate::config::{ExperimentConfig, RoundMode, StalenessFn};
use crate::data::{Batch, Shard};
use crate::metrics::{staleness_summary, RoundMetrics, TrainingReport};
use crate::network::{pre_encode_dense, Msg, ServerTransport, TrafficLog, UpdateStats};
use crate::runtime::{EvalOut, ModelRuntime};
use crate::telemetry::{self, ControlCmd, ControlPlane, Counter, Gauge, Histogram};
use crate::util::parallel::{resolve_ingest_threads, ShardPool};
use crate::util::rng::Rng;
use crate::util::scratch::ScratchPool;
use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Centralized evaluation harness (paper §5.3: accuracy on a
/// centralized held-out set).
pub struct EvalHarness {
    pub runtime: Box<dyn ModelRuntime>,
    pub shard: Shard,
}

impl EvalHarness {
    pub fn evaluate(&self, params: &[f32]) -> Result<EvalOut> {
        let b = self.runtime.eval_batch();
        let n_batches = (self.shard.n / b).max(1);
        let mut total = EvalOut {
            loss_sum: 0.0,
            correct: 0.0,
            n: 0,
        };
        for i in 0..n_batches {
            let mut x = Vec::with_capacity(b * self.shard.x_len);
            let mut y = Vec::with_capacity(b * self.shard.y_len);
            for k in 0..b {
                let idx = (i * b + k) % self.shard.n;
                let (ex, ey) = self.shard.example(idx);
                x.extend_from_slice(ex);
                y.extend_from_slice(ey);
            }
            total.merge(self.runtime.eval_step(params, &Batch { x, y, n: b })?);
        }
        Ok(total)
    }
}

/// Hooks for experiment harnesses (ablation logging, live dashboards).
pub trait OrchestratorHooks {
    /// Called once per round, after selection and before broadcast.
    fn on_round_start(&mut self, _round: u32, _selected: &[NodeId]) {}

    /// Called for every client update the aggregator accepted, as it
    /// arrives (rejected updates — undecodable or refused by the
    /// strategy — are not reported here).
    fn on_update(&mut self, _round: u32, _client: NodeId, _stats: &UpdateStats) {}

    /// Called after each round with its metrics.
    fn on_round(&mut self, _m: &RoundMetrics) {}
}

/// Default no-op hooks.
pub struct NoHooks;
impl OrchestratorHooks for NoHooks {}

/// Outcome of a completed round.
#[derive(Debug)]
pub struct RoundOutcome {
    pub metrics: RoundMetrics,
    pub converged: bool,
}

/// Typed builder for [`Orchestrator`] — the one place orchestration
/// policy is assembled. `transport` and `initial_params` are required;
/// everything else defaults from the config (`strategy` / `server_opt`
/// from the registry names in `cfg.aggregation` / `cfg.server_opt`,
/// fresh traffic log, evaluation every round).
pub struct OrchestratorBuilder<T: ServerTransport> {
    cfg: ExperimentConfig,
    transport: Option<T>,
    traffic: Option<Arc<TrafficLog>>,
    initial_params: Option<Vec<f32>>,
    eval: Option<EvalHarness>,
    eval_every: u32,
    strategy: Option<Arc<dyn AggStrategy>>,
    server_opt: Option<Box<dyn ServerOpt>>,
    planner: Option<Box<dyn CohortPlanner>>,
    control: Option<Arc<ControlPlane>>,
}

impl<T: ServerTransport> OrchestratorBuilder<T> {
    pub fn new(cfg: ExperimentConfig) -> Self {
        OrchestratorBuilder {
            cfg,
            transport: None,
            traffic: None,
            initial_params: None,
            eval: None,
            eval_every: 1,
            strategy: None,
            server_opt: None,
            planner: None,
            control: None,
        }
    }

    /// Server endpoint the round loop drives (required).
    pub fn transport(mut self, transport: T) -> Self {
        self.transport = Some(transport);
        self
    }

    /// Traffic accounting shared with the transport (defaults to a
    /// fresh log — pass the transport's log to see real byte counts).
    pub fn traffic(mut self, traffic: Arc<TrafficLog>) -> Self {
        self.traffic = Some(traffic);
        self
    }

    /// Initial global model `M_0` (required).
    pub fn initial_params(mut self, params: Vec<f32>) -> Self {
        self.initial_params = Some(params);
        self
    }

    /// Centralized evaluation harness (optional; without one, rounds
    /// report no accuracy).
    pub fn eval(mut self, eval: EvalHarness) -> Self {
        self.eval = Some(eval);
        self
    }

    /// Evaluate every `n` rounds (default 1 = every round).
    ///
    /// **`0` means never evaluate.** This is the single home of that
    /// convention: `run_round` consults it through one predicate and
    /// a regression test pins the zero case.
    pub fn eval_every(mut self, n: u32) -> Self {
        self.eval_every = n;
        self
    }

    /// Override the aggregation strategy (defaults to the registry
    /// instance for `cfg.aggregation`).
    pub fn strategy(mut self, strategy: Arc<dyn AggStrategy>) -> Self {
        self.strategy = Some(strategy);
        self
    }

    /// Override the server optimizer (defaults to the registry
    /// instance for `cfg.server_opt`).
    pub fn server_opt(mut self, server_opt: Box<dyn ServerOpt>) -> Self {
        self.server_opt = Some(server_opt);
        self
    }

    /// Override the cohort planner (defaults to the registry instance
    /// for `cfg.selection` — the explicit `planner` spec when set,
    /// else the legacy `policy`).
    pub fn planner(mut self, planner: Box<dyn CohortPlanner>) -> Self {
        self.planner = Some(planner);
        self
    }

    /// Attach an operator control plane (see [`crate::telemetry`]).
    /// The orchestrator drains its mailbox at round/commit boundaries,
    /// flips `/readyz` after the first dispatch and publishes a status
    /// line each boundary. Without one, the run is uncontrolled
    /// (pre-telemetry behavior).
    pub fn control(mut self, control: Arc<ControlPlane>) -> Self {
        self.control = Some(control);
        self
    }

    pub fn build(self) -> Result<Orchestrator<T>> {
        let transport = self
            .transport
            .ok_or_else(|| anyhow!("OrchestratorBuilder: transport(..) is required"))?;
        let params = self
            .initial_params
            .ok_or_else(|| anyhow!("OrchestratorBuilder: initial_params(..) is required"))?;
        let strategy = self
            .strategy
            .unwrap_or_else(|| strategy_registry::strategy_from_config(&self.cfg.aggregation));
        let server_opt = self
            .server_opt
            .unwrap_or_else(|| strategy_registry::server_opt_from_config(&self.cfg.server_opt));
        let planner = self
            .planner
            .unwrap_or_else(|| planner::planner_from_selection(&self.cfg.selection));
        let traffic = self.traffic.unwrap_or_else(|| Arc::new(TrafficLog::new()));
        let rng = Rng::new(self.cfg.seed ^ 0x0C5);
        // Persistent shard-worker pool for parallel ingest. Built once
        // per orchestrator (not per round): the pool owns its threads
        // for the whole run and rounds merely enqueue fold jobs into
        // it. `ingest_threads == 1` (or auto-resolving to 1) keeps the
        // serial reference path with zero pool machinery.
        let ingest = {
            let threads = resolve_ingest_threads(self.cfg.ingest_threads);
            if threads > 1 {
                Some(Arc::new(ShardPool::new(
                    threads,
                    default_ingest_shards(params.len()),
                )))
            } else {
                None
            }
        };
        Ok(Orchestrator {
            cfg: self.cfg,
            transport,
            registry: ClientRegistry::new(),
            traffic,
            eval: self.eval,
            rng,
            params,
            model_version: 0,
            strategy,
            server_opt,
            planner,
            eval_every: self.eval_every,
            scratch: Arc::new(ScratchPool::new()),
            ingest,
            last_stalls: 0,
            last_fold_ns: 0,
            control: self.control,
            om: OrchMetrics::new(),
        })
    }
}

/// Handles into the global telemetry registry, resolved once at build
/// time so the per-update path is a single relaxed atomic op (see the
/// accuracy contract in [`crate::telemetry`]).
struct OrchMetrics {
    rounds_total: Arc<Counter>,
    round_seconds: Arc<Histogram>,
    staleness: Arc<Histogram>,
    stale_drops: Arc<Counter>,
    /// Deadline misses keyed by client speed tier (fast, mid, slow).
    deadline_miss: [Arc<Counter>; 3],
    ingest_bytes: Arc<Counter>,
    ingest_updates: Arc<Counter>,
    model_version: Arc<Gauge>,
    /// Jobs waiting in the sharded-ingest pool queues (0 when serial).
    shard_queue_depth: Arc<Gauge>,
    /// Producer stalls on a full shard queue (backpressure events).
    ingest_stalls: Arc<Counter>,
    /// Nanoseconds shard workers spent inside fold jobs.
    ingest_fold_ns: Arc<Counter>,
}

impl OrchMetrics {
    fn new() -> Self {
        use crate::telemetry::names;
        let g = telemetry::global();
        let miss_help = "Deadline misses, by client speed tier.";
        OrchMetrics {
            rounds_total: g.counter(
                names::ROUNDS_TOTAL,
                "Rounds (sync) / commits (async) finalized.",
            ),
            round_seconds: g.histogram(
                names::ROUND_SECONDS,
                "Round/commit duration, seconds.",
                telemetry::ROUND_SECONDS_BUCKETS,
            ),
            staleness: g.histogram(
                names::STALENESS,
                "Staleness of folded updates, model versions behind.",
                telemetry::STALENESS_BUCKETS,
            ),
            stale_drops: g.counter(
                names::STALE_DROPS_TOTAL,
                "Updates discarded for exceeding max_staleness.",
            ),
            deadline_miss: [
                g.counter_with(names::DEADLINE_MISSES_TOTAL, miss_help, "tier", "fast"),
                g.counter_with(names::DEADLINE_MISSES_TOTAL, miss_help, "tier", "mid"),
                g.counter_with(names::DEADLINE_MISSES_TOTAL, miss_help, "tier", "slow"),
            ],
            ingest_bytes: g.counter(
                names::INGEST_BYTES_TOTAL,
                "Encoded update bytes folded by the server.",
            ),
            ingest_updates: g.counter(
                names::INGEST_UPDATES_TOTAL,
                "Updates folded by the server.",
            ),
            model_version: g.gauge(names::MODEL_VERSION, "Current global model version."),
            shard_queue_depth: g.gauge(
                names::INGEST_SHARD_QUEUE_DEPTH,
                "Fold jobs queued in the sharded-ingest pool (0 when serial).",
            ),
            ingest_stalls: g.counter(
                names::INGEST_STALLS_TOTAL,
                "Ingest producer stalls on a full shard queue.",
            ),
            ingest_fold_ns: g.counter(
                names::INGEST_FOLD_NS_TOTAL,
                "Nanoseconds shard workers spent folding updates.",
            ),
        }
    }

    fn miss_for(&self, speed_factor: f64) -> &Counter {
        let [fast, mid, slow] = &self.deadline_miss;
        match telemetry::tier_of(speed_factor) {
            "fast" => fast,
            "mid" => mid,
            _ => slow,
        }
    }
}

/// What a boundary's control-mailbox sweep decided.
#[derive(Debug, PartialEq, Eq)]
enum ControlAction {
    Continue,
    /// Stop cleanly after the work already finalized — the report
    /// stays complete.
    Drain,
}

/// The central orchestrator. Assemble with [`Orchestrator::builder`].
pub struct Orchestrator<T: ServerTransport> {
    cfg: ExperimentConfig,
    transport: T,
    registry: ClientRegistry,
    traffic: Arc<TrafficLog>,
    eval: Option<EvalHarness>,
    rng: Rng,
    params: Vec<f32>,
    model_version: u32,
    strategy: Arc<dyn AggStrategy>,
    server_opt: Box<dyn ServerOpt>,
    /// Cohort planning + registry feedback (see
    /// [`crate::orchestrator::planner`]).
    planner: Box<dyn CohortPlanner>,
    eval_every: u32,
    /// Dense scratch buffers recycled across updates and rounds (used
    /// only by the ingest paths that must densify — see
    /// [`crate::util::scratch`]).
    scratch: Arc<ScratchPool>,
    /// Persistent shard-worker pool for parallel ingest, shared by
    /// every round's aggregator. `None` runs the serial reference
    /// path (`aggregation.ingest_threads = 1`, or auto on a 1-cpu
    /// box). See [`crate::util::parallel::ShardPool`].
    ingest: Option<Arc<ShardPool>>,
    /// Last-sampled pool stall count, for delta publication into the
    /// monotone telemetry counter.
    last_stalls: usize,
    /// Last-sampled pool fold-nanoseconds, same delta scheme.
    last_fold_ns: u64,
    /// Operator mailbox + readiness/status surface, when a telemetry
    /// endpoint is attached (see [`OrchestratorBuilder::control`]).
    control: Option<Arc<ControlPlane>>,
    /// Always-on counters into the global telemetry registry.
    om: OrchMetrics,
}

/// What the collect phase hands to finalize.
struct CollectOutcome {
    /// Clients the broadcast actually reached (send succeeded).
    reached: Vec<NodeId>,
    /// Clients that reported (good or bad update) before cutoff.
    reported: BTreeSet<NodeId>,
}

impl<T: ServerTransport> Orchestrator<T> {
    /// Start building an orchestrator over `cfg`.
    pub fn builder(cfg: ExperimentConfig) -> OrchestratorBuilder<T> {
        OrchestratorBuilder::new(cfg)
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    pub fn registry(&self) -> &ClientRegistry {
        &self.registry
    }

    /// The aggregation strategy rounds run under.
    pub fn strategy(&self) -> &dyn AggStrategy {
        self.strategy.as_ref()
    }

    /// Phase 0: absorb registrations until `expected` clients joined or
    /// `timeout` passed. Returns the number registered.
    pub fn wait_for_clients(&mut self, expected: usize, timeout: Duration) -> Result<usize> {
        let deadline = Instant::now() + timeout;
        while self.registry.len() < expected {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let step = (deadline - now).min(Duration::from_millis(100));
            if let Some((from, msg)) = self.transport.recv_timeout(step)? {
                self.handle_control(from, msg)?;
            }
        }
        log::info!(
            "orchestrator: {} / {expected} clients registered",
            self.registry.len()
        );
        Ok(self.registry.len())
    }

    fn handle_control(&mut self, from: NodeId, msg: Msg) -> Result<()> {
        match msg {
            Msg::Register { client, profile } => {
                if client != from {
                    log::warn!("register id mismatch: envelope {from}, body {client}");
                }
                self.registry.register(client, profile);
                self.transport
                    .send_to(client, &Msg::RegisterAck { client })?;
            }
            Msg::Heartbeat { .. } => {}
            other => {
                log::debug!("orchestrator: ignoring {} outside round", other.name());
            }
        }
        Ok(())
    }

    /// Publish the operator-visible state line (served by `GET
    /// /status` and the `status` verb).
    fn publish_status(&self, cp: &ControlPlane, boundary: u32, state: &str) {
        cp.set_status(format!(
            "state={state} round={boundary} model_version={} planner={} strategy={} clients={}",
            self.model_version,
            self.planner.name(),
            self.strategy.name(),
            self.registry.len(),
        ));
    }

    /// Drain the operator mailbox at a round/commit boundary and apply
    /// every queued verb. `quiesce` parks right here — clients stay
    /// connected, nothing is dispatched or folded — until `resume` or
    /// `drain` arrives. `set-planner` / `set-strategy` swap the live
    /// instances (specs were validated at submission; in async mode the
    /// cohort is fixed at launch, so a planner swap redirects
    /// success/failure feedback rather than changing membership, and a
    /// buffering strategy is refused because the async engine needs
    /// streaming folds).
    fn apply_control(&mut self, boundary: u32) -> ControlAction {
        let Some(cp) = self.control.clone() else {
            return ControlAction::Continue;
        };
        let is_async = matches!(self.cfg.round_mode, RoundMode::BufferedAsync { .. });
        let mut cmds: VecDeque<ControlCmd> = cp.drain_mailbox().into();
        let mut quiesced = false;
        loop {
            while let Some(cmd) = cmds.pop_front() {
                match cmd {
                    ControlCmd::Drain => {
                        log::info!("control: drain at boundary {boundary} — stopping cleanly");
                        self.publish_status(&cp, boundary, "draining");
                        return ControlAction::Drain;
                    }
                    ControlCmd::Quiesce => quiesced = true,
                    ControlCmd::Resume => quiesced = false,
                    ControlCmd::SetPlanner(spec) => {
                        match planner::planner_by_name(&spec) {
                            Ok(p) => {
                                log::info!(
                                    "control: planner {} -> {spec} at boundary {boundary}",
                                    self.planner.name()
                                );
                                self.planner = p;
                            }
                            // unreachable for mailbox-delivered specs
                            // (validated at submission) — logged, not fatal
                            Err(e) => log::warn!("control: set-planner {spec:?} refused: {e}"),
                        }
                    }
                    ControlCmd::SetStrategy(spec) => {
                        match strategy_registry::strategy_by_name(&spec) {
                            Ok(s) if is_async && s.needs_buffering() => log::warn!(
                                "control: set-strategy {spec:?} refused — async mode \
                                 needs a streaming strategy"
                            ),
                            Ok(s) => {
                                log::info!(
                                    "control: strategy {} -> {spec} at boundary {boundary}",
                                    self.strategy.name()
                                );
                                self.strategy = s;
                            }
                            Err(e) => log::warn!("control: set-strategy {spec:?} refused: {e}"),
                        }
                    }
                    // answered inline by the HTTP layer; nothing to do
                    ControlCmd::Status => {}
                }
            }
            if !quiesced {
                break;
            }
            self.publish_status(&cp, boundary, "quiesced");
            std::thread::sleep(Duration::from_millis(25));
            cmds = cp.drain_mailbox().into();
        }
        self.publish_status(&cp, boundary, "running");
        ControlAction::Continue
    }

    /// `/readyz` gate: listening is not enough — ready means the first
    /// round/launch actually went out to clients.
    fn mark_ready(&self) {
        if let Some(cp) = &self.control {
            cp.mark_ready();
        }
    }

    /// Whether round `round` gets a centralized evaluation
    /// (`eval_every == 0` = never — see
    /// [`OrchestratorBuilder::eval_every`]).
    fn should_eval(&self, round: u32) -> bool {
        self.eval_every != 0 && round % self.eval_every == 0
    }

    fn round_deadline_ms(&self) -> u64 {
        self.cfg.straggler.deadline_ms.unwrap_or(3_600_000)
    }

    /// Dispatch terms for a client the planner doesn't tune — the
    /// config's global deadline / epochs / compression.
    fn dispatch_defaults(&self) -> DispatchPlan {
        DispatchPlan {
            deadline_ms: self.round_deadline_ms(),
            local_epochs: self.cfg.train.local_epochs as u32,
            compression: self.cfg.compression,
        }
    }

    /// Plan this round's cohort + per-client dispatch terms
    /// (Algorithm 1 line 4, generalized to heterogeneity-aware
    /// planners).
    fn select_phase(&mut self, round: u32) -> Result<RoundPlan> {
        let available = self.registry.ids();
        if available.is_empty() {
            bail!("round {round}: no clients registered");
        }
        let ctx = PlanContext {
            round,
            k: self.cfg.selection.clients_per_round,
            defaults: self.dispatch_defaults(),
        };
        let mut round_rng = self.rng.fork(round as u64);
        let plan = self
            .planner
            .plan(&mut self.registry, &available, &ctx, &mut round_rng);
        if plan.is_empty() {
            bail!("round {round}: planner returned no clients");
        }
        log::debug!("round {round}: planned cohort {:?}", plan.cohort());
        planner::record_plan_telemetry(&plan);
        Ok(plan)
    }

    /// Phase 1 (Algorithm 1 line 5): broadcast the global model. The
    /// payload is serialized exactly once per round; each send only
    /// clones the Arc (inproc) or re-writes the shared bytes (tcp),
    /// while the planner's per-client dispatch terms (deadline, epoch
    /// budget, compression) ride in each client's `RoundStart` fields.
    /// Returns the clients the model actually reached — a failed send
    /// is excluded from the expected-reporter count so collection
    /// never waits out the deadline for a client that never got the
    /// model (it still counts in `dropped`).
    fn broadcast_phase(&mut self, round: u32, plan: &RoundPlan) -> Vec<NodeId> {
        let shared_params = Encoded::PreEncoded(pre_encode_dense(&self.params));
        let mut reached = Vec::with_capacity(plan.len());
        for (c, p) in plan.iter() {
            let msg = Msg::RoundStart {
                round,
                model_version: self.model_version,
                deadline_ms: p.deadline_ms,
                lr: self.cfg.train.lr,
                mu: self.strategy.mu(),
                local_epochs: p.local_epochs,
                params: shared_params.clone(),
                mask_seed: mask_seed(self.cfg.seed, round, c),
                compression: p.compression,
            };
            match self.transport.send_to(c, &msg) {
                Ok(()) => reached.push(c),
                Err(e) => log::warn!(
                    "round {round}: broadcast to {c} failed ({e}) — excluded from collection"
                ),
            }
        }
        self.mark_ready();
        reached
    }

    /// Publish sharded-ingest pool health into the global telemetry
    /// registry. Called at round/commit boundaries: the gauge snapshots
    /// current queue depth, the counters get the delta since the last
    /// sample (pool totals are cumulative, registry counters are
    /// monotone adds).
    fn sample_ingest_pool(&mut self) {
        let Some(pool) = &self.ingest else { return };
        self.om.shard_queue_depth.set(pool.queue_depth() as u64);
        let stalls = pool.stall_count();
        self.om
            .ingest_stalls
            .add(stalls.saturating_sub(self.last_stalls) as u64);
        self.last_stalls = stalls;
        let fold_ns = pool.fold_ns_total();
        self.om
            .ingest_fold_ns
            .add(fold_ns.saturating_sub(self.last_fold_ns));
        self.last_fold_ns = fold_ns;
    }

    /// Phase 2 (Algorithm 1 lines 6–10): collect updates under the
    /// deadline / partial-k stopping rule, folding each one into the
    /// aggregator as it arrives. `deadline_ms` is the cohort's maximum
    /// planned deadline — per-client deadlines are advisory on the
    /// wire, the server waits for the slowest budget it handed out.
    fn collect_phase(
        &mut self,
        round: u32,
        t_round: Instant,
        deadline_ms: u64,
        reached: Vec<NodeId>,
        agg: &mut RoundAggregator,
        hooks: &mut dyn OrchestratorHooks,
    ) -> Result<CollectOutcome> {
        let core = self.fold_core();
        let partial_k = self
            .cfg
            .straggler
            .partial_k
            .unwrap_or(usize::MAX)
            .min(reached.len());
        let deadline = t_round + Duration::from_millis(deadline_ms);
        let reached_set: BTreeSet<NodeId> = reached.iter().copied().collect();
        let mut reported: BTreeSet<NodeId> = BTreeSet::new();
        while reported.len() < reached.len() && agg.n_updates() < partial_k {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let step = (deadline - now).min(Duration::from_millis(50));
            let Some((from, msg)) = self.transport.recv_timeout(step)? else {
                continue;
            };
            match msg {
                Msg::Update {
                    round: r,
                    client,
                    // sync rounds train on the round's own model, so
                    // the base version adds nothing over the round tag
                    base_version: _,
                    delta,
                    stats,
                } => {
                    if r != round {
                        log::debug!("stale update from {client} for round {r}");
                        continue;
                    }
                    if !reached_set.contains(&client) || reported.contains(&client) {
                        continue;
                    }
                    // a bad update (undecodable, or rejected by the
                    // strategy — e.g. a custom weight() returning
                    // NaN) skips this client, never aborts the round.
                    // The fused O(nnz) ingest dispatch lives in
                    // [`FoldCore::fold_encoded`] (shared with the
                    // async engine and the site aggregator); sync
                    // rounds fold at scale 1.
                    match core.fold_encoded(agg, client, delta, &stats, 1.0) {
                        Ok(()) => {
                            hooks.on_update(round, client, &stats);
                            // sync rounds fold only same-version updates
                            self.om.staleness.observe(0.0);
                            self.om.ingest_updates.inc();
                            reported.insert(client);
                            self.planner.report_success(
                                &mut self.registry,
                                client,
                                round,
                                t_round.elapsed().as_secs_f64() * 1e3,
                            );
                        }
                        Err(e) => {
                            log::warn!("round {round}: bad update from {client}: {e}");
                            self.planner.report_failure(&mut self.registry, client, round);
                            reported.insert(client);
                        }
                    }
                }
                other => self.handle_control(from, other)?,
            }
        }
        Ok(CollectOutcome { reached, reported })
    }

    /// Phase 3 (Algorithm 1 lines 11–13): fault accounting, finalize
    /// Δ_agg, server-optimizer step, evaluation, convergence.
    fn finalize_phase(
        &mut self,
        round: u32,
        t_round: Instant,
        selected: &[NodeId],
        collect: CollectOutcome,
        agg: RoundAggregator,
        tracker: &mut ConvergenceTracker,
    ) -> Result<RoundOutcome> {
        let CollectOutcome { reached, reported } = collect;
        // fault accounting: a reached client that never reported is a
        // deadline miss; every selected non-reporter (including failed
        // broadcasts) feeds the registry's reliability signal
        let reached_set: BTreeSet<NodeId> = reached.iter().copied().collect();
        let mut deadline_misses = 0u32;
        for &c in selected {
            if !reported.contains(&c) {
                let speed = self
                    .registry
                    .get(c)
                    .map_or(1.0, |r| r.profile.speed_factor);
                self.planner.report_failure(&mut self.registry, c, round);
                if reached_set.contains(&c) {
                    deadline_misses += 1;
                    self.om.miss_for(speed).inc();
                }
            }
        }

        // finalize the aggregate (one normalization scalar / order
        // statistic) + server-optimizer model step. On a zero-update
        // round the old model is kept as-is — no clone, and the
        // optimizer state does not advance.
        let n_updates = agg.n_updates();
        let (new_params, mean_loss) = if n_updates == 0 {
            log::warn!("round {round}: zero updates — keeping old model");
            (None, f64::NAN)
        } else {
            let out = agg.finalize(&self.params, self.server_opt.as_mut())?;
            (Some(out.new_params), out.mean_train_loss)
        };
        let current: &[f32] = new_params.as_deref().unwrap_or(&self.params);

        // evaluate (centralized, §5.3)
        let (eval_accuracy, eval_loss) = if self.should_eval(round) {
            match &self.eval {
                Some(h) => {
                    let e = h.evaluate(current)?;
                    (Some(e.accuracy()), Some(e.mean_loss()))
                }
                None => (None, None),
            }
        } else {
            (None, None)
        };

        let converged = tracker.update(&self.params, current, eval_accuracy);
        let model_delta = tracker.last_delta();
        if let Some(p) = new_params {
            self.params = p;
        }
        self.model_version = round + 1;

        // notify round end (selected only; broadcast would also be fine)
        for &c in selected {
            let _ = self.transport.send_to(
                c,
                &Msg::RoundEnd {
                    round,
                    model_version: self.model_version,
                },
            );
        }

        let (bytes_down, bytes_up) = self.traffic.round(round);
        let duration_s = t_round.elapsed().as_secs_f64();
        self.om.rounds_total.inc();
        self.om.round_seconds.observe(duration_s);
        self.om.ingest_bytes.add(bytes_up);
        self.om.model_version.set(u64::from(self.model_version));
        self.sample_ingest_pool();
        Ok(RoundOutcome {
            metrics: RoundMetrics {
                round,
                selected: selected.len() as u32,
                reported: n_updates as u32,
                dropped: (selected.len() - reported.len()) as u32,
                deadline_misses,
                train_loss: mean_loss,
                eval_accuracy,
                eval_loss,
                duration_s,
                bytes_down,
                bytes_up,
                model_delta,
                // sync: every fold is version-current by construction
                staleness_min: 0,
                staleness_mean: 0.0,
                staleness_max: 0,
            },
            converged,
        })
    }

    /// The role-agnostic fold core this orchestrator's rounds run
    /// through — three `Arc` clones, built per use so a live
    /// `set-strategy` swap is always reflected in the next window.
    fn fold_core(&self) -> FoldCore {
        FoldCore::new(
            self.strategy.clone(),
            self.params.len(),
            self.scratch.clone(),
            self.ingest.clone(),
        )
    }

    /// Run one round `r`: broadcast → collect → finalize. Blocking;
    /// returns metrics + convergence info.
    pub fn run_round(
        &mut self,
        round: u32,
        tracker: &mut ConvergenceTracker,
        hooks: &mut dyn OrchestratorHooks,
    ) -> Result<RoundOutcome> {
        let t_round = Instant::now();
        let plan = self.select_phase(round)?;
        hooks.on_round_start(round, plan.cohort());
        let reached = self.broadcast_phase(round, &plan);
        let mut agg = self.fold_core().begin();
        let collect = self.collect_phase(
            round,
            t_round,
            plan.max_deadline_ms(),
            reached,
            &mut agg,
            hooks,
        )?;
        self.finalize_phase(round, t_round, plan.cohort(), collect, agg, tracker)
    }

    /// Full training run. Consumes registrations first if `wait_for`
    /// is given, then drives the engine the config's
    /// [`RoundMode`] selects: synchronous rounds (Algorithm 1) or
    /// buffered-async commits (FedBuff — see the module docs).
    pub fn run(
        &mut self,
        wait_for: Option<(usize, Duration)>,
        hooks: &mut dyn OrchestratorHooks,
    ) -> Result<TrainingReport> {
        if let Some((n, timeout)) = wait_for {
            let got = self.wait_for_clients(n, timeout)?;
            if got == 0 {
                bail!("no clients registered");
            }
        }
        match self.cfg.round_mode {
            RoundMode::Sync => self.run_sync(hooks),
            RoundMode::BufferedAsync {
                buffer_k,
                max_staleness,
                staleness,
            } => self.run_async(buffer_k, max_staleness, staleness, hooks),
        }
    }

    /// The synchronous engine: `rounds` iterations of
    /// [`Orchestrator::run_round`] (Algorithm 1).
    fn run_sync(&mut self, hooks: &mut dyn OrchestratorHooks) -> Result<TrainingReport> {
        let mut report = TrainingReport::new(&self.cfg.name);
        let mut tracker = ConvergenceTracker::new(
            self.cfg.train.converge_eps,
            self.cfg.train.converge_patience,
            self.cfg.train.target_accuracy,
        );
        for round in 0..self.cfg.train.rounds as u32 {
            // operator verbs apply between rounds, never mid-round —
            // a drain leaves every pushed RoundMetrics complete
            if self.apply_control(round) == ControlAction::Drain {
                break;
            }
            let outcome = self.run_round(round, &mut tracker, hooks)?;
            log::info!(
                "round {round}: loss={:.4} acc={} reported={}/{} dur={:.2}s",
                outcome.metrics.train_loss,
                outcome
                    .metrics
                    .eval_accuracy
                    .map_or("-".into(), |a| format!("{:.3}", a)),
                outcome.metrics.reported,
                outcome.metrics.selected,
                outcome.metrics.duration_s,
            );
            hooks.on_round(&outcome.metrics);
            let converged = outcome.converged;
            report.push(outcome.metrics);
            if converged {
                report.converged_at = Some(round);
                log::info!("converged at round {round}");
                break;
            }
        }
        if let Some(t) = self.cfg.train.target_accuracy {
            report.target_accuracy_at = report.rounds_to_accuracy(t);
        }
        self.release_fleet();
        Ok(report)
    }

    /// Hand `client` the current global model for async training,
    /// under the dispatch terms its launch plan assigned.
    /// `dispatch_no` (a per-run counter) tags the `RoundStart`, so a
    /// client re-dispatched within one commit window still draws fresh
    /// training RNG, fault decisions and compression masks — the
    /// worker keys all three off the round tag / mask seed. Staleness
    /// is derived from `model_version`, never the tag.
    fn dispatch_async(
        &mut self,
        client: NodeId,
        dispatch_no: u64,
        shared: &Encoded,
        plan: DispatchPlan,
    ) -> Result<()> {
        let msg = Msg::RoundStart {
            round: dispatch_no as u32,
            model_version: self.model_version,
            deadline_ms: plan.deadline_ms,
            lr: self.cfg.train.lr,
            mu: self.strategy.mu(),
            local_epochs: plan.local_epochs,
            params: shared.clone(),
            mask_seed: mask_seed(self.cfg.seed, dispatch_no as u32, client),
            compression: plan.compression,
        };
        self.transport.send_to(client, &msg)
    }

    /// The buffered-async engine (FedBuff; see the module docs).
    /// `cfg.train.rounds` counts commits; each metrics row is one
    /// commit.
    fn run_async(
        &mut self,
        buffer_k: usize,
        max_staleness: u32,
        staleness: StalenessFn,
        hooks: &mut dyn OrchestratorHooks,
    ) -> Result<TrainingReport> {
        // config-selected strategies are validated up front; this
        // catches builder-injected ones
        if self.strategy.needs_buffering() {
            bail!(
                "async round mode requires a streaming aggregation strategy \
                 (got buffered '{}')",
                self.strategy.name()
            );
        }
        let mut report = TrainingReport::new(&self.cfg.name);
        let mut tracker = ConvergenceTracker::new(
            self.cfg.train.converge_eps,
            self.cfg.train.converge_patience,
            self.cfg.train.target_accuracy,
        );
        let total_commits = self.cfg.train.rounds as u32;

        // launch: one concurrency slot per planned client, all on M_0.
        // The launch plan's per-client dispatch terms stay with each
        // client for the whole run (every re-dispatch reuses them).
        let launch_plan = self.select_phase(0)?;
        hooks.on_round_start(0, launch_plan.cohort());
        let plans: BTreeMap<NodeId, DispatchPlan> = launch_plan.to_map();
        let cohort: Vec<NodeId> = launch_plan.cohort().to_vec();
        let mut shared = Encoded::PreEncoded(pre_encode_dense(&self.params));
        let mut dispatch_no: u64 = 0;
        // BTree keeps the stalled-client sweep below NodeId-ordered, so
        // re-dispatch order is a function of state, not hasher seed
        let mut in_flight: BTreeSet<NodeId> = BTreeSet::new();
        // when each in-flight client last got a dispatch — non-reporting
        // clients (crashes, injected dropouts) are re-dispatched after a
        // deadline so their concurrency slot is never lost for good
        let mut last_dispatch: BTreeMap<NodeId, Instant> = BTreeMap::new();
        for (c, p) in launch_plan.iter() {
            match self.dispatch_async(c, dispatch_no, &shared, *p) {
                Ok(()) => {
                    in_flight.insert(c);
                    last_dispatch.insert(c, Instant::now());
                }
                Err(e) => log::warn!("async launch: dispatch to {c} failed ({e})"),
            }
            dispatch_no += 1;
        }
        if in_flight.is_empty() {
            bail!("async launch: no client reachable");
        }
        self.mark_ready();

        let mut commit = 0u32;
        let mut core = self.fold_core();
        let mut agg = core.begin();
        let mut t_commit = Instant::now();
        let mut stale_drops = 0u32;
        let mut bad_folds = 0u32;
        // staleness of each update folded into the open window, for
        // the commit's RoundMetrics triple
        let mut fold_staleness: Vec<u32> = Vec::new();
        let mut last_traffic = self.traffic.totals();
        // clients owed a fresh dispatch; flushed at the loop top so a
        // fold that fills the buffer hands back the *post*-commit model
        let mut pending: Vec<NodeId> = Vec::new();
        while commit < total_commits {
            let now = Instant::now();
            let deadline = t_commit + Duration::from_millis(self.round_deadline_ms());
            // a commit may not wait forever: at the deadline it closes
            // with whatever arrived (possibly nothing — model unchanged)
            if now >= deadline || agg.n_updates() >= buffer_k {
                let full = std::mem::replace(&mut agg, core.begin());
                let totals = self.traffic.totals();
                let traffic_delta = (totals.0 - last_traffic.0, totals.1 - last_traffic.1);
                last_traffic = totals;
                let staleness_stats = staleness_summary(&fold_staleness);
                fold_staleness.clear();
                let outcome = self.commit_async(
                    commit,
                    t_commit,
                    in_flight.len(),
                    (stale_drops, bad_folds),
                    traffic_delta,
                    staleness_stats,
                    full,
                    &mut tracker,
                )?;
                if outcome.metrics.reported > 0 {
                    // the model moved: share the new version
                    shared = Encoded::PreEncoded(pre_encode_dense(&self.params));
                }
                hooks.on_round(&outcome.metrics);
                let converged = outcome.converged;
                report.push(outcome.metrics);
                commit += 1;
                t_commit = Instant::now();
                stale_drops = 0;
                bad_folds = 0;
                if converged {
                    report.converged_at = Some(commit - 1);
                    log::info!("async: converged at commit {}", commit - 1);
                    break;
                }
                if self.apply_control(commit) == ControlAction::Drain {
                    break;
                }
                // a set-strategy at this boundary must govern the
                // window that opens now; the replacement aggregator is
                // still empty, so rebuilding core + aggregator is free
                // and safe
                core = self.fold_core();
                agg = core.begin();
                // a long quiesce park must not expire the next window
                // before it folds anything
                t_commit = Instant::now();
                // revive silent clients: anyone whose last dispatch is a
                // full deadline old reported nothing (dropout, crash,
                // lost frame) — hand them the fresh model instead of
                // leaking their concurrency slot
                let stall = Duration::from_millis(self.round_deadline_ms());
                for &c in &in_flight {
                    let stalled = last_dispatch
                        .get(&c)
                        .is_none_or(|t| t.elapsed() >= stall);
                    if stalled && !pending.contains(&c) {
                        log::debug!("async: re-dispatching silent client {c}");
                        pending.push(c);
                    }
                }
                continue;
            }
            // keep reporters busy on the freshest model, each under its
            // launch-plan dispatch terms
            for client in pending.drain(..) {
                let p = plans.get(&client).copied().unwrap_or_else(|| self.dispatch_defaults());
                if let Err(e) = self.dispatch_async(client, dispatch_no, &shared, p) {
                    log::warn!("async: re-dispatch to {client} failed ({e})");
                    in_flight.remove(&client);
                } else {
                    last_dispatch.insert(client, Instant::now());
                }
                dispatch_no += 1;
            }
            if in_flight.is_empty() {
                bail!("async: every client became unreachable");
            }
            let step = (deadline - now).min(Duration::from_millis(50));
            let Some((from, msg)) = self.transport.recv_timeout(step)? else {
                continue;
            };
            match msg {
                Msg::Update {
                    round: _,
                    client,
                    base_version,
                    delta,
                    stats,
                } => {
                    if !in_flight.contains(&client) {
                        continue;
                    }
                    if base_version > self.model_version {
                        log::warn!(
                            "async: client {client} claims future base version \
                             {base_version} (current {})",
                            self.model_version
                        );
                        stale_drops += 1;
                        self.om.stale_drops.inc();
                    } else {
                        let s = self.model_version - base_version;
                        if s > max_staleness {
                            log::debug!(
                                "async: dropping update from {client} at staleness {s}"
                            );
                            stale_drops += 1;
                            self.om.stale_drops.inc();
                            let speed = self
                                .registry
                                .get(client)
                                .map_or(1.0, |r| r.profile.speed_factor);
                            self.om.miss_for(speed).inc();
                            self.planner.report_failure(&mut self.registry, client, commit);
                        } else {
                            // the same fused [`FoldCore::fold_encoded`]
                            // path as the sync engine, with scale =
                            // discount(s) instead of 1. Sharded rounds
                            // hand ownership to the worker pool.
                            match core.fold_encoded(
                                &mut agg,
                                client,
                                delta,
                                &stats,
                                staleness.discount(s),
                            ) {
                                Ok(()) => {
                                    hooks.on_update(commit, client, &stats);
                                    fold_staleness.push(s);
                                    self.om.staleness.observe(f64::from(s));
                                    self.om.ingest_updates.inc();
                                    self.planner.report_success(
                                        &mut self.registry,
                                        client,
                                        commit,
                                        t_commit.elapsed().as_secs_f64() * 1e3,
                                    );
                                }
                                Err(e) => {
                                    log::warn!("async: bad update from {client}: {e}");
                                    bad_folds += 1;
                                    self.planner.report_failure(&mut self.registry, client, commit);
                                }
                            }
                        }
                    }
                    pending.push(client);
                }
                other => self.handle_control(from, other)?,
            }
        }
        if let Some(t) = self.cfg.train.target_accuracy {
            report.target_accuracy_at = report.rounds_to_accuracy(t);
        }
        self.release_fleet();
        Ok(report)
    }

    /// Close one async commit: finalize the buffered folds (if any),
    /// step the server optimizer, evaluate, and advance the model
    /// version. An empty commit keeps the model — and the version, so
    /// in-flight staleness stays truthful — and does *not* advance the
    /// convergence tracker (an idle deadline is no evidence the model
    /// stopped moving).
    ///
    /// Metric semantics in async mode (shared with the async sim):
    /// `dropped` counts every discarded update this commit (too stale
    /// + undecodable/refused), `deadline_misses` the too-stale subset.
    #[allow(clippy::too_many_arguments)]
    fn commit_async(
        &mut self,
        commit: u32,
        t_commit: Instant,
        in_flight: usize,
        (stale_drops, bad_folds): (u32, u32),
        (bytes_down, bytes_up): (u64, u64),
        (staleness_min, staleness_mean, staleness_max): (u32, f64, u32),
        agg: RoundAggregator,
        tracker: &mut ConvergenceTracker,
    ) -> Result<RoundOutcome> {
        let n_updates = agg.n_updates();
        let (new_params, mean_loss) = if n_updates == 0 {
            log::warn!("async commit {commit}: zero folds — keeping model");
            (None, f64::NAN)
        } else {
            let out = agg.finalize(&self.params, self.server_opt.as_mut())?;
            (Some(out.new_params), out.mean_train_loss)
        };
        let current: &[f32] = new_params.as_deref().unwrap_or(&self.params);
        let (eval_accuracy, eval_loss) = if self.should_eval(commit) {
            match &self.eval {
                Some(h) => {
                    let e = h.evaluate(current)?;
                    (Some(e.accuracy()), Some(e.mean_loss()))
                }
                None => (None, None),
            }
        } else {
            (None, None)
        };
        let (converged, model_delta) = if new_params.is_some() {
            let c = tracker.update(&self.params, current, eval_accuracy);
            (c, tracker.last_delta())
        } else {
            (false, 0.0)
        };
        if let Some(p) = new_params {
            self.params = p;
            self.model_version += 1;
        }
        let duration_s = t_commit.elapsed().as_secs_f64();
        self.om.rounds_total.inc();
        self.om.round_seconds.observe(duration_s);
        self.om.ingest_bytes.add(bytes_up);
        self.om.model_version.set(u64::from(self.model_version));
        self.sample_ingest_pool();
        Ok(RoundOutcome {
            metrics: RoundMetrics {
                round: commit,
                selected: in_flight as u32,
                reported: n_updates as u32,
                dropped: stale_drops + bad_folds,
                deadline_misses: stale_drops,
                train_loss: mean_loss,
                eval_accuracy,
                eval_loss,
                duration_s,
                bytes_down,
                bytes_up,
                model_delta,
                staleness_min,
                staleness_mean,
                staleness_max,
            },
            converged,
        })
    }

    /// Training over: release the fleet.
    fn release_fleet(&mut self) {
        for c in self.transport.connected() {
            let _ = self.transport.send_to(c, &Msg::Shutdown);
        }
    }
}

/// Federated-dropout mask seed for (experiment, round, client) — the
/// client derives the identical mask from this.
pub fn mask_seed(exp_seed: u64, round: u32, client: NodeId) -> u64 {
    exp_seed ^ (((round as u64) << 32) | client as u64).wrapping_mul(0x2545F4914F6CDD1D)
}

#[cfg(test)]
mod tests {
    use super::super::registry::test_profile;
    use super::*;
    use crate::compress::decompress;
    use crate::config::{Aggregation, SelectionPolicy};
    use crate::orchestrator::{aggregate, AggInput};
    use crate::network::inproc::{InprocClient, InprocHub, InprocServer};
    use crate::network::{ClientTransport, LinkShaper};
    use crate::orchestrator::strategy::FedAvgM;

    #[test]
    fn mask_seed_unique_per_round_and_client() {
        let mut seen = std::collections::HashSet::new();
        for r in 0..100 {
            for c in 0..60 {
                assert!(seen.insert(mask_seed(7, r, c)));
            }
        }
        assert_eq!(mask_seed(7, 3, 4), mask_seed(7, 3, 4));
        assert_ne!(mask_seed(7, 3, 4), mask_seed(8, 3, 4));
    }

    fn test_cfg(k: usize) -> ExperimentConfig {
        let mut cfg = crate::config::presets::quickstart();
        cfg.selection.clients_per_round = k;
        cfg.selection.policy = SelectionPolicy::Random;
        cfg.straggler.deadline_ms = Some(400);
        cfg.straggler.partial_k = None;
        cfg
    }

    /// n registered dummy clients + an orchestrator over inproc, with
    /// the RegisterAck handshake already drained from every client.
    fn federation(
        cfg: ExperimentConfig,
        n: u32,
        initial: Vec<f32>,
    ) -> (Orchestrator<InprocServer>, Vec<InprocClient>) {
        let traffic = Arc::new(TrafficLog::new());
        let hub = InprocHub::new(traffic.clone());
        let clients: Vec<InprocClient> = (0..n)
            .map(|i| hub.add_client(i, LinkShaper::unshaped()))
            .collect();
        let mut orch = Orchestrator::builder(cfg)
            .transport(hub.server())
            .traffic(traffic)
            .initial_params(initial)
            .build()
            .unwrap();
        for c in &clients {
            c.send(&Msg::Register {
                client: c.id(),
                profile: test_profile(1.0, 1e9),
            })
            .unwrap();
        }
        assert_eq!(
            orch.wait_for_clients(n as usize, Duration::from_secs(5)).unwrap(),
            n as usize
        );
        for c in &clients {
            let ack = c.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
            assert!(matches!(ack, Msg::RegisterAck { .. }));
        }
        (orch, clients)
    }

    fn update(client: NodeId, round: u32, delta: Vec<f32>) -> Msg {
        update_based(client, round, round, delta)
    }

    fn update_based(client: NodeId, round: u32, base_version: u32, delta: Vec<f32>) -> Msg {
        Msg::Update {
            round,
            client,
            base_version,
            delta: Encoded::Dense(delta),
            stats: UpdateStats {
                n_samples: 100,
                train_loss: 1.0,
                steps: 1,
                compute_ms: 1.0,
                update_var: 0.0,
            },
        }
    }

    fn tracker() -> ConvergenceTracker {
        ConvergenceTracker::new(1e-12, 1000, None)
    }

    #[test]
    fn builder_requires_transport_and_params() {
        let cfg = test_cfg(1);
        assert!(Orchestrator::<InprocServer>::builder(cfg.clone())
            .build()
            .is_err());
        let hub = InprocHub::new(Arc::new(TrafficLog::new()));
        assert!(Orchestrator::builder(cfg)
            .transport(hub.server())
            .build()
            .is_err());
    }

    #[test]
    fn builder_defaults_strategy_and_server_opt_from_config() {
        let mut cfg = test_cfg(1);
        cfg.aggregation = Aggregation::TrimmedMean { trim_frac: 0.2 };
        let hub = InprocHub::new(Arc::new(TrafficLog::new()));
        let orch = Orchestrator::builder(cfg)
            .transport(hub.server())
            .initial_params(vec![0f32; 2])
            .build()
            .unwrap();
        assert_eq!(orch.strategy().name(), "trimmed_mean");
        assert!(orch.strategy().needs_buffering());
    }

    #[test]
    fn eval_every_zero_means_never_evaluate() {
        // regression: `round % eval_every` used to divide by zero
        let (mut orch, clients) = {
            let cfg = test_cfg(1);
            let traffic = Arc::new(TrafficLog::new());
            let hub = InprocHub::new(traffic.clone());
            let clients: Vec<InprocClient> =
                (0..1).map(|i| hub.add_client(i, LinkShaper::unshaped())).collect();
            let mut orch = Orchestrator::builder(cfg)
                .transport(hub.server())
                .traffic(traffic)
                .initial_params(vec![0f32; 4])
                .eval_every(0)
                .build()
                .unwrap();
            for c in &clients {
                c.send(&Msg::Register {
                    client: c.id(),
                    profile: test_profile(1.0, 1e9),
                })
                .unwrap();
            }
            orch.wait_for_clients(1, Duration::from_secs(5)).unwrap();
            for c in &clients {
                c.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
            }
            (orch, clients)
        };
        clients[0].send(&update(0, 0, vec![1.0; 4])).unwrap();
        let out = orch.run_round(0, &mut tracker(), &mut NoHooks).unwrap();
        assert_eq!(out.metrics.reported, 1);
        assert!(out.metrics.eval_accuracy.is_none());
    }

    #[test]
    fn stale_round_updates_are_ignored() {
        let (mut orch, clients) = federation(test_cfg(1), 1, vec![0f32; 3]);
        clients[0].send(&update(0, 7, vec![9.0; 3])).unwrap(); // stale
        clients[0].send(&update(0, 0, vec![2.0; 3])).unwrap();
        let out = orch.run_round(0, &mut tracker(), &mut NoHooks).unwrap();
        assert_eq!(out.metrics.reported, 1);
        assert_eq!(orch.params(), &[2.0f32; 3][..]);
    }

    #[test]
    fn duplicate_updates_from_same_client_first_wins() {
        let (mut orch, clients) = federation(test_cfg(2), 2, vec![0f32; 3]);
        clients[0].send(&update(0, 0, vec![2.0; 3])).unwrap();
        clients[0].send(&update(0, 0, vec![100.0; 3])).unwrap(); // dup
        clients[1].send(&update(1, 0, vec![4.0; 3])).unwrap();
        let out = orch.run_round(0, &mut tracker(), &mut NoHooks).unwrap();
        assert_eq!(out.metrics.reported, 2);
        // (100·2 + 100·4) / 200 = 3; the duplicate never contributes
        assert_eq!(orch.params(), &[3.0f32; 3][..]);
    }

    #[test]
    fn updates_from_unselected_clients_are_ignored() {
        let (mut orch, clients) = federation(test_cfg(1), 2, vec![0f32; 3]);
        clients[0].send(&update(0, 0, vec![1.0; 3])).unwrap();
        clients[1].send(&update(1, 0, vec![2.0; 3])).unwrap();
        let out = orch.run_round(0, &mut tracker(), &mut NoHooks).unwrap();
        assert_eq!(out.metrics.selected, 1);
        assert_eq!(out.metrics.reported, 1);
        // only the selected client (the one that got a RoundStart)
        // contributed to the aggregate
        let mut sel = None;
        for c in &clients {
            if let Some(Msg::RoundStart { .. }) =
                c.recv_timeout(Duration::from_millis(100)).unwrap()
            {
                sel = Some(c.id());
            }
        }
        let want = if sel.unwrap() == 0 { 1.0f32 } else { 2.0f32 };
        assert_eq!(orch.params(), &[want; 3][..]);
    }

    #[test]
    fn partial_k_cuts_off_in_arrival_order() {
        let mut cfg = test_cfg(3);
        cfg.straggler.partial_k = Some(2);
        let (mut orch, clients) = federation(cfg, 3, vec![0f32; 3]);
        clients[0].send(&update(0, 0, vec![2.0; 3])).unwrap();
        clients[1].send(&update(1, 0, vec![4.0; 3])).unwrap();
        clients[2].send(&update(2, 0, vec![1000.0; 3])).unwrap(); // too late
        let out = orch.run_round(0, &mut tracker(), &mut NoHooks).unwrap();
        assert_eq!(out.metrics.selected, 3);
        assert_eq!(out.metrics.reported, 2);
        assert_eq!(out.metrics.dropped, 1);
        assert_eq!(out.metrics.deadline_misses, 1);
        // first two arrivals only: (100·2 + 100·4) / 200 = 3
        assert_eq!(orch.params(), &[3.0f32; 3][..]);
    }

    #[test]
    fn broadcast_payload_is_encoded_once_and_shared() {
        let (mut orch, clients) = federation(test_cfg(3), 3, vec![0.5f32; 3]);
        for c in &clients {
            c.send(&update(c.id(), 0, vec![1.0; 3])).unwrap();
        }
        orch.run_round(0, &mut tracker(), &mut NoHooks).unwrap();
        let mut arcs = Vec::new();
        for c in &clients {
            match c.recv_timeout(Duration::from_secs(1)).unwrap().unwrap() {
                Msg::RoundStart { params, .. } => match params {
                    Encoded::PreEncoded(p) => {
                        let dec = decompress(&Encoded::PreEncoded(p.clone()), 3).unwrap();
                        assert_eq!(dec, vec![0.5f32; 3]);
                        arcs.push(p.bytes);
                    }
                    other => panic!("expected shared payload, got {other:?}"),
                },
                other => panic!("expected RoundStart, got {}", other.name()),
            }
        }
        // one serialization per round: all k sends share the same bytes
        assert!(Arc::ptr_eq(&arcs[0], &arcs[1]));
        assert!(Arc::ptr_eq(&arcs[1], &arcs[2]));
    }

    /// A compressed (sparse+quantized) update flowing through the
    /// round loop's fused ingest must land bit-identically to the old
    /// densify-then-fold path (replayed here via the batch wrapper).
    #[test]
    fn compressed_update_folds_through_fused_ingest() {
        let p = 128;
        let (mut orch, clients) = federation(test_cfg(1), 1, vec![0f32; p]);
        let mut rng = crate::util::rng::Rng::new(9);
        let upd: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
        let enc = crate::compress::compress(&upd, &crate::config::CompressionConfig::PAPER, 5);
        let dense = decompress(&enc, p).unwrap();
        clients[0]
            .send(&Msg::Update {
                round: 0,
                client: 0,
                base_version: 0,
                delta: enc,
                stats: UpdateStats {
                    n_samples: 100,
                    train_loss: 1.0,
                    steps: 1,
                    compute_ms: 1.0,
                    update_var: 0.0,
                },
            })
            .unwrap();
        let out = orch.run_round(0, &mut tracker(), &mut NoHooks).unwrap();
        assert_eq!(out.metrics.reported, 1);
        let want = aggregate(
            &vec![0f32; p],
            &[AggInput {
                client: 0,
                delta: dense,
                n_samples: 100,
                train_loss: 1.0,
                update_var: 0.0,
            }],
            Aggregation::FedAvg,
        )
        .unwrap();
        for (a, b) in orch.params().iter().zip(&want.new_params) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// Tentpole regression: a planner's per-client dispatch terms ride
    /// in each client's `RoundStart` fields — same round, same shared
    /// payload, different deadline / epoch budget / compression.
    #[test]
    fn planner_dispatch_terms_are_per_client_on_the_wire() {
        /// Gives client `id` a plan with `deadline = 1000·(id+1)`,
        /// `epochs = id+1`, and top-k = 1/(id+1).
        struct PerClientStub;
        impl CohortPlanner for PerClientStub {
            fn name(&self) -> &'static str {
                "per_client_stub"
            }
            fn plan(
                &mut self,
                _registry: &mut ClientRegistry,
                available: &[NodeId],
                ctx: &PlanContext,
                _rng: &mut crate::util::rng::Rng,
            ) -> RoundPlan {
                RoundPlan::from_entries(
                    available
                        .iter()
                        .take(ctx.k)
                        .map(|&id| {
                            (
                                id,
                                DispatchPlan {
                                    deadline_ms: 1000 * (id as u64 + 1),
                                    local_epochs: id + 1,
                                    compression: crate::config::CompressionConfig {
                                        quant_bits: 32,
                                        topk_frac: 1.0 / (id as f32 + 1.0),
                                        dropout_keep: 1.0,
                                    },
                                },
                            )
                        })
                        .collect(),
                )
            }
        }

        let traffic = Arc::new(TrafficLog::new());
        let hub = InprocHub::new(traffic.clone());
        let clients: Vec<InprocClient> =
            (0..3).map(|i| hub.add_client(i, LinkShaper::unshaped())).collect();
        let mut orch = Orchestrator::builder(test_cfg(3))
            .transport(hub.server())
            .traffic(traffic)
            .initial_params(vec![0.5f32; 3])
            .planner(Box::new(PerClientStub))
            .build()
            .unwrap();
        for c in &clients {
            c.send(&Msg::Register {
                client: c.id(),
                profile: test_profile(1.0, 1e9),
            })
            .unwrap();
        }
        orch.wait_for_clients(3, Duration::from_secs(5)).unwrap();
        for c in &clients {
            c.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        }
        for c in &clients {
            c.send(&update(c.id(), 0, vec![1.0; 3])).unwrap();
        }
        let out = orch.run_round(0, &mut tracker(), &mut NoHooks).unwrap();
        assert_eq!(out.metrics.selected, 3);
        assert_eq!(out.metrics.reported, 3);
        let mut payloads = Vec::new();
        for c in &clients {
            match c.recv_timeout(Duration::from_secs(1)).unwrap().unwrap() {
                Msg::RoundStart {
                    round,
                    deadline_ms,
                    local_epochs,
                    compression,
                    params,
                    ..
                } => {
                    assert_eq!(round, 0);
                    let id = c.id();
                    assert_eq!(deadline_ms, 1000 * (id as u64 + 1));
                    assert_eq!(local_epochs, id + 1);
                    assert_eq!(compression.topk_frac, 1.0 / (id as f32 + 1.0));
                    if let Encoded::PreEncoded(p) = params {
                        payloads.push(p.bytes);
                    }
                }
                other => panic!("expected RoundStart, got {}", other.name()),
            }
        }
        // per-client terms never cost extra serializations: the model
        // payload is still encoded once and Arc-shared
        assert_eq!(payloads.len(), 3);
        assert!(Arc::ptr_eq(&payloads[0], &payloads[1]));
        assert!(Arc::ptr_eq(&payloads[1], &payloads[2]));
    }

    #[test]
    fn builder_defaults_planner_from_selection_config() {
        let mut cfg = test_cfg(1);
        cfg.selection.planner = Some(crate::config::PlannerKind::Tiered { tiers: 2 });
        let hub = InprocHub::new(Arc::new(TrafficLog::new()));
        let orch = Orchestrator::builder(cfg)
            .transport(hub.server())
            .initial_params(vec![0f32; 2])
            .build()
            .unwrap();
        assert_eq!(orch.planner.name(), "tiered");
    }

    #[test]
    fn zero_update_round_keeps_model_unchanged() {
        let (mut orch, _clients) = federation(test_cfg(1), 1, vec![1.5f32; 3]);
        let out = orch.run_round(0, &mut tracker(), &mut NoHooks).unwrap();
        assert_eq!(out.metrics.reported, 0);
        assert_eq!(out.metrics.deadline_misses, 1);
        assert!(out.metrics.train_loss.is_nan());
        assert_eq!(orch.params(), &[1.5f32; 3][..]);
    }

    /// ISSUE satellite bugfix: a client whose broadcast send fails must
    /// not count toward the expected-reporter count — before the fix,
    /// collection waited out the whole round deadline for it.
    #[test]
    fn failed_broadcast_is_excluded_from_expected_reporters() {
        let mut cfg = test_cfg(2);
        // long deadline: the pre-fix behaviour would stall here
        cfg.straggler.deadline_ms = Some(30_000);
        let (mut orch, mut clients) = federation(cfg, 2, vec![0f32; 3]);
        // client 1 disconnects: its channel closes, so send_to fails
        drop(clients.pop().unwrap());
        clients[0].send(&update(0, 0, vec![2.0; 3])).unwrap();
        let t0 = Instant::now();
        let out = orch.run_round(0, &mut tracker(), &mut NoHooks).unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "collection waited for a client that never got the model"
        );
        assert_eq!(out.metrics.selected, 2);
        assert_eq!(out.metrics.reported, 1);
        // the unreachable client is dropped, but not a deadline miss
        assert_eq!(out.metrics.dropped, 1);
        assert_eq!(out.metrics.deadline_misses, 0);
        assert_eq!(orch.params(), &[2.0f32; 3][..]);
    }

    #[test]
    fn hooks_observe_round_start_and_updates() {
        #[derive(Default)]
        struct Counting {
            starts: Vec<(u32, usize)>,
            updates: Vec<(u32, NodeId)>,
            rounds: u32,
        }
        impl OrchestratorHooks for Counting {
            fn on_round_start(&mut self, round: u32, selected: &[NodeId]) {
                self.starts.push((round, selected.len()));
            }
            fn on_update(&mut self, round: u32, client: NodeId, stats: &UpdateStats) {
                assert_eq!(stats.n_samples, 100);
                self.updates.push((round, client));
            }
            fn on_round(&mut self, _m: &RoundMetrics) {
                self.rounds += 1;
            }
        }
        let (mut orch, clients) = federation(test_cfg(2), 2, vec![0f32; 3]);
        clients[0].send(&update(0, 0, vec![2.0; 3])).unwrap();
        clients[1].send(&update(1, 0, vec![4.0; 3])).unwrap();
        let mut hooks = Counting::default();
        orch.run_round(0, &mut tracker(), &mut hooks).unwrap();
        assert_eq!(hooks.starts, vec![(0, 2)]);
        assert_eq!(hooks.updates.len(), 2);
        assert!(hooks.updates.iter().all(|&(r, _)| r == 0));
        // on_round fires from run(), not run_round — untouched here
        assert_eq!(hooks.rounds, 0);
    }

    #[test]
    fn buffered_strategy_runs_through_the_round_loop() {
        let mut cfg = test_cfg(3);
        cfg.aggregation = Aggregation::CoordinateMedian;
        let (mut orch, clients) = federation(cfg, 3, vec![0f32; 3]);
        clients[0].send(&update(0, 0, vec![1.0; 3])).unwrap();
        clients[1].send(&update(1, 0, vec![2.0; 3])).unwrap();
        clients[2].send(&update(2, 0, vec![900.0; 3])).unwrap(); // outlier
        let out = orch.run_round(0, &mut tracker(), &mut NoHooks).unwrap();
        assert_eq!(out.metrics.reported, 3);
        // median of {1, 2, 900} per coordinate
        assert_eq!(orch.params(), &[2.0f32; 3][..]);
    }

    /// A strategy that rejects an update (bad weight) must skip that
    /// client like any other bad update — never abort the round.
    #[test]
    fn strategy_rejecting_updates_does_not_abort_the_round() {
        struct NanWeight;
        impl AggStrategy for NanWeight {
            fn name(&self) -> &'static str {
                "nan_weight"
            }
            fn weight(&self, _input: &AggInput) -> f64 {
                f64::NAN
            }
        }
        let traffic = Arc::new(TrafficLog::new());
        let hub = InprocHub::new(traffic.clone());
        let client = hub.add_client(0, LinkShaper::unshaped());
        let mut orch = Orchestrator::builder(test_cfg(1))
            .transport(hub.server())
            .traffic(traffic)
            .initial_params(vec![1.0f32; 3])
            .strategy(Arc::new(NanWeight))
            .build()
            .unwrap();
        client
            .send(&Msg::Register {
                client: 0,
                profile: test_profile(1.0, 1e9),
            })
            .unwrap();
        orch.wait_for_clients(1, Duration::from_secs(5)).unwrap();
        client.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        client.send(&update(0, 0, vec![5.0; 3])).unwrap();
        let out = orch.run_round(0, &mut tracker(), &mut NoHooks).unwrap();
        // the update was rejected, not aggregated; model unchanged
        assert_eq!(out.metrics.reported, 0);
        assert_eq!(orch.params(), &[1.0f32; 3][..]);
    }

    /// Server-optimizer state carries across rounds inside the real
    /// round loop (not just in unit isolation).
    #[test]
    fn server_opt_momentum_carries_across_rounds() {
        let cfg = {
            let mut c = test_cfg(1);
            c.train.rounds = 2;
            c
        };
        let traffic = Arc::new(TrafficLog::new());
        let hub = InprocHub::new(traffic.clone());
        let client = hub.add_client(0, LinkShaper::unshaped());
        let mut orch = Orchestrator::builder(cfg)
            .transport(hub.server())
            .traffic(traffic)
            .initial_params(vec![0f32; 3])
            .server_opt(Box::new(FedAvgM::new(0.5)))
            .build()
            .unwrap();
        client
            .send(&Msg::Register {
                client: 0,
                profile: test_profile(1.0, 1e9),
            })
            .unwrap();
        orch.wait_for_clients(1, Duration::from_secs(5)).unwrap();
        client.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();

        // round 0: Δ_agg = 1 → v = 1, M = 1
        client.send(&update(0, 0, vec![1.0; 3])).unwrap();
        orch.run_round(0, &mut tracker(), &mut NoHooks).unwrap();
        assert_eq!(orch.params(), &[1.0f32; 3][..]);
        // round 1: Δ_agg = 1 → v = 0.5·1 + 1 = 1.5, M = 2.5
        client.send(&update(0, 1, vec![1.0; 3])).unwrap();
        orch.run_round(1, &mut tracker(), &mut NoHooks).unwrap();
        assert_eq!(orch.params(), &[2.5f32; 3][..]);
    }

    fn async_cfg(k: usize, buffer_k: usize, max_staleness: u32, deadline_ms: u64) -> ExperimentConfig {
        let mut cfg = test_cfg(k);
        cfg.straggler.deadline_ms = Some(deadline_ms);
        cfg.round_mode = crate::config::RoundMode::BufferedAsync {
            buffer_k,
            max_staleness,
            staleness: crate::config::StalenessFn::Polynomial { alpha: 1.0 },
        };
        cfg
    }

    /// The tentpole behaviour: the async engine folds updates as they
    /// arrive regardless of round tag, discounts them by staleness,
    /// and commits a model version every `buffer_k` folds.
    #[test]
    fn async_engine_commits_every_buffer_k_with_staleness_discounts() {
        let mut cfg = async_cfg(3, 2, 10, 5_000);
        cfg.train.rounds = 2; // = commits in async mode
        let (mut orch, clients) = federation(cfg, 3, vec![0f32; 3]);
        // commit 0: two fresh updates (staleness 0 each)
        clients[0].send(&update_based(0, 0, 0, vec![8.0; 3])).unwrap();
        clients[1].send(&update_based(1, 0, 0, vec![4.0; 3])).unwrap();
        // commit 1: one update still based on M_0 (staleness 1 after
        // the first commit) + one fresh update based on M_1
        clients[2].send(&update_based(2, 0, 0, vec![12.0; 3])).unwrap();
        clients[0].send(&update_based(0, 1, 1, vec![3.0; 3])).unwrap();
        let report = orch.run(None, &mut NoHooks).unwrap();

        assert_eq!(report.rounds.len(), 2);
        assert_eq!(report.rounds[0].reported, 2);
        assert_eq!(report.rounds[1].reported, 2);
        assert_eq!(report.rounds[0].dropped + report.rounds[1].dropped, 0);
        // commit 0: Δ = (100·8 + 100·4) / 200 = 6 → M_1 = 6
        // commit 1: the stale update weighs discount(1)·100, the fresh
        // one 100 — same formula as the engine, computed here to stay
        // robust to libm rounding in powf
        let d = crate::config::StalenessFn::Polynomial { alpha: 1.0 }.discount(1);
        let acc = (d * 100.0) * 12.0 + 100.0 * 3.0;
        let want = (6.0f64 + acc / (d * 100.0 + 100.0)) as f32;
        for p in orch.params() {
            assert_eq!(p.to_bits(), want.to_bits(), "got {p}, want {want}");
        }
        // and the discount genuinely bit: the undiscounted mean would
        // have landed at (100·12 + 100·3)/200 + 6 = 13.5
        assert!(orch.params()[0] < 13.0, "staleness discount not applied");
    }

    #[test]
    fn async_engine_drops_updates_beyond_max_staleness() {
        let mut cfg = async_cfg(2, 1, 0, 300);
        cfg.train.rounds = 2;
        let (mut orch, clients) = federation(cfg, 2, vec![0f32; 3]);
        clients[0].send(&update_based(0, 0, 0, vec![2.0; 3])).unwrap();
        // base 0 after one commit → staleness 1 > max_staleness 0
        clients[1].send(&update_based(1, 0, 0, vec![900.0; 3])).unwrap();
        let report = orch.run(None, &mut NoHooks).unwrap();
        assert_eq!(report.rounds.len(), 2);
        assert_eq!(report.rounds[0].reported, 1);
        // the too-stale update is rejected; the second commit closes
        // empty at the deadline and keeps the model
        assert_eq!(report.rounds[1].reported, 0);
        assert_eq!(report.rounds[1].dropped, 1);
        assert_eq!(report.rounds[1].deadline_misses, 1);
        assert_eq!(orch.params(), &[2.0f32; 3][..]);
    }

    /// Async commits only advance the model version when something
    /// folded — an empty commit must not inflate in-flight staleness.
    #[test]
    fn async_empty_commits_do_not_advance_the_model_version() {
        let mut cfg = async_cfg(2, 1, 0, 250);
        cfg.train.rounds = 3;
        let (mut orch, clients) = federation(cfg, 2, vec![0f32; 3]);
        clients[0].send(&update_based(0, 0, 0, vec![2.0; 3])).unwrap();
        // sent up front, still base 0: would be staleness 1 if empty
        // commits bumped the version — they must not, so after commit 0
        // (the only non-empty one) this stays droppable, and a fresh
        // base-1 update keeps folding
        clients[1].send(&update_based(1, 0, 1, vec![4.0; 3])).unwrap();
        let report = orch.run(None, &mut NoHooks).unwrap();
        assert_eq!(report.rounds.len(), 3);
        assert_eq!(report.rounds[0].reported, 1); // [2.0]
        assert_eq!(report.rounds[1].reported, 1); // [2.0] + 4 = [6.0]
        assert_eq!(report.rounds[2].reported, 0); // empty, model kept
        assert_eq!(orch.params(), &[6.0f32; 3][..]);
    }

    /// Review fix: a dispatched client that never reports (crash,
    /// injected dropout, lost frame) must get a fresh dispatch after a
    /// deadline instead of losing its concurrency slot forever — and
    /// idle deadline commits must not count as convergence evidence.
    #[test]
    fn async_silent_clients_are_redispatched_and_empty_commits_dont_converge() {
        let mut cfg = async_cfg(2, 2, 10, 250);
        cfg.train.rounds = 4; // > converge_patience (3) empty commits
        let (mut orch, clients) = federation(cfg, 2, vec![0f32; 3]);
        // client 0 reports once; client 1 never reports at all
        clients[0].send(&update_based(0, 0, 0, vec![2.0; 3])).unwrap();
        let report = orch.run(None, &mut NoHooks).unwrap();
        assert_eq!(report.rounds.len(), 4);
        assert_eq!(report.rounds[0].reported, 1);
        // three consecutive empty commits kept the model bit-still —
        // that must not trip the eps/patience convergence tracker
        assert!(report.converged_at.is_none());
        assert_eq!(orch.params(), &[2.0f32; 3][..]);
        // the silent client kept receiving fresh dispatches: the
        // launch one plus at least one post-deadline revival
        let mut round_starts = 0;
        while let Ok(Some(msg)) = clients[1].recv_timeout(Duration::from_millis(50)) {
            if matches!(msg, Msg::RoundStart { .. }) {
                round_starts += 1;
            }
        }
        assert!(
            round_starts >= 2,
            "silent client got only {round_starts} dispatch(es)"
        );
    }

    #[test]
    fn async_mode_rejects_buffered_strategies() {
        let mut cfg = async_cfg(1, 1, 10, 300);
        cfg.train.rounds = 1;
        let hub = InprocHub::new(Arc::new(TrafficLog::new()));
        let mut orch = Orchestrator::builder(cfg)
            .transport(hub.server())
            .initial_params(vec![0f32; 2])
            .strategy(Arc::new(crate::orchestrator::strategy::CoordinateMedian))
            .build()
            .unwrap();
        let err = orch.run(None, &mut NoHooks).unwrap_err();
        assert!(
            format!("{err:#}").contains("streaming"),
            "unexpected error: {err:#}"
        );
    }
}
