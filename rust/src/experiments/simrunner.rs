//! Virtual-time federation (DESIGN.md §4: E2, E5, E7).
//!
//! Reuses the *same* selection, registry, fault and aggregation code as
//! the real loop, but time is discrete-event virtual time derived from
//! the cluster model:
//!
//! ```text
//! t_client = t_down(link, model bytes)
//!          + t_compute(steps × ref_step_s / speed, jitter, straggle)
//!          + t_up(link, compressed bytes)
//! ```
//!
//! Two engines, selected by `cfg.round_mode` exactly like the real
//! orchestrator:
//!
//! * **Sync** — the round ends at the partial-k'th arrival, the
//!   deadline, or the last arrival, whichever the config dictates.
//! * **Buffered async** (`async_fedbuff`) — every client trains
//!   continuously; each arrival folds immediately with its staleness
//!   discount and a commit closes every `buffer_k` folds
//!   ([`crate::sim::EventQueue`] drives arrivals). Stragglers produce
//!   *stale* updates instead of deadline drops.
//!
//! Optionally each reporting client *really trains* (mock runtime) so
//! time-to-accuracy ablations (E7) get honest accuracy curves attached
//! to honest times.
//!
//! # Determinism contract
//!
//! For a fixed config (seed included) a sim run is bit-reproducible:
//! the same per-round/per-commit reporter sets ([`SimReport::details`])
//! and the same final model fingerprint ([`SimReport::model_hash`]).
//! Everything stochastic draws from seeded [`Rng`] streams, event ties
//! break FIFO, and aggregation is the bit-deterministic fold from
//! `orchestrator::aggregate` — `rust/tests/sim_faults.rs` pins this in
//! both modes.

use crate::cluster::{Cluster, Node, SiteMap};
use crate::compress::{expected_wire_bytes, Encoded, SharedDecoded};
use crate::config::{ExperimentConfig, RoundMode, StalenessFn};
use crate::data::FederatedDataset;
use crate::faults::{FaultAction, FaultInjector};
use crate::metrics::{RoundMetrics, TrainingReport};
use crate::network::ClientProfile;
use crate::orchestrator::planner::planner_from_selection;
use crate::orchestrator::strategy::registry as strategy_registry;
use crate::orchestrator::{
    default_ingest_shards, AggInput, ClientRegistry, DispatchPlan, EvalHarness, PlanContext,
    RoundAggregator, SharedInput,
};
use crate::runtime::{MockRuntime, ModelRuntime};
use crate::sim::{EventQueue, VirtualClock};
use crate::telemetry::{self, Counter};
use crate::util::parallel::{resolve_ingest_threads, ShardPool};
use crate::util::rng::Rng;
use crate::util::scratch::ScratchPool;
use anyhow::{bail, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

/// Timing model parameters.
#[derive(Debug, Clone, Copy)]
pub struct SimTiming {
    /// Reference-node seconds per train step (measured once on real
    /// hardware; see EXPERIMENTS.md §Perf for the measured value).
    pub ref_step_s: f64,
    /// Fixed orchestrator overhead per round (selection + aggregation).
    pub orchestrator_overhead_s: f64,
}

impl Default for SimTiming {
    fn default() -> Self {
        SimTiming {
            ref_step_s: 0.015,
            orchestrator_overhead_s: 0.05,
        }
    }
}

/// Per-round (sync) / per-commit (async) replay detail — what the
/// deterministic-regression tests pin.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RoundDetail {
    pub round: u32,
    /// `(client, staleness)` per folded update, in fold order.
    /// Staleness is always 0 in sync mode.
    pub reporters: Vec<(u32, u32)>,
    /// Virtual time the round/commit closed, in integer microseconds
    /// (quantized so the detail is `Eq`-comparable across runs).
    pub end_us: u64,
}

/// Virtual-time run result.
#[derive(Debug)]
pub struct SimReport {
    pub report: TrainingReport,
    /// Total virtual seconds.
    pub total_time_s: f64,
    /// Per-round / per-commit replay log (see [`RoundDetail`]).
    pub details: Vec<RoundDetail>,
    /// Bit-level fingerprint of the final model
    /// ([`crate::util::hash_f32_bits`]); `None` for pure-timing runs.
    pub model_hash: Option<u64>,
}

impl SimReport {
    /// First virtual time at which the eval accuracy reached `target`
    /// (scanning cumulative round durations), if it ever did.
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        let mut t = 0.0;
        for r in &self.report.rounds {
            t += r.duration_s;
            if r.eval_accuracy.is_some_and(|a| a >= target) {
                return Some(t);
            }
        }
        None
    }
}

fn profile_of(node: &Node, n_samples: u64) -> ClientProfile {
    let (bw, _) = node.link().profile();
    ClientProfile {
        speed_factor: node.speed_factor,
        mem_gb: node.sku.mem_gb,
        link_bw: bw,
        n_samples,
        bench_step_ms: 10.0 / node.speed_factor.max(1e-6),
    }
}

fn quantize_us(t_s: f64) -> u64 {
    (t_s * 1e6).round() as u64
}

/// Shared setup for both engines: cluster, data, runtime, registry.
struct SimSetup {
    cluster: Cluster,
    dataset: Option<FederatedDataset>,
    runtime: Option<MockRuntime>,
    params: Vec<f32>,
    eval: Option<EvalHarness>,
    registry: ClientRegistry,
    injector: FaultInjector,
    /// Local train steps for ONE epoch; a client's per-round step count
    /// is this × its planned epoch budget.
    steps_per_epoch: usize,
    down_bytes: u64,
    /// Config-default dispatch terms (what every planner hands a client
    /// it doesn't tune). `deadline_ms` is `u64::MAX` when the config
    /// disables the cutoff.
    defaults: DispatchPlan,
}

fn setup(cfg: &ExperimentConfig, with_training: bool) -> Result<SimSetup> {
    crate::config::validate(cfg)?;
    let cluster = Cluster::build(&cfg.cluster, cfg.seed)?;
    let n_clients = cluster.len();

    #[allow(clippy::type_complexity)]
    let (dataset, runtime, params, eval): (
        Option<FederatedDataset>,
        Option<MockRuntime>,
        Vec<f32>,
        Option<EvalHarness>,
    ) = if with_training {
        let ds = FederatedDataset::build(&cfg.data, n_clients, cfg.seed)?;
        if ds.clients[0].y_len != 1 {
            bail!("run_sim with_training requires a scalar-label dataset");
        }
        let rt = MockRuntime::new(ds.clients[0].x_len, ds.n_classes);
        let params = rt.init(cfg.seed as u32)?;
        let eval = EvalHarness {
            runtime: Box::new(MockRuntime::new(ds.clients[0].x_len, ds.n_classes)),
            shard: ds.eval.clone(),
        };
        (Some(ds), Some(rt), params, Some(eval))
    } else {
        // pure-timing: P from the artifact manifest if present, else a
        // representative 250k-param model
        let p = crate::runtime::Manifest::load(&cfg.artifacts_dir)
            .ok()
            .and_then(|m| m.model(&cfg.data.dataset).ok().map(|i| i.n_params))
            .unwrap_or(250_000);
        (None, None, vec![0f32; p], None)
    };

    let mut registry = ClientRegistry::new();
    let samples = cfg.data.samples_per_client as u64;
    for node in &cluster.nodes {
        registry.register(node.id, profile_of(node, samples));
    }
    let steps_per_epoch = {
        // ceil(samples / batch), batch 16 (mock) or artifact
        let batch = runtime.as_ref().map_or(16, |r| r.train_batch());
        cfg.data.samples_per_client.div_ceil(batch)
    };
    let down_bytes = 4 * params.len() as u64;
    let defaults = DispatchPlan {
        deadline_ms: cfg.straggler.deadline_ms.unwrap_or(u64::MAX),
        local_epochs: cfg.train.local_epochs as u32,
        compression: cfg.compression,
    };
    Ok(SimSetup {
        cluster,
        dataset,
        runtime,
        params,
        eval,
        registry,
        injector: FaultInjector::new(cfg.faults, cfg.seed),
        steps_per_epoch,
        down_bytes,
        defaults,
    })
}

/// Build the sim's sharded-ingest pool from the config knob, exactly
/// like the real orchestrator's builder: `None` is the serial
/// reference path (`ingest_threads` 1, or auto on a 1-cpu box).
fn sim_ingest_pool(cfg: &ExperimentConfig, n_params: usize) -> Option<Arc<ShardPool>> {
    let threads = resolve_ingest_threads(cfg.ingest_threads);
    (threads > 1).then(|| Arc::new(ShardPool::new(threads, default_ingest_shards(n_params))))
}

/// Fold one locally-trained update on whichever ingest path the round
/// aggregator selected: the sharded pool takes ownership of the dense
/// delta (workers fold disjoint spans), the serial path streams it.
/// Both produce bit-identical aggregates for the sim's fixed virtual
/// arrival order.
fn sim_fold(
    agg: &mut RoundAggregator,
    input: AggInput,
    n_params: usize,
    scale: f64,
) -> Result<()> {
    if agg.ingest_sharded() {
        let AggInput {
            client,
            delta,
            n_samples,
            train_loss,
            update_var,
        } = input;
        let payload = SharedDecoded::new(Arc::new(Encoded::Dense(delta)), n_params)?;
        agg.fold_shared_scaled(
            &SharedInput {
                client,
                payload: Arc::new(payload),
                n_samples,
                train_loss,
                update_var,
            },
            scale,
        )
    } else {
        agg.fold_scaled(&input, scale)
    }
}

/// Per-site telemetry handles for a sim run (one pair per site,
/// resolved once — bumped only at site-round / commit boundaries, the
/// same sampling discipline the live `Aggregator` uses).
struct SimSiteCounters {
    updates: Arc<Counter>,
    upstream_bytes: Arc<Counter>,
}

fn sim_site_counters(n_sites: usize) -> Vec<SimSiteCounters> {
    use crate::telemetry::names;
    let g = telemetry::global();
    (0..n_sites)
        .map(|site| {
            let s = site.to_string();
            SimSiteCounters {
                updates: g.counter_with(
                    names::SITE_UPDATES_TOTAL,
                    "Member updates folded by a site aggregator, by site.",
                    "site",
                    &s,
                ),
                upstream_bytes: g.counter_with(
                    names::UPSTREAM_REPORT_BYTES_TOTAL,
                    "Encoded bytes of pre-folded deltas reported upstream, by site.",
                    "site",
                    &s,
                ),
            }
        })
        .collect()
}

/// Build the run's site map when the config enables the hierarchy
/// plane (validated already, so `build` cannot fail on a validated
/// config — errors still propagate for injected configs).
fn sim_site_map(cfg: &ExperimentConfig) -> Result<Option<SiteMap>> {
    if cfg.hierarchy.enabled() {
        Ok(Some(SiteMap::build(&cfg.cluster, cfg.hierarchy.grouping)?))
    } else {
        Ok(None)
    }
}

/// Fold a site's member inputs (arrival order) and return the site's
/// upstream report as one [`AggInput`] — the sim counterpart of the
/// live `Aggregator::run_site_round` re-encode: the pre-folded f32
/// site mean at the site's summed weight, attributed to the site's
/// representative node. Returns `None` when no member folded.
#[allow(clippy::too_many_arguments)]
fn fold_site_report(
    map: &SiteMap,
    site: usize,
    members: Vec<AggInput>,
    n_params: usize,
    strategy: &Arc<dyn crate::orchestrator::AggStrategy>,
    scratch: &Arc<ScratchPool>,
    ingest: &Option<Arc<ShardPool>>,
) -> Result<Option<AggInput>> {
    if members.is_empty() {
        return Ok(None);
    }
    let mut site_agg =
        RoundAggregator::with_ingest(strategy.clone(), n_params, scratch.clone(), ingest.clone());
    for input in members {
        sim_fold(&mut site_agg, input, n_params, 1.0)?;
    }
    let (site_delta, total_weight) = site_agg.finalize_delta()?;
    let rep = map.representative(site).unwrap_or(0);
    Ok(Some(AggInput {
        client: rep,
        delta: site_delta.delta.iter().map(|&d| d as f32).collect(),
        // the site's summed weight, carried exactly like the live
        // aggregator's `stats.n_samples` (rounded at the tier boundary)
        n_samples: (total_weight.round() as u64).max(1),
        train_loss: site_delta.mean_train_loss as f32,
        update_var: 0.0,
    }))
}

/// Run a virtual-time experiment. `with_training=false` skips model
/// math entirely (pure timing, e.g. Table 3); `true` trains a mock
/// model so accuracy-vs-time questions can be answered. The engine —
/// synchronous rounds or buffered-async commits — follows
/// `cfg.round_mode`, exactly like the real orchestrator.
pub fn run_sim(
    cfg: &ExperimentConfig,
    timing: &SimTiming,
    with_training: bool,
) -> Result<SimReport> {
    match cfg.round_mode {
        RoundMode::Sync => run_sim_sync(cfg, timing, with_training),
        RoundMode::BufferedAsync {
            buffer_k,
            max_staleness,
            staleness,
        } => run_sim_async(cfg, timing, with_training, buffer_k, max_staleness, staleness),
    }
}

fn run_sim_sync(
    cfg: &ExperimentConfig,
    timing: &SimTiming,
    with_training: bool,
) -> Result<SimReport> {
    let SimSetup {
        cluster,
        dataset,
        runtime,
        mut params,
        eval,
        mut registry,
        injector,
        steps_per_epoch,
        down_bytes,
        defaults,
    } = setup(cfg, with_training)?;
    // same strategy/server-opt/planner plumbing as the real loop;
    // optimizer state and planner state (bench counters, learned
    // tiers) carry across virtual rounds
    let strategy = strategy_registry::strategy_from_config(&cfg.aggregation);
    let mut server_opt = strategy_registry::server_opt_from_config(&cfg.server_opt);
    let mut planner = planner_from_selection(&cfg.selection);
    // one scratch + shard pool for the whole run, like the real loop
    let scratch = Arc::new(ScratchPool::new());
    let ingest = sim_ingest_pool(cfg, params.len());
    // two-tier plane (config `hierarchy`): reporters fold per site,
    // each reporting site ships ONE pre-folded delta cross-facility
    let sites = sim_site_map(cfg)?;
    let site_up_bytes = expected_wire_bytes(params.len(), &cfg.compression);
    let site_counters = sites.as_ref().map(|m| sim_site_counters(m.n_sites()));
    let mut rng = Rng::new(cfg.seed ^ 0x51312);
    let mut now_s = 0.0f64;
    let mut report = TrainingReport::new(&cfg.name);
    let mut details: Vec<RoundDetail> = Vec::new();
    let mut tracker = crate::orchestrator::ConvergenceTracker::new(
        cfg.train.converge_eps,
        cfg.train.converge_patience,
        cfg.train.target_accuracy,
    );

    for round in 0..cfg.train.rounds as u32 {
        // availability at virtual time: spot nodes may be down
        let available: Vec<u32> = cluster
            .nodes
            .iter()
            .filter(|n| n.availability.is_up_at(cfg.seed ^ n.id as u64, now_s))
            .map(|n| n.id)
            .collect();
        if available.is_empty() {
            bail!("round {round}: every node is down");
        }
        let mut round_rng = rng.fork(round as u64);
        let ctx = PlanContext {
            round,
            k: cfg.selection.clients_per_round,
            defaults,
        };
        let plan = planner.plan(&mut registry, &available, &ctx, &mut round_rng);
        let selected = plan.len();

        // per-client virtual finish times under per-client dispatch
        // terms: a client's step count follows its planned epoch
        // budget, its upload its planned compression
        struct Arrival {
            client: u32,
            finish_s: f64,
            epochs: u32,
            up_bytes: u64,
            reports: bool,
        }
        let mut arrivals: Vec<Arrival> = Vec::with_capacity(selected);
        for (c, p) in plan.iter() {
            let node = cluster.node(c).unwrap();
            let action = injector.action(round, c, node.sku.preempt_per_hour > 0.0);
            let t_down = node.transfer_time_s(down_bytes);
            let steps = steps_per_epoch * p.local_epochs as usize;
            let work_s = steps as f64 * timing.ref_step_s;
            let mut t_compute = node.compute_time_s(work_s, &mut round_rng);
            if let FaultAction::Straggle { factor } = action {
                t_compute *= factor;
            }
            let client_up = expected_wire_bytes(params.len(), &p.compression);
            let t_up = node.transfer_time_s(client_up);
            arrivals.push(Arrival {
                client: c,
                finish_s: t_down + t_compute + t_up,
                epochs: p.local_epochs,
                up_bytes: client_up,
                reports: action.reports_update(),
            });
        }
        arrivals.sort_by(|a, b| a.finish_s.total_cmp(&b.finish_s));

        // stopping rule: deadline + partial-k over *reporting*
        // arrivals. Exactly like the real collect phase, the server's
        // cutoff is the *latest* deadline it handed out — per-client
        // deadlines below the max are advisory wire hints (the worker
        // ignores them), so an "early-deadline" client arriving before
        // the cohort max still folds, in sim and real alike.
        let deadline_s = plan.max_deadline_ms() as f64 / 1e3;
        let partial_k = cfg.straggler.partial_k.unwrap_or(usize::MAX);
        let mut reporters: Vec<&Arrival> = Vec::new();
        let mut round_ends_s: f64 = 0.0;
        for a in &arrivals {
            if a.finish_s > deadline_s {
                break;
            }
            if a.reports {
                reporters.push(a);
                round_ends_s = a.finish_s;
                if reporters.len() >= partial_k.min(selected) {
                    break;
                }
            }
        }
        if reporters.is_empty() {
            // nobody made it: round burns the full deadline
            round_ends_s = deadline_s.min(
                arrivals
                    .last()
                    .map(|a| a.finish_s)
                    .unwrap_or(deadline_s),
            );
        } else if reporters.len() < partial_k.min(selected) {
            // waited until deadline for the rest
            let last_wait = arrivals
                .iter()
                .filter(|a| a.finish_s <= deadline_s)
                .map(|a| a.finish_s)
                .fold(0.0, f64::max);
            round_ends_s = round_ends_s.max(last_wait);
        }
        if let Some(map) = &sites {
            // tier-2 hop: a site aggregator can only re-encode and ship
            // its folded delta after its last reporting member lands, so
            // the global round ends at the slowest site's report arrival
            // (per-tier link class via the representative node)
            let mut tier2_end = 0.0f64;
            for site in 0..map.n_sites() {
                let last = reporters
                    .iter()
                    .filter(|a| map.site_of(a.client) == Some(site))
                    .map(|a| a.finish_s)
                    .fold(f64::NEG_INFINITY, f64::max);
                if last.is_finite() {
                    let hop = map
                        .representative(site)
                        .and_then(|r| cluster.node(r))
                        .map_or(0.0, |n| n.transfer_time_s(site_up_bytes));
                    tier2_end = tier2_end.max(last + hop);
                }
            }
            round_ends_s = round_ends_s.max(tier2_end);
        }
        let duration_s = round_ends_s + timing.orchestrator_overhead_s;

        // planner feedback — adaptive/tiered planners learn from
        // virtual round times exactly like the real loop
        for a in &arrivals {
            if a.reports && a.finish_s <= round_ends_s + 1e-9 {
                planner.report_success(&mut registry, a.client, round, a.finish_s * 1e3);
            } else {
                planner.report_failure(&mut registry, a.client, round);
            }
        }

        // optional real training for reporters
        let (train_loss, eval_accuracy, eval_loss, model_delta) = if let (
            Some(ds),
            Some(rt),
        ) = (&dataset, &runtime)
        {
            let mut inputs = Vec::new();
            for a in reporters.iter() {
                let shard = &ds.clients[a.client as usize];
                let out = crate::client::train_local(
                    rt,
                    shard,
                    &params,
                    a.epochs as usize,
                    cfg.train.lr,
                    strategy.mu(),
                    cfg.seed ^ (((round as u64) << 20) | a.client as u64),
                    1.0,
                )?;
                inputs.push(AggInput {
                    client: a.client,
                    delta: out.delta,
                    n_samples: out.n_samples,
                    train_loss: out.train_loss,
                    update_var: out.update_var,
                });
            }
            if inputs.is_empty() {
                (f64::NAN, None, None, 0.0)
            } else {
                let mut agg = RoundAggregator::with_ingest(
                    strategy.clone(),
                    params.len(),
                    scratch.clone(),
                    ingest.clone(),
                );
                let n_params = params.len();
                match &sites {
                    None => {
                        for input in inputs {
                            sim_fold(&mut agg, input, n_params, 1.0)?;
                        }
                    }
                    Some(map) => {
                        // two-tier fold: members fold per site (arrival
                        // order within the site), the root folds each
                        // site's pre-folded mean at its summed weight in
                        // ascending site order — the virtual replay of
                        // the live tree
                        let mut per_site: BTreeMap<usize, Vec<AggInput>> = BTreeMap::new();
                        for input in inputs {
                            let Some(site) = map.site_of(input.client) else {
                                bail!("round {round}: client {} has no site", input.client);
                            };
                            per_site.entry(site).or_default().push(input);
                        }
                        for (site, members) in per_site {
                            if let Some(report) = fold_site_report(
                                map, site, members, n_params, &strategy, &scratch, &ingest,
                            )? {
                                sim_fold(&mut agg, report, n_params, 1.0)?;
                            }
                        }
                    }
                }
                let out = agg.finalize(&params, server_opt.as_mut())?;
                let e = eval.as_ref().unwrap().evaluate(&out.new_params)?;
                let delta =
                    crate::orchestrator::ConvergenceTracker::relative_delta(&params, &out.new_params);
                params = out.new_params;
                (
                    out.mean_train_loss,
                    Some(e.accuracy()),
                    Some(e.mean_loss()),
                    delta,
                )
            }
        } else {
            (f64::NAN, None, None, 0.0)
        };

        now_s += duration_s;
        let n_rep = reporters.len() as u32;
        // byte metrics: flat counts the root's own link; two-tier counts
        // the cross-facility tier only (root ⇄ site aggregators) —
        // intra-site traffic never leaves the facility, and the
        // O(clients) → O(sites) uplink shrink is exactly what
        // BENCH_hierarchy.json measures
        let (bytes_down_round, bytes_up_round) = match &sites {
            None => (
                down_bytes * selected as u64,
                reporters.iter().map(|a| a.up_bytes).sum(),
            ),
            Some(map) => {
                let mut site_members: BTreeMap<usize, u64> = BTreeMap::new();
                for a in &reporters {
                    if let Some(s) = map.site_of(a.client) {
                        *site_members.entry(s).or_default() += 1;
                    }
                }
                if let Some(counters) = &site_counters {
                    for (&site, &n) in &site_members {
                        if let Some(c) = counters.get(site) {
                            c.updates.add(n);
                            c.upstream_bytes.add(site_up_bytes);
                        }
                    }
                }
                (
                    down_bytes * map.n_sites() as u64,
                    site_members.len() as u64 * site_up_bytes,
                )
            }
        };
        details.push(RoundDetail {
            round,
            reporters: reporters.iter().map(|a| (a.client, 0)).collect(),
            end_us: quantize_us(now_s),
        });
        report.push(RoundMetrics {
            round,
            selected: selected as u32,
            reported: n_rep,
            dropped: selected as u32 - n_rep,
            deadline_misses: arrivals
                .iter()
                .filter(|a| a.finish_s > deadline_s)
                .count() as u32,
            train_loss,
            eval_accuracy,
            eval_loss,
            duration_s,
            bytes_down: bytes_down_round,
            bytes_up: bytes_up_round,
            model_delta,
            staleness_min: 0,
            staleness_mean: 0.0,
            staleness_max: 0,
        });

        if with_training {
            if let (Some(acc), Some(target)) = (eval_accuracy, cfg.train.target_accuracy) {
                if acc >= target {
                    report.target_accuracy_at = Some(round);
                    break;
                }
            }
            let _ = &mut tracker;
        }
    }
    if let Some(t) = cfg.train.target_accuracy {
        report.target_accuracy_at = report.target_accuracy_at.or(report.rounds_to_accuracy(t));
    }
    Ok(SimReport {
        total_time_s: now_s,
        model_hash: with_training.then(|| crate::util::hash_f32_bits(&params)),
        details,
        report,
    })
}

/// One in-flight client's eventual arrival at the async server. Under
/// the hierarchy plane the "client" is a whole site (keyed by its
/// representative node): one dispatch runs a batched site round and the
/// arrival carries the site's pre-folded report.
struct AsyncArrival {
    client: u32,
    /// Commit count when the client was dispatched (its base model).
    base_version: u32,
    /// False for injected dropouts/preemptions: the slot comes back,
    /// but nothing folds.
    reports: bool,
    /// Upload size under this client's planned compression (the
    /// cross-facility report size in hierarchy mode).
    up_bytes: u64,
    /// Member updates folded into this arrival (1 flat; the site's
    /// reporting-member count in hierarchy mode) — per-site telemetry.
    member_updates: u64,
    /// The locally-trained update (`with_training` only) — computed at
    /// dispatch against the then-current model, exactly what a real
    /// client would have produced from that broadcast.
    input: Option<AggInput>,
}

/// The buffered-async virtual-time engine (FedBuff; see the module
/// docs and `orchestrator::server` for the real-time counterpart).
/// `cfg.train.rounds` counts commits; every commit closes on exactly
/// `buffer_k` folds (the sim has no wall-clock deadline).
fn run_sim_async(
    cfg: &ExperimentConfig,
    timing: &SimTiming,
    with_training: bool,
    buffer_k: usize,
    max_staleness: u32,
    staleness: StalenessFn,
) -> Result<SimReport> {
    let SimSetup {
        cluster,
        dataset,
        runtime,
        mut params,
        eval,
        mut registry,
        injector,
        steps_per_epoch,
        down_bytes,
        defaults,
    } = setup(cfg, with_training)?;
    let strategy = strategy_registry::strategy_from_config(&cfg.aggregation);
    let mut server_opt = strategy_registry::server_opt_from_config(&cfg.server_opt);
    let mut planner = planner_from_selection(&cfg.selection);
    // one scratch + shard pool for the whole run, like the real loop
    let scratch = Arc::new(ScratchPool::new());
    let ingest = sim_ingest_pool(cfg, params.len());
    // two-tier plane: dispatch granularity becomes the site — one
    // batched site round per dispatch, one pre-folded report per arrival
    let sites = sim_site_map(cfg)?;
    let site_up_bytes = expected_wire_bytes(params.len(), &cfg.compression);
    let site_counters = sites.as_ref().map(|m| sim_site_counters(m.n_sites()));
    let mut rng = Rng::new(cfg.seed ^ 0x51312);
    let mut clock = VirtualClock::new();
    let mut queue: EventQueue<AsyncArrival> = EventQueue::new();
    let mut report = TrainingReport::new(&cfg.name);
    let mut details: Vec<RoundDetail> = Vec::new();

    // jitter stream for compute-time draws, consumed in dispatch order
    // (deterministic because dispatch order is)
    let mut jitter_rng = rng.fork(0x0A57);
    let mut dispatch_seq: u64 = 0;
    let mut commit: u32 = 0;
    let mut bytes_down_total: u64 = 0;
    let mut bytes_up_total: u64 = 0;

    // one dispatch: fault decision, virtual finish time, optional
    // local training against the *current* model (the broadcast the
    // client would have received), all under the client's planned
    // dispatch terms (epoch budget, uplink compression)
    let dispatch = |c: u32,
                        now_s: f64,
                        commit: u32,
                        params: &[f32],
                        plan: &DispatchPlan,
                        dispatch_seq: &mut u64,
                        jitter_rng: &mut Rng,
                        queue: &mut EventQueue<AsyncArrival>,
                        bytes_down_total: &mut u64|
     -> Result<()> {
        let node = cluster
            .node(c)
            .ok_or_else(|| anyhow::anyhow!("unknown client {c}"))?;
        let seq = *dispatch_seq;
        *dispatch_seq += 1;
        // fault oracle keyed by dispatch number: every re-dispatch is a
        // fresh (deterministic) draw, like a fresh round in sync mode
        let action = injector.action(seq as u32, c, node.sku.preempt_per_hour > 0.0);
        let t_down = node.transfer_time_s(down_bytes);
        let steps = steps_per_epoch * plan.local_epochs as usize;
        let work_s = steps as f64 * timing.ref_step_s;
        let up_bytes = expected_wire_bytes(params.len(), &plan.compression);
        let mut t_compute = node.compute_time_s(work_s, jitter_rng);
        let finish_s;
        match action {
            FaultAction::Straggle { factor } => {
                t_compute *= factor;
                finish_s = now_s + t_down + t_compute + node.transfer_time_s(up_bytes);
            }
            FaultAction::Preempt { progress } => {
                // killed partway: the slot frees early, nothing uploads
                finish_s = now_s + t_down + t_compute * progress;
            }
            _ => {
                finish_s = now_s + t_down + t_compute + node.transfer_time_s(up_bytes);
            }
        }
        *bytes_down_total += down_bytes;
        let input = match (&dataset, &runtime) {
            (Some(ds), Some(rt)) if action.reports_update() => {
                let shard = &ds.clients[c as usize];
                let out = crate::client::train_local(
                    rt,
                    shard,
                    params,
                    plan.local_epochs as usize,
                    cfg.train.lr,
                    strategy.mu(),
                    cfg.seed ^ ((seq << 20) | c as u64),
                    1.0,
                )?;
                Some(AggInput {
                    client: c,
                    delta: out.delta,
                    n_samples: out.n_samples,
                    train_loss: out.train_loss,
                    update_var: out.update_var,
                })
            }
            _ => None,
        };
        queue.push(
            finish_s,
            AsyncArrival {
                client: c,
                base_version: commit,
                reports: action.reports_update(),
                up_bytes,
                member_updates: 1,
                input,
            },
        );
        Ok(())
    };

    // one site dispatch (hierarchy mode): run the whole site's member
    // round against the current model — per-member fault/jitter/train
    // draws exactly like flat dispatches — then queue ONE arrival at
    // the site's straggler finish time plus the representative's
    // cross-facility hop, carrying the pre-folded site report
    #[allow(clippy::too_many_arguments)]
    let dispatch_site = |map: &SiteMap,
                         site: usize,
                         now_s: f64,
                         commit: u32,
                         params: &[f32],
                         plans: &BTreeMap<u32, DispatchPlan>,
                         dispatch_seq: &mut u64,
                         jitter_rng: &mut Rng,
                         queue: &mut EventQueue<AsyncArrival>,
                         bytes_down_total: &mut u64|
     -> Result<()> {
        let mut site_finish = now_s;
        let mut member_inputs: Vec<AggInput> = Vec::new();
        let mut member_updates = 0u64;
        for &c in map.members(site) {
            let node = cluster
                .node(c)
                .ok_or_else(|| anyhow::anyhow!("unknown client {c}"))?;
            let seq = *dispatch_seq;
            *dispatch_seq += 1;
            let action = injector.action(seq as u32, c, node.sku.preempt_per_hour > 0.0);
            let p = plans.get(&c).copied().unwrap_or(defaults);
            let t_down = node.transfer_time_s(down_bytes);
            let steps = steps_per_epoch * p.local_epochs as usize;
            let work_s = steps as f64 * timing.ref_step_s;
            let up_bytes = expected_wire_bytes(params.len(), &p.compression);
            let mut t_compute = node.compute_time_s(work_s, jitter_rng);
            let member_finish = match action {
                FaultAction::Straggle { factor } => {
                    t_compute *= factor;
                    now_s + t_down + t_compute + node.transfer_time_s(up_bytes)
                }
                FaultAction::Preempt { progress } => now_s + t_down + t_compute * progress,
                _ => now_s + t_down + t_compute + node.transfer_time_s(up_bytes),
            };
            site_finish = site_finish.max(member_finish);
            if action.reports_update() {
                member_updates += 1;
                if let (Some(ds), Some(rt)) = (&dataset, &runtime) {
                    let shard = &ds.clients[c as usize];
                    let out = crate::client::train_local(
                        rt,
                        shard,
                        params,
                        p.local_epochs as usize,
                        cfg.train.lr,
                        strategy.mu(),
                        cfg.seed ^ ((seq << 20) | c as u64),
                        1.0,
                    )?;
                    member_inputs.push(AggInput {
                        client: c,
                        delta: out.delta,
                        n_samples: out.n_samples,
                        train_loss: out.train_loss,
                        update_var: out.update_var,
                    });
                }
            }
        }
        // one cross-facility broadcast down, one report hop up
        *bytes_down_total += down_bytes;
        let rep = map.representative(site).unwrap_or(0);
        let reports = member_updates > 0;
        if reports {
            let hop = cluster
                .node(rep)
                .map_or(0.0, |n| n.transfer_time_s(site_up_bytes));
            site_finish += hop;
        }
        let input = fold_site_report(
            map,
            site,
            member_inputs,
            params.len(),
            &strategy,
            &scratch,
            &ingest,
        )?;
        queue.push(
            site_finish,
            AsyncArrival {
                client: rep,
                base_version: commit,
                reports,
                up_bytes: site_up_bytes,
                member_updates,
                input,
            },
        );
        Ok(())
    };

    // launch: the selected cohort is the concurrency — every slot stays
    // filled for the whole run (each arrival re-dispatches its client)
    let available: Vec<u32> = cluster
        .nodes
        .iter()
        .filter(|n| n.availability.is_up_at(cfg.seed ^ n.id as u64, 0.0))
        .map(|n| n.id)
        .collect();
    if available.is_empty() {
        bail!("async sim: every node is down at launch");
    }
    let mut round_rng = rng.fork(0);
    let ctx = PlanContext {
        round: 0,
        k: cfg.selection.clients_per_round,
        defaults,
    };
    let launch_plan = planner.plan(&mut registry, &available, &ctx, &mut round_rng);
    if launch_plan.is_empty() {
        bail!("async sim: planner returned no clients");
    }
    // the launch plan's per-client dispatch terms stay with each
    // client for the whole run, exactly like the real async engine
    let plans = launch_plan.to_map();
    let selected: Vec<u32> = launch_plan.cohort().to_vec();
    match &sites {
        None => {
            for (c, p) in launch_plan.iter() {
                dispatch(
                    c,
                    0.0,
                    0,
                    &params,
                    p,
                    &mut dispatch_seq,
                    &mut jitter_rng,
                    &mut queue,
                    &mut bytes_down_total,
                )?;
            }
        }
        Some(map) => {
            // hierarchy: concurrency = sites; every site is launched as
            // one in-flight batched round (members keep their planned
            // per-client dispatch terms where the launch cohort set any)
            for site in 0..map.n_sites() {
                dispatch_site(
                    map,
                    site,
                    0.0,
                    0,
                    &params,
                    &plans,
                    &mut dispatch_seq,
                    &mut jitter_rng,
                    &mut queue,
                    &mut bytes_down_total,
                )?;
            }
        }
    }

    let total_commits = cfg.train.rounds as u32;
    let mut agg = RoundAggregator::with_ingest(
        strategy.clone(),
        params.len(),
        scratch.clone(),
        ingest.clone(),
    );
    let mut folds: Vec<(u32, u32)> = Vec::new();
    let mut stale_drops: u32 = 0;
    let mut silent: u32 = 0;
    let mut last_commit_end_s = 0.0f64;
    let mut last_down = 0u64;
    let mut last_up = 0u64;
    // progress guard: with pathological fault rates (e.g. dropout 1.0)
    // no commit can ever fill — fail loudly instead of spinning
    let max_events = (total_commits as usize)
        .saturating_mul(cluster.len().max(1))
        .saturating_mul(200)
        .max(100_000);
    let mut events = 0usize;
    while commit < total_commits {
        events += 1;
        if events > max_events {
            bail!(
                "async sim: {events} events without finishing {total_commits} commits \
                 (fault rates too high for buffer_k {buffer_k}?)"
            );
        }
        let Some((t, mut arr)) = queue.pop() else {
            bail!("async sim: event queue drained unexpectedly");
        };
        clock.advance_to(t)?;
        if arr.reports {
            bytes_up_total += arr.up_bytes;
            // hierarchy: each arrival closes one site round — the same
            // boundary at which the live aggregator samples its metrics
            if let (Some(map), Some(counters)) = (&sites, &site_counters) {
                if let Some(c) = map.site_of(arr.client).and_then(|s| counters.get(s)) {
                    c.updates.add(arr.member_updates);
                    c.upstream_bytes.add(arr.up_bytes);
                }
            }
            // staleness: commits finished since this client's dispatch
            let s = commit - arr.base_version;
            if s > max_staleness {
                stale_drops += 1;
                planner.report_failure(&mut registry, arr.client, commit);
            } else {
                if let Some(input) = arr.input.take() {
                    sim_fold(&mut agg, input, params.len(), staleness.discount(s))?;
                }
                folds.push((arr.client, s));
                planner.report_success(
                    &mut registry,
                    arr.client,
                    commit,
                    (t - last_commit_end_s).max(0.0) * 1e3,
                );
            }
        } else {
            silent += 1;
            planner.report_failure(&mut registry, arr.client, commit);
        }

        if folds.len() >= buffer_k {
            // close the commit. No per-commit orchestrator overhead:
            // the streaming fold happens as updates arrive, overlapped
            // with client compute (sync rounds pay it because nothing
            // else can run during aggregation+selection)
            let end_s = clock.now_s();
            let (train_loss, eval_accuracy, eval_loss, model_delta) = if with_training {
                let full = std::mem::replace(
                    &mut agg,
                    RoundAggregator::with_ingest(
                        strategy.clone(),
                        params.len(),
                        scratch.clone(),
                        ingest.clone(),
                    ),
                );
                let out = full.finalize(&params, server_opt.as_mut())?;
                let e = eval.as_ref().unwrap().evaluate(&out.new_params)?;
                let delta = crate::orchestrator::ConvergenceTracker::relative_delta(
                    &params,
                    &out.new_params,
                );
                params = out.new_params;
                (
                    out.mean_train_loss,
                    Some(e.accuracy()),
                    Some(e.mean_loss()),
                    delta,
                )
            } else {
                agg = RoundAggregator::with_ingest(
                    strategy.clone(),
                    params.len(),
                    scratch.clone(),
                    ingest.clone(),
                );
                (f64::NAN, None, None, 0.0)
            };
            let (staleness_min, staleness_mean, staleness_max) =
                crate::metrics::staleness_summary(
                    &folds.iter().map(|&(_, s)| s).collect::<Vec<u32>>(),
                );
            details.push(RoundDetail {
                round: commit,
                reporters: std::mem::take(&mut folds),
                end_us: quantize_us(end_s),
            });
            // async metric semantics (shared with the real engine's
            // commit_async): `dropped` = everything that didn't
            // contribute this commit (too stale + silent faults),
            // `deadline_misses` = the too-stale subset
            report.push(RoundMetrics {
                round: commit,
                // hierarchy: the in-flight unit is the site
                selected: sites.as_ref().map_or(selected.len(), SiteMap::n_sites) as u32,
                reported: buffer_k as u32,
                dropped: stale_drops + silent,
                deadline_misses: stale_drops,
                train_loss,
                eval_accuracy,
                eval_loss,
                duration_s: end_s - last_commit_end_s,
                bytes_down: bytes_down_total - last_down,
                bytes_up: bytes_up_total - last_up,
                model_delta,
                staleness_min,
                staleness_mean,
                staleness_max,
            });
            commit += 1;
            stale_drops = 0;
            silent = 0;
            last_commit_end_s = end_s;
            last_down = bytes_down_total;
            last_up = bytes_up_total;
            if let (Some(acc), Some(target)) = (eval_accuracy, cfg.train.target_accuracy) {
                if acc >= target {
                    report.target_accuracy_at = Some(commit - 1);
                    break;
                }
            }
        }
        // the slot is free again: hand the client (or whole site) the
        // current model. Deliberately *after* the commit block,
        // mirroring the real engine's pending-drain ordering — the
        // arrival that fills the buffer is re-dispatched on the
        // post-commit model
        match &sites {
            None => {
                let p = plans.get(&arr.client).copied().unwrap_or(defaults);
                dispatch(
                    arr.client,
                    t,
                    commit,
                    &params,
                    &p,
                    &mut dispatch_seq,
                    &mut jitter_rng,
                    &mut queue,
                    &mut bytes_down_total,
                )?;
            }
            Some(map) => {
                let site = map.site_of(arr.client).unwrap_or(0);
                dispatch_site(
                    map,
                    site,
                    t,
                    commit,
                    &params,
                    &plans,
                    &mut dispatch_seq,
                    &mut jitter_rng,
                    &mut queue,
                    &mut bytes_down_total,
                )?;
            }
        }
    }
    if let Some(t) = cfg.train.target_accuracy {
        report.target_accuracy_at = report.target_accuracy_at.or(report.rounds_to_accuracy(t));
    }
    Ok(SimReport {
        total_time_s: last_commit_end_s,
        model_hash: with_training.then(|| crate::util::hash_f32_bits(&params)),
        details,
        report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{paper_testbed, quickstart};

    fn timing() -> SimTiming {
        SimTiming::default()
    }

    #[test]
    fn pure_timing_run_produces_rounds() {
        let mut cfg = paper_testbed();
        cfg.train.rounds = 5;
        let sim = run_sim(&cfg, &timing(), false).unwrap();
        assert_eq!(sim.report.rounds.len(), 5);
        assert_eq!(sim.details.len(), 5);
        assert!(sim.model_hash.is_none());
        assert!(sim.total_time_s > 0.0);
        for (r, d) in sim.report.rounds.iter().zip(&sim.details) {
            assert!(r.reported > 0, "round {} had no reporters", r.round);
            assert!(r.duration_s > 0.0);
            assert_eq!(d.reporters.len(), r.reported as usize);
            assert!(d.reporters.iter().all(|&(_, s)| s == 0));
        }
    }

    #[test]
    fn more_clients_is_faster_per_data() {
        // Table 3's shape: with samples split over more clients, total
        // time shrinks (each client trains fewer steps)
        let total_samples = 10_240;
        let mut times = Vec::new();
        for n in [10usize, 40] {
            let mut cfg = paper_testbed();
            cfg.cluster.nodes = vec![("hpc-rtx6000".into(), n)];
            cfg.selection.clients_per_round = n;
            cfg.data.samples_per_client = total_samples / n;
            cfg.train.rounds = 5;
            cfg.straggler.partial_k = None;
            let sim = run_sim(&cfg, &timing(), false).unwrap();
            times.push(sim.total_time_s);
        }
        assert!(
            times[1] < times[0] * 0.5,
            "40 clients ({:.1}s) should be ≫ faster than 10 ({:.1}s)",
            times[1],
            times[0]
        );
    }

    #[test]
    fn partial_k_shortens_rounds() {
        let mut cfg = paper_testbed();
        cfg.train.rounds = 5;
        cfg.straggler.partial_k = None;
        cfg.straggler.deadline_ms = None;
        let full = run_sim(&cfg, &timing(), false).unwrap();
        cfg.straggler.partial_k = Some(5);
        let partial = run_sim(&cfg, &timing(), false).unwrap();
        assert!(
            partial.total_time_s < full.total_time_s,
            "partial {:.1}s !< full {:.1}s",
            partial.total_time_s,
            full.total_time_s
        );
    }

    #[test]
    fn training_sim_learns() {
        let mut cfg = quickstart();
        cfg.mock_runtime = true;
        cfg.train.rounds = 8;
        cfg.train.lr = 0.2;
        cfg.train.local_epochs = 1;
        cfg.data.samples_per_client = 64;
        cfg.data.eval_samples = 128;
        cfg.data.partition = crate::config::Partition::Iid;
        let sim = run_sim(&cfg, &timing(), true).unwrap();
        let acc = sim.report.final_accuracy().unwrap();
        assert!(acc > 0.4, "sim training should learn, got {acc}");
        assert!(sim.model_hash.is_some());
    }

    #[test]
    fn training_sim_supports_robust_strategy_and_server_opt() {
        let mut cfg = quickstart();
        cfg.mock_runtime = true;
        cfg.train.rounds = 6;
        cfg.train.lr = 0.2;
        cfg.train.local_epochs = 1;
        cfg.data.samples_per_client = 64;
        cfg.data.eval_samples = 128;
        cfg.data.partition = crate::config::Partition::Iid;
        cfg.aggregation = crate::config::Aggregation::TrimmedMean { trim_frac: 0.2 };
        cfg.server_opt = crate::config::ServerOptKind::FedAvgM { beta: 0.3 };
        let sim = run_sim(&cfg, &timing(), true).unwrap();
        assert_eq!(sim.report.rounds.len(), 6);
        assert!(sim.report.final_accuracy().is_some());
    }

    #[test]
    fn compression_reduces_sim_upload() {
        let mut cfg = paper_testbed();
        cfg.train.rounds = 3;
        cfg.compression = crate::config::CompressionConfig::NONE;
        let none = run_sim(&cfg, &timing(), false).unwrap();
        cfg.compression = crate::config::CompressionConfig::PAPER;
        let comp = run_sim(&cfg, &timing(), false).unwrap();
        let (_, up_none) = none.report.total_bytes();
        let (_, up_comp) = comp.report.total_bytes();
        let ratio = up_comp as f64 / up_none as f64;
        assert!(
            (0.2..0.45).contains(&ratio),
            "compressed/dense upload ratio {ratio}"
        );
    }

    #[test]
    fn deterministic_in_seed() {
        let mut cfg = paper_testbed();
        cfg.train.rounds = 3;
        let a = run_sim(&cfg, &timing(), false).unwrap();
        let b = run_sim(&cfg, &timing(), false).unwrap();
        assert_eq!(a.total_time_s, b.total_time_s);
        assert_eq!(a.details, b.details);
        cfg.seed += 1;
        let c = run_sim(&cfg, &timing(), false).unwrap();
        assert_ne!(a.total_time_s, c.total_time_s);
    }

    fn async_quickstart(buffer_k: usize) -> crate::config::ExperimentConfig {
        let mut cfg = quickstart();
        cfg.mock_runtime = true;
        // homogeneous cluster: injected faults are then the *only*
        // source of staleness, which is what these tests pin
        cfg.cluster.nodes = vec![("hpc-rtx6000".into(), 8)];
        cfg.train.rounds = 6;
        cfg.train.lr = 0.2;
        cfg.train.local_epochs = 1;
        cfg.data.samples_per_client = 64;
        cfg.data.eval_samples = 128;
        cfg.data.partition = crate::config::Partition::Iid;
        cfg.round_mode = RoundMode::BufferedAsync {
            buffer_k,
            max_staleness: 20,
            staleness: StalenessFn::Polynomial { alpha: 0.5 },
        };
        cfg
    }

    #[test]
    fn async_sim_commits_and_learns() {
        let sim = run_sim(&async_quickstart(3), &timing(), true).unwrap();
        assert_eq!(sim.report.rounds.len(), 6);
        assert_eq!(sim.details.len(), 6);
        for (r, d) in sim.report.rounds.iter().zip(&sim.details) {
            assert_eq!(r.reported, 3, "every commit closes on buffer_k folds");
            assert_eq!(d.reporters.len(), 3);
            assert!(r.duration_s > 0.0);
        }
        assert!(sim.model_hash.is_some());
        assert!(sim.report.final_accuracy().is_some());
        // commits close at non-decreasing virtual times
        for w in sim.details.windows(2) {
            assert!(w[0].end_us <= w[1].end_us);
        }
    }

    #[test]
    fn async_sim_pure_timing_runs_without_training() {
        let mut cfg = async_quickstart(4);
        cfg.mock_runtime = false;
        let sim = run_sim(&cfg, &timing(), false).unwrap();
        assert_eq!(sim.report.rounds.len(), 6);
        assert!(sim.model_hash.is_none());
        assert!(sim.total_time_s > 0.0);
    }

    #[test]
    fn async_sim_stragglers_fold_with_staleness() {
        // heavy 4× stragglers: with a small buffer the fast clients
        // race ahead, so straggler arrivals land with staleness > 0 —
        // absorbed, not dropped
        let mut cfg = async_quickstart(2);
        cfg.train.rounds = 12;
        cfg.faults.straggler_prob = 0.5;
        cfg.faults.straggler_factor = 4.0;
        let sim = run_sim(&cfg, &timing(), true).unwrap();
        let max_stale = sim
            .details
            .iter()
            .flat_map(|d| d.reporters.iter().map(|&(_, s)| s))
            .max()
            .unwrap();
        assert!(
            max_stale > 0,
            "expected at least one stale fold under 4x stragglers"
        );
        let dropped: u32 = sim.report.rounds.iter().map(|r| r.deadline_misses).sum();
        assert_eq!(dropped, 0, "within max_staleness nothing is discarded");
    }

    #[test]
    fn async_sim_respects_max_staleness() {
        let mut cfg = async_quickstart(2);
        cfg.train.rounds = 12;
        cfg.faults.straggler_prob = 0.5;
        cfg.faults.straggler_factor = 8.0;
        cfg.round_mode = RoundMode::BufferedAsync {
            buffer_k: 2,
            max_staleness: 0,
            staleness: StalenessFn::Uniform,
        };
        let sim = run_sim(&cfg, &timing(), true).unwrap();
        // every fold in the log is fresh; slower arrivals were dropped
        for d in &sim.details {
            assert!(d.reporters.iter().all(|&(_, s)| s == 0));
        }
        let stale_dropped: u32 = sim.report.rounds.iter().map(|r| r.deadline_misses).sum();
        assert!(
            stale_dropped > 0,
            "8x stragglers with max_staleness 0 must shed stale updates"
        );
    }
}
