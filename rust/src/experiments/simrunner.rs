//! Virtual-time federation (DESIGN.md §4: E2, E5, E7).
//!
//! Reuses the *same* selection, registry, fault and aggregation code as
//! the real loop, but time is discrete-event virtual time derived from
//! the cluster model:
//!
//! ```text
//! t_client = t_down(link, model bytes)
//!          + t_compute(steps × ref_step_s / speed, jitter, straggle)
//!          + t_up(link, compressed bytes)
//! ```
//!
//! The round ends at the partial-k'th arrival, the deadline, or the
//! last arrival — whichever the config dictates. Optionally each
//! reporting client *really trains* (mock runtime) so time-to-accuracy
//! ablations (E7) get honest accuracy curves attached to honest times.

use crate::cluster::{Cluster, Node};
use crate::compress::expected_wire_bytes;
use crate::config::ExperimentConfig;
use crate::data::FederatedDataset;
use crate::faults::{FaultAction, FaultInjector};
use crate::metrics::{RoundMetrics, TrainingReport};
use crate::network::ClientProfile;
use crate::orchestrator::strategy::registry as strategy_registry;
use crate::orchestrator::{select_clients, AggInput, ClientRegistry, EvalHarness, RoundAggregator};
use crate::runtime::{MockRuntime, ModelRuntime};
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// Timing model parameters.
#[derive(Debug, Clone, Copy)]
pub struct SimTiming {
    /// Reference-node seconds per train step (measured once on real
    /// hardware; see EXPERIMENTS.md §Perf for the measured value).
    pub ref_step_s: f64,
    /// Fixed orchestrator overhead per round (selection + aggregation).
    pub orchestrator_overhead_s: f64,
}

impl Default for SimTiming {
    fn default() -> Self {
        SimTiming {
            ref_step_s: 0.015,
            orchestrator_overhead_s: 0.05,
        }
    }
}

/// Virtual-time run result.
#[derive(Debug)]
pub struct SimReport {
    pub report: TrainingReport,
    /// Total virtual seconds.
    pub total_time_s: f64,
}

fn profile_of(node: &Node, n_samples: u64) -> ClientProfile {
    let (bw, _) = node.link().profile();
    ClientProfile {
        speed_factor: node.speed_factor,
        mem_gb: node.sku.mem_gb,
        link_bw: bw,
        n_samples,
        bench_step_ms: 10.0 / node.speed_factor.max(1e-6),
    }
}

/// Run a virtual-time experiment. `with_training=false` skips model
/// math entirely (pure timing, e.g. Table 3); `true` trains a mock
/// model so accuracy-vs-time questions can be answered.
pub fn run_sim(
    cfg: &ExperimentConfig,
    timing: &SimTiming,
    with_training: bool,
) -> Result<SimReport> {
    crate::config::validate(cfg)?;
    let cluster = Cluster::build(&cfg.cluster, cfg.seed)?;
    let n_clients = cluster.len();

    // data + optional mock training state
    #[allow(clippy::type_complexity)]
    let (dataset, runtime, mut params, eval): (
        Option<FederatedDataset>,
        Option<MockRuntime>,
        Vec<f32>,
        Option<EvalHarness>,
    ) = if with_training {
        let ds = FederatedDataset::build(&cfg.data, n_clients, cfg.seed)?;
        if ds.clients[0].y_len != 1 {
            bail!("run_sim with_training requires a scalar-label dataset");
        }
        let rt = MockRuntime::new(ds.clients[0].x_len, ds.n_classes);
        let params = rt.init(cfg.seed as u32)?;
        let eval = EvalHarness {
            runtime: Box::new(MockRuntime::new(ds.clients[0].x_len, ds.n_classes)),
            shard: ds.eval.clone(),
        };
        (Some(ds), Some(rt), params, Some(eval))
    } else {
        // pure-timing: P from the artifact manifest if present, else a
        // representative 250k-param model
        let p = crate::runtime::Manifest::load(&cfg.artifacts_dir)
            .ok()
            .and_then(|m| m.model(&cfg.data.dataset).ok().map(|i| i.n_params))
            .unwrap_or(250_000);
        (None, None, vec![0f32; p], None)
    };
    let n_params = params.len();

    let mut registry = ClientRegistry::new();
    let samples = cfg.data.samples_per_client as u64;
    for node in &cluster.nodes {
        registry.register(node.id, profile_of(node, samples));
    }
    let injector = FaultInjector::new(cfg.faults, cfg.seed);
    // same strategy/server-opt plumbing as the real loop; optimizer
    // state (momentum etc.) carries across virtual rounds
    let strategy = strategy_registry::strategy_from_config(&cfg.aggregation);
    let mut server_opt = strategy_registry::server_opt_from_config(&cfg.server_opt);
    let mut rng = Rng::new(cfg.seed ^ 0x51312);
    let mut now_s = 0.0f64;
    let mut report = TrainingReport::new(&cfg.name);
    let mut tracker = crate::orchestrator::ConvergenceTracker::new(
        cfg.train.converge_eps,
        cfg.train.converge_patience,
        cfg.train.target_accuracy,
    );

    let steps_per_round = {
        // ceil(samples / batch) × epochs, batch 16 (mock) or artifact
        let batch = runtime.as_ref().map_or(16, |r| r.train_batch());
        cfg.data.samples_per_client.div_ceil(batch) * cfg.train.local_epochs
    };
    let down_bytes = 4 * n_params as u64;
    let up_bytes = expected_wire_bytes(n_params, &cfg.compression);

    for round in 0..cfg.train.rounds as u32 {
        // availability at virtual time: spot nodes may be down
        let available: Vec<u32> = cluster
            .nodes
            .iter()
            .filter(|n| n.availability.is_up_at(cfg.seed ^ n.id as u64, now_s))
            .map(|n| n.id)
            .collect();
        if available.is_empty() {
            bail!("round {round}: every node is down");
        }
        let mut round_rng = rng.fork(round as u64);
        let selected = select_clients(
            &mut registry,
            &available,
            &cfg.selection,
            round,
            &mut round_rng,
        );

        // per-client virtual finish times
        struct Arrival {
            client: u32,
            finish_s: f64,
            reports: bool,
        }
        let mut arrivals: Vec<Arrival> = Vec::with_capacity(selected.len());
        for &c in &selected {
            let node = cluster.node(c).unwrap();
            let action = injector.action(round, c, node.sku.preempt_per_hour > 0.0);
            let t_down = node.transfer_time_s(down_bytes);
            let work_s = steps_per_round as f64 * timing.ref_step_s;
            let mut t_compute = node.compute_time_s(work_s, &mut round_rng);
            if let FaultAction::Straggle { factor } = action {
                t_compute *= factor;
            }
            let t_up = node.transfer_time_s(up_bytes);
            arrivals.push(Arrival {
                client: c,
                finish_s: t_down + t_compute + t_up,
                reports: action.reports_update(),
            });
        }
        arrivals.sort_by(|a, b| a.finish_s.total_cmp(&b.finish_s));

        // stopping rule: deadline + partial-k over *reporting* arrivals
        let deadline_s = cfg
            .straggler
            .deadline_ms
            .map(|d| d as f64 / 1e3)
            .unwrap_or(f64::INFINITY);
        let partial_k = cfg.straggler.partial_k.unwrap_or(usize::MAX);
        let mut reporters: Vec<&Arrival> = Vec::new();
        let mut round_ends_s: f64 = 0.0;
        for a in &arrivals {
            if a.finish_s > deadline_s {
                break;
            }
            if a.reports {
                reporters.push(a);
                round_ends_s = a.finish_s;
                if reporters.len() >= partial_k.min(selected.len()) {
                    break;
                }
            }
        }
        if reporters.is_empty() {
            // nobody made it: round burns the full deadline
            round_ends_s = deadline_s.min(
                arrivals
                    .last()
                    .map(|a| a.finish_s)
                    .unwrap_or(deadline_s),
            );
        } else if reporters.len() < partial_k.min(selected.len()) {
            // waited until deadline for the rest
            let last_wait = arrivals
                .iter()
                .filter(|a| a.finish_s <= deadline_s)
                .map(|a| a.finish_s)
                .fold(0.0, f64::max);
            round_ends_s = round_ends_s.max(last_wait);
        }
        let duration_s = round_ends_s + timing.orchestrator_overhead_s;

        // registry feedback — the adaptive policy learns from virtual time
        for a in &arrivals {
            if a.reports && a.finish_s <= round_ends_s + 1e-9 {
                registry.report_success(a.client, round, a.finish_s * 1e3);
            } else {
                registry.report_failure(a.client, round);
            }
        }

        // optional real training for reporters
        let (train_loss, eval_accuracy, eval_loss, model_delta) = if let (
            Some(ds),
            Some(rt),
        ) = (&dataset, &runtime)
        {
            let mut inputs = Vec::new();
            for a in reporters.iter() {
                let shard = &ds.clients[a.client as usize];
                let out = crate::client::train_local(
                    rt,
                    shard,
                    &params,
                    cfg.train.local_epochs,
                    cfg.train.lr,
                    strategy.mu(),
                    cfg.seed ^ (((round as u64) << 20) | a.client as u64),
                    1.0,
                )?;
                inputs.push(AggInput {
                    client: a.client,
                    delta: out.delta,
                    n_samples: out.n_samples,
                    train_loss: out.train_loss,
                    update_var: out.update_var,
                });
            }
            if inputs.is_empty() {
                (f64::NAN, None, None, 0.0)
            } else {
                let mut agg = RoundAggregator::new(strategy.clone(), params.len());
                for input in &inputs {
                    agg.fold(input)?;
                }
                let out = agg.finalize(&params, server_opt.as_mut())?;
                let e = eval.as_ref().unwrap().evaluate(&out.new_params)?;
                let delta =
                    crate::orchestrator::ConvergenceTracker::relative_delta(&params, &out.new_params);
                params = out.new_params;
                (
                    out.mean_train_loss,
                    Some(e.accuracy()),
                    Some(e.mean_loss()),
                    delta,
                )
            }
        } else {
            (f64::NAN, None, None, 0.0)
        };

        now_s += duration_s;
        let n_rep = reporters.len() as u32;
        report.push(RoundMetrics {
            round,
            selected: selected.len() as u32,
            reported: n_rep,
            dropped: selected.len() as u32 - n_rep,
            deadline_misses: arrivals
                .iter()
                .filter(|a| a.finish_s > deadline_s)
                .count() as u32,
            train_loss,
            eval_accuracy,
            eval_loss,
            duration_s,
            bytes_down: down_bytes * selected.len() as u64,
            bytes_up: up_bytes * n_rep as u64,
            model_delta,
        });

        if with_training {
            if let (Some(acc), Some(target)) = (eval_accuracy, cfg.train.target_accuracy) {
                if acc >= target {
                    report.target_accuracy_at = Some(round);
                    break;
                }
            }
            let _ = &mut tracker;
        }
    }
    if let Some(t) = cfg.train.target_accuracy {
        report.target_accuracy_at = report.target_accuracy_at.or(report.rounds_to_accuracy(t));
    }
    Ok(SimReport {
        total_time_s: now_s,
        report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{paper_testbed, quickstart};

    fn timing() -> SimTiming {
        SimTiming::default()
    }

    #[test]
    fn pure_timing_run_produces_rounds() {
        let mut cfg = paper_testbed();
        cfg.train.rounds = 5;
        let sim = run_sim(&cfg, &timing(), false).unwrap();
        assert_eq!(sim.report.rounds.len(), 5);
        assert!(sim.total_time_s > 0.0);
        for r in &sim.report.rounds {
            assert!(r.reported > 0, "round {} had no reporters", r.round);
            assert!(r.duration_s > 0.0);
        }
    }

    #[test]
    fn more_clients_is_faster_per_data() {
        // Table 3's shape: with samples split over more clients, total
        // time shrinks (each client trains fewer steps)
        let total_samples = 10_240;
        let mut times = Vec::new();
        for n in [10usize, 40] {
            let mut cfg = paper_testbed();
            cfg.cluster.nodes = vec![("hpc-rtx6000".into(), n)];
            cfg.selection.clients_per_round = n;
            cfg.data.samples_per_client = total_samples / n;
            cfg.train.rounds = 5;
            cfg.straggler.partial_k = None;
            let sim = run_sim(&cfg, &timing(), false).unwrap();
            times.push(sim.total_time_s);
        }
        assert!(
            times[1] < times[0] * 0.5,
            "40 clients ({:.1}s) should be ≫ faster than 10 ({:.1}s)",
            times[1],
            times[0]
        );
    }

    #[test]
    fn partial_k_shortens_rounds() {
        let mut cfg = paper_testbed();
        cfg.train.rounds = 5;
        cfg.straggler.partial_k = None;
        cfg.straggler.deadline_ms = None;
        let full = run_sim(&cfg, &timing(), false).unwrap();
        cfg.straggler.partial_k = Some(5);
        let partial = run_sim(&cfg, &timing(), false).unwrap();
        assert!(
            partial.total_time_s < full.total_time_s,
            "partial {:.1}s !< full {:.1}s",
            partial.total_time_s,
            full.total_time_s
        );
    }

    #[test]
    fn training_sim_learns() {
        let mut cfg = quickstart();
        cfg.mock_runtime = true;
        cfg.train.rounds = 8;
        cfg.train.lr = 0.2;
        cfg.train.local_epochs = 1;
        cfg.data.samples_per_client = 64;
        cfg.data.eval_samples = 128;
        cfg.data.partition = crate::config::Partition::Iid;
        let sim = run_sim(&cfg, &timing(), true).unwrap();
        let acc = sim.report.final_accuracy().unwrap();
        assert!(acc > 0.4, "sim training should learn, got {acc}");
    }

    #[test]
    fn training_sim_supports_robust_strategy_and_server_opt() {
        let mut cfg = quickstart();
        cfg.mock_runtime = true;
        cfg.train.rounds = 6;
        cfg.train.lr = 0.2;
        cfg.train.local_epochs = 1;
        cfg.data.samples_per_client = 64;
        cfg.data.eval_samples = 128;
        cfg.data.partition = crate::config::Partition::Iid;
        cfg.aggregation = crate::config::Aggregation::TrimmedMean { trim_frac: 0.2 };
        cfg.server_opt = crate::config::ServerOptKind::FedAvgM { beta: 0.3 };
        let sim = run_sim(&cfg, &timing(), true).unwrap();
        assert_eq!(sim.report.rounds.len(), 6);
        assert!(sim.report.final_accuracy().is_some());
    }

    #[test]
    fn compression_reduces_sim_upload() {
        let mut cfg = paper_testbed();
        cfg.train.rounds = 3;
        cfg.compression = crate::config::CompressionConfig::NONE;
        let none = run_sim(&cfg, &timing(), false).unwrap();
        cfg.compression = crate::config::CompressionConfig::PAPER;
        let comp = run_sim(&cfg, &timing(), false).unwrap();
        let (_, up_none) = none.report.total_bytes();
        let (_, up_comp) = comp.report.total_bytes();
        let ratio = up_comp as f64 / up_none as f64;
        assert!(
            (0.2..0.45).contains(&ratio),
            "compressed/dense upload ratio {ratio}"
        );
    }

    #[test]
    fn deterministic_in_seed() {
        let mut cfg = paper_testbed();
        cfg.train.rounds = 3;
        let a = run_sim(&cfg, &timing(), false).unwrap();
        let b = run_sim(&cfg, &timing(), false).unwrap();
        assert_eq!(a.total_time_s, b.total_time_s);
        cfg.seed += 1;
        let c = run_sim(&cfg, &timing(), false).unwrap();
        assert_ne!(a.total_time_s, c.total_time_s);
    }
}
