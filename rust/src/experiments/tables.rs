//! Table/figure harnesses: each function regenerates one paper
//! artifact, prints the same rows the paper reports, and saves the
//! underlying series under `results/` (CSV for Fig 2 curves, JSON for
//! everything). Paper numbers quoted in comments for side-by-side
//! reading; EXPERIMENTS.md records paper-vs-measured.
//!
//! `quick=true` shrinks every workload to smoke-test size (mock
//! runtime, few rounds) so the whole suite runs in CI seconds.

use super::launcher::run_real;
use super::simrunner::{run_sim, SimTiming};
use crate::config::{
    presets::paper_testbed, Aggregation, CompressionConfig, ExperimentConfig, Partition,
    SelectionPolicy,
};
use crate::metrics::TrainingReport;
use crate::util::human_bytes;
use anyhow::Result;

fn out(dir: &str, rep: &TrainingReport) {
    if let Err(e) = rep.save(dir) {
        log::warn!("saving report failed: {e}");
    }
}

/// Base config for accuracy experiments (real training).
fn accuracy_cfg(dataset: &str, quick: bool) -> ExperimentConfig {
    let mut cfg = paper_testbed();
    cfg.data.dataset = dataset.into();
    cfg.data.partition = Partition::LabelShard {
        classes_per_client: 2,
    };
    if quick {
        cfg.mock_runtime = true; // only valid for scalar-label tasks
        cfg.cluster.nodes = vec![("hpc-rtx6000".into(), 6)];
        cfg.selection.clients_per_round = 4;
        cfg.train.rounds = 4;
        cfg.train.local_epochs = 1;
        cfg.data.samples_per_client = 64;
        cfg.data.eval_samples = 128;
        cfg.straggler = crate::config::StragglerConfig::default();
    } else {
        // tractable-on-CPU scale that preserves the paper's structure:
        // heterogeneous 12-node cluster, 8 clients/round
        cfg.cluster.nodes = vec![
            ("p3.2xlarge".into(), 3),
            ("t3.large".into(), 3),
            ("hpc-rtx6000".into(), 4),
            ("hpc-cpu".into(), 2),
        ];
        cfg.selection.clients_per_round = 8;
        cfg.train.rounds = 12; // tractable on the 1-vCPU testbed
        cfg.train.local_epochs = 2;
        cfg.data.samples_per_client = 128;
        cfg.data.eval_samples = 512;
        cfg.straggler.deadline_ms = Some(600_000);
        cfg.straggler.partial_k = None;
    }
    cfg.compression = CompressionConfig::NONE;
    cfg
}

/// Table 2 + Fig 2: FedAvg vs FedProx accuracy on the three datasets
/// under non-IID partitioning. Paper: CIFAR-10 81.7/83.2, Shakespeare
/// 57.9/59.3, MedMNIST 89.3/90.1 — FedProx wins everywhere; we check
/// the ordering and save per-round curves (Fig 2).
pub fn table2(quick: bool, out_dir: &str) -> Result<()> {
    let datasets: &[&str] = if quick {
        &["medmnist_mlp"]
    } else {
        &["cifar_cnn", "charlm", "medmnist_mlp"]
    };
    println!("\n=== Table 2: FedAvg vs FedProx (non-IID) ===");
    println!("{:<14} {:>10} {:>10}", "dataset", "FedAvg", "FedProx");
    for ds in datasets {
        let mut accs = Vec::new();
        // strategies selected by registry name — the same string axis
        // config files and the CLI use
        for agg_name in ["fedavg", "fedprox:0.05"] {
            let agg = Aggregation::parse(agg_name)?;
            let mut cfg = accuracy_cfg(ds, quick);
            if *ds == "charlm" {
                cfg.mock_runtime = false; // LM needs the real runtime
                cfg.train.lr = 0.3;
            }
            if *ds == "cifar_cnn" && !quick {
                cfg.train.lr = 0.02;
            }
            cfg.aggregation = agg;
            cfg.name = format!("table2_{ds}_{}", agg.name());
            let rep = run_real(&cfg)?;
            out(out_dir, &rep); // per-round series = Fig 2 source
            accs.push(rep.best_accuracy().unwrap_or(0.0));
        }
        println!(
            "{:<14} {:>9.1}% {:>9.1}%",
            ds,
            accs[0] * 100.0,
            accs[1] * 100.0
        );
    }
    println!("(paper: cifar 81.7/83.2, shakespeare 57.9/59.3, medmnist 89.3/90.1)");
    Ok(())
}

/// Table 3: scalability — total training time and speedup from 10 to 60
/// clients over a fixed global workload. Paper: 100→22 min, 4.55×.
pub fn table3(quick: bool, out_dir: &str) -> Result<()> {
    let rounds = if quick { 5 } else { 100 };
    let total_samples = 61_440; // divisible by 10..60
    println!("\n=== Table 3: scalability (virtual time) ===");
    println!(
        "{:>8} {:>14} {:>10}",
        "clients", "total time", "speedup"
    );
    let mut base_time = None;
    let mut rows = Vec::new();
    for n in [10usize, 20, 30, 40, 50, 60] {
        let mut cfg = paper_testbed();
        // keep the paper's hybrid mix ratio at every scale
        let gpu_cloud = n / 6 + usize::from(n % 6 > 3);
        let cpu_cloud = n / 4;
        let gpu_hpc = n / 3;
        let cpu_hpc = n - gpu_cloud - cpu_cloud - gpu_hpc;
        cfg.cluster.nodes = vec![
            ("p3.2xlarge".into(), gpu_cloud),
            ("t3.large".into(), cpu_cloud),
            ("hpc-rtx6000".into(), gpu_hpc),
            ("hpc-cpu".into(), cpu_hpc),
        ];
        cfg.selection.clients_per_round = (n * 2 / 3).max(1);
        cfg.data.samples_per_client = total_samples / n;
        cfg.train.rounds = rounds;
        cfg.straggler.partial_k = Some((cfg.selection.clients_per_round * 3 / 5).max(1));
        cfg.name = format!("table3_{n}clients");
        // average over seeds: the per-instance speed lottery + adaptive
        // selection make single runs noisy at small n
        let seeds = [7u64, 8, 9];
        let mut t = 0.0;
        for &s in &seeds {
            cfg.seed = s;
            let sim = run_sim(&cfg, &SimTiming::default(), false)?;
            t += sim.total_time_s / seeds.len() as f64;
            if s == seeds[0] {
                out(out_dir, &sim.report);
            }
        }
        let speedup = base_time.get_or_insert(t).max(1e-9) / t * 1.0;
        let speedup = if n == 10 { 1.0 } else { speedup };
        println!(
            "{:>8} {:>12.1} m {:>9.2}x",
            n,
            t / 60.0,
            speedup
        );
        rows.push((n, t, speedup));
    }
    println!("(paper: 10→100 min 1.00x … 60→22 min 4.55x)");
    Ok(())
}

/// Table 4: communication volume per round with vs without compression
/// over rounds 1–10. Paper: ~45 MB → ~15 MB (≈65% reduction).
pub fn table4(quick: bool, out_dir: &str) -> Result<()> {
    let mut base = accuracy_cfg("medmnist_mlp", quick);
    base.train.rounds = if quick { 3 } else { 10 };
    base.mock_runtime = quick;
    println!("\n=== Table 4: per-round communication volume ===");
    println!(
        "{:>6} {:>18} {:>18}",
        "round", "no compression", "with compression"
    );
    let mut reports = Vec::new();
    for (label, comp) in [
        ("none", CompressionConfig::NONE),
        ("paper", CompressionConfig::PAPER),
    ] {
        let mut cfg = base.clone();
        cfg.compression = comp;
        cfg.name = format!("table4_{label}");
        let rep = run_real(&cfg)?;
        out(out_dir, &rep);
        reports.push(rep);
    }
    let rounds = reports[0].rounds.len().min(reports[1].rounds.len());
    for i in 0..rounds {
        println!(
            "{:>6} {:>18} {:>18}",
            i + 1,
            human_bytes(reports[0].rounds[i].bytes_up),
            human_bytes(reports[1].rounds[i].bytes_up),
        );
    }
    let (u0, u1) = (
        reports[0].mean_upload_per_round(),
        reports[1].mean_upload_per_round(),
    );
    println!(
        "mean upload/round: {} -> {} ({:.0}% reduction; paper ≈65%)",
        human_bytes(u0 as u64),
        human_bytes(u1 as u64),
        (1.0 - u1 / u0) * 100.0
    );
    Ok(())
}

/// §5.4 straggler resilience: 20% dropouts per round must cost <~2%
/// final accuracy (paper: <1.8%).
pub fn straggler(quick: bool, out_dir: &str) -> Result<()> {
    let mut base = accuracy_cfg("medmnist_mlp", quick);
    base.mock_runtime = true; // accuracy-delta experiment: mock suffices + fast
    // mock compute is ms-scale: a short deadline keeps dropout rounds
    // from burning 60 s each waiting for clients that will never report
    base.straggler.deadline_ms = Some(3_000);
    base.straggler.partial_k = None;
    if !quick {
        base.train.rounds = 25;
        base.cluster.nodes = vec![("hpc-rtx6000".into(), 12)];
        base.selection.clients_per_round = 8;
    }
    println!("\n=== §5.4 straggler resilience (20% dropouts) ===");
    let mut accs = Vec::new();
    for (label, p) in [("baseline", 0.0), ("dropout20", 0.2)] {
        let mut cfg = base.clone();
        cfg.faults.dropout_prob = p;
        cfg.name = format!("straggler_{label}");
        let rep = run_real(&cfg)?;
        out(out_dir, &rep);
        accs.push(rep.best_accuracy().unwrap_or(0.0));
    }
    println!(
        "baseline {:.1}%  with-dropouts {:.1}%  drop {:.2} pp (paper <1.8 pp)",
        accs[0] * 100.0,
        accs[1] * 100.0,
        (accs[0] - accs[1]) * 100.0
    );
    Ok(())
}

/// §5.5 ablation: disabling adaptive selection → +12% round duration.
pub fn ablation_selection(quick: bool, out_dir: &str) -> Result<()> {
    let rounds = if quick { 10 } else { 60 };
    println!("\n=== §5.5 ablation: adaptive selection ===");
    let mut durs = Vec::new();
    for (label, policy) in [
        ("adaptive", SelectionPolicy::default()),
        ("random", SelectionPolicy::Random),
    ] {
        let mut cfg = paper_testbed();
        cfg.train.rounds = rounds;
        cfg.selection.policy = policy;
        cfg.straggler.partial_k = None; // isolate the selection effect
        cfg.straggler.deadline_ms = Some(3_600_000);
        cfg.name = format!("ablation_selection_{label}");
        let sim = run_sim(&cfg, &SimTiming::default(), false)?;
        out(out_dir, &sim.report);
        durs.push(sim.total_time_s / rounds as f64);
    }
    println!(
        "mean round: adaptive {:.1}s, random {:.1}s → +{:.0}% without adaptive (paper +12%)",
        durs[0],
        durs[1],
        (durs[1] / durs[0] - 1.0) * 100.0
    );
    Ok(())
}

/// §5.5 ablation: disabling compression → +70% bandwidth.
pub fn ablation_compression(quick: bool, out_dir: &str) -> Result<()> {
    let mut base = accuracy_cfg("medmnist_mlp", true);
    base.mock_runtime = true;
    base.train.rounds = if quick { 3 } else { 10 };
    println!("\n=== §5.5 ablation: communication compression ===");
    let mut ups = Vec::new();
    for (label, comp) in [
        ("with", CompressionConfig::PAPER),
        ("without", CompressionConfig::NONE),
    ] {
        let mut cfg = base.clone();
        cfg.compression = comp;
        cfg.name = format!("ablation_compression_{label}");
        let rep = run_real(&cfg)?;
        out(out_dir, &rep);
        ups.push(rep.mean_upload_per_round());
    }
    println!(
        "upload/round: with {} → without {} (+{:.0}%; paper +70%)",
        human_bytes(ups[0] as u64),
        human_bytes(ups[1] as u64),
        (ups[1] / ups[0] - 1.0) * 100.0
    );
    Ok(())
}

/// §5.5 ablation: disabling straggler mitigation → 15–20% longer to
/// reach 80% accuracy (virtual time, with real mock training).
pub fn ablation_straggler(quick: bool, out_dir: &str) -> Result<()> {
    println!("\n=== §5.5 ablation: straggler mitigation ===");
    let target = if quick { 0.5 } else { 0.8 };
    let mut times = Vec::new();
    for (label, mitigated) in [("with", true), ("without", false)] {
        let mut cfg = paper_testbed();
        cfg.mock_runtime = true;
        cfg.data.dataset = "medmnist_mlp".into();
        cfg.data.partition = Partition::LabelShard {
            classes_per_client: 3,
        };
        cfg.data.samples_per_client = if quick { 64 } else { 192 };
        cfg.data.eval_samples = if quick { 128 } else { 512 };
        cfg.train.rounds = if quick { 10 } else { 60 };
        cfg.train.lr = 0.2;
        cfg.train.local_epochs = if quick { 1 } else { 2 };
        cfg.train.target_accuracy = Some(target);
        cfg.faults.straggler_prob = 0.25;
        cfg.faults.straggler_factor = 6.0;
        if mitigated {
            cfg.straggler.deadline_ms = Some(120_000);
            cfg.straggler.partial_k = Some(16);
        } else {
            cfg.straggler.deadline_ms = None;
            cfg.straggler.partial_k = None;
        }
        cfg.name = format!("ablation_straggler_{label}");
        let sim = run_sim(&cfg, &SimTiming::default(), true)?;
        out(out_dir, &sim.report);
        let t = sim
            .report
            .time_to_accuracy(target)
            .unwrap_or(sim.total_time_s);
        times.push(t);
    }
    println!(
        "virtual time to {:.0}% acc: with {:.1}s, without {:.1}s (+{:.0}%; paper +15–20%)",
        target * 100.0,
        times[0],
        times[1],
        (times[1] / times[0] - 1.0) * 100.0
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Quick-mode smoke tests for every harness that doesn't need PJRT
    // artifacts. table2 quick-mode uses the mock runtime.

    #[test]
    fn table3_quick() {
        table3(true, "/tmp/fedhpc_test_results").unwrap();
    }

    #[test]
    fn table4_quick() {
        table4(true, "/tmp/fedhpc_test_results").unwrap();
    }

    #[test]
    fn straggler_quick() {
        straggler(true, "/tmp/fedhpc_test_results").unwrap();
    }

    #[test]
    fn ablation_selection_quick() {
        ablation_selection(true, "/tmp/fedhpc_test_results").unwrap();
    }

    #[test]
    fn ablation_compression_quick() {
        ablation_compression(true, "/tmp/fedhpc_test_results").unwrap();
    }

    #[test]
    fn ablation_straggler_quick() {
        ablation_straggler(true, "/tmp/fedhpc_test_results").unwrap();
    }

    #[test]
    fn table2_quick() {
        table2(true, "/tmp/fedhpc_test_results").unwrap();
    }
}
