//! Federation launcher: config → running system.
//!
//! Builds the simulated heterogeneous cluster, partitions the dataset,
//! creates one worker thread per node and runs the orchestrator round
//! loop to completion. This is the single entry point examples, the
//! CLI and the accuracy experiments share.
//!
//! Transport selection follows the cluster backends: configs naming a
//! `"grpc"` backend (the paper testbed's cloud side) run over the real
//! TCP stack on loopback — reactor, framing, negotiated compression
//! and all — while everything else stays on the in-process transport
//! (microsecond latency, the default for tests).

use crate::client::{Worker, WorkerOptions};
use crate::cluster::{Cluster, Node, SiteMap};
use crate::config::ExperimentConfig;
use crate::data::{FederatedDataset, Shard};
use crate::faults::FaultInjector;
use crate::metrics::TrainingReport;
use crate::network::inproc::InprocHub;
use crate::network::tcp::{TcpClient, TcpServer};
use crate::network::transport::{ClientTransport, ServerTransport};
use crate::network::{LinkShaper, Msg, TrafficLog};
use crate::orchestrator::{Aggregator, EvalHarness, NoHooks, Orchestrator, OrchestratorHooks};
use crate::runtime::{MockRuntime, ModelRuntime, PjrtRuntime};
use anyhow::{bail, Context, Result};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Build a runtime for `cfg`'s model. Mock runtimes only support
/// scalar-label tasks (y_len == 1).
fn build_runtime(
    cfg: &ExperimentConfig,
    sample: &Shard,
    n_classes: usize,
) -> Result<Box<dyn ModelRuntime>> {
    if cfg.mock_runtime {
        if sample.y_len != 1 {
            bail!(
                "mock runtime supports scalar-label tasks only (dataset {} has y_len {})",
                cfg.data.dataset,
                sample.y_len
            );
        }
        let mut rt = MockRuntime::new(sample.x_len, n_classes);
        rt.train_batch = 16;
        rt.eval_batch = 32;
        Ok(Box::new(rt))
    } else {
        let rt = PjrtRuntime::load(&cfg.artifacts_dir, &cfg.data.dataset)
            .with_context(|| format!("loading PJRT runtime for {}", cfg.data.dataset))?;
        Ok(Box::new(rt))
    }
}

/// Run a full federated training experiment in-process.
pub fn run_real(cfg: &ExperimentConfig) -> Result<TrainingReport> {
    run_real_with_control(cfg, &mut NoHooks, None)
}

/// Like [`run_real`] but with per-round hooks for harnesses.
pub fn run_real_with_hooks(
    cfg: &ExperimentConfig,
    hooks: &mut dyn OrchestratorHooks,
) -> Result<TrainingReport> {
    run_real_with_control(cfg, hooks, None)
}

/// Like [`run_real_with_hooks`] but attaching an operator control
/// plane ([`crate::telemetry::ControlPlane`]): the orchestrator drains
/// its mailbox at round/commit boundaries and publishes readiness +
/// status through it. `None` behaves exactly like plain hooks.
pub fn run_real_with_control(
    cfg: &ExperimentConfig,
    hooks: &mut dyn OrchestratorHooks,
    control: Option<Arc<crate::telemetry::ControlPlane>>,
) -> Result<TrainingReport> {
    crate::config::validate(cfg)?;
    let cluster = Cluster::build(&cfg.cluster, cfg.seed)?;
    let n_clients = cluster.len();
    log::info!("cluster: {}", cluster.describe());
    let dataset = FederatedDataset::build(&cfg.data, n_clients, cfg.seed)?;

    let traffic = Arc::new(TrafficLog::new());

    // PJRT: one shared service (clones share compiled executables);
    // mock: cheap per-worker instances.
    let shared_pjrt: Option<PjrtRuntime> = if cfg.mock_runtime {
        None
    } else {
        Some(
            PjrtRuntime::load(&cfg.artifacts_dir, &cfg.data.dataset)
                .with_context(|| format!("loading PJRT runtime for {}", cfg.data.dataset))?,
        )
    };
    let worker_runtime = |shard: &Shard| -> Result<Box<dyn ModelRuntime>> {
        match &shared_pjrt {
            Some(rt) => Ok(Box::new(rt.clone())),
            None => build_runtime(cfg, shard, dataset.n_classes),
        }
    };

    // initial global model
    let eval_runtime = worker_runtime(&dataset.eval)?;
    let initial = eval_runtime.init(cfg.seed as u32)?;
    let eval = EvalHarness {
        runtime: eval_runtime,
        shard: dataset.eval.clone(),
    };

    // hierarchical plane (config `hierarchy`): root ⇄ site aggregators
    // ⇄ workers, all in-process — multi-process trees deploy via
    // `serve --role aggregator` instead. The launcher's shared traffic
    // log sees only the tier-2 (cross-facility) hop; each site hub runs
    // its own intra-facility log, so `report.total_bytes()` measures
    // exactly the traffic that would cross facilities.
    if cfg.hierarchy.enabled() {
        let map = SiteMap::build(&cfg.cluster, cfg.hierarchy.grouping)?;
        log::info!(
            "hierarchy: {} sites under '{}' (launcher trees run in-process)",
            map.n_sites(),
            cfg.hierarchy.grouping.spec()
        );
        let root_hub = InprocHub::new(traffic.clone());
        let mut handles = Vec::with_capacity(n_clients + map.n_sites());
        for site in 0..map.n_sites() {
            let members = map.members(site).to_vec();
            let rep = map
                .representative(site)
                .ok_or_else(|| anyhow::anyhow!("site {site} has no members"))?;
            let rep_node = cluster
                .node(rep)
                .ok_or_else(|| anyhow::anyhow!("unknown representative node {rep}"))?;
            // the site's upstream leg rides the representative's link
            let upstream = root_hub.add_client(rep, LinkShaper::from_class(rep_node.link()));
            let site_hub = InprocHub::new(Arc::new(TrafficLog::new()));
            for &m in &members {
                let node = cluster
                    .node(m)
                    .ok_or_else(|| anyhow::anyhow!("unknown node {m}"))?;
                let shard = dataset
                    .clients
                    .get(m as usize)
                    .ok_or_else(|| anyhow::anyhow!("no shard for node {m}"))?;
                let endpoint = site_hub.add_client(m, LinkShaper::from_class(node.link()));
                let runtime = worker_runtime(shard)?;
                handles.push(spawn_worker(cfg, endpoint, runtime, node, shard)?);
            }
            let mut agg =
                Aggregator::new(cfg.clone(), site, initial.len(), site_hub.server(), upstream);
            let expected = members.len();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("site-agg-{site}"))
                    .spawn(move || agg.run(expected, Duration::from_secs(60)))
                    .context("spawning site aggregator thread")?,
            );
        }
        // the root sees one "client" per site: select every site each
        // round, never cut a site off at partial-k, and double the
        // round budget (site aggregators hand members 3/4 of theirs)
        let mut root_cfg = cfg.clone();
        root_cfg.selection.clients_per_round = map.n_sites();
        root_cfg.straggler.partial_k = None;
        root_cfg.straggler.deadline_ms =
            cfg.straggler.deadline_ms.map(|d| d.saturating_mul(2));
        return orchestrate(
            &root_cfg,
            root_hub.server(),
            traffic,
            initial,
            eval,
            map.n_sites(),
            handles,
            hooks,
            control,
        );
    }

    // transport by backend name: "grpc" anywhere means the real TCP
    // stack over loopback; otherwise the in-process hub
    let use_tcp =
        cfg.cluster.cloud_backend == "grpc" || cfg.cluster.hpc_backend == "grpc";

    let mut handles = Vec::with_capacity(n_clients);
    if use_tcp {
        let server = TcpServer::bind_with("127.0.0.1:0", &cfg.transport, traffic.clone())?;
        let addr = server.local_addr.to_string();
        for (node, shard) in cluster.nodes.iter().zip(&dataset.clients) {
            let runtime = worker_runtime(shard)?;
            let profile = crate::client::profile_runtime(runtime.as_ref(), node, shard, 0)?;
            let endpoint = TcpClient::connect_with(
                &addr,
                &Msg::Register {
                    client: node.id,
                    profile,
                },
                LinkShaper::from_class(node.link()),
                // one shared log: server records down on flush, each
                // client records its own up on send — same split as
                // the multi-process deployment
                traffic.clone(),
                cfg.transport.compression,
            )?;
            handles.push(spawn_worker(cfg, endpoint, runtime, node, shard)?);
        }
        orchestrate(cfg, server, traffic, initial, eval, n_clients, handles, hooks, control)
    } else {
        let hub = InprocHub::new(traffic.clone());
        for (node, shard) in cluster.nodes.iter().zip(&dataset.clients) {
            let endpoint = hub.add_client(node.id, LinkShaper::from_class(node.link()));
            let runtime = worker_runtime(shard)?;
            handles.push(spawn_worker(cfg, endpoint, runtime, node, shard)?);
        }
        orchestrate(cfg, hub.server(), traffic, initial, eval, n_clients, handles, hooks, control)
    }
}

/// Spawn one worker thread over any client transport.
fn spawn_worker<T: ClientTransport + Send + 'static>(
    cfg: &ExperimentConfig,
    endpoint: T,
    runtime: Box<dyn ModelRuntime>,
    node: &Node,
    shard: &Shard,
) -> Result<JoinHandle<Result<u64>>> {
    let worker = Worker::new(
        endpoint,
        runtime,
        node.clone(),
        shard.clone(),
        FaultInjector::new(cfg.faults, cfg.seed),
        WorkerOptions {
            emulate_speed: true,
            max_slowdown: 4.0,
            bench_steps: 0,
            seed: cfg.seed ^ node.id as u64,
        },
    );
    let name = format!("worker-{}", node.id);
    std::thread::Builder::new()
        .name(name)
        .spawn(move || worker.run())
        .context("spawning worker thread")
}

/// Run the orchestrator round loop over any server transport and
/// reap the worker threads.
#[allow(clippy::too_many_arguments)]
fn orchestrate<T: ServerTransport>(
    cfg: &ExperimentConfig,
    transport: T,
    traffic: Arc<TrafficLog>,
    initial: Vec<f32>,
    eval: EvalHarness,
    n_clients: usize,
    handles: Vec<JoinHandle<Result<u64>>>,
    hooks: &mut dyn OrchestratorHooks,
    control: Option<Arc<crate::telemetry::ControlPlane>>,
) -> Result<TrainingReport> {
    // strategy + server optimizer come from the config's registry names
    let mut builder = Orchestrator::builder(cfg.clone())
        .transport(transport)
        .traffic(traffic)
        .initial_params(initial)
        .eval(eval);
    if let Some(cp) = control {
        builder = builder.control(cp);
    }
    let mut orch = builder.build()?;
    let report = orch.run(Some((n_clients, Duration::from_secs(60))), hooks)?;

    for h in handles {
        match h.join() {
            Ok(Ok(_rounds)) => {}
            Ok(Err(e)) => log::warn!("worker error: {e}"),
            Err(_) => log::warn!("worker panicked"),
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets::quickstart, Partition};

    /// End-to-end federation over the mock runtime: 8 heterogeneous
    /// clients, real threads, real transport, real aggregation.
    #[test]
    fn mock_federation_learns() {
        let mut cfg = quickstart();
        cfg.mock_runtime = true;
        cfg.train.rounds = 6;
        cfg.train.local_epochs = 1;
        cfg.train.lr = 0.2;
        cfg.selection.clients_per_round = 4;
        cfg.data.samples_per_client = 96;
        cfg.data.eval_samples = 256;
        cfg.data.partition = Partition::Iid;
        let report = run_real(&cfg).unwrap();
        assert!(!report.rounds.is_empty());
        let final_acc = report.final_accuracy().unwrap();
        assert!(
            final_acc > 0.5,
            "mock federation should beat 10-way chance easily, got {final_acc}"
        );
        // traffic was accounted
        let (down, up) = report.total_bytes();
        assert!(down > 0 && up > 0);
    }

    /// The paper testbed names a "grpc" backend — that must select the
    /// real TCP stack (reactor + framing + negotiated compression) on
    /// loopback, and still learn + account traffic end-to-end.
    #[test]
    fn mock_federation_over_tcp_loopback() {
        let mut cfg = quickstart();
        cfg.mock_runtime = true;
        cfg.cluster.cloud_backend = "grpc".into();
        cfg.train.rounds = 3;
        cfg.train.local_epochs = 1;
        cfg.train.lr = 0.2;
        cfg.selection.clients_per_round = 4;
        cfg.data.samples_per_client = 64;
        cfg.data.eval_samples = 128;
        cfg.data.partition = Partition::Iid;
        let report = run_real(&cfg).unwrap();
        assert_eq!(report.rounds.len(), 3);
        assert!(report.final_accuracy().is_some());
        // traffic crossed real sockets in both directions
        let (down, up) = report.total_bytes();
        assert!(down > 0 && up > 0, "down {down} up {up}");
    }

    #[test]
    fn mock_federation_with_faults_still_trains() {
        let mut cfg = quickstart();
        cfg.mock_runtime = true;
        cfg.train.rounds = 4;
        cfg.train.local_epochs = 1;
        cfg.faults.dropout_prob = 0.25;
        cfg.data.samples_per_client = 64;
        cfg.data.eval_samples = 128;
        cfg.straggler.deadline_ms = Some(15_000);
        let report = run_real(&cfg).unwrap();
        // some rounds must have fewer reporters than selected
        let total_dropped: u32 = report.rounds.iter().map(|r| r.dropped).sum();
        assert!(total_dropped > 0, "expected injected dropouts");
        assert!(report.final_accuracy().is_some());
    }

    /// Two-tier in-process tree: 8 workers under 2 site aggregators.
    /// The root folds pre-folded site reports and the federation still
    /// learns; every round commits with both sites reporting, and the
    /// shared traffic log counts only the tier-2 (cross-facility) hop.
    #[test]
    fn hierarchical_federation_learns() {
        let mut cfg = quickstart();
        cfg.mock_runtime = true;
        cfg.train.rounds = 6;
        cfg.train.local_epochs = 1;
        cfg.train.lr = 0.2;
        cfg.data.samples_per_client = 96;
        cfg.data.eval_samples = 256;
        cfg.data.partition = Partition::Iid;
        cfg.hierarchy.grouping = crate::config::GroupingPolicy::Site { sites: 2 };
        let report = run_real(&cfg).unwrap();
        assert_eq!(report.rounds.len(), 6);
        let final_acc = report.final_accuracy().unwrap();
        assert!(
            final_acc > 0.5,
            "tree federation should learn, got {final_acc}"
        );
        for r in &report.rounds {
            assert_eq!(r.selected, 2, "root must select every site");
            assert_eq!(r.reported, 2, "round {} lost a site report", r.round);
        }
    }

    #[test]
    fn charlm_requires_real_runtime() {
        let mut cfg = quickstart();
        cfg.mock_runtime = true;
        cfg.data.dataset = "charlm".into();
        assert!(run_real(&cfg).is_err());
    }
}
