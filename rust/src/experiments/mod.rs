//! Paper experiment reproductions (DESIGN.md §4 experiment index).
//!
//! * [`launcher`] — wires cluster + data + transports + workers +
//!   orchestrator into a real in-process federation (`run_real`).
//! * [`simrunner`] — the virtual-time counterpart for timing
//!   experiments (Table 3, ablations E5/E7).
//! * [`tables`] — one entry point per paper table/figure; each prints
//!   the same rows the paper reports and saves CSV/JSON under
//!   `results/`.

pub mod launcher;
pub mod simrunner;
pub mod tables;

pub use launcher::{run_real, run_real_with_control, run_real_with_hooks};
pub use simrunner::{run_sim, RoundDetail, SimReport, SimTiming};

use anyhow::{bail, Result};

/// Experiment ids accepted by `fedhpc experiment --id <id>`.
pub const EXPERIMENTS: &[(&str, &str)] = &[
    ("table2", "Accuracy: FedAvg vs FedProx on 3 datasets (Table 2 + Fig 2 series)"),
    ("table3", "Scalability: total time / speedup at 10–60 clients (Table 3)"),
    ("table4", "Communication volume with/without compression (Table 4)"),
    ("straggler", "Fault tolerance: 20% dropouts vs baseline (§5.4)"),
    ("ablation-selection", "Ablation: adaptive selection off → +round time (§5.5)"),
    ("ablation-compression", "Ablation: compression off → +bandwidth (§5.5)"),
    ("ablation-straggler", "Ablation: straggler mitigation off → +time-to-80% (§5.5)"),
];

/// Dispatch an experiment by id. `quick` shrinks workloads for smoke
/// runs (used by tests); the full-size run regenerates the paper rows.
pub fn run(id: &str, quick: bool, out_dir: &str) -> Result<()> {
    match id {
        "table2" => tables::table2(quick, out_dir),
        "table3" => tables::table3(quick, out_dir),
        "table4" => tables::table4(quick, out_dir),
        "straggler" => tables::straggler(quick, out_dir),
        "ablation-selection" => tables::ablation_selection(quick, out_dir),
        "ablation-compression" => tables::ablation_compression(quick, out_dir),
        "ablation-straggler" => tables::ablation_straggler(quick, out_dir),
        "all" => {
            for (id, _) in EXPERIMENTS {
                run(id, quick, out_dir)?;
            }
            Ok(())
        }
        other => bail!(
            "unknown experiment '{other}'; available: {:?}",
            EXPERIMENTS.iter().map(|(i, _)| *i).collect::<Vec<_>>()
        ),
    }
}
