//! Property-testing harness (proptest is not vendored on this image).
//!
//! [`check`] runs a property over N generated cases; on failure it
//! reports the case seed so the exact input replays with
//! `FEDHPC_PROP_SEED=<seed>`. `FEDHPC_PROP_CASES=<n>` overrides every
//! property's case count (the `PROPTEST_CASES` convention) — CI pins
//! it so runs are reproducible and time-bounded; locally leave it
//! unset for each property's default. [`Gen`] wraps the in-tree RNG
//! with generator combinators for the shapes our invariants need
//! (vectors, ranges, weights). Used by `rust/tests/prop_*.rs` for
//! coordinator invariants (selection, aggregation, codecs, wire
//! format, faults).

use crate::util::rng::Rng;

/// Case generator handed to properties.
pub struct Gen {
    pub rng: Rng,
    /// Size hint for collections this case (grows across cases).
    pub size: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.f32()
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Normal-distributed f32 vector of generated length ≤ size.
    pub fn f32_vec(&mut self, max_len: usize) -> Vec<f32> {
        let n = self.usize_in(1, max_len.max(1));
        (0..n).map(|_| self.rng.normal() as f32).collect()
    }

    /// Vector with occasional pathological values (zeros, huge, tiny,
    /// repeated) — the cases uniform sampling misses.
    pub fn f32_vec_nasty(&mut self, max_len: usize) -> Vec<f32> {
        let mut v = self.f32_vec(max_len);
        let n = v.len();
        for _ in 0..self.usize_in(0, n.min(8)) {
            let i = self.rng.below(n);
            v[i] = match self.rng.below(5) {
                0 => 0.0,
                1 => 1e30,
                2 => -1e30,
                3 => 1e-30,
                _ => v[self.rng.below(n)], // duplicate (ties)
            };
        }
        v
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Run `prop` over `cases` generated cases (overridden by
/// `FEDHPC_PROP_CASES` when set). Panics with the failing seed on the
/// first violation.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut prop: F) {
    // replay mode
    if let Ok(seed) = std::env::var("FEDHPC_PROP_SEED") {
        let seed: u64 = seed.parse().expect("FEDHPC_PROP_SEED must be a u64");
        let mut g = Gen {
            rng: Rng::new(seed),
            size: 64,
        };
        prop(&mut g);
        return;
    }
    let cases = match std::env::var("FEDHPC_PROP_CASES") {
        Ok(n) => n.parse().expect("FEDHPC_PROP_CASES must be a usize"),
        Err(_) => cases,
    };
    let base = 0xF00D_0000u64;
    for case in 0..cases {
        let seed = base + case as u64;
        let mut g = Gen {
            rng: Rng::new(seed),
            // ramp sizes so early failures are small
            size: 4 + case * 97 / cases.max(1),
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g);
        }));
        if let Err(e) = result {
            eprintln!(
                "\nproperty '{name}' FAILED on case {case} — replay with FEDHPC_PROP_SEED={seed}\n"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 50, |g| {
            let v = g.f32_vec(100);
            assert!(!v.is_empty());
        });
    }

    #[test]
    #[should_panic]
    fn check_propagates_failures() {
        check("failing", 50, |g| {
            let n = g.usize_in(0, 100);
            assert!(n < 90, "boom at {n}");
        });
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen {
            rng: Rng::new(1),
            size: 10,
        };
        for _ in 0..1000 {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..=1.0).contains(&f));
        }
    }

    #[test]
    fn nasty_vectors_contain_pathologies_sometimes() {
        let mut saw_zero = false;
        let mut saw_huge = false;
        for seed in 0..200 {
            let mut g = Gen {
                rng: Rng::new(seed),
                size: 64,
            };
            let v = g.f32_vec_nasty(64);
            saw_zero |= v.contains(&0.0);
            saw_huge |= v.iter().any(|&x| x.abs() >= 1e30);
        }
        assert!(saw_zero && saw_huge);
    }
}
