//! Local pass-through adapter: every submitted job runs immediately on
//! its own "node" (thread). Used for in-process development runs where
//! queueing behaviour is not under study.

use super::job::{Job, JobId, JobState};
use super::SchedulerAdapter;
use crate::cluster::NodeId;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

#[derive(Default)]
pub struct LocalAdapter {
    jobs: BTreeMap<JobId, (Job, JobState)>,
    next_id: JobId,
    now_s: f64,
}

impl LocalAdapter {
    pub fn new() -> Self {
        LocalAdapter {
            next_id: 1,
            ..Default::default()
        }
    }
}

impl SchedulerAdapter for LocalAdapter {
    fn submit(&mut self, job: Job) -> Result<JobId> {
        let id = self.next_id;
        self.next_id += 1;
        let node = job.client; // 1:1 — the client's own node
        self.jobs.insert(
            id,
            (
                job,
                JobState::Running {
                    node,
                    since_s: self.now_s,
                },
            ),
        );
        Ok(id)
    }

    fn tick(&mut self, now_s: f64) -> Vec<(JobId, JobState)> {
        self.now_s = now_s;
        let mut changes = Vec::new();
        for (&id, (job, st)) in self.jobs.iter_mut() {
            if let JobState::Running { since_s, .. } = *st {
                if now_s - since_s >= job.walltime_s {
                    *st = JobState::Completed { at_s: now_s };
                    changes.push((id, *st));
                }
            }
        }
        changes
    }

    fn state(&self, id: JobId) -> Option<JobState> {
        self.jobs.get(&id).map(|(_, s)| *s)
    }

    fn allocated_nodes(&self) -> Vec<NodeId> {
        self.jobs
            .values()
            .filter_map(|(_, s)| match s {
                JobState::Running { node, .. } => Some(*node),
                _ => None,
            })
            .collect()
    }

    fn cancel(&mut self, id: JobId) -> Result<()> {
        let (_, st) = self
            .jobs
            .get_mut(&id)
            .ok_or_else(|| anyhow!("local: no such job {id}"))?;
        if !st.is_terminal() {
            *st = JobState::Cancelled;
        }
        Ok(())
    }

    fn queue_summary(&self) -> String {
        format!(
            "local: {} running",
            self.jobs
                .values()
                .filter(|(_, s)| s.is_running())
                .count()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn immediate_start_and_completion() {
        let mut l = LocalAdapter::new();
        let id = l
            .submit(Job {
                client: 7,
                partition: "any".into(),
                priority: 0,
                walltime_s: 5.0,
                preemptible: false,
            })
            .unwrap();
        assert!(l.state(id).unwrap().is_running());
        assert_eq!(l.allocated_nodes(), vec![7]);
        let ch = l.tick(5.0);
        assert_eq!(ch.len(), 1);
        assert!(l.state(id).unwrap().is_terminal());
    }

    #[test]
    fn cancel() {
        let mut l = LocalAdapter::new();
        let id = l
            .submit(Job {
                client: 1,
                partition: "any".into(),
                priority: 0,
                walltime_s: 100.0,
                preemptible: false,
            })
            .unwrap();
        l.cancel(id).unwrap();
        assert_eq!(l.state(id), Some(JobState::Cancelled));
        assert!(l.cancel(42).is_err());
    }
}
