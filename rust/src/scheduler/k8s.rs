//! Kubernetes-like pod orchestration simulation.
//!
//! Models what matters for FL-on-cloud (paper §3.2): node pools with
//! autoscaling (pods pending until the pool scales up, with a scale-up
//! delay), pod startup latency (image pull + container start), and
//! spot-pool evictions expressed as preemptions. No partitions or
//! priorities — cloud capacity is elastic but not instant.

use super::job::{Job, JobId, JobState};
use super::SchedulerAdapter;
use crate::cluster::NodeId;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// A node pool with autoscaling bounds.
#[derive(Debug, Clone)]
pub struct Pool {
    pub name: String,
    /// Nodes pre-provisioned at start.
    pub initial: Vec<NodeId>,
    /// Extra node ids the autoscaler may bring up, in order.
    pub scale_reserve: Vec<NodeId>,
    /// Seconds for a new node to become Ready.
    pub scale_up_delay_s: f64,
}

struct Entry {
    job: Job,
    state: JobState,
    submit_seq: u64,
}

struct PoolState {
    pool: Pool,
    /// Ready nodes (provisioned and past their ready time).
    ready: Vec<NodeId>,
    /// (node, ready_at) nodes still provisioning.
    warming: Vec<(NodeId, f64)>,
    /// How many reserve nodes already used.
    used_reserve: usize,
}

/// The simulated cluster.
pub struct K8sSim {
    pools: BTreeMap<String, PoolState>,
    busy: BTreeMap<NodeId, JobId>,
    jobs: BTreeMap<JobId, Entry>,
    next_id: JobId,
    seq: u64,
    now_s: f64,
    /// Pod startup latency applied to every placement.
    pub pod_start_delay_s: f64,
    /// (job, node, starts_at) pods scheduled but still starting.
    starting: Vec<(JobId, NodeId, f64)>,
}

impl K8sSim {
    pub fn new(pools: Vec<Pool>) -> Self {
        K8sSim {
            pools: pools
                .into_iter()
                .map(|p| {
                    let ready = p.initial.clone();
                    (
                        p.name.clone(),
                        PoolState {
                            pool: p,
                            ready,
                            warming: Vec::new(),
                            used_reserve: 0,
                        },
                    )
                })
                .collect(),
            busy: BTreeMap::new(),
            jobs: BTreeMap::new(),
            next_id: 1,
            seq: 0,
            now_s: 0.0,
            pod_start_delay_s: 3.0,
            starting: Vec::new(),
        }
    }

    fn schedule(&mut self, changes: &mut Vec<(JobId, JobState)>) {
        let mut pending: Vec<JobId> = self
            .jobs
            .iter()
            .filter(|(_, e)| e.state == JobState::Pending)
            .map(|(&id, _)| id)
            .collect();
        pending.sort_by_key(|id| self.jobs[id].submit_seq);
        for id in pending {
            // already queued to start?
            if self.starting.iter().any(|(j, _, _)| *j == id) {
                continue;
            }
            let pool_name = self.jobs[&id].job.partition.clone();
            let Some(ps) = self.pools.get_mut(&pool_name) else {
                continue;
            };
            // find a free ready node
            let free = ps
                .ready
                .iter()
                .copied()
                .find(|n| !self.busy.contains_key(n));
            if let Some(node) = free {
                self.busy.insert(node, id);
                self.starting
                    .push((id, node, self.now_s + self.pod_start_delay_s));
            } else if ps.used_reserve < ps.pool.scale_reserve.len() {
                // autoscale: provision a reserve node
                let node = ps.pool.scale_reserve[ps.used_reserve];
                ps.used_reserve += 1;
                ps.warming
                    .push((node, self.now_s + ps.pool.scale_up_delay_s));
                log::debug!("k8s: scaling up pool {pool_name} with node {node}");
            }
        }
        let _ = changes;
    }
}

/// Kubernetes probe wiring for an orchestrator pod exposing the
/// telemetry endpoint (`--telemetry-addr`): liveness hits `/healthz`
/// (process up), readiness hits `/readyz` (first round dispatched —
/// workers pointed at a Service stay out of rotation until the round
/// loop is actually live). `telemetry_addr` is the bind address the
/// orchestrator was started with, e.g. "0.0.0.0:9469"; only its port
/// lands in the manifest.
pub fn probe_manifest_snippet(telemetry_addr: &str) -> String {
    let port = telemetry_addr.rsplit(':').next().unwrap_or("9469");
    format!(
        "livenessProbe:\n\
         \x20 httpGet:\n\
         \x20   path: /healthz\n\
         \x20   port: {port}\n\
         \x20 initialDelaySeconds: 5\n\
         \x20 periodSeconds: 10\n\
         readinessProbe:\n\
         \x20 httpGet:\n\
         \x20   path: /readyz\n\
         \x20   port: {port}\n\
         \x20 initialDelaySeconds: 2\n\
         \x20 periodSeconds: 5\n"
    )
}

impl SchedulerAdapter for K8sSim {
    fn submit(&mut self, job: Job) -> Result<JobId> {
        if !self.pools.contains_key(&job.partition) {
            bail!(
                "k8s: no such pool '{}' (have: {:?})",
                job.partition,
                self.pools.keys().collect::<Vec<_>>()
            );
        }
        let id = self.next_id;
        self.next_id += 1;
        self.seq += 1;
        self.jobs.insert(
            id,
            Entry {
                job,
                state: JobState::Pending,
                submit_seq: self.seq,
            },
        );
        Ok(id)
    }

    fn tick(&mut self, now_s: f64) -> Vec<(JobId, JobState)> {
        assert!(now_s >= self.now_s, "time went backwards");
        self.now_s = now_s;
        let mut changes = Vec::new();
        // warmed nodes become ready
        for ps in self.pools.values_mut() {
            let (ready, still): (Vec<_>, Vec<_>) =
                ps.warming.drain(..).partition(|(_, at)| *at <= now_s);
            ps.ready.extend(ready.into_iter().map(|(n, _)| n));
            ps.warming = still;
        }
        // starting pods become Running
        let (started, still): (Vec<_>, Vec<_>) = self
            .starting
            .drain(..)
            .partition(|(_, _, at)| *at <= now_s);
        self.starting = still;
        for (id, node, _) in started {
            let st = JobState::Running {
                node,
                since_s: now_s,
            };
            self.jobs.get_mut(&id).unwrap().state = st;
            changes.push((id, st));
        }
        // walltime completions
        let done: Vec<(JobId, NodeId)> = self
            .jobs
            .iter()
            .filter_map(|(&id, e)| match e.state {
                JobState::Running { node, since_s }
                    if now_s - since_s >= e.job.walltime_s =>
                {
                    Some((id, node))
                }
                _ => None,
            })
            .collect();
        for (id, node) in done {
            self.busy.remove(&node);
            let st = JobState::Completed { at_s: now_s };
            self.jobs.get_mut(&id).unwrap().state = st;
            changes.push((id, st));
        }
        self.schedule(&mut changes);
        changes
    }

    fn state(&self, id: JobId) -> Option<JobState> {
        self.jobs.get(&id).map(|e| e.state)
    }

    fn allocated_nodes(&self) -> Vec<NodeId> {
        self.busy.keys().copied().collect()
    }

    fn cancel(&mut self, id: JobId) -> Result<()> {
        let e = self
            .jobs
            .get_mut(&id)
            .ok_or_else(|| anyhow!("k8s: no such pod {id}"))?;
        if e.state.is_terminal() {
            return Ok(());
        }
        if let JobState::Running { node, .. } = e.state {
            self.busy.remove(&node);
        }
        self.starting.retain(|(j, n, _)| {
            if *j == id {
                self.busy.remove(n);
                false
            } else {
                true
            }
        });
        e.state = JobState::Cancelled;
        Ok(())
    }

    fn queue_summary(&self) -> String {
        let pending = self
            .jobs
            .values()
            .filter(|e| e.state == JobState::Pending)
            .count();
        let running = self.jobs.values().filter(|e| e.state.is_running()).count();
        format!(
            "k8s: {} pools, {running} running, {pending} pending, {} starting",
            self.pools.len(),
            self.starting.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_manifest_uses_port_and_both_endpoints() {
        let y = probe_manifest_snippet("0.0.0.0:9469");
        assert!(y.contains("path: /healthz"));
        assert!(y.contains("path: /readyz"));
        assert_eq!(y.matches("port: 9469").count(), 2);
        // parses as indented YAML-ish lines, not one blob
        assert!(y.lines().count() >= 10);
    }

    fn pod(client: NodeId, pool: &str) -> Job {
        Job {
            client,
            partition: pool.into(),
            priority: 0,
            walltime_s: 100.0,
            preemptible: false,
        }
    }

    fn sim() -> K8sSim {
        K8sSim::new(vec![Pool {
            name: "gpu".into(),
            initial: vec![0, 1],
            scale_reserve: vec![2, 3],
            scale_up_delay_s: 30.0,
        }])
    }

    #[test]
    fn pod_start_delay_applies() {
        let mut s = sim();
        let a = s.submit(pod(1, "gpu")).unwrap();
        s.tick(0.0);
        assert_eq!(s.state(a), Some(JobState::Pending)); // still starting
        s.tick(3.0);
        assert!(s.state(a).unwrap().is_running());
    }

    #[test]
    fn autoscaler_provisions_reserve_nodes() {
        let mut s = sim();
        for i in 0..4 {
            s.submit(pod(i, "gpu")).unwrap();
        }
        s.tick(0.0);
        s.tick(3.0); // pods on the 2 initial nodes running
        let running = |s: &K8sSim| {
            s.jobs
                .values()
                .filter(|e| e.state.is_running())
                .count()
        };
        assert_eq!(running(&s), 2);
        // scale-up kicks in for the remaining two after 30s + pod delay
        s.tick(31.0);
        s.tick(35.0);
        assert_eq!(running(&s), 4, "{}", s.queue_summary());
    }

    #[test]
    fn no_capacity_beyond_reserve() {
        let mut s = sim();
        for i in 0..6 {
            s.submit(pod(i, "gpu")).unwrap();
        }
        for t in [0.0, 3.0, 31.0, 35.0, 100.0] {
            s.tick(t);
        }
        // only 4 nodes exist: 2 initial + 2 reserve; after walltime the
        // last 2 pods finally run
        s.tick(104.0);
        let running = s.jobs.values().filter(|e| e.state.is_running()).count();
        assert!(running >= 1, "{}", s.queue_summary());
    }

    #[test]
    fn cancel_during_start_frees_node() {
        let mut s = sim();
        let a = s.submit(pod(1, "gpu")).unwrap();
        s.tick(0.0);
        s.cancel(a).unwrap();
        assert_eq!(s.state(a), Some(JobState::Cancelled));
        let b = s.submit(pod(2, "gpu")).unwrap();
        s.tick(1.0);
        s.tick(4.5);
        assert!(s.state(b).unwrap().is_running());
    }

    #[test]
    fn unknown_pool_rejected() {
        let mut s = sim();
        assert!(s.submit(pod(1, "tpu")).is_err());
    }
}
