//! Scheduler adapter (paper §3.2 "Scheduler Adapter").
//!
//! One trait, three backends:
//! * [`SlurmSim`] — batch scheduler with partitions, FIFO + priority
//!   queueing, node exclusivity and preemption (the HPC side).
//! * [`K8sSim`] — pod orchestration with autoscaling (the cloud side).
//! * [`HybridScheduler`] — routes jobs across both by domain, the
//!   paper's "hybrid coordination capability".
//! * [`LocalAdapter`] — trivial pass-through for in-process runs.
//!
//! The FL launcher asks the scheduler for worker placements; the
//! simulators model queue wait and allocation lifecycles so that
//! "requesting 20 workers on a busy SLURM partition" behaves like it
//! does in real deployments (delayed starts = stragglers at round 0).

mod hybrid;
mod job;
mod k8s;
mod local;
mod slurm;

pub use hybrid::HybridScheduler;
pub use job::{Job, JobId, JobState, Placement};
pub use k8s::{probe_manifest_snippet, K8sSim, Pool};
pub use local::LocalAdapter;
pub use slurm::{health_check_script, SlurmSim};

use crate::cluster::NodeId;
use anyhow::Result;

/// Abstraction over resource managers (SLURM, Kubernetes, hybrid).
pub trait SchedulerAdapter: Send {
    /// Submit a job requesting one node; returns its id.
    fn submit(&mut self, job: Job) -> Result<JobId>;

    /// Advance the scheduler's virtual clock to `now_s`, processing
    /// queue movements. Returns jobs that changed state.
    fn tick(&mut self, now_s: f64) -> Vec<(JobId, JobState)>;

    /// Current state of a job.
    fn state(&self, id: JobId) -> Option<JobState>;

    /// Nodes currently allocated to running jobs.
    fn allocated_nodes(&self) -> Vec<NodeId>;

    /// Cancel a job (scancel / pod delete).
    fn cancel(&mut self, id: JobId) -> Result<()>;

    /// Human-readable queue summary (squeue / kubectl get pods).
    fn queue_summary(&self) -> String;
}
