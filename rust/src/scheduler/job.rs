//! Job model shared by all scheduler backends.

use crate::cluster::NodeId;

pub type JobId = u64;

/// A request for one worker placement.
#[derive(Debug, Clone, PartialEq)]
pub struct Job {
    /// FL client this job will host.
    pub client: NodeId,
    /// Partition (SLURM) / node pool (K8s) name, e.g. "gpu", "cpu".
    pub partition: String,
    /// Higher runs earlier within a partition.
    pub priority: i32,
    /// Requested wall time (seconds); the sim releases the node after.
    pub walltime_s: f64,
    /// Whether the job may be preempted by higher-priority arrivals.
    pub preemptible: bool,
}

/// Lifecycle: Pending → Running → {Completed, Cancelled, Preempted}.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum JobState {
    Pending,
    Running { node: NodeId, since_s: f64 },
    Completed { at_s: f64 },
    Cancelled,
    Preempted { at_s: f64 },
}

impl JobState {
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            JobState::Completed { .. } | JobState::Cancelled | JobState::Preempted { .. }
        )
    }

    pub fn is_running(&self) -> bool {
        matches!(self, JobState::Running { .. })
    }
}

/// A granted placement: which node hosts which client.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Placement {
    pub job: JobId,
    pub client: NodeId,
    pub node: NodeId,
    pub start_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_predicates() {
        assert!(!JobState::Pending.is_terminal());
        assert!(JobState::Running {
            node: 1,
            since_s: 0.0
        }
        .is_running());
        assert!(JobState::Completed { at_s: 5.0 }.is_terminal());
        assert!(JobState::Cancelled.is_terminal());
        assert!(JobState::Preempted { at_s: 1.0 }.is_terminal());
    }
}
