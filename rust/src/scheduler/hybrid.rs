//! Hybrid scheduler: routes jobs to SLURM (HPC) or K8s (cloud) by
//! partition prefix — the paper's "hybrid coordination capabilities,
//! facilitating scheduling across both HPC and cloud resources".
//!
//! Partition names `hpc:<partition>` go to SLURM; `cloud:<pool>` go to
//! K8s. Job ids are made globally unique by an origin bit.

use super::job::{Job, JobId, JobState};
use super::{K8sSim, SchedulerAdapter, SlurmSim};
use crate::cluster::NodeId;
use anyhow::{bail, Result};

const CLOUD_BIT: JobId = 1 << 62;

pub struct HybridScheduler {
    slurm: SlurmSim,
    k8s: K8sSim,
}

impl HybridScheduler {
    pub fn new(slurm: SlurmSim, k8s: K8sSim) -> Self {
        HybridScheduler { slurm, k8s }
    }

    fn route(partition: &str) -> Result<(bool, String)> {
        if let Some(p) = partition.strip_prefix("hpc:") {
            Ok((false, p.to_string()))
        } else if let Some(p) = partition.strip_prefix("cloud:") {
            Ok((true, p.to_string()))
        } else {
            bail!(
                "hybrid: partition '{partition}' must be prefixed 'hpc:' or 'cloud:'"
            )
        }
    }
}

impl SchedulerAdapter for HybridScheduler {
    fn submit(&mut self, mut job: Job) -> Result<JobId> {
        let (is_cloud, inner) = Self::route(&job.partition)?;
        job.partition = inner;
        if is_cloud {
            Ok(self.k8s.submit(job)? | CLOUD_BIT)
        } else {
            self.slurm.submit(job)
        }
    }

    fn tick(&mut self, now_s: f64) -> Vec<(JobId, JobState)> {
        let mut out = self.slurm.tick(now_s);
        out.extend(
            self.k8s
                .tick(now_s)
                .into_iter()
                .map(|(id, st)| (id | CLOUD_BIT, st)),
        );
        out
    }

    fn state(&self, id: JobId) -> Option<JobState> {
        if id & CLOUD_BIT != 0 {
            self.k8s.state(id & !CLOUD_BIT)
        } else {
            self.slurm.state(id)
        }
    }

    fn allocated_nodes(&self) -> Vec<NodeId> {
        let mut v = self.slurm.allocated_nodes();
        v.extend(self.k8s.allocated_nodes());
        v.sort_unstable();
        v
    }

    fn cancel(&mut self, id: JobId) -> Result<()> {
        if id & CLOUD_BIT != 0 {
            self.k8s.cancel(id & !CLOUD_BIT)
        } else {
            self.slurm.cancel(id)
        }
    }

    fn queue_summary(&self) -> String {
        format!(
            "hybrid [{} | {}]",
            self.slurm.queue_summary(),
            self.k8s.queue_summary()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::super::k8s::Pool;
    use super::*;

    fn hybrid() -> HybridScheduler {
        HybridScheduler::new(
            SlurmSim::new(vec![("gpu", vec![0, 1])]),
            K8sSim::new(vec![Pool {
                name: "gpu".into(),
                initial: vec![100, 101],
                scale_reserve: vec![],
                scale_up_delay_s: 10.0,
            }]),
        )
    }

    fn job(client: NodeId, partition: &str) -> Job {
        Job {
            client,
            partition: partition.into(),
            priority: 0,
            walltime_s: 50.0,
            preemptible: false,
        }
    }

    #[test]
    fn routes_by_prefix() {
        let mut h = hybrid();
        let a = h.submit(job(1, "hpc:gpu")).unwrap();
        let b = h.submit(job(2, "cloud:gpu")).unwrap();
        assert_eq!(a & CLOUD_BIT, 0);
        assert_ne!(b & CLOUD_BIT, 0);
        h.tick(0.0);
        h.tick(3.0); // k8s pod start delay
        assert!(h.state(a).unwrap().is_running());
        assert!(h.state(b).unwrap().is_running());
        // HPC node 0/1 + cloud node 100/101 both allocated
        let nodes = h.allocated_nodes();
        assert!(nodes.contains(&0));
        assert!(nodes.contains(&100));
    }

    #[test]
    fn rejects_unprefixed_partition() {
        let mut h = hybrid();
        assert!(h.submit(job(1, "gpu")).is_err());
    }

    #[test]
    fn cancel_routes_correctly() {
        let mut h = hybrid();
        let a = h.submit(job(1, "hpc:gpu")).unwrap();
        let b = h.submit(job(2, "cloud:gpu")).unwrap();
        h.tick(0.0);
        h.cancel(a).unwrap();
        h.cancel(b).unwrap();
        assert_eq!(h.state(a), Some(JobState::Cancelled));
        assert_eq!(h.state(b), Some(JobState::Cancelled));
    }

    #[test]
    fn summary_mentions_both() {
        let h = hybrid();
        let s = h.queue_summary();
        assert!(s.contains("slurm"));
        assert!(s.contains("k8s"));
    }
}
