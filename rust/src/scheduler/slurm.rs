//! SLURM-like batch scheduler simulation.
//!
//! Models the behaviours that matter to FL-on-HPC (Haus et al.; paper
//! §2, §3.2): named partitions with fixed node sets, FIFO-within-
//! priority queueing, exclusive node allocation, walltime-bounded runs
//! and priority preemption. Queue wait — the dominant HPC latency —
//! emerges naturally when jobs outnumber partition nodes.

use super::job::{Job, JobId, JobState};
use super::SchedulerAdapter;
use crate::cluster::NodeId;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

struct Entry {
    job: Job,
    state: JobState,
    submit_seq: u64,
}

/// One SLURM "cluster" with named partitions.
pub struct SlurmSim {
    partitions: BTreeMap<String, Vec<NodeId>>,
    /// node -> job currently occupying it
    busy: BTreeMap<NodeId, JobId>,
    jobs: BTreeMap<JobId, Entry>,
    next_id: JobId,
    seq: u64,
    now_s: f64,
    /// Enable priority preemption of preemptible jobs.
    pub preemption_enabled: bool,
}

impl SlurmSim {
    pub fn new(partitions: Vec<(&str, Vec<NodeId>)>) -> Self {
        SlurmSim {
            partitions: partitions
                .into_iter()
                .map(|(n, v)| (n.to_string(), v))
                .collect(),
            busy: BTreeMap::new(),
            jobs: BTreeMap::new(),
            next_id: 1,
            seq: 0,
            now_s: 0.0,
            preemption_enabled: true,
        }
    }

    fn free_nodes(&self, partition: &str) -> Vec<NodeId> {
        self.partitions
            .get(partition)
            .map(|nodes| {
                nodes
                    .iter()
                    .copied()
                    .filter(|n| !self.busy.contains_key(n))
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Try to start pending jobs (highest priority, then FIFO).
    fn schedule(&mut self, changes: &mut Vec<(JobId, JobState)>) {
        // collect pending ids ordered by (-priority, submit_seq)
        let mut pending: Vec<JobId> = self
            .jobs
            .iter()
            .filter(|(_, e)| e.state == JobState::Pending)
            .map(|(&id, _)| id)
            .collect();
        pending.sort_by_key(|id| {
            let e = &self.jobs[id];
            (-e.job.priority, e.submit_seq)
        });
        for id in pending {
            let partition = self.jobs[&id].job.partition.clone();
            let free = self.free_nodes(&partition);
            if let Some(&node) = free.first() {
                self.busy.insert(node, id);
                let st = JobState::Running {
                    node,
                    since_s: self.now_s,
                };
                self.jobs.get_mut(&id).unwrap().state = st;
                changes.push((id, st));
            } else if self.preemption_enabled {
                // look for a lower-priority preemptible victim
                let my_prio = self.jobs[&id].job.priority;
                let victim = self
                    .partitions
                    .get(&partition)
                    .into_iter()
                    .flatten()
                    .filter_map(|n| self.busy.get(n).map(|&j| (*n, j)))
                    .filter(|(_, j)| {
                        let e = &self.jobs[j];
                        e.job.preemptible && e.job.priority < my_prio
                    })
                    .min_by_key(|(_, j)| self.jobs[j].job.priority);
                if let Some((node, victim_id)) = victim {
                    let st = JobState::Preempted { at_s: self.now_s };
                    self.jobs.get_mut(&victim_id).unwrap().state = st;
                    changes.push((victim_id, st));
                    self.busy.insert(node, id);
                    let st = JobState::Running {
                        node,
                        since_s: self.now_s,
                    };
                    self.jobs.get_mut(&id).unwrap().state = st;
                    changes.push((id, st));
                }
            }
        }
    }
}

impl SchedulerAdapter for SlurmSim {
    fn submit(&mut self, job: Job) -> Result<JobId> {
        if !self.partitions.contains_key(&job.partition) {
            bail!(
                "sbatch: invalid partition '{}' (have: {:?})",
                job.partition,
                self.partitions.keys().collect::<Vec<_>>()
            );
        }
        if job.walltime_s <= 0.0 {
            bail!("sbatch: walltime must be positive");
        }
        let id = self.next_id;
        self.next_id += 1;
        self.seq += 1;
        self.jobs.insert(
            id,
            Entry {
                job,
                state: JobState::Pending,
                submit_seq: self.seq,
            },
        );
        Ok(id)
    }

    fn tick(&mut self, now_s: f64) -> Vec<(JobId, JobState)> {
        assert!(now_s >= self.now_s, "time went backwards");
        self.now_s = now_s;
        let mut changes = Vec::new();
        // complete jobs whose walltime elapsed
        let done: Vec<(JobId, NodeId)> = self
            .jobs
            .iter()
            .filter_map(|(&id, e)| match e.state {
                JobState::Running { node, since_s }
                    if now_s - since_s >= e.job.walltime_s =>
                {
                    Some((id, node))
                }
                _ => None,
            })
            .collect();
        for (id, node) in done {
            self.busy.remove(&node);
            let st = JobState::Completed { at_s: now_s };
            self.jobs.get_mut(&id).unwrap().state = st;
            changes.push((id, st));
        }
        self.schedule(&mut changes);
        changes
    }

    fn state(&self, id: JobId) -> Option<JobState> {
        self.jobs.get(&id).map(|e| e.state)
    }

    fn allocated_nodes(&self) -> Vec<NodeId> {
        self.busy.keys().copied().collect()
    }

    fn cancel(&mut self, id: JobId) -> Result<()> {
        let e = self
            .jobs
            .get_mut(&id)
            .ok_or_else(|| anyhow!("scancel: no such job {id}"))?;
        if e.state.is_terminal() {
            return Ok(()); // idempotent like scancel
        }
        if let JobState::Running { node, .. } = e.state {
            self.busy.remove(&node);
        }
        e.state = JobState::Cancelled;
        Ok(())
    }

    fn queue_summary(&self) -> String {
        let pending = self
            .jobs
            .values()
            .filter(|e| e.state == JobState::Pending)
            .count();
        let running = self.jobs.values().filter(|e| e.state.is_running()).count();
        format!(
            "slurm: {} partitions, {running} running, {pending} pending",
            self.partitions.len()
        )
    }
}

/// SLURM-side probe wiring for the orchestrator's telemetry endpoint:
/// a shell fragment for the batch script that (a) blocks worker
/// startup until `/readyz` answers 200 — queue-delayed workers would
/// otherwise connect before the first round is dispatched and idle
/// against a warming server — and (b) polls `/healthz` in the
/// background, scancel-ing the job if the orchestrator dies so the
/// allocation is released instead of burning walltime.
pub fn health_check_script(telemetry_addr: &str) -> String {
    format!(
        "# fedhpc telemetry probes (orchestrator at {telemetry_addr})\n\
         until curl -sf http://{telemetry_addr}/readyz; do sleep 2; done\n\
         (while curl -sf http://{telemetry_addr}/healthz >/dev/null; do sleep 10; done; \
         scancel \"$SLURM_JOB_ID\") &\n"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn health_check_script_targets_both_probes() {
        let s = health_check_script("10.0.0.5:9469");
        assert!(s.contains("http://10.0.0.5:9469/readyz"));
        assert!(s.contains("http://10.0.0.5:9469/healthz"));
        assert!(s.contains("scancel"), "must release the allocation");
    }

    fn job(client: NodeId, partition: &str, prio: i32, wall: f64) -> Job {
        Job {
            client,
            partition: partition.into(),
            priority: prio,
            walltime_s: wall,
            preemptible: false,
        }
    }

    fn sim2() -> SlurmSim {
        SlurmSim::new(vec![("gpu", vec![0, 1]), ("cpu", vec![2])])
    }

    #[test]
    fn fifo_within_capacity() {
        let mut s = sim2();
        let a = s.submit(job(10, "gpu", 0, 100.0)).unwrap();
        let b = s.submit(job(11, "gpu", 0, 100.0)).unwrap();
        let c = s.submit(job(12, "gpu", 0, 100.0)).unwrap();
        s.tick(0.0);
        assert!(s.state(a).unwrap().is_running());
        assert!(s.state(b).unwrap().is_running());
        assert_eq!(s.state(c), Some(JobState::Pending)); // queue full
        assert_eq!(s.allocated_nodes().len(), 2);
    }

    #[test]
    fn queued_job_starts_when_walltime_frees_node() {
        let mut s = sim2();
        let a = s.submit(job(10, "gpu", 0, 50.0)).unwrap();
        let _b = s.submit(job(11, "gpu", 0, 50.0)).unwrap();
        let c = s.submit(job(12, "gpu", 0, 50.0)).unwrap();
        s.tick(0.0);
        s.tick(49.0);
        assert_eq!(s.state(c), Some(JobState::Pending));
        let changes = s.tick(50.0);
        assert!(changes
            .iter()
            .any(|(id, st)| *id == a && matches!(st, JobState::Completed { .. })));
        assert!(s.state(c).unwrap().is_running());
    }

    #[test]
    fn priority_order() {
        let mut s = SlurmSim::new(vec![("gpu", vec![0])]);
        s.submit(job(1, "gpu", 0, 10.0)).unwrap();
        s.tick(0.0);
        let low = s.submit(job(2, "gpu", 1, 10.0)).unwrap();
        let high = s.submit(job(3, "gpu", 5, 10.0)).unwrap();
        s.tick(10.0); // first job completes; high-prio must win
        assert!(s.state(high).unwrap().is_running());
        assert_eq!(s.state(low), Some(JobState::Pending));
    }

    #[test]
    fn preemption_of_low_priority_preemptible() {
        let mut s = SlurmSim::new(vec![("gpu", vec![0])]);
        let victim = s
            .submit(Job {
                client: 1,
                partition: "gpu".into(),
                priority: 0,
                walltime_s: 1000.0,
                preemptible: true,
            })
            .unwrap();
        s.tick(0.0);
        assert!(s.state(victim).unwrap().is_running());
        let bully = s.submit(job(2, "gpu", 10, 10.0)).unwrap();
        let changes = s.tick(1.0);
        assert!(matches!(
            s.state(victim),
            Some(JobState::Preempted { .. })
        ));
        assert!(s.state(bully).unwrap().is_running());
        assert!(changes.len() >= 2);
    }

    #[test]
    fn no_preemption_when_disabled_or_not_preemptible() {
        let mut s = SlurmSim::new(vec![("gpu", vec![0])]);
        s.preemption_enabled = false;
        let a = s
            .submit(Job {
                client: 1,
                partition: "gpu".into(),
                priority: 0,
                walltime_s: 1000.0,
                preemptible: true,
            })
            .unwrap();
        s.tick(0.0);
        let b = s.submit(job(2, "gpu", 10, 10.0)).unwrap();
        s.tick(1.0);
        assert!(s.state(a).unwrap().is_running());
        assert_eq!(s.state(b), Some(JobState::Pending));
    }

    #[test]
    fn cancel_frees_node_and_is_idempotent() {
        let mut s = sim2();
        let a = s.submit(job(1, "gpu", 0, 100.0)).unwrap();
        s.tick(0.0);
        s.cancel(a).unwrap();
        assert_eq!(s.state(a), Some(JobState::Cancelled));
        assert!(s.allocated_nodes().is_empty() || !s.allocated_nodes().contains(&0));
        s.cancel(a).unwrap(); // idempotent
        assert!(s.cancel(999).is_err());
    }

    #[test]
    fn invalid_partition_rejected() {
        let mut s = sim2();
        assert!(s.submit(job(1, "tpu", 0, 10.0)).is_err());
        assert!(s.submit(job(1, "gpu", 0, 0.0)).is_err());
    }

    #[test]
    fn queue_summary_counts() {
        let mut s = sim2();
        s.submit(job(1, "gpu", 0, 10.0)).unwrap();
        s.submit(job(2, "gpu", 0, 10.0)).unwrap();
        s.submit(job(3, "gpu", 0, 10.0)).unwrap();
        s.tick(0.0);
        let q = s.queue_summary();
        assert!(q.contains("2 running"), "{q}");
        assert!(q.contains("1 pending"), "{q}");
    }
}
