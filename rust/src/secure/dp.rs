//! Differential privacy on the aggregate (paper §6: "differential
//! privacy" under secure aggregation future work).
//!
//! Gaussian mechanism: per-client updates are L2-clipped to bound
//! sensitivity, then calibrated N(0, σ²) noise is added to the
//! aggregate; σ follows the standard analytic bound
//! `σ ≥ clip · √(2 ln(1.25/δ)) / ε` for one release.

use crate::util::rng::Rng;

/// DP configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DpConfig {
    pub epsilon: f64,
    pub delta: f64,
    /// L2 clipping norm applied to each client update.
    pub clip_norm: f64,
}

impl DpConfig {
    /// Noise stddev for one aggregate release.
    pub fn sigma(&self) -> f64 {
        assert!(self.epsilon > 0.0 && self.delta > 0.0 && self.delta < 1.0);
        self.clip_norm * (2.0 * (1.25 / self.delta).ln()).sqrt() / self.epsilon
    }
}

/// Clip `v` in place to L2 norm ≤ `clip`; returns the original norm.
pub fn clip_l2(v: &mut [f32], clip: f64) -> f64 {
    let norm = v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
    if norm > clip && norm > 0.0 {
        let scale = (clip / norm) as f32;
        for x in v.iter_mut() {
            *x *= scale;
        }
    }
    norm
}

/// Add Gaussian noise to an aggregate (noise scaled by 1/n_clients,
/// since the mean of n clipped updates has sensitivity clip/n).
pub fn gaussian_mechanism(agg: &mut [f32], cfg: &DpConfig, n_clients: usize, seed: u64) {
    let sigma = cfg.sigma() / n_clients.max(1) as f64;
    let mut rng = Rng::new(seed ^ 0xD1FF_5EED_0000_0001);
    for x in agg.iter_mut() {
        *x += (sigma * rng.normal()) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigma_monotone_in_privacy() {
        let tight = DpConfig {
            epsilon: 0.5,
            delta: 1e-5,
            clip_norm: 1.0,
        };
        let loose = DpConfig {
            epsilon: 4.0,
            delta: 1e-5,
            clip_norm: 1.0,
        };
        assert!(tight.sigma() > loose.sigma());
    }

    #[test]
    fn clip_preserves_small_and_shrinks_large() {
        let mut small = vec![0.1f32, 0.2];
        let n = clip_l2(&mut small, 10.0);
        assert!(n < 10.0);
        assert_eq!(small, vec![0.1, 0.2]);

        let mut large = vec![3.0f32, 4.0]; // norm 5
        clip_l2(&mut large, 1.0);
        let norm: f64 = large.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
        // direction preserved
        assert!((large[0] / large[1] - 0.75).abs() < 1e-5);
    }

    #[test]
    fn noise_statistics() {
        let cfg = DpConfig {
            epsilon: 1.0,
            delta: 1e-5,
            clip_norm: 1.0,
        };
        let n = 20_000;
        let mut v = vec![0f32; n];
        gaussian_mechanism(&mut v, &cfg, 10, 0);
        let expect_sigma = cfg.sigma() / 10.0;
        let mean = v.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var = v.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < expect_sigma * 0.1, "mean {mean}");
        assert!(
            (var.sqrt() - expect_sigma).abs() / expect_sigma < 0.1,
            "std {} vs {expect_sigma}",
            var.sqrt()
        );
    }

    #[test]
    fn noise_deterministic_in_seed() {
        let cfg = DpConfig {
            epsilon: 1.0,
            delta: 1e-5,
            clip_norm: 1.0,
        };
        let mut a = vec![0f32; 50];
        let mut b = vec![0f32; 50];
        gaussian_mechanism(&mut a, &cfg, 5, 7);
        gaussian_mechanism(&mut b, &cfg, 5, 7);
        assert_eq!(a, b);
    }
}
