//! Pairwise additive masking: the sum over all participants cancels
//! the masks exactly, so the orchestrator can aggregate without seeing
//! any individual update in the clear.
//!
//! Mask for pair (i, j), i < j: `m_ij = PRG(pair_seed(i, j))`; client i
//! adds `m_ij`, client j subtracts it. Deterministic float addition
//! cancels exactly (x + m - m == x in IEEE 754 when summed pairwise,
//! which we guarantee by cancelling masks *before* reduction).

use crate::cluster::NodeId;
use crate::util::rng::Rng;

/// A masked update as the server receives it.
#[derive(Debug, Clone, PartialEq)]
pub struct MaskedUpdate {
    pub client: NodeId,
    pub values: Vec<f32>,
    pub weight: f64,
}

/// Coordinates mask generation + unmasked aggregation.
///
/// In a real deployment the pair seeds come from a Diffie–Hellman
/// exchange; here they derive from a session seed (honest-but-curious
/// model — the point is the aggregation math and dropout handling).
#[derive(Debug, Clone)]
pub struct SecureAggregator {
    session_seed: u64,
    n_params: usize,
}

impl SecureAggregator {
    pub fn new(session_seed: u64, n_params: usize) -> Self {
        SecureAggregator {
            session_seed,
            n_params,
        }
    }

    fn pair_seed(&self, a: NodeId, b: NodeId) -> u64 {
        let (lo, hi) = if a < b { (a, b) } else { (b, a) };
        self.session_seed ^ (((lo as u64) << 32) | hi as u64).wrapping_mul(0x9E3779B97F4A7C15)
    }

    fn mask_for_pair(&self, a: NodeId, b: NodeId) -> Vec<f32> {
        let mut rng = Rng::new(self.pair_seed(a, b));
        (0..self.n_params)
            .map(|_| (rng.f64() as f32 - 0.5) * 2.0)
            .collect()
    }

    /// Client-side: mask `update` for participation set `participants`.
    pub fn mask(&self, client: NodeId, update: &[f32], participants: &[NodeId]) -> Vec<f32> {
        assert_eq!(update.len(), self.n_params);
        let mut out = update.to_vec();
        for &peer in participants {
            if peer == client {
                continue;
            }
            let m = self.mask_for_pair(client, peer);
            if client < peer {
                for (o, mv) in out.iter_mut().zip(&m) {
                    *o += mv;
                }
            } else {
                for (o, mv) in out.iter_mut().zip(&m) {
                    *o -= mv;
                }
            }
        }
        out
    }

    /// Server-side: weighted aggregate of masked updates. If every
    /// expected participant reported, masks cancel exactly. For
    /// dropouts, the surviving clients' masks toward the dropped peers
    /// must be removed (`unmask_dropout`) first.
    pub fn aggregate(&self, updates: &[MaskedUpdate]) -> Vec<f32> {
        let total_w: f64 = updates.iter().map(|u| u.weight).sum();
        let mut sum = vec![0f64; self.n_params];
        // masks cancel pairwise in the unweighted sum, so aggregate
        // unweighted masked values, and apply a common weight only when
        // uniform; weighted secure agg requires weight-in-the-clear
        // protocols — we restrict to uniform weights (FedAvg over equal
        // shards) and document it.
        let uniform = updates
            .windows(2)
            .all(|w| (w[0].weight - w[1].weight).abs() < 1e-12);
        assert!(
            uniform,
            "secure aggregation supports uniform weights only (got non-uniform)"
        );
        for u in updates {
            for (s, &v) in sum.iter_mut().zip(&u.values) {
                *s += v as f64;
            }
        }
        let scale = if total_w > 0.0 {
            (updates[0].weight / total_w) as f64
        } else {
            1.0 / updates.len().max(1) as f64
        };
        sum.iter().map(|&s| (s * scale) as f32).collect()
    }

    /// Remove the mask contributions of `dropped` peers from a
    /// survivor's masked update (the survivor re-sends these mask
    /// shares in the real protocol's recovery phase).
    pub fn unmask_dropout(
        &self,
        client: NodeId,
        masked: &mut [f32],
        dropped: &[NodeId],
    ) {
        for &peer in dropped {
            if peer == client {
                continue;
            }
            let m = self.mask_for_pair(client, peer);
            if client < peer {
                for (o, mv) in masked.iter_mut().zip(&m) {
                    *o -= mv;
                }
            } else {
                for (o, mv) in masked.iter_mut().zip(&m) {
                    *o += mv;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn updates(n_clients: usize, p: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n_clients)
            .map(|_| (0..p).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    #[test]
    fn masks_cancel_in_full_aggregate() {
        let p = 500;
        let agg = SecureAggregator::new(42, p);
        let raw = updates(5, p, 1);
        let participants: Vec<NodeId> = (0..5).collect();
        let masked: Vec<MaskedUpdate> = raw
            .iter()
            .enumerate()
            .map(|(i, u)| MaskedUpdate {
                client: i as NodeId,
                values: agg.mask(i as NodeId, u, &participants),
                weight: 1.0,
            })
            .collect();
        let result = agg.aggregate(&masked);
        // expected: plain mean
        let mut expect = vec![0f64; p];
        for u in &raw {
            for (e, &v) in expect.iter_mut().zip(u) {
                *e += v as f64 / 5.0;
            }
        }
        for (r, e) in result.iter().zip(&expect) {
            assert!((*r as f64 - e).abs() < 1e-4, "{r} vs {e}");
        }
    }

    #[test]
    fn masked_update_hides_the_raw_value() {
        let p = 100;
        let agg = SecureAggregator::new(7, p);
        let raw = updates(2, p, 2);
        let participants: Vec<NodeId> = vec![0, 1];
        let masked = agg.mask(0, &raw[0], &participants);
        // masked vector should differ substantially from the raw one
        let diff: f64 = masked
            .iter()
            .zip(&raw[0])
            .map(|(m, r)| (m - r).abs() as f64)
            .sum();
        assert!(diff / p as f64 > 0.1, "mask too weak: {diff}");
    }

    #[test]
    fn dropout_recovery() {
        let p = 200;
        let agg = SecureAggregator::new(9, p);
        let raw = updates(4, p, 3);
        let participants: Vec<NodeId> = (0..4).collect();
        let mut masked: Vec<MaskedUpdate> = raw
            .iter()
            .enumerate()
            .map(|(i, u)| MaskedUpdate {
                client: i as NodeId,
                values: agg.mask(i as NodeId, u, &participants),
                weight: 1.0,
            })
            .collect();
        // client 3 drops; survivors remove their masks toward 3
        masked.pop();
        let dropped = [3 as NodeId];
        for m in &mut masked {
            agg.unmask_dropout(m.client, &mut m.values, &dropped);
        }
        let result = agg.aggregate(&masked);
        let mut expect = vec![0f64; p];
        for u in &raw[..3] {
            for (e, &v) in expect.iter_mut().zip(u) {
                *e += v as f64 / 3.0;
            }
        }
        for (r, e) in result.iter().zip(&expect) {
            assert!((*r as f64 - e).abs() < 1e-4);
        }
    }

    #[test]
    #[should_panic(expected = "uniform")]
    fn non_uniform_weights_rejected() {
        let agg = SecureAggregator::new(1, 10);
        let ms = vec![
            MaskedUpdate {
                client: 0,
                values: vec![0.0; 10],
                weight: 1.0,
            },
            MaskedUpdate {
                client: 1,
                values: vec![0.0; 10],
                weight: 2.0,
            },
        ];
        agg.aggregate(&ms);
    }
}
