//! Pairwise additive masking: the sum over all participants cancels
//! the masks exactly, so the orchestrator can aggregate without seeing
//! any individual update in the clear.
//!
//! Mask for pair (i, j), i < j: `m_ij = PRG(pair_seed(i, j))`; client i
//! adds `m_ij`, client j subtracts it.
//!
//! Two domains:
//!
//! * **Float** ([`SecureAggregator::mask`] /
//!   [`SecureAggregator::aggregate`]) — masks applied in f32.
//!   Cancellation is *approximate*: IEEE-754 addition rounds, so
//!   `(x₀+m) + (x₁−m)` recovers `x₀+x₁` only to within rounding noise.
//!   Fine for experiments; not bit-exact.
//! * **Fixed point** ([`SecureAggregator::mask_fixed`] /
//!   [`SecureAggregator::aggregate_fixed`]) — the real-SecAgg
//!   construction (Bonawitz et al.): quantize to `i64` at
//!   [`FIXED_SCALE`], mask additively in `Z_2^64` (wrapping), sum in
//!   `Z_2^64`. Modular masks cancel *exactly*, so masked aggregation
//!   is **bit-identical** to the unmasked fixed-point aggregate
//!   ([`SecureAggregator::aggregate_fixed_unmasked`]) — pinned by a
//!   property test in `rust/tests/prop_invariants.rs`.

use crate::cluster::NodeId;
use crate::util::rng::Rng;

/// Fixed-point quantization scale for the exact-cancellation path:
/// values are stored as `round(x · 2^24)` in i64, leaving ~2^39 of
/// headroom before a k-client sum could overflow for |x| ≤ ~1e4.
pub const FIXED_SCALE: f64 = (1u64 << 24) as f64;

/// A masked update as the server receives it.
#[derive(Debug, Clone, PartialEq)]
pub struct MaskedUpdate {
    pub client: NodeId,
    pub values: Vec<f32>,
    pub weight: f64,
}

/// Coordinates mask generation + unmasked aggregation.
///
/// In a real deployment the pair seeds come from a Diffie–Hellman
/// exchange; here they derive from a session seed (honest-but-curious
/// model — the point is the aggregation math and dropout handling).
#[derive(Debug, Clone)]
pub struct SecureAggregator {
    session_seed: u64,
    n_params: usize,
}

impl SecureAggregator {
    pub fn new(session_seed: u64, n_params: usize) -> Self {
        SecureAggregator {
            session_seed,
            n_params,
        }
    }

    fn pair_seed(&self, a: NodeId, b: NodeId) -> u64 {
        let (lo, hi) = if a < b { (a, b) } else { (b, a) };
        self.session_seed ^ (((lo as u64) << 32) | hi as u64).wrapping_mul(0x9E3779B97F4A7C15)
    }

    fn mask_for_pair(&self, a: NodeId, b: NodeId) -> Vec<f32> {
        let mut rng = Rng::new(self.pair_seed(a, b));
        (0..self.n_params)
            .map(|_| (rng.f64() as f32 - 0.5) * 2.0)
            .collect()
    }

    /// Client-side: mask `update` for participation set `participants`.
    pub fn mask(&self, client: NodeId, update: &[f32], participants: &[NodeId]) -> Vec<f32> {
        assert_eq!(update.len(), self.n_params);
        let mut out = update.to_vec();
        for &peer in participants {
            if peer == client {
                continue;
            }
            let m = self.mask_for_pair(client, peer);
            if client < peer {
                for (o, mv) in out.iter_mut().zip(&m) {
                    *o += mv;
                }
            } else {
                for (o, mv) in out.iter_mut().zip(&m) {
                    *o -= mv;
                }
            }
        }
        out
    }

    /// Server-side: weighted aggregate of masked updates. If every
    /// expected participant reported, masks cancel exactly. For
    /// dropouts, the surviving clients' masks toward the dropped peers
    /// must be removed (`unmask_dropout`) first.
    pub fn aggregate(&self, updates: &[MaskedUpdate]) -> Vec<f32> {
        let total_w: f64 = updates.iter().map(|u| u.weight).sum();
        let mut sum = vec![0f64; self.n_params];
        // masks cancel pairwise in the unweighted sum, so aggregate
        // unweighted masked values, and apply a common weight only when
        // uniform; weighted secure agg requires weight-in-the-clear
        // protocols — we restrict to uniform weights (FedAvg over equal
        // shards) and document it.
        let uniform = updates
            .windows(2)
            .all(|w| (w[0].weight - w[1].weight).abs() < 1e-12);
        assert!(
            uniform,
            "secure aggregation supports uniform weights only (got non-uniform)"
        );
        for u in updates {
            for (s, &v) in sum.iter_mut().zip(&u.values) {
                *s += v as f64;
            }
        }
        let scale = if total_w > 0.0 {
            (updates[0].weight / total_w) as f64
        } else {
            1.0 / updates.len().max(1) as f64
        };
        sum.iter().map(|&s| (s * scale) as f32).collect()
    }

    /// One pair's mask in the modular fixed-point domain.
    fn mask_words_for_pair(&self, a: NodeId, b: NodeId) -> Vec<u64> {
        let mut rng = Rng::new(self.pair_seed(a, b) ^ 0xF1DE);
        (0..self.n_params).map(|_| rng.next_u64()).collect()
    }

    /// Quantize one value into the wrapping fixed-point domain.
    fn quantize_fixed(x: f32) -> u64 {
        (x as f64 * FIXED_SCALE).round() as i64 as u64
    }

    /// Client-side, exact-cancellation domain: quantize `update` to
    /// fixed point and apply the pairwise masks with wrapping `Z_2^64`
    /// arithmetic. The result is statistically uniform per coordinate
    /// (a one-time pad over `Z_2^64`), yet sums — with every
    /// participant present — to exactly `Σ round(x·2^24)`.
    pub fn mask_fixed(
        &self,
        client: NodeId,
        update: &[f32],
        participants: &[NodeId],
    ) -> Vec<u64> {
        assert_eq!(update.len(), self.n_params);
        let mut out: Vec<u64> = update.iter().map(|&x| Self::quantize_fixed(x)).collect();
        for &peer in participants {
            if peer == client {
                continue;
            }
            let m = self.mask_words_for_pair(client, peer);
            if client < peer {
                for (o, mv) in out.iter_mut().zip(&m) {
                    *o = o.wrapping_add(*mv);
                }
            } else {
                for (o, mv) in out.iter_mut().zip(&m) {
                    *o = o.wrapping_sub(*mv);
                }
            }
        }
        out
    }

    /// Server-side mean over fixed-point masked updates: wrapping sum
    /// (masks cancel exactly in `Z_2^64`), then dequantize. With a
    /// subset-free round this is bit-identical to
    /// [`SecureAggregator::aggregate_fixed_unmasked`] over the raw
    /// updates — the modular sums are *equal integers*, not merely
    /// close floats.
    pub fn aggregate_fixed(&self, updates: &[&[u64]]) -> Vec<f32> {
        assert!(!updates.is_empty());
        let k = updates.len() as f64;
        (0..self.n_params)
            .map(|j| {
                let mut sum = 0u64;
                for u in updates {
                    sum = sum.wrapping_add(u[j]);
                }
                ((sum as i64 as f64) / (FIXED_SCALE * k)) as f32
            })
            .collect()
    }

    /// The unmasked reference path: quantize each raw update and run
    /// the identical wrapping-sum + dequantize pipeline. Exists so the
    /// bit-identity property has a mask-free twin to compare against
    /// (and so callers can compute the plaintext fixed-point mean).
    pub fn aggregate_fixed_unmasked(&self, raw: &[&[f32]]) -> Vec<f32> {
        let quantized: Vec<Vec<u64>> = raw
            .iter()
            .map(|u| {
                assert_eq!(u.len(), self.n_params);
                u.iter().map(|&x| Self::quantize_fixed(x)).collect()
            })
            .collect();
        let views: Vec<&[u64]> = quantized.iter().map(|v| v.as_slice()).collect();
        self.aggregate_fixed(&views)
    }

    /// Fixed-point counterpart of [`SecureAggregator::unmask_dropout`]:
    /// remove a survivor's mask words toward dropped peers (wrapping),
    /// restoring exact cancellation for the surviving subset.
    pub fn unmask_dropout_fixed(
        &self,
        client: NodeId,
        masked: &mut [u64],
        dropped: &[NodeId],
    ) {
        for &peer in dropped {
            if peer == client {
                continue;
            }
            let m = self.mask_words_for_pair(client, peer);
            if client < peer {
                for (o, mv) in masked.iter_mut().zip(&m) {
                    *o = o.wrapping_sub(*mv);
                }
            } else {
                for (o, mv) in masked.iter_mut().zip(&m) {
                    *o = o.wrapping_add(*mv);
                }
            }
        }
    }

    /// Remove the mask contributions of `dropped` peers from a
    /// survivor's masked update (the survivor re-sends these mask
    /// shares in the real protocol's recovery phase).
    pub fn unmask_dropout(
        &self,
        client: NodeId,
        masked: &mut [f32],
        dropped: &[NodeId],
    ) {
        for &peer in dropped {
            if peer == client {
                continue;
            }
            let m = self.mask_for_pair(client, peer);
            if client < peer {
                for (o, mv) in masked.iter_mut().zip(&m) {
                    *o -= mv;
                }
            } else {
                for (o, mv) in masked.iter_mut().zip(&m) {
                    *o += mv;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn updates(n_clients: usize, p: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n_clients)
            .map(|_| (0..p).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    #[test]
    fn masks_cancel_in_full_aggregate() {
        let p = 500;
        let agg = SecureAggregator::new(42, p);
        let raw = updates(5, p, 1);
        let participants: Vec<NodeId> = (0..5).collect();
        let masked: Vec<MaskedUpdate> = raw
            .iter()
            .enumerate()
            .map(|(i, u)| MaskedUpdate {
                client: i as NodeId,
                values: agg.mask(i as NodeId, u, &participants),
                weight: 1.0,
            })
            .collect();
        let result = agg.aggregate(&masked);
        // expected: plain mean
        let mut expect = vec![0f64; p];
        for u in &raw {
            for (e, &v) in expect.iter_mut().zip(u) {
                *e += v as f64 / 5.0;
            }
        }
        for (r, e) in result.iter().zip(&expect) {
            assert!((*r as f64 - e).abs() < 1e-4, "{r} vs {e}");
        }
    }

    #[test]
    fn masked_update_hides_the_raw_value() {
        let p = 100;
        let agg = SecureAggregator::new(7, p);
        let raw = updates(2, p, 2);
        let participants: Vec<NodeId> = vec![0, 1];
        let masked = agg.mask(0, &raw[0], &participants);
        // masked vector should differ substantially from the raw one
        let diff: f64 = masked
            .iter()
            .zip(&raw[0])
            .map(|(m, r)| (m - r).abs() as f64)
            .sum();
        assert!(diff / p as f64 > 0.1, "mask too weak: {diff}");
    }

    #[test]
    fn dropout_recovery() {
        let p = 200;
        let agg = SecureAggregator::new(9, p);
        let raw = updates(4, p, 3);
        let participants: Vec<NodeId> = (0..4).collect();
        let mut masked: Vec<MaskedUpdate> = raw
            .iter()
            .enumerate()
            .map(|(i, u)| MaskedUpdate {
                client: i as NodeId,
                values: agg.mask(i as NodeId, u, &participants),
                weight: 1.0,
            })
            .collect();
        // client 3 drops; survivors remove their masks toward 3
        masked.pop();
        let dropped = [3 as NodeId];
        for m in &mut masked {
            agg.unmask_dropout(m.client, &mut m.values, &dropped);
        }
        let result = agg.aggregate(&masked);
        let mut expect = vec![0f64; p];
        for u in &raw[..3] {
            for (e, &v) in expect.iter_mut().zip(u) {
                *e += v as f64 / 3.0;
            }
        }
        for (r, e) in result.iter().zip(&expect) {
            assert!((*r as f64 - e).abs() < 1e-4);
        }
    }

    /// The exact-cancellation domain: masked fixed-point aggregation
    /// is bit-identical to the unmasked fixed-point mean (broad random
    /// coverage lives in `prop_invariants`).
    #[test]
    fn fixed_point_masks_cancel_bit_exactly() {
        let p = 300;
        let agg = SecureAggregator::new(11, p);
        let raw = updates(5, p, 4);
        let participants: Vec<NodeId> = (0..5).collect();
        let masked: Vec<Vec<u64>> = raw
            .iter()
            .enumerate()
            .map(|(i, u)| agg.mask_fixed(i as NodeId, u, &participants))
            .collect();
        // each masked vector differs from its plain quantization
        for (i, m) in masked.iter().enumerate() {
            let plain: Vec<u64> = raw[i]
                .iter()
                .map(|&x| SecureAggregator::quantize_fixed(x))
                .collect();
            assert_ne!(m, &plain, "client {i} update left in the clear");
        }
        let views: Vec<&[u64]> = masked.iter().map(|v| v.as_slice()).collect();
        let got = agg.aggregate_fixed(&views);
        let raws: Vec<&[f32]> = raw.iter().map(|v| v.as_slice()).collect();
        let want = agg.aggregate_fixed_unmasked(&raws);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
        // and the fixed-point mean matches the float mean to within
        // quantization error
        for (j, w) in want.iter().enumerate() {
            let float_mean: f64 =
                raw.iter().map(|u| u[j] as f64).sum::<f64>() / raw.len() as f64;
            assert!((*w as f64 - float_mean).abs() < 1e-5, "coord {j}");
        }
    }

    #[test]
    fn fixed_point_dropout_recovery_stays_bit_exact() {
        let p = 120;
        let agg = SecureAggregator::new(13, p);
        let raw = updates(4, p, 5);
        let participants: Vec<NodeId> = (0..4).collect();
        let mut masked: Vec<Vec<u64>> = raw
            .iter()
            .enumerate()
            .map(|(i, u)| agg.mask_fixed(i as NodeId, u, &participants))
            .collect();
        masked.pop(); // client 3 drops
        for (i, m) in masked.iter_mut().enumerate() {
            agg.unmask_dropout_fixed(i as NodeId, m, &[3]);
        }
        let views: Vec<&[u64]> = masked.iter().map(|v| v.as_slice()).collect();
        let got = agg.aggregate_fixed(&views);
        let raws: Vec<&[f32]> = raw[..3].iter().map(|v| v.as_slice()).collect();
        let want = agg.aggregate_fixed_unmasked(&raws);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "uniform")]
    fn non_uniform_weights_rejected() {
        let agg = SecureAggregator::new(1, 10);
        let ms = vec![
            MaskedUpdate {
                client: 0,
                values: vec![0.0; 10],
                weight: 1.0,
            },
            MaskedUpdate {
                client: 1,
                values: vec![0.0; 10],
                weight: 2.0,
            },
        ];
        agg.aggregate(&ms);
    }
}
