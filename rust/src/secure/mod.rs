//! Secure aggregation + differential privacy (paper §6 future work,
//! implemented here as first-class extensions).
//!
//! * [`masking`] — pairwise additive masking (Bonawitz-style, simplified
//!   to the honest-but-curious model): each client pair (i, j) derives a
//!   shared mask from a common seed; client i adds it, client j
//!   subtracts it, so the server learns only the *sum* of updates.
//! * [`dp`] — Gaussian-mechanism noise on the aggregate with optional
//!   per-client update clipping.

pub mod dp;
pub mod masking;

pub use dp::{clip_l2, gaussian_mechanism, DpConfig};
pub use masking::{MaskedUpdate, SecureAggregator, FIXED_SCALE};
