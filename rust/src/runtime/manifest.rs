//! `artifacts/manifest.json` reader — the contract between
//! `python/compile/aot.py` (writer) and the PJRT runtime (reader).

use crate::util::json::Value;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

pub const SUPPORTED_VERSION: usize = 1;

/// Per-model artifact metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelInfo {
    pub name: String,
    pub n_params: usize,
    pub kernel_impl: String,
    pub train_batch: usize,
    pub eval_batch: usize,
    /// Per-example input shape (no batch dim).
    pub x_shape: Vec<usize>,
    /// "f32" or "i32".
    pub x_dtype: String,
    /// Per-example label shape ([] for scalar labels).
    pub y_shape: Vec<usize>,
    pub samples_per_example: usize,
}

impl ModelInfo {
    pub fn x_len(&self) -> usize {
        self.x_shape.iter().product::<usize>().max(1)
    }

    pub fn y_len(&self) -> usize {
        self.y_shape.iter().product::<usize>().max(1)
    }

    /// Artifact path for a step kind ("init" | "train" | "eval").
    pub fn hlo_path(&self, dir: &Path, kind: &str) -> PathBuf {
        dir.join(format!("{}_{kind}.hlo.txt", self.name))
    }
}

/// The whole manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelInfo>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let dir = PathBuf::from(dir);
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let v = Value::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let version = v
            .req("version")?
            .as_usize()
            .ok_or_else(|| anyhow!("manifest: bad version"))?;
        if version != SUPPORTED_VERSION {
            bail!("manifest version {version} unsupported (want {SUPPORTED_VERSION})");
        }
        let mut models = BTreeMap::new();
        let obj = v
            .req("models")?
            .as_obj()
            .ok_or_else(|| anyhow!("manifest: models must be an object"))?;
        for (name, m) in obj {
            let shape_of = |key: &str| -> Result<Vec<usize>> {
                m.req(key)?
                    .as_arr()
                    .ok_or_else(|| anyhow!("manifest: {key} must be an array"))?
                    .iter()
                    .map(|x| {
                        x.as_usize()
                            .ok_or_else(|| anyhow!("manifest: bad dim in {key}"))
                    })
                    .collect()
            };
            let usize_of = |key: &str| -> Result<usize> {
                m.req(key)?
                    .as_usize()
                    .ok_or_else(|| anyhow!("manifest: {key} must be an integer"))
            };
            let str_of = |key: &str| -> Result<String> {
                Ok(m.req(key)?
                    .as_str()
                    .ok_or_else(|| anyhow!("manifest: {key} must be a string"))?
                    .to_string())
            };
            let info = ModelInfo {
                name: name.clone(),
                n_params: usize_of("n_params")?,
                kernel_impl: str_of("kernel_impl")?,
                train_batch: usize_of("train_batch")?,
                eval_batch: usize_of("eval_batch")?,
                x_shape: shape_of("x_shape")?,
                x_dtype: str_of("x_dtype")?,
                y_shape: shape_of("y_shape")?,
                samples_per_example: usize_of("samples_per_example")?,
            };
            if info.x_dtype != "f32" && info.x_dtype != "i32" {
                bail!("manifest: model {name}: unsupported x_dtype {}", info.x_dtype);
            }
            models.insert(name.clone(), info);
        }
        Ok(Manifest { models, dir })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models.get(name).ok_or_else(|| {
            anyhow!(
                "model '{name}' not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "models": {
        "medmnist_mlp": {
          "n_params": 235146, "kernel_impl": "pallas",
          "train_batch": 32, "eval_batch": 64,
          "x_shape": [784], "x_dtype": "f32", "y_shape": [],
          "samples_per_example": 1,
          "param_names": ["fc1_w"], "param_shapes": [[784, 256]]
        },
        "charlm": {
          "n_params": 60416, "kernel_impl": "pallas",
          "train_batch": 16, "eval_batch": 32,
          "x_shape": [32], "x_dtype": "i32", "y_shape": [32],
          "samples_per_example": 32,
          "param_names": [], "param_shapes": []
        }
      }
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let mlp = m.model("medmnist_mlp").unwrap();
        assert_eq!(mlp.n_params, 235146);
        assert_eq!(mlp.x_len(), 784);
        assert_eq!(mlp.y_len(), 1);
        let lm = m.model("charlm").unwrap();
        assert_eq!(lm.x_dtype, "i32");
        assert_eq!(lm.y_len(), 32);
        assert_eq!(
            lm.hlo_path(&m.dir, "train"),
            PathBuf::from("/tmp/a/charlm_train.hlo.txt")
        );
    }

    #[test]
    fn unknown_model_errors() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        assert!(m.model("resnet50").is_err());
    }

    #[test]
    fn rejects_bad_version_and_dtype() {
        let bad_ver = SAMPLE.replace("\"version\": 1", "\"version\": 99");
        assert!(Manifest::parse(&bad_ver, PathBuf::from(".")).is_err());
        let bad_dtype = SAMPLE.replace("\"f32\"", "\"f64\"");
        assert!(Manifest::parse(&bad_dtype, PathBuf::from(".")).is_err());
    }

    #[test]
    fn load_real_artifacts_if_present() {
        // integration: parse the manifest actually produced by aot.py
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if std::path::Path::new(dir).join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(m.models.contains_key("medmnist_mlp"));
            for info in m.models.values() {
                assert!(info.n_params > 0);
                assert!(info.hlo_path(&m.dir, "train").exists());
            }
        }
    }
}
