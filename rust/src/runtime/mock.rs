//! Mock runtime: multinomial logistic regression in pure Rust.
//!
//! Same `ModelRuntime` interface and FedProx update rule as the PJRT
//! path, so every coordinator feature (selection, aggregation,
//! compression, faults) can be exercised in fast tests and virtual-
//! time simulations without compiled artifacts. It *really learns* —
//! integration tests assert accuracy gains, which keeps the FL control
//! loop honest end to end.

use super::{EvalOut, ModelRuntime, StepOut};
use crate::data::Batch;
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// Logistic-regression mock: params = [W (d×c), b (c)].
pub struct MockRuntime {
    pub dim: usize,
    pub classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
}

impl MockRuntime {
    pub fn new(dim: usize, classes: usize) -> Self {
        MockRuntime {
            dim,
            classes,
            train_batch: 16,
            eval_batch: 32,
        }
    }

    /// Matches the medmnist_mlp input so mock and real runs can share
    /// dataset builders.
    pub fn for_medmnist() -> Self {
        MockRuntime::new(784, 10)
    }

    fn forward(&self, params: &[f32], x: &[f32]) -> Vec<f32> {
        // logits[c] = sum_d x[d] * W[d,c] + b[c]
        let (d, c) = (self.dim, self.classes);
        let w = &params[..d * c];
        let b = &params[d * c..];
        let mut logits = b.to_vec();
        for (i, &xv) in x.iter().enumerate() {
            if xv != 0.0 {
                let row = &w[i * c..(i + 1) * c];
                for (l, &wv) in logits.iter_mut().zip(row) {
                    *l += xv * wv;
                }
            }
        }
        logits
    }

    fn softmax_inplace(logits: &mut [f32]) {
        let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut z = 0f32;
        for l in logits.iter_mut() {
            *l = (*l - m).exp();
            z += *l;
        }
        for l in logits.iter_mut() {
            *l /= z;
        }
    }
}

impl ModelRuntime for MockRuntime {
    fn n_params(&self) -> usize {
        self.dim * self.classes + self.classes
    }

    fn train_batch(&self) -> usize {
        self.train_batch
    }

    fn eval_batch(&self) -> usize {
        self.eval_batch
    }

    fn samples_per_example(&self) -> usize {
        1
    }

    fn init(&self, seed: u32) -> Result<Vec<f32>> {
        let mut rng = Rng::new(seed as u64 ^ 0x0C4);
        let scale = (2.0 / self.dim as f64).sqrt();
        Ok((0..self.n_params())
            .map(|i| {
                if i < self.dim * self.classes {
                    (rng.normal() * scale * 0.1) as f32
                } else {
                    0.0
                }
            })
            .collect())
    }

    fn train_step(
        &self,
        params: &[f32],
        global: &[f32],
        batch: &Batch,
        lr: f32,
        mu: f32,
    ) -> Result<StepOut> {
        if params.len() != self.n_params() || global.len() != self.n_params() {
            bail!("mock: param length mismatch");
        }
        let (d, c) = (self.dim, self.classes);
        let n = batch.n;
        let mut grad = vec![0f32; self.n_params()];
        let mut loss = 0f32;
        let mut correct = 0f32;
        for i in 0..n {
            let x = &batch.x[i * d..(i + 1) * d];
            let y = batch.y[i] as usize;
            let mut p = self.forward(params, x);
            let pred = p
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            if pred == y {
                correct += 1.0;
            }
            Self::softmax_inplace(&mut p);
            loss += -(p[y].max(1e-12)).ln();
            // dL/dlogit = p - onehot(y)
            p[y] -= 1.0;
            for (j, &xv) in x.iter().enumerate() {
                if xv != 0.0 {
                    let row = &mut grad[j * c..(j + 1) * c];
                    for (g, &pv) in row.iter_mut().zip(&p) {
                        *g += xv * pv;
                    }
                }
            }
            let gb = &mut grad[d * c..];
            for (g, &pv) in gb.iter_mut().zip(&p) {
                *g += pv;
            }
        }
        let inv_n = 1.0 / n as f32;
        // fused FedProx update — identical rule to the L1 kernel
        let new_params: Vec<f32> = params
            .iter()
            .zip(global)
            .zip(&grad)
            .map(|((&w, &w0), &g)| w - lr * (g * inv_n + mu * (w - w0)))
            .collect();
        Ok(StepOut {
            params: new_params,
            loss: loss * inv_n,
            correct,
        })
    }

    fn eval_step(&self, params: &[f32], batch: &Batch) -> Result<EvalOut> {
        if params.len() != self.n_params() {
            bail!("mock: param length mismatch");
        }
        let d = self.dim;
        let mut loss_sum = 0f32;
        let mut correct = 0f32;
        for i in 0..batch.n {
            let x = &batch.x[i * d..(i + 1) * d];
            let y = batch.y[i] as usize;
            let mut p = self.forward(params, x);
            let pred = p
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            if pred == y {
                correct += 1.0;
            }
            Self::softmax_inplace(&mut p);
            loss_sum += -(p[y].max(1e-12)).ln();
        }
        Ok(EvalOut {
            loss_sum,
            correct,
            n: batch.n as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny linearly-separable task: class = argmax of first `c` dims.
    fn toy_batch(rt: &MockRuntime, n: usize, seed: u64) -> Batch {
        let mut rng = Rng::new(seed);
        let mut x = Vec::with_capacity(n * rt.dim);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let cls = rng.below(rt.classes);
            for j in 0..rt.dim {
                let base = if j % rt.classes == cls { 1.5 } else { 0.0 };
                x.push(base + 0.3 * rng.normal() as f32);
            }
            y.push(cls as i32);
        }
        Batch { x, y, n }
    }

    #[test]
    fn init_shapes_and_determinism() {
        let rt = MockRuntime::new(20, 4);
        assert_eq!(rt.n_params(), 84);
        assert_eq!(rt.init(1).unwrap(), rt.init(1).unwrap());
        assert_ne!(rt.init(1).unwrap(), rt.init(2).unwrap());
    }

    #[test]
    fn learns_separable_task() {
        let rt = MockRuntime::new(20, 4);
        let mut params = rt.init(0).unwrap();
        let global = params.clone();
        let batch = toy_batch(&rt, 64, 1);
        let mut first_loss = None;
        for _ in 0..30 {
            let out = rt.train_step(&params, &global, &batch, 0.1, 0.0).unwrap();
            params = out.params;
            first_loss.get_or_insert(out.loss);
        }
        let eval = rt.eval_step(&params, &toy_batch(&rt, 64, 2)).unwrap();
        assert!(
            eval.accuracy() > 0.8,
            "accuracy {} after training",
            eval.accuracy()
        );
    }

    #[test]
    fn fedprox_mu_pulls_toward_global() {
        let rt = MockRuntime::new(10, 3);
        let params = rt.init(3).unwrap();
        let global = vec![0.0; rt.n_params()];
        let batch = toy_batch(&rt, 16, 4);
        let free = rt.train_step(&params, &global, &batch, 0.1, 0.0).unwrap();
        let prox = rt.train_step(&params, &global, &batch, 0.1, 5.0).unwrap();
        let norm = |v: &[f32]| v.iter().map(|&x| (x * x) as f64).sum::<f64>().sqrt();
        assert!(norm(&prox.params) < norm(&free.params));
    }

    #[test]
    fn rejects_bad_param_length() {
        let rt = MockRuntime::new(10, 3);
        let batch = toy_batch(&rt, 4, 0);
        assert!(rt.train_step(&[0.0; 5], &[0.0; 5], &batch, 0.1, 0.0).is_err());
        assert!(rt.eval_step(&[0.0; 5], &batch).is_err());
    }

    #[test]
    fn loss_counts_are_consistent() {
        let rt = MockRuntime::new(12, 4);
        let params = rt.init(5).unwrap();
        let batch = toy_batch(&rt, 32, 6);
        let e = rt.eval_step(&params, &batch).unwrap();
        assert_eq!(e.n, 32);
        assert!(e.correct >= 0.0 && e.correct <= 32.0);
        assert!(e.mean_loss() > 0.0);
    }
}
