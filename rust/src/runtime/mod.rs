//! Model runtime: executes the AOT-compiled L2/L1 compute from Rust.
//!
//! * [`manifest`] — reads `artifacts/manifest.json` (shapes, dtypes,
//!   batch sizes) written by `python/compile/aot.py`.
//! * [`pjrt`] — the real thing: HLO text → PJRT CPU executable →
//!   `train_step`/`eval_step` over flat `f32[P]` parameter buffers.
//! * [`mock`] — a pure-Rust multinomial-logistic-regression runtime
//!   with the same interface, for tests and timing simulations that
//!   must run without artifacts.
//!
//! Python never runs at training time: the runtime is the only bridge
//! between the Rust coordinator and the paper's model math.

pub mod manifest;
pub mod mock;
pub mod pjrt;

pub use manifest::{Manifest, ModelInfo};
pub use mock::MockRuntime;
pub use pjrt::PjrtRuntime;

use crate::data::Batch;
use anyhow::Result;

/// Outcome of one train step.
#[derive(Debug, Clone, PartialEq)]
pub struct StepOut {
    pub params: Vec<f32>,
    pub loss: f32,
    /// Correct predictions in the batch (label positions for LMs).
    pub correct: f32,
}

/// Outcome of an eval pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalOut {
    pub loss_sum: f32,
    pub correct: f32,
    /// Label positions evaluated.
    pub n: u64,
}

impl EvalOut {
    pub fn accuracy(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.correct as f64 / self.n as f64
        }
    }

    pub fn mean_loss(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.loss_sum as f64 / self.n as f64
        }
    }

    pub fn merge(&mut self, other: EvalOut) {
        self.loss_sum += other.loss_sum;
        self.correct += other.correct;
        self.n += other.n;
    }
}

/// What every runtime backend provides. One instance serves one model.
pub trait ModelRuntime: Send {
    /// Flat parameter count P.
    fn n_params(&self) -> usize;

    /// Train minibatch rows expected by `train_step`.
    fn train_batch(&self) -> usize;

    /// Eval minibatch rows expected by `eval_step`.
    fn eval_batch(&self) -> usize;

    /// Label positions per example (seq_len for LMs, 1 for images).
    fn samples_per_example(&self) -> usize;

    /// Initialize parameters from a seed.
    fn init(&self, seed: u32) -> Result<Vec<f32>>;

    /// One SGD/FedProx minibatch step (Algorithm 1 line 7, fused with
    /// the L1 kernel's update rule).
    fn train_step(
        &self,
        params: &[f32],
        global: &[f32],
        batch: &Batch,
        lr: f32,
        mu: f32,
    ) -> Result<StepOut>;

    /// Evaluate on one batch.
    fn eval_step(&self, params: &[f32], batch: &Batch) -> Result<EvalOut>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_out_merge_and_ratios() {
        let mut a = EvalOut {
            loss_sum: 10.0,
            correct: 5.0,
            n: 10,
        };
        a.merge(EvalOut {
            loss_sum: 2.0,
            correct: 5.0,
            n: 10,
        });
        assert_eq!(a.n, 20);
        assert_eq!(a.accuracy(), 0.5);
        assert!((a.mean_loss() - 0.6).abs() < 1e-9);
        let empty = EvalOut {
            loss_sum: 0.0,
            correct: 0.0,
            n: 0,
        };
        assert_eq!(empty.accuracy(), 0.0);
        assert_eq!(empty.mean_loss(), 0.0);
    }
}
